# pytest: the AOT pipeline emits parseable HLO text + a consistent manifest.
import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.lower_all(str(out))
    return out, manifest


def test_all_models_emitted(artifacts):
    out, manifest = artifacts
    assert set(manifest) == set(aot.MODELS)
    for name, entry in manifest.items():
        path = out / entry["file"]
        assert path.exists(), name
        text = path.read_text()
        assert "ENTRY" in text and "HloModule" in text


def test_manifest_matches_specs(artifacts):
    out, manifest = artifacts
    for name, (fn, specs) in aot.MODELS.items():
        entry = manifest[name]
        assert len(entry["inputs"]) == len(specs)
        for got, spec in zip(entry["inputs"], specs):
            assert tuple(got["shape"]) == spec.shape
            assert got["dtype"] == str(spec.dtype)


def test_manifest_json_roundtrip(artifacts):
    out, manifest = artifacts
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest


def test_hlo_is_tuple_rooted(artifacts):
    # Lowered with return_tuple=True; the rust side unwraps with to_tuple1.
    out, manifest = artifacts
    for entry in manifest.values():
        text = (out / entry["file"]).read_text()
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert root_lines, entry["file"]
        assert any("tuple" in l for l in root_lines), entry["file"]
