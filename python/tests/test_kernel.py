# pytest: Pallas kernel vs pure-jnp ref — the CORE correctness signal.
# hypothesis sweeps shapes (block-multiples) and values; assert_allclose
# against ref.py before aot.py may emit artifacts.
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(deadline=None, max_examples=20)

finite_f32 = st.floats(
    min_value=-1e3, max_value=1e3, allow_nan=False, allow_infinity=False, width=32
)


def arrays(shape):
    return hnp.arrays(np.float32, shape, elements=finite_f32)


@st.composite
def fma_operands(draw):
    rows = draw(st.sampled_from([8, 16, 24, 32]))
    shape = (rows, 128)
    return tuple(draw(arrays(shape)) for _ in range(3))


@st.composite
def matmul_operands(draw):
    m = draw(st.sampled_from([128, 256]))
    k = draw(st.sampled_from([128, 256]))
    n = draw(st.sampled_from([128, 256]))
    x = draw(arrays((m, k)))
    y = draw(arrays((k, n)))
    return x, y


class TestFma:
    @settings(**SETTINGS)
    @given(fma_operands())
    def test_matches_ref(self, ops):
        x, m, b = ops
        got = kernels.fma(x, m, b)
        np.testing.assert_allclose(got, ref.fma_ref(x, m, b), rtol=1e-4, atol=1e-5)

    def test_flat_wrapper(self):
        rng = np.random.default_rng(0)
        x, m, b = (rng.standard_normal(4096).astype(np.float32) for _ in range(3))
        got = kernels.fma_flat(x, m, b)
        np.testing.assert_allclose(got, ref.fma_ref(x, m, b), rtol=1e-4, atol=1e-5)
        assert got.shape == (4096,)

    def test_rejects_bad_lanes(self):
        bad = np.zeros((8, 64), np.float32)
        with pytest.raises(ValueError, match="lanes"):
            kernels.fma(bad, bad, bad)

    def test_rejects_unaligned_rows(self):
        bad = np.zeros((9, 128), np.float32)
        with pytest.raises(ValueError, match="block_rows"):
            kernels.fma(bad, bad, bad)

    @pytest.mark.parametrize("block_rows", [4, 8, 16])
    def test_block_shape_invariance(self, block_rows):
        rng = np.random.default_rng(1)
        x, m, b = (rng.standard_normal((16, 128)).astype(np.float32) for _ in range(3))
        got = kernels.fma(x, m, b, block_rows=block_rows)
        np.testing.assert_allclose(got, ref.fma_ref(x, m, b), rtol=1e-4, atol=1e-5)


class TestRelax:
    @settings(**SETTINGS)
    @given(fma_operands())
    def test_matches_ref(self, ops):
        dv, du, w = ops
        got = kernels.relax(dv, du, w)
        np.testing.assert_allclose(got, ref.relax_ref(dv, du, w), rtol=1e-6)

    def test_flat_wrapper(self):
        rng = np.random.default_rng(2)
        dv, du, w = (rng.standard_normal(4096).astype(np.float32) for _ in range(3))
        got = kernels.relax_flat(dv, du, w)
        np.testing.assert_allclose(got, ref.relax_ref(dv, du, w), rtol=1e-6)

    def test_idempotent_on_self(self):
        # min(dv, dv + 0) == dv — merge-able op sanity (paper Def. 2).
        dv = np.linspace(-5, 5, 8 * 128, dtype=np.float32).reshape(8, 128)
        zero = np.zeros_like(dv)
        np.testing.assert_array_equal(kernels.relax(dv, dv, zero), dv)


class TestTileMatmul:
    @settings(deadline=None, max_examples=8)
    @given(matmul_operands())
    def test_matches_ref(self, ops):
        x, y = ops
        got = kernels.tile_matmul(x, y)
        np.testing.assert_allclose(
            got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-2
        )

    def test_identity(self):
        eye = np.eye(256, dtype=np.float32)
        rng = np.random.default_rng(3)
        x = rng.standard_normal((256, 256)).astype(np.float32)
        np.testing.assert_allclose(kernels.tile_matmul(eye, x), x, rtol=1e-5)

    def test_k_accumulation(self):
        # K spans multiple tiles — exercises the accumulate-over-grid path.
        rng = np.random.default_rng(4)
        x = rng.standard_normal((128, 512)).astype(np.float32)
        y = rng.standard_normal((512, 128)).astype(np.float32)
        got = kernels.tile_matmul(x, y, bk=128)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-2)

    def test_rejects_mismatched_contraction(self):
        x = np.zeros((128, 128), np.float32)
        y = np.zeros((256, 128), np.float32)
        with pytest.raises(ValueError, match="contraction"):
            kernels.tile_matmul(x, y)

    def test_rejects_unaligned(self):
        x = np.zeros((100, 128), np.float32)
        y = np.zeros((128, 128), np.float32)
        with pytest.raises(ValueError, match="divisible"):
            kernels.tile_matmul(x, y)
