# pytest: L2 model graphs vs refs at the AOT artifact shapes.
import jax
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def rng():
    return np.random.default_rng(42)


def test_ycsb_batch(rng):
    vals, mul, add = (
        rng.standard_normal(aot.BATCH).astype(np.float32) for _ in range(3)
    )
    got = model.ycsb_batch(vals, mul, add)
    np.testing.assert_allclose(got, ref.ycsb_batch_ref(vals, mul, add), rtol=1e-4, atol=1e-5)


def test_spmv_panel(rng):
    a = rng.standard_normal((aot.TILE_M, aot.TILE_K)).astype(np.float32)
    x = rng.standard_normal((aot.TILE_K, aot.PANEL)).astype(np.float32)
    alpha, beta = np.float32(0.85), np.float32(0.15)
    got = model.spmv_panel(a, x, alpha, beta)
    np.testing.assert_allclose(
        got, ref.spmv_panel_ref(a, x, alpha, beta), rtol=1e-4, atol=1e-2
    )


def test_relax_batch(rng):
    dv, du, w = (
        rng.standard_normal(aot.BATCH).astype(np.float32) for _ in range(3)
    )
    got = model.relax_batch(dv, du, w)
    np.testing.assert_allclose(got, ref.relax_batch_ref(dv, du, w), rtol=1e-6)


@pytest.mark.parametrize("name", sorted(aot.MODELS))
def test_models_trace_at_manifest_shapes(name):
    fn, specs = aot.MODELS[name]
    out = jax.eval_shape(fn, *specs)
    assert out.dtype == np.float32
    assert all(d > 0 for d in out.shape) or out.shape == ()
