"""AOT compile path: lower every L2 model to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README.md).

Writes artifacts/<name>.hlo.txt plus artifacts/manifest.json describing the
fixed input/output shapes the Rust runtime must honor (it pads batches to
these shapes).

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32

# Fixed AOT shapes.  Batches in Rust are padded to BATCH; the panel step is
# a (TILE_M, TILE_K) adjacency block times a (TILE_K, PANEL) value panel.
BATCH = 4096
TILE_M = 512
TILE_K = 512
PANEL = 128

_scalar = jax.ShapeDtypeStruct((), F32)
_batch = jax.ShapeDtypeStruct((BATCH,), F32)

MODELS = {
    "ycsb_batch": (model.ycsb_batch, [_batch, _batch, _batch]),
    "spmv_panel": (
        model.spmv_panel,
        [
            jax.ShapeDtypeStruct((TILE_M, TILE_K), F32),
            jax.ShapeDtypeStruct((TILE_K, PANEL), F32),
            _scalar,
            _scalar,
        ],
    ),
    "relax_batch": (model.relax_batch, [_batch, _batch, _batch]),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in MODELS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shape = jax.eval_shape(fn, *specs)
        manifest[name] = {
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "output": {
                "shape": list(out_shape.shape),
                "dtype": str(out_shape.dtype),
            },
            "file": f"{name}.hlo.txt",
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # TSV twin for the (dependency-light) Rust runtime loader:
    #   name \t file \t in0_shape,in1_shape,... \t out_shape
    # where a shape is dims joined by 'x' ('scalar' for rank 0).
    def fmt(shape):
        return "x".join(map(str, shape)) if shape else "scalar"

    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        for name in sorted(manifest):
            e = manifest[name]
            ins = ",".join(fmt(i["shape"]) for i in e["inputs"])
            f.write(f"{name}\t{e['file']}\t{ins}\t{fmt(e['output']['shape'])}\n")
    print(f"wrote {os.path.join(out_dir, 'manifest.json')} (+.tsv)")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    # Back-compat with the scaffold Makefile's `--out <file>` form.
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    lower_all(out_dir or ".")


if __name__ == "__main__":
    main()
