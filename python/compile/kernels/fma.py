"""Pallas kernel: batched fused multiply-add — the YCSB task lambda.

Each YCSB task in the paper's §4 evaluation "fetches an item from the
key-value store, performs a multiply-and-add operation, and then optionally
writes the updated value back".  Phase 3 of TD-Orch batches the co-located
task lambdas and executes them as one call into this kernel.

TPU layout notes (§Hardware-Adaptation in DESIGN.md): the batch is shaped
(rows, 128) so each block is a whole (block_rows, 128) register tile; the
default block is (8, 128) — one float32 VREG tile — and the grid walks row
blocks, so the HBM->VMEM stream is a single contiguous sweep per operand.
VMEM footprint: 4 refs * 8*128*4B = 16 KiB, trivially double-bufferable.

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is what the AOT path validates.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 8


def _fma_kernel(x_ref, m_ref, b_ref, o_ref):
    o_ref[...] = x_ref[...] * m_ref[...] + b_ref[...]


def fma(x, m, b, block_rows: int = DEFAULT_BLOCK_ROWS):
    """out[i,j] = x[i,j] * m[i,j] + b[i,j] over (rows, 128) arrays.

    ``rows`` must be a multiple of ``block_rows``.
    """
    rows, lanes = x.shape
    if lanes != LANES:
        raise ValueError(f"fma expects {LANES} lanes, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        _fma_kernel,
        grid=(rows // block_rows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), x.dtype),
        interpret=True,
    )(x, m, b)


def fma_flat(x, m, b, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Flat-vector wrapper: (n,) arrays with n a multiple of 128*block_rows."""
    n = x.shape[0]
    rows = n // LANES
    r = lambda a: a.reshape(rows, LANES)
    return fma(r(x), r(m), r(b), block_rows=block_rows).reshape(n)
