"""L1: Pallas kernels for the paper's compute hot-spots (build-time only)."""

from .fma import fma, fma_flat
from .relax import relax, relax_flat
from .tile_matmul import tile_matmul

__all__ = ["fma", "fma_flat", "relax", "relax_flat", "tile_matmul"]
