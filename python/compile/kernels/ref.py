"""Pure-jnp oracles for every Pallas kernel — the build-time correctness
signal.  pytest asserts kernel(...) == ref(...) under hypothesis-driven
shape/value sweeps before aot.py is allowed to emit artifacts.
"""

import jax.numpy as jnp


def fma_ref(x, m, b):
    return x * m + b


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def relax_ref(dv, du, w):
    return jnp.minimum(dv, du + w)


def ycsb_batch_ref(vals, mul, add):
    return vals * mul + add


def spmv_panel_ref(a, x, alpha, beta):
    return alpha * jnp.dot(a, x, preferred_element_type=jnp.float32) + beta


def relax_batch_ref(dv, du, w):
    return jnp.minimum(dv, du + w)
