"""Pallas kernel: MXU-tiled matmul — the dense-mode aggregation hot spot.

TDO-GP's dense mode (and the linear-algebra baseline family the paper
compares against, Graphite/LA3) reduces each round to a per-machine
adjacency-block x value-panel product.  The panel width is 128 so a column
block is one MXU operand tile; multi-source algorithms (batched BC /
landmark queries) use the full panel, single-vector PR uses column 0.

TPU layout notes: classic (128,128,128) systolic-array tiling.  The grid is
(m/bm, n/bn, k/bk) with k innermost, accumulating into the output ref —
BlockSpec expresses the HBM<->VMEM schedule that a CUDA version would have
written with threadblocks + shared memory.  VMEM per step: 3 * 64 KiB
tiles = 192 KiB « 16 MiB, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.named_call, name="tile_matmul")
def tile_matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """x @ y with (bm, bn, bk) MXU tiles; dims must divide evenly."""
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {k} vs {k2}")
    if m % bm or n % bn or k % bk:
        raise ValueError(f"({m},{k},{n}) not divisible by tiles ({bm},{bk},{bn})")
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
