"""Pallas kernel: batched min-plus relaxation — the SSSP task lambda.

A co-located SSSP batch holds, per task (edge), the source distance du,
the edge weight w and the current destination distance dv; the lambda is
dv' = min(dv, du + w).  Same (rows, 128) lane layout as fma.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK_ROWS = 8


def _relax_kernel(dv_ref, du_ref, w_ref, o_ref):
    o_ref[...] = jnp.minimum(dv_ref[...], du_ref[...] + w_ref[...])


def relax(dv, du, w, block_rows: int = DEFAULT_BLOCK_ROWS):
    """out = min(dv, du + w) over (rows, 128) float32 arrays."""
    rows, lanes = dv.shape
    if lanes != LANES:
        raise ValueError(f"relax expects {LANES} lanes, got {lanes}")
    if rows % block_rows:
        raise ValueError(f"rows={rows} not a multiple of block_rows={block_rows}")
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return pl.pallas_call(
        _relax_kernel,
        grid=(rows // block_rows,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, lanes), dv.dtype),
        interpret=True,
    )(dv, du, w)


def relax_flat(dv, du, w, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Flat-vector wrapper: (n,) arrays, n a multiple of 128*block_rows."""
    n = dv.shape[0]
    rows = n // LANES
    r = lambda a: a.reshape(rows, LANES)
    return relax(r(dv), r(du), r(w), block_rows=block_rows).reshape(n)
