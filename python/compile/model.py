"""L2: JAX compute graphs for the batched task lambdas of TD-Orch Phase 3.

Each function here is a build-time JAX model that calls the L1 Pallas
kernels; aot.py lowers them once to HLO text and the Rust coordinator
(rust/src/runtime/) executes the artifacts on its hot path.  Python is
never on the request path.

Entry points (names are the artifact names):
  ycsb_batch  — the KV-store case study's per-task lambda (paper §4):
                out = vals * mul + add over a padded batch.
  spmv_panel  — dense-mode aggregation / linear-algebra baseline step:
                out = alpha * (A @ X) + beta on a per-machine tile.
  relax_batch — SSSP relaxation lambda: out = min(dv, du + w).
"""

import jax.numpy as jnp

from . import kernels


def ycsb_batch(vals, mul, add):
    """Batched YCSB multiply-and-add lambda over flat (n,) f32 arrays."""
    return kernels.fma_flat(vals, mul, add)


def spmv_panel(a, x, alpha, beta):
    """alpha * (a @ x) + beta: (m,k) adjacency tile times (k,128) panel.

    alpha/beta are f32 scalars; the matmul runs on the MXU-tiled Pallas
    kernel so XLA fuses the scale/shift into the same module.
    """
    return alpha * kernels.tile_matmul(a, x) + beta


def relax_batch(dv, du, w):
    """Batched min-plus SSSP relaxation over flat (n,) f32 arrays."""
    return kernels.relax_flat(dv, du, w)
