//! Hot-spot demo: what happens when *every* task wants the same chunk
//! (the adversarial case of paper §2.3).
//!
//! Prints per-machine execution histograms for the four schedulers:
//! TD-Orch spreads the hot chunk's tasks over transit machines via
//! meta-task trees; direct-push collapses onto the owner.
//!
//! ```sh
//! cargo run --release --example hotspot
//! ```

use tdorch::repro::kv::hotspot_loads;

fn main() {
    let p = 16;
    let n = 64_000;
    println!("== adversarial hot spot: {n} update tasks, ALL targeting one key, P={p} ==\n");

    for (name, loads, imbalance) in hotspot_loads(p, n) {
        println!("{name:<12} imbalance(max/mean) = {imbalance:>6.2}");
        let max = *loads.iter().max().unwrap() as f64;
        for (m, l) in loads.iter().enumerate() {
            let bar = "#".repeat(((*l as f64 / max) * 50.0).round() as usize);
            println!("  machine {m:>2} | {bar} {l}");
        }
        println!();
    }
    println!("hotspot OK");
}
