//! Hot-spot demo, in two acts.
//!
//! **Act 1 — the scheduler view** (paper §2.3): every update task wants
//! the same chunk; per-machine execution histograms show TD-Orch
//! spreading the hot chunk's tasks over transit machines via meta-task
//! trees while direct-push collapses onto the owner.
//!
//! **Act 2 — the serving view** (end to end): the same pathology arising
//! *live*.  One long-lived serving engine takes a Zipf-hot query stream
//! while an insert-heavy, sharply-Zipf mutation feed accretes edges onto
//! the hottest sources' owners, so the initially balanced placement
//! drifts into a hotspot.  Two legs on identical traffic:
//!
//! * **static** — the drift stays; every post-drift wave pays the
//!   straggler under work-sensitive pricing;
//! * **adaptive** — a `PlacementController` watches the flight
//!   recorder's per-machine work and, between dispatches, splits the hot
//!   block (replicating the read-hot source) and migrates blocks
//!   hot→cold, in place, without re-ingesting.
//!
//! The demo prints per-machine load bars from the adaptive leg's own
//! recorder — the drifted picture before the first migration vs the
//! repaired picture after — plus the static/adaptive goodput comparison.
//!
//! ```sh
//! cargo run --release --example hotspot
//! ```

use tdorch::exec::Substrate;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::mutate::{generate_mutations, MutationConfig, MutationFeed};
use tdorch::obs::{EventKind, FlightRecorder};
use tdorch::place::{PlacementController, PlacementPolicy};
use tdorch::repro::kv::hotspot_loads;
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServeReport, Server};
use tdorch::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryMix, StreamConfig,
};
use tdorch::{Cluster, CostModel};

const P: usize = 8;
const QUERIES: usize = 24;
const SEED: u64 = 7;

fn bars(title: &str, loads: &[u64]) {
    println!("{title}");
    let max = loads.iter().copied().max().unwrap_or(0).max(1) as f64;
    for (m, l) in loads.iter().enumerate() {
        let bar = "#".repeat(((*l as f64 / max) * 50.0).round() as usize);
        println!("  machine {m:>2} | {bar} {l}");
    }
    println!();
}

/// Serve the drifting workload once; `adaptive` decides whether a
/// placement controller rides along.  Returns the report plus the
/// per-machine work sums of the drifted-but-unrepaired window (after the
/// last mutation batch, before the first migration) and of everything
/// after the first migration (empty on the static leg).
fn serve_leg(
    dg: tdorch::graph::ingest::DistGraph,
    stream: &[Query],
    batches: &[tdorch::mutate::MutationBatch],
    cfg: ServeConfig,
    adaptive: bool,
) -> (ServeReport, Vec<u64>, Vec<u64>) {
    let cost = CostModel::paper_cluster();
    let rec = FlightRecorder::shared(1 << 16);
    let mut server = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(P, cost),
            dg,
            cost,
            Flags::tdo_gp(),
            if adaptive { "hotspot-adaptive" } else { "hotspot-static" },
            QueryShard::new,
        ),
        cfg,
    );
    server.set_recorder(Some(rec.clone()));
    let mut feed = MutationFeed::new(batches.to_vec());
    let mut src = OpenLoopSource::new(stream);
    let rep = if adaptive {
        let mut ctl = PlacementController::new(
            PlacementPolicy::default().with_trigger(1.03).with_max_moves(1).with_max_rounds(16),
        );
        server.serve(&mut src, RunOpts::new().feed(&mut feed).placement(&mut ctl))
    } else {
        server.serve(&mut src, RunOpts::new().feed(&mut feed))
    };
    let machines = server.engine().sub().machines();
    let mut drifted = vec![0u64; machines];
    let mut repaired = vec![0u64; machines];
    let (mut saw_drift, mut saw_repair) = (false, false);
    for e in rec.lock().unwrap().events() {
        match &e.kind {
            EventKind::MutationApply { .. } if !saw_repair => {
                saw_drift = true;
                drifted.iter_mut().for_each(|x| *x = 0);
            }
            EventKind::PlacementApply { .. } => saw_repair = true,
            EventKind::Superstep { work, .. } => {
                let acc = if saw_repair {
                    &mut repaired
                } else if saw_drift {
                    &mut drifted
                } else {
                    continue;
                };
                for (a, w) in acc.iter_mut().zip(work) {
                    *a += *w;
                }
            }
            _ => {}
        }
    }
    (rep, drifted, repaired)
}

fn main() {
    // ---- Act 1: the adversarial scheduler histogram -------------------
    let n = 64_000;
    println!("== adversarial hot spot: {n} update tasks, ALL targeting one key, P=16 ==\n");
    for (name, loads, imbalance) in hotspot_loads(16, n) {
        println!("{name:<12} imbalance(max/mean) = {imbalance:>6.2}");
        bars("", &loads);
    }

    // ---- Act 2: the same hotspot arising live under serving traffic ---
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(3_000, 6, SEED);
    println!(
        "== live drift: BA graph n={} m={}, P={P}, {QUERIES} Zipf-hot queries + \
         insert-heavy Zipf deltas ==\n",
        g.n,
        g.m()
    );
    let dg = ingest_once(&g, P, cost, Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries: QUERIES,
            per_tick: 2,
            every_ticks: 1,
            zipf_s: 1.5,
            mix: QueryMix { bfs: 1, sssp: 1, pr: 4, cc: 1, bc: 1 },
        },
        &hot,
        SEED.wrapping_add(1),
    );
    let batches = generate_mutations(
        MutationConfig {
            batches: 3,
            ops_per_batch: 200,
            insert_pct: 95,
            zipf_s: 2.5,
            start_tick: 2,
            every_ticks: 3,
        },
        &g,
        &hot,
        SEED.wrapping_add(2),
    );
    let cfg = ServeConfig {
        batch: 4,
        queue_cap: QUERIES,
        work_per_tick: Some((g.m() as u64 / (P as u64 * 4)).max(64)),
        ..ServeConfig::default()
    };

    let (rep_static, drifted_static, _) =
        serve_leg(dg.clone(), &stream, &batches, cfg, false);
    let (rep_adaptive, drifted, repaired) = serve_leg(dg, &stream, &batches, cfg, true);

    bars("static leg, after the drift lands (per-machine superstep work):", &drifted_static);
    bars("adaptive leg, drifted — BEFORE the first migration:", &drifted);
    bars("adaptive leg, AFTER migration + hot-block split:", &repaired);

    for pr in &rep_adaptive.placements {
        println!(
            "placement round {}: {} moves + {} splits at tick {} -> epoch {} ({} service ticks)",
            pr.round, pr.moves, pr.splits, pr.applied_tick, pr.epoch_after, pr.service_ticks
        );
    }
    println!(
        "\nstatic:   {} served in {} ticks — goodput {:.5}/tick",
        rep_static.served(),
        rep_static.ticks,
        rep_static.goodput_per_tick()
    );
    println!(
        "adaptive: {} served in {} ticks — goodput {:.5}/tick ({} placement rounds)",
        rep_adaptive.served(),
        rep_adaptive.ticks,
        rep_adaptive.goodput_per_tick(),
        rep_adaptive.placements.len()
    );
    assert!(rep_adaptive.placements.iter().map(|p| p.moves + p.splits).sum::<usize>() >= 1);
    assert_eq!(rep_static.served(), rep_adaptive.served());
    println!("\nhotspot OK");
}
