//! Quickstart: the task-data orchestration interface in ~30 lines of
//! user code (paper Fig 1).
//!
//! A batch of lambda tasks increments counters stored in distributed
//! chunks: `execute` is the lambda f, `combine` is the merge-able ⊗,
//! `apply` is the write-back ⊙.  Run with:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, OrchApp, Scheduler, Task};
use tdorch::{Cluster, CostModel, DistStore};

/// A distributed counter service.
struct Counters;

impl OrchApp for Counters {
    type Ctx = i64; // the increment each task carries
    type Val = i64; // a counter chunk
    type Out = i64; // merged increments

    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        8
    }
    fn out_words(&self) -> u64 {
        1
    }

    /// f: read the chunk, emit the task's contribution.
    fn execute(&self, inc: &i64, _val: &i64) -> Option<i64> {
        Some(*inc)
    }

    /// ⊗: contributions to the same chunk merge associatively.
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }

    /// ⊙: one merged write-back per chunk.
    fn apply(&self, val: &mut i64, out: i64) {
        *val += out;
    }
}

fn main() {
    let p = 8; // simulated machines
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<i64> = DistStore::new(p);

    // 100k increments over 1k counters, with counter 7 adversarially hot.
    let tasks: Vec<Task<i64>> = (0..100_000)
        .map(|i| {
            let addr = if i % 2 == 0 { 7 } else { i as u64 % 1000 };
            Task::inplace(addr, 1)
        })
        .collect();

    let outcome = TdOrch::new().run_stage(
        &mut cluster,
        &Counters,
        spread_tasks(tasks, p),
        &mut store,
    );

    println!("executed {} tasks on {p} machines", outcome.total_executed);
    println!("hot counter 7 = {}", store.get(7).copied().unwrap_or(0));
    println!(
        "simulated time {:.4}s  (comm {:.4} / comp {:.4} / overhead {:.4})",
        cluster.metrics.sim_seconds(),
        cluster.metrics.time.communication,
        cluster.metrics.time.computation,
        cluster.metrics.time.overhead,
    );
    println!(
        "execution load balance (max/mean): {:.2} — even though half of all tasks hit one chunk",
        tdorch::metrics::Metrics::imbalance(&outcome.executed_per_machine)
    );
    assert_eq!(store.get(7).copied().unwrap_or(0), 50_100); // 50k even + 100 odd i≡7 (mod 1000)
    println!("quickstart OK");
}
