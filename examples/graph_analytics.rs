//! Graph analytics with TDO-GP: all five paper algorithms on a skewed
//! social graph, compared against the prior-system baselines — a small
//! Table 2 (paper §6.2) on the unified SPMD engine.
//!
//! ```sh
//! cargo run --release --example graph_analytics
//! ```

use tdorch::graph::algorithms::{bc, bfs, cc, pagerank, sssp, Algorithm};
use tdorch::graph::baselines::{gemini_like, la_like, ligra_dist};
use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::serve::QueryShard;
use tdorch::{Cluster, CostModel};

fn main() {
    let p = 8;
    let g = gen::barabasi_albert(30_000, 10, 99);
    println!(
        "== TDO-GP graph analytics: BA graph n={} m={} (max degree {}), P={p} ==\n",
        g.n,
        g.m(),
        g.max_degree()
    );

    let cost = CostModel::paper_cluster();
    // Four policy configurations of ONE engine; each holds all five
    // algorithm shards (QueryShard), reset between runs exactly like the
    // serving layer does.
    let mut engines = vec![
        SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new),
        gemini_like(Cluster::new(p, cost), &g, cost, QueryShard::new),
        la_like(Cluster::new(p, cost), &g, cost, QueryShard::new),
        ligra_dist(Cluster::new(p, cost), &g, cost, QueryShard::new),
    ];

    println!(
        "{:<6} {:>11} {:>12} {:>12} {:>12}",
        "Alg", "TDO-GP", "gemini-like", "la-like", "ligra-dist"
    );
    for alg in Algorithm::ALL {
        print!("{:<6}", alg.label());
        for e in engines.iter_mut() {
            e.reset_for_query(|m, meta, st: &mut QueryShard| st.reset(m, meta));
            e.sub_mut().reset_metrics();
            match alg {
                Algorithm::Bfs => {
                    let d = bfs(e, 0);
                    assert!(d.iter().filter(|x| **x >= 0).count() > g.n / 2);
                }
                Algorithm::Sssp => {
                    let d = sssp(e, 0);
                    assert!(d[0] == 0.0);
                }
                Algorithm::Bc => {
                    bc(e, 0);
                }
                Algorithm::Cc => {
                    let labels = cc(e);
                    let comps: std::collections::HashSet<u32> = labels.into_iter().collect();
                    assert!(!comps.is_empty());
                }
                Algorithm::Pr => {
                    let r = pagerank(e, 10);
                    let sum: f64 = r.iter().sum();
                    assert!(sum > 0.5 && sum <= 1.0 + 1e-6);
                }
            }
            print!(" {:>11.4}s", e.sub().metrics.sim_seconds());
        }
        println!();
    }

    // Verify all engines agree on BFS distances (correctness across
    // engine families — they differ only in cost structure).
    let run_bfs = |e: &mut SpmdEngine<Cluster, QueryShard>| {
        e.reset_for_query(|m, meta, st: &mut QueryShard| st.reset(m, meta));
        bfs(e, 0)
    };
    let reference = run_bfs(&mut engines[0]);
    for e in engines.iter_mut().skip(1) {
        let d = run_bfs(e);
        assert_eq!(d, reference, "engine disagrees on BFS");
    }
    println!("\nall engines agree on BFS distances");
    println!("graph_analytics OK");
}
