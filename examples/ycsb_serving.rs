//! End-to-end serving driver — the full three-layer stack on a real
//! workload (DESIGN.md §5).
//!
//! Loads the AOT-compiled Pallas artifacts via PJRT, builds the
//! distributed KV store on a simulated 16-machine cluster, and serves
//! YCSB-A batches end to end: Rust coordinator → TD-Orch 4-phase
//! orchestration → XLA-executed `fma` lambda batches → merge-able
//! write-backs.  Reports throughput, simulated latency per batch,
//! per-machine balance, and speedup over the three §2.3 baselines.
//!
//! ```sh
//! make artifacts && cargo run --release --example ycsb_serving
//! ```

use std::time::Instant;

use tdorch::baselines::{DirectPull, DirectPush, SortingBased};
use tdorch::kvstore::{preload, Bucket, KvApp};
use tdorch::metrics::Metrics;
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::runtime::Engine;
use tdorch::workload::{YcsbKind, YcsbWorkload};
use tdorch::{Cluster, CostModel, DistStore};

const P: usize = 16;
const BATCHES: usize = 16;
const PER_MACHINE: usize = 20_000;
const BUCKETS: u64 = 1 << 16;
const KEYSPACE: u64 = 1_000_000;
const GAMMA: f64 = 1.5;

fn make_batches() -> Vec<Vec<Vec<Task<tdorch::kvstore::KvOp>>>> {
    let workload = YcsbWorkload::new(YcsbKind::A, KEYSPACE, GAMMA, BUCKETS);
    let mut rng = Rng::new(2026);
    let mut seq = 0u64;
    (0..BATCHES)
        .map(|_| {
            (0..P)
                .map(|_| {
                    let b = workload.generate(&mut rng, PER_MACHINE, seq);
                    seq += PER_MACHINE as u64;
                    b
                })
                .collect()
        })
        .collect()
}

fn serve<S: Scheduler<KvApp<'static>>>(
    name: &str,
    sched: &S,
    app: &KvApp<'static>,
    batches: &[Vec<Vec<Task<tdorch::kvstore::KvOp>>>],
) -> f64 {
    let mut cluster = Cluster::new(P, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(P);
    preload(&mut store, BUCKETS, 50_000);
    let wall = Instant::now();
    let mut executed = 0u64;
    let mut worst_imbalance: f64 = 1.0;
    for batch in batches {
        let outcome = sched.run_stage(&mut cluster, app, batch.clone(), &mut store);
        executed += outcome.total_executed;
        worst_imbalance = worst_imbalance.max(Metrics::imbalance(&outcome.executed_per_machine));
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let sim_s = cluster.metrics.sim_seconds();
    let n_ops = (BATCHES * P * PER_MACHINE) as f64;
    assert_eq!(executed as f64, n_ops);
    println!(
        "{name:<12} sim {sim_s:>8.4}s  ({:>6.1}M ops/sim-s)  sim-latency/batch {:>7.3} ms  exec-imbalance {worst_imbalance:>5.2}  [host wall {wall_s:.2}s]",
        n_ops / sim_s / 1e6,
        sim_s / BATCHES as f64 * 1e3,
    );
    sim_s
}

fn main() {
    println!("== TD-Orch end-to-end YCSB-A serving: P={P}, {BATCHES} batches x {PER_MACHINE} ops/machine, Zipf γ={GAMMA} ==\n");

    // L1/L2 artifacts through PJRT — the lambda hot path.
    let engine: &'static Engine = match Engine::load_default() {
        Ok(e) => Box::leak(Box::new(e)),
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("PJRT artifacts loaded: {:?}\n", engine.artifact_names());

    let batches = make_batches();

    let app = KvApp::with_engine(BUCKETS, engine);
    let td_sim = serve("td-orch", &TdOrch::new(), &app, &batches);
    println!(
        "  -> {} of {} lambda executions served by the AOT Pallas kernel\n",
        app.xla_served(),
        BATCHES * P * PER_MACHINE
    );

    // Baselines use the same XLA-backed app: the comparison isolates
    // orchestration, not the lambda backend.
    let push_sim = serve("direct-push", &DirectPush, &app, &batches);
    let pull_sim = serve("direct-pull", &DirectPull, &app, &batches);
    let sort_sim = serve("sorting-mpc", &SortingBased, &app, &batches);

    println!(
        "\nTD-Orch speedup: {:.2}x vs direct-push, {:.2}x vs direct-pull, {:.2}x vs sorting  (paper §4: 2.09x / 2.83x / 1.42x)",
        push_sim / td_sim,
        pull_sim / td_sim,
        sort_sim / td_sim,
    );
    println!("ycsb_serving OK");
}
