//! Profiling driver for the §Perf pass: the per-stage wallclock A/Bs
//! behind the flat shard memory layout (scheduler stage, DetMap vs
//! slab scratch, sparse vs dense frontier, per-message vs batched
//! sends).  Same code path as `repro profile`; pass a rep count:
//!
//! ```sh
//! cargo run --release --example profile_stage -- 20
//! ```

fn main() {
    let reps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let report = tdorch::repro::profile::run_profile(reps);
    // Keep the measured numbers alive past the prints so a future
    // harness can diff the JSON shape.
    std::hint::black_box(report.json());
}
