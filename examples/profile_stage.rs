//! Profiling driver for the §Perf pass: runs TD-Orch stages in a loop.
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, Scheduler, Task};
use tdorch::{Cluster, CostModel, DistStore};

struct CounterApp;
impl tdorch::OrchApp for CounterApp {
    type Ctx = i64; type Val = i64; type Out = i64;
    fn sigma(&self) -> u64 { 2 }
    fn chunk_words(&self) -> u64 { 16 }
    fn out_words(&self) -> u64 { 1 }
    fn execute(&self, c: &i64, _v: &i64) -> Option<i64> { Some(*c) }
    fn combine(&self, a: i64, b: i64) -> i64 { a + b }
    fn apply(&self, v: &mut i64, o: i64) { *v += o; }
}

fn main() {
    let tasks: Vec<Task<i64>> = (0..200_000).map(|i| {
        let addr = if i % 4 == 0 { (i % 16) as u64 } else { (i as u64).wrapping_mul(0x9E3779B9) % 1_000_000 };
        Task::inplace(addr, 1)
    }).collect();
    let reps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    for _ in 0..reps {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let mut s: DistStore<i64> = DistStore::new(16);
        let o = TdOrch::new().run_stage(&mut c, &CounterApp, spread_tasks(tasks.clone(), 16), &mut s);
        std::hint::black_box(o.total_executed);
    }
}
