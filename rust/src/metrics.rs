//! Run metrics: the quantities the paper's evaluation reports.
//!
//! Every superstep contributes to three time series — communication,
//! computation, overhead — which is exactly the breakdown of Fig 10.  We
//! additionally track cumulative per-machine loads so load-balance claims
//! (Def. 1) are testable, and wall-clock time of the simulation itself for
//! the §Perf pass.

/// Time breakdown in simulated seconds (the BSP cost of the run).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub communication: f64,
    pub computation: f64,
    pub overhead: f64,
}

impl Breakdown {
    #[inline]
    pub fn total(&self) -> f64 {
        self.communication + self.computation + self.overhead
    }

    /// Fold another breakdown into this one.  (Named `accumulate`, not
    /// `add`, so it cannot be mistaken for an `std::ops::Add` impl.)
    pub fn accumulate(&mut self, other: &Breakdown) {
        self.communication += other.communication;
        self.computation += other.computation;
        self.overhead += other.overhead;
    }
}

/// Cumulative metrics for one simulated run.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub p: usize,
    pub supersteps: u64,
    pub time: Breakdown,
    /// Total words sent over the whole run (aggregate I in Def. 1).
    pub total_words: u64,
    /// Total messages over the whole run.
    pub total_msgs: u64,
    /// Cumulative words sent, per machine.
    pub sent_by_machine: Vec<u64>,
    /// Cumulative words received, per machine.
    pub recv_by_machine: Vec<u64>,
    /// Cumulative local work units, per machine (W in Def. 1).
    pub work_by_machine: Vec<u64>,
    /// Tasks executed, per machine (Theorem 1(ii)).
    pub executed_by_machine: Vec<u64>,
    /// Cumulative work *makespan*: Σ over ledger supersteps of the
    /// max-over-machines work units of that step.  Unlike the cumulative
    /// per-machine vectors (which fold all steps together), this is what
    /// the critical path actually pays — a placement that halves the
    /// hottest machine's per-step load halves this even when total work
    /// is unchanged.  Built from the same per-step ledger quantities the
    /// flight recorder emits, so it is bit-identical across backends.
    pub makespan_work: u64,
}

impl Metrics {
    pub fn new(p: usize) -> Self {
        Metrics {
            p,
            supersteps: 0,
            time: Breakdown::default(),
            total_words: 0,
            total_msgs: 0,
            sent_by_machine: vec![0; p],
            recv_by_machine: vec![0; p],
            work_by_machine: vec![0; p],
            executed_by_machine: vec![0; p],
            makespan_work: 0,
        }
    }

    /// Simulated runtime in seconds.
    pub fn sim_seconds(&self) -> f64 {
        self.time.total()
    }

    /// max/mean ratio of per-machine quantities — 1.0 is perfect balance.
    pub fn imbalance(xs: &[u64]) -> f64 {
        let max = xs.iter().copied().max().unwrap_or(0) as f64;
        let sum: u64 = xs.iter().sum();
        if sum == 0 {
            return 1.0;
        }
        let mean = sum as f64 / xs.len() as f64;
        max / mean
    }

    pub fn work_imbalance(&self) -> f64 {
        Self::imbalance(&self.work_by_machine)
    }

    /// Per-superstep companion to the cumulative [`Metrics::imbalance`]
    /// accessors: the max/mean factor of ONE superstep's per-machine
    /// loads (the cumulative vectors above fold all steps together and
    /// can hide a single hot step behind a balanced tail).  The flight
    /// recorder's heatmap export reuses this for its per-step imbalance
    /// column.
    pub fn step_imbalance(step_loads: &[u64]) -> f64 {
        Self::imbalance(step_loads)
    }

    pub fn comm_imbalance(&self) -> f64 {
        let combined: Vec<u64> = self
            .sent_by_machine
            .iter()
            .zip(&self.recv_by_machine)
            .map(|(s, r)| s + r)
            .collect();
        Self::imbalance(&combined)
    }

    pub fn exec_imbalance(&self) -> f64 {
        Self::imbalance(&self.executed_by_machine)
    }
}

/// Nearest-rank percentile of `samples` (`q` in [0, 1]; q = 0.5 is the
/// median).  Sorts a copy — the serving layer calls this once per report,
/// not per query.  Empty input yields NaN (there is no sample to report);
/// a single sample is every percentile of itself; ties collapse naturally
/// (every percentile of `[5, 5, 5]` is 5).  NaN *samples* are a caller
/// bug and panic.
pub fn percentile(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return f64::NAN;
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("percentile: NaN sample"));
    rank_in_sorted(&xs, q)
}

#[inline]
fn rank_in_sorted(xs_sorted: &[f64], q: f64) -> f64 {
    let q = q.clamp(0.0, 1.0);
    let rank = (q * xs_sorted.len() as f64).ceil() as usize;
    xs_sorted[rank.clamp(1, xs_sorted.len()) - 1]
}

/// A (p50, p95, p99) latency triple as one named value — what the
/// serving load-curve reports carry per sweep point (in ticks for the
/// deterministic queue/service quantities, in milliseconds for measured
/// wall-clock).  Empty-sample summaries are NaN across the board, like
/// [`percentile`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl LatencySummary {
    pub fn of(samples: &[f64]) -> Self {
        let (p50, p95, p99) = p50_p95_p99(samples);
        LatencySummary { p50, p95, p99 }
    }
}

/// The (p50, p95, p99) triple the serving reports print — one sort,
/// three rank reads.
pub fn p50_p95_p99(samples: &[f64]) -> (f64, f64, f64) {
    if samples.is_empty() {
        return (f64::NAN, f64::NAN, f64::NAN);
    }
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).expect("percentile: NaN sample"));
    (
        rank_in_sorted(&xs, 0.50),
        rank_in_sorted(&xs, 0.95),
        rank_in_sorted(&xs, 0.99),
    )
}

/// Summary of one benchmark run, printable as a paper-style table row.
#[derive(Clone, Debug)]
pub struct Report {
    pub label: String,
    pub sim_seconds: f64,
    pub breakdown: Breakdown,
    pub wall_ms: f64,
    pub supersteps: u64,
    pub total_words: u64,
    pub work_imbalance: f64,
    pub comm_imbalance: f64,
}

impl Report {
    pub fn from_metrics(label: impl Into<String>, m: &Metrics, wall_ms: f64) -> Self {
        Report {
            label: label.into(),
            sim_seconds: m.sim_seconds(),
            breakdown: m.time,
            wall_ms,
            supersteps: m.supersteps,
            total_words: m.total_words,
            work_imbalance: m.work_imbalance(),
            comm_imbalance: m.comm_imbalance(),
        }
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<28} sim={:>9.4}s  (comm {:>8.4} comp {:>8.4} ovhd {:>8.4})  steps={:<5} words={:<10} imb(work)={:.2} imb(comm)={:.2}  wall={:.0}ms",
            self.label,
            self.sim_seconds,
            self.breakdown.communication,
            self.breakdown.computation,
            self.breakdown.overhead,
            self.supersteps,
            self.total_words,
            self.work_imbalance,
            self.comm_imbalance,
            self.wall_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert!((Metrics::imbalance(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_of_hotspot() {
        // One machine does everything: max/mean = P.
        assert!((Metrics::imbalance(&[8, 0, 0, 0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_of_empty_is_one() {
        assert_eq!(Metrics::imbalance(&[0, 0]), 1.0);
    }

    #[test]
    fn step_imbalance_is_per_step_max_over_mean() {
        // One superstep where machine 0 does all 8 units: factor P.
        assert!((Metrics::step_imbalance(&[8, 0, 0, 0]) - 4.0).abs() < 1e-12);
        // An idle (all-zero) step is balanced by convention, like the
        // cumulative accessor.
        assert_eq!(Metrics::step_imbalance(&[0, 0, 0]), 1.0);
    }

    #[test]
    fn breakdown_total() {
        let b = Breakdown { communication: 1.0, computation: 2.0, overhead: 0.5 };
        assert!((b.total() - 3.5).abs() < 1e-12);
    }

    #[test]
    fn breakdown_accumulate_sums_componentwise() {
        let mut a = Breakdown { communication: 1.0, computation: 2.0, overhead: 0.5 };
        let b = Breakdown { communication: 0.25, computation: 0.5, overhead: 0.125 };
        a.accumulate(&b);
        assert_eq!(a, Breakdown { communication: 1.25, computation: 2.5, overhead: 0.625 });
    }

    #[test]
    fn latency_summary_matches_triple() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = LatencySummary::of(&xs);
        assert_eq!((s.p50, s.p95, s.p99), p50_p95_p99(&xs));
        let empty = LatencySummary::of(&[]);
        assert!(empty.p50.is_nan() && empty.p95.is_nan() && empty.p99.is_nan());
    }

    #[test]
    fn percentile_of_empty_is_nan() {
        assert!(percentile(&[], 0.5).is_nan());
        let (a, b, c) = p50_p95_p99(&[]);
        assert!(a.is_nan() && b.is_nan() && c.is_nan());
    }

    #[test]
    fn percentile_of_single_sample_is_that_sample() {
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(percentile(&[7.25], q), 7.25, "q={q}");
        }
    }

    #[test]
    fn percentile_of_tied_samples_collapses() {
        let xs = [5.0, 5.0, 5.0, 5.0];
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile(&xs, q), 5.0, "q={q}");
        }
    }

    #[test]
    fn percentile_nearest_rank_on_1_to_100() {
        // Input deliberately unsorted: percentile sorts internally.
        let mut xs: Vec<f64> = (1..=100).rev().map(|x| x as f64).collect();
        xs.swap(3, 77);
        assert_eq!(percentile(&xs, 0.50), 50.0);
        assert_eq!(percentile(&xs, 0.95), 95.0);
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&xs, 0.0), 1.0, "q=0 is the minimum");
        assert_eq!(percentile(&xs, 1.0), 100.0, "q=1 is the maximum");
        assert_eq!(p50_p95_p99(&xs), (50.0, 95.0, 99.0));
    }

    #[test]
    fn percentile_clamps_out_of_range_q() {
        let xs = [1.0, 2.0, 3.0];
        assert_eq!(percentile(&xs, -0.5), 1.0);
        assert_eq!(percentile(&xs, 1.5), 3.0);
    }
}
