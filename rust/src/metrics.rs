//! Run metrics: the quantities the paper's evaluation reports.
//!
//! Every superstep contributes to three time series — communication,
//! computation, overhead — which is exactly the breakdown of Fig 10.  We
//! additionally track cumulative per-machine loads so load-balance claims
//! (Def. 1) are testable, and wall-clock time of the simulation itself for
//! the §Perf pass.

/// Time breakdown in simulated seconds (the BSP cost of the run).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub communication: f64,
    pub computation: f64,
    pub overhead: f64,
}

impl Breakdown {
    #[inline]
    pub fn total(&self) -> f64 {
        self.communication + self.computation + self.overhead
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.communication += other.communication;
        self.computation += other.computation;
        self.overhead += other.overhead;
    }
}

/// Cumulative metrics for one simulated run.
#[derive(Clone, Debug)]
pub struct Metrics {
    pub p: usize,
    pub supersteps: u64,
    pub time: Breakdown,
    /// Total words sent over the whole run (aggregate I in Def. 1).
    pub total_words: u64,
    /// Total messages over the whole run.
    pub total_msgs: u64,
    /// Cumulative words sent, per machine.
    pub sent_by_machine: Vec<u64>,
    /// Cumulative words received, per machine.
    pub recv_by_machine: Vec<u64>,
    /// Cumulative local work units, per machine (W in Def. 1).
    pub work_by_machine: Vec<u64>,
    /// Tasks executed, per machine (Theorem 1(ii)).
    pub executed_by_machine: Vec<u64>,
}

impl Metrics {
    pub fn new(p: usize) -> Self {
        Metrics {
            p,
            supersteps: 0,
            time: Breakdown::default(),
            total_words: 0,
            total_msgs: 0,
            sent_by_machine: vec![0; p],
            recv_by_machine: vec![0; p],
            work_by_machine: vec![0; p],
            executed_by_machine: vec![0; p],
        }
    }

    /// Simulated runtime in seconds.
    pub fn sim_seconds(&self) -> f64 {
        self.time.total()
    }

    /// max/mean ratio of per-machine quantities — 1.0 is perfect balance.
    pub fn imbalance(xs: &[u64]) -> f64 {
        let max = xs.iter().copied().max().unwrap_or(0) as f64;
        let sum: u64 = xs.iter().sum();
        if sum == 0 {
            return 1.0;
        }
        let mean = sum as f64 / xs.len() as f64;
        max / mean
    }

    pub fn work_imbalance(&self) -> f64 {
        Self::imbalance(&self.work_by_machine)
    }

    pub fn comm_imbalance(&self) -> f64 {
        let combined: Vec<u64> = self
            .sent_by_machine
            .iter()
            .zip(&self.recv_by_machine)
            .map(|(s, r)| s + r)
            .collect();
        Self::imbalance(&combined)
    }

    pub fn exec_imbalance(&self) -> f64 {
        Self::imbalance(&self.executed_by_machine)
    }
}

/// Summary of one benchmark run, printable as a paper-style table row.
#[derive(Clone, Debug)]
pub struct Report {
    pub label: String,
    pub sim_seconds: f64,
    pub breakdown: Breakdown,
    pub wall_ms: f64,
    pub supersteps: u64,
    pub total_words: u64,
    pub work_imbalance: f64,
    pub comm_imbalance: f64,
}

impl Report {
    pub fn from_metrics(label: impl Into<String>, m: &Metrics, wall_ms: f64) -> Self {
        Report {
            label: label.into(),
            sim_seconds: m.sim_seconds(),
            breakdown: m.time,
            wall_ms,
            supersteps: m.supersteps,
            total_words: m.total_words,
            work_imbalance: m.work_imbalance(),
            comm_imbalance: m.comm_imbalance(),
        }
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<28} sim={:>9.4}s  (comm {:>8.4} comp {:>8.4} ovhd {:>8.4})  steps={:<5} words={:<10} imb(work)={:.2} imb(comm)={:.2}  wall={:.0}ms",
            self.label,
            self.sim_seconds,
            self.breakdown.communication,
            self.breakdown.computation,
            self.breakdown.overhead,
            self.supersteps,
            self.total_words,
            self.work_imbalance,
            self.comm_imbalance,
            self.wall_ms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_of_uniform_is_one() {
        assert!((Metrics::imbalance(&[5, 5, 5, 5]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_of_hotspot() {
        // One machine does everything: max/mean = P.
        assert!((Metrics::imbalance(&[8, 0, 0, 0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_of_empty_is_one() {
        assert_eq!(Metrics::imbalance(&[0, 0]), 1.0);
    }

    #[test]
    fn breakdown_total() {
        let b = Breakdown { communication: 1.0, computation: 2.0, overhead: 0.5 };
        assert!((b.total() - 3.5).abs() < 1e-12);
    }
}
