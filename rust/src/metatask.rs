//! Meta-task structures (paper §3.2, Figs 3–4).
//!
//! Messages climbing the communication forest carry *meta-task sets*:
//! per-level collections where level 0 holds full task contexts and level
//! i ≥ 1 holds pointers to arrays of level-(i−1) meta-tasks parked on some
//! machine.  Merging two sets cascades overflow: whenever a level exceeds
//! C entries, all entries at that level are stored locally in a *slot* and
//! replaced by a single pointer meta-task one level up.  This bounds every
//! in-flight message at C·log_C n words while preserving both the
//! reference count and the location of every parked context — exactly the
//! information Phase 2's distributed push-pull needs.

use crate::bsp::MachineId;

/// Wire size (words) of a pointer meta-task: {level+count, holder, slot}.
pub const PTR_WORDS: u64 = 3;

/// One meta-task (Fig 3).
#[derive(Clone, Debug)]
pub enum MetaTask<T> {
    /// L0 — a full task context in flight (or parked in a slot).
    Ctx(T),
    /// L ≥ 1 — pointer to a slot of level-(level−1) meta-tasks on `holder`.
    Ptr {
        level: u8,
        count: u64,
        holder: MachineId,
        slot: u32,
    },
}

impl<T> MetaTask<T> {
    #[inline]
    pub fn level(&self) -> u8 {
        match self {
            MetaTask::Ctx(_) => 0,
            MetaTask::Ptr { level, .. } => *level,
        }
    }

    /// Number of underlying tasks this meta-task represents.
    #[inline]
    pub fn count(&self) -> u64 {
        match self {
            MetaTask::Ctx(_) => 1,
            MetaTask::Ptr { count, .. } => *count,
        }
    }

    /// Wire size in words, with contexts costing σ.
    #[inline]
    pub fn words(&self, sigma: u64) -> u64 {
        match self {
            MetaTask::Ctx(_) => sigma,
            MetaTask::Ptr { .. } => PTR_WORDS,
        }
    }
}

/// Machine-local storage for parked meta-task arrays.  `slots[i]` is the
/// array some pointer meta-task `{holder: me, slot: i}` refers to.
#[derive(Clone, Debug, Default)]
pub struct SlotStore<T> {
    pub slots: Vec<Vec<MetaTask<T>>>,
}

impl<T> SlotStore<T> {
    pub fn new() -> Self {
        SlotStore { slots: Vec::new() }
    }

    pub fn alloc(&mut self, content: Vec<MetaTask<T>>) -> u32 {
        self.slots.push(content);
        (self.slots.len() - 1) as u32
    }

    /// Take the content of a slot (each slot is consumed exactly once by
    /// the pull phase).
    pub fn take(&mut self, slot: u32) -> Vec<MetaTask<T>> {
        std::mem::take(&mut self.slots[slot as usize])
    }

    pub fn clear(&mut self) {
        self.slots.clear();
    }
}

/// A meta-task set: ≤ C meta-tasks per level after normalization.
#[derive(Clone, Debug)]
pub struct MetaTaskSet<T> {
    /// `levels[l]` = meta-tasks at level l.
    pub levels: Vec<Vec<MetaTask<T>>>,
}

// Manual impl (not derived): a derive would demand `T: Default`, which
// meta-task payloads have no reason to satisfy.
impl<T> Default for MetaTaskSet<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> MetaTaskSet<T> {
    pub fn new() -> Self {
        MetaTaskSet { levels: Vec::new() }
    }

    pub fn from_ctxs(ctxs: impl IntoIterator<Item = T>) -> Self {
        let mut s = Self::new();
        s.levels.push(ctxs.into_iter().map(MetaTask::Ctx).collect());
        s
    }

    /// Total reference count represented by the set.
    pub fn total_count(&self) -> u64 {
        self.levels
            .iter()
            .flat_map(|l| l.iter())
            .map(|m| m.count())
            .sum()
    }

    /// Number of meta-task entries (not underlying tasks).
    pub fn entry_count(&self) -> usize {
        self.levels.iter().map(|l| l.len()).sum()
    }

    pub fn max_level(&self) -> u8 {
        (self.levels.len().saturating_sub(1)) as u8
    }

    pub fn is_empty(&self) -> bool {
        self.levels.iter().all(|l| l.is_empty())
    }

    /// Wire size in words.
    pub fn words(&self, sigma: u64) -> u64 {
        self.levels
            .iter()
            .flat_map(|l| l.iter())
            .map(|m| m.words(sigma))
            .sum::<u64>()
            + 1 // the addr key it travels with
    }

    /// True iff the set holds only L0 contexts (the uncontended push case).
    pub fn is_all_ctx(&self) -> bool {
        self.levels.len() <= 1
    }

    /// Merge `other` into `self` (Fig 4), cascading overflow into local
    /// slots on machine `me`.  Returns the number of set *entries* touched
    /// (for work accounting — parking a whole level in a slot is a pointer
    /// move, so it costs O(1), not O(contexts); both set sizes are bounded
    /// by C·log_C n).
    pub fn merge(&mut self, other: MetaTaskSet<T>, c: usize, slots: &mut SlotStore<T>, me: MachineId) -> u64 {
        let mut touched = 0u64;
        for (l, lvl) in other.levels.into_iter().enumerate() {
            if self.levels.len() <= l {
                self.levels.resize_with(l + 1, Vec::new);
            }
            touched += 1 + lvl.len().min(c) as u64;
            self.levels[l].extend(lvl);
        }
        touched += self.normalize(c, slots, me);
        touched
    }

    /// Cascade overflow bottom-up until every level has ≤ C entries.
    /// Returns O(1) work per overflowed level (slot parking is a move).
    pub fn normalize(&mut self, c: usize, slots: &mut SlotStore<T>, me: MachineId) -> u64 {
        let c = c.max(1);
        let mut touched = 0u64;
        let mut l = 0usize;
        while l < self.levels.len() {
            if self.levels[l].len() > c {
                let popped = std::mem::take(&mut self.levels[l]);
                let count: u64 = popped.iter().map(|m| m.count()).sum();
                touched += 2; // pointer-move the level into a slot + new Ptr
                let slot = slots.alloc(popped);
                if self.levels.len() <= l + 1 {
                    self.levels.resize_with(l + 2, Vec::new);
                }
                self.levels[l + 1].push(MetaTask::Ptr {
                    level: (l + 1) as u8,
                    count,
                    holder: me,
                    slot,
                });
            }
            l += 1;
        }
        touched
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctxs(n: usize) -> MetaTaskSet<u32> {
        MetaTaskSet::from_ctxs(0..n as u32)
    }

    #[test]
    fn small_sets_stay_flat() {
        let mut slots = SlotStore::new();
        let mut a = ctxs(2);
        a.merge(ctxs(1), 3, &mut slots, 0);
        assert!(a.is_all_ctx());
        assert_eq!(a.total_count(), 3);
        assert!(slots.slots.is_empty());
    }

    #[test]
    fn overflow_creates_pointer_and_slot() {
        let mut slots = SlotStore::new();
        let mut a = ctxs(3);
        a.merge(ctxs(3), 3, &mut slots, 7);
        // 6 L0 > C=3: all popped into one slot, one L1 pointer remains.
        assert_eq!(a.levels[0].len(), 0);
        assert_eq!(a.levels[1].len(), 1);
        assert_eq!(a.total_count(), 6);
        match &a.levels[1][0] {
            MetaTask::Ptr { level, count, holder, slot } => {
                assert_eq!((*level, *count, *holder), (1, 6, 7));
                assert_eq!(slots.slots[*slot as usize].len(), 6);
            }
            _ => panic!("expected pointer"),
        }
    }

    #[test]
    fn cascade_to_higher_levels() {
        // Repeated merges must cascade: with C=2, merging many singletons
        // produces a log-depth pointer hierarchy, never >C per level.
        let c = 2;
        let mut slots = SlotStore::new();
        let mut acc = MetaTaskSet::new();
        for i in 0..64u32 {
            acc.merge(MetaTaskSet::from_ctxs([i]), c, &mut slots, 0);
        }
        assert_eq!(acc.total_count(), 64);
        for lvl in &acc.levels {
            assert!(lvl.len() <= c);
        }
        assert!(acc.max_level() >= 3);
    }

    #[test]
    fn size_bound_c_log_n() {
        // entry_count ≤ C * (log_C n + 1) after any merge sequence.
        for c in [2usize, 3, 8] {
            let mut slots = SlotStore::new();
            let mut acc = MetaTaskSet::new();
            let n = 500u32;
            for i in 0..n {
                acc.merge(MetaTaskSet::from_ctxs([i]), c, &mut slots, 0);
            }
            let bound = c as f64 * ((n as f64).ln() / (c as f64).ln() + 1.0);
            assert!(
                (acc.entry_count() as f64) <= bound,
                "c={c}: {} > {bound}",
                acc.entry_count()
            );
        }
    }

    #[test]
    fn counts_preserved_across_merges() {
        let mut slots = SlotStore::new();
        let mut a = ctxs(5);
        let mut b = ctxs(9);
        b.normalize(4, &mut slots, 1);
        a.normalize(4, &mut slots, 0);
        a.merge(b, 4, &mut slots, 0);
        assert_eq!(a.total_count(), 14);
    }

    #[test]
    fn words_accounting() {
        let sigma = 4;
        let mut slots = SlotStore::new();
        let mut a = ctxs(2); // 2 ctx = 8 words + 1 addr
        assert_eq!(a.words(sigma), 9);
        a.merge(ctxs(3), 2, &mut slots, 0); // overflow -> 1 ptr
        assert_eq!(a.words(sigma), PTR_WORDS + 1);
    }

    #[test]
    fn slot_take_consumes() {
        let mut slots = SlotStore::new();
        let s = slots.alloc(vec![MetaTask::Ctx(1u32)]);
        assert_eq!(slots.take(s).len(), 1);
        assert!(slots.take(s).is_empty());
    }
}
