//! Distributed chunked data store (paper §2.2 "Data Storage").
//!
//! Data are partitioned into chunks of granularity B words; each chunk
//! address is placed on a machine chosen by a stable hash — the randomized
//! placement the paper relies on for adversary-resistant storage balance.

use std::collections::HashMap;

use crate::bsp::MachineId;
use crate::rng::hash64;

/// Address of a data chunk.
pub type Addr = u64;

/// Owner machine of a chunk address under random placement.
#[inline]
pub fn owner_of(addr: Addr, p: usize) -> MachineId {
    (hash64(addr) % p as u64) as usize
}

/// A P-way partitioned key→chunk store.  All accesses in the simulator go
/// through machine-local maps; *remote* access must be done with messages
/// (the store intentionally has no cross-machine API).
#[derive(Clone, Debug)]
pub struct DistStore<V> {
    p: usize,
    maps: Vec<HashMap<Addr, V>>,
}

impl<V: Clone + Default> DistStore<V> {
    pub fn new(p: usize) -> Self {
        DistStore {
            p,
            maps: (0..p).map(|_| HashMap::new()).collect(),
        }
    }

    pub fn p(&self) -> usize {
        self.p
    }

    #[inline]
    pub fn owner(&self, addr: Addr) -> MachineId {
        owner_of(addr, self.p)
    }

    /// Insert/overwrite a chunk (placed on its owner machine).
    pub fn insert(&mut self, addr: Addr, v: V) {
        let m = self.owner(addr);
        self.maps[m].insert(addr, v);
    }

    /// Read a chunk from its owner machine (local view).
    pub fn get(&self, addr: Addr) -> Option<&V> {
        self.maps[self.owner(addr)].get(&addr)
    }

    /// Read a chunk, materializing the default if absent (e.g. an empty
    /// hash-table bucket).
    pub fn get_or_default(&mut self, addr: Addr) -> &mut V {
        let m = self.owner(addr);
        self.maps[m].entry(addr).or_default()
    }

    pub fn get_mut(&mut self, addr: Addr) -> Option<&mut V> {
        let m = self.owner(addr);
        self.maps[m].get_mut(&addr)
    }

    /// Clone the chunk value or default — what a pull sends over the wire.
    pub fn read_copy(&self, addr: Addr) -> V {
        self.get(addr).cloned().unwrap_or_default()
    }

    /// Number of chunks stored on machine `m`.
    pub fn len_on(&self, m: MachineId) -> usize {
        self.maps[m].len()
    }

    pub fn total_len(&self) -> usize {
        self.maps.iter().map(|m| m.len()).sum()
    }

    /// Iterate all (addr, value) pairs (test/verification use only).
    pub fn iter(&self) -> impl Iterator<Item = (&Addr, &V)> {
        self.maps.iter().flat_map(|m| m.iter())
    }

    /// Detach the per-machine shard maps so a shared-nothing execution
    /// backend can hand each worker thread *ownership* of its shard (see
    /// [`crate::exec`]).  The store is left with fresh empty shards; pair
    /// every call with [`DistStore::put_maps`].
    pub fn take_maps(&mut self) -> Vec<HashMap<Addr, V>> {
        std::mem::replace(
            &mut self.maps,
            (0..self.p).map(|_| HashMap::new()).collect(),
        )
    }

    /// Re-attach shards detached by [`DistStore::take_maps`], in machine
    /// order.
    pub fn put_maps(&mut self, maps: Vec<HashMap<Addr, V>>) {
        assert_eq!(maps.len(), self.p, "shard count must match P");
        self.maps = maps;
    }

    /// Deterministic snapshot for equality checks in tests.
    pub fn snapshot(&self) -> Vec<(Addr, V)> {
        let mut all: Vec<(Addr, V)> = self
            .iter()
            .map(|(a, v)| (*a, v.clone()))
            .collect();
        all.sort_by_key(|(a, _)| *a);
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_is_stable_and_spread() {
        let p = 8;
        let store: DistStore<u64> = DistStore::new(p);
        for addr in 0..100 {
            assert_eq!(store.owner(addr), owner_of(addr, p));
        }
        // Random placement should hit every machine for 10k addrs.
        let mut hit = vec![false; p];
        for addr in 0..10_000u64 {
            hit[owner_of(addr, p)] = true;
        }
        assert!(hit.iter().all(|h| *h));
    }

    #[test]
    fn insert_get_roundtrip() {
        let mut s: DistStore<String> = DistStore::new(4);
        s.insert(42, "hi".into());
        assert_eq!(s.get(42).unwrap(), "hi");
        assert_eq!(s.get(43), None);
        assert_eq!(s.read_copy(43), String::default());
    }

    #[test]
    fn get_or_default_materializes() {
        let mut s: DistStore<Vec<u32>> = DistStore::new(2);
        s.get_or_default(7).push(1);
        s.get_or_default(7).push(2);
        assert_eq!(s.get(7).unwrap(), &vec![1, 2]);
        assert_eq!(s.total_len(), 1);
    }

    #[test]
    fn take_put_maps_roundtrip() {
        let mut s: DistStore<u8> = DistStore::new(4);
        for a in 0..64u64 {
            s.insert(a, a as u8);
        }
        let snap = s.snapshot();
        let maps = s.take_maps();
        assert_eq!(maps.len(), 4);
        assert_eq!(s.total_len(), 0); // detached
        s.put_maps(maps);
        assert_eq!(s.snapshot(), snap);
    }

    #[test]
    fn snapshot_sorted() {
        let mut s: DistStore<u8> = DistStore::new(3);
        for a in [5u64, 1, 9, 3] {
            s.insert(a, a as u8);
        }
        let snap = s.snapshot();
        assert_eq!(snap, vec![(1, 1), (3, 3), (5, 5), (9, 9)]);
    }

    #[test]
    fn placement_balance_statistical() {
        // 100k random addrs over 16 machines: max/mean under 1.15.
        let p = 16;
        let mut counts = vec![0u64; p];
        for addr in 0..100_000u64 {
            counts[owner_of(addr * 2654435761 + 11, p)] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let mean = 100_000.0 / p as f64;
        assert!(max / mean < 1.15, "imbalance {}", max / mean);
    }
}
