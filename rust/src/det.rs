//! Deterministic (and fast) hash containers for the hot paths.
//!
//! `std::collections::HashMap`'s default `RandomState` seeds per process,
//! which would make simulated runs non-reproducible (combine order, message
//! emission order).  All coordinator state therefore uses an FxHash-style
//! fixed-seed hasher: deterministic across runs *and* measurably faster
//! than SipHash on the small integer keys that dominate here.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash-style multiply-xor hasher (fixed seed, not DoS-resistant — fine
/// for a simulator whose inputs we generate ourselves).
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type DetBuildHasher = BuildHasherDefault<FxHasher>;
pub type DetMap<K, V> = HashMap<K, V, DetBuildHasher>;
pub type DetSet<K> = HashSet<K, DetBuildHasher>;

pub fn det_map<K, V>() -> DetMap<K, V> {
    DetMap::default()
}

pub fn det_set<K>() -> DetSet<K> {
    DetSet::default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iteration_order_is_reproducible() {
        let build = || {
            let mut m: DetMap<u64, u64> = det_map();
            for i in 0..1000 {
                m.insert(i * 7919, i);
            }
            m.into_iter().collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    fn hashes_differ_across_keys() {
        use std::hash::{BuildHasher, Hash};
        let bh = DetBuildHasher::default();
        let h = |x: u64| {
            let mut hasher = bh.build_hasher();
            x.hash(&mut hasher);
            hasher.finish()
        };
        assert_ne!(h(1), h(2));
        assert_ne!(h(0), h(u64::MAX));
    }
}
