//! Communication forest (paper §3.1, Fig 2).
//!
//! One balanced F-ary tree per machine.  The tree rooted at machine `r`
//! funnels information about every task that targets data stored on `r`:
//! the P leaves are the physical machines, internal nodes are *virtual
//! transit machines* mapped onto physical machines by a globally-known
//! hash, and the root (level 0) is `r` itself.  Messages climb one level
//! per BSP round, merging meta-task sets at every node, which is what
//! keeps Phase 1 load-balanced even when a single data chunk is hot.

use crate::bsp::MachineId;
use crate::rng::hash2;

/// The static shape shared by all P trees of the forest.
#[derive(Clone, Copy, Debug)]
pub struct Forest {
    p: usize,
    fanout: usize,
    height: u32,
}

impl Forest {
    /// Build a forest over `p` machines with the given fanout (≥ 2).
    pub fn new(p: usize, fanout: usize) -> Self {
        assert!(p >= 1);
        let fanout = fanout.max(2);
        // height = ceil(log_F p): number of rounds Phase 1 needs.
        let mut height = 0u32;
        let mut reach = 1usize;
        while reach < p {
            reach = reach.saturating_mul(fanout);
            height += 1;
        }
        Forest { p, fanout, height }
    }

    /// The paper's F = Θ(log P / log log P) default (§3.5), floored at 2.
    pub fn default_fanout(p: usize) -> usize {
        if p <= 2 {
            return 2;
        }
        let lp = (p as f64).ln();
        let llp = lp.ln().max(1.0);
        (lp / llp).round().max(2.0) as usize
    }

    pub fn with_default_fanout(p: usize) -> Self {
        Self::new(p, Self::default_fanout(p))
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// Tree height = number of Phase-1 rounds (0 when P == 1).
    pub fn height(&self) -> u32 {
        self.height
    }

    /// Level/index of the leaf owned by machine `m` (leaves live at
    /// `level == height`, indexed by machine id).
    #[inline]
    pub fn leaf(&self, m: MachineId) -> (u32, u64) {
        (self.height, m as u64)
    }

    /// Parent coordinates of node `(level, idx)`.
    #[inline]
    pub fn parent(&self, level: u32, idx: u64) -> (u32, u64) {
        debug_assert!(level > 0, "root has no parent");
        (level - 1, idx / self.fanout as u64)
    }

    /// Physical machine hosting node `(level, idx)` of the tree rooted at
    /// `root`.  Level 0 is pinned to `root`; leaves are pinned to their
    /// machine; transit nodes are hashed (the VM→PM map of Fig 2).
    #[inline]
    pub fn machine_of(&self, root: MachineId, level: u32, idx: u64) -> MachineId {
        if level == 0 {
            return root;
        }
        if level == self.height {
            return idx as usize;
        }
        let key = (level as u64) << 48 | idx;
        (hash2(root as u64, key) % self.p as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn height_matches_log() {
        assert_eq!(Forest::new(1, 2).height(), 0);
        assert_eq!(Forest::new(2, 2).height(), 1);
        assert_eq!(Forest::new(8, 2).height(), 3);
        assert_eq!(Forest::new(9, 2).height(), 4);
        assert_eq!(Forest::new(16, 4).height(), 2);
    }

    #[test]
    fn default_fanout_grows_slowly() {
        assert_eq!(Forest::default_fanout(2), 2);
        let f16 = Forest::default_fanout(16);
        let f1024 = Forest::default_fanout(1024);
        assert!(f16 >= 2 && f1024 >= f16, "{f16} {f1024}");
        assert!(f1024 <= 8);
    }

    #[test]
    fn every_leaf_path_reaches_root() {
        let f = Forest::new(13, 3);
        for m in 0..13usize {
            let (mut level, mut idx) = f.leaf(m);
            let mut hops = 0;
            while level > 0 {
                let (pl, pi) = f.parent(level, idx);
                level = pl;
                idx = pi;
                hops += 1;
                assert!(hops <= f.height());
            }
            assert_eq!((level, idx), (0, 0));
            assert_eq!(hops, f.height());
        }
    }

    #[test]
    fn root_and_leaves_are_pinned() {
        let f = Forest::new(8, 2);
        for r in 0..8 {
            assert_eq!(f.machine_of(r, 0, 0), r);
            for m in 0..8u64 {
                assert_eq!(f.machine_of(r, f.height(), m), m as usize);
            }
        }
    }

    #[test]
    fn transit_mapping_is_stable_and_in_range() {
        let f = Forest::new(16, 2);
        for r in 0..16 {
            for level in 1..f.height() {
                for idx in 0..4u64 {
                    let m1 = f.machine_of(r, level, idx);
                    let m2 = f.machine_of(r, level, idx);
                    assert_eq!(m1, m2);
                    assert!(m1 < 16);
                }
            }
        }
    }

    #[test]
    fn distinct_roots_use_different_transit_machines() {
        // The forest property: hot traffic for different roots spreads
        // over different transit machines.
        let f = Forest::new(16, 2);
        let ms: Vec<MachineId> = (0..16)
            .map(|r| f.machine_of(r, 1, 0))
            .collect();
        let uniq: std::collections::HashSet<_> = ms.iter().collect();
        assert!(uniq.len() > 4, "transit nodes badly clustered: {ms:?}");
    }

    #[test]
    fn fanout_bounds_children() {
        // No node at level l-1 can have more than `fanout` children at l.
        let f = Forest::new(16, 4);
        let mut child_count = std::collections::HashMap::new();
        for m in 0..16usize {
            let (l, i) = f.leaf(m);
            *child_count.entry(f.parent(l, i)).or_insert(0usize) += 1;
        }
        for (_, c) in child_count {
            assert!(c <= 4);
        }
    }
}
