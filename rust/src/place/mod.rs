//! `place` — deterministic hotspot-adaptive placement.
//!
//! TD-Orch's push-pull balances each batch, but block *placement* is
//! decided once at ingestion — persistent skew (the paper's data-hot-spot
//! case, §2.3) pays the push-pull tax every round, and live mutation
//! makes it worse: the frozen-placement insert rule accretes every new
//! arc at its source's owner, so a Zipf-hot insert stream piles edges
//! onto hub owners long after ingestion balanced them.  This module
//! closes the loop: a [`PlacementController`] consumes the flight
//! recorder's per-(superstep, machine) work totals over a sliding window
//! and, when the windowed imbalance crosses its trigger, emits a
//! [`PlacementDelta`] — whole-block **migrations** from the hottest to
//! the coldest machine plus a **split** of the hottest resident block
//! (hot-vertex replication: the split fans the hub's out-edges across
//! machines, its broadcast value is replicated to the new leaf and the
//! pull contributions still merge at the owner through the destination
//! relay tree, which is the deterministic merge-at-owner write path).
//!
//! The server applies deltas at **epoch boundaries only** — between
//! dispatches, under the same barrier mutation batches use — via
//! [`crate::graph::spmd::SpmdEngine::apply_placement`], which patches
//! blocks, `BlockIndex`, leaf sets and relay trees in place inside one
//! superstep (no re-ingestion; `ingest::ingestions()` stays the witness;
//! `graph_epoch` bumps once per op, so every query result names the
//! placement snapshot it ran on).
//!
//! **Determinism contract.**  The decision function is a pure function
//! of the deterministic event stream — windowed ledger work vectors,
//! never wall-clock — and of the (deterministic) block catalog, so the
//! decisions, the decision log, and the post-migration query bits are
//! bit-identical between the simulator and the threaded pool at every P
//! (`tests/placement_equivalence.rs`).  [`apply_to_distgraph`] replays a
//! delta's structural edits onto a driverless [`DistGraph`] in the exact
//! (machine, emission) order the engine applies them, so a reference
//! engine built from the replayed graph is bit-identical to the live
//! one — including the f64 fold grouping PR/BC depend on.
//!
//! [`DistGraph`]: crate::graph::ingest::DistGraph

mod controller;
mod delta;

pub use controller::{PlacementController, PlacementPolicy};
pub use delta::{apply_to_distgraph, PlaceOp, PlacementDelta};

pub(crate) use delta::{apply_patches, build_patches, Patch};
