//! The placement controller: windowed skew signal → deterministic
//! migration/replication decisions.
//!
//! Signal → decision → apply, all on deterministic quantities:
//!
//! ```text
//!   FlightRecorder ── Superstep{work: Vec<u64>} events ──► observe_recorder
//!        │   sliding window of per-machine work vectors (ledger, not wall)
//!        ▼
//!   decide(block_catalog, out_deg)        pure function of its arguments
//!        │   windowed imbalance ≥ trigger?  hot = argmax, cold = argmin
//!        │   split the hottest resident block (replication), move the
//!        │   next-hottest whole blocks (migration), hot → cold
//!        ▼
//!   Some(PlacementDelta)  ──►  SpmdEngine::apply_placement  (the server
//!                              calls it between dispatches only)
//! ```
//!
//! Everything the decision reads is bit-identical across backends — the
//! recorder's work vectors are the shared ledger, the block catalog is
//! driver-side state, wall-clock never enters — so two controllers fed
//! the same run produce the same deltas and the same [`decision
//! log`](PlacementController::decision_log) on the simulator and the
//! threaded pool at every P.

use std::collections::VecDeque;

use crate::graph::Vid;
use crate::metrics::Metrics;
use crate::obs::{EventKind, FlightRecorder};

use super::delta::{PlaceOp, PlacementDelta};

/// Tuning knobs for the placement controller (all deterministic
/// quantities; `Default` is the serving default).
#[derive(Clone, Copy, Debug)]
pub struct PlacementPolicy {
    /// Sliding-window length, in ledger supersteps, of the per-machine
    /// work signal a decision folds over.
    pub window: usize,
    /// Minimum window fill before a decision is attempted (a freshly
    /// cleared window must re-observe the post-move behavior first).
    pub min_steps: usize,
    /// Trigger threshold on the windowed work imbalance (max/mean; 1.0
    /// is perfect balance).  Below it, `decide` returns `None` — the
    /// no-skew guarantee.
    pub trigger: f64,
    /// Whole-block migrations per round (beyond the one split).
    pub max_moves: usize,
    /// Minimum resident targets for a block to be split rather than
    /// moved (replicating a tiny block buys nothing).
    pub split_min_targets: usize,
    /// Upper bound on placement rounds per serve (0 = unlimited) — a
    /// deterministic brake against oscillation.
    pub max_rounds: u64,
}

impl Default for PlacementPolicy {
    fn default() -> Self {
        PlacementPolicy {
            window: 32,
            min_steps: 8,
            trigger: 1.25,
            max_moves: 2,
            split_min_targets: 16,
            max_rounds: 8,
        }
    }
}

impl PlacementPolicy {
    pub fn with_window(mut self, window: usize) -> Self {
        self.window = window.max(1);
        self
    }

    pub fn with_min_steps(mut self, min_steps: usize) -> Self {
        self.min_steps = min_steps.max(1);
        self
    }

    pub fn with_trigger(mut self, trigger: f64) -> Self {
        self.trigger = trigger.max(1.0);
        self
    }

    pub fn with_max_moves(mut self, max_moves: usize) -> Self {
        self.max_moves = max_moves;
        self
    }

    pub fn with_split_min_targets(mut self, t: usize) -> Self {
        self.split_min_targets = t.max(2);
        self
    }

    pub fn with_max_rounds(mut self, r: u64) -> Self {
        self.max_rounds = r;
        self
    }
}

/// Windowed skew → placement decisions.  Create one per serve (its
/// cursor tracks one recorder); the server drives it between dispatches.
pub struct PlacementController {
    policy: PlacementPolicy,
    /// Recorder events already consumed (cursor on
    /// `FlightRecorder::recorded()` — monotone, survives ring drops
    /// because drops are oldest-first and deterministic).
    consumed: u64,
    /// Sliding window of per-machine work vectors, oldest first.
    window: VecDeque<Vec<u64>>,
    rounds: u64,
    decision_log: Vec<String>,
    applied: Vec<PlacementDelta>,
}

impl PlacementController {
    pub fn new(policy: PlacementPolicy) -> Self {
        PlacementController {
            policy,
            consumed: 0,
            window: VecDeque::new(),
            rounds: 0,
            decision_log: Vec::new(),
            applied: Vec::new(),
        }
    }

    pub fn policy(&self) -> PlacementPolicy {
        self.policy
    }

    /// Placement rounds decided so far.
    pub fn rounds(&self) -> u64 {
        self.rounds
    }

    /// One line per decision — round, windowed per-machine sums,
    /// imbalance, and the ops.  Deterministic, so cross-backend equality
    /// of this log is the decision-equality gate.
    pub fn decision_log(&self) -> &[String] {
        &self.decision_log
    }

    /// Every delta this controller has emitted, in order.
    pub fn applied(&self) -> &[PlacementDelta] {
        &self.applied
    }

    /// Ingest the recorder events that arrived since the last call,
    /// folding each ledger `Superstep`'s per-machine work vector into
    /// the sliding window.  Only the deterministic core of each event is
    /// read — wall annotations never reach the window.
    pub fn observe_recorder(&mut self, rec: &FlightRecorder) {
        let total = rec.recorded();
        if total <= self.consumed {
            return;
        }
        let fresh = (total - self.consumed) as usize;
        // The ring retains the newest `len()` events; anything older
        // than that was dropped oldest-first (deterministically — both
        // backends record the same stream), so the last min(fresh, len)
        // events are exactly the unconsumed survivors.
        let len = rec.len();
        let take = fresh.min(len);
        for e in rec.events().skip(len - take) {
            if let EventKind::Superstep { work, .. } = &e.kind {
                self.window.push_back(work.clone());
                while self.window.len() > self.policy.window {
                    self.window.pop_front();
                }
            }
        }
        self.consumed = total;
    }

    /// Decide a placement round from the windowed signal and the current
    /// block catalog (`catalog[m]` = the engine's per-slot `(src,
    /// targets_len)` view; hollow slots report 0).  Pure function of its
    /// inputs and the window — no clock, no randomness.  Returns `None`
    /// when the window is under-filled, the imbalance sits below the
    /// trigger, the round budget is spent, or the hot machine has no
    /// eligible block; otherwise records the delta (and its log line)
    /// and clears the window so the next decision re-observes the moved
    /// system.
    pub fn decide(
        &mut self,
        catalog: &[Vec<(Vid, u32)>],
        out_deg: &[u32],
    ) -> Option<PlacementDelta> {
        if self.policy.max_rounds > 0 && self.rounds >= self.policy.max_rounds {
            return None;
        }
        if self.window.len() < self.policy.min_steps {
            return None;
        }
        let p = catalog.len();
        let mut sums = vec![0u64; p];
        for step in &self.window {
            for (s, w) in sums.iter_mut().zip(step) {
                *s += w;
            }
        }
        let imb = Metrics::imbalance(&sums);
        if imb < self.policy.trigger {
            return None;
        }
        // Ties break to the lower machine id — deterministic at every P.
        let hot = (0..p).max_by_key(|&m| (sums[m], std::cmp::Reverse(m)))?;
        let cold = (0..p).min_by_key(|&m| (sums[m], m))?;
        if hot == cold || sums[hot] == sums[cold] {
            return None;
        }
        // Candidate blocks on the hot machine, hottest first: resident
        // size, then source degree (the Zipf-rank proxy), then slot —
        // all deterministic keys.
        let mut cands: Vec<(u32, Vid, u32)> = catalog[hot]
            .iter()
            .enumerate()
            .filter(|(_, &(_, len))| len > 0)
            .map(|(i, &(src, len))| (i as u32, src, len))
            .collect();
        cands.sort_by_key(|&(i, src, len)| {
            (std::cmp::Reverse(len), std::cmp::Reverse(out_deg[src as usize]), i)
        });
        let mut ops: Vec<PlaceOp> = Vec::new();
        let mut iter = cands.into_iter();
        // Replicate the hottest block when it is big enough to split;
        // a small head block is just moved with the rest.
        if let Some(&(i, _src, len)) = iter.as_slice().first() {
            if len as usize >= self.policy.split_min_targets {
                ops.push(PlaceOp::Split {
                    from: hot,
                    block: i,
                    at: len as usize / 2,
                    to: cold,
                });
                iter.next();
            }
        }
        for (i, _src, _len) in iter.by_ref().take(self.policy.max_moves) {
            ops.push(PlaceOp::Move { from: hot, block: i, to: cold });
        }
        if ops.is_empty() {
            return None;
        }
        let delta = PlacementDelta { round: self.rounds, ops };
        self.decision_log.push(format!(
            "round {}: window {} steps, work sums {:?}, imbalance {:.4}, hot m{} -> cold m{}, ops {:?}",
            self.rounds,
            self.window.len(),
            sums,
            imb,
            hot,
            cold,
            delta.ops,
        ));
        self.applied.push(delta.clone());
        self.rounds += 1;
        self.window.clear();
        Some(delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::FlightRecorder;

    fn feed_steps(ctl: &mut PlacementController, rec: &mut FlightRecorder, steps: &[Vec<u64>]) {
        for (i, w) in steps.iter().enumerate() {
            let p = w.len();
            rec.record_superstep(i as u64 + 1, w.clone(), vec![0; p], vec![0; p], vec![0; p], None);
        }
        ctl.observe_recorder(rec);
    }

    #[test]
    fn balanced_window_triggers_nothing() {
        let mut rec = FlightRecorder::with_capacity(64);
        let mut ctl = PlacementController::new(PlacementPolicy::default().with_min_steps(4));
        feed_steps(&mut ctl, &mut rec, &vec![vec![10, 10, 10, 10]; 8]);
        let catalog = vec![vec![(0, 50u32)]; 4];
        assert!(ctl.decide(&catalog, &[9]).is_none());
        assert!(ctl.decision_log().is_empty());
        assert_eq!(ctl.rounds(), 0);
    }

    #[test]
    fn underfilled_window_defers() {
        let mut rec = FlightRecorder::with_capacity(64);
        let mut ctl = PlacementController::new(PlacementPolicy::default().with_min_steps(8));
        feed_steps(&mut ctl, &mut rec, &vec![vec![100, 1, 1, 1]; 3]);
        let catalog = vec![vec![(0, 50u32)]; 4];
        assert!(ctl.decide(&catalog, &[9]).is_none());
    }

    #[test]
    fn skewed_window_splits_then_moves_hot_blocks() {
        let mut rec = FlightRecorder::with_capacity(64);
        let mut ctl = PlacementController::new(
            PlacementPolicy::default().with_min_steps(4).with_max_moves(1),
        );
        feed_steps(&mut ctl, &mut rec, &vec![vec![100, 10, 10, 10]; 6]);
        // Machine 0 holds a big splittable block (slot 1) and a smaller
        // movable one (slot 0).
        let catalog = vec![
            vec![(3, 20u32), (7, 40u32)],
            vec![(1, 5u32)],
            vec![(2, 5u32)],
            vec![(4, 5u32)],
        ];
        let out_deg = vec![0u32; 8];
        let delta = ctl.decide(&catalog, &out_deg).expect("skew must trigger");
        assert_eq!(delta.round, 0);
        assert_eq!(
            delta.ops,
            vec![
                PlaceOp::Split { from: 0, block: 1, at: 20, to: 1 },
                PlaceOp::Move { from: 0, block: 0, to: 1 },
            ],
        );
        assert_eq!(ctl.applied(), &[delta]);
        assert_eq!(ctl.decision_log().len(), 1);
        // The window cleared: an immediate re-decide defers.
        assert!(ctl.decide(&catalog, &out_deg).is_none());
    }

    #[test]
    fn round_budget_is_a_hard_stop() {
        let mut rec = FlightRecorder::with_capacity(256);
        let mut ctl = PlacementController::new(
            PlacementPolicy::default().with_min_steps(2).with_max_rounds(1),
        );
        let catalog = vec![vec![(0, 64u32), (1, 8u32)], vec![], vec![], vec![]];
        let out_deg = vec![9u32; 2];
        feed_steps(&mut ctl, &mut rec, &vec![vec![100, 1, 1, 1]; 4]);
        assert!(ctl.decide(&catalog, &out_deg).is_some());
        feed_steps(&mut ctl, &mut rec, &vec![vec![100, 1, 1, 1]; 4]);
        assert!(ctl.decide(&catalog, &out_deg).is_none(), "budget spent");
        assert_eq!(ctl.rounds(), 1);
    }

    #[test]
    fn cursor_survives_ring_drops() {
        let mut rec = FlightRecorder::with_capacity(4);
        let mut ctl = PlacementController::new(PlacementPolicy::default().with_min_steps(1));
        // 10 steps through a 4-slot ring: the controller sees the newest
        // 4 it has not consumed, never double-counts.
        feed_steps(&mut ctl, &mut rec, &vec![vec![5, 1]; 10]);
        assert_eq!(ctl.window.len(), 4);
        ctl.observe_recorder(&rec); // no new events: a no-op
        assert_eq!(ctl.window.len(), 4);
    }
}
