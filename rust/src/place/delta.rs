//! Placement deltas: the structural edit language of adaptive placement,
//! plus the single-address-space replay that keeps references buildable.
//!
//! A [`PlacementDelta`] is applied by
//! [`crate::graph::spmd::SpmdEngine::apply_placement`] in one superstep:
//! the driver snapshots the shipped payloads from the pre-delta blocks
//! and hands each machine a patch inbox ([`build_patches`]); each worker
//! applies its patches in inbox order ([`apply_patches`]) and reports
//! which of its per-vertex holdings changed; the driver folds the
//! reports into the shared catalog.  [`apply_to_distgraph`] replays the
//! identical patch pipeline onto a plain [`DistGraph`] — same snapshot
//! rule, same per-machine application order, same (machine, emission)
//! membership fold — so `SpmdEngine::from_ingested` over the replayed
//! graph reconstructs the live engine's post-delta state bit for bit
//! (block order included, which the PR/BC f64 fold grouping depends on).
//!
//! Like the mutation path, placement is **frozen-ownership**: ops move
//! *blocks* between machines, never vertex ownership — the partition map
//! is immutable, hollowed block slots stay in place so indices remain
//! stable, and `out_deg`/`m` never change (every arc still exists,
//! somewhere).

use crate::bsp::MachineId;
use crate::graph::ingest::{DistGraph, EdgeBlock};
use crate::graph::layout::BlockIndex;
use crate::graph::Vid;
use crate::mutate;

/// One placement edit.  `block` is an absolute index into the source
/// machine's block vector — stable across deltas because detached slots
/// are hollowed, never removed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlaceOp {
    /// Migrate a whole block from `from` to `to` (the slot at `from` is
    /// hollowed in place).
    Move { from: MachineId, block: u32, to: MachineId },
    /// Replicate a hot source: ship `targets[at..]` of the block to a
    /// new block on `to`, keeping `targets[..at]` — the source vertex
    /// now has a leaf on both machines, so its broadcast value fans out
    /// and its pull contributions merge back at the owner through the
    /// destination relay trees.
    Split { from: MachineId, block: u32, at: usize, to: MachineId },
}

/// One placement decision: the ops of one controller round, applied
/// atomically between dispatches.  `graph_epoch` advances by
/// `ops.len()` when applied — one bump per move, so epoch-keyed caches
/// and references see every placement distinctly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlacementDelta {
    /// Controller round that produced this delta (0-based).
    pub round: u64,
    pub ops: Vec<PlaceOp>,
}

/// Worker-side patch: what one machine must do to its shard.  Payloads
/// are snapshotted by the driver from the pre-delta blocks, so patch
/// application is per-machine-local and order-independent *across*
/// machines (within a machine, inbox order is the application order).
#[derive(Clone, Debug)]
pub(crate) enum Patch {
    /// Hollow block `block` in place (targets emptied, index entry
    /// removed, slot kept).
    Detach { block: u32 },
    /// Keep only `targets[..at]` of block `block`.
    Truncate { block: u32, at: usize },
    /// Append a new block holding `src`'s shipped targets.
    Install { src: Vid, targets: Vec<(Vid, f32)> },
}

/// Build per-machine patch inboxes from a delta, snapshotting every
/// shipped payload through `read_block(machine, block) -> (src, targets)`
/// **before** any patch is applied.  Each `(from, block)` may appear in
/// at most one op per delta (installs create fresh slots a same-delta op
/// cannot reference), which is what makes the snapshot equal the
/// at-application-time state on every machine.
pub(crate) fn build_patches(
    p: usize,
    delta: &PlacementDelta,
    read_block: impl Fn(MachineId, u32) -> (Vid, Vec<(Vid, f32)>),
) -> Vec<Vec<Patch>> {
    let mut inboxes: Vec<Vec<Patch>> = (0..p).map(|_| Vec::new()).collect();
    #[cfg(debug_assertions)]
    let mut touched = std::collections::HashSet::new();
    for op in &delta.ops {
        match *op {
            PlaceOp::Move { from, block, to } => {
                debug_assert!(from < p && to < p, "machine out of range");
                debug_assert_ne!(from, to, "move must change machines");
                #[cfg(debug_assertions)]
                debug_assert!(touched.insert((from, block)), "block touched twice in one delta");
                let (src, targets) = read_block(from, block);
                debug_assert!(!targets.is_empty(), "moving a hollow block");
                inboxes[from].push(Patch::Detach { block });
                inboxes[to].push(Patch::Install { src, targets });
            }
            PlaceOp::Split { from, block, at, to } => {
                debug_assert!(from < p && to < p, "machine out of range");
                debug_assert_ne!(from, to, "split must change machines");
                #[cfg(debug_assertions)]
                debug_assert!(touched.insert((from, block)), "block touched twice in one delta");
                let (src, targets) = read_block(from, block);
                debug_assert!(at >= 1 && at < targets.len(), "split point must leave both halves");
                inboxes[from].push(Patch::Truncate { block, at });
                inboxes[to].push(Patch::Install { src, targets: targets[at..].to_vec() });
            }
        }
    }
    inboxes
}

/// Distinct destination vertices of a target slice, ascending — the
/// vertices whose dst-leaf membership this edit may have changed.
fn distinct_dsts(targets: &[(Vid, f32)]) -> Vec<Vid> {
    let mut vs: Vec<Vid> = targets.iter().map(|(v, _)| *v).collect();
    vs.sort_unstable();
    vs.dedup();
    vs
}

/// Apply one machine's patch inbox in order, returning
/// `(vertex, is_src, present)` membership notes in emission order plus
/// the work units charged (per patch: shipped/landed targets + 1).  The
/// engine ships the notes to its driver as `DeltaNote`s; the replay
/// folds them directly — same notes, same order, either way.
pub(crate) fn apply_patches(
    blocks: &mut Vec<EdgeBlock>,
    block_of: &mut BlockIndex,
    inbox: Vec<Patch>,
) -> (Vec<(Vid, bool, bool)>, u64) {
    let mut notes: Vec<(Vid, bool, bool)> = Vec::new();
    let mut work = 0u64;
    for patch in inbox {
        match patch {
            Patch::Detach { block } => {
                let src = blocks[block as usize].src;
                let removed = std::mem::take(&mut blocks[block as usize].targets);
                let was_indexed = block_of.remove(src, block);
                debug_assert!(was_indexed, "detached block was not indexed");
                work += removed.len() as u64 + 1;
                notes.push((src, true, mutate::holds_src(blocks, block_of, src)));
                for v in distinct_dsts(&removed) {
                    notes.push((v, false, mutate::holds_dst(blocks, v)));
                }
            }
            Patch::Truncate { block, at } => {
                let src = blocks[block as usize].src;
                let shipped = blocks[block as usize].targets.split_off(at);
                work += shipped.len() as u64 + 1;
                notes.push((src, true, mutate::holds_src(blocks, block_of, src)));
                for v in distinct_dsts(&shipped) {
                    notes.push((v, false, mutate::holds_dst(blocks, v)));
                }
            }
            Patch::Install { src, targets } => {
                let idx = blocks.len() as u32;
                work += targets.len() as u64 + 1;
                let vs = distinct_dsts(&targets);
                blocks.push(EdgeBlock { src, targets });
                block_of.insert(src, idx);
                notes.push((src, true, true));
                for v in vs {
                    notes.push((v, false, true));
                }
            }
        }
    }
    (notes, work)
}

/// Replay a placement delta onto a plain [`DistGraph`] — the
/// single-address-space reference for `SpmdEngine::apply_placement`,
/// following the identical snapshot/patch/fold pipeline so the replayed
/// graph's blocks, indices and leaf sets match the live engine's bit
/// for bit.  `out_deg` and `m` are untouched by construction (placement
/// moves arcs between machines, it never creates or destroys them).
pub fn apply_to_distgraph(dg: &mut DistGraph, delta: &PlacementDelta) {
    let inboxes = build_patches(dg.p, delta, |m, b| {
        let blk = &dg.blocks[m][b as usize];
        (blk.src, blk.targets.clone())
    });
    for (m, inbox) in inboxes.into_iter().enumerate() {
        let (notes, _work) = apply_patches(&mut dg.blocks[m], &mut dg.block_of[m], inbox);
        // Fold in (machine, emission) order — exactly the (sender,
        // emission-index) delivery order of the engine's note superstep.
        for (vertex, is_src, present) in notes {
            if is_src {
                mutate::set_membership(&mut dg.src_leaves[vertex as usize], m, present);
            } else {
                mutate::set_membership(&mut dg.dst_leaves[vertex as usize], m, present);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::ingest::ingest;
    use crate::mutate::recompute_leaves;
    use crate::{Cluster, CostModel};

    fn ingested(n: usize, p: usize, seed: u64) -> DistGraph {
        let g = gen::barabasi_albert(n, 5, seed);
        let mut c = Cluster::new(p, CostModel::paper_cluster());
        ingest(&mut c, &g, 8)
    }

    /// A (from, block, to) pick with a non-trivial block on `from`.
    fn pick_block(dg: &DistGraph, min_len: usize) -> (usize, u32, usize) {
        for (m, bs) in dg.blocks.iter().enumerate() {
            for (i, b) in bs.iter().enumerate() {
                if b.targets.len() >= min_len {
                    let to = (m + 1) % dg.p;
                    return (m, i as u32, to);
                }
            }
        }
        panic!("no block of len >= {min_len}");
    }

    #[test]
    fn move_keeps_leaves_in_sync_with_ground_truth() {
        let mut dg = ingested(600, 4, 3);
        let (from, block, to) = pick_block(&dg, 2);
        let src = dg.blocks[from][block as usize].src;
        let len = dg.blocks[from][block as usize].targets.len();
        let m0 = dg.m;
        apply_to_distgraph(
            &mut dg,
            &PlacementDelta { round: 0, ops: vec![PlaceOp::Move { from, block, to }] },
        );
        // Hollowed in place, landed whole at the tail of `to`.
        assert!(dg.blocks[from][block as usize].targets.is_empty());
        assert_eq!(dg.blocks[to].last().unwrap().src, src);
        assert_eq!(dg.blocks[to].last().unwrap().targets.len(), len);
        assert_eq!(dg.m, m0, "placement never changes the arc count");
        let (src_l, dst_l) = recompute_leaves(&dg);
        assert_eq!(dg.src_leaves, src_l, "incremental src leaves drifted");
        assert_eq!(dg.dst_leaves, dst_l, "incremental dst leaves drifted");
    }

    #[test]
    fn split_replicates_the_source_on_both_machines() {
        let mut dg = ingested(600, 4, 7);
        let (from, block, to) = pick_block(&dg, 4);
        let src = dg.blocks[from][block as usize].src;
        let len = dg.blocks[from][block as usize].targets.len();
        let at = len / 2;
        apply_to_distgraph(
            &mut dg,
            &PlacementDelta { round: 0, ops: vec![PlaceOp::Split { from, block, at, to }] },
        );
        assert_eq!(dg.blocks[from][block as usize].targets.len(), at);
        assert_eq!(dg.blocks[to].last().unwrap().targets.len(), len - at);
        assert!(dg.src_leaves[src as usize].contains(&from), "kept half stays a leaf");
        assert!(dg.src_leaves[src as usize].contains(&to), "replica is a leaf");
        let (src_l, dst_l) = recompute_leaves(&dg);
        assert_eq!(dg.src_leaves, src_l);
        assert_eq!(dg.dst_leaves, dst_l);
    }

    #[test]
    fn degrees_and_arc_count_survive_any_delta() {
        let mut dg = ingested(500, 4, 11);
        let deg0 = dg.out_deg.clone();
        let m0 = dg.m;
        let (from, block, to) = pick_block(&dg, 4);
        let at = dg.blocks[from][block as usize].targets.len() / 2;
        apply_to_distgraph(
            &mut dg,
            &PlacementDelta {
                round: 0,
                ops: vec![PlaceOp::Split { from, block, at, to }],
            },
        );
        assert_eq!(dg.out_deg, deg0);
        assert_eq!(dg.m, m0);
        let placed: usize =
            dg.blocks.iter().flat_map(|bs| bs.iter().map(|b| b.targets.len())).sum();
        assert_eq!(placed, dg.m, "every arc still resides somewhere");
    }
}
