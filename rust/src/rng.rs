//! Deterministic pseudo-random number generation for the whole stack.
//!
//! The simulator must be bit-reproducible across runs (tests and benches
//! key on it), so we carry our own small PRNG instead of pulling in `rand`:
//! SplitMix64 for seeding and xoshiro256** for the streams.

/// SplitMix64 step — used to expand a user seed into stream seeds.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, deterministic.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; the tiny
        // modulo bias is irrelevant for workload generation.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    #[inline]
    pub fn next_usize(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_usize(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Stable 64-bit hash (fmix64 from MurmurHash3) — used for data-chunk
/// placement and virtual-transit-machine mapping.  NOT a PRNG: the same
/// input must always land on the same machine.
#[inline]
pub fn hash64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xFF51AFD7ED558CCD);
    x ^= x >> 33;
    x = x.wrapping_mul(0xC4CEB9FE1A85EC53);
    x ^= x >> 33;
    x
}

/// Hash two words (e.g. (tree_root, node_id) -> physical machine).
#[inline]
pub fn hash2(a: u64, b: u64) -> u64 {
    hash64(a.wrapping_mul(0x9E3779B97F4A7C15) ^ hash64(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.next_below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..64).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn hash64_stable() {
        // Pin a value so accidental algorithm changes (which would silently
        // re-place every chunk) fail loudly.
        assert_eq!(hash64(42), hash64(42));
        assert_ne!(hash64(42), hash64(43));
    }
}
