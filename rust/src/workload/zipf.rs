//! Zipf-distributed key sampling (paper §4: "data access patterns
//! following a Zipf distribution, a common setting in database
//! benchmarks").  P(rank k) ∝ 1/k^γ over ranks 1..=n; sampled by binary
//! search over the precomputed CDF, with ranks mapped to a shuffled key
//! space so hot keys are spread over machines like real hashed keys.

use crate::rng::Rng;

/// Precomputed Zipf(γ) sampler over `n` ranks.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, gamma: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(gamma);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `0..n` (rank 0 is the hottest).
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|c| *c < u).min(self.cdf.len() - 1)
    }

    pub fn n(&self) -> usize {
        self.cdf.len()
    }

    /// Expected probability of the hottest rank.
    pub fn p_hot(&self) -> f64 {
        self.cdf[0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_in_range() {
        let z = Zipf::new(100, 1.5);
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            assert!(z.sample(&mut rng) < 100);
        }
    }

    #[test]
    fn higher_gamma_is_more_skewed() {
        let n = 1000;
        let count_hot = |gamma: f64| {
            let z = Zipf::new(n, gamma);
            let mut rng = Rng::new(7);
            (0..20_000).filter(|_| z.sample(&mut rng) == 0).count()
        };
        let h15 = count_hot(1.5);
        let h25 = count_hot(2.5);
        assert!(h25 > h15, "γ=2.5 hot {h25} !> γ=1.5 hot {h15}");
        // γ=2.5 over 1000 keys: rank-0 mass ≈ 1/ζ(2.5) ≈ 0.75.
        assert!(h25 as f64 / 20_000.0 > 0.5);
    }

    #[test]
    fn rank_probabilities_monotone() {
        let z = Zipf::new(50, 2.0);
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; 50];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[1]);
        assert!(counts[1] > counts[5]);
        assert!(counts[5] > counts[49]);
    }
}
