//! Closed-loop clients for the serving layer.
//!
//! The open-loop generator ([`super::queries::generate_stream`]) offers
//! load that never reacts to the server — the right model for measuring
//! shed load past saturation, but it cannot show the self-throttling
//! regime every interactive deployment actually runs in.  This module is
//! the complementary model (the canonical closed-loop harness shape —
//! N clients, think time, at most one outstanding request each):
//!
//! * each of `clients` clients keeps **at most one query outstanding**;
//! * after its query completes (or is shed at admission), the client
//!   *thinks* for `think_ticks` logical ticks, then issues the next one
//!   — so the offered rate adapts to service latency, with an upper
//!   bound of `clients / (think_ticks + service)` queries per tick;
//! * each client draws kinds and Zipf sources from its **own** RNG
//!   stream (split off the run seed), so the sequence of queries a
//!   client issues is independent of how the other clients' completions
//!   interleave — the whole run is a deterministic function of
//!   (config, hot order, seed, and the server's logical clock).
//!
//! A shed query counts against the client's budget and triggers the same
//! think-time backoff as a completion (retry-after, not hammering), so
//! `clients * queries_per_client` is exactly the offered load of a run.
//!
//! The model talks to the server through the
//! [`ArrivalSource`](super::queries::ArrivalSource) feedback hooks; the
//! server's admission loop polls it between queries of an executing
//! batch, which is what makes think-time expire *during* service —
//! see `serve::Server::serve`.

use crate::graph::Vid;
use crate::rng::{splitmix64, Rng};

use super::queries::{ArrivalSource, Query, QueryMix};
use super::Zipf;

/// Closed-loop client population parameters.
#[derive(Clone, Copy, Debug)]
pub struct ClosedLoopConfig {
    /// Number of concurrent clients (the offered-load knob: a load curve
    /// sweeps this).
    pub clients: usize,
    /// Logical ticks a client thinks between its previous query's
    /// completion (or rejection) and its next issue.
    pub think_ticks: u64,
    /// Queries each client issues before retiring (bounds the run).
    pub queries_per_client: usize,
    /// Zipf exponent over source-vertex hotness ranks.
    pub zipf_s: f64,
    pub mix: QueryMix,
}

struct Client {
    rng: Rng,
    /// Earliest tick this client may issue its next query.
    issue_at: u64,
    issued: usize,
    /// A query is in the admission queue or in service right now.
    outstanding: bool,
}

/// Deterministic closed-loop [`ArrivalSource`]: see the module docs.
pub struct ClosedLoop {
    cfg: ClosedLoopConfig,
    zipf: Zipf,
    hot: Vec<Vid>,
    clients: Vec<Client>,
    /// `owner[id]` = index of the client that issued query `id` (ids are
    /// assigned in emission order).
    owner: Vec<usize>,
    /// Every query emitted so far, indexed by id — the cross-check
    /// replays served queries against a single-shot reference from here.
    emitted: Vec<Query>,
}

impl ClosedLoop {
    pub fn new(cfg: ClosedLoopConfig, hot_order: &[Vid], seed: u64) -> Self {
        assert!(cfg.clients >= 1, "need at least one client");
        assert!(cfg.queries_per_client >= 1, "each client needs a query budget");
        assert!(!hot_order.is_empty(), "empty source universe");
        assert!(cfg.mix.total() > 0, "query mix has zero total weight");
        let mut sm = seed;
        let clients = (0..cfg.clients)
            .map(|_| Client {
                rng: Rng::new(splitmix64(&mut sm)),
                issue_at: 0,
                issued: 0,
                outstanding: false,
            })
            .collect();
        ClosedLoop {
            cfg,
            zipf: Zipf::new(hot_order.len(), cfg.zipf_s),
            hot: hot_order.to_vec(),
            clients,
            owner: Vec::new(),
            emitted: Vec::new(),
        }
    }

    /// Total queries this population will offer over a full run.
    pub fn offered_total(&self) -> u64 {
        (self.cfg.clients * self.cfg.queries_per_client) as u64
    }

    /// Every query emitted so far, indexed by id.
    pub fn emitted(&self) -> &[Query] {
        &self.emitted
    }

    /// Which client issued query `id`.
    pub fn owner_of(&self, id: u64) -> usize {
        self.owner[id as usize]
    }

    fn client_finished(&mut self, id: u64, tick: u64) {
        let c = self.owner[id as usize];
        let client = &mut self.clients[c];
        debug_assert!(client.outstanding, "feedback for a query client {c} never issued");
        client.outstanding = false;
        client.issue_at = tick + self.cfg.think_ticks;
    }
}

impl ArrivalSource for ClosedLoop {
    fn poll(&mut self, tick: u64) -> Vec<Query> {
        let total = self.cfg.mix.total();
        let mut out = Vec::new();
        for (c, client) in self.clients.iter_mut().enumerate() {
            if client.outstanding
                || client.issued >= self.cfg.queries_per_client
                || client.issue_at > tick
            {
                continue;
            }
            let kind = self.cfg.mix.pick(client.rng.next_below(total as u64) as u32);
            let source = self.hot[self.zipf.sample(&mut client.rng)];
            let q = Query { id: self.emitted.len() as u64, kind, source, arrival: tick };
            client.outstanding = true;
            client.issued += 1;
            self.owner.push(c);
            self.emitted.push(q);
            out.push(q);
        }
        out
    }

    fn next_arrival(&self) -> Option<u64> {
        self.clients
            .iter()
            .filter(|c| !c.outstanding && c.issued < self.cfg.queries_per_client)
            .map(|c| c.issue_at)
            .min()
    }

    fn done(&self) -> bool {
        self.clients.iter().all(|c| c.issued >= self.cfg.queries_per_client)
    }

    fn on_complete(&mut self, id: u64, tick: u64) {
        self.client_finished(id, tick);
    }

    fn on_reject(&mut self, id: u64, tick: u64) {
        // Shedding is a completion from the client's point of view: back
        // off one think time before retrying with the next query.
        self.client_finished(id, tick);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(clients: usize, think: u64, per_client: usize) -> ClosedLoopConfig {
        ClosedLoopConfig {
            clients,
            think_ticks: think,
            queries_per_client: per_client,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        }
    }

    /// Drive a source like the server does, completing every query
    /// `service` ticks after its dispatch tick (single-server FIFO, batch
    /// of 1) — enough to exercise the full issue→complete→think cycle
    /// without the serving layer.
    fn drive(src: &mut ClosedLoop, service: u64) -> Vec<Query> {
        let mut tick = 0u64;
        let mut seen = Vec::new();
        let mut queue: std::collections::VecDeque<Query> = std::collections::VecDeque::new();
        while !(src.done() && queue.is_empty()) {
            queue.extend(src.poll(tick));
            if let Some(q) = queue.pop_front() {
                tick += service;
                src.on_complete(q.id, tick);
                seen.push(q);
            } else {
                match src.next_arrival() {
                    Some(t) => tick = t.max(tick + 1),
                    None => break,
                }
            }
        }
        seen
    }

    #[test]
    fn same_seed_same_schedule() {
        let hot: Vec<Vid> = (0..200).collect();
        let a = drive(&mut ClosedLoop::new(cfg(4, 3, 8), &hot, 42), 2);
        let b = drive(&mut ClosedLoop::new(cfg(4, 3, 8), &hot, 42), 2);
        assert_eq!(a, b, "identical seeds must give identical schedules");
        let c = drive(&mut ClosedLoop::new(cfg(4, 3, 8), &hot, 43), 2);
        assert_ne!(a, c, "distinct seeds must diverge");
    }

    #[test]
    fn budget_is_exact_and_one_outstanding_per_client() {
        let hot: Vec<Vid> = (0..100).collect();
        let mut src = ClosedLoop::new(cfg(3, 2, 5), &hot, 7);
        let seen = drive(&mut src, 4);
        assert_eq!(seen.len() as u64, src.offered_total(), "every budgeted query issues");
        assert_eq!(src.emitted().len(), seen.len());
        assert!(src.done());
        // With service 4 and one server, a client can never have two
        // queries in flight: consecutive queries of one client are
        // separated by at least service + think ticks.
        for c in 0..3 {
            let mine: Vec<&Query> =
                seen.iter().filter(|q| src.owner_of(q.id) == c).collect();
            assert_eq!(mine.len(), 5, "client {c} must issue its whole budget");
            for w in mine.windows(2) {
                assert!(
                    w[1].arrival >= w[0].arrival + 4 + 2,
                    "client {c} overlapped its own queries"
                );
            }
        }
    }

    #[test]
    fn rejection_backs_off_like_completion() {
        let hot: Vec<Vid> = (0..50).collect();
        let mut src = ClosedLoop::new(cfg(1, 5, 2), &hot, 9);
        let first = src.poll(0);
        assert_eq!(first.len(), 1);
        assert!(src.poll(0).is_empty(), "one outstanding query per client");
        src.on_reject(first[0].id, 3);
        assert_eq!(src.next_arrival(), Some(8), "rejected at 3 + think 5");
        assert!(src.poll(7).is_empty());
        let second = src.poll(8);
        assert_eq!(second.len(), 1);
        src.on_complete(second[0].id, 10);
        assert!(src.done(), "budget of 2 spent");
        assert_eq!(src.next_arrival(), None);
    }

    #[test]
    fn think_time_throttles_offered_rate() {
        let hot: Vec<Vid> = (0..100).collect();
        // Near-instant service: the inter-arrival spacing is governed by
        // think time (arrivals at 0, 11, 22, 33, 44 for service 1).
        let seen = drive(&mut ClosedLoop::new(cfg(1, 10, 5), &hot, 3), 1);
        assert_eq!(seen.len(), 5);
        for w in seen.windows(2) {
            assert!(
                w[1].arrival - w[0].arrival >= 11,
                "arrivals must be separated by service + think"
            );
        }
    }
}
