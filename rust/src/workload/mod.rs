//! Workload generators for the paper's evaluations: YCSB mixes over
//! Zipf-distributed keys (§4), adversarial single-key batches, and the
//! serving layer's open-loop graph query streams ([`queries`]).

pub mod queries;
pub mod ycsb;
pub mod zipf;

pub use queries::{generate_stream, hot_source_order, Query, QueryKind, QueryMix, StreamConfig};
pub use ycsb::{YcsbKind, YcsbWorkload};
pub use zipf::Zipf;
