//! Workload generators for the paper's evaluations: YCSB mixes over
//! Zipf-distributed keys (§4) and adversarial single-key batches.

pub mod ycsb;
pub mod zipf;

pub use ycsb::{YcsbKind, YcsbWorkload};
pub use zipf::Zipf;
