//! Workload generators for the paper's evaluations: YCSB mixes over
//! Zipf-distributed keys (§4), adversarial single-key batches, and the
//! serving layer's graph query arrivals — open-loop fixed-rate streams
//! ([`queries`]) and closed-loop client populations ([`closed_loop`]),
//! both feeding the server through the [`ArrivalSource`] admission
//! interface.

pub mod closed_loop;
pub mod queries;
pub mod ycsb;
pub mod zipf;

pub use closed_loop::{ClosedLoop, ClosedLoopConfig};
pub use queries::{
    generate_stream, hot_source_order, ArrivalSource, OpenLoopSource, Query, QueryKind, QueryMix,
    StreamConfig,
};
pub use ycsb::{YcsbKind, YcsbWorkload};
pub use zipf::Zipf;
