//! YCSB workload generation (paper §4).
//!
//! Workloads A (50% reads / 50% writes), B (95/5), C (read-only) and LOAD
//! (write-only), with keys drawn Zipf(γ).  Each task "fetches an item from
//! the key-value store, performs a multiply-and-add operation, and then
//! optionally writes the updated value back".

use crate::kvstore::KvOp;
use crate::orchestration::Task;
use crate::rng::{hash64, Rng};
use crate::workload::zipf::Zipf;

/// The four paper workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum YcsbKind {
    A,
    B,
    C,
    Load,
}

impl YcsbKind {
    pub fn write_fraction(self) -> f64 {
        match self {
            YcsbKind::A => 0.5,
            YcsbKind::B => 0.05,
            YcsbKind::C => 0.0,
            YcsbKind::Load => 1.0,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            YcsbKind::A => "YCSB-A",
            YcsbKind::B => "YCSB-B",
            YcsbKind::C => "YCSB-C",
            YcsbKind::Load => "LOAD",
        }
    }

    pub const ALL: [YcsbKind; 4] = [YcsbKind::A, YcsbKind::B, YcsbKind::C, YcsbKind::Load];
}

/// Generator for one YCSB configuration.
#[derive(Clone, Debug)]
pub struct YcsbWorkload {
    pub kind: YcsbKind,
    pub key_space: u64,
    pub gamma: f64,
    pub buckets: u64,
    zipf: Zipf,
}

impl YcsbWorkload {
    pub fn new(kind: YcsbKind, key_space: u64, gamma: f64, buckets: u64) -> Self {
        YcsbWorkload {
            kind,
            key_space,
            gamma,
            buckets,
            zipf: Zipf::new(key_space as usize, gamma),
        }
    }

    /// Zipf rank -> key: ranks are scattered over the key space so hot
    /// keys land on independent buckets/machines.
    fn key_of_rank(&self, rank: usize) -> u64 {
        hash64(rank as u64) % self.key_space
    }

    /// Generate `n` tasks (ops), sequence-numbered from `seq0` so
    /// concurrent writes resolve deterministically (Def. 2 class iv).
    pub fn generate(&self, rng: &mut Rng, n: usize, seq0: u64) -> Vec<Task<KvOp>> {
        (0..n)
            .map(|i| {
                let key = self.key_of_rank(self.zipf.sample(rng));
                let is_write = rng.next_f64() < self.kind.write_fraction();
                let op = if is_write {
                    KvOp::update(key, seq0 + i as u64, 1.0 + rng.next_f32() * 0.5, rng.next_f32())
                } else {
                    KvOp::read(key, seq0 + i as u64)
                };
                Task::inplace(op.bucket(self.buckets), op)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_fractions() {
        assert_eq!(YcsbKind::C.write_fraction(), 0.0);
        assert_eq!(YcsbKind::Load.write_fraction(), 1.0);
        assert!((YcsbKind::A.write_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn generate_respects_mix() {
        let w = YcsbWorkload::new(YcsbKind::B, 10_000, 1.5, 1024);
        let mut rng = Rng::new(5);
        let tasks = w.generate(&mut rng, 4000, 0);
        let writes = tasks.iter().filter(|t| t.ctx.is_write()).count();
        let frac = writes as f64 / 4000.0;
        assert!((0.02..0.09).contains(&frac), "write frac {frac}");
    }

    #[test]
    fn tasks_target_their_buckets() {
        let w = YcsbWorkload::new(YcsbKind::A, 1000, 2.0, 64);
        let mut rng = Rng::new(9);
        for t in w.generate(&mut rng, 500, 0) {
            assert_eq!(t.read_addr, t.ctx.bucket(64));
            assert_eq!(t.read_addr, t.write_addr);
            assert!(t.read_addr < 64);
        }
    }

    #[test]
    fn zipf_skew_shows_in_buckets() {
        let w = YcsbWorkload::new(YcsbKind::C, 100_000, 2.5, 4096);
        let mut rng = Rng::new(11);
        let tasks = w.generate(&mut rng, 10_000, 0);
        let mut counts = std::collections::HashMap::new();
        for t in tasks {
            *counts.entry(t.read_addr).or_insert(0usize) += 1;
        }
        let max = counts.values().copied().max().unwrap();
        assert!(max > 2_000, "hottest bucket only {max} hits at γ=2.5");
    }
}
