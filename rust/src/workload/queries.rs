//! Deterministic open-loop graph query streams for the serving layer.
//!
//! The paper frames TD-Orch as a *serving* framework (§2: batches of
//! lambda tasks under Zipf-skewed access); hotspot-aware stream work
//! (AutoFlow, arXiv:2103.08888; DPA, arXiv:2308.00938) shows the
//! interesting load-balancing behavior only appears under a continuous
//! skewed query stream.  This module generates that stream: a mixed
//! {BFS, SSSP, PR, CC, BC} sequence whose BFS/SSSP/BC sources are drawn
//! Zipf-distributed over vertex *hotness ranks* — rank k is the k-th
//! highest-out-degree vertex ([`hot_source_order`]) — so a high exponent
//! concentrates traversal roots on the hubs, the adversarial case for
//! owner-centric placements.
//!
//! The stream is a pure function of (hot order, config, seed).  It never
//! sees the machine count: the same seed drives byte-identical streams
//! into a P=1 engine and a P=64 engine (`tests/serve_stream.rs`), which
//! is what keeps serving runs cross-checkable against any reference
//! deployment.  Arrivals are open-loop at a fixed per-tick rate —
//! arrivals never wait for completions, so queueing behavior is the
//! server's problem, not the generator's.

use crate::graph::Vid;
use crate::rng::Rng;

use super::Zipf;

/// Which algorithm a query runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryKind {
    Bfs,
    Sssp,
    Pr,
    Cc,
    Bc,
}

impl QueryKind {
    pub const ALL: [QueryKind; 5] =
        [QueryKind::Bfs, QueryKind::Sssp, QueryKind::Pr, QueryKind::Cc, QueryKind::Bc];

    pub fn label(self) -> &'static str {
        match self {
            QueryKind::Bfs => "BFS",
            QueryKind::Sssp => "SSSP",
            QueryKind::Pr => "PR",
            QueryKind::Cc => "CC",
            QueryKind::Bc => "BC",
        }
    }
}

/// One query in the stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub id: u64,
    pub kind: QueryKind,
    /// Source vertex.  BFS/SSSP/BC traverse from it; PR/CC ignore it,
    /// but it is drawn for *every* query so the stream layout (and every
    /// later query) is independent of the kind mix.
    pub source: Vid,
    /// Logical arrival tick (open loop: fixed arrivals per tick).
    pub arrival: u64,
}

/// Relative weights of the five query kinds.
#[derive(Clone, Copy, Debug)]
pub struct QueryMix {
    pub bfs: u32,
    pub sssp: u32,
    pub pr: u32,
    pub cc: u32,
    pub bc: u32,
}

impl QueryMix {
    /// The canonical serving mix: all five kinds, equally weighted.
    pub fn balanced() -> Self {
        QueryMix { bfs: 1, sssp: 1, pr: 1, cc: 1, bc: 1 }
    }

    fn total(&self) -> u32 {
        self.bfs + self.sssp + self.pr + self.cc + self.bc
    }

    fn pick(&self, r: u32) -> QueryKind {
        debug_assert!(r < self.total());
        if r < self.bfs {
            QueryKind::Bfs
        } else if r < self.bfs + self.sssp {
            QueryKind::Sssp
        } else if r < self.bfs + self.sssp + self.pr {
            QueryKind::Pr
        } else if r < self.bfs + self.sssp + self.pr + self.cc {
            QueryKind::Cc
        } else {
            QueryKind::Bc
        }
    }
}

/// Vertices ordered hottest-first (out-degree descending, vertex id
/// ascending on ties) — the Zipf rank → source mapping.  Derived from
/// the per-vertex degree array, which is a property of the GRAPH, not of
/// the deployment: every machine count produces the same order.
pub fn hot_source_order(out_deg: &[u32]) -> Vec<Vid> {
    let mut order: Vec<Vid> = (0..out_deg.len() as Vid).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(out_deg[v as usize]), v));
    order
}

/// Open-loop stream parameters.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub queries: usize,
    /// Queries arriving per logical tick (fixed-rate open loop).
    pub per_tick: usize,
    /// Zipf exponent over source-vertex hotness ranks.
    pub zipf_s: f64,
    pub mix: QueryMix,
}

/// Generate the deterministic query stream: query `i` arrives at tick
/// `i / per_tick`, draws its kind from the weighted mix and its source
/// from Zipf(`zipf_s`) over `hot_order` ranks.  Arrivals are emitted in
/// nondecreasing tick order (what `serve::Server::run` requires).
pub fn generate_stream(cfg: StreamConfig, hot_order: &[Vid], seed: u64) -> Vec<Query> {
    assert!(cfg.per_tick >= 1, "need at least one arrival per tick");
    assert!(!hot_order.is_empty(), "empty source universe");
    let total = cfg.mix.total();
    assert!(total > 0, "query mix has zero total weight");
    let zipf = Zipf::new(hot_order.len(), cfg.zipf_s);
    let mut rng = Rng::new(seed);
    (0..cfg.queries)
        .map(|i| {
            let kind = cfg.mix.pick(rng.next_below(total as u64) as u32);
            let source = hot_order[zipf.sample(&mut rng)];
            Query { id: i as u64, kind, source, arrival: (i / cfg.per_tick) as u64 }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(queries: usize, zipf_s: f64) -> StreamConfig {
        StreamConfig { queries, per_tick: 3, zipf_s, mix: QueryMix::balanced() }
    }

    #[test]
    fn same_seed_same_stream() {
        let hot: Vec<Vid> = (0..500).collect();
        let a = generate_stream(cfg(300, 1.5), &hot, 42);
        let b = generate_stream(cfg(300, 1.5), &hot, 42);
        assert_eq!(a, b);
        let c = generate_stream(cfg(300, 1.5), &hot, 43);
        assert_ne!(a, c, "distinct seeds must diverge");
    }

    #[test]
    fn arrivals_are_nondecreasing_at_the_configured_rate() {
        let hot: Vec<Vid> = (0..100).collect();
        let s = generate_stream(cfg(10, 1.2), &hot, 7);
        let arrivals: Vec<u64> = s.iter().map(|q| q.arrival).collect();
        assert_eq!(arrivals, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
        assert_eq!(s[4].id, 4);
    }

    #[test]
    fn balanced_mix_covers_every_kind() {
        let hot: Vec<Vid> = (0..100).collect();
        let s = generate_stream(cfg(400, 1.2), &hot, 11);
        for kind in QueryKind::ALL {
            let count = s.iter().filter(|q| q.kind == kind).count();
            // 80 expected per kind; 3σ ≈ 24.
            assert!(count > 45, "{}: only {count}/400", kind.label());
        }
    }

    #[test]
    fn hot_source_order_is_degree_descending_id_ascending() {
        let out_deg = [3u32, 9, 9, 1, 0];
        assert_eq!(hot_source_order(&out_deg), vec![1, 2, 0, 3, 4]);
    }
}
