//! Deterministic open-loop graph query streams for the serving layer.
//!
//! The paper frames TD-Orch as a *serving* framework (§2: batches of
//! lambda tasks under Zipf-skewed access); hotspot-aware stream work
//! (AutoFlow, arXiv:2103.08888; DPA, arXiv:2308.00938) shows the
//! interesting load-balancing behavior only appears under a continuous
//! skewed query stream.  This module generates that stream: a mixed
//! {BFS, SSSP, PR, CC, BC} sequence whose BFS/SSSP/BC sources are drawn
//! Zipf-distributed over vertex *hotness ranks* — rank k is the k-th
//! highest-out-degree vertex ([`hot_source_order`]) — so a high exponent
//! concentrates traversal roots on the hubs, the adversarial case for
//! owner-centric placements.
//!
//! The stream is a pure function of (hot order, config, seed).  It never
//! sees the machine count: the same seed drives byte-identical streams
//! into a P=1 engine and a P=64 engine (`tests/serve_stream.rs`), which
//! is what keeps serving runs cross-checkable against any reference
//! deployment.  Arrivals are open-loop at a fixed per-tick rate —
//! arrivals never wait for completions, so queueing behavior is the
//! server's problem, not the generator's.

use crate::graph::Vid;
use crate::rng::Rng;

use super::Zipf;

/// Which algorithm a query runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QueryKind {
    Bfs,
    Sssp,
    Pr,
    Cc,
    Bc,
}

impl QueryKind {
    pub const ALL: [QueryKind; 5] =
        [QueryKind::Bfs, QueryKind::Sssp, QueryKind::Pr, QueryKind::Cc, QueryKind::Bc];

    pub fn label(self) -> &'static str {
        match self {
            QueryKind::Bfs => "BFS",
            QueryKind::Sssp => "SSSP",
            QueryKind::Pr => "PR",
            QueryKind::Cc => "CC",
            QueryKind::Bc => "BC",
        }
    }

    /// Position of this kind in [`QueryKind::ALL`] — the index for
    /// per-kind counter arrays (`ServeReport::rejected_by_kind`).
    pub fn index(self) -> usize {
        match self {
            QueryKind::Bfs => 0,
            QueryKind::Sssp => 1,
            QueryKind::Pr => 2,
            QueryKind::Cc => 3,
            QueryKind::Bc => 4,
        }
    }
}

/// One query in the stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Query {
    pub id: u64,
    pub kind: QueryKind,
    /// Source vertex.  BFS/SSSP/BC traverse from it; PR/CC ignore it,
    /// but it is drawn for *every* query so the stream layout (and every
    /// later query) is independent of the kind mix.
    pub source: Vid,
    /// Logical arrival tick (open loop: fixed arrivals per tick).
    pub arrival: u64,
}

/// Relative weights of the five query kinds.
#[derive(Clone, Copy, Debug)]
pub struct QueryMix {
    pub bfs: u32,
    pub sssp: u32,
    pub pr: u32,
    pub cc: u32,
    pub bc: u32,
}

impl QueryMix {
    /// The canonical serving mix: all five kinds, equally weighted.
    pub fn balanced() -> Self {
        QueryMix { bfs: 1, sssp: 1, pr: 1, cc: 1, bc: 1 }
    }

    fn total(&self) -> u32 {
        self.bfs + self.sssp + self.pr + self.cc + self.bc
    }

    fn pick(&self, r: u32) -> QueryKind {
        debug_assert!(r < self.total());
        if r < self.bfs {
            QueryKind::Bfs
        } else if r < self.bfs + self.sssp {
            QueryKind::Sssp
        } else if r < self.bfs + self.sssp + self.pr {
            QueryKind::Pr
        } else if r < self.bfs + self.sssp + self.pr + self.cc {
            QueryKind::Cc
        } else {
            QueryKind::Bc
        }
    }
}

/// Vertices ordered hottest-first (out-degree descending, vertex id
/// ascending on ties) — the Zipf rank → source mapping.  Derived from
/// the per-vertex degree array, which is a property of the GRAPH, not of
/// the deployment: every machine count produces the same order.
pub fn hot_source_order(out_deg: &[u32]) -> Vec<Vid> {
    let mut order: Vec<Vid> = (0..out_deg.len() as Vid).collect();
    order.sort_by_key(|&v| (std::cmp::Reverse(out_deg[v as usize]), v));
    order
}

/// Open-loop stream parameters.  The offered load is
/// `per_tick / every_ticks` queries per logical tick: `per_tick` arrivals
/// land together every `every_ticks` ticks, so rates *below* one query
/// per tick (the underloaded end of a latency-vs-offered-load curve) are
/// expressible as `per_tick: 1, every_ticks: k`.
#[derive(Clone, Copy, Debug)]
pub struct StreamConfig {
    pub queries: usize,
    /// Queries arriving per arrival event (fixed-rate open loop).
    pub per_tick: usize,
    /// Ticks between consecutive arrival events (1 = every tick).
    pub every_ticks: u64,
    /// Zipf exponent over source-vertex hotness ranks.
    pub zipf_s: f64,
    pub mix: QueryMix,
}

impl StreamConfig {
    /// Configured offered load in queries per logical tick.
    pub fn offered_per_tick(&self) -> f64 {
        self.per_tick as f64 / self.every_ticks as f64
    }
}

/// Generate the deterministic query stream: query `i` arrives at tick
/// `(i / per_tick) * every_ticks`, draws its kind from the weighted mix
/// and its source from Zipf(`zipf_s`) over `hot_order` ranks.  Arrivals
/// are emitted in nondecreasing tick order (what `serve::Server::run`
/// requires).
pub fn generate_stream(cfg: StreamConfig, hot_order: &[Vid], seed: u64) -> Vec<Query> {
    assert!(cfg.per_tick >= 1, "need at least one arrival per event");
    assert!(cfg.every_ticks >= 1, "arrival events need a period of at least one tick");
    assert!(!hot_order.is_empty(), "empty source universe");
    let total = cfg.mix.total();
    assert!(total > 0, "query mix has zero total weight");
    let zipf = Zipf::new(hot_order.len(), cfg.zipf_s);
    let mut rng = Rng::new(seed);
    (0..cfg.queries)
        .map(|i| {
            let kind = cfg.mix.pick(rng.next_below(total as u64) as u32);
            let source = hot_order[zipf.sample(&mut rng)];
            Query {
                id: i as u64,
                kind,
                source,
                arrival: (i / cfg.per_tick) as u64 * cfg.every_ticks,
            }
        })
        .collect()
}

/// How the serving loop consumes arrivals: a source is polled tick by
/// tick and — unlike a fixed slice — can *react* to completions, which
/// is what a closed-loop client model needs
/// ([`super::closed_loop::ClosedLoop`]).  Implementations must be
/// deterministic functions of (config, seed, observed tick/feedback
/// sequence): the server promises to drive them with a deterministic
/// logical clock, and together that makes whole serving runs
/// bit-reproducible.
pub trait ArrivalSource {
    /// Hand out every not-yet-emitted query whose arrival time is at or
    /// before `tick`, in deterministic order.  Called with nondecreasing
    /// ticks, possibly several times per tick (the server re-polls
    /// between queries of an executing batch); each query is emitted
    /// exactly once.
    fn poll(&mut self, tick: u64) -> Vec<Query>;

    /// Earliest tick at which a currently-scheduled future arrival will
    /// occur (None = nothing scheduled right now; a closed loop may
    /// schedule more after a completion).  Lets the server skip idle
    /// ticks without missing an admission.
    fn next_arrival(&self) -> Option<u64>;

    /// True once the source will never emit another query.
    fn done(&self) -> bool;

    /// Feedback: query `id` finished service at logical `tick`.
    fn on_complete(&mut self, _id: u64, _tick: u64) {}

    /// Feedback: query `id` was shed at admission (queue full) at `tick`.
    fn on_reject(&mut self, _id: u64, _tick: u64) {}
}

/// [`ArrivalSource`] view of a pregenerated open-loop stream: arrivals
/// never wait for completions, so the feedback hooks are no-ops.
pub struct OpenLoopSource<'a> {
    stream: &'a [Query],
    next: usize,
}

impl<'a> OpenLoopSource<'a> {
    pub fn new(stream: &'a [Query]) -> Self {
        debug_assert!(
            stream.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "stream must arrive in nondecreasing tick order"
        );
        OpenLoopSource { stream, next: 0 }
    }
}

impl ArrivalSource for OpenLoopSource<'_> {
    fn poll(&mut self, tick: u64) -> Vec<Query> {
        let mut out = Vec::new();
        while let Some(q) = self.stream.get(self.next) {
            if q.arrival > tick {
                break;
            }
            out.push(*q);
            self.next += 1;
        }
        out
    }

    fn next_arrival(&self) -> Option<u64> {
        self.stream.get(self.next).map(|q| q.arrival)
    }

    fn done(&self) -> bool {
        self.next >= self.stream.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(queries: usize, zipf_s: f64) -> StreamConfig {
        StreamConfig { queries, per_tick: 3, every_ticks: 1, zipf_s, mix: QueryMix::balanced() }
    }

    #[test]
    fn same_seed_same_stream() {
        let hot: Vec<Vid> = (0..500).collect();
        let a = generate_stream(cfg(300, 1.5), &hot, 42);
        let b = generate_stream(cfg(300, 1.5), &hot, 42);
        assert_eq!(a, b);
        let c = generate_stream(cfg(300, 1.5), &hot, 43);
        assert_ne!(a, c, "distinct seeds must diverge");
    }

    #[test]
    fn arrivals_are_nondecreasing_at_the_configured_rate() {
        let hot: Vec<Vid> = (0..100).collect();
        let s = generate_stream(cfg(10, 1.2), &hot, 7);
        let arrivals: Vec<u64> = s.iter().map(|q| q.arrival).collect();
        assert_eq!(arrivals, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3]);
        assert_eq!(s[4].id, 4);
    }

    #[test]
    fn balanced_mix_covers_every_kind() {
        let hot: Vec<Vid> = (0..100).collect();
        let s = generate_stream(cfg(400, 1.2), &hot, 11);
        for kind in QueryKind::ALL {
            let count = s.iter().filter(|q| q.kind == kind).count();
            // 80 expected per kind; 3σ ≈ 24.
            assert!(count > 45, "{}: only {count}/400", kind.label());
        }
    }

    #[test]
    fn hot_source_order_is_degree_descending_id_ascending() {
        let out_deg = [3u32, 9, 9, 1, 0];
        assert_eq!(hot_source_order(&out_deg), vec![1, 2, 0, 3, 4]);
    }

    #[test]
    fn every_ticks_spaces_arrival_events() {
        let hot: Vec<Vid> = (0..100).collect();
        let mut c = cfg(7, 1.2);
        c.per_tick = 2;
        c.every_ticks = 5;
        let s = generate_stream(c, &hot, 7);
        let arrivals: Vec<u64> = s.iter().map(|q| q.arrival).collect();
        assert_eq!(arrivals, vec![0, 0, 5, 5, 10, 10, 15]);
        assert!((c.offered_per_tick() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn open_loop_source_emits_each_query_once_and_skips_ahead() {
        let hot: Vec<Vid> = (0..100).collect();
        let mut c = cfg(6, 1.2);
        c.per_tick = 2;
        c.every_ticks = 4;
        let stream = generate_stream(c, &hot, 3);
        let mut src = OpenLoopSource::new(&stream);
        assert_eq!(src.next_arrival(), Some(0));
        assert!(!src.done());
        let first = src.poll(0);
        assert_eq!(first.len(), 2);
        assert!(src.poll(0).is_empty(), "re-polling the same tick re-emits nothing");
        assert_eq!(src.next_arrival(), Some(4));
        assert_eq!(src.poll(7).len(), 2);
        assert_eq!(src.poll(100).len(), 2);
        assert!(src.done());
        assert_eq!(src.next_arrival(), None);
    }
}
