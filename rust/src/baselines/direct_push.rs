//! Direct-push baseline (§2.3): offload every task to the machine storing
//! its requested chunk (RPC style).  A hot chunk's owner receives — and
//! must *execute* — up to n task contexts, the `O(nDσ/min{D,P})`
//! worst-case communication and work imbalance the paper derives.

use crate::bsp::{Cluster, MachineId};
use crate::det::{det_map, DetMap};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{Addr, DistStore};

#[derive(Clone, Copy, Debug, Default)]
pub struct DirectPush;

impl<A: OrchApp> Scheduler<A> for DirectPush {
    fn name(&self) -> &'static str {
        "direct-push"
    }

    fn run_stage(
        &self,
        cluster: &mut Cluster,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = cluster.p;
        let sigma = app.sigma();
        let out_words = app.out_words();
        let mut outcome = StageOutcome {
            executed_per_machine: vec![0; p],
            total_executed: 0,
        };

        // Superstep 1: ship every task context to the chunk owner.
        let mut push_out: Vec<Vec<(MachineId, Task<A::Ctx>)>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in tasks.into_iter().enumerate() {
            cluster.work(m, batch.len() as u64);
            for t in batch {
                push_out[m].push((store.owner(t.read_addr), t));
            }
        }
        let push_in = cluster.exchange(push_out, |_| sigma + 1);

        // Superstep 2: owners execute everything they received (this is
        // where the load imbalance materializes), then write back.
        let mut wb_out: Vec<Vec<(MachineId, (Addr, A::Out))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in push_in.into_iter().enumerate() {
            // Group tasks by chunk so each value is fetched locally once.
            let mut by_addr: DetMap<Addr, Vec<Task<A::Ctx>>> = det_map();
            for t in batch {
                by_addr.entry(t.read_addr).or_default().push(t);
            }
            let groups: Vec<(A::Val, Vec<Task<A::Ctx>>)> = by_addr
                .into_iter()
                .map(|(addr, ts)| (store.read_copy(addr), ts))
                .collect();
            let items: Vec<(&A::Ctx, &A::Val)> = groups
                .iter()
                .flat_map(|(val, ts)| ts.iter().map(move |t| (&t.ctx, val)))
                .collect();
            let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
            app.execute_batch(&items, &mut outs);
            let n = items.len() as u64;
            cluster.work(m, n * app.task_work());
            cluster.executed(m, n);
            outcome.executed_per_machine[m] += n;

            let mut pool: DetMap<Addr, A::Out> = det_map();
            let mut it = outs.into_iter();
            for (_, ts) in &groups {
                for t in ts {
                    let Some(out) = it.next().expect("arity") else { continue };
                    cluster.work(m, 1);
                    match pool.remove(&t.write_addr) {
                        Some(acc) => {
                            pool.insert(t.write_addr, app.combine(acc, out));
                        }
                        None => {
                            pool.insert(t.write_addr, out);
                        }
                    }
                }
            }
            for (addr, out) in pool {
                wb_out[m].push((store.owner(addr), (addr, out)));
            }
        }
        let wb_in = cluster.exchange(wb_out, |_| out_words + 1);

        // Superstep 3: merge + apply write-backs.
        for (m, inbox) in wb_in.into_iter().enumerate() {
            let mut merged: DetMap<Addr, A::Out> = det_map();
            for (addr, out) in inbox {
                cluster.work(m, 1);
                match merged.remove(&addr) {
                    Some(acc) => {
                        merged.insert(addr, app.combine(acc, out));
                    }
                    None => {
                        merged.insert(addr, out);
                    }
                }
            }
            let mut addrs: Vec<Addr> = merged.keys().copied().collect();
            addrs.sort_unstable();
            for addr in addrs {
                let out = merged.remove(&addr).unwrap();
                app.apply(store.get_or_default(addr), out);
            }
        }
        cluster.barrier();

        outcome.total_executed = outcome.executed_per_machine.iter().sum();
        outcome
    }
}
