//! Direct-push baseline (§2.3): offload every task to the machine storing
//! its requested chunk (RPC style).  A hot chunk's owner receives — and
//! must *execute* — up to n task contexts, the `O(nDσ/min{D,P})`
//! worst-case communication and work imbalance the paper derives.
//!
//! Written as [`Substrate`] supersteps, so it runs identically on the BSP
//! simulator and on the threaded backend.

use crate::det::{det_map, DetMap};
use crate::exec::{no_messages, nothing_words, Nothing, Substrate};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{owner_of, Addr, DistStore};

#[derive(Clone, Copy, Debug, Default)]
pub struct DirectPush;

impl<A, S> Scheduler<A, S> for DirectPush
where
    A: OrchApp + Sync,
    A::Ctx: Send + 'static,
    A::Val: Send + 'static,
    A::Out: Send + 'static,
    S: Substrate,
{
    fn name(&self) -> &'static str {
        "direct-push"
    }

    fn run_stage(
        &self,
        sub: &mut S,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = sub.machines();
        let (submitted, mut st) = crate::orchestration::start_stage::<A>(p, tasks, store);
        let sigma = app.sigma();
        let out_words = app.out_words();

        // Superstep 1: ship every task context to the chunk owner.
        let pushed: Vec<Vec<Task<A::Ctx>>> = sub.superstep(
            &mut st,
            no_messages(p),
            |_m, s, _in, acct| {
                let batch = std::mem::take(&mut s.batch);
                acct.work(batch.len() as u64);
                batch
                    .into_iter()
                    .map(|t| (owner_of(t.read_addr, p), t))
                    .collect()
            },
            |_t: &Task<A::Ctx>| sigma + 1,
        );

        // Superstep 2: owners execute everything they received (this is
        // where the load imbalance materializes), then write back.
        let wb_in: Vec<Vec<(Addr, A::Out)>> = sub.superstep(
            &mut st,
            pushed,
            |_m, s, inbox, acct| {
                // Group tasks by chunk so each value is fetched once.
                let mut by_addr: DetMap<Addr, Vec<Task<A::Ctx>>> = det_map();
                for t in inbox {
                    by_addr.entry(t.read_addr).or_default().push(t);
                }
                let groups: Vec<(A::Val, Vec<Task<A::Ctx>>)> = by_addr
                    .into_iter()
                    .map(|(addr, ts)| {
                        (s.shard.get(&addr).cloned().unwrap_or_default(), ts)
                    })
                    .collect();
                let items: Vec<(&A::Ctx, &A::Val)> = groups
                    .iter()
                    .flat_map(|(val, ts)| ts.iter().map(move |t| (&t.ctx, val)))
                    .collect();
                let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
                app.execute_batch(&items, &mut outs);
                debug_assert_eq!(outs.len(), items.len());
                let n = items.len() as u64;
                acct.work(n * app.task_work());
                acct.executed(n);
                s.executed += n;

                let mut pool: DetMap<Addr, Option<A::Out>> = det_map();
                let mut it = outs.into_iter();
                for (_, ts) in &groups {
                    for t in ts {
                        let Some(out) = it.next().expect("arity") else { continue };
                        acct.work(1);
                        crate::orchestration::combine_into(app, &mut pool, t.write_addr, out);
                    }
                }
                pool.into_iter()
                    .map(|(addr, out)| (owner_of(addr, p), (addr, out.expect("pool slot"))))
                    .collect()
            },
            |_msg: &(Addr, A::Out)| out_words + 1,
        );

        // Superstep 3: merge + apply write-backs at the owners.
        let _done: Vec<Vec<Nothing>> = sub.superstep(
            &mut st,
            wb_in,
            |_m, s, inbox, acct| {
                crate::orchestration::merge_and_apply(app, inbox, &mut s.shard, acct);
                Vec::new()
            },
            nothing_words,
        );

        crate::orchestration::finish_stage(
            store,
            st.into_iter().map(|s| (s.executed, s.shard)).collect(),
            submitted,
            "direct-push",
        )
    }
}
