//! Direct-pull baseline (§2.3): fetch every requested chunk to the tasks.
//!
//! Each machine deduplicates the chunk addresses its local tasks request,
//! fetches each chunk once from its owner, executes locally, and sends
//! pre-combined write-backs to the owners.  Works well at low contention;
//! a hot chunk's owner must ship up to P·B words (and receive up to P
//! requests) — the `O(DPB/min{D,P})` worst case the paper derives.

use crate::bsp::{Cluster, MachineId};
use crate::det::{det_map, det_set, DetMap};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{Addr, DistStore};

#[derive(Clone, Copy, Debug, Default)]
pub struct DirectPull;

impl<A: OrchApp> Scheduler<A> for DirectPull {
    fn name(&self) -> &'static str {
        "direct-pull"
    }

    fn run_stage(
        &self,
        cluster: &mut Cluster,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = cluster.p;
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();
        let mut outcome = StageOutcome {
            executed_per_machine: vec![0; p],
            total_executed: 0,
        };

        // Superstep 1: dedup + request.
        let mut req_out: Vec<Vec<(MachineId, (Addr, MachineId))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in tasks.iter().enumerate() {
            cluster.work(m, batch.len() as u64); // dedup sweep
            let mut seen = det_set();
            for t in batch {
                if seen.insert(t.read_addr) {
                    req_out[m].push((store.owner(t.read_addr), (t.read_addr, m)));
                }
            }
        }
        let req_in = cluster.exchange(req_out, |_| 2);

        // Superstep 2: owners ship chunk copies to each requester.
        let mut val_out: Vec<Vec<(MachineId, (Addr, A::Val))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, inbox) in req_in.into_iter().enumerate() {
            cluster.work(m, inbox.len() as u64);
            for (addr, requester) in inbox {
                val_out[m].push((requester, (addr, store.read_copy(addr))));
            }
        }
        let val_in = cluster.exchange(val_out, |_| chunk_words + 1);

        // Superstep 3: execute locally (one XLA-able batch per machine),
        // pre-combine write-backs per target address.
        let mut wb_out: Vec<Vec<(MachineId, (Addr, A::Out))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, (inbox, batch)) in val_in.into_iter().zip(tasks.into_iter()).enumerate() {
            let mut vals: DetMap<Addr, A::Val> = det_map();
            for (addr, val) in inbox {
                vals.insert(addr, val);
            }
            let items: Vec<(&A::Ctx, &A::Val)> = batch
                .iter()
                .map(|t| (&t.ctx, vals.get(&t.read_addr).expect("missing pulled chunk")))
                .collect();
            let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
            app.execute_batch(&items, &mut outs);
            let n = batch.len() as u64;
            cluster.work(m, n * app.task_work());
            cluster.executed(m, n);
            outcome.executed_per_machine[m] += n;

            let mut pool: DetMap<Addr, A::Out> = det_map();
            for (t, out) in batch.iter().zip(outs) {
                let Some(out) = out else { continue };
                cluster.work(m, 1);
                match pool.remove(&t.write_addr) {
                    Some(acc) => {
                        pool.insert(t.write_addr, app.combine(acc, out));
                    }
                    None => {
                        pool.insert(t.write_addr, out);
                    }
                }
            }
            for (addr, out) in pool {
                wb_out[m].push((store.owner(addr), (addr, out)));
            }
        }
        let wb_in = cluster.exchange(wb_out, |_| out_words + 1);

        // Superstep 4: owners merge + apply.
        for (m, inbox) in wb_in.into_iter().enumerate() {
            let mut merged: DetMap<Addr, A::Out> = det_map();
            for (addr, out) in inbox {
                cluster.work(m, 1);
                match merged.remove(&addr) {
                    Some(acc) => {
                        merged.insert(addr, app.combine(acc, out));
                    }
                    None => {
                        merged.insert(addr, out);
                    }
                }
            }
            let mut addrs: Vec<Addr> = merged.keys().copied().collect();
            addrs.sort_unstable();
            for addr in addrs {
                let out = merged.remove(&addr).unwrap();
                app.apply(store.get_or_default(addr), out);
            }
        }
        cluster.barrier();

        outcome.total_executed = outcome.executed_per_machine.iter().sum();
        outcome
    }
}
