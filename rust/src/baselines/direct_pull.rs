//! Direct-pull baseline (§2.3): fetch every requested chunk to the tasks.
//!
//! Each machine deduplicates the chunk addresses its local tasks request,
//! fetches each chunk once from its owner, executes locally, and sends
//! pre-combined write-backs to the owners.  Works well at low contention;
//! a hot chunk's owner must ship up to P·B words (and receive up to P
//! requests) — the `O(DPB/min{D,P})` worst case the paper derives.
//!
//! Written as [`Substrate`] supersteps, so it runs identically on the BSP
//! simulator and on the threaded backend.

use crate::bsp::MachineId;
use crate::det::{det_map, det_set, DetMap};
use crate::exec::{no_messages, nothing_words, Nothing, Substrate};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{owner_of, Addr, DistStore};

#[derive(Clone, Copy, Debug, Default)]
pub struct DirectPull;

impl<A, S> Scheduler<A, S> for DirectPull
where
    A: OrchApp + Sync,
    A::Ctx: Send + 'static,
    A::Val: Send + 'static,
    A::Out: Send + 'static,
    S: Substrate,
{
    fn name(&self) -> &'static str {
        "direct-pull"
    }

    fn run_stage(
        &self,
        sub: &mut S,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = sub.machines();
        let (submitted, mut st) = crate::orchestration::start_stage::<A>(p, tasks, store);
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();

        // Superstep 1: dedup the locally requested addresses + request.
        let req_in: Vec<Vec<(Addr, MachineId)>> = sub.superstep(
            &mut st,
            no_messages(p),
            |m, s, _in, acct| {
                acct.work(s.batch.len() as u64); // dedup sweep
                let mut seen = det_set();
                let mut out = Vec::new();
                for t in &s.batch {
                    if seen.insert(t.read_addr) {
                        out.push((owner_of(t.read_addr, p), (t.read_addr, m)));
                    }
                }
                out
            },
            |_msg: &(Addr, MachineId)| 2,
        );

        // Superstep 2: owners ship chunk copies to each requester.
        let val_in: Vec<Vec<(Addr, A::Val)>> = sub.superstep(
            &mut st,
            req_in,
            |_m, s, inbox, acct| {
                acct.work(inbox.len() as u64);
                inbox
                    .into_iter()
                    .map(|(addr, requester)| {
                        (requester, (addr, s.shard.get(&addr).cloned().unwrap_or_default()))
                    })
                    .collect()
            },
            |_msg: &(Addr, A::Val)| chunk_words + 1,
        );

        // Superstep 3: execute locally (one XLA-able batch per machine),
        // pre-combine write-backs per target address.
        let wb_in: Vec<Vec<(Addr, A::Out)>> = sub.superstep(
            &mut st,
            val_in,
            |_m, s, inbox, acct| {
                let mut vals: DetMap<Addr, A::Val> = det_map();
                for (addr, val) in inbox {
                    vals.insert(addr, val);
                }
                let batch = std::mem::take(&mut s.batch);
                let items: Vec<(&A::Ctx, &A::Val)> = batch
                    .iter()
                    .map(|t| (&t.ctx, vals.get(&t.read_addr).expect("missing pulled chunk")))
                    .collect();
                let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
                app.execute_batch(&items, &mut outs);
                debug_assert_eq!(outs.len(), items.len());
                let n = batch.len() as u64;
                acct.work(n * app.task_work());
                acct.executed(n);
                s.executed += n;

                let mut pool: DetMap<Addr, Option<A::Out>> = det_map();
                for (t, out) in batch.iter().zip(outs) {
                    let Some(out) = out else { continue };
                    acct.work(1);
                    crate::orchestration::combine_into(app, &mut pool, t.write_addr, out);
                }
                pool.into_iter()
                    .map(|(addr, out)| (owner_of(addr, p), (addr, out.expect("pool slot"))))
                    .collect()
            },
            |_msg: &(Addr, A::Out)| out_words + 1,
        );

        // Superstep 4: owners merge + apply.
        let _done: Vec<Vec<Nothing>> = sub.superstep(
            &mut st,
            wb_in,
            |_m, s, inbox, acct| {
                crate::orchestration::merge_and_apply(app, inbox, &mut s.shard, acct);
                Vec::new()
            },
            nothing_words,
        );

        crate::orchestration::finish_stage(
            store,
            st.into_iter().map(|s| (s.executed, s.shard)).collect(),
            submitted,
            "direct-pull",
        )
    }
}
