//! Sorting-based MPC baseline (§2.3 "Theory-Guided Designs").
//!
//! The Goodrich/Im-et-al. recipe the paper compares against (their
//! implementation uses the KaDiS sample-sort library): (1) sample-sort all
//! task contexts by requested chunk address, (2) broadcast each chunk to
//! the now-contiguous run of requesting tasks, (3) execute, (4) reverse
//! write-backs, (5) reverse-sort tasks to their origin machines.  Load
//! balance is optimal whp, but the tasks cross the network ≥ 3 times
//! (sort, reverse-sort, plus samples/values) — the constant factor that
//! makes it slower than TD-Orch in practice (paper: 1.42× geomean).
//!
//! Written as [`Substrate`] supersteps, so it runs identically on the BSP
//! simulator and on the threaded backend.

use std::collections::HashMap;

use crate::bsp::MachineId;
use crate::det::{det_map, DetMap};
use crate::exec::{no_messages, nothing_words, Nothing, Substrate};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{owner_of, Addr, DistStore};

/// Samples collected per machine for splitter selection.
const SAMPLES_PER_MACHINE: usize = 32;

#[derive(Clone, Copy, Debug, Default)]
pub struct SortingBased;

/// Machine-private stage state.
struct MState<A: OrchApp> {
    /// (uid, origin machine, task) — the uid tie-break is what keeps
    /// sample sort load-balanced under duplicate keys (all-equal
    /// addresses still spread over machines), as in KaDiS.
    batch: Vec<(u64, MachineId, Task<A::Ctx>)>,
    shard: HashMap<Addr, A::Val>,
    /// Tasks assigned to this machine by the sample-sort partition.
    sorted: Vec<(MachineId, Task<A::Ctx>)>,
    /// Pass-5 payload: tasks returning to their origin machines.
    returns: Vec<(MachineId, Task<A::Ctx>)>,
    executed: u64,
}

impl<A, S> Scheduler<A, S> for SortingBased
where
    A: OrchApp + Sync,
    A::Ctx: Send + 'static,
    A::Val: Send + 'static,
    A::Out: Send + 'static,
    S: Substrate,
{
    fn name(&self) -> &'static str {
        "sorting-mpc"
    }

    fn run_stage(
        &self,
        sub: &mut S,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let (p, submitted) =
            crate::orchestration::stage_contract(sub.machines(), &tasks, store);
        let sigma = app.sigma();
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();

        let shards = store.take_maps();
        let mut st: Vec<MState<A>> = tasks
            .into_iter()
            .enumerate()
            .zip(shards)
            .map(|((m, batch), shard)| MState {
                batch: batch
                    .into_iter()
                    .enumerate()
                    .map(|(i, t)| ((i * p + m) as u64, m, t))
                    .collect(),
                shard,
                sorted: Vec::new(),
                returns: Vec::new(),
                executed: 0,
            })
            .collect();

        // ---- Pass 1a: local sort + sample for splitters ----------------
        let samples_in: Vec<Vec<(Addr, u64)>> = sub.superstep(
            &mut st,
            no_messages(p),
            |_m, s, _in, acct| {
                s.batch.sort_by_key(|(uid, _, t)| (t.read_addr, *uid));
                // n/P log(n/P) local sort charged as a linear sweep x log.
                let n = s.batch.len() as u64;
                acct.work(n.max(1) * (64 - n.leading_zeros() as u64).max(1) / 8);
                let stride = (s.batch.len() / SAMPLES_PER_MACHINE).max(1);
                s.batch
                    .iter()
                    .step_by(stride)
                    .take(SAMPLES_PER_MACHINE)
                    .map(|(uid, _, t)| (0, (t.read_addr, *uid)))
                    .collect()
            },
            |_msg: &(Addr, u64)| 2,
        );

        // ---- Pass 1b: machine 0 picks splitters, broadcasts -------------
        let bcast_in: Vec<Vec<Vec<(Addr, u64)>>> = sub.superstep(
            &mut st,
            samples_in,
            |m, _s, inbox, acct| {
                if m != 0 {
                    debug_assert!(inbox.is_empty());
                    return Vec::new();
                }
                let mut samples: Vec<(Addr, u64)> = inbox;
                samples.sort_unstable();
                acct.work(samples.len() as u64);
                let splitters: Vec<(Addr, u64)> = if samples.is_empty() {
                    vec![(0, 0); p.saturating_sub(1)]
                } else {
                    (1..p).map(|i| samples[i * samples.len() / p]).collect()
                };
                (0..p).map(|to| (to, splitters.clone())).collect()
            },
            |msg: &Vec<(Addr, u64)>| 2 * msg.len() as u64,
        );

        // ---- Pass 2: all-to-all partition by splitter -------------------
        let part_in: Vec<Vec<(MachineId, Task<A::Ctx>)>> = sub.superstep(
            &mut st,
            bcast_in,
            |_m, s, mut inbox, _acct| {
                let splitters = inbox.pop().unwrap_or_default();
                let batch = std::mem::take(&mut s.batch);
                batch
                    .into_iter()
                    .map(|(uid, origin, t)| {
                        let dst = splitters.partition_point(|sp| *sp <= (t.read_addr, uid));
                        (dst, (origin, t))
                    })
                    .collect()
            },
            |_msg: &(MachineId, Task<A::Ctx>)| sigma + 2,
        );

        // ---- Pass 3: request values for the contiguous addr runs --------
        let req_in: Vec<Vec<(Addr, MachineId)>> = sub.superstep(
            &mut st,
            part_in,
            |m, s, inbox, acct| {
                s.sorted = inbox;
                s.sorted.sort_by_key(|(_, t)| t.read_addr);
                acct.work(s.sorted.len() as u64);
                let mut out = Vec::new();
                let mut last: Option<Addr> = None;
                for (_, t) in s.sorted.iter() {
                    if last != Some(t.read_addr) {
                        last = Some(t.read_addr);
                        out.push((owner_of(t.read_addr, p), (t.read_addr, m)));
                    }
                }
                out
            },
            |_msg: &(Addr, MachineId)| 2,
        );
        let val_in: Vec<Vec<(Addr, A::Val)>> = sub.superstep(
            &mut st,
            req_in,
            |_m, s, inbox, acct| {
                acct.work(inbox.len() as u64);
                inbox
                    .into_iter()
                    .map(|(addr, requester)| {
                        (requester, (addr, s.shard.get(&addr).cloned().unwrap_or_default()))
                    })
                    .collect()
            },
            |_msg: &(Addr, A::Val)| chunk_words + 1,
        );

        // ---- Pass 4: execute (balanced: ~n/P tasks each) ----------------
        let wb_in: Vec<Vec<(Addr, A::Out)>> = sub.superstep(
            &mut st,
            val_in,
            |_m, s, inbox, acct| {
                let mut vals: DetMap<Addr, A::Val> = det_map();
                for (addr, val) in inbox {
                    vals.insert(addr, val);
                }
                let batch = std::mem::take(&mut s.sorted);
                let items: Vec<(&A::Ctx, &A::Val)> = batch
                    .iter()
                    .map(|(_, t)| (&t.ctx, vals.get(&t.read_addr).expect("missing value")))
                    .collect();
                let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
                app.execute_batch(&items, &mut outs);
                debug_assert_eq!(outs.len(), items.len());
                let n = batch.len() as u64;
                acct.work(n * app.task_work());
                acct.executed(n);
                s.executed += n;

                let mut pool: DetMap<Addr, Option<A::Out>> = det_map();
                for ((origin, t), out) in batch.into_iter().zip(outs) {
                    if let Some(out) = out {
                        acct.work(1);
                        crate::orchestration::combine_into(app, &mut pool, t.write_addr, out);
                    }
                    // Pass 5 payload: tasks return to their origin
                    // machines (the reverse sort restoring input order).
                    s.returns.push((origin, t));
                }
                pool.into_iter()
                    .map(|(addr, out)| (owner_of(addr, p), (addr, out.expect("pool slot"))))
                    .collect()
            },
            |_msg: &(Addr, A::Out)| out_words + 1,
        );

        // Merge + apply write-backs; launch the reverse sort.
        let ret_in: Vec<Vec<Task<A::Ctx>>> = sub.superstep(
            &mut st,
            wb_in,
            |_m, s, inbox, acct| {
                crate::orchestration::merge_and_apply(app, inbox, &mut s.shard, acct);
                std::mem::take(&mut s.returns)
            },
            |_msg: &Task<A::Ctx>| sigma + 1,
        );

        // ---- Pass 5: reverse sort (tasks travel home) --------------------
        let _done: Vec<Vec<Nothing>> = sub.superstep(
            &mut st,
            ret_in,
            |_m, _s, _inbox, _acct| Vec::new(),
            nothing_words,
        );

        crate::orchestration::finish_stage(
            store,
            st.into_iter().map(|s| (s.executed, s.shard)).collect(),
            submitted,
            "sorting-mpc",
        )
    }
}
