//! Sorting-based MPC baseline (§2.3 "Theory-Guided Designs").
//!
//! The Goodrich/Im-et-al. recipe the paper compares against (their
//! implementation uses the KaDiS sample-sort library): (1) sample-sort all
//! task contexts by requested chunk address, (2) broadcast each chunk to
//! the now-contiguous run of requesting tasks, (3) execute, (4) reverse
//! write-backs, (5) reverse-sort tasks to their origin machines.  Load
//! balance is optimal whp, but the tasks cross the network ≥ 3 times
//! (sort, reverse-sort, plus samples/values) — the constant factor that
//! makes it slower than TD-Orch in practice (paper: 1.42× geomean).

use crate::bsp::{Cluster, MachineId};
use crate::det::{det_map, DetMap};
use crate::orchestration::{OrchApp, Scheduler, StageOutcome, Task};
use crate::store::{Addr, DistStore};

/// Samples collected per machine for splitter selection.
const SAMPLES_PER_MACHINE: usize = 32;

#[derive(Clone, Copy, Debug, Default)]
pub struct SortingBased;

impl<A: OrchApp> Scheduler<A> for SortingBased {
    fn name(&self) -> &'static str {
        "sorting-mpc"
    }

    fn run_stage(
        &self,
        cluster: &mut Cluster,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = cluster.p;
        let sigma = app.sigma();
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();
        let mut outcome = StageOutcome {
            executed_per_machine: vec![0; p],
            total_executed: 0,
        };

        // ---- Pass 1a: local sort + sample for splitters ----------------
        // Sort/partition key is (addr, uid): the uid tie-break is what
        // keeps sample sort load-balanced under duplicate keys (all-equal
        // addresses still spread over machines) — as in KaDiS.
        let mut tasks: Vec<Vec<(u64, MachineId, Task<A::Ctx>)>> = tasks
            .into_iter()
            .enumerate()
            .map(|(m, batch)| {
                batch
                    .into_iter()
                    .enumerate()
                    .map(|(i, t)| ((i * p + m) as u64, m, t))
                    .collect()
            })
            .collect();
        let mut sample_out: Vec<Vec<(MachineId, (Addr, u64))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in tasks.iter_mut().enumerate() {
            batch.sort_by_key(|(uid, _, t)| (t.read_addr, *uid));
            // n/P log(n/P) local sort charged as a linear sweep x log factor
            let n = batch.len() as u64;
            cluster.work(m, n.max(1) * (64 - n.leading_zeros() as u64).max(1) / 8);
            let stride = (batch.len() / SAMPLES_PER_MACHINE).max(1);
            for (uid, _, t) in batch.iter().step_by(stride).take(SAMPLES_PER_MACHINE) {
                sample_out[m].push((0, (t.read_addr, *uid)));
            }
        }
        let samples_in = cluster.exchange(sample_out, |_| 2);

        // ---- Pass 1b: machine 0 picks splitters, broadcasts -------------
        let mut samples: Vec<(Addr, u64)> = samples_in.into_iter().flatten().collect();
        samples.sort_unstable();
        cluster.work(0, samples.len() as u64);
        let splitters: Vec<(Addr, u64)> = if samples.is_empty() {
            vec![(0, 0); p.saturating_sub(1)]
        } else {
            (1..p).map(|i| samples[i * samples.len() / p]).collect()
        };
        let mut bcast_out: Vec<Vec<(MachineId, Vec<(Addr, u64)>)>> =
            (0..p).map(|_| Vec::new()).collect();
        for m in 0..p {
            bcast_out[0].push((m, splitters.clone()));
        }
        let bcast_in = cluster.exchange(bcast_out, |s| 2 * s.len() as u64);
        let splitters = bcast_in
            .into_iter()
            .map(|mut v| v.pop().unwrap_or_default())
            .collect::<Vec<_>>();

        // ---- Pass 2: all-to-all partition by splitter -------------------
        let mut part_out: Vec<Vec<(MachineId, (MachineId, Task<A::Ctx>))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in tasks.into_iter().enumerate() {
            for (uid, origin, t) in batch {
                let dst = splitters[m].partition_point(|s| *s <= (t.read_addr, uid));
                part_out[m].push((dst, (origin, t)));
            }
        }
        let part_in = cluster.exchange(part_out, |_| sigma + 2);

        // ---- Pass 3: request values for the contiguous addr runs --------
        let mut sorted: Vec<Vec<(MachineId, Task<A::Ctx>)>> = part_in;
        let mut req_out: Vec<Vec<(MachineId, (Addr, MachineId))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, batch) in sorted.iter_mut().enumerate() {
            batch.sort_by_key(|(_, t)| t.read_addr);
            cluster.work(m, batch.len() as u64);
            let mut last: Option<Addr> = None;
            for (_, t) in batch.iter() {
                if last != Some(t.read_addr) {
                    last = Some(t.read_addr);
                    req_out[m].push((store.owner(t.read_addr), (t.read_addr, m)));
                }
            }
        }
        let req_in = cluster.exchange(req_out, |_| 2);
        let mut val_out: Vec<Vec<(MachineId, (Addr, A::Val))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, inbox) in req_in.into_iter().enumerate() {
            cluster.work(m, inbox.len() as u64);
            for (addr, requester) in inbox {
                val_out[m].push((requester, (addr, store.read_copy(addr))));
            }
        }
        let val_in = cluster.exchange(val_out, |_| chunk_words + 1);

        // ---- Pass 4: execute (balanced: ~n/P tasks each) ----------------
        let mut wb_out: Vec<Vec<(MachineId, (Addr, A::Out))>> =
            (0..p).map(|_| Vec::new()).collect();
        let mut return_out: Vec<Vec<(MachineId, Task<A::Ctx>)>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, (inbox, batch)) in val_in.into_iter().zip(sorted.into_iter()).enumerate() {
            let mut vals: DetMap<Addr, A::Val> = det_map();
            for (addr, val) in inbox {
                vals.insert(addr, val);
            }
            let items: Vec<(&A::Ctx, &A::Val)> = batch
                .iter()
                .map(|(_, t)| (&t.ctx, vals.get(&t.read_addr).expect("missing value")))
                .collect();
            let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
            app.execute_batch(&items, &mut outs);
            let n = batch.len() as u64;
            cluster.work(m, n * app.task_work());
            cluster.executed(m, n);
            outcome.executed_per_machine[m] += n;

            let mut pool: DetMap<Addr, A::Out> = det_map();
            for ((origin, t), out) in batch.into_iter().zip(outs) {
                if let Some(out) = out {
                    cluster.work(m, 1);
                    match pool.remove(&t.write_addr) {
                        Some(acc) => {
                            pool.insert(t.write_addr, app.combine(acc, out));
                        }
                        None => {
                            pool.insert(t.write_addr, out);
                        }
                    }
                }
                // Pass 5 payload: tasks return to their origin machines
                // (the reverse sort restoring input order).
                return_out[m].push((origin, t));
            }
            for (addr, out) in pool {
                wb_out[m].push((store.owner(addr), (addr, out)));
            }
        }
        let wb_in = cluster.exchange(wb_out, |_| out_words + 1);
        for (m, inbox) in wb_in.into_iter().enumerate() {
            let mut merged: DetMap<Addr, A::Out> = det_map();
            for (addr, out) in inbox {
                cluster.work(m, 1);
                match merged.remove(&addr) {
                    Some(acc) => {
                        merged.insert(addr, app.combine(acc, out));
                    }
                    None => {
                        merged.insert(addr, out);
                    }
                }
            }
            let mut addrs: Vec<Addr> = merged.keys().copied().collect();
            addrs.sort_unstable();
            for addr in addrs {
                let out = merged.remove(&addr).unwrap();
                app.apply(store.get_or_default(addr), out);
            }
        }
        cluster.barrier();

        // ---- Pass 5: reverse sort (tasks travel home) --------------------
        let _ = cluster.exchange(return_out, |_| sigma + 1);

        outcome.total_executed = outcome.executed_per_machine.iter().sum();
        outcome
    }
}
