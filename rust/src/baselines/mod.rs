//! The paper's §2.3 baseline scheduling strategies, implemented on the
//! same BSP substrate and behind the same [`Scheduler`] interface as
//! TD-Orch so Fig 5's four-way comparison is apples-to-apples.

pub mod direct_pull;
pub mod direct_push;
pub mod sorting;

pub use direct_pull::DirectPull;
pub use direct_push::DirectPush;
pub use sorting::SortingBased;
