//! `repro serve` — the online serving layer end to end.
//!
//! Admits a deterministic open-loop stream of {BFS, SSSP, PR, CC, BC}
//! queries with Zipf-skewed traversal sources, batches it, and serves it
//! on ONE long-lived `SpmdEngine` (sim or threaded backend).  Every
//! served query is cross-checked **bit-for-bit** against a single-shot
//! run on a sim-backend reference engine, and the whole process is held
//! to the serving contract: the graph is ingested exactly once
//! (`graph::ingest::ingestions()` is the witness), however many queries
//! run.  The cross-check walks the stream in *reverse* order, so state
//! leaking across queries on either engine meets a different predecessor
//! and breaks the comparison instead of cancelling out.
//!
//! Reported: per-kind and overall queue-wait percentiles (logical
//! ticks), service-cost percentiles (deterministic ticks and measured
//! ms), offered vs goodput throughput with the rejection rate broken out
//! (shed queries vanish from goodput, never from offered), batch count,
//! and — on the threaded backend — worker-pool epoch accounting per
//! query.  For the full latency-vs-offered-load sweeps see
//! `repro loadcurve` ([`super::loadcurve`]).

use crate::exec::{PoolSnapshot, ThreadedCluster};
use crate::graph::flags::Flags;
use crate::graph::gen;
use crate::graph::ingest::ingestions;
use crate::graph::spmd::{ingest_once, Placement, SpmdEngine};
use crate::metrics::p50_p95_p99;
use crate::place::PlacementPolicy;
use crate::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use crate::workload::{
    generate_stream, hot_source_order, OpenLoopSource, QueryKind, QueryMix, StreamConfig,
};
use crate::{Cluster, CostModel};

use super::TablePrinter;

/// Graph size for the serving runs: big enough that hub skew shapes the
/// load, small enough for the CI smoke leg.
const SERVE_N: usize = 8_000;
const SERVE_K: usize = 6;
/// Open-loop arrival rate (queries per logical tick).
const ARRIVALS_PER_TICK: usize = 2;

/// Result of one `repro serve` invocation (consumed by main/tests).
pub struct ServeSummary {
    pub served: usize,
    pub rejected: u64,
    pub mismatches: usize,
    /// Ingestion passes this run performed (must be exactly 1).
    pub ingestions: u64,
    /// Queries served from the result cache (0 with `--cache` off).
    pub cache_hits: u64,
    /// Queries served by engine execution.
    pub cache_misses: u64,
    /// Engine passes with >= 2 lanes (0 with `--fuse` off).
    pub fused_waves: usize,
    pub all_valid: bool,
}

#[allow(clippy::too_many_arguments)]
pub fn run_serve(
    p: usize,
    queries: usize,
    zipf_s: f64,
    batch: usize,
    seed: u64,
    backend: &str,
    fuse: bool,
    cache: bool,
    adapt: bool,
) -> ServeSummary {
    assert!(p >= 1, "need at least one machine");
    assert!(queries >= 1, "need at least one query");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(SERVE_N, SERVE_K, seed);
    println!(
        "\n## repro serve — online {{BFS,SSSP,PR,CC,BC}} Zipf stream on the reused engine: \
         BA graph n={} m={}, P={p}, {queries} queries, zipf {zipf_s}, batch {batch}, \
         seed {seed}, backend {backend}, fuse {fuse}, cache {cache}, adapt {adapt}\n",
        g.n,
        g.m()
    );

    // ONE ingestion for the whole process; both engines (serving +
    // cross-check reference) are built from clones of this placement.
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let cfg = ServeConfig { batch, ..ServeConfig::default() };
    let mut policy = ServePolicy::new().with_fuse(fuse).with_cache(cache);
    if adapt {
        policy = policy.with_placement(PlacementPolicy::default());
    }
    // The reference stays fusion- and cache-free: it re-executes every
    // query single-shot, so a served result is always compared against a
    // fresh computation, never against a stored copy of itself.
    let ref_cfg = ServeConfig { batch, ..ServeConfig::default() };
    let mut reference = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "serve-sim-ref",
            QueryShard::new,
        ),
        ref_cfg,
    );
    let hot = hot_source_order(&reference.engine().meta().out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries,
            per_tick: ARRIVALS_PER_TICK,
            every_ticks: 1,
            zipf_s,
            mix: QueryMix::balanced(),
        },
        &hot,
        seed,
    );

    let (report, pool_note): (ServeReport, Option<String>) = if backend == "threaded" {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg,
                cost,
                Flags::tdo_gp(),
                "serve-threaded",
                QueryShard::new,
            ),
            cfg,
        )
        .with_serving_policy(policy);
        let mut snaps: Vec<PoolSnapshot> = Vec::new();
        let report = server.serve(
            &mut OpenLoopSource::new(&stream),
            RunOpts::new().observe(|_r, e| snaps.push(e.sub().snapshot())),
        );
        let engine = server.into_engine();
        let tc = engine.sub();
        let total = tc.snapshot();
        // Per-query epoch accounting: each observer snapshot closes one
        // query's window, so consecutive diffs are that query's epochs
        // (reset epoch included) and busy nanoseconds.
        let mut prev = PoolSnapshot::default();
        let mut max_epochs = 0u64;
        let mut max_busy_ms = 0.0f64;
        for s in &snaps {
            let d = s.since(prev);
            max_epochs = max_epochs.max(d.epochs);
            max_busy_ms = max_busy_ms.max(d.busy_ns as f64 / 1e6);
            prev = *s;
        }
        let mean_epochs = if snaps.is_empty() {
            0.0
        } else {
            total.epochs as f64 / snaps.len() as f64
        };
        let note = format!(
            "worker pool: {} threads spawned once for the whole stream; {} epochs total — \
             per query (incl. its reset epoch): mean {mean_epochs:.1} / max {max_epochs} \
             epochs, max {max_busy_ms:.2} ms busy; {:.1} ms busy summed over machines",
            tc.pool_threads(),
            total.epochs,
            total.busy_ns as f64 / 1e6,
        );
        (report, Some(note))
    } else {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost),
                dg,
                cost,
                Flags::tdo_gp(),
                "serve-sim",
                QueryShard::new,
            ),
            cfg,
        )
        .with_serving_policy(policy);
        (server.serve(&mut OpenLoopSource::new(&stream), RunOpts::default()), None)
    };

    // Cross-check EVERY served query against the single-shot sim
    // reference, in reverse stream order (see module docs).
    let mut mismatches = 0usize;
    for r in report.results.iter().rev() {
        let q = stream[r.id as usize];
        debug_assert_eq!(q.id, r.id, "stream ids must be positional");
        // With `--adapt`, epochs past 0 hold a migrated placement the
        // epoch-0 reference engine doesn't; only the
        // placement-independent exact kinds can still be compared
        // against it (PR/BC reductions are placement-shaped — `repro
        // placement` cross-checks those against per-epoch references).
        if r.graph_epoch > 0
            && !matches!(r.kind, QueryKind::Bfs | QueryKind::Sssp | QueryKind::Cc)
        {
            continue;
        }
        if reference.run_query(&q) != r.bits {
            mismatches += 1;
            eprintln!(
                "MISMATCH: query {} ({}) diverged from the sim single-shot reference",
                r.id,
                r.kind.label()
            );
        }
    }

    let t = TablePrinter::new(
        &["kind", "served", "wait p50/p95/p99 (ticks)", "service p50/p95/p99 (ms)"],
        &[5, 7, 25, 26],
    );
    for kind in QueryKind::ALL {
        let waits: Vec<f64> = report
            .results
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.wait_ticks as f64)
            .collect();
        let svc: Vec<f64> = report
            .results
            .iter()
            .filter(|r| r.kind == kind)
            .map(|r| r.service_ms)
            .collect();
        if waits.is_empty() {
            // A short or heavily skewed run can draw zero queries of a
            // kind; a dash beats a NaN/NaN/NaN row.
            t.row(&[kind.label().to_string(), "0".to_string(), "-".to_string(), "-".to_string()]);
            continue;
        }
        let (w50, w95, w99) = p50_p95_p99(&waits);
        let (s50, s95, s99) = p50_p95_p99(&svc);
        t.row(&[
            kind.label().to_string(),
            waits.len().to_string(),
            format!("{w50:.0} / {w95:.0} / {w99:.0}"),
            format!("{s50:.2} / {s95:.2} / {s99:.2}"),
        ]);
    }

    let (w50, _, w99) = report.wait_tick_percentiles();
    let (st50, _, st99) = report.service_tick_percentiles();
    let (s50, _, s99) = report.service_ms_percentiles();
    println!(
        "\noverall: {} offered = {} served + {} rejected (rejection rate {:.3}), \
         {} batches over {} logical ticks; wait p50 {w50:.0} / p99 {w99:.0} ticks; \
         service p50 {st50:.0} / p99 {st99:.0} ticks = p50 {s50:.2} / p99 {s99:.2} ms; \
         goodput {:.4} queries/tick ({:.1}/sec measured, {:.1}/sec offered)",
        report.offered(),
        report.served(),
        report.rejected,
        report.rejection_rate(),
        report.batches,
        report.ticks,
        report.goodput_per_tick(),
        report.goodput_qps(),
        report.offered_qps(),
    );
    if let Some(note) = pool_note {
        println!("{note}");
    }
    let fused_waves = report.waves.iter().filter(|w| w.lanes >= 2).count();
    let max_lanes = report.waves.iter().map(|w| w.lanes).max().unwrap_or(0);
    println!(
        "dispatch: {} engine passes for {} served queries — {} fused waves (max {} lanes), \
         {} cache hits / {} misses",
        report.waves.len(),
        report.served(),
        fused_waves,
        max_lanes,
        report.cache_hits,
        report.cache_misses,
    );
    let ingested = ingestions() - ing0;
    println!(
        "ingestions this run: {ingested} (one shared placement; engines cloned from it, \
         queries separated by reset_for_query)"
    );

    let all_valid = mismatches == 0
        && ingested == 1
        && report.served() as u64 + report.rejected == queries as u64
        && report.served() as u64 == report.cache_hits + report.cache_misses;
    println!(
        "\nserve {}",
        if all_valid {
            "OK (every query bit-identical to the single-shot sim reference; graph ingested once)"
        } else {
            "FAILED"
        }
    );
    ServeSummary {
        served: report.served(),
        rejected: report.rejected,
        mismatches,
        ingestions: ingested,
        cache_hits: report.cache_hits,
        cache_misses: report.cache_misses,
        fused_waves,
        all_valid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_serve_sim_smoke_is_valid() {
        let s = run_serve(2, 6, 1.5, 4, 7, "sim", false, false, false);
        assert_eq!(s.mismatches, 0);
        assert_eq!(s.ingestions, 1);
        assert!(s.all_valid);
        assert_eq!(s.served as u64 + s.rejected, 6);
        assert_eq!(s.cache_hits, 0, "cache off: every served query is a miss");
        assert_eq!(s.fused_waves, 0, "fusion off: every wave is a single query");
    }

    #[test]
    fn run_serve_sim_fused_cached_smoke_is_valid() {
        // Same stream served through fusion + memoization must still
        // cross-check bit-for-bit against the single-shot reference.
        let s = run_serve(2, 12, 1.5, 4, 7, "sim", true, true, false);
        assert_eq!(s.mismatches, 0);
        assert_eq!(s.ingestions, 1);
        assert!(s.all_valid);
        assert_eq!(s.served as u64, s.cache_hits + s.cache_misses);
    }

    #[test]
    fn run_serve_sim_adaptive_smoke_is_valid() {
        // `--adapt` wires a policy-owned placement controller into the
        // same serving loop; with a short stream on a balanced static
        // ingest the controller may never trigger, but the run must stay
        // valid and still ingest exactly once.
        let s = run_serve(2, 12, 1.5, 4, 7, "sim", false, false, true);
        assert_eq!(s.mismatches, 0);
        assert_eq!(s.ingestions, 1, "placement must never re-ingest");
        assert!(s.all_valid);
    }
}
