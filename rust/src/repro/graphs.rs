//! Graph-system reproductions: Table 2 (end-to-end), Fig 8 (strong
//! scaling), Fig 9 (weak scaling), Fig 10 (breakdown), Table 3 (TD-Orch
//! ablation), Table 4 (technique ablation), Tables 5/6 (NUMA ablations),
//! plus `repro graph` (threaded-vs-sim bit-equality on the worker pool)
//! and `repro graphs [--quick]` (the whole figure sweep, with a CI-sized
//! asserting mode).
//!
//! Every figure path runs THE unified engine — `SpmdEngine<Cluster>`
//! with the family's [`Flags`] — the exact code the threaded runtime and
//! the serving layer execute, so the simulated-cost comparisons are
//! structural: one engine, one substrate API, one metrics ledger
//! (`tests/unified_engine_costs.rs` pins the headline orderings).

use crate::exec::ThreadedCluster;
use crate::graph::algorithms::{bc, bfs, cc, pagerank, sssp, Algorithm};
use crate::graph::flags::Flags;
use crate::graph::gen::{self, Dataset};
use crate::graph::ingest::ingestions;
use crate::graph::spmd::{ingest_once, Placement, SpmdEngine};
use crate::graph::Graph;
use crate::metrics::Breakdown;
use crate::serve::QueryShard;
use crate::{Cluster, CostModel};

use super::{fmt_s, geomean, TablePrinter};

pub const PR_ITERS: usize = 10;

/// The engine type every figure path drives: the unified SPMD engine on
/// the simulator substrate, holding all five algorithm shards.
pub type FigEngine = SpmdEngine<Cluster, QueryShard>;

/// Run one algorithm on a figure engine; returns (sim-seconds,
/// breakdown), excluding ingestion and the shard reset (the paper times
/// queries, not loading).
pub fn run_alg(engine: &mut FigEngine, alg: Algorithm) -> (f64, Breakdown) {
    engine.reset_for_query(|m, meta, st: &mut QueryShard| st.reset(m, meta));
    engine.sub_mut().reset_metrics();
    match alg {
        Algorithm::Bfs => {
            bfs(engine, 0);
        }
        Algorithm::Sssp => {
            sssp(engine, 0);
        }
        Algorithm::Bc => {
            bc(engine, 0);
        }
        Algorithm::Cc => {
            cc(engine);
        }
        Algorithm::Pr => {
            pagerank(engine, PR_ITERS);
        }
    }
    let m = &engine.sub().metrics;
    (m.sim_seconds(), m.time)
}

/// The §6 engine matrix: TDO-GP (spread placement) and the three
/// baseline families (owner placement), all instances of the one SPMD
/// engine.  The two placement passes run once and are cloned into the
/// four engines.
pub fn engines_for(g: &Graph, p: usize, cost: CostModel) -> Vec<FigEngine> {
    let spread = ingest_once(g, p, cost, Placement::Spread);
    let owner = ingest_once(g, p, cost, Placement::AtOwner);
    vec![
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            spread,
            cost,
            Flags::tdo_gp(),
            "tdo-gp",
            QueryShard::new,
        ),
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            owner.clone(),
            cost,
            Flags::gemini_like(),
            "gemini-like",
            QueryShard::new,
        ),
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            owner.clone(),
            cost,
            Flags::la_like(),
            "la-like",
            QueryShard::new,
        ),
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            owner,
            cost,
            Flags::ligra_dist(),
            "ligra-dist",
            QueryShard::new,
        ),
    ]
}

/// Table 2: end-to-end runtimes across datasets x algorithms x engines.
/// Returns (dataset, alg, engine-label, sim-seconds) tuples.
pub fn table2(seed: u64) -> Vec<(String, String, String, f64)> {
    println!("\n## Table 2 — end-to-end runtime (sim-seconds)\n");
    let mut rows = Vec::new();
    for ds in Dataset::ALL {
        let g = ds.build(seed);
        let p = ds.machines();
        println!(
            "### {} (n={}, m={}, P={p})",
            ds.label(),
            g.n,
            g.m()
        );
        let t = TablePrinter::new(
            &["Alg", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
            &[5, 9, 11, 9, 10],
        );
        let mut engines = engines_for(&g, p, CostModel::paper_cluster());
        for alg in Algorithm::ALL {
            let mut cells = vec![alg.label().to_string()];
            for e in engines.iter_mut() {
                let (s, _) = run_alg(e, alg);
                cells.push(fmt_s(s));
                rows.push((
                    ds.label().to_string(),
                    alg.label().to_string(),
                    e.label().to_string(),
                    s,
                ));
            }
            t.row(&cells);
        }
        println!();
    }
    table2_summary(&rows);
    rows
}

/// §5 headline: geomean speedup of TDO-GP over the best prior system per
/// (dataset, algorithm) cell.  "Prior systems" are the gemini-like and
/// la-like families (the paper's Table 2 columns); ligra-dist is the
/// paper's own no-TD-Orch prototype (Table 3) and is excluded.
pub fn table2_summary(rows: &[(String, String, String, f64)]) {
    use std::collections::HashMap;
    let mut cells: HashMap<(String, String), (f64, f64)> = HashMap::new();
    for (ds, alg, eng, s) in rows {
        if eng == "ligra-dist" {
            continue;
        }
        let e = cells
            .entry((ds.clone(), alg.clone()))
            .or_insert((f64::NAN, f64::INFINITY));
        if eng == "tdo-gp" {
            e.0 = *s;
        } else if *s < e.1 {
            e.1 = *s; // best prior system
        }
    }
    let mut speedups = Vec::new();
    let mut wins = 0;
    let total = cells.len();
    for (_, (tdo, best_prior)) in cells {
        speedups.push(best_prior / tdo);
        if tdo <= best_prior {
            wins += 1;
        }
    }
    println!(
        "TDO-GP wins {wins}/{total} cells; geomean speedup vs best prior: {:.2}x  (paper: 28/30 wins, 4.1x geomean)",
        geomean(&speedups)
    );
}

/// Fig 8: strong scaling of SSSP and BC on the twitter-like graph.
pub fn fig8(seed: u64) -> Vec<(String, usize, String, f64)> {
    println!("\n## Fig 8 — strong scaling on twitter-like (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let mut rows = Vec::new();
    for alg in [Algorithm::Sssp, Algorithm::Bc] {
        println!("### {}", alg.label());
        let t = TablePrinter::new(
            &["P", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
            &[4, 9, 11, 9, 10],
        );
        for p in [1usize, 2, 4, 8, 16] {
            let mut cells = vec![p.to_string()];
            for e in engines_for(&g, p, CostModel::paper_cluster()).iter_mut() {
                let (s, _) = run_alg(e, alg);
                cells.push(fmt_s(s));
                rows.push((alg.label().to_string(), p, e.label().to_string(), s));
            }
            t.row(&cells);
        }
        println!();
    }
    rows
}

/// Fig 9: weak scaling on ER (unskewed) and BA (skewed, γ≈2.2) with a
/// fixed number of edges per machine.
pub fn fig9(edges_per_machine: usize, seed: u64) -> Vec<(String, usize, String, f64)> {
    println!(
        "\n## Fig 9 — weak scaling ({edges_per_machine} edges/machine, sim-seconds)\n"
    );
    let mut rows = Vec::new();
    for (gname, make) in [
        (
            "ER",
            Box::new(|p: usize, seed: u64| {
                let m = edges_per_machine * p / 2; // symmetrized to ~target
                gen::erdos_renyi(m / 8, m, seed)
            }) as Box<dyn Fn(usize, u64) -> Graph>,
        ),
        (
            "BA",
            Box::new(|p: usize, seed: u64| {
                let m = edges_per_machine * p / 2;
                let k = 8;
                gen::barabasi_albert(m / k, k, seed)
            }),
        ),
    ] {
        for alg in [Algorithm::Pr, Algorithm::Bc] {
            println!("### {gname} / {}", alg.label());
            let t = TablePrinter::new(
                &["P", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
                &[4, 9, 11, 9, 10],
            );
            for p in [1usize, 2, 4, 8, 16] {
                let g = make(p, seed);
                let mut cells = vec![p.to_string()];
                for e in engines_for(&g, p, CostModel::paper_cluster()).iter_mut() {
                    let (s, _) = run_alg(e, alg);
                    cells.push(fmt_s(s));
                    rows.push((
                        format!("{gname}/{}", alg.label()),
                        p,
                        e.label().to_string(),
                        s,
                    ));
                }
                t.row(&cells);
            }
            println!();
        }
    }
    rows
}

/// Fig 10: execution-time breakdown of TDO-GP on twitter-like, P = 16.
pub fn fig10(seed: u64) -> Vec<(String, Breakdown)> {
    println!("\n## Fig 10 — breakdown on twitter-like, P=16 (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let t = TablePrinter::new(
        &["Alg", "Communication", "Computation", "Overhead", "Total"],
        &[5, 13, 11, 9, 8],
    );
    let mut rows = Vec::new();
    let cost = CostModel::paper_cluster();
    let mut engine = SpmdEngine::tdo_gp(Cluster::new(16, cost), &g, cost, QueryShard::new);
    for alg in Algorithm::ALL {
        let (_, b) = run_alg(&mut engine, alg);
        t.row(&[
            alg.label().to_string(),
            fmt_s(b.communication),
            fmt_s(b.computation),
            fmt_s(b.overhead),
            fmt_s(b.total()),
        ]);
        rows.push((alg.label().to_string(), b));
    }
    println!();
    rows
}

/// Table 3: BC on twitter-like — Ligra-Dist (no TD-Orch) vs TDO-GP.
pub fn table3(seed: u64) -> Vec<(usize, f64, f64)> {
    println!("\n## Table 3 — BC on twitter-like: TD-Orch ablation (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let t = TablePrinter::new(
        &["P", "ligra-dist (no TD-Orch)", "TDO-GP"],
        &[4, 23, 9],
    );
    let mut rows = Vec::new();
    for p in [1usize, 4, 8, 16] {
        let cost = CostModel::paper_cluster();
        let (lig, _) = run_alg(
            &mut SpmdEngine::baseline(
                Cluster::new(p, cost),
                &g,
                cost,
                Flags::ligra_dist(),
                "ligra-dist",
                QueryShard::new,
            ),
            Algorithm::Bc,
        );
        let (tdo, _) = run_alg(
            &mut SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new),
            Algorithm::Bc,
        );
        t.row(&[p.to_string(), fmt_s(lig), fmt_s(tdo)]);
        rows.push((p, lig, tdo));
    }
    println!();
    rows
}

/// Table 4: slowdown from removing each technique family (T1/T2/T3).
pub fn table4(seed: u64) -> Vec<(String, String, usize, f64)> {
    println!("\n## Table 4 — technique ablation on twitter-like (slowdown vs full)\n");
    let g = Dataset::TwitterLike.build(seed);
    let algs = [Algorithm::Sssp, Algorithm::Bc, Algorithm::Cc];
    let mut rows = Vec::new();
    let cost = CostModel::paper_cluster();
    let descs = ["global comm", "local comp", "coordination"];
    for ((short, flags), desc) in Flags::ablations().into_iter().zip(descs) {
        let label = &format!("{short} ({desc})");
        println!("### {label}");
        let t = TablePrinter::new(&["Alg", "P=4", "P=8", "P=16"], &[5, 7, 7, 7]);
        for alg in algs {
            let mut cells = vec![alg.label().to_string()];
            for p in [4usize, 8, 16] {
                // One spread placement per (p); the full and ablated
                // engines are the same ingestion under different flags.
                let dg = ingest_once(&g, p, cost, Placement::Spread);
                let (full, _) = run_alg(
                    &mut SpmdEngine::from_ingested(
                        Cluster::new(p, cost),
                        dg.clone(),
                        cost,
                        Flags::tdo_gp(),
                        "tdo-gp",
                        QueryShard::new,
                    ),
                    alg,
                );
                let (ablated, _) = run_alg(
                    &mut SpmdEngine::from_ingested(
                        Cluster::new(p, cost),
                        dg,
                        cost,
                        flags,
                        label,
                        QueryShard::new,
                    ),
                    alg,
                );
                let slowdown = ablated / full;
                cells.push(format!("{slowdown:.2}x"));
                rows.push((label.to_string(), alg.label().to_string(), p, slowdown));
            }
            t.row(&cells);
        }
        println!();
    }
    rows
}

/// Table 5: PR on twitter-like with one NUMA node per machine.
pub fn table5(seed: u64) -> Vec<(String, usize, f64)> {
    println!("\n## Table 5 — PR on twitter-like, 1 NUMA node/machine (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let cost = CostModel::single_numa();
    let t = TablePrinter::new(
        &["Engine", "P=1", "P=4", "P=8", "P=16"],
        &[12, 8, 8, 8, 8],
    );
    let mut rows = Vec::new();
    for (label, flags, tdo) in [
        ("gemini-like", Flags::gemini_like(), false),
        ("la-like", Flags::la_like(), false),
        ("TDO-GP", Flags::tdo_gp(), true),
    ] {
        let mut cells = vec![label.to_string()];
        for p in [1usize, 4, 8, 16] {
            let mut e = if tdo {
                SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new)
            } else {
                SpmdEngine::baseline(Cluster::new(p, cost), &g, cost, flags, label, QueryShard::new)
            };
            let (s, _) = run_alg(&mut e, Algorithm::Pr);
            cells.push(fmt_s(s));
            rows.push((label.to_string(), p, s));
        }
        t.row(&cells);
    }
    println!();
    rows
}

/// Table 6: single big all-to-all NUMA server (P = 1), BFS/BC/PR,
/// including a GBBS-like single-machine engine (ligra flags at P=1 ==
/// work-efficient local edgemap without distribution overheads).
pub fn table6(seed: u64) -> Vec<(String, String, f64)> {
    println!("\n## Table 6 — twitter-like on the big NUMA server (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let cost = CostModel::big_numa_server();
    let t = TablePrinter::new(&["Engine", "BFS", "BC", "PR"], &[12, 8, 8, 8]);
    let mut rows = Vec::new();
    for (label, flags, tdo) in [
        ("gemini-like", Flags::gemini_like(), false),
        ("la-like", Flags::la_like(), false),
        ("gbbs-like", Flags::ligra_dist(), false),
        ("TDO-GP", Flags::tdo_gp(), true),
    ] {
        let mut cells = vec![label.to_string()];
        for alg in [Algorithm::Bfs, Algorithm::Bc, Algorithm::Pr] {
            let mut e = if tdo {
                SpmdEngine::tdo_gp(Cluster::new(1, cost), &g, cost, QueryShard::new)
            } else {
                SpmdEngine::baseline(Cluster::new(1, cost), &g, cost, flags, label, QueryShard::new)
            };
            let (s, _) = run_alg(&mut e, alg);
            cells.push(fmt_s(s));
            rows.push((label.to_string(), alg.label().to_string(), s));
        }
        t.row(&cells);
    }
    println!();
    rows
}

/// The per-algorithm cost-ordering claims of Table 2, stated ONCE and
/// shared by `repro graphs --quick` and `tests/unified_engine_costs.rs`
/// (recalibrate a bound here and both enforcers move together).  `secs`
/// is the `engines_for` order [tdo-gp, gemini-like, la-like,
/// ligra-dist]; returns one message per violated relation.
// `!(a < b)` rather than `a >= b`: a NaN cost must count as a violation,
// and the De-Morganed form would silently pass it.
#[allow(clippy::neg_cmp_op_on_partial_ord)]
pub fn ordering_violations(alg: Algorithm, secs: &[f64]) -> Vec<String> {
    assert_eq!(secs.len(), 4, "expected the engines_for family order");
    let (tdo, gem, la, lig) = (secs[0], secs[1], secs[2], secs[3]);
    let mut v = Vec::new();
    if !(tdo > 0.0) {
        v.push(format!("{}: tdo-gp charged nothing", alg.label()));
    }
    if !(tdo < gem) {
        v.push(format!("{}: tdo {tdo:.5} !< gemini-like {gem:.5}", alg.label()));
    }
    if !(tdo < lig) {
        v.push(format!("{}: tdo {tdo:.5} !< ligra-dist {lig:.5}", alg.label()));
    }
    if alg == Algorithm::Pr {
        // The paper's two Table-2 losses are PR cells (NUMA-aware
        // la-like local engines): allow la a small PR edge, but never a
        // structural one.
        if !(tdo < la * 1.15) {
            v.push(format!("PR: tdo {tdo:.5} !< 1.15x la-like {la:.5}"));
        }
    } else if !(tdo < la) {
        v.push(format!("{}: tdo {tdo:.5} !< la-like {la:.5}", alg.label()));
    }
    v
}

/// `repro graphs [--quick]`: the figure sweep on the unified engine.
///
/// Full mode regenerates every graph table/figure (what `repro all`
/// runs; `edges_per_machine` feeds Fig 9 exactly like `repro fig9
/// --edges`).  `--quick` is the CI smoke: a reduced dataset pair, every
/// algorithm, all four engine families — *asserting* the headline
/// structural orderings ([`ordering_violations`]; plus road-shape
/// blowups and T1–T3 ablation costs) instead of just printing, and
/// returning false on any violation.  Figures and runtime share one
/// engine now, so this exercises exactly the code `repro serve` serves.
pub fn run_graphs(edges_per_machine: usize, seed: u64, quick: bool) -> bool {
    if !quick {
        table2(seed);
        fig8(seed);
        fig9(edges_per_machine, seed);
        fig10(seed);
        table3(seed);
        table4(seed);
        table5(seed);
        table6(seed);
        return true;
    }

    println!("\n## repro graphs --quick — unified-engine figure smoke (seed {seed})\n");
    let cost = CostModel::paper_cluster();
    let mut ok = true;
    let mut check = |what: &str, cond: bool| {
        if !cond {
            println!("VIOLATION: {what}");
            ok = false;
        }
    };

    // Skewed social shape (Table 2's BA column), P=8, all five
    // algorithms x all four families.
    let g = gen::barabasi_albert(4_000, 8, seed);
    let p = 8;
    let mut engines = engines_for(&g, p, cost);
    let t = TablePrinter::new(
        &["Alg", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
        &[5, 9, 11, 9, 10],
    );
    for alg in Algorithm::ALL {
        let mut secs = Vec::new();
        for e in engines.iter_mut() {
            let (s, b) = run_alg(e, alg);
            check(&format!("{} {}: sim-seconds not positive", e.label(), alg.label()), s > 0.0);
            check(
                &format!("{} {}: breakdown != total", e.label(), alg.label()),
                (b.total() - s).abs() < 1e-12,
            );
            secs.push(s);
        }
        t.row(&[
            alg.label().to_string(),
            fmt_s(secs[0]),
            fmt_s(secs[1]),
            fmt_s(secs[2]),
            fmt_s(secs[3]),
        ]);
        for violation in ordering_violations(alg, &secs) {
            check(&violation, false);
        }
    }
    println!();

    // High-diameter road shape: the per-round dense-array / full-scan
    // overheads must blow the baselines up on frontier-sparse BFS (the
    // ~190-round corner BFS makes the Θ(n/P)/Θ(m/P) terms dominate).
    let road = gen::grid2d(96, seed);
    let mut road_engines = engines_for(&road, 8, cost);
    let (r_tdo, _) = run_alg(&mut road_engines[0], Algorithm::Bfs);
    let (r_gem, _) = run_alg(&mut road_engines[1], Algorithm::Bfs);
    let (r_la, _) = run_alg(&mut road_engines[2], Algorithm::Bfs);
    println!(
        "road BFS: tdo {} gemini {} ({:.1}x) la {} ({:.1}x)",
        fmt_s(r_tdo),
        fmt_s(r_gem),
        r_gem / r_tdo,
        fmt_s(r_la),
        r_la / r_tdo,
    );
    check(&format!("road BFS: gemini {r_gem} !> 2x tdo {r_tdo}"), r_gem > 2.0 * r_tdo);
    check(&format!("road BFS: la {r_la} !> 2x tdo {r_tdo}"), r_la > 2.0 * r_tdo);

    // T1-T3 ablations each cost extra (Table 4 shape), SSSP P=8.
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let sssp_with = |flags: Flags, label: &str, dg: crate::graph::ingest::DistGraph| {
        run_alg(
            &mut SpmdEngine::from_ingested(
                Cluster::new(p, cost),
                dg,
                cost,
                flags,
                label,
                QueryShard::new,
            ),
            Algorithm::Sssp,
        )
        .0
    };
    let full = sssp_with(Flags::tdo_gp(), "tdo-gp", dg.clone());
    for (label, flags) in Flags::ablations() {
        let ablated = sssp_with(flags, label, dg.clone());
        println!("ablation {label}: {:.2}x vs full", ablated / full);
        check(&format!("{label}: ablated {ablated} !> full {full}"), ablated > full);
    }

    let ing = ingestions();
    println!("\ningestion passes so far on this thread: {ing}");
    println!("\ngraphs --quick {}", if ok { "OK" } else { "FAILED (see VIOLATION lines)" });
    ok
}

/// Bit-exact f64 slice equality — the comparison the cross-backend
/// determinism contract is stated in (shared with
/// `benches/graph_wallclock.rs`).
pub fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// `repro graph` — TDO-GP's `DistEdgeMap` on the chosen backend.
///
/// `backend` is `"sim"` (cost-model simulator only) or `"threaded"`
/// (default): run PageRank and SSSP through the *same* SPMD engine on
/// both backends, assert the threaded results are bit-identical to the
/// simulated ones, and report measured per-machine busy wall-clock from
/// the persistent worker pool.  The graph is ingested exactly ONCE for
/// the whole run (`ingest_once` + `from_ingested` clones; algorithms are
/// separated by `reset_for_query`, the serving-layer contract) — the
/// `ingestions()` counter is part of the validity this returns.
pub fn run_graph_backend(p: usize, seed: u64, backend: &str) -> bool {
    assert!(p >= 1, "need at least one machine");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(20_000, 6, seed);
    println!(
        "\n## repro graph — TDO-GP edge_map, SPMD engine: BA graph n={} m={}, P={p}, \
         seed {seed}, backend {backend}\n",
        g.n,
        g.m()
    );

    // ONE ingestion; every engine on every backend clones the placement.
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let reset = |m: crate::MachineId, meta: &crate::graph::spmd::GraphMeta, st: &mut QueryShard| {
        st.reset(m, meta)
    };

    let mut sim = SpmdEngine::from_ingested(
        Cluster::new(p, cost),
        dg.clone(),
        cost,
        Flags::tdo_gp(),
        "tdo-gp",
        QueryShard::new,
    );
    let pr_sim = pagerank(&mut sim, PR_ITERS);
    let (pr_sim_s, pr_sim_steps) =
        (sim.sub().metrics.sim_seconds(), sim.sub().metrics.supersteps);
    sim.sub_mut().reset_metrics();
    sim.reset_for_query(reset);
    let ss_sim = sssp(&mut sim, 0);
    println!(
        "simulator: PR({PR_ITERS} iters) sim {pr_sim_s:.4}s over {pr_sim_steps} supersteps; \
         SSSP sim {:.4}s over {} supersteps  (one engine, reset between queries)",
        sim.sub().metrics.sim_seconds(),
        sim.sub().metrics.supersteps,
    );

    let ingested = ingestions() - ing0;
    if backend == "sim" {
        println!("\ningestions this run: {ingested}");
        let ok = ingested == 1;
        println!("graph {}", if ok { "OK (simulator only)" } else { "FAILED (re-ingested)" });
        return ok;
    }

    // ONE engine (hence one pool and the same single ingestion) serves
    // both algorithms on the threaded backend too: PR runs, the ledger
    // is snapshotted and reset, reset_for_query re-inits the shards, and
    // SSSP reuses the same P parked workers.
    let mut thr = SpmdEngine::from_ingested(
        ThreadedCluster::new(p),
        dg,
        cost,
        Flags::tdo_gp(),
        "tdo-gp",
        QueryShard::new,
    );
    let pr_thr = pagerank(&mut thr, PR_ITERS);
    let pr_busy = thr.sub().busy_ms_by_machine();
    let pr_max = thr.sub().max_busy_ms();
    let pr_imb = thr.sub().metrics.work_imbalance();
    let pr_epochs = thr.sub().epochs();
    thr.sub_mut().reset_metrics();
    thr.reset_for_query(reset);
    let ss_thr = sssp(&mut thr, 0);
    let tc = thr.sub();
    let ss_busy = tc.busy_ms_by_machine();
    let pr_ok = bits_equal(&pr_thr, &pr_sim);
    let ss_ok = bits_equal(&ss_thr, &ss_sim);
    println!(
        "threaded == simulator (bit-identical): PR {}  SSSP {}",
        if pr_ok { "PASS" } else { "FAIL" },
        if ss_ok { "PASS" } else { "FAIL" },
    );
    println!(
        "worker pool: {} threads total, reused across PR ({} epochs) and SSSP ({} epochs, \
         incl. the reset epoch) — spawned once per run",
        tc.pool_threads(),
        pr_epochs,
        tc.epochs() - pr_epochs,
    );

    println!("\nper-machine busy wall-clock (ms), one pooled OS thread per machine:");
    let t = TablePrinter::new(&["machine", "PR", "SSSP"], &[7, 10, 10]);
    for m in 0..p {
        t.row(&[
            m.to_string(),
            format!("{:.2}", pr_busy[m]),
            format!("{:.2}", ss_busy[m]),
        ]);
    }
    println!(
        "\nmax-loaded machine: PR {:.2} ms  SSSP {:.2} ms;  work imbalance(max/mean): PR {:.2}  SSSP {:.2}",
        pr_max,
        tc.max_busy_ms(),
        pr_imb,
        tc.metrics.work_imbalance(),
    );

    let ingested = ingestions() - ing0;
    println!("ingestions this run: {ingested} (both backends share one placement)");
    let all_valid = pr_ok && ss_ok && ingested == 1;
    println!(
        "\ngraph {}",
        if all_valid {
            "OK"
        } else {
            "FAILED (threaded diverged from simulator, or the graph was re-ingested)"
        }
    );
    all_valid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_alg_returns_positive_times() {
        let g = gen::barabasi_albert(500, 4, 3);
        let cost = CostModel::paper_cluster();
        let mut e = SpmdEngine::tdo_gp(Cluster::new(4, cost), &g, cost, QueryShard::new);
        for alg in Algorithm::ALL {
            let (s, b) = run_alg(&mut e, alg);
            assert!(s > 0.0, "{:?}", alg);
            assert!((b.total() - s).abs() < 1e-12);
        }
    }

    #[test]
    fn table2_summary_counts_wins() {
        let rows = vec![
            ("d".into(), "BFS".into(), "tdo-gp".into(), 1.0),
            ("d".into(), "BFS".into(), "gemini-like".into(), 2.0),
            ("d".into(), "BFS".into(), "la-like".into(), 3.0),
        ];
        table2_summary(&rows); // prints 1/1 wins, 2.0x
    }
}
