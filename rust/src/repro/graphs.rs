//! Graph-system reproductions: Table 2 (end-to-end), Fig 8 (strong
//! scaling), Fig 9 (weak scaling), Fig 10 (breakdown), Table 3 (TD-Orch
//! ablation), Table 4 (technique ablation), Tables 5/6 (NUMA ablations) —
//! all on the BSP cost-model simulator — plus `repro graph`, which runs
//! the SPMD `DistEdgeMap` engine on the REAL threaded worker pool and
//! validates it bit-for-bit against the simulator backend.

use crate::exec::ThreadedCluster;
use crate::graph::algorithms::{bc, bfs, cc, pagerank, pagerank_spmd, sssp, sssp_spmd, Algorithm};
use crate::graph::engine::{Engine, Flags, GraphEngine};
use crate::graph::gen::{self, Dataset};
use crate::graph::ingest::ingestions;
use crate::graph::spmd::{ingest_once, Placement, SpmdEngine};
use crate::graph::Graph;
use crate::metrics::Breakdown;
use crate::serve::QueryShard;
use crate::{Cluster, CostModel};

use super::{fmt_s, geomean, TablePrinter};

pub const PR_ITERS: usize = 10;

/// Run one algorithm on an engine; returns (sim-seconds, breakdown),
/// excluding ingestion (the paper times queries, not loading).
pub fn run_alg(engine: &mut Engine, alg: Algorithm) -> (f64, Breakdown) {
    engine.reset_metrics();
    match alg {
        Algorithm::Bfs => {
            bfs(engine, 0);
        }
        Algorithm::Sssp => {
            sssp(engine, 0);
        }
        Algorithm::Bc => {
            bc(engine, 0);
        }
        Algorithm::Cc => {
            cc(engine);
        }
        Algorithm::Pr => {
            pagerank(engine, PR_ITERS);
        }
    }
    (engine.metrics().sim_seconds(), engine.metrics().time)
}

fn engines_for(g: &Graph, p: usize, cost: CostModel) -> Vec<Engine> {
    vec![
        Engine::tdo_gp(g, p, cost),
        Engine::baseline(g, p, cost, Flags::gemini_like(), "gemini-like"),
        Engine::baseline(g, p, cost, Flags::la_like(), "la-like"),
        Engine::baseline(g, p, cost, Flags::ligra_dist(), "ligra-dist"),
    ]
}

/// Table 2: end-to-end runtimes across datasets x algorithms x engines.
/// Returns (dataset, alg, engine-label, sim-seconds) tuples.
pub fn table2(seed: u64) -> Vec<(String, String, String, f64)> {
    println!("\n## Table 2 — end-to-end runtime (sim-seconds)\n");
    let mut rows = Vec::new();
    for ds in Dataset::ALL {
        let g = ds.build(seed);
        let p = ds.machines();
        println!(
            "### {} (n={}, m={}, P={p})",
            ds.label(),
            g.n,
            g.m()
        );
        let t = TablePrinter::new(
            &["Alg", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
            &[5, 9, 11, 9, 10],
        );
        let mut engines = engines_for(&g, p, CostModel::paper_cluster());
        for alg in Algorithm::ALL {
            let mut cells = vec![alg.label().to_string()];
            for e in engines.iter_mut() {
                let (s, _) = run_alg(e, alg);
                cells.push(fmt_s(s));
                rows.push((
                    ds.label().to_string(),
                    alg.label().to_string(),
                    e.label().to_string(),
                    s,
                ));
            }
            t.row(&cells);
        }
        println!();
    }
    table2_summary(&rows);
    rows
}

/// §5 headline: geomean speedup of TDO-GP over the best prior system per
/// (dataset, algorithm) cell.  "Prior systems" are the gemini-like and
/// la-like families (the paper's Table 2 columns); ligra-dist is the
/// paper's own no-TD-Orch prototype (Table 3) and is excluded.
pub fn table2_summary(rows: &[(String, String, String, f64)]) {
    use std::collections::HashMap;
    let mut cells: HashMap<(String, String), (f64, f64)> = HashMap::new();
    for (ds, alg, eng, s) in rows {
        if eng == "ligra-dist" {
            continue;
        }
        let e = cells
            .entry((ds.clone(), alg.clone()))
            .or_insert((f64::NAN, f64::INFINITY));
        if eng == "tdo-gp" {
            e.0 = *s;
        } else if *s < e.1 {
            e.1 = *s; // best prior system
        }
    }
    let mut speedups = Vec::new();
    let mut wins = 0;
    let total = cells.len();
    for (_, (tdo, best_prior)) in cells {
        speedups.push(best_prior / tdo);
        if tdo <= best_prior {
            wins += 1;
        }
    }
    println!(
        "TDO-GP wins {wins}/{total} cells; geomean speedup vs best prior: {:.2}x  (paper: 28/30 wins, 4.1x geomean)",
        geomean(&speedups)
    );
}

/// Fig 8: strong scaling of SSSP and BC on the twitter-like graph.
pub fn fig8(seed: u64) -> Vec<(String, usize, String, f64)> {
    println!("\n## Fig 8 — strong scaling on twitter-like (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let mut rows = Vec::new();
    for alg in [Algorithm::Sssp, Algorithm::Bc] {
        println!("### {}", alg.label());
        let t = TablePrinter::new(
            &["P", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
            &[4, 9, 11, 9, 10],
        );
        for p in [1usize, 2, 4, 8, 16] {
            let mut cells = vec![p.to_string()];
            for e in engines_for(&g, p, CostModel::paper_cluster()).iter_mut() {
                let (s, _) = run_alg(e, alg);
                cells.push(fmt_s(s));
                rows.push((alg.label().to_string(), p, e.label().to_string(), s));
            }
            t.row(&cells);
        }
        println!();
    }
    rows
}

/// Fig 9: weak scaling on ER (unskewed) and BA (skewed, γ≈2.2) with a
/// fixed number of edges per machine.
pub fn fig9(edges_per_machine: usize, seed: u64) -> Vec<(String, usize, String, f64)> {
    println!(
        "\n## Fig 9 — weak scaling ({edges_per_machine} edges/machine, sim-seconds)\n"
    );
    let mut rows = Vec::new();
    for (gname, make) in [
        (
            "ER",
            Box::new(|p: usize, seed: u64| {
                let m = edges_per_machine * p / 2; // symmetrized to ~target
                gen::erdos_renyi(m / 8, m, seed)
            }) as Box<dyn Fn(usize, u64) -> Graph>,
        ),
        (
            "BA",
            Box::new(|p: usize, seed: u64| {
                let m = edges_per_machine * p / 2;
                let k = 8;
                gen::barabasi_albert(m / k, k, seed)
            }),
        ),
    ] {
        for alg in [Algorithm::Pr, Algorithm::Bc] {
            println!("### {gname} / {}", alg.label());
            let t = TablePrinter::new(
                &["P", "TDO-GP", "gemini-like", "la-like", "ligra-dist"],
                &[4, 9, 11, 9, 10],
            );
            for p in [1usize, 2, 4, 8, 16] {
                let g = make(p, seed);
                let mut cells = vec![p.to_string()];
                for e in engines_for(&g, p, CostModel::paper_cluster()).iter_mut() {
                    let (s, _) = run_alg(e, alg);
                    cells.push(fmt_s(s));
                    rows.push((
                        format!("{gname}/{}", alg.label()),
                        p,
                        e.label().to_string(),
                        s,
                    ));
                }
                t.row(&cells);
            }
            println!();
        }
    }
    rows
}

/// Fig 10: execution-time breakdown of TDO-GP on twitter-like, P = 16.
pub fn fig10(seed: u64) -> Vec<(String, Breakdown)> {
    println!("\n## Fig 10 — breakdown on twitter-like, P=16 (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let t = TablePrinter::new(
        &["Alg", "Communication", "Computation", "Overhead", "Total"],
        &[5, 13, 11, 9, 8],
    );
    let mut rows = Vec::new();
    let mut engine = Engine::tdo_gp(&g, 16, CostModel::paper_cluster());
    for alg in Algorithm::ALL {
        let (_, b) = run_alg(&mut engine, alg);
        t.row(&[
            alg.label().to_string(),
            fmt_s(b.communication),
            fmt_s(b.computation),
            fmt_s(b.overhead),
            fmt_s(b.total()),
        ]);
        rows.push((alg.label().to_string(), b));
    }
    println!();
    rows
}

/// Table 3: BC on twitter-like — Ligra-Dist (no TD-Orch) vs TDO-GP.
pub fn table3(seed: u64) -> Vec<(usize, f64, f64)> {
    println!("\n## Table 3 — BC on twitter-like: TD-Orch ablation (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let t = TablePrinter::new(
        &["P", "ligra-dist (no TD-Orch)", "TDO-GP"],
        &[4, 23, 9],
    );
    let mut rows = Vec::new();
    for p in [1usize, 4, 8, 16] {
        let cost = CostModel::paper_cluster();
        let (lig, _) = run_alg(
            &mut Engine::baseline(&g, p, cost, Flags::ligra_dist(), "ligra-dist"),
            Algorithm::Bc,
        );
        let (tdo, _) = run_alg(&mut Engine::tdo_gp(&g, p, cost), Algorithm::Bc);
        t.row(&[p.to_string(), fmt_s(lig), fmt_s(tdo)]);
        rows.push((p, lig, tdo));
    }
    println!();
    rows
}

/// Table 4: slowdown from removing each technique family (T1/T2/T3).
pub fn table4(seed: u64) -> Vec<(String, String, usize, f64)> {
    println!("\n## Table 4 — technique ablation on twitter-like (slowdown vs full)\n");
    let g = Dataset::TwitterLike.build(seed);
    let algs = [Algorithm::Sssp, Algorithm::Bc, Algorithm::Cc];
    let mut rows = Vec::new();
    let cost = CostModel::paper_cluster();
    for (label, flags) in [
        ("-T1 (global comm)", Flags::with_techniques(false, true, true)),
        ("-T2 (local comp)", Flags::with_techniques(true, false, true)),
        ("-T3 (coordination)", Flags::with_techniques(true, true, false)),
    ] {
        println!("### {label}");
        let t = TablePrinter::new(&["Alg", "P=4", "P=8", "P=16"], &[5, 7, 7, 7]);
        for alg in algs {
            let mut cells = vec![alg.label().to_string()];
            for p in [4usize, 8, 16] {
                let (full, _) = run_alg(&mut Engine::tdo_gp(&g, p, cost), alg);
                let (ablated, _) =
                    run_alg(&mut Engine::tdo_gp_with(&g, p, cost, flags, label), alg);
                let slowdown = ablated / full;
                cells.push(format!("{slowdown:.2}x"));
                rows.push((label.to_string(), alg.label().to_string(), p, slowdown));
            }
            t.row(&cells);
        }
        println!();
    }
    rows
}

/// Table 5: PR on twitter-like with one NUMA node per machine.
pub fn table5(seed: u64) -> Vec<(String, usize, f64)> {
    println!("\n## Table 5 — PR on twitter-like, 1 NUMA node/machine (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let cost = CostModel::single_numa();
    let t = TablePrinter::new(
        &["Engine", "P=1", "P=4", "P=8", "P=16"],
        &[12, 8, 8, 8, 8],
    );
    let mut rows = Vec::new();
    for (label, flags, tdo) in [
        ("gemini-like", Flags::gemini_like(), false),
        ("la-like", Flags::la_like(), false),
        ("TDO-GP", Flags::tdo_gp(), true),
    ] {
        let mut cells = vec![label.to_string()];
        for p in [1usize, 4, 8, 16] {
            let mut e = if tdo {
                Engine::tdo_gp(&g, p, cost)
            } else {
                Engine::baseline(&g, p, cost, flags, label)
            };
            let (s, _) = run_alg(&mut e, Algorithm::Pr);
            cells.push(fmt_s(s));
            rows.push((label.to_string(), p, s));
        }
        t.row(&cells);
    }
    println!();
    rows
}

/// Table 6: single big all-to-all NUMA server (P = 1), BFS/BC/PR,
/// including a GBBS-like single-machine engine (ligra flags at P=1 ==
/// work-efficient local edgemap without distribution overheads).
pub fn table6(seed: u64) -> Vec<(String, String, f64)> {
    println!("\n## Table 6 — twitter-like on the big NUMA server (sim-seconds)\n");
    let g = Dataset::TwitterLike.build(seed);
    let cost = CostModel::big_numa_server();
    let t = TablePrinter::new(&["Engine", "BFS", "BC", "PR"], &[12, 8, 8, 8]);
    let mut rows = Vec::new();
    for (label, flags, tdo) in [
        ("gemini-like", Flags::gemini_like(), false),
        ("la-like", Flags::la_like(), false),
        ("gbbs-like", Flags::ligra_dist(), false),
        ("TDO-GP", Flags::tdo_gp(), true),
    ] {
        let mut cells = vec![label.to_string()];
        for alg in [Algorithm::Bfs, Algorithm::Bc, Algorithm::Pr] {
            let mut e = if tdo {
                Engine::tdo_gp(&g, 1, cost)
            } else {
                Engine::baseline(&g, 1, cost, flags, label)
            };
            let (s, _) = run_alg(&mut e, alg);
            cells.push(fmt_s(s));
            rows.push((label.to_string(), alg.label().to_string(), s));
        }
        t.row(&cells);
    }
    println!();
    rows
}

/// Bit-exact f64 slice equality — the comparison the cross-backend
/// determinism contract is stated in (shared with
/// `benches/graph_wallclock.rs`).
pub fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// `repro graph` — TDO-GP's `DistEdgeMap` on the chosen backend.
///
/// `backend` is `"sim"` (cost-model simulator only) or `"threaded"`
/// (default): run PageRank and SSSP through the *same* SPMD engine on
/// both backends, assert the threaded results are bit-identical to the
/// simulated ones, and report measured per-machine busy wall-clock from
/// the persistent worker pool.  The graph is ingested exactly ONCE for
/// the whole run (`ingest_once` + `from_ingested` clones; algorithms are
/// separated by `reset_for_query`, the serving-layer contract) — the
/// `ingestions()` counter is part of the validity this returns.
pub fn run_graph_backend(p: usize, seed: u64, backend: &str) -> bool {
    assert!(p >= 1, "need at least one machine");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(20_000, 6, seed);
    println!(
        "\n## repro graph — TDO-GP edge_map, SPMD engine: BA graph n={} m={}, P={p}, \
         seed {seed}, backend {backend}\n",
        g.n,
        g.m()
    );

    // ONE ingestion; every engine on every backend clones the placement.
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let reset = |m: crate::MachineId, meta: &crate::graph::spmd::GraphMeta, st: &mut QueryShard| {
        st.reset(m, meta)
    };

    let mut sim = SpmdEngine::from_ingested(
        Cluster::new(p, cost),
        dg.clone(),
        cost,
        Flags::tdo_gp(),
        "tdo-gp-spmd",
        QueryShard::new,
    );
    let pr_sim = pagerank_spmd(&mut sim, PR_ITERS);
    let (pr_sim_s, pr_sim_steps) =
        (sim.sub().metrics.sim_seconds(), sim.sub().metrics.supersteps);
    sim.sub_mut().reset_metrics();
    sim.reset_for_query(reset);
    let ss_sim = sssp_spmd(&mut sim, 0);
    println!(
        "simulator: PR({PR_ITERS} iters) sim {pr_sim_s:.4}s over {pr_sim_steps} supersteps; \
         SSSP sim {:.4}s over {} supersteps  (one engine, reset between queries)",
        sim.sub().metrics.sim_seconds(),
        sim.sub().metrics.supersteps,
    );

    let ingested = ingestions() - ing0;
    if backend == "sim" {
        println!("\ningestions this run: {ingested}");
        let ok = ingested == 1;
        println!("graph {}", if ok { "OK (simulator only)" } else { "FAILED (re-ingested)" });
        return ok;
    }

    // ONE engine (hence one pool and the same single ingestion) serves
    // both algorithms on the threaded backend too: PR runs, the ledger
    // is snapshotted and reset, reset_for_query re-inits the shards, and
    // SSSP reuses the same P parked workers.
    let mut thr = SpmdEngine::from_ingested(
        ThreadedCluster::new(p),
        dg,
        cost,
        Flags::tdo_gp(),
        "tdo-gp-spmd",
        QueryShard::new,
    );
    let pr_thr = pagerank_spmd(&mut thr, PR_ITERS);
    let pr_busy = thr.sub().busy_ms_by_machine();
    let pr_max = thr.sub().max_busy_ms();
    let pr_imb = thr.sub().metrics.work_imbalance();
    let pr_epochs = thr.sub().epochs();
    thr.sub_mut().reset_metrics();
    thr.reset_for_query(reset);
    let ss_thr = sssp_spmd(&mut thr, 0);
    let tc = thr.sub();
    let ss_busy = tc.busy_ms_by_machine();
    let pr_ok = bits_equal(&pr_thr, &pr_sim);
    let ss_ok = bits_equal(&ss_thr, &ss_sim);
    println!(
        "threaded == simulator (bit-identical): PR {}  SSSP {}",
        if pr_ok { "PASS" } else { "FAIL" },
        if ss_ok { "PASS" } else { "FAIL" },
    );
    println!(
        "worker pool: {} threads total, reused across PR ({} epochs) and SSSP ({} epochs, \
         incl. the reset epoch) — spawned once per run",
        tc.pool_threads(),
        pr_epochs,
        tc.epochs() - pr_epochs,
    );

    println!("\nper-machine busy wall-clock (ms), one pooled OS thread per machine:");
    let t = TablePrinter::new(&["machine", "PR", "SSSP"], &[7, 10, 10]);
    for m in 0..p {
        t.row(&[
            m.to_string(),
            format!("{:.2}", pr_busy[m]),
            format!("{:.2}", ss_busy[m]),
        ]);
    }
    println!(
        "\nmax-loaded machine: PR {:.2} ms  SSSP {:.2} ms;  work imbalance(max/mean): PR {:.2}  SSSP {:.2}",
        pr_max,
        tc.max_busy_ms(),
        pr_imb,
        tc.metrics.work_imbalance(),
    );

    let ingested = ingestions() - ing0;
    println!("ingestions this run: {ingested} (both backends share one placement)");
    let all_valid = pr_ok && ss_ok && ingested == 1;
    println!(
        "\ngraph {}",
        if all_valid {
            "OK"
        } else {
            "FAILED (threaded diverged from simulator, or the graph was re-ingested)"
        }
    );
    all_valid
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_alg_returns_positive_times() {
        let g = gen::barabasi_albert(500, 4, 3);
        let mut e = Engine::tdo_gp(&g, 4, CostModel::paper_cluster());
        for alg in Algorithm::ALL {
            let (s, b) = run_alg(&mut e, alg);
            assert!(s > 0.0, "{:?}", alg);
            assert!((b.total() - s).abs() < 1e-12);
        }
    }

    #[test]
    fn table2_summary_counts_wins() {
        let rows = vec![
            ("d".into(), "BFS".into(), "tdo-gp".into(), 1.0),
            ("d".into(), "BFS".into(), "gemini-like".into(), 2.0),
            ("d".into(), "BFS".into(), "la-like".into(), 3.0),
        ];
        table2_summary(&rows); // prints 1/1 wins, 2.0x
    }
}
