//! `repro exec` — the real threaded substrate, end to end.
//!
//! Runs TD-Orch and the direct-push / direct-pull baselines on
//! [`ThreadedCluster`] (one OS worker thread per logical machine) over a
//! Zipf-hotspot YCSB batch, validates every threaded store against the
//! sequential oracle, and reports *measured* per-machine wall-clock — the
//! quantity the BSP simulator's max-terms model, observed for real.  A
//! second leg cross-checks SSSP-as-orchestration-stages on the threaded
//! backend against the unified TDO-GP graph engine on the simulator.

use std::collections::HashMap;

use crate::baselines::{DirectPull, DirectPush};
use crate::exec::apps::sssp_stages;
use crate::exec::ThreadedCluster;
use crate::graph::algorithms::{sssp as engine_sssp, SsspShard};
use crate::graph::gen;
use crate::graph::spmd::SpmdEngine;
use crate::kvstore::{normalized_snapshot, preload, Bucket, KvApp, KvOp};
use crate::metrics::Metrics;
use crate::orchestration::tdorch::TdOrch;
use crate::orchestration::{sequential_reference, Scheduler, Task};
use crate::rng::Rng;
use crate::workload::{YcsbKind, YcsbWorkload};
use crate::{CostModel, DistStore};

use super::TablePrinter;

/// Workload shape: few buckets + deep preload makes each bucket heavy on
/// the wire, which is exactly where direct-pull's O(D·P·B) chunk motion
/// hurts and TD-Orch's σ-word context pushes win.  Public so
/// `benches/exec_wallclock.rs` measures the exact workload `repro exec`
/// reports.
pub const BUCKETS: u64 = 1 << 12;
const KEY_SPACE: u64 = 200_000;
pub const N_PRELOAD: u64 = 16 * BUCKETS;

/// Build the canonical Zipf-hotspot YCSB-A batch plus the
/// sequential-oracle snapshot every run is validated against (shared by
/// `repro exec` and the wall-clock bench).
#[allow(clippy::type_complexity)]
pub fn hotspot_workload(
    p: usize,
    per_machine: usize,
    gamma: f64,
    seed: u64,
) -> (Vec<Vec<Task<KvOp>>>, Vec<(u64, Vec<(u64, u32)>)>) {
    let workload = YcsbWorkload::new(YcsbKind::A, KEY_SPACE, gamma, BUCKETS);
    let mut rng = Rng::new(seed);
    let mut tasks: Vec<Vec<Task<KvOp>>> = (0..p).map(|_| Vec::new()).collect();
    for (m, batch) in tasks.iter_mut().enumerate() {
        *batch = workload.generate(&mut rng, per_machine, (m * per_machine) as u64);
    }
    let app = KvApp::new(BUCKETS);
    let mut oracle: DistStore<Bucket> = DistStore::new(p);
    preload(&mut oracle, BUCKETS, N_PRELOAD);
    sequential_reference(&app, &tasks, &mut oracle);
    let expected = normalized_snapshot(&oracle);
    (tasks, expected)
}

/// Result of one `repro exec` invocation (consumed by tests/benches).
pub struct ExecSummary {
    /// (scheduler name, per-machine busy ms, max busy ms, executed/machine)
    pub rows: Vec<(&'static str, Vec<f64>, f64, Vec<u64>)>,
    /// Store state matched `sequential_reference` for every scheduler.
    pub all_valid: bool,
}

/// Run one scheduler on the threaded backend; return metrics + validity.
#[allow(clippy::type_complexity)]
fn run_one(
    sched: &dyn Scheduler<KvApp<'static>, ThreadedCluster>,
    name: &'static str,
    p: usize,
    tasks: &[Vec<Task<KvOp>>],
    expected: &[(u64, Vec<(u64, u32)>)],
) -> (&'static str, Vec<f64>, f64, Vec<u64>, bool) {
    let app = KvApp::new(BUCKETS);
    let mut cluster = ThreadedCluster::new(p);
    let mut store: DistStore<Bucket> = DistStore::new(p);
    preload(&mut store, BUCKETS, N_PRELOAD);
    let outcome = sched.run_stage(&mut cluster, &app, tasks.to_vec(), &mut store);
    let valid = normalized_snapshot(&store).as_slice() == expected;
    (
        name,
        cluster.busy_ms_by_machine(),
        cluster.max_busy_ms(),
        outcome.executed_per_machine,
        valid,
    )
}

/// The `repro exec` entry point: P worker threads, `per_machine` YCSB-A
/// ops each at Zipf(γ).  Returns the summary for programmatic use.
pub fn run_exec(p: usize, per_machine: usize, gamma: f64, seed: u64) -> ExecSummary {
    assert!(p >= 1, "need at least one machine");
    assert!(per_machine >= 1, "need at least one op per machine");
    println!(
        "\n## repro exec — threaded shared-nothing substrate: {p} worker threads, \
         {per_machine} YCSB-A ops/machine, Zipf γ={gamma}, seed {seed}\n"
    );

    // Workload + the sequential oracle every threaded run is validated
    // against.
    let (tasks, expected) = hotspot_workload(p, per_machine, gamma, seed);

    // Hottest bucket, to show where the Zipf head lands.
    let mut hits: HashMap<u64, usize> = HashMap::new();
    for batch in &tasks {
        for t in batch {
            *hits.entry(t.read_addr).or_insert(0) += 1;
        }
    }
    // Tie-break on the lowest address so the line is run-to-run stable
    // (std HashMap iteration order is per-process random).
    let (hot_addr, hot_hits) = hits
        .iter()
        .max_by_key(|(a, n)| (**n, std::cmp::Reverse(**a)))
        .map(|(a, n)| (*a, *n))
        .unwrap_or((0, 0));
    println!(
        "hottest bucket: addr {hot_addr} with {hot_hits} of {} ops ({:.1}%)\n",
        p * per_machine,
        100.0 * hot_hits as f64 / (p * per_machine) as f64
    );

    let td = TdOrch::new();
    let scheds: [(&'static str, &dyn Scheduler<KvApp<'static>, ThreadedCluster>); 3] = [
        ("td-orch", &td),
        ("direct-push", &DirectPush),
        ("direct-pull", &DirectPull),
    ];

    let mut rows = Vec::new();
    let mut all_valid = true;
    for (name, sched) in scheds {
        let (name, busy, max_busy, executed, valid) =
            run_one(sched, name, p, &tasks, &expected);
        println!(
            "{name:<12} store == sequential_reference: {}",
            if valid { "PASS" } else { "FAIL" }
        );
        all_valid &= valid;
        rows.push((name, busy, max_busy, executed));
    }

    println!("\nper-machine busy wall-clock (ms), one OS thread per machine:");
    let t = TablePrinter::new(
        &["machine", "td-orch", "direct-push", "direct-pull"],
        &[7, 10, 11, 11],
    );
    for m in 0..p {
        t.row(&[
            m.to_string(),
            format!("{:.2}", rows[0].1[m]),
            format!("{:.2}", rows[1].1[m]),
            format!("{:.2}", rows[2].1[m]),
        ]);
    }

    println!("\nmax-loaded machine (busy ms) and execution balance:");
    for (name, _, max_busy, executed) in &rows {
        println!(
            "  {name:<12} max {max_busy:>8.2} ms   exec imbalance(max/mean) {:.2}",
            Metrics::imbalance(executed)
        );
    }
    // Informational perf comparison — PASS/FAIL and the exit code are
    // reserved for correctness (store == oracle, SSSP agreement).
    let td_max = rows[0].2;
    let perf = |theirs: f64| {
        if td_max < theirs {
            "td-orch faster"
        } else {
            "td-orch slower — perf target missed, or a noisy host"
        }
    };
    let push_max = rows[1].2;
    let pull_max = rows[2].2;
    println!(
        "\ntd-orch max-loaded machine vs direct-push: {:.2}x  [{}]",
        push_max / td_max,
        perf(push_max)
    );
    println!(
        "td-orch max-loaded machine vs direct-pull: {:.2}x  [{}]",
        pull_max / td_max,
        perf(pull_max)
    );

    // ---- SSSP leg: graph algorithm through the threaded substrate ----
    println!("\n## SSSP via orchestration stages on the threaded substrate");
    let g = gen::barabasi_albert(4_000, 6, seed);
    let mut tc = ThreadedCluster::new(p);
    let dist_threaded = sssp_stages(&mut tc, &td, &g, 0);
    let cost = CostModel::paper_cluster();
    let mut engine =
        SpmdEngine::tdo_gp(crate::Cluster::new(p, cost), &g, cost, SsspShard::new);
    let dist_engine = engine_sssp(&mut engine, 0);
    let agree = dist_threaded
        .iter()
        .zip(&dist_engine)
        .all(|(a, b)| a == b || (a.is_infinite() && b.is_infinite()));
    let reached = dist_threaded.iter().filter(|d| d.is_finite()).count();
    println!(
        "BA graph n={} m={}: reached {reached} vertices over {} supersteps; \
         distances == simulated TDO-GP engine: {}",
        g.n,
        g.m(),
        tc.metrics.supersteps,
        if agree { "PASS" } else { "FAIL" }
    );
    all_valid &= agree;

    println!(
        "\nexec {}",
        if all_valid { "OK" } else { "FAILED (see FAIL lines above)" }
    );
    ExecSummary { rows, all_valid }
}
