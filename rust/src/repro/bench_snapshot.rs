//! `repro bench-snapshot` — committed perf snapshots with a CI check
//! gate.
//!
//! The repo commits two perf snapshot files at its root:
//!
//! * `BENCH_graph_wallclock.json` — key points of the graph-engine
//!   sweep (per-(P, algorithm) simulated seconds, ledger supersteps,
//!   total words on a fixed BA graph);
//! * `BENCH_loadcurve.json` — key points of the quick load-curve sweep
//!   (per-point offered/served/rejected, tick-domain wait percentiles,
//!   logical goodput).
//!
//! Both snapshots (schema v2) also pin flight-recorder counters: every
//! graph key point carries its deterministic `trace_events` count (=
//! dirty ledger supersteps), and the load-curve snapshot embeds a
//! `trace` object of per-P event / wave / epoch-bump totals from
//! [`super::trace::trace_det_json`] — all pure functions of (graph,
//! config, seed, P), so they diff like every other deterministic field.
//!
//! Only **machine-normalized** quantities go into the `deterministic`
//! object: everything in it is a pure function of (graph, flags, P,
//! seed, config) in the cost/tick domain — never host wall-clock, which
//! lives outside the compared region as annotation (`host`, `status`).
//! That is what makes the snapshots committable: the same commit
//! produces byte-identical `deterministic` objects on every machine, so
//! CI can *diff* them instead of applying noise tolerances.
//!
//! `repro bench-snapshot` regenerates both files under `--out` (default
//! `target/bench-snapshot/`).  With `--check --baseline <dir>` it also
//! compares each fresh `deterministic` object against the committed
//! file in `<dir>`:
//!
//! * committed file missing ............................ FAIL
//! * committed file carries `"status":"pending"` ....... FAIL — the
//!   placeholder is an IOU, not a baseline; the gate stays red until a
//!   real snapshot is committed (run the refresh command below on a
//!   machine with the toolchain and commit the two files)
//! * committed file contains the fresh object .......... pass
//! * anything else ..................................... FAIL — the
//!   deterministic perf surface moved without a snapshot refresh.
//!
//! Refreshing after an intentional change is one command:
//! `cargo run --release -- bench-snapshot` and copy the two files from
//! the out dir over the repo-root ones.

use crate::graph::gen;
use crate::graph::algorithms::Algorithm;
use crate::graph::spmd::SpmdEngine;
use crate::obs::FlightRecorder;
use crate::serve::QueryShard;
use crate::{Cluster, CostModel};

use super::graphs::run_alg;
use super::loadcurve::{run_loadcurve, CurvePoint};
use super::trace::trace_det_json;

/// Repo-root snapshot file names (also the names written under `--out`).
pub const GRAPH_FILE: &str = "BENCH_graph_wallclock.json";
pub const LOADCURVE_FILE: &str = "BENCH_loadcurve.json";

const GRAPH_N: usize = 2_000;
const GRAPH_K: usize = 6;
const SEED: u64 = 7;
const MACHINES: [usize; 2] = [2, 8];

pub struct BenchSnapshotSummary {
    /// Paths of the freshly written snapshot files.
    pub wrote: Vec<String>,
    /// Baseline files that matched the fresh deterministic object.
    pub checked: usize,
    /// Baseline files still carrying the `pending` placeholder (these
    /// fail the check: a placeholder is not a baseline).
    pub pending: usize,
    /// Baseline files that exist but disagree (or could not be read).
    pub mismatches: usize,
    pub all_valid: bool,
}

/// Outcome of comparing one committed snapshot against the fresh
/// deterministic object (separated from I/O so it is unit-testable).
#[derive(Debug, PartialEq, Eq, Clone, Copy)]
pub enum CheckOutcome {
    /// Committed file contains the fresh deterministic object verbatim.
    Ok,
    /// Committed file is the `"status":"pending"` placeholder.
    Pending,
    /// Committed file disagrees with the fresh deterministic object.
    Mismatch,
}

pub fn check_file(committed: &str, det: &str) -> CheckOutcome {
    if committed.contains("\"status\":\"pending\"") {
        CheckOutcome::Pending
    } else if committed.contains(det) {
        CheckOutcome::Ok
    } else {
        CheckOutcome::Mismatch
    }
}

fn jnum(x: f64) -> String {
    if x.is_finite() {
        x.to_string()
    } else {
        "null".to_string()
    }
}

/// The graph-engine key points: TDO-GP on a fixed BA graph, every
/// algorithm at P ∈ {2, 8}.  Simulated seconds, ledger supersteps and
/// total words are all cost-domain quantities — bit-identical across
/// hosts for a fixed commit.
fn graph_det_json() -> String {
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(GRAPH_N, GRAPH_K, SEED);
    let mut points = Vec::new();
    for p in MACHINES {
        // A flight recorder rides along so every key point also pins its
        // deterministic event count (= dirty ledger supersteps, a pure
        // function of (graph, flags, P)).  `recorded()` counts every
        // record ever made, so the per-point delta is exact even if the
        // ring were to wrap.
        let rec = FlightRecorder::shared(crate::obs::trace::DEFAULT_CAPACITY);
        let mut engine = SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new);
        engine.set_observer(Some(rec.clone()));
        let mut seen = 0u64;
        for alg in Algorithm::ALL {
            let (s, _) = run_alg(&mut engine, alg);
            let m = &engine.sub().metrics;
            let recorded = rec.lock().unwrap().recorded();
            let trace_events = recorded - seen;
            seen = recorded;
            points.push(format!(
                "{{\"label\":\"p{p}-{}\",\"sim_seconds\":{},\"supersteps\":{},\
                 \"total_words\":{},\"trace_events\":{trace_events}}}",
                alg.label().to_lowercase(),
                jnum(s),
                m.supersteps,
                m.total_words,
            ));
        }
    }
    format!(
        "{{\"graph\":{{\"kind\":\"barabasi_albert\",\"n\":{},\"m\":{},\"seed\":{SEED}}},\
         \"engine\":\"tdo-gp\",\"points\":[{}]}}",
        g.n,
        g.m(),
        points.join(",")
    )
}

fn lc_point(pt: &CurvePoint) -> String {
    format!(
        "{{\"label\":\"{}\",\"offered\":{},\"served\":{},\"rejected\":{},\"ticks\":{},\
         \"graph_epoch\":{},\"wait_p50\":{},\"wait_p99\":{},\"goodput_per_tick\":{}}}",
        pt.label,
        pt.offered,
        pt.served,
        pt.rejected,
        pt.ticks,
        pt.graph_epoch,
        jnum(pt.wait_ticks.p50),
        jnum(pt.wait_ticks.p99),
        jnum(pt.goodput_per_tick),
    )
}

/// The load-curve key points: the quick sim sweep, tick-domain fields
/// only (the full v2 report with wall-clock annotation is written to
/// `lc_out` as a side artifact).  Returns (deterministic object, sweep
/// validity).
fn loadcurve_det_json(lc_out: &str) -> (String, bool) {
    let lc = run_loadcurve(2, SEED, "sim", true, lc_out);
    let open: Vec<String> = lc.open.iter().map(lc_point).collect();
    let closed: Vec<String> = lc.closed.iter().map(lc_point).collect();
    // Trace summary counters (events / waves / epoch bumps per key
    // point) are deterministic too, so they join the compared object.
    let det = format!(
        "{{\"open\":[{}],\"closed\":[{}],\"trace\":{}}}",
        open.join(","),
        closed.join(","),
        trace_det_json(),
    );
    (det, lc.all_valid)
}

fn snapshot_json(schema: &str, det: &str) -> String {
    format!(
        "{{\"schema\":\"{schema}\",\"status\":\"ok\",\
         \"refresh\":\"cargo run --release -- bench-snapshot\",\
         \"deterministic\":{det},\
         \"host\":{{\"os\":\"{}\",\"arch\":\"{}\"}}}}\n",
        std::env::consts::OS,
        std::env::consts::ARCH,
    )
}

pub fn run_bench_snapshot(out_dir: &str, baseline: Option<&str>) -> BenchSnapshotSummary {
    println!("\n## repro bench-snapshot — machine-normalized perf key points\n");
    let graph_det = graph_det_json();
    let (lc_det, lc_valid) = loadcurve_det_json(&format!("{out_dir}/loadcurve-quick-sim.json"));
    let files = [
        (GRAPH_FILE, "tdorch.bench.graph.v2", &graph_det),
        (LOADCURVE_FILE, "tdorch.bench.loadcurve.v2", &lc_det),
    ];

    let mut wrote = Vec::new();
    let mut write_failures = 0usize;
    if let Err(e) = std::fs::create_dir_all(out_dir) {
        println!("FAILED to create {out_dir}: {e}");
        write_failures += 1;
    }
    for (name, schema, det) in &files {
        let path = format!("{out_dir}/{name}");
        match std::fs::write(&path, snapshot_json(schema, det)) {
            Ok(()) => {
                println!("wrote {path}");
                wrote.push(path);
            }
            Err(e) => {
                println!("FAILED to write {path}: {e}");
                write_failures += 1;
            }
        }
    }

    // Per-stage wallclock A/Bs ride along as a side artifact next to
    // the snapshots.  Annotation ONLY: host wall-clock never enters the
    // compared deterministic objects, so a write failure here warns
    // instead of failing the gate.
    let profile_path = format!("{out_dir}/profile-stage.json");
    match std::fs::write(&profile_path, crate::repro::profile::run_profile(3).json()) {
        Ok(()) => println!("wrote {profile_path} (annotation only, never diffed)"),
        Err(e) => println!("warning: could not write {profile_path}: {e}"),
    }

    let (mut checked, mut pending, mut mismatches) = (0usize, 0usize, 0usize);
    if let Some(base) = baseline {
        for (name, _, det) in &files {
            let path = format!("{base}/{name}");
            match std::fs::read_to_string(&path) {
                Err(e) => {
                    println!("CHECK FAILED: baseline {path} unreadable: {e}");
                    mismatches += 1;
                }
                Ok(committed) => match check_file(&committed, det) {
                    CheckOutcome::Ok => {
                        println!("check OK: {path} matches the fresh snapshot");
                        checked += 1;
                    }
                    CheckOutcome::Pending => {
                        println!(
                            "CHECK FAILED: {path} is still the placeholder — \
                             a pending snapshot is an IOU, not a baseline; \
                             commit the freshly written file to turn the gate green"
                        );
                        pending += 1;
                    }
                    CheckOutcome::Mismatch => {
                        println!(
                            "CHECK FAILED: {path} disagrees with the fresh snapshot — \
                             deterministic perf surface moved; refresh the committed \
                             file if the change is intentional"
                        );
                        mismatches += 1;
                    }
                },
            }
        }
    }

    let all_valid = lc_valid && mismatches == 0 && pending == 0 && write_failures == 0;
    println!(
        "\nbench-snapshot {}  (wrote {}, checked {checked}, pending {pending}, \
         mismatches {mismatches})",
        if all_valid { "OK" } else { "FAILED" },
        wrote.len(),
    );
    BenchSnapshotSummary {
        wrote,
        checked,
        pending,
        mismatches,
        all_valid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_file_classifies_the_three_outcomes() {
        let det = "{\"points\":[{\"label\":\"p2-bfs\"}]}";
        let fresh = snapshot_json("s.v1", det);
        assert_eq!(check_file(&fresh, det), CheckOutcome::Ok);
        let placeholder = "{\"schema\":\"s.v1\",\"status\":\"pending\"}";
        assert_eq!(check_file(placeholder, det), CheckOutcome::Pending);
        let stale = snapshot_json("s.v1", "{\"points\":[]}");
        assert_eq!(check_file(&stale, det), CheckOutcome::Mismatch);
    }

    #[test]
    fn graph_points_are_stable_across_runs() {
        let a = graph_det_json();
        let b = graph_det_json();
        assert_eq!(a, b, "cost-domain points must be run-to-run identical");
        for p in MACHINES {
            assert!(a.contains(&format!("\"label\":\"p{p}-bfs\"")));
        }
        assert!(
            a.contains("\"trace_events\":"),
            "every key point must pin its deterministic event count"
        );
        assert!(!a.contains("null"), "every point must be finite");
    }

    #[test]
    fn snapshot_roundtrip_matches_its_own_baseline() {
        let dir = std::env::temp_dir().join("tdorch-bench-snapshot-test");
        let out = dir.to_str().unwrap();
        // Fresh files are written before the check reads them back, so a
        // self-baseline run must fully pass: nothing pending, nothing
        // mismatched.
        let s = run_bench_snapshot(out, Some(out));
        assert_eq!(s.wrote.len(), 2);
        assert_eq!(s.checked, 2);
        assert_eq!(s.pending, 0);
        assert_eq!(s.mismatches, 0);
        assert!(s.all_valid);
    }

    #[test]
    fn pending_placeholder_fails_the_check() {
        // A committed placeholder is an IOU, not a baseline: the gate
        // must go red, not warn-and-pass.
        let dir = std::env::temp_dir().join("tdorch-bench-snapshot-pending-test");
        let base = dir.join("baseline");
        std::fs::create_dir_all(&base).unwrap();
        for name in [GRAPH_FILE, LOADCURVE_FILE] {
            std::fs::write(
                base.join(name),
                "{\"schema\":\"x\",\"status\":\"pending\"}\n",
            )
            .unwrap();
        }
        let s = run_bench_snapshot(
            dir.join("out").to_str().unwrap(),
            Some(base.to_str().unwrap()),
        );
        assert_eq!(s.pending, 2);
        assert_eq!(s.mismatches, 0);
        assert!(!s.all_valid, "pending placeholders must fail the gate");
    }
}
