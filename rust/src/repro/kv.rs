//! Fig 5 reproduction: YCSB weak scaling over the four schedulers
//! (paper §4), plus the §4 headline geomean speedups.

use crate::baselines::{DirectPull, DirectPush, SortingBased};
use crate::kvstore::{preload, Bucket, KvApp};
use crate::metrics::Metrics;
use crate::orchestration::tdorch::TdOrch;
use crate::orchestration::{Scheduler, Task};
use crate::rng::Rng;
use crate::workload::{YcsbKind, YcsbWorkload};
use crate::{Cluster, CostModel, DistStore};

use super::{fmt_s, geomean, TablePrinter};

pub const SCHEDULER_NAMES: [&str; 4] = ["td-orch", "direct-push", "direct-pull", "sorting-mpc"];

/// One Fig 5 cell: run `kind` at Zipf `gamma` on `p` machines with
/// `per_machine` tasks each; returns sim-seconds for the 4 schedulers.
pub fn run_cell(
    kind: YcsbKind,
    gamma: f64,
    p: usize,
    per_machine: usize,
    seed: u64,
) -> [f64; 4] {
    let buckets = 1u64 << 16;
    let key_space = 1_000_000u64;
    let n_preload = 20_000u64;
    let n = per_machine * p;

    let workload = YcsbWorkload::new(kind, key_space, gamma, buckets);
    let mut rng = Rng::new(seed);
    // Generate per-machine batches (tasks start evenly spread, §2.2).
    let mut tasks: Vec<Vec<Task<crate::kvstore::KvOp>>> = (0..p).map(|_| Vec::new()).collect();
    for (m, batch) in tasks.iter_mut().enumerate() {
        *batch = workload.generate(&mut rng, per_machine, (m * per_machine) as u64);
    }
    debug_assert_eq!(tasks.iter().map(|b| b.len()).sum::<usize>(), n);

    let app = KvApp::new(buckets);
    let mut out = [0.0f64; 4];
    let run = |sched: &dyn Scheduler<KvApp>, slot: &mut f64| {
        let mut cluster = Cluster::new(p, CostModel::paper_cluster());
        let mut store: DistStore<Bucket> = DistStore::new(p);
        preload(&mut store, buckets, n_preload);
        sched.run_stage(&mut cluster, &app, tasks.clone(), &mut store);
        *slot = cluster.metrics.sim_seconds();
    };
    run(&TdOrch::new(), &mut out[0]);
    run(&DirectPush, &mut out[1]);
    run(&DirectPull, &mut out[2]);
    run(&SortingBased, &mut out[3]);
    out
}

/// Full Fig 5: workloads A/C/LOAD (B "exhibits similar trends and is
/// omitted", §4) x γ ∈ {1.5, 2.0, 2.5} x P ∈ {2,4,8,16}.
/// `per_machine` is scaled from the paper's 2M (DESIGN.md §2).
pub fn fig5(per_machine: usize, seed: u64) -> Vec<(String, [f64; 4])> {
    let mut results = Vec::new();
    println!("\n## Fig 5 — YCSB weak scaling (sim-seconds, {per_machine} tasks/machine)\n");
    for kind in [YcsbKind::A, YcsbKind::C, YcsbKind::Load] {
        for gamma in [1.5, 2.0, 2.5] {
            println!("### {} γ={gamma}", kind.label());
            let t = TablePrinter::new(
                &["P", "td-orch", "direct-push", "direct-pull", "sorting-mpc"],
                &[4, 10, 11, 11, 11],
            );
            for p in [2usize, 4, 8, 16] {
                let cell = run_cell(kind, gamma, p, per_machine, seed);
                t.row(&[
                    p.to_string(),
                    fmt_s(cell[0]),
                    fmt_s(cell[1]),
                    fmt_s(cell[2]),
                    fmt_s(cell[3]),
                ]);
                results.push((format!("{}/γ{gamma}/P{p}", kind.label()), cell));
            }
            println!();
        }
    }
    summary(&results);
    results
}

/// §4 headline: geomean speedup of TD-Orch over each baseline on the
/// multi-machine cells.
pub fn summary(results: &[(String, [f64; 4])]) {
    let mut speedups = [Vec::new(), Vec::new(), Vec::new()];
    for (_, cell) in results {
        for b in 0..3 {
            speedups[b].push(cell[b + 1] / cell[0]);
        }
    }
    println!(
        "geomean speedup of td-orch: {:.2}x vs direct-push, {:.2}x vs direct-pull, {:.2}x vs sorting  (paper: 2.09x push, 2.83x pull, 1.42x sorting)",
        geomean(&speedups[0]),
        geomean(&speedups[1]),
        geomean(&speedups[2]),
    );
}

/// Load-balance demo used by the hotspot example: per-machine executed
/// tasks for all four schedulers under an adversarial single-key batch.
pub fn hotspot_loads(p: usize, n: usize) -> Vec<(&'static str, Vec<u64>, f64)> {
    let buckets = 1u64 << 16;
    let app = KvApp::new(buckets);
    let make_tasks = || -> Vec<Vec<Task<crate::kvstore::KvOp>>> {
        let mut per: Vec<Vec<Task<crate::kvstore::KvOp>>> = (0..p).map(|_| Vec::new()).collect();
        for i in 0..n {
            let op = crate::kvstore::KvOp::update(42, i as u64, 1.0, 1.0);
            per[i % p].push(Task::inplace(op.bucket(buckets), op));
        }
        per
    };
    let mut out = Vec::new();
    let mut run = |name: &'static str, sched: &dyn Scheduler<KvApp>| {
        let mut cluster = Cluster::new(p, CostModel::paper_cluster());
        let mut store: DistStore<Bucket> = DistStore::new(p);
        let outcome = sched.run_stage(&mut cluster, &app, make_tasks(), &mut store);
        out.push((
            name,
            outcome.executed_per_machine.clone(),
            Metrics::imbalance(&outcome.executed_per_machine),
        ));
    };
    run("td-orch", &TdOrch::new());
    run("direct-push", &DirectPush);
    run("direct-pull", &DirectPull);
    run("sorting-mpc", &SortingBased);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_cell_shapes() {
        // One cell at small scale: td-orch must beat push & pull at high
        // skew, and all four produce positive times.
        let cell = run_cell(YcsbKind::A, 2.0, 8, 5_000, 1);
        for t in cell {
            assert!(t > 0.0);
        }
        assert!(cell[0] < cell[1], "td {} !< push {}", cell[0], cell[1]);
        assert!(cell[0] < cell[3], "td {} !< sort {}", cell[0], cell[3]);
    }

    #[test]
    fn hotspot_loads_shapes() {
        let loads = hotspot_loads(8, 8_000);
        let td = &loads[0];
        let push = &loads[1];
        assert!(td.2 < 3.0, "td imbalance {}", td.2);
        assert!(push.2 > 6.0, "push imbalance {}", push.2);
    }
}
