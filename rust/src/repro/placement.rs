//! `repro placement` — hotspot-adaptive shard migration + hot-vertex
//! replication, verified bit-for-bit and shown to win.
//!
//! Two legs on the SAME drifting workload (one shared ingestion, both
//! engines built from clones of it): a {BFS, SSSP, PR, CC, BC} Zipf
//! stream weighted toward PageRank — whose per-machine work is exactly
//! the resident arc count, the signal placement repairs — plus an
//! insert-heavy, sharply-Zipf mutation feed that accretes arcs onto the
//! hottest sources' owners (the PR-6 first-resident-block rule), so the
//! edge-balanced static placement *drifts* into a hotspot mid-run.
//!
//! * **static** — the drift lands and stays; every post-drift wave pays
//!   the straggler under work-sensitive pricing
//!   ([`crate::serve::ServeConfig::work_per_tick`]).
//! * **adaptive** — a [`PlacementController`] watches the flight
//!   recorder's per-machine work totals and, at epoch boundaries only,
//!   splits the drifted hot block (replication of a read-hot source) and
//!   migrates whole blocks hot→cold, each application absorbed in place
//!   inside one superstep ([`SpmdEngine::apply_placement`]) and priced
//!   on the same logical clock queries pay.
//!
//! Validity gates (exit 1 on any failure):
//! 1. every served query on BOTH legs matches a reference engine built
//!    at exactly its epoch — the epoch chain (mutation batches and
//!    placement deltas, merged in `epoch_after` order) replayed onto a
//!    clone of the shared ingestion, walked in reverse like
//!    `repro mutate`;
//! 2. the adaptive engine's final block catalog and leaf sets equal a
//!    from-scratch engine over the final replayed assignment;
//! 3. `ingest::ingestions()` stays at one — migration never re-ingests;
//! 4. epoch accounting: +1 per mutation batch, +1 per placement op;
//! 5. the win is real: adaptive serves at strictly higher goodput/tick
//!    AND strictly lower steady-state step imbalance than static;
//! 6. on the threaded backend, an extra sim leg must reproduce the
//!    adaptive leg's decision log, deltas, schedule, and bits exactly.

use std::sync::Arc;

use crate::exec::{Substrate, ThreadedCluster};
use crate::graph::flags::Flags;
use crate::graph::gen;
use crate::graph::ingest::{ingestions, DistGraph};
use crate::graph::spmd::{ingest_once, GraphMeta, Placement, SpmdEngine};
use crate::graph::Vid;
use crate::metrics::Metrics;
use crate::mutate::{generate_mutations, MutationBatch, MutationConfig, MutationFeed};
use crate::obs::{EventKind, FlightRecorder};
use crate::place::{apply_to_distgraph, PlacementController, PlacementDelta, PlacementPolicy};
use crate::serve::{QueryShard, RunOpts, ServeConfig, ServeReport, Server};
use crate::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryMix, StreamConfig,
};
use crate::{Cluster, CostModel};

use super::TablePrinter;

const FULL_N: usize = 8_000;
const QUICK_N: usize = 2_000;
const GRAPH_K: usize = 6;
const FULL_QUERIES: usize = 64;
const QUICK_QUERIES: usize = 24;
/// Open-loop arrival rate (queries per logical tick).
const ARRIVALS_PER_TICK: usize = 2;
const ZIPF_S: f64 = 1.5;

/// PR-weighted serving mix: PageRank's dense supersteps make per-machine
/// work track resident arcs, so the drift (and the repair) show directly
/// in the recorder signal the controller consumes.
fn serving_mix() -> QueryMix {
    QueryMix { bfs: 1, sssp: 1, pr: 4, cc: 1, bc: 1 }
}

/// Insert-heavy and sharply Zipf (s = 2.5): most inserts are incident to
/// the very hottest sources, so their owners' resident arc counts drift
/// far above the mean while total load grows only modestly.
fn mutation_cfg(quick: bool) -> MutationConfig {
    MutationConfig {
        batches: if quick { 4 } else { 6 },
        ops_per_batch: if quick { 240 } else { 480 },
        insert_pct: 95,
        zipf_s: 2.5,
        start_tick: 2,
        every_ticks: 3,
    }
}

/// Low trigger + one move per round: each round splits the drifted hot
/// block (shipping roughly half the excess) and migrates one more whole
/// block, so repair converges geometrically instead of overshooting and
/// oscillating.
fn placement_policy() -> PlacementPolicy {
    PlacementPolicy::default().with_trigger(1.03).with_max_moves(1).with_max_rounds(16)
}

/// Result of one `repro placement` invocation (consumed by main/tests).
pub struct PlacementSummary {
    pub served_static: usize,
    pub served_adaptive: usize,
    pub ticks_static: u64,
    pub ticks_adaptive: u64,
    pub goodput_static: f64,
    pub goodput_adaptive: f64,
    pub imbalance_static: f64,
    pub imbalance_adaptive: f64,
    /// Placement rounds the controller applied on the adaptive leg.
    pub rounds: usize,
    pub moves: usize,
    pub splits: usize,
    /// Bit divergences against the per-epoch replay references (both legs).
    pub mismatches: usize,
    /// Ingestion passes over the whole run (must be exactly 1).
    pub ingestions_serving: u64,
    /// Sim and threaded adaptive legs agreed on every decision and bit
    /// (trivially true on the sim backend).
    pub decisions_match: bool,
    pub all_valid: bool,
}

/// Everything the comparison needs from one serving leg.
struct Leg {
    rep: ServeReport,
    catalog: Vec<Vec<(Vid, u32)>>,
    meta: Arc<GraphMeta>,
    epoch: u64,
    /// Per-superstep per-machine work vectors, in ledger order.
    works: Vec<Vec<u64>>,
    log: Vec<String>,
    deltas: Vec<PlacementDelta>,
}

fn run_leg<B: Substrate>(
    sub: B,
    dg: DistGraph,
    label: &'static str,
    cfg: ServeConfig,
    stream: &[Query],
    batches: &[MutationBatch],
    policy: Option<PlacementPolicy>,
) -> Leg {
    let cost = CostModel::paper_cluster();
    let rec = FlightRecorder::shared(1 << 18);
    let mut server = Server::new(
        SpmdEngine::from_ingested(sub, dg, cost, Flags::tdo_gp(), label, QueryShard::new),
        cfg,
    );
    server.set_recorder(Some(rec.clone()));
    let mut feed = MutationFeed::new(batches.to_vec());
    let mut src = OpenLoopSource::new(stream);
    let (rep, log, deltas) = match policy {
        Some(pol) => {
            let mut ctl = PlacementController::new(pol);
            let rep = server.serve(&mut src, RunOpts::new().feed(&mut feed).placement(&mut ctl));
            (rep, ctl.decision_log().to_vec(), ctl.applied().to_vec())
        }
        None => {
            let rep = server.serve(&mut src, RunOpts::new().feed(&mut feed));
            (rep, Vec::new(), Vec::new())
        }
    };
    let catalog = server.engine().block_catalog();
    let meta = server.engine().meta();
    let epoch = server.engine().graph_epoch();
    let guard = rec.lock().unwrap();
    let works: Vec<Vec<u64>> = guard
        .events()
        .filter_map(|e| match &e.kind {
            EventKind::Superstep { work, .. } => Some(work.clone()),
            _ => None,
        })
        .collect();
    drop(guard);
    Leg { rep, catalog, meta, epoch, works, log, deltas }
}

/// Steady-state step imbalance of a leg: max `step_imbalance` over the
/// *heavy* supersteps of the run's final quarter.  The whole-run maximum
/// would tie the legs — both share the identical pre-repair drifted
/// steps — so the metric looks only at where each leg settled; the
/// heaviness filter (at least half the tail's largest per-step maximum)
/// keeps near-idle frontier and delta-apply steps from dominating a
/// max/mean ratio that only matters where the work is.
fn steady_state_imbalance(works: &[Vec<u64>]) -> f64 {
    if works.is_empty() {
        return 1.0;
    }
    let tail = &works[works.len() - (works.len() / 4).max(1)..];
    let global_max =
        tail.iter().map(|w| w.iter().copied().max().unwrap_or(0)).max().unwrap_or(0);
    if global_max == 0 {
        return 1.0;
    }
    tail.iter()
        .filter(|w| w.iter().copied().max().unwrap_or(0) * 2 >= global_max)
        .map(|w| Metrics::step_imbalance(w))
        .fold(1.0, f64::max)
}

/// Replay the leg's epoch chain — mutation batches and placement deltas
/// merged in `epoch_after` order (the engine's single counter makes
/// those values globally unique, so the sort reconstructs the exact
/// live interleaving) — onto a clone of the shared ingestion, keeping a
/// snapshot per epoch for the per-query cross-check.
fn epoch_snapshots(
    dg0: &DistGraph,
    rep: &ServeReport,
    batches: &[MutationBatch],
) -> Vec<(u64, DistGraph)> {
    enum Ev<'a> {
        Delta(&'a MutationBatch),
        Place(PlacementDelta),
    }
    let mut events: Vec<(u64, Ev)> = rep
        .mutations
        .iter()
        .map(|m| (m.epoch_after, Ev::Delta(&batches[m.batch_id as usize])))
        .collect();
    events.extend(rep.placements.iter().map(|pr| {
        (pr.epoch_after, Ev::Place(PlacementDelta { round: pr.round, ops: pr.ops.clone() }))
    }));
    events.sort_by_key(|(e, _)| *e);
    let mut cur = dg0.clone();
    let mut snaps = vec![(0u64, cur.clone())];
    for (e, ev) in events {
        match ev {
            Ev::Delta(b) => {
                cur.apply_batch(b);
            }
            Ev::Place(d) => apply_to_distgraph(&mut cur, &d),
        }
        snaps.push((e, cur.clone()));
    }
    snaps
}

/// Reverse walk over a leg's served results, re-executing every query on
/// a sim reference engine built at exactly its epoch's replayed
/// assignment.  All five kinds compare bit-for-bit: the replay
/// reproduces block structures exactly, so even the rounding-merge kinds
/// (PR/BC, whose f64 fold grouping is part of the bits) must agree.
fn cross_check(
    p: usize,
    cfg: ServeConfig,
    rep: &ServeReport,
    snaps: &[(u64, DistGraph)],
    label: &str,
) -> usize {
    let cost = CostModel::paper_cluster();
    let mut mismatches = 0usize;
    let mut current: Option<(u64, Server<Cluster>)> = None;
    for r in rep.results.iter().rev() {
        if !current.as_ref().is_some_and(|(e, _)| *e == r.graph_epoch) {
            let Some((_, snap)) = snaps.iter().find(|(e, _)| *e == r.graph_epoch) else {
                eprintln!(
                    "  {label}: query {} at epoch {} has no replay snapshot",
                    r.id, r.graph_epoch
                );
                mismatches += 1;
                continue;
            };
            current = Some((
                r.graph_epoch,
                Server::new(
                    SpmdEngine::from_ingested(
                        Cluster::new(p, cost),
                        snap.clone(),
                        cost,
                        Flags::tdo_gp(),
                        "placement-epoch-ref",
                        QueryShard::new,
                    ),
                    cfg,
                ),
            ));
        }
        let (_, srv) = current.as_mut().unwrap();
        let q = Query { id: r.id, kind: r.kind, source: r.source, arrival: 0 };
        if srv.run_query(&q) != r.bits {
            eprintln!(
                "  {label}: query {} ({:?} from {}) diverges from its epoch-{} reference",
                r.id, r.kind, r.source, r.graph_epoch
            );
            mismatches += 1;
        }
    }
    mismatches
}

pub fn run_placement(
    p: usize,
    seed: u64,
    backend: &str,
    quick: bool,
    out: &str,
) -> PlacementSummary {
    assert!(p >= 2, "adaptive placement needs at least two machines");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let n = if quick { QUICK_N } else { FULL_N };
    let queries = if quick { QUICK_QUERIES } else { FULL_QUERIES };
    let g = gen::barabasi_albert(n, GRAPH_K, seed);
    let mcfg = mutation_cfg(quick);
    // The loaded-pricing grain: roughly a quarter of one machine's
    // resident arcs per tick, so per-wave makespan differences of a few
    // percent survive the ceiling division.
    let work_per_tick = (g.m() as u64 / (p as u64 * 4)).max(64);
    println!(
        "\n## repro placement — hotspot-adaptive migration + replication under a drifting \
         Zipf stream: BA graph n={} m={}, P={p}, {queries} queries (PR-weighted mix), \
         {} delta batches × {} edge ops (insert {}%, zipf {}), work_per_tick {work_per_tick}, \
         seed {seed}, backend {backend}\n",
        g.n,
        g.m(),
        mcfg.batches,
        mcfg.ops_per_batch,
        mcfg.insert_pct,
        mcfg.zipf_s,
    );

    // ONE ingestion, shared by both legs, the sim replica, and every
    // reference below (all built from clones).
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries,
            per_tick: ARRIVALS_PER_TICK,
            every_ticks: 1,
            zipf_s: ZIPF_S,
            mix: serving_mix(),
        },
        &hot,
        seed.wrapping_add(1),
    );
    let batches = generate_mutations(mcfg, &g, &hot, seed.wrapping_add(2));
    // queue_cap = offered load: neither leg sheds, so the goodput
    // comparison is purely about how fast the logical clock had to run.
    let cfg = ServeConfig {
        batch: 4,
        queue_cap: queries,
        work_per_tick: Some(work_per_tick),
        ..ServeConfig::default()
    };
    let policy = placement_policy();

    let (stat, adap, replica) = match backend {
        "threaded" => {
            let s = run_leg(
                ThreadedCluster::new(p),
                dg.clone(),
                "placement-static",
                cfg,
                &stream,
                &batches,
                None,
            );
            let a = run_leg(
                ThreadedCluster::new(p),
                dg.clone(),
                "placement-adaptive",
                cfg,
                &stream,
                &batches,
                Some(policy),
            );
            let r = run_leg(
                Cluster::new(p, cost),
                dg.clone(),
                "placement-adaptive-sim",
                cfg,
                &stream,
                &batches,
                Some(policy),
            );
            (s, a, Some(r))
        }
        _ => {
            let s = run_leg(
                Cluster::new(p, cost),
                dg.clone(),
                "placement-static",
                cfg,
                &stream,
                &batches,
                None,
            );
            let a = run_leg(
                Cluster::new(p, cost),
                dg.clone(),
                "placement-adaptive",
                cfg,
                &stream,
                &batches,
                Some(policy),
            );
            (s, a, None)
        }
    };

    // The migration witness, read BEFORE any reference is built.
    let ingestions_serving = ingestions() - ing0;

    // Sim <-> threaded determinism: the adaptive leg's whole trajectory
    // — decisions, deltas, schedule, bits — is a pure function of the
    // deterministic event stream, never of the backend.
    let decisions_match = match &replica {
        None => true,
        Some(r) => {
            let mut ok = r.log == adap.log
                && r.deltas == adap.deltas
                && r.rep.ticks == adap.rep.ticks
                && r.rep.served() == adap.rep.served();
            for (a, b) in adap.rep.results.iter().zip(&r.rep.results) {
                ok &= a.id == b.id && a.bits == b.bits && a.graph_epoch == b.graph_epoch;
            }
            if !ok {
                eprintln!("  adaptive decisions/bits diverged between threaded and sim");
            }
            ok
        }
    };

    // Per-epoch bit cross-check, both legs (the static chain is
    // mutations only; the adaptive chain interleaves placements).
    let snaps_static = epoch_snapshots(&dg, &stat.rep, &batches);
    let snaps_adaptive = epoch_snapshots(&dg, &adap.rep, &batches);
    let mismatches = cross_check(p, cfg, &stat.rep, &snaps_static, "static")
        + cross_check(p, cfg, &adap.rep, &snaps_adaptive, "adaptive");

    // Structural gate: the in-place patched engine equals a from-scratch
    // engine over the final replayed assignment — catalog, leaf sets,
    // degrees, arc count.
    let (_, final_dg) = snaps_adaptive.last().unwrap();
    let final_ref = SpmdEngine::from_ingested(
        Cluster::new(p, cost),
        final_dg.clone(),
        cost,
        Flags::tdo_gp(),
        "placement-final-ref",
        QueryShard::new,
    );
    let ref_meta = final_ref.meta();
    let structure_ok = adap.catalog == final_ref.block_catalog()
        && adap.meta.src_leaves == ref_meta.src_leaves
        && adap.meta.dst_leaves == ref_meta.dst_leaves
        && adap.meta.out_deg == ref_meta.out_deg
        && adap.meta.m == ref_meta.m;
    if !structure_ok {
        eprintln!("  adaptive engine structure diverges from the replayed assignment");
    }

    // Epoch accounting: +1 per mutation batch, +1 per placement op.
    let total_ops: usize = adap.deltas.iter().map(|d| d.ops.len()).sum();
    let epochs_ok = stat.epoch == batches.len() as u64
        && adap.epoch == (batches.len() + total_ops) as u64;
    if !epochs_ok {
        eprintln!(
            "  epoch accounting broken: static {} (want {}), adaptive {} (want {})",
            stat.epoch,
            batches.len(),
            adap.epoch,
            batches.len() + total_ops,
        );
    }

    let rounds = adap.rep.placements.len();
    let moves: usize = adap.rep.placements.iter().map(|pr| pr.moves).sum();
    let splits: usize = adap.rep.placements.iter().map(|pr| pr.splits).sum();

    if rounds > 0 {
        let t = TablePrinter::new(
            &["round", "applied@tick", "moves", "splits", "epoch after", "service ticks"],
            &[5, 12, 5, 6, 11, 13],
        );
        for pr in &adap.rep.placements {
            t.row(&[
                pr.round.to_string(),
                pr.applied_tick.to_string(),
                pr.moves.to_string(),
                pr.splits.to_string(),
                pr.epoch_after.to_string(),
                pr.service_ticks.to_string(),
            ]);
        }
        println!();
        for line in &adap.log {
            println!("    {line}");
        }
    }

    let goodput_static = stat.rep.goodput_per_tick();
    let goodput_adaptive = adap.rep.goodput_per_tick();
    let imbalance_static = steady_state_imbalance(&stat.works);
    let imbalance_adaptive = steady_state_imbalance(&adap.works);
    let served_ok = stat.rep.served() == queries
        && adap.rep.served() == queries
        && stat.rep.rejected == 0
        && adap.rep.rejected == 0;

    println!(
        "\n  static:   served {} in {} ticks — goodput {:.5}/tick, steady-state imbalance {:.4}",
        stat.rep.served(),
        stat.rep.ticks,
        goodput_static,
        imbalance_static,
    );
    println!(
        "  adaptive: served {} in {} ticks — goodput {:.5}/tick, steady-state imbalance {:.4} \
         ({rounds} rounds: {moves} moves, {splits} splits, {total_ops} ops)",
        adap.rep.served(),
        adap.rep.ticks,
        goodput_adaptive,
        imbalance_adaptive,
    );
    println!(
        "  cross-check: {mismatches} mismatches over {} results; ingestions {ingestions_serving}; \
         decisions sim==threaded: {decisions_match}; structure vs replay: {structure_ok}",
        stat.rep.results.len() + adap.rep.results.len(),
    );

    let all_valid = served_ok
        && mismatches == 0
        && ingestions_serving == 1
        && rounds >= 1
        && moves + splits >= 1
        && goodput_adaptive > goodput_static
        && imbalance_adaptive < imbalance_static
        && decisions_match
        && structure_ok
        && epochs_ok;
    println!("  PLACEMENT {}", if all_valid { "VALID" } else { "INVALID" });

    let json = format!(
        "{{\"schema\":\"tdorch.placement.v1\",\"p\":{p},\"backend\":\"{backend}\",\
         \"quick\":{quick},\"seed\":{seed},\"graph\":{{\"n\":{},\"m\":{}}},\
         \"work_per_tick\":{work_per_tick},\
         \"static\":{{\"served\":{},\"ticks\":{},\"goodput_per_tick\":{goodput_static:.6},\
         \"steady_imbalance\":{imbalance_static:.6}}},\
         \"adaptive\":{{\"served\":{},\"ticks\":{},\"goodput_per_tick\":{goodput_adaptive:.6},\
         \"steady_imbalance\":{imbalance_adaptive:.6},\"rounds\":{rounds},\"moves\":{moves},\
         \"splits\":{splits}}},\
         \"mismatches\":{mismatches},\"ingestions\":{ingestions_serving},\
         \"decisions_match\":{decisions_match},\"all_valid\":{all_valid}}}",
        g.n,
        g.m(),
        stat.rep.served(),
        stat.rep.ticks,
        adap.rep.served(),
        adap.rep.ticks,
    );
    match write_report(out, &json) {
        Ok(()) => println!("  report: {out}"),
        Err(e) => eprintln!("  report write failed ({out}): {e}"),
    }

    PlacementSummary {
        served_static: stat.rep.served(),
        served_adaptive: adap.rep.served(),
        ticks_static: stat.rep.ticks,
        ticks_adaptive: adap.rep.ticks,
        goodput_static,
        goodput_adaptive,
        imbalance_static,
        imbalance_adaptive,
        rounds,
        moves,
        splits,
        mismatches,
        ingestions_serving,
        decisions_match,
        all_valid,
    }
}

fn write_report(path: &str, json: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sim_placement_is_valid() {
        let out = "target/placement/test_quick_sim.json";
        let s = run_placement(4, 7, "sim", true, out);
        assert!(s.rounds >= 1, "the drift must trigger at least one placement round");
        assert!(s.moves + s.splits >= 1, "repair must move or split something");
        assert_eq!(s.mismatches, 0, "every served bit must match its epoch reference");
        assert_eq!(s.ingestions_serving, 1, "migration must never re-ingest");
        assert!(
            s.goodput_adaptive > s.goodput_static,
            "adaptive goodput {} must beat static {}",
            s.goodput_adaptive,
            s.goodput_static,
        );
        assert!(
            s.imbalance_adaptive < s.imbalance_static,
            "adaptive steady-state imbalance {} must beat static {}",
            s.imbalance_adaptive,
            s.imbalance_static,
        );
        assert!(s.all_valid, "quick sim placement repro must pass every gate");
        let json = std::fs::read_to_string(out).expect("artifact written");
        assert!(json.starts_with("{\"schema\":\"tdorch.placement.v1\""));
    }
}
