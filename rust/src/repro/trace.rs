//! `repro trace` — the flight-recorder divergence gate and Chrome-trace
//! exporter.
//!
//! Replays the `repro mutate` serving workload (a {BFS,SSSP,PR,CC,BC}
//! Zipf stream interleaved with edge delta batches, fusion and the
//! epoch-keyed cache both ON so every event kind is exercised) with a
//! [`FlightRecorder`] attached, at the requested machine count AND at
//! P=1, and on each leg runs the workload twice — once on the simulator,
//! once on the requested backend — asserting the **deterministic event
//! streams are bit-identical** line for line.  `--backend sim` compares
//! two independent sim runs, pinning run-to-run determinism instead.
//!
//! On top of the stream equality, the recorder is cross-checked against
//! the `ServeReport` it narrates: admit events == served queries, reject
//! events == the rejection total AND the per-kind split, the deepest
//! recorded admission depth == `max_queue_depth`, cache hit/miss events
//! == the report counters, wave events == wave records (with total lanes
//! == cache misses), mutation events == mutation records, and zero ring
//! drops.  Any failure exits 1 (the CI gate).
//!
//! Artifacts (requested backend, requested P): `trace.json` — Chrome
//! trace-event JSON for `chrome://tracing` / <https://ui.perfetto.dev> —
//! and `heatmap.txt`, the per-(superstep, machine) work/words table.

use std::fs;
use std::path::Path;

use crate::exec::{Substrate, ThreadedCluster};
use crate::graph::flags::Flags;
use crate::graph::gen;
use crate::graph::ingest::{ingestions, DistGraph};
use crate::graph::spmd::{ingest_once, Placement, SpmdEngine};
use crate::mutate::{generate_mutations, MutationBatch, MutationConfig, MutationFeed};
use crate::obs::{chrome_trace_json, first_divergence, heatmap_table, EventKind, FlightRecorder, ObserverHandle};
use crate::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use crate::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryMix, StreamConfig,
};
use crate::{Cluster, CostModel};

use super::TablePrinter;

const FULL_N: usize = 8_000;
const QUICK_N: usize = 2_000;
const GRAPH_K: usize = 6;
const FULL_QUERIES: usize = 64;
const QUICK_QUERIES: usize = 24;
const ARRIVALS_PER_TICK: usize = 2;
const ZIPF_S: f64 = 1.5;

fn mutation_cfg(quick: bool) -> MutationConfig {
    MutationConfig {
        batches: if quick { 4 } else { 8 },
        ops_per_batch: if quick { 8 } else { 16 },
        insert_pct: 60,
        zipf_s: 1.2,
        start_tick: 2,
        every_ticks: 6,
    }
}

/// Result of one `repro trace` invocation (consumed by main/tests).
pub struct TraceSummary {
    /// Machine counts compared (the requested P, plus 1 when distinct).
    pub legs: Vec<usize>,
    /// Events recorded on the requested backend at the requested P.
    pub events: u64,
    pub superstep_events: u64,
    pub waves: u64,
    /// Mutation-apply (epoch bump) events.
    pub epoch_bumps: u64,
    pub served: usize,
    pub rejected: u64,
    /// Legs whose sim/backend deterministic streams diverged.
    pub divergences: usize,
    /// Legs whose served result bits differed between the two runs.
    pub bit_mismatches: usize,
    /// Recorder-vs-report consistency failures across all legs.
    pub consistency_failures: usize,
    /// Ring-buffer drops across all recorders (must be 0).
    pub dropped: u64,
    /// Ingestion passes (must equal the number of legs — one per P).
    pub ingestions: u64,
    pub all_valid: bool,
}

/// Deterministic-stream side counts, folded from one recorder.
#[derive(Default)]
struct StreamStats {
    events: u64,
    supersteps: u64,
    admits: u64,
    rejects: u64,
    rejects_by_kind: [u64; 5],
    max_admit_depth: usize,
    hits: u64,
    misses: u64,
    waves: u64,
    wave_lanes: u64,
    mutation_applies: u64,
    last_epoch_after: u64,
    completes: u64,
}

fn stats_of(rec: &FlightRecorder) -> StreamStats {
    let mut s = StreamStats { events: rec.recorded(), ..StreamStats::default() };
    for e in rec.events() {
        match &e.kind {
            EventKind::Superstep { .. } => s.supersteps += 1,
            EventKind::Admit { queue_depth, .. } => {
                s.admits += 1;
                s.max_admit_depth = s.max_admit_depth.max(*queue_depth);
            }
            EventKind::Reject { kind, .. } => {
                s.rejects += 1;
                s.rejects_by_kind[kind.index()] += 1;
            }
            EventKind::CacheHit { .. } => s.hits += 1,
            EventKind::CacheMiss { .. } => s.misses += 1,
            EventKind::WaveDispatch { lanes, .. } => {
                s.waves += 1;
                s.wave_lanes += *lanes as u64;
            }
            EventKind::MutationApply { epoch_after, .. } => {
                s.mutation_applies += 1;
                s.last_epoch_after = *epoch_after;
            }
            EventKind::QueryComplete { .. } => s.completes += 1,
            // No placement controller in this workload — counted nowhere,
            // and `consistency_failures` never expects one.
            EventKind::PlacementApply { .. } => {}
            EventKind::BatchClose { .. } => {}
        }
    }
    s
}

/// The recorder must narrate exactly the run the report summarizes.
/// Returns the number of violated invariants (0 = consistent).
fn consistency_failures(leg: usize, rec: &FlightRecorder, report: &ServeReport) -> usize {
    let s = stats_of(rec);
    let mut failures = 0usize;
    let mut check = |ok: bool, what: &str| {
        if !ok {
            failures += 1;
            eprintln!("INCONSISTENT (P={leg}): {what}");
        }
    };
    check(s.admits == report.served() as u64, "admit events != served queries");
    check(s.completes == report.served() as u64, "complete events != served queries");
    check(s.rejects == report.rejected, "reject events != rejected total");
    check(
        s.rejects_by_kind == report.rejected_by_kind,
        "per-kind reject events != rejected_by_kind",
    );
    check(
        s.max_admit_depth == report.max_queue_depth,
        "deepest recorded admission != max_queue_depth",
    );
    check(s.hits == report.cache_hits, "cache-hit events != cache_hits");
    check(s.misses == report.cache_misses, "cache-miss events != cache_misses");
    check(s.waves == report.waves.len() as u64, "wave events != wave records");
    check(s.wave_lanes == report.cache_misses, "total wave lanes != cache_misses");
    check(
        s.mutation_applies == report.mutations.len() as u64,
        "mutation events != mutation records",
    );
    check(
        s.mutation_applies == 0 || s.last_epoch_after == report.graph_epoch,
        "last epoch bump != final graph_epoch",
    );
    check(rec.dropped() == 0, "ring buffer dropped events (capacity too small)");
    failures
}

/// One recorded serving run on one substrate: build the engine from the
/// shared ingestion, attach a fresh recorder to both layers, serve the
/// mutating workload.
fn run_leg<B: Substrate>(
    sub: B,
    dg: DistGraph,
    cost: CostModel,
    label: &str,
    serve_cfg: ServeConfig,
    stream: &[Query],
    batches: &[MutationBatch],
) -> (ServeReport, ObserverHandle) {
    let rec = FlightRecorder::shared(crate::obs::trace::DEFAULT_CAPACITY);
    let mut server = Server::new(
        SpmdEngine::from_ingested(sub, dg, cost, Flags::tdo_gp(), label, QueryShard::new),
        serve_cfg,
    )
    .with_serving_policy(ServePolicy::new().with_fuse(true).with_cache(true));
    server.set_recorder(Some(rec.clone()));
    let mut feed = MutationFeed::new(batches.to_vec());
    let report = server.serve(&mut OpenLoopSource::new(stream), RunOpts::new().feed(&mut feed));
    (report, rec)
}

/// Served results must be bit-identical between the two runs of a leg
/// (same ids, same bits, same deterministic stamps).
fn report_bits_match(a: &ServeReport, b: &ServeReport) -> bool {
    a.results.len() == b.results.len()
        && a.results.iter().zip(&b.results).all(|(x, y)| {
            x.id == y.id
                && x.bits == y.bits
                && x.wait_ticks == y.wait_ticks
                && x.service_ticks == y.service_ticks
                && x.batch == y.batch
                && x.graph_epoch == y.graph_epoch
                && x.cached == y.cached
        })
}

pub fn run_trace(p: usize, seed: u64, backend: &str, quick: bool, out_dir: &str) -> TraceSummary {
    assert!(p >= 1, "need at least one machine");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let n = if quick { QUICK_N } else { FULL_N };
    let queries = if quick { QUICK_QUERIES } else { FULL_QUERIES };
    let g = gen::barabasi_albert(n, GRAPH_K, seed);
    let mcfg = mutation_cfg(quick);
    let legs: Vec<usize> = if p == 1 { vec![1] } else { vec![p, 1] };
    println!(
        "\n## repro trace — deterministic flight recorder, sim vs {backend}: BA graph n={} \
         m={}, P∈{legs:?}, {queries} queries (fuse+cache ON), {} delta batches × {} ops, \
         seed {seed}\n",
        g.n,
        g.m(),
        mcfg.batches,
        mcfg.ops_per_batch,
    );

    let serve_cfg = ServeConfig { batch: 4, ..ServeConfig::default() };
    let mut stream: Vec<Query> = Vec::new();
    let mut batches: Vec<MutationBatch> = Vec::new();

    let mut divergences = 0usize;
    let mut bit_mismatches = 0usize;
    let mut consistency = 0usize;
    let mut dropped = 0u64;
    let mut headline: Option<(StreamStats, ServeReport, ObserverHandle)> = None;

    let t = TablePrinter::new(
        &["P", "events", "supersteps", "waves", "epoch bumps", "served", "rejected", "stream"],
        &[3, 7, 10, 5, 11, 6, 8, 10],
    );
    for (i, &pp) in legs.iter().enumerate() {
        let dg = ingest_once(&g, pp, cost, Placement::Spread);
        if i == 0 {
            // The stream/feed are P-independent (hot order is a degree
            // property of the graph); built once from the first leg.
            let hot = hot_source_order(&dg.out_deg);
            stream = generate_stream(
                StreamConfig {
                    queries,
                    per_tick: ARRIVALS_PER_TICK,
                    every_ticks: 1,
                    zipf_s: ZIPF_S,
                    mix: QueryMix::balanced(),
                },
                &hot,
                seed,
            );
            batches = generate_mutations(mcfg, &g, &hot, seed.wrapping_add(1));
        }
        // Leg reference: always the simulator.  The comparison run is
        // the requested backend — or a second, independent sim run when
        // `--backend sim`, which pins run-to-run determinism.
        let (report_a, rec_a) = run_leg(
            Cluster::new(pp, cost),
            dg.clone(),
            cost,
            "trace-sim",
            serve_cfg,
            &stream,
            &batches,
        );
        let (report_b, rec_b) = if backend == "threaded" {
            run_leg(
                ThreadedCluster::new(pp),
                dg,
                cost,
                "trace-threaded",
                serve_cfg,
                &stream,
                &batches,
            )
        } else {
            run_leg(Cluster::new(pp, cost), dg, cost, "trace-sim-2", serve_cfg, &stream, &batches)
        };

        let (stream_a, stream_b) = {
            let (ra, rb) = (rec_a.lock().unwrap(), rec_b.lock().unwrap());
            dropped += ra.dropped() + rb.dropped();
            (ra.det_stream(), rb.det_stream())
        };
        let verdict = match first_divergence(&stream_a, &stream_b) {
            None => "identical".to_string(),
            Some((i, la, lb)) => {
                divergences += 1;
                eprintln!("DIVERGENCE (P={pp}) at event {i}:\n  sim:      {la}\n  {backend}: {lb}");
                format!("DIVERGED@{i}")
            }
        };
        if !report_bits_match(&report_a, &report_b) {
            bit_mismatches += 1;
            eprintln!("MISMATCH (P={pp}): served results differ between the two runs");
        }
        {
            let rb = rec_b.lock().unwrap();
            consistency += consistency_failures(pp, &rb, &report_b);
        }
        let s = stats_of(&rec_b.lock().unwrap());
        t.row(&[
            pp.to_string(),
            s.events.to_string(),
            s.supersteps.to_string(),
            s.waves.to_string(),
            s.mutation_applies.to_string(),
            report_b.served().to_string(),
            report_b.rejected.to_string(),
            verdict,
        ]);
        if i == 0 {
            headline = Some((s, report_b, rec_b));
        }
    }
    let ingestions_used = ingestions() - ing0;
    let (stats, report, recorder) = headline.expect("at least one leg ran");

    // ---- artifacts: Chrome trace + heatmap from the requested-P run
    //      on the requested backend ----
    let mut artifacts_ok = true;
    let trace_path = Path::new(out_dir).join("trace.json");
    let heatmap_path = Path::new(out_dir).join("heatmap.txt");
    let heatmap = {
        let rec = recorder.lock().unwrap();
        let json = chrome_trace_json(&rec);
        let heatmap = heatmap_table(&rec);
        if let Err(e) = fs::create_dir_all(out_dir)
            .and_then(|_| fs::write(&trace_path, &json))
            .and_then(|_| fs::write(&heatmap_path, &heatmap))
        {
            artifacts_ok = false;
            eprintln!("FAILED to write trace artifacts under {out_dir}: {e}");
        }
        heatmap
    };
    println!("\nper-(superstep, machine) work/words heatmap (head):");
    for line in heatmap.lines().take(10) {
        println!("  {line}");
    }
    println!(
        "\nartifacts: {} (load in chrome://tracing or ui.perfetto.dev) and {}",
        trace_path.display(),
        heatmap_path.display(),
    );
    println!(
        "overall: {} events on the headline leg ({} supersteps, {} waves, {} cache hits / \
         {} misses, {} epoch bumps); max queue depth {}; {} ingestions for {} legs",
        stats.events,
        stats.supersteps,
        stats.waves,
        report.cache_hits,
        report.cache_misses,
        stats.mutation_applies,
        report.max_queue_depth,
        ingestions_used,
        legs.len(),
    );

    let all_valid = divergences == 0
        && bit_mismatches == 0
        && consistency == 0
        && dropped == 0
        && ingestions_used == legs.len() as u64
        && artifacts_ok;
    println!(
        "\ntrace {}",
        if all_valid {
            "OK (deterministic event streams bit-identical across backends at every P)"
        } else {
            "FAILED"
        }
    );
    TraceSummary {
        legs,
        events: stats.events,
        superstep_events: stats.supersteps,
        waves: stats.waves,
        epoch_bumps: stats.mutation_applies,
        served: report.served(),
        rejected: report.rejected,
        divergences,
        bit_mismatches,
        consistency_failures: consistency,
        dropped,
        ingestions: ingestions_used,
        all_valid,
    }
}

/// Backend-independent trace summary counters for the bench snapshot's
/// deterministic objects: tiny sim-only key points (events / superstep
/// events / waves / epoch bumps / served / rejected per P), checkable
/// today without a toolchain refresh because every quantity is a pure
/// function of (graph, config, seed, P).
pub fn trace_det_json() -> String {
    const N: usize = 1_000;
    const QUERIES: usize = 16;
    const SEED: u64 = 7;
    let cost = CostModel::paper_cluster();
    let g = gen::barabasi_albert(N, GRAPH_K, SEED);
    let mcfg = MutationConfig {
        batches: 2,
        ops_per_batch: 8,
        insert_pct: 60,
        zipf_s: 1.2,
        start_tick: 2,
        every_ticks: 6,
    };
    let serve_cfg = ServeConfig { batch: 4, ..ServeConfig::default() };
    let mut points = Vec::new();
    for p in [2usize, 8] {
        let dg = ingest_once(&g, p, cost, Placement::Spread);
        let hot = hot_source_order(&dg.out_deg);
        let stream = generate_stream(
            StreamConfig {
                queries: QUERIES,
                per_tick: ARRIVALS_PER_TICK,
                every_ticks: 1,
                zipf_s: ZIPF_S,
                mix: QueryMix::balanced(),
            },
            &hot,
            SEED,
        );
        let batches = generate_mutations(mcfg, &g, &hot, SEED.wrapping_add(1));
        let (report, rec) = run_leg(
            Cluster::new(p, cost),
            dg,
            cost,
            "trace-bench",
            serve_cfg,
            &stream,
            &batches,
        );
        let s = stats_of(&rec.lock().unwrap());
        points.push(format!(
            "{{\"label\":\"trace-p{p}\",\"events\":{},\"superstep_events\":{},\"waves\":{},\
             \"epoch_bumps\":{},\"served\":{},\"rejected\":{}}}",
            s.events,
            s.supersteps,
            s.waves,
            s.mutation_applies,
            report.served(),
            report.rejected,
        ));
    }
    format!("{{\"points\":[{}]}}", points.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_trace_sim_quick_is_valid() {
        let dir = std::env::temp_dir().join("tdorch-repro-trace-test");
        let s = run_trace(2, 7, "sim", true, dir.to_str().expect("utf8 temp path"));
        assert_eq!(s.divergences, 0, "two sim runs must produce one stream");
        assert_eq!(s.bit_mismatches, 0);
        assert_eq!(s.consistency_failures, 0);
        assert_eq!(s.dropped, 0);
        assert_eq!(s.legs, vec![2, 1]);
        assert_eq!(s.ingestions, 2, "one ingestion per leg");
        assert!(s.superstep_events > 0, "substrate events must flow");
        assert!(s.waves > 0, "serving events must flow");
        assert!(s.epoch_bumps > 0, "mutation events must flow");
        assert!(s.all_valid);
        let trace = std::fs::read_to_string(dir.join("trace.json")).expect("artifact written");
        assert!(trace.starts_with("{\"traceEvents\":["));
        let heatmap = std::fs::read_to_string(dir.join("heatmap.txt")).expect("artifact written");
        assert!(heatmap.contains("imbalance"));
    }

    #[test]
    fn trace_det_points_are_stable_across_runs() {
        let a = trace_det_json();
        let b = trace_det_json();
        assert_eq!(a, b, "trace det points must be a pure function of the inputs");
        assert!(a.contains("\"label\":\"trace-p2\""));
        assert!(a.contains("\"label\":\"trace-p8\""));
        assert!(!a.contains("null"));
    }
}
