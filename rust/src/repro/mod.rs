//! Reproduction harness: one entry point per table/figure of the paper's
//! evaluation (see DESIGN.md §4 for the experiment index).  Each function
//! prints the same rows/series the paper reports, from runs on the BSP
//! substrate, and returns the raw numbers for benches/tests.

pub mod bench_snapshot;
pub mod exec;
pub mod graphs;
pub mod kv;
pub mod loadcurve;
pub mod mutate;
pub mod placement;
pub mod profile;
pub mod serve;
pub mod trace;

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Markdown-ish table printer.
pub struct TablePrinter {
    widths: Vec<usize>,
}

impl TablePrinter {
    pub fn new(headers: &[&str], widths: &[usize]) -> Self {
        let widths: Vec<usize> = headers
            .iter()
            .zip(widths)
            .map(|(h, w)| (*w).max(h.len()))
            .collect();
        let mut line = String::from("|");
        for (h, w) in headers.iter().zip(&widths) {
            line.push_str(&format!(" {h:<w$} |"));
        }
        println!("{line}");
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<1$}|", "", w + 2));
        }
        println!("{sep}");
        TablePrinter { widths }
    }

    pub fn row(&self, cells: &[String]) {
        let mut line = String::from("|");
        for (c, w) in cells.iter().zip(&self.widths) {
            line.push_str(&format!(" {c:<w$} |"));
        }
        println!("{line}");
    }
}

/// Format simulated seconds like the paper (3 significant-ish digits).
pub fn fmt_s(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[4.0, 1.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_s(123.4), "123");
        assert_eq!(fmt_s(1.234), "1.23");
        assert_eq!(fmt_s(0.01234), "0.0123");
    }
}
