//! `repro loadcurve` — latency vs offered load for the serving layer.
//!
//! The serving claims of hotspot-aware balancers are judged on exactly
//! one picture (AutoFlow, arXiv:2103.08888; DPA, arXiv:2308.00938): sweep
//! offered load, plot goodput / rejection / latency percentiles per
//! point.  This driver produces that picture twice, on the pipelined
//! server ([`crate::serve::Server::serve`]):
//!
//! * **open-loop sweep** — fixed-rate Zipf streams at increasing
//!   queries-per-tick ([`StreamConfig::every_ticks`] expresses the
//!   underloaded sub-1/tick end); past saturation the bounded queue
//!   sheds load, so the rejection rate must be **nondecreasing in the
//!   offered rate** (asserted in `--quick` mode — the CI gate);
//! * **closed-loop sweep** — client populations of increasing size
//!   ([`crate::workload::ClosedLoop`]), the self-throttling regime where
//!   latency, not shedding, absorbs the pressure (a population no larger
//!   than the queue cap can never be shed — at most one outstanding
//!   query per client).
//!
//! Every point is also a correctness gate: each served query is replayed
//! single-shot on a sim-backend reference engine (walked in reverse
//! dispatch order, so cross-query leaks meet a different predecessor and
//! cannot cancel) and must match **bit for bit**; the whole sweep must
//! perform exactly ONE ingestion ([`crate::graph::ingest::ingestions`]).
//!
//! Because queueing runs on the logical service clock, every
//! deterministic column of the report (offered, served, rejected, ticks,
//! wait/service-tick percentiles, goodput/tick) is identical across
//! backends and hosts; wall-clock columns (ms percentiles, goodput/sec,
//! pool busy fraction) annotate the run and vary with the machine.
//!
//! After the sweeps, a **fusion A/B stage** replays the top open-loop
//! rate twice on the same server — fusion+cache OFF, then ON
//! ([`crate::serve::Server::set_serving_policy`]; the ON run starts
//! with a cold cache) — with both runs bit-checked against the reference.  In
//! `--quick` the ON run must *strictly* beat the OFF run's goodput per
//! tick and hit the cache at least once, which is how "a served batch
//! costs about one engine pass" becomes a CI-enforced claim rather than
//! a narrative.  The main sweeps themselves keep both knobs off, so
//! their dynamics (and the rejection-monotonicity gate) stay comparable
//! across releases.
//!
//! The per-point results are written as a machine-readable JSON report
//! (`--out`, default `target/loadcurve/loadcurve.json`; schema
//! `tdorch.loadcurve.v3`, which added per-point `cache_hits` /
//! `cache_misses` / `hit_rate` and the top-level `fusion_compare`
//! object; v2 added the per-point `graph_epoch` — constant 0 for these
//! mutation-free sweeps) that the CI release legs upload as a build
//! artifact — the perf trajectory of every commit is downloadable.

use crate::exec::{PoolSnapshot, Substrate, ThreadedCluster};
use crate::graph::flags::Flags;
use crate::graph::gen;
use crate::graph::ingest::ingestions;
use crate::graph::spmd::{ingest_once, Placement, SpmdEngine};
use crate::graph::{Graph, Vid};
use crate::metrics::LatencySummary;
use crate::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use crate::workload::{
    generate_stream, hot_source_order, ArrivalSource, ClosedLoop, ClosedLoopConfig,
    OpenLoopSource, Query, QueryMix, StreamConfig,
};
use crate::{Cluster, CostModel};

use super::TablePrinter;

/// Graph sizes: the full sweep uses the serving graph; `--quick` shrinks
/// it so the CI gate stays a smoke, not a soak.
const FULL_N: usize = 8_000;
const QUICK_N: usize = 2_000;
const GRAPH_K: usize = 6;

/// Queries per open-loop point.
const FULL_QUERIES: usize = 64;
const QUICK_QUERIES: usize = 32;

/// Open-loop offered rates as (per_tick, every_ticks) — ascending
/// offered load; the quick triple spans under- to heavily-overloaded by
/// 4x–16x steps so the nondecreasing-rejection assertion is structural,
/// not a knife edge.
const FULL_RATES: [(usize, u64); 7] =
    [(1, 16), (1, 8), (1, 4), (1, 2), (1, 1), (2, 1), (4, 1)];
const QUICK_RATES: [(usize, u64); 3] = [(1, 16), (1, 4), (4, 1)];

/// Closed-loop population sizes.
const FULL_CLIENTS: [usize; 6] = [1, 2, 4, 8, 16, 32];
const QUICK_CLIENTS: [usize; 2] = [2, 8];
const THINK_TICKS: u64 = 4;
const FULL_PER_CLIENT: usize = 4;
const QUICK_PER_CLIENT: usize = 8;

fn serve_cfg() -> ServeConfig {
    // A tight queue (cap 8) so the overloaded end of the sweep actually
    // sheds; everything else at the serving defaults.
    ServeConfig { batch: 4, queue_cap: 8, ..ServeConfig::default() }
}

/// One sweep point, fully evaluated.
#[derive(Clone)]
pub struct CurvePoint {
    pub label: String,
    /// Configured offered rate, queries/tick (NaN for closed-loop
    /// points: a closed loop self-paces, so its offered rate is an
    /// outcome, not a knob).
    pub offered_rate_cfg: f64,
    /// Closed-loop population size (None for open-loop points).
    pub clients: Option<usize>,
    /// What the generator was configured to offer (stream length /
    /// `clients * queries_per_client`) — compared against
    /// served + rejected, so a query the server loses outright is
    /// caught (served + rejected == `offered` is true by construction
    /// and catches nothing).
    pub expected_offered: u64,
    /// Achieved offered rate over the run's span, queries/tick — for a
    /// closed loop this is an *outcome* (the population self-paces), so
    /// it is the number to read where `offered_rate_cfg` is null.
    pub offered_rate_achieved: f64,
    pub offered: u64,
    pub served: u64,
    pub rejected: u64,
    pub rejection_rate: f64,
    pub goodput_per_tick: f64,
    pub ticks: u64,
    pub wait_ticks: LatencySummary,
    pub service_ticks: LatencySummary,
    /// End-to-end logical latency (queue wait + service) — the y-axis a
    /// latency-vs-offered-load curve is actually judged on.
    pub sojourn_ticks: LatencySummary,
    pub service_ms: LatencySummary,
    pub wall_ms: f64,
    pub goodput_qps: f64,
    /// Worker-pool busy fraction over the point's wall-clock window
    /// (NaN on the sim backend — there is no pool).
    pub pool_busy_fraction: f64,
    pub mismatches: u64,
    /// Engine epoch when the point finished — constant 0 here (the
    /// sweeps are mutation-free), present so downstream tooling keys on
    /// the same field `repro mutate` runs populate.
    pub graph_epoch: u64,
    /// Queries served from the result cache (0 on the off-policy sweeps).
    pub cache_hits: u64,
    /// Queries served by engine execution (== served on the off-policy
    /// sweeps).
    pub cache_misses: u64,
}

impl CurvePoint {
    /// Fraction of served queries that were cache hits (NaN when the
    /// point served nothing).
    pub fn hit_rate(&self) -> f64 {
        if self.served == 0 {
            return f64::NAN;
        }
        self.cache_hits as f64 / self.served as f64
    }
}

/// The fusion A/B stage: the top open-loop rate served twice on one
/// server — policies off, then on (cold cache).
pub struct FusionCompare {
    pub off: CurvePoint,
    pub on: CurvePoint,
}

impl FusionCompare {
    /// Goodput-per-tick ratio ON/OFF (the amortization factor the
    /// tentpole claims; > 1 means fusion+memoization paid off).
    pub fn goodput_gain(&self) -> f64 {
        self.on.goodput_per_tick / self.off.goodput_per_tick
    }

    pub fn strictly_faster(&self) -> bool {
        self.on.goodput_per_tick > self.off.goodput_per_tick
    }

    pub fn nonzero_hits(&self) -> bool {
        self.on.cache_hits > 0
    }
}

/// Result of one `repro loadcurve` invocation (consumed by main/tests).
pub struct LoadCurveSummary {
    pub open: Vec<CurvePoint>,
    pub closed: Vec<CurvePoint>,
    pub mismatches: u64,
    pub ingestions: u64,
    /// Open-loop rejection rate nondecreasing in offered load.
    pub monotone: bool,
    /// The fusion A/B stage at the top open-loop rate.
    pub fusion: FusionCompare,
    pub all_valid: bool,
    pub json_path: Option<String>,
}

/// Run one point on the server; returns the report and the pool busy
/// fraction over the point's wall-clock window (`snap` yields None on
/// backends without a pool).
fn run_point<B: Substrate>(
    server: &mut Server<B>,
    source: &mut dyn ArrivalSource,
    snap: &dyn Fn(&B) -> Option<PoolSnapshot>,
) -> (ServeReport, f64) {
    let before = snap(server.engine().sub());
    let report = server.serve(source, RunOpts::default());
    let after = snap(server.engine().sub());
    let busy = match (before, after) {
        (Some(b), Some(a)) => {
            let p = server.engine().meta().p;
            a.since(b).busy_fraction((report.wall_ms * 1e6) as u64, p)
        }
        _ => f64::NAN,
    };
    (report, busy)
}

/// Replay every served query single-shot on the sim reference, in
/// reverse dispatch order; count bitwise divergences.
fn cross_check(
    reference: &mut Server<Cluster>,
    report: &ServeReport,
    queries_of: &dyn Fn(u64) -> Query,
    label: &str,
) -> u64 {
    let mut mismatches = 0u64;
    for r in report.results.iter().rev() {
        let q = queries_of(r.id);
        debug_assert_eq!(q.id, r.id, "query ids must be positional");
        if reference.run_query(&q) != r.bits {
            mismatches += 1;
            eprintln!(
                "MISMATCH: {label}: query {} ({}) diverged from the sim single-shot reference",
                r.id,
                r.kind.label()
            );
        }
    }
    mismatches
}

fn fold_point(
    label: String,
    offered_rate_cfg: f64,
    clients: Option<usize>,
    expected_offered: u64,
    report: &ServeReport,
    pool_busy_fraction: f64,
    mismatches: u64,
) -> CurvePoint {
    let waits: Vec<f64> = report.results.iter().map(|r| r.wait_ticks as f64).collect();
    let svc_t: Vec<f64> = report.results.iter().map(|r| r.service_ticks as f64).collect();
    let sojourn: Vec<f64> = report.results.iter().map(|r| r.sojourn_ticks() as f64).collect();
    let svc_ms: Vec<f64> = report.results.iter().map(|r| r.service_ms).collect();
    CurvePoint {
        label,
        offered_rate_cfg,
        clients,
        expected_offered,
        offered_rate_achieved: report.offered_per_tick(),
        offered: report.offered(),
        served: report.served() as u64,
        rejected: report.rejected,
        rejection_rate: report.rejection_rate(),
        goodput_per_tick: report.goodput_per_tick(),
        ticks: report.ticks,
        wait_ticks: LatencySummary::of(&waits),
        service_ticks: LatencySummary::of(&svc_t),
        sojourn_ticks: LatencySummary::of(&sojourn),
        service_ms: LatencySummary::of(&svc_ms),
        wall_ms: report.wall_ms,
        goodput_qps: report.goodput_qps(),
        pool_busy_fraction,
        mismatches,
        graph_epoch: report.graph_epoch,
        cache_hits: report.cache_hits,
        cache_misses: report.cache_misses,
    }
}

/// A/B the serving policies on ONE server at the top open-loop rate:
/// the same stream served with fusion+cache off, then on
/// ([`Server::set_serving_policy`] clears the cache, so the ON run
/// starts cold).  Both runs are bit-checked against the single-shot
/// reference; the policies are restored to off afterwards.
fn fusion_compare<B: Substrate>(
    server: &mut Server<B>,
    reference: &mut Server<Cluster>,
    hot: &[Vid],
    seed: u64,
    quick: bool,
    snap: &dyn Fn(&B) -> Option<PoolSnapshot>,
) -> FusionCompare {
    let rates: &[(usize, u64)] = if quick { &QUICK_RATES } else { &FULL_RATES };
    let &(per_tick, every_ticks) = rates.last().expect("nonempty rate table");
    let cfg = StreamConfig {
        queries: if quick { QUICK_QUERIES } else { FULL_QUERIES },
        per_tick,
        every_ticks,
        zipf_s: 1.5,
        mix: QueryMix::balanced(),
    };
    let stream = generate_stream(cfg, hot, seed);
    let mut run = |fuse: bool, cache: bool, tag: &str| {
        server.set_serving_policy(ServePolicy::new().with_fuse(fuse).with_cache(cache));
        let label = format!("fusion:{tag}@{:.4}/tick", cfg.offered_per_tick());
        let (report, busy) = run_point(server, &mut OpenLoopSource::new(&stream), snap);
        let mismatches = cross_check(reference, &report, &|id| stream[id as usize], &label);
        fold_point(
            label,
            cfg.offered_per_tick(),
            None,
            stream.len() as u64,
            &report,
            busy,
            mismatches,
        )
    };
    let off = run(false, false, "off");
    let on = run(true, true, "on");
    server.set_serving_policy(ServePolicy::default());
    FusionCompare { off, on }
}

/// Run both sweeps on `server` (generic over backend; `snap` extracts a
/// pool snapshot where one exists).
fn sweep<B: Substrate>(
    server: &mut Server<B>,
    reference: &mut Server<Cluster>,
    hot: &[Vid],
    seed: u64,
    quick: bool,
    snap: &dyn Fn(&B) -> Option<PoolSnapshot>,
) -> (Vec<CurvePoint>, Vec<CurvePoint>) {
    let rates: &[(usize, u64)] = if quick { &QUICK_RATES } else { &FULL_RATES };
    let queries = if quick { QUICK_QUERIES } else { FULL_QUERIES };
    let mut open = Vec::new();
    for &(per_tick, every_ticks) in rates {
        let cfg = StreamConfig {
            queries,
            per_tick,
            every_ticks,
            zipf_s: 1.5,
            mix: QueryMix::balanced(),
        };
        let stream = generate_stream(cfg, hot, seed);
        let label = format!("open:{:.4}/tick", cfg.offered_per_tick());
        let (report, busy) = run_point(server, &mut OpenLoopSource::new(&stream), snap);
        let mismatches =
            cross_check(reference, &report, &|id| stream[id as usize], &label);
        open.push(fold_point(
            label,
            cfg.offered_per_tick(),
            None,
            stream.len() as u64,
            &report,
            busy,
            mismatches,
        ));
    }
    let populations: &[usize] = if quick { &QUICK_CLIENTS } else { &FULL_CLIENTS };
    let per_client = if quick { QUICK_PER_CLIENT } else { FULL_PER_CLIENT };
    let mut closed = Vec::new();
    for &clients in populations {
        let mut source = ClosedLoop::new(
            ClosedLoopConfig {
                clients,
                think_ticks: THINK_TICKS,
                queries_per_client: per_client,
                zipf_s: 1.5,
                mix: QueryMix::balanced(),
            },
            hot,
            seed,
        );
        let label = format!("closed:{clients}c");
        let (report, busy) = run_point(server, &mut source, snap);
        debug_assert_eq!(source.emitted().len() as u64, source.offered_total());
        // The closed loop materializes its queries as it runs, so the
        // cross-check replays from the emitted log.
        let mismatches =
            cross_check(reference, &report, &|id| source.emitted()[id as usize], &label);
        closed.push(fold_point(
            label,
            f64::NAN,
            Some(clients),
            source.offered_total(),
            &report,
            busy,
            mismatches,
        ));
    }
    (open, closed)
}

// ---- JSON (hand-rolled: the offline crate carries zero deps) ----

/// A finite f64 as a JSON number, NaN/inf as `null`.
fn jnum(x: f64) -> String {
    if x.is_finite() {
        x.to_string()
    } else {
        "null".to_string()
    }
}

fn jlat(l: &LatencySummary) -> String {
    format!(
        "{{\"p50\":{},\"p95\":{},\"p99\":{}}}",
        jnum(l.p50),
        jnum(l.p95),
        jnum(l.p99)
    )
}

fn jpoint(pt: &CurvePoint) -> String {
    let clients = match pt.clients {
        Some(c) => c.to_string(),
        None => "null".to_string(),
    };
    format!(
        "{{\"label\":\"{}\",\"offered_rate_cfg\":{},\"offered_rate_achieved\":{},\
         \"clients\":{},\"expected_offered\":{},\"offered\":{},\
         \"served\":{},\"rejected\":{},\"rejection_rate\":{},\"goodput_per_tick\":{},\
         \"ticks\":{},\"graph_epoch\":{},\"cache_hits\":{},\"cache_misses\":{},\
         \"hit_rate\":{},\"wait_ticks\":{},\"service_ticks\":{},\
         \"sojourn_ticks\":{},\"service_ms\":{},\
         \"wall_ms\":{},\"goodput_qps\":{},\"pool_busy_fraction\":{},\"mismatches\":{}}}",
        pt.label,
        jnum(pt.offered_rate_cfg),
        jnum(pt.offered_rate_achieved),
        clients,
        pt.expected_offered,
        pt.offered,
        pt.served,
        pt.rejected,
        jnum(pt.rejection_rate),
        jnum(pt.goodput_per_tick),
        pt.ticks,
        pt.graph_epoch,
        pt.cache_hits,
        pt.cache_misses,
        jnum(pt.hit_rate()),
        jlat(&pt.wait_ticks),
        jlat(&pt.service_ticks),
        jlat(&pt.sojourn_ticks),
        jlat(&pt.service_ms),
        jnum(pt.wall_ms),
        jnum(pt.goodput_qps),
        jnum(pt.pool_busy_fraction),
        pt.mismatches,
    )
}

fn json_report(
    g: &Graph,
    p: usize,
    seed: u64,
    backend: &str,
    quick: bool,
    open: &[CurvePoint],
    closed: &[CurvePoint],
    fusion: &FusionCompare,
) -> String {
    let open_json: Vec<String> = open.iter().map(jpoint).collect();
    let closed_json: Vec<String> = closed.iter().map(jpoint).collect();
    format!(
        "{{\"schema\":\"tdorch.loadcurve.v3\",\"graph\":{{\"n\":{},\"m\":{},\
         \"seed\":{seed}}},\"p\":{p},\"backend\":\"{backend}\",\"quick\":{quick},\
         \"supersteps_per_tick\":{},\"open_loop\":[{}],\"closed_loop\":[{}],\
         \"fusion_compare\":{{\"off\":{},\"on\":{},\"goodput_gain\":{},\
         \"strictly_faster\":{},\"nonzero_hits\":{}}}}}\n",
        g.n,
        g.m(),
        serve_cfg().supersteps_per_tick,
        open_json.join(","),
        closed_json.join(","),
        jpoint(&fusion.off),
        jpoint(&fusion.on),
        jnum(fusion.goodput_gain()),
        fusion.strictly_faster(),
        fusion.nonzero_hits(),
    )
}

fn print_curve(title: &str, points: &[CurvePoint]) {
    println!("\n### {title}");
    let t = TablePrinter::new(
        &[
            "point",
            "offered",
            "served",
            "rej",
            "rej.rate",
            "goodput/tick",
            "wait p50/p95/p99",
            "svc p50/p99 (ticks)",
            "busy",
        ],
        &[14, 7, 6, 4, 8, 12, 17, 19, 5],
    );
    for pt in points {
        let busy = if pt.pool_busy_fraction.is_finite() {
            format!("{:.2}", pt.pool_busy_fraction)
        } else {
            "-".to_string()
        };
        t.row(&[
            pt.label.clone(),
            pt.offered.to_string(),
            pt.served.to_string(),
            pt.rejected.to_string(),
            format!("{:.3}", pt.rejection_rate),
            format!("{:.4}", pt.goodput_per_tick),
            format!(
                "{:.0} / {:.0} / {:.0}",
                pt.wait_ticks.p50, pt.wait_ticks.p95, pt.wait_ticks.p99
            ),
            format!("{:.0} / {:.0}", pt.service_ticks.p50, pt.service_ticks.p99),
            busy,
        ]);
    }
}

pub fn run_loadcurve(
    p: usize,
    seed: u64,
    backend: &str,
    quick: bool,
    out: &str,
) -> LoadCurveSummary {
    assert!(p >= 1, "need at least one machine");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let n = if quick { QUICK_N } else { FULL_N };
    let g = gen::barabasi_albert(n, GRAPH_K, seed);
    println!(
        "\n## repro loadcurve — latency vs offered load on the pipelined server: \
         BA graph n={} m={}, P={p}, seed {seed}, backend {backend}{}",
        g.n,
        g.m(),
        if quick { ", --quick (CI gate)" } else { "" }
    );

    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let mut reference = Server::new(
        SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "loadcurve-sim-ref",
            QueryShard::new,
        ),
        serve_cfg(),
    );
    let hot = hot_source_order(&reference.engine().meta().out_deg);

    let (open, closed, fusion) = if backend == "threaded" {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg,
                cost,
                Flags::tdo_gp(),
                "loadcurve-threaded",
                QueryShard::new,
            ),
            serve_cfg(),
        );
        let snap = |tc: &ThreadedCluster| Some(tc.snapshot());
        let (open, closed) = sweep(&mut server, &mut reference, &hot, seed, quick, &snap);
        let fusion = fusion_compare(&mut server, &mut reference, &hot, seed, quick, &snap);
        (open, closed, fusion)
    } else {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost),
                dg,
                cost,
                Flags::tdo_gp(),
                "loadcurve-sim",
                QueryShard::new,
            ),
            serve_cfg(),
        );
        let snap = |_: &Cluster| None;
        let (open, closed) = sweep(&mut server, &mut reference, &hot, seed, quick, &snap);
        let fusion = fusion_compare(&mut server, &mut reference, &hot, seed, quick, &snap);
        (open, closed, fusion)
    };

    print_curve("open loop (offered rate sweep)", &open);
    print_curve("closed loop (client population sweep)", &closed);
    print_curve(
        "fusion A/B (same stream, same server, policies off vs on)",
        &[fusion.off.clone(), fusion.on.clone()],
    );
    println!(
        "\nfusion A/B at the top rate: goodput {:.4} -> {:.4} queries/tick \
         (gain {:.2}x), ticks {} -> {}, {} cache hits / {} misses on the ON run",
        fusion.off.goodput_per_tick,
        fusion.on.goodput_per_tick,
        fusion.goodput_gain(),
        fusion.off.ticks,
        fusion.on.ticks,
        fusion.on.cache_hits,
        fusion.on.cache_misses,
    );

    let mismatches: u64 = open
        .iter()
        .chain(&closed)
        .chain([&fusion.off, &fusion.on])
        .map(|pt| pt.mismatches)
        .sum();
    let monotone = open
        .windows(2)
        .all(|w| w[0].rejection_rate <= w[1].rejection_rate);
    // Against the CONFIGURED load, not `pt.offered` (which is defined
    // as served + rejected): a query the server loses outright shrinks
    // served without raising rejected, and only this comparison sees it.
    let accounted = open
        .iter()
        .chain(&closed)
        .chain([&fusion.off, &fusion.on])
        .all(|pt| pt.served + pt.rejected == pt.expected_offered);
    let ingested = ingestions() - ing0;

    // ---- JSON artifact ----
    let json = json_report(&g, p, seed, backend, quick, &open, &closed, &fusion);
    let json_path = match write_report(out, &json) {
        Ok(()) => {
            println!("\nJSON report written to {out}");
            Some(out.to_string())
        }
        Err(e) => {
            eprintln!("could not write the JSON report to {out}: {e}");
            None
        }
    };

    // The quick sweep is the CI gate: rejection must be nondecreasing in
    // offered load (a server that sheds LESS when offered MORE is
    // broken), and the fusion+cache run must strictly out-serve the
    // plain run at the top rate with a nonzero hit rate; the full sweep
    // reports the curves without gating on them.
    let all_valid = mismatches == 0
        && ingested == 1
        && accounted
        && json_path.is_some()
        && (!quick || (monotone && fusion.strictly_faster() && fusion.nonzero_hits()));
    println!(
        "\nloadcurve {}",
        if all_valid {
            "OK (every served query bit-identical to the single-shot sim reference; \
             graph ingested once; rejection nondecreasing in offered load; fusion+cache \
             strictly out-serves the plain policy at the top rate)"
        } else {
            "FAILED"
        }
    );
    if !monotone {
        eprintln!(
            "rejection rate is NOT nondecreasing across the open-loop sweep: {:?}",
            open.iter().map(|pt| pt.rejection_rate).collect::<Vec<_>>()
        );
    }
    if !fusion.strictly_faster() {
        eprintln!(
            "fusion+cache did NOT strictly raise goodput/tick at the top rate: \
             off {:.4} vs on {:.4}",
            fusion.off.goodput_per_tick, fusion.on.goodput_per_tick
        );
    }
    if !fusion.nonzero_hits() {
        eprintln!("the Zipf stream produced zero cache hits — memoization never engaged");
    }
    if ingested != 1 {
        eprintln!("expected exactly one ingestion, counted {ingested}");
    }
    LoadCurveSummary {
        open,
        closed,
        mismatches,
        ingestions: ingested,
        monotone,
        fusion,
        all_valid,
        json_path,
    }
}

fn write_report(path: &str, json: &str) -> std::io::Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_loadcurve_sim_is_valid() {
        let dir = std::env::temp_dir().join("tdorch-loadcurve-test");
        let out = dir.join("loadcurve.json");
        let s = run_loadcurve(2, 7, "sim", true, out.to_str().unwrap());
        assert_eq!(s.mismatches, 0);
        assert_eq!(s.ingestions, 1);
        assert!(s.monotone, "rejection must be nondecreasing in offered load");
        assert!(s.all_valid);
        assert_eq!(s.open.len(), 3);
        assert_eq!(s.closed.len(), 2);
        // The overloaded end of the quick sweep must actually shed load.
        assert!(
            s.open.last().unwrap().rejected > 0,
            "4 q/tick against a cap-8 queue must reject"
        );
        // The fusion A/B gate: strictly better goodput with hits, bits
        // still clean on both runs.
        assert!(s.fusion.strictly_faster(), "fusion+cache must out-serve the plain policy");
        assert!(s.fusion.nonzero_hits(), "the Zipf stream must repeat at least one key");
        assert_eq!(s.fusion.off.cache_hits, 0, "the OFF run must not touch the cache");
        assert_eq!(
            s.fusion.on.served,
            s.fusion.on.cache_hits + s.fusion.on.cache_misses,
            "every served query is a hit or a miss"
        );
        let json = std::fs::read_to_string(&out).expect("report written");
        assert!(json.starts_with("{\"schema\":\"tdorch.loadcurve.v3\""));
        assert!(json.contains("\"open_loop\":["));
        assert!(json.contains("\"fusion_compare\":{\"off\":{"));
        assert!(json.contains("\"strictly_faster\":true"));
        assert!(json.contains("\"cache_hits\":"));
        assert!(
            json.contains("\"graph_epoch\":0"),
            "mutation-free sweeps report epoch 0 on every point"
        );
        assert!(json.contains("\"sojourn_ticks\":{\"p50\":"));
        assert!(json.contains("\"expected_offered\":32"), "open points offer 32 queries");
        assert!(!json.contains("NaN"), "NaN must serialize as null");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jnum_maps_non_finite_to_null() {
        assert_eq!(jnum(0.5), "0.5");
        assert_eq!(jnum(f64::NAN), "null");
        assert_eq!(jnum(f64::INFINITY), "null");
    }
}
