//! `repro profile` — per-stage wallclock A/Bs for the hot-path layouts.
//!
//! Times the concrete representation choices the flat shard memory
//! layout is built on, side by side with the shapes they replaced:
//!
//! * the full TD-Orch scheduler stage (the L3 hot path the §Perf pass
//!   optimizes — the old `examples/profile_stage.rs` loop body);
//! * `DetMap` scratch vs the flat [`Slab`](crate::graph::layout::Slab)
//!   for the edge_map merge-and-walk;
//! * sorted-sparse vs dense-bitset
//!   [`Frontier`](crate::graph::layout::Frontier) iteration at the two
//!   occupancies bracketing the engine's seal threshold;
//! * one mpsc send per payload vs one batched send (the threaded
//!   substrate's old vs new wire discipline).
//!
//! Everything here is **measured host wall-clock** — annotation, never
//! a comparison surface.  `repro bench-snapshot` echoes the numbers
//! into `profile-stage.json` next to the snapshots, but the committed
//! `BENCH_*.json` baselines never include them: the CI diff gate
//! compares deterministic objects only.  The computed *checksums* are
//! deterministic and asserted equal across each A/B pair, so the two
//! sides provably do the same work.

use std::sync::mpsc;
use std::time::Instant;

use crate::det::{det_map, DetMap};
use crate::graph::layout::{Frontier, Slab};
use crate::orchestration::tdorch::TdOrch;
use crate::orchestration::{spread_tasks, Scheduler, Task};
use crate::repro::TablePrinter;
use crate::{Cluster, CostModel, DistStore, OrchApp};

/// Minimal in-place counting app (same shape `benches/microbench.rs`
/// and the retired profiling example used) — the scheduler stage cost
/// is routing, not lambda work.
struct CounterApp;
impl OrchApp for CounterApp {
    type Ctx = i64;
    type Val = i64;
    type Out = i64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        16
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, c: &i64, _v: &i64) -> Option<i64> {
        Some(*c)
    }
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }
    fn apply(&self, v: &mut i64, o: i64) {
        *v += o;
    }
}

/// One timed stage: best-of-`reps` and mean, in nanoseconds.
pub struct StageTime {
    pub label: String,
    pub reps: usize,
    pub best_ns: u128,
    pub mean_ns: u128,
}

pub struct ProfileReport {
    pub stages: Vec<StageTime>,
}

impl ProfileReport {
    fn stage(&self, label: &str) -> &StageTime {
        self.stages
            .iter()
            .find(|s| s.label == label)
            .unwrap_or_else(|| panic!("no stage {label:?}"))
    }

    /// best-of A-side ns / best-of B-side ns (how much faster B is).
    pub fn speedup(&self, a: &str, b: &str) -> f64 {
        self.stage(a).best_ns as f64 / self.stage(b).best_ns.max(1) as f64
    }

    /// JSON annotation blob (`tdorch.profile.v1`).  Host wall-clock —
    /// written next to the bench snapshots, never diffed by the gate.
    pub fn json(&self) -> String {
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|s| {
                format!(
                    "{{\"label\":\"{}\",\"reps\":{},\"best_ns\":{},\"mean_ns\":{}}}",
                    s.label, s.reps, s.best_ns, s.mean_ns
                )
            })
            .collect();
        format!(
            "{{\"schema\":\"tdorch.profile.v1\",\
             \"note\":\"host wall-clock annotation — never a comparison surface\",\
             \"host\":{{\"os\":\"{}\",\"arch\":\"{}\"}},\
             \"stages\":[{}]}}\n",
            std::env::consts::OS,
            std::env::consts::ARCH,
            stages.join(","),
        )
    }
}

fn time<T>(label: &str, reps: usize, mut f: impl FnMut() -> T) -> (StageTime, T) {
    let mut best = u128::MAX;
    let mut total = 0u128;
    let mut out = None;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        let r = std::hint::black_box(f());
        let ns = t0.elapsed().as_nanos();
        best = best.min(ns);
        total += ns;
        out = Some(r);
    }
    let st = StageTime {
        label: label.to_string(),
        reps: reps.max(1),
        best_ns: best,
        mean_ns: total / reps.max(1) as u128,
    };
    (st, out.expect("reps >= 1"))
}

fn fmt_ns(ns: u128) -> String {
    if ns >= 10_000_000 {
        format!("{:.1}ms", ns as f64 / 1e6)
    } else {
        format!("{:.1}us", ns as f64 / 1e3)
    }
}

/// Run every stage `reps` times and print the table plus the A/B
/// speedups.  Called by `repro profile`, the `profile_stage` example,
/// and (with small reps) the bench-snapshot annotation writer.
pub fn run_profile(reps: usize) -> ProfileReport {
    println!("\n## repro profile — per-stage wallclock A/Bs ({reps} reps, best-of)\n");
    let mut stages = Vec::new();

    // --- TD-Orch scheduler stage (L3 hot path) ---
    let tasks: Vec<Task<i64>> = (0..200_000)
        .map(|i| {
            let addr = if i % 4 == 0 {
                (i % 16) as u64
            } else {
                (i as u64).wrapping_mul(0x9E3779B9) % 1_000_000
            };
            Task::inplace(addr, 1)
        })
        .collect();
    let (st, executed) = time("tdorch-stage-200k-p16", reps, || {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let mut s: DistStore<i64> = DistStore::new(16);
        let o = TdOrch::new().run_stage(&mut c, &CounterApp, spread_tasks(tasks.clone(), 16), &mut s);
        o.total_executed
    });
    assert_eq!(executed, 200_000, "scheduler stage dropped tasks");
    stages.push(st);

    // --- scratch: DetMap vs flat slab (merge 300k contribs over 100k
    // keys, walk touched keys ascending — the edge_map fold shape) ---
    let n = 100_000usize;
    let contribs: Vec<(u32, f64)> = (0..300_000u64)
        .map(|i| ((i.wrapping_mul(0x9E37_79B9) % n as u64) as u32, i as f64))
        .collect();
    let (st, sum_map) = time("scratch-detmap", reps, || {
        let mut m: DetMap<u32, f64> = det_map();
        for &(v, x) in &contribs {
            m.entry(v).and_modify(|a| *a = a.min(x)).or_insert(x);
        }
        let mut keys: Vec<u32> = m.keys().copied().collect();
        keys.sort_unstable();
        let mut acc = 0.0;
        for k in keys {
            acc += m[&k];
        }
        acc
    });
    stages.push(st);
    let mut slab = Slab::new();
    slab.ensure(n);
    let (st, sum_slab) = time("scratch-flat-slab", reps, || {
        slab.clear();
        for &(v, x) in &contribs {
            slab.merge_with(v, x, f64::min);
        }
        slab.normalize();
        let mut acc = 0.0;
        for &v in slab.dirty() {
            acc += slab.get(v).unwrap();
        }
        acc
    });
    stages.push(st);
    assert_eq!(
        sum_map.to_bits(),
        sum_slab.to_bits(),
        "scratch A/B sides disagree — the slab is not a drop-in fold"
    );

    // --- frontier: sparse vec vs dense bitset iteration, bracketing
    // the 1/DENSE_OCCUPANCY_DIV seal threshold ---
    let span = 1_000_000usize;
    for (tag, stride) in [("hi-occ-1of2", 2usize), ("lo-occ-1of64", 64)] {
        let mut sparse_f = Frontier::new(0, span);
        let mut dense_f = Frontier::new(0, span);
        for v in (0..span as u32).step_by(stride) {
            sparse_f.push(v);
            dense_f.push(v);
        }
        dense_f.force_dense();
        let (st, a) = time(&format!("frontier-sparse-{tag}"), reps, || {
            let mut acc = 0u64;
            for v in sparse_f.iter() {
                acc = acc.wrapping_add(v as u64);
            }
            acc
        });
        stages.push(st);
        let (st, b) = time(&format!("frontier-dense-{tag}"), reps, || {
            let mut acc = 0u64;
            for v in dense_f.iter() {
                acc = acc.wrapping_add(v as u64);
            }
            acc
        });
        stages.push(st);
        assert_eq!(a, b, "frontier representations iterated different sets");
    }

    // --- channel discipline: per-message vs one batched send ---
    let msgs: Vec<u64> = (0..100_000u64).collect();
    let (st, a) = time("mpsc-per-message", reps, || {
        let (tx, rx) = mpsc::channel::<u64>();
        for &x in &msgs {
            tx.send(x).unwrap();
        }
        drop(tx);
        let mut acc = 0u64;
        while let Ok(x) = rx.recv() {
            acc = acc.wrapping_add(x);
        }
        acc
    });
    stages.push(st);
    let (st, b) = time("mpsc-batched", reps, || {
        let (tx, rx) = mpsc::channel::<Vec<u64>>();
        tx.send(msgs.clone()).unwrap();
        drop(tx);
        let mut acc = 0u64;
        while let Ok(batch) = rx.recv() {
            for x in batch {
                acc = acc.wrapping_add(x);
            }
        }
        acc
    });
    stages.push(st);
    assert_eq!(a, b, "channel A/B sides moved different payloads");

    let report = ProfileReport { stages };
    let t = TablePrinter::new(&["stage", "best", "mean"], &[26, 10, 10]);
    for s in &report.stages {
        t.row(&[s.label.clone(), fmt_ns(s.best_ns), fmt_ns(s.mean_ns)]);
    }
    println!();
    for (a, b, what) in [
        ("scratch-detmap", "scratch-flat-slab", "flat slab vs DetMap scratch"),
        ("frontier-sparse-hi-occ-1of2", "frontier-dense-hi-occ-1of2", "dense vs sparse at 1/2 occupancy"),
        ("frontier-dense-lo-occ-1of64", "frontier-sparse-lo-occ-1of64", "sparse vs dense at 1/64 occupancy"),
        ("mpsc-per-message", "mpsc-batched", "batched vs per-message sends"),
    ] {
        println!("{what}: {:.2}x", report.speedup(a, b));
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One rep through every stage: the A/B checksum asserts inside
    /// `run_profile` are the real test (each pair provably does the
    /// same work); the JSON must carry every stage.
    #[test]
    fn profile_runs_and_reports_every_stage() {
        let r = run_profile(1);
        assert_eq!(r.stages.len(), 9);
        let j = r.json();
        assert!(j.contains("\"schema\":\"tdorch.profile.v1\""));
        for s in &r.stages {
            assert!(j.contains(&format!("\"label\":\"{}\"", s.label)), "{} missing", s.label);
        }
        assert!(r.speedup("scratch-detmap", "scratch-flat-slab") > 0.0);
    }
}
