//! `repro mutate` — live graph mutation under serving traffic, verified
//! bit-for-bit.
//!
//! Replays a deterministic interleaving of Zipf-hotness edge delta
//! batches and {BFS, SSSP, PR, CC, BC} queries on ONE long-lived engine
//! ([`crate::serve::Server::serve`] with a mutation feed), then cross-checks
//! every served query against reference engines built **at that query's
//! epoch**, walking the results in reverse order like `repro serve` so
//! state leaking across queries or deltas meets a different predecessor
//! and breaks the comparison instead of cancelling out.  Two references:
//!
//! 1. **Replayed placement, all five kinds, bit-for-bit** — a fresh
//!    engine per epoch from `DistGraph::apply_batch` replayed onto a
//!    clone of the epoch-0 ingestion.  `apply_delta` follows the
//!    identical frozen-placement rules inside pool supersteps, so even
//!    the rounding-merge kinds (PR/BC, whose f64 fold grouping is part
//!    of the bits) must match exactly.
//! 2. **Fresh ingestion, exact kinds, bit-for-bit** — the mutated edge
//!    set re-ingested from scratch (new placement pass) for BFS/SSSP/CC,
//!    whose min/first-writer merges are placement-independent by the
//!    determinism contract.  This pins that the in-place deltas really
//!    produce *the mutated graph*, not merely a self-consistent state.
//!
//! The run fails (exit 1) on any divergence, on a second ingestion on
//! the served engine (`ingest::ingestions()` is the witness — reference
//! 2's re-ingests happen only after the witness is read), or on a broken
//! epoch discipline (epochs must be nondecreasing in dispatch order and
//! finish at the number of scheduled batches).

use crate::exec::ThreadedCluster;
use crate::graph::flags::Flags;
use crate::graph::gen;
use crate::graph::ingest::{ingestions, DistGraph};
use crate::graph::spmd::{ingest_once, GraphMeta, Placement, SpmdEngine};
use crate::graph::{Graph, Vid};
use crate::mutate::{generate_mutations, EdgeOp, MutationConfig, MutationFeed};
use crate::serve::{QueryShard, RunOpts, ServeConfig, ServePolicy, ServeReport, Server};
use crate::workload::{
    generate_stream, hot_source_order, OpenLoopSource, Query, QueryKind, QueryMix, StreamConfig,
};
use crate::{Cluster, CostModel};

use super::TablePrinter;

const FULL_N: usize = 8_000;
const QUICK_N: usize = 2_000;
const GRAPH_K: usize = 6;
const FULL_QUERIES: usize = 64;
const QUICK_QUERIES: usize = 24;
/// Open-loop arrival rate (queries per logical tick).
const ARRIVALS_PER_TICK: usize = 2;
const ZIPF_S: f64 = 1.5;

fn mutation_cfg(quick: bool) -> MutationConfig {
    MutationConfig {
        batches: if quick { 4 } else { 8 },
        ops_per_batch: if quick { 8 } else { 16 },
        insert_pct: 60,
        zipf_s: 1.2,
        start_tick: 2,
        every_ticks: 6,
    }
}

/// Result of one `repro mutate` invocation (consumed by main/tests).
pub struct MutateSummary {
    pub served: usize,
    pub rejected: u64,
    /// Divergences against the replayed-placement reference (all kinds).
    pub mismatches_replay: usize,
    /// Divergences against the fresh-ingestion reference (exact kinds).
    pub mismatches_fresh: usize,
    /// Queries the fresh-ingestion reference covered.
    pub checked_fresh: usize,
    /// Ingestion passes on the serving side (must be exactly 1).
    pub ingestions_serving: u64,
    /// Engine epoch after the run (must equal scheduled batches).
    pub final_epoch: u64,
    /// Queries that executed against a mutated graph (epoch > 0).
    pub post_mutation_queries: usize,
    /// Cache hits on the serving side (0 with `--cache` off).
    pub cache_hits: u64,
    pub all_valid: bool,
}

fn arc_key(u: Vid, v: Vid) -> u64 {
    ((u as u64) << 32) | v as u64
}

pub fn run_mutate(
    p: usize,
    seed: u64,
    backend: &str,
    quick: bool,
    fuse: bool,
    cache: bool,
) -> MutateSummary {
    assert!(p >= 1, "need at least one machine");
    let ing0 = ingestions();
    let cost = CostModel::paper_cluster();
    let n = if quick { QUICK_N } else { FULL_N };
    let queries = if quick { QUICK_QUERIES } else { FULL_QUERIES };
    let g = gen::barabasi_albert(n, GRAPH_K, seed);
    let mcfg = mutation_cfg(quick);
    println!(
        "\n## repro mutate — live edge deltas under a {{BFS,SSSP,PR,CC,BC}} Zipf stream on \
         the reused engine: BA graph n={} m={}, P={p}, {queries} queries, {} delta batches × \
         {} edge ops, seed {seed}, backend {backend}\n",
        g.n,
        g.m(),
        mcfg.batches,
        mcfg.ops_per_batch,
    );

    // ONE ingestion for the serving side; every reference below is built
    // from clones (reference 1) or counted separately (reference 2).
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let hot = hot_source_order(&dg.out_deg);
    let stream = generate_stream(
        StreamConfig {
            queries,
            per_tick: ARRIVALS_PER_TICK,
            every_ticks: 1,
            zipf_s: ZIPF_S,
            mix: QueryMix::balanced(),
        },
        &hot,
        seed,
    );
    // Derived seed: the mutation draw chain must not alias the query
    // stream's.
    let batches = generate_mutations(mcfg, &g, &hot, seed.wrapping_add(1));
    let scheduled = batches.len() as u64;

    let serve_cfg = ServeConfig { batch: 4, ..ServeConfig::default() };
    let serve_policy = ServePolicy::new().with_fuse(fuse).with_cache(cache);
    // The references below MUST keep both knobs off: the reverse-order
    // walk re-executes served queries through `run_query`, and a cached
    // reference would "verify" a result against a stored copy of itself
    // (run_query never consults the cache either — dispatch-only — but
    // the reference config pins the intent; tests/serve_cache.rs holds
    // both lines).
    let reference_cfg = ServeConfig { batch: 4, ..ServeConfig::default() };
    let (report, final_meta, engine_epoch): (ServeReport, std::sync::Arc<GraphMeta>, u64) =
        if backend == "threaded" {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg.clone(),
                cost,
                Flags::tdo_gp(),
                "mutate-threaded",
                QueryShard::new,
            ),
            serve_cfg,
        )
        .with_serving_policy(serve_policy);
        let mut feed = MutationFeed::new(batches.clone());
        let report =
            server.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut feed));
        let engine = server.into_engine();
        (report, engine.meta(), engine.graph_epoch())
    } else {
        let mut server = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost),
                dg.clone(),
                cost,
                Flags::tdo_gp(),
                "mutate-sim",
                QueryShard::new,
            ),
            serve_cfg,
        )
        .with_serving_policy(serve_policy);
        let mut feed = MutationFeed::new(batches.clone());
        let report =
            server.serve(&mut OpenLoopSource::new(&stream), RunOpts::new().feed(&mut feed));
        let engine = server.into_engine();
        (report, engine.meta(), engine.graph_epoch())
    };

    // THE WITNESS, read before any reference exists: the serving side
    // must have ingested exactly once, deltas included.
    let ingestions_serving = ingestions() - ing0;

    // ---- epoch discipline ----
    let epochs_nondecreasing = report
        .results
        .windows(2)
        .all(|w| w[0].graph_epoch <= w[1].graph_epoch);
    let records_consistent = report.mutations.len() as u64 == scheduled
        && report
            .mutations
            .iter()
            .enumerate()
            .all(|(i, m)| m.epoch_after == i as u64 + 1 && m.applied_tick >= m.arrival);
    let epochs_ok = report.graph_epoch == scheduled
        && engine_epoch == scheduled
        && epochs_nondecreasing
        && records_consistent;
    let post_mutation_queries =
        report.results.iter().filter(|r| r.graph_epoch > 0).count();

    // ---- reference 1: replayed placement, per-epoch DistGraph
    // snapshots from apply_batch on clones of the epoch-0 ingestion ----
    let mut dgs: Vec<DistGraph> = Vec::with_capacity(batches.len() + 1);
    dgs.push(dg);
    for b in &batches {
        let mut next = dgs.last().expect("nonempty").clone();
        next.apply_batch(b);
        dgs.push(next);
    }

    // Structural check: the served engine's catalog must equal the
    // replayed final snapshot field for field.
    let last = &dgs[scheduled as usize];
    let structure_ok = final_meta.m == last.m
        && final_meta.out_deg == last.out_deg
        && final_meta.src_leaves == last.src_leaves
        && final_meta.dst_leaves == last.dst_leaves;

    let mut mismatches_replay = 0usize;
    {
        // Reverse walk: epochs are nonincreasing, so each reference
        // engine is built at most once per epoch.
        let mut reference: Option<(u64, Server<Cluster>)> = None;
        for r in report.results.iter().rev() {
            if reference.as_ref().map(|(e, _)| *e) != Some(r.graph_epoch) {
                reference = Some((
                    r.graph_epoch,
                    Server::new(
                        SpmdEngine::from_ingested(
                            Cluster::new(p, cost),
                            dgs[r.graph_epoch as usize].clone(),
                            cost,
                            Flags::tdo_gp(),
                            "mutate-replay-ref",
                            QueryShard::new,
                        ),
                        reference_cfg,
                    ),
                ));
            }
            let (_, srv) = reference.as_mut().expect("just built");
            let q = Query { id: r.id, kind: r.kind, source: r.source, arrival: 0 };
            if srv.run_query(&q) != r.bits {
                mismatches_replay += 1;
                eprintln!(
                    "MISMATCH (replayed placement): query {} ({}) at epoch {} diverged",
                    r.id,
                    r.kind.label(),
                    r.graph_epoch
                );
            }
        }
    }

    // ---- reference 2: fresh ingestion of the mutated edge set, exact
    // kinds only (placement-independent merges) ----
    let mut arcmap: crate::det::DetMap<u64, f32> = crate::det::det_map();
    for u in 0..g.n as Vid {
        for &(v, w) in g.neighbors(u) {
            arcmap.insert(arc_key(u, v), w);
        }
    }
    let mut graphs: Vec<Graph> = Vec::with_capacity(batches.len() + 1);
    graphs.push(g.clone());
    for b in &batches {
        for op in &b.ops {
            match *op {
                EdgeOp::Insert { u, v, w } => {
                    arcmap.insert(arc_key(u, v), w);
                }
                EdgeOp::Delete { u, v } => {
                    arcmap.remove(&arc_key(u, v));
                }
            }
        }
        let arcs: Vec<(Vid, Vid, f32)> = arcmap
            .iter()
            .map(|(&k, &w)| ((k >> 32) as Vid, (k & 0xFFFF_FFFF) as Vid, w))
            .collect();
        graphs.push(Graph::from_arcs(g.n, arcs));
    }
    let arc_counts_ok =
        (0..=scheduled as usize).all(|e| graphs[e].m() == dgs[e].m);

    let mut mismatches_fresh = 0usize;
    let mut checked_fresh = 0usize;
    {
        let mut fresh: Vec<Option<Server<Cluster>>> =
            (0..=scheduled as usize).map(|_| None).collect();
        for r in report.results.iter().rev() {
            if !matches!(r.kind, QueryKind::Bfs | QueryKind::Sssp | QueryKind::Cc) {
                continue;
            }
            let e = r.graph_epoch as usize;
            if fresh[e].is_none() {
                // A genuinely new placement pass — counted by the
                // ingestion witness, which was already read above.
                let fdg = ingest_once(&graphs[e], p, cost, Placement::Spread);
                fresh[e] = Some(Server::new(
                    SpmdEngine::from_ingested(
                        Cluster::new(p, cost),
                        fdg,
                        cost,
                        Flags::tdo_gp(),
                        "mutate-fresh-ref",
                        QueryShard::new,
                    ),
                    reference_cfg,
                ));
            }
            let srv = fresh[e].as_mut().expect("just built");
            checked_fresh += 1;
            let q = Query { id: r.id, kind: r.kind, source: r.source, arrival: 0 };
            if srv.run_query(&q) != r.bits {
                mismatches_fresh += 1;
                eprintln!(
                    "MISMATCH (fresh ingestion): query {} ({}) at epoch {} diverged",
                    r.id,
                    r.kind.label(),
                    r.graph_epoch
                );
            }
        }
    }

    // ---- report ----
    let t = TablePrinter::new(
        &["batch", "arrival", "applied@", "ops", "epoch after", "service ticks"],
        &[5, 7, 8, 5, 11, 13],
    );
    for m in &report.mutations {
        t.row(&[
            m.batch_id.to_string(),
            m.arrival.to_string(),
            m.applied_tick.to_string(),
            m.ops.to_string(),
            m.epoch_after.to_string(),
            m.service_ticks.to_string(),
        ]);
    }
    println!();
    let t = TablePrinter::new(&["kind", "served", "post-mutation", "fresh-checked"], &[5, 7, 13, 13]);
    for kind in QueryKind::ALL {
        let of_kind: Vec<_> = report.results.iter().filter(|r| r.kind == kind).collect();
        let post = of_kind.iter().filter(|r| r.graph_epoch > 0).count();
        let exact = matches!(kind, QueryKind::Bfs | QueryKind::Sssp | QueryKind::Cc);
        t.row(&[
            kind.label().to_string(),
            of_kind.len().to_string(),
            post.to_string(),
            if exact { of_kind.len().to_string() } else { "-".to_string() },
        ]);
    }
    let total_ops: usize = report.mutations.iter().map(|m| m.ops).sum();
    println!(
        "\noverall: {} offered = {} served + {} rejected over {} logical ticks; \
         {} delta batches ({} directed ops) absorbed in place → final epoch {}; \
         {} queries executed on a mutated graph",
        report.offered(),
        report.served(),
        report.rejected,
        report.ticks,
        scheduled,
        total_ops,
        report.graph_epoch,
        post_mutation_queries,
    );
    println!(
        "ingestions on the serving side: {ingestions_serving} (deltas absorbed by \
         apply_delta supersteps — never by re-ingestion; the fresh-ingest reference's \
         own passes are read separately)"
    );
    println!(
        "dispatch: {} engine passes ({} fused waves), {} cache hits / {} misses \
         (fuse {fuse}, cache {cache}; every hit's epoch matched the live graph by key)",
        report.waves.len(),
        report.waves.iter().filter(|w| w.lanes >= 2).count(),
        report.cache_hits,
        report.cache_misses,
    );

    let all_valid = mismatches_replay == 0
        && mismatches_fresh == 0
        && checked_fresh > 0
        && ingestions_serving == 1
        && report.served() as u64 + report.rejected == queries as u64
        && report.served() as u64 == report.cache_hits + report.cache_misses
        && epochs_ok
        && structure_ok
        && arc_counts_ok
        && post_mutation_queries > 0;
    println!(
        "\nmutate {}",
        if all_valid {
            "OK (every query bit-identical to its epoch's references; deltas absorbed \
             with exactly one ingestion)"
        } else {
            "FAILED"
        }
    );
    MutateSummary {
        served: report.served(),
        rejected: report.rejected,
        mismatches_replay,
        mismatches_fresh,
        checked_fresh,
        ingestions_serving,
        final_epoch: report.graph_epoch,
        post_mutation_queries,
        cache_hits: report.cache_hits,
        all_valid,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_mutate_sim_quick_is_valid() {
        let s = run_mutate(2, 7, "sim", true, false, false);
        assert_eq!(s.mismatches_replay, 0);
        assert_eq!(s.mismatches_fresh, 0);
        assert!(s.checked_fresh > 0);
        assert_eq!(s.ingestions_serving, 1);
        assert_eq!(s.final_epoch, 4);
        assert!(s.post_mutation_queries > 0, "mutations must land mid-stream");
        assert_eq!(s.cache_hits, 0);
        assert!(s.all_valid);
    }
}
