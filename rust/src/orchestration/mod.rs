//! Task-data orchestration — the paper's Fig 1 interface.
//!
//! A batch of *lambda tasks*, each reading one data chunk and writing one
//! (possibly different) chunk, is executed in a single BSP orchestration
//! stage: read → execute → write-back.  Applications implement [`OrchApp`]
//! (the closure triple: lambda `f` = `execute`, write-back merge `⊗` =
//! `combine`, write-back apply `⊙` = `apply` — Def. 2's merge-able
//! operations) and hand batches of [`Task`]s to a [`Scheduler`].
//!
//! Four interchangeable schedulers ship with the crate:
//! [`tdorch::TdOrch`] (the paper's contribution) and the three §2.3
//! baselines in [`crate::baselines`].

pub mod tdorch;

use std::collections::HashMap;

use crate::bsp::Cluster;
use crate::det::{det_map, DetMap};
use crate::exec::{MachineAcct, Substrate};
use crate::store::{Addr, DistStore};

/// One lambda task: context plus input/output pointers (Fig 1 with
/// |InputPointers| = |OutputPointers| = 1; multi-pointer tasks are split
/// into one task per pointer by the caller, one stage per dependency).
#[derive(Clone, Debug)]
pub struct Task<C> {
    /// Address of the chunk the lambda reads.
    pub read_addr: Addr,
    /// Address the returned value is written back to (may equal
    /// `read_addr`, as in the KV store, or differ, as in graph edges).
    pub write_addr: Addr,
    /// Per-task local metadata (the closure captures).
    pub ctx: C,
}

impl<C> Task<C> {
    pub fn new(read_addr: Addr, write_addr: Addr, ctx: C) -> Self {
        Task { read_addr, write_addr, ctx }
    }

    /// Task whose write target is its read target (KV-store style).
    pub fn inplace(addr: Addr, ctx: C) -> Self {
        Task { read_addr: addr, write_addr: addr, ctx }
    }
}

/// Application hooks for one orchestration stage (paper Fig 1 + Def. 2).
pub trait OrchApp {
    /// Task context type (the closure).
    type Ctx: Clone;
    /// Data chunk type; `Default` is the not-yet-present chunk.
    type Val: Clone + Default;
    /// Write-back value type.
    type Out: Clone;

    /// Context size σ in words.
    fn sigma(&self) -> u64;
    /// Chunk size B in words.
    fn chunk_words(&self) -> u64;
    /// Write-back value size in words.
    fn out_words(&self) -> u64;
    /// Work units charged per executed task (default 1).
    fn task_work(&self) -> u64 {
        1
    }

    /// The lambda `f`: consume the read value, produce the write-back.
    /// `None` means the task writes nothing.
    fn execute(&self, ctx: &Self::Ctx, val: &Self::Val) -> Option<Self::Out>;

    /// `⊗` — merge two write-backs headed for the same chunk.  Must be
    /// associative and commutative (Def. 2).
    fn combine(&self, a: Self::Out, b: Self::Out) -> Self::Out;

    /// `⊙` — apply a (merged) write-back to the chunk.
    fn apply(&self, val: &mut Self::Val, out: Self::Out);

    /// Batched execution hook: schedulers funnel every co-located
    /// (task, value) pair on a machine through one call so applications
    /// can offload to the AOT-compiled XLA artifact (see
    /// [`crate::kvstore`]).  The default loops over [`OrchApp::execute`].
    fn execute_batch(
        &self,
        items: &[(&Self::Ctx, &Self::Val)],
        sink: &mut Vec<Option<Self::Out>>,
    ) {
        sink.extend(items.iter().map(|(c, v)| self.execute(c, v)));
    }
}

/// Outcome of one orchestration stage (metrics live on the [`Cluster`]).
#[derive(Clone, Debug, Default)]
pub struct StageOutcome {
    /// Tasks executed per machine — Theorem 1(ii)'s load-balance object.
    pub executed_per_machine: Vec<u64>,
    /// Total tasks executed (sanity: must equal the number submitted).
    pub total_executed: u64,
}

/// An orchestration scheduler: the paper's TD-Orch or one of the §2.3
/// baselines.  `tasks[m]` is the batch initially resident on machine `m`.
///
/// Schedulers are written against the [`Substrate`] superstep API, so one
/// implementation runs unchanged on the BSP simulator (`S =`
/// [`Cluster`], the default — all existing call sites) or on the real
/// threaded backend (`S =` [`crate::exec::ThreadedCluster`]).
pub trait Scheduler<A: OrchApp, S: Substrate = Cluster> {
    fn name(&self) -> &'static str;

    fn run_stage(
        &self,
        sub: &mut S,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome;
}

/// Sequential oracle: apply all tasks to the store in a single thread with
/// the same combine-then-apply semantics.  Schedulers are verified against
/// this in tests (any scheduler must produce an identical store when ⊗ is
/// associative+commutative).
pub fn sequential_reference<A: OrchApp>(
    app: &A,
    tasks: &[Vec<Task<A::Ctx>>],
    store: &mut DistStore<A::Val>,
) {
    use std::collections::HashMap;
    let mut pending: HashMap<Addr, A::Out> = HashMap::new();
    for batch in tasks {
        for t in batch {
            let val = store.read_copy(t.read_addr);
            if let Some(out) = app.execute(&t.ctx, &val) {
                match pending.remove(&t.write_addr) {
                    Some(acc) => {
                        pending.insert(t.write_addr, app.combine(acc, out));
                    }
                    None => {
                        pending.insert(t.write_addr, out);
                    }
                }
            }
        }
    }
    let mut addrs: Vec<Addr> = pending.keys().copied().collect();
    addrs.sort_unstable();
    for addr in addrs {
        let out = pending.remove(&addr).unwrap();
        app.apply(store.get_or_default(addr), out);
    }
}

/// Per-machine stage scaffold shared by the simple (non-tree)
/// schedulers: the machine's initial task batch, its store shard, and
/// its executed-task count.
pub(crate) struct ShardState<A: OrchApp> {
    pub batch: Vec<Task<A::Ctx>>,
    pub shard: HashMap<Addr, A::Val>,
    pub executed: u64,
}

/// Stage-contract checks shared by every scheduler: the task batches and
/// the store partitioning must both match the substrate's P.  Returns
/// (P, submitted task count).
pub(crate) fn stage_contract<C, V: Clone + Default>(
    p: usize,
    tasks: &[Vec<Task<C>>],
    store: &DistStore<V>,
) -> (usize, u64) {
    assert_eq!(tasks.len(), p, "tasks must be pre-spread over P machines");
    assert_eq!(store.p(), p, "store partitioning must match the substrate");
    (p, task_count(tasks))
}

/// Stage prologue for [`ShardState`]-based schedulers: check the
/// contract and hand each machine its shard plus its batch.
pub(crate) fn start_stage<A: OrchApp>(
    p: usize,
    tasks: Vec<Vec<Task<A::Ctx>>>,
    store: &mut DistStore<A::Val>,
) -> (u64, Vec<ShardState<A>>) {
    let (_, submitted) = stage_contract(p, &tasks, store);
    let st = tasks
        .into_iter()
        .zip(store.take_maps())
        .map(|(batch, shard)| ShardState { batch, shard, executed: 0 })
        .collect();
    (submitted, st)
}

/// ⊗-accumulate `out` into `pool[addr]` with a single hash lookup (the
/// Option slot allows in-place combine).  The shared accumulation idiom
/// of every scheduler's write-back pool.
pub(crate) fn combine_into<A: OrchApp>(
    app: &A,
    pool: &mut DetMap<Addr, Option<A::Out>>,
    addr: Addr,
    out: A::Out,
) {
    let slot = pool.entry(addr).or_insert(None);
    *slot = Some(match slot.take() {
        Some(acc) => app.combine(acc, out),
        None => out,
    });
}

/// Owner-side write-back epilogue shared by every scheduler: ⊗-merge an
/// inbox of (addr, out) pairs (in arrival order, one hash op per item)
/// and ⊙-apply the merged results to the local shard in deterministic
/// address order — exactly one apply per chunk, as in
/// [`sequential_reference`].
pub(crate) fn merge_and_apply<A: OrchApp>(
    app: &A,
    inbox: Vec<(Addr, A::Out)>,
    shard: &mut HashMap<Addr, A::Val>,
    acct: &mut MachineAcct,
) {
    let mut merged: DetMap<Addr, Option<A::Out>> = det_map();
    for (addr, out) in inbox {
        acct.work(1);
        combine_into(app, &mut merged, addr, out);
    }
    let mut pairs: Vec<(Addr, A::Out)> = merged
        .drain()
        .map(|(a, o)| (a, o.expect("merged slot")))
        .collect();
    pairs.sort_unstable_by_key(|(a, _)| *a);
    for (addr, out) in pairs {
        app.apply(shard.entry(addr).or_default(), out);
    }
}

/// Stage epilogue shared by every scheduler: reassemble the store from
/// the per-machine (executed count, shard) pairs and enforce the
/// submitted == executed invariant.
pub(crate) fn finish_stage<V: Clone + Default>(
    store: &mut DistStore<V>,
    parts: Vec<(u64, HashMap<Addr, V>)>,
    submitted: u64,
    scheduler: &str,
) -> StageOutcome {
    let mut executed_per_machine = Vec::with_capacity(parts.len());
    let mut maps = Vec::with_capacity(parts.len());
    for (executed, shard) in parts {
        executed_per_machine.push(executed);
        maps.push(shard);
    }
    store.put_maps(maps);
    let total_executed: u64 = executed_per_machine.iter().sum();
    debug_assert_eq!(
        total_executed, submitted,
        "{scheduler} executed {total_executed} of {submitted} submitted tasks"
    );
    StageOutcome { executed_per_machine, total_executed }
}

/// Evenly spread `n` tasks over `p` machines (the paper's initialization:
/// each machine starts with Θ(n/P) tasks).
pub fn spread_tasks<C>(tasks: Vec<Task<C>>, p: usize) -> Vec<Vec<Task<C>>> {
    let mut per: Vec<Vec<Task<C>>> = (0..p).map(|_| Vec::new()).collect();
    for (i, t) in tasks.into_iter().enumerate() {
        per[i % p].push(t);
    }
    per
}

/// Count tasks across machines.
pub fn task_count<C>(tasks: &[Vec<Task<C>>]) -> u64 {
    tasks.iter().map(|b| b.len() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy app: chunk = i64 counter, ctx = increment, out = sum.
    struct CounterApp;
    impl OrchApp for CounterApp {
        type Ctx = i64;
        type Val = i64;
        type Out = i64;
        fn sigma(&self) -> u64 {
            1
        }
        fn chunk_words(&self) -> u64 {
            1
        }
        fn out_words(&self) -> u64 {
            1
        }
        fn execute(&self, ctx: &i64, _val: &i64) -> Option<i64> {
            Some(*ctx)
        }
        fn combine(&self, a: i64, b: i64) -> i64 {
            a + b
        }
        fn apply(&self, val: &mut i64, out: i64) {
            *val += out;
        }
    }

    #[test]
    fn sequential_reference_combines_and_applies() {
        let app = CounterApp;
        let mut store: DistStore<i64> = DistStore::new(4);
        let tasks = vec![vec![
            Task::inplace(10, 1),
            Task::inplace(10, 2),
            Task::inplace(20, 5),
        ]];
        sequential_reference(&app, &tasks, &mut store);
        assert_eq!(*store.get(10).unwrap(), 3);
        assert_eq!(*store.get(20).unwrap(), 5);
    }

    #[test]
    fn spread_is_even() {
        let tasks: Vec<Task<i64>> = (0..10).map(|i| Task::inplace(i, i as i64)).collect();
        let spread = spread_tasks(tasks, 4);
        let sizes: Vec<usize> = spread.iter().map(|b| b.len()).collect();
        assert_eq!(sizes, vec![3, 3, 2, 2]);
        assert_eq!(task_count(&spread), 10);
    }

    #[test]
    fn cross_addr_write() {
        // read one addr, write another.
        let app = CounterApp;
        let mut store: DistStore<i64> = DistStore::new(2);
        store.insert(1, 100);
        let tasks = vec![vec![Task::new(1, 2, 7)]];
        sequential_reference(&app, &tasks, &mut store);
        assert_eq!(*store.get(2).unwrap(), 7);
        assert_eq!(*store.get(1).unwrap(), 100);
    }
}
