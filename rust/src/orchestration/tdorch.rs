//! TD-Orch: the paper's four-phase push-pull orchestration engine (§3).
//!
//! Phase 1 — *contention detection*: every task's context climbs the
//! communication forest toward the machine owning its read chunk, merging
//! into meta-task sets at each transit node (so no machine ever receives
//! more than F bounded-size messages per node per round, even for a chunk
//! requested by all n tasks).
//!
//! Phase 2 — *co-location (distributed push-pull)*: at the root, a chunk
//! whose reference count is ≤ C already holds all requesting contexts (the
//! *push* completed during Phase 1 — no extra hops).  A contended chunk
//! instead *pulls*: its value is broadcast down the meta-task tree, level
//! by level, to every machine where contexts were parked.
//!
//! Phase 3 — *execution*: each machine executes its co-located (context,
//! value) pairs; the per-machine batch is funneled through
//! [`OrchApp::execute_batch`] so applications can dispatch to the
//! AOT-compiled XLA artifact.
//!
//! Phase 4 — *write-backs*: results aimed at the pulled chunk merge (⊗)
//! up the reverse meta-task tree; results aimed at other chunks are
//! pre-combined per machine and sent to their owners.  At each owner the
//! tree-merged result and the direct write-backs for a chunk ⊗-combine
//! into one value that is applied (⊙) exactly once.
//!
//! The whole stage is expressed as [`Substrate::superstep`] rounds over
//! per-machine state ([`MState`]): each machine's store shard, slot
//! store, climbing meta-task sets, pull-tree nodes and write-back pool
//! are private to that machine, so the same code runs sequentially on the
//! BSP simulator and in parallel (one worker thread per machine) on
//! [`crate::exec::ThreadedCluster`] — shared-nothing either way.

use std::collections::hash_map::Entry;
use std::collections::HashMap;

use crate::bsp::MachineId;
use crate::det::{det_map, DetMap};
use crate::exec::{no_messages, nothing_words, MachineAcct, Nothing, Substrate};
use crate::forest::Forest;
use crate::metatask::{MetaTask, MetaTaskSet, SlotStore};
use crate::store::{owner_of, Addr, DistStore};

use super::{OrchApp, Scheduler, StageOutcome, Task};

/// Wire overhead (words) of a pull-down message beyond the chunk value:
/// {addr, slot, parent machine, parent node}.
const PULL_HDR_WORDS: u64 = 4;
/// Wire overhead of an ack climbing the reverse tree: {node, has_value}.
const ACK_HDR_WORDS: u64 = 2;
/// Wire overhead of a direct write-back: {addr}.
const WB_HDR_WORDS: u64 = 1;

/// The TD-Orch scheduler.  `fanout`/`c` default to the paper's
/// theory-guided choices: F = Θ(log P / log log P), C = Θ(B/σ).
#[derive(Clone, Copy, Debug)]
pub struct TdOrch {
    pub fanout: Option<usize>,
    pub c: Option<usize>,
    /// Paper §3 key takeaway (a): a machine whose *local* reference count
    /// for a chunk is ≤ C sends those contexts straight to the owner (one
    /// hop) instead of climbing the forest; only locally-contended groups
    /// (a strong signal of global contention) take the aggregating tree
    /// path.  Disable to measure the ablation.
    pub direct_shortcut: bool,
}

impl Default for TdOrch {
    fn default() -> Self {
        TdOrch { fanout: None, c: None, direct_shortcut: true }
    }
}

impl TdOrch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_params(fanout: usize, c: usize) -> Self {
        TdOrch { fanout: Some(fanout), c: Some(c), direct_shortcut: true }
    }

    pub fn without_shortcut() -> Self {
        TdOrch { direct_shortcut: false, ..Self::default() }
    }

    fn effective_c<A: OrchApp>(&self, app: &A) -> usize {
        self.c.unwrap_or_else(|| {
            let ratio = app.chunk_words() / app.sigma().max(1);
            (ratio as usize).clamp(2, 64)
        })
    }
}

/// A node of a pull tree (one per expanded slot, plus one per root).
struct PullNode<O> {
    addr: Addr,
    parent: Option<(MachineId, u32)>,
    expected: u32,
    received: u32,
    acc: Option<O>,
    sent: bool,
}

/// Value copy descending the meta-task tree.
struct PullMsg<V> {
    addr: Addr,
    val: V,
    slot: u32,
    parent: (MachineId, u32),
}

/// Merged write-back climbing the reverse tree.
struct AckMsg<O> {
    node: u32,
    acc: Option<O>,
}

/// Machine-private stage state: everything one logical machine owns while
/// a TD-Orch stage runs, including its shard of the distributed store.
struct MState<A: OrchApp> {
    /// This machine's initial task batch (consumed by Phase 1).
    batch: Vec<Task<A::Ctx>>,
    /// This machine's shard of the `DistStore`.
    shard: HashMap<Addr, A::Val>,
    /// Parked meta-task arrays (transit-machine storage).
    slots: SlotStore<Task<A::Ctx>>,
    /// Meta-task sets climbing the forest, keyed by (addr, node index).
    holding: DetMap<(Addr, u64), MetaTaskSet<Task<A::Ctx>>>,
    /// Fully-arrived sets at the owner (level 0).
    roots: DetMap<Addr, MetaTaskSet<Task<A::Ctx>>>,
    /// Pull-tree bookkeeping (one node per expanded slot / root).
    nodes: Vec<PullNode<A::Out>>,
    /// Direct write-back pool: write_addr -> merged out.  Option-wrapped
    /// values allow in-place ⊗ with one hash lookup.
    wb: DetMap<Addr, Option<A::Out>>,
    /// Tasks this machine executed (Theorem 1(ii) load-balance object).
    executed: u64,
}

/// Merge a set arriving at its owner (level 0) into the root sets.
fn merge_at_root<A: OrchApp>(
    roots: &mut DetMap<Addr, MetaTaskSet<Task<A::Ctx>>>,
    slots: &mut SlotStore<Task<A::Ctx>>,
    m: MachineId,
    addr: Addr,
    set: MetaTaskSet<Task<A::Ctx>>,
    c: usize,
    acct: &mut MachineAcct,
) {
    match roots.entry(addr) {
        Entry::Occupied(mut e) => {
            let touched = e.get_mut().merge(set, c, slots, m);
            acct.work(touched);
        }
        Entry::Vacant(e) => {
            let mut set = set;
            let touched = set.normalize(c, slots, m);
            acct.work(touched);
            e.insert(set);
        }
    }
}

/// Phase-3 helper: batch-execute groups of co-located (value, tasks) on
/// one machine, then route each write-back — into the group's pull-tree
/// node (reverse-tree path) when it targets the pulled chunk, else into
/// the direct write-back pool.
fn execute_groups<A: OrchApp>(
    app: &A,
    groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)>,
    s: &mut MState<A>,
    acct: &mut MachineAcct,
) {
    if groups.is_empty() {
        return;
    }
    // One flat batch per machine: this is the XLA dispatch point.
    let items: Vec<(&A::Ctx, &A::Val)> = groups
        .iter()
        .flat_map(|(val, tasks, _)| tasks.iter().map(move |t| (&t.ctx, val)))
        .collect();
    let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
    app.execute_batch(&items, &mut outs);
    debug_assert_eq!(outs.len(), items.len());
    let n_tasks = items.len() as u64;
    acct.work(n_tasks * app.task_work());
    acct.executed(n_tasks);
    s.executed += n_tasks;

    let mut it = outs.into_iter();
    for (_, tasks, tree_node) in groups {
        for t in tasks {
            let Some(out) = it.next().expect("execute_batch arity") else {
                continue;
            };
            let group_addr = tree_node.map(|id| s.nodes[id as usize].addr);
            match tree_node {
                Some(id) if group_addr == Some(t.write_addr) => {
                    let node = &mut s.nodes[id as usize];
                    node.acc = Some(match node.acc.take() {
                        Some(a) => app.combine(a, out),
                        None => out,
                    });
                    acct.work(1);
                }
                _ => {
                    // Pure push at the owner (write==read) lands here too:
                    // owner(write_addr) == m makes the send free.
                    super::combine_into(app, &mut s.wb, t.write_addr, out);
                    acct.work(1);
                }
            }
        }
    }
}

/// Phase-4a helper: emit acks for every pull-tree node whose children all
/// reported.  A root node folds its fully merged write-back into the
/// direct write-back pool instead of applying it immediately: the Phase-4b
/// epilogue then ⊗-combines it with any direct write-backs targeting the
/// same chunk and applies exactly ONCE — matching `sequential_reference`
/// even for apps whose ⊙ is not distributive over ⊗ (e.g. overwrite
/// semantics).  The pool entry travels to `owner_of(addr)` in 4b, which
/// is this machine, so the detour is a free self-send.
fn emit_ready_acks<A: OrchApp>(
    s: &mut MState<A>,
    app: &A,
    acct: &mut MachineAcct,
) -> Vec<(MachineId, AckMsg<A::Out>)> {
    let mut out = Vec::new();
    // Split-borrow the node list away from the write-back pool.
    let MState { nodes, wb, .. } = s;
    for node in nodes.iter_mut() {
        if !node.sent && node.received == node.expected {
            node.sent = true;
            match node.parent {
                Some((pm, pid)) => {
                    out.push((pm, AckMsg { node: pid, acc: node.acc.take() }));
                }
                None => {
                    if let Some(o) = node.acc.take() {
                        super::combine_into(app, wb, node.addr, o);
                        acct.work(1);
                    }
                }
            }
        }
    }
    out
}

impl<A, S> Scheduler<A, S> for TdOrch
where
    A: OrchApp + Sync,
    A::Ctx: Send + 'static,
    A::Val: Send + 'static,
    A::Out: Send + 'static,
    S: Substrate,
{
    fn name(&self) -> &'static str {
        "td-orch"
    }

    fn run_stage(
        &self,
        sub: &mut S,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let (p, submitted) = super::stage_contract(sub.machines(), &tasks, store);
        let forest = Forest::new(p, self.fanout.unwrap_or_else(|| Forest::default_fanout(p)));
        let height = forest.height();
        let c = self.effective_c(app);
        let sigma = app.sigma();
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();

        // Hand each machine its private stage state, including its shard.
        let shards = store.take_maps();
        let mut st: Vec<MState<A>> = tasks
            .into_iter()
            .zip(shards)
            .map(|(batch, shard)| MState {
                batch,
                shard,
                slots: SlotStore::new(),
                holding: det_map(),
                roots: det_map(),
                nodes: Vec::new(),
                wb: det_map(),
                executed: 0,
            })
            .collect();

        // ---------------- Phase 1: contention detection ----------------
        // 1a: group the local batch by requested chunk.  Groups with ≤ C
        // contexts push straight to the owner (the shortcut); contended
        // groups enter the forest at this machine's leaf.
        let direct_in: Vec<Vec<(Addr, MetaTaskSet<Task<A::Ctx>>)>> = sub.superstep(
            &mut st,
            no_messages(p),
            |m, s, _in, acct| {
                let batch = std::mem::take(&mut s.batch);
                acct.work(batch.len() as u64); // local grouping sweep
                // Pre-sized map: grouping was rehash-bound before (Perf
                // pass: RawTable::reserve_rehash was ~11% of stage time).
                let mut groups: DetMap<Addr, Vec<Task<A::Ctx>>> =
                    DetMap::with_capacity_and_hasher(batch.len(), Default::default());
                for t in batch {
                    groups.entry(t.read_addr).or_default().push(t);
                }
                let (_, leaf_idx) = forest.leaf(m);
                let mut out = Vec::new();
                for (addr, ctxs) in groups {
                    let root = owner_of(addr, p);
                    if self.direct_shortcut && ctxs.len() <= c {
                        // Low local contention: push contexts straight to
                        // the owner — "no hops on a communication tree".
                        out.push((root, (addr, MetaTaskSet::from_ctxs(ctxs))));
                    } else {
                        let mut set = MetaTaskSet::from_ctxs(ctxs);
                        let touched = set.normalize(c, &mut s.slots, m);
                        acct.work(touched);
                        s.holding.insert((addr, leaf_idx), set);
                    }
                }
                out
            },
            |msg: &(Addr, MetaTaskSet<Task<A::Ctx>>)| msg.1.words(sigma),
        );

        // 1b: merge shortcut arrivals at their owners and start the climb
        // (leaf level H → H-1).  With H == 0 (P == 1) the tree entries
        // never move — they are already at their owner.
        let mut climbing: Vec<Vec<(Addr, u64, MetaTaskSet<Task<A::Ctx>>)>> = sub.superstep(
            &mut st,
            direct_in,
            |m, s, inbox, acct| {
                for (addr, set) in inbox {
                    merge_at_root::<A>(&mut s.roots, &mut s.slots, m, addr, set, c, acct);
                }
                let mut out = Vec::new();
                if height == 0 {
                    let holding = std::mem::take(&mut s.holding);
                    for ((addr, _), set) in holding {
                        merge_at_root::<A>(&mut s.roots, &mut s.slots, m, addr, set, c, acct);
                    }
                } else {
                    for ((addr, idx), set) in s.holding.drain() {
                        let root = owner_of(addr, p);
                        let (pl, pidx) = forest.parent(height, idx);
                        out.push((forest.machine_of(root, pl, pidx), (addr, pidx, set)));
                    }
                }
                out
            },
            |msg: &(Addr, u64, MetaTaskSet<Task<A::Ctx>>)| msg.2.words(sigma),
        );

        // 1c: climb the forest one level per superstep; equal
        // (addr, node) sets merge on arrival, then move to their parent.
        for level in (1..height).rev() {
            climbing = sub.superstep(
                &mut st,
                climbing,
                |m, s, inbox, acct| {
                    for (addr, pidx, set) in inbox {
                        match s.holding.entry((addr, pidx)) {
                            Entry::Occupied(mut e) => {
                                let touched = e.get_mut().merge(set, c, &mut s.slots, m);
                                acct.work(touched);
                            }
                            Entry::Vacant(e) => {
                                let mut set = set;
                                let touched = set.normalize(c, &mut s.slots, m);
                                acct.work(touched);
                                e.insert(set);
                            }
                        }
                    }
                    let mut out = Vec::new();
                    for ((addr, idx), set) in s.holding.drain() {
                        let root = owner_of(addr, p);
                        let (pl, pidx) = forest.parent(level, idx);
                        out.push((forest.machine_of(root, pl, pidx), (addr, pidx, set)));
                    }
                    out
                },
                |msg: &(Addr, u64, MetaTaskSet<Task<A::Ctx>>)| msg.2.words(sigma),
            );
        }

        // ------------- Phase 2+3: co-location and execution -------------
        // Root processing: merge the final (level 1 → 0) arrivals, then
        // for every finalized meta-task set execute local contexts and
        // spawn pull trees for pointer entries.
        let mut pulls: Vec<Vec<PullMsg<A::Val>>> = sub.superstep(
            &mut st,
            climbing,
            |m, s, inbox, acct| {
                for (addr, _pidx, set) in inbox {
                    merge_at_root::<A>(&mut s.roots, &mut s.slots, m, addr, set, c, acct);
                }
                let roots = std::mem::take(&mut s.roots);
                // (val, tasks, tree_node): batched after collection.
                let mut exec_groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)> = Vec::new();
                let mut out: Vec<(MachineId, PullMsg<A::Val>)> = Vec::new();
                for (addr, set) in roots {
                    debug_assert_eq!(owner_of(addr, p), m, "final set not at owner");
                    let val: A::Val = s.shard.get(&addr).cloned().unwrap_or_default();
                    let mut ctxs: Vec<Task<A::Ctx>> = Vec::new();
                    let mut ptrs: Vec<(MachineId, u32)> = Vec::new();
                    for lvl in set.levels {
                        for mt in lvl {
                            match mt {
                                MetaTask::Ctx(t) => ctxs.push(t),
                                MetaTask::Ptr { holder, slot, .. } => ptrs.push((holder, slot)),
                            }
                        }
                    }
                    let tree_node = if ptrs.is_empty() {
                        None // pure push case: executes here, applies here
                    } else {
                        let id = s.nodes.len() as u32;
                        s.nodes.push(PullNode {
                            addr,
                            parent: None,
                            expected: ptrs.len() as u32,
                            received: 0,
                            acc: None,
                            sent: false,
                        });
                        for (holder, slot) in ptrs {
                            out.push((
                                holder,
                                PullMsg { addr, val: val.clone(), slot, parent: (m, id) },
                            ));
                        }
                        Some(id)
                    };
                    if !ctxs.is_empty() {
                        exec_groups.push((val, ctxs, tree_node));
                    }
                }
                execute_groups(app, exec_groups, s, acct);
                out
            },
            |_msg: &PullMsg<A::Val>| chunk_words + PULL_HDR_WORDS,
        );

        // Pull rounds: broadcast values down the meta-task trees, one
        // tree level per superstep, executing parked contexts on arrival.
        while pulls.iter().any(|v| !v.is_empty()) {
            pulls = sub.superstep(
                &mut st,
                pulls,
                |m, s, inbox, acct| {
                    let mut exec_groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)> =
                        Vec::new();
                    let mut out: Vec<(MachineId, PullMsg<A::Val>)> = Vec::new();
                    for PullMsg { addr, val, slot, parent } in inbox {
                        // Slot expansion is a single pass that the
                        // execution batch below already pays for per
                        // context; charge only the pointer handling here.
                        let content = s.slots.take(slot);
                        acct.work(1);
                        let mut ctxs: Vec<Task<A::Ctx>> = Vec::new();
                        let mut ptrs: Vec<(MachineId, u32)> = Vec::new();
                        for mt in content {
                            match mt {
                                MetaTask::Ctx(t) => ctxs.push(t),
                                MetaTask::Ptr { holder, slot, .. } => ptrs.push((holder, slot)),
                            }
                        }
                        let id = s.nodes.len() as u32;
                        s.nodes.push(PullNode {
                            addr,
                            parent: Some(parent),
                            expected: ptrs.len() as u32,
                            received: 0,
                            acc: None,
                            sent: false,
                        });
                        for (holder, pslot) in ptrs {
                            out.push((
                                holder,
                                PullMsg { addr, val: val.clone(), slot: pslot, parent: (m, id) },
                            ));
                        }
                        if !ctxs.is_empty() {
                            exec_groups.push((val, ctxs, Some(id)));
                        }
                    }
                    execute_groups(app, exec_groups, s, acct);
                    out
                },
                |_msg: &PullMsg<A::Val>| chunk_words + PULL_HDR_WORDS,
            );
        }

        // ------------- Phase 4a: reverse-tree write-back merge -----------
        let mut acks: Vec<Vec<AckMsg<A::Out>>> = sub.superstep(
            &mut st,
            no_messages(p),
            |_m, s, _in, acct| emit_ready_acks(s, app, acct),
            |_msg: &AckMsg<A::Out>| out_words + ACK_HDR_WORDS,
        );
        while acks.iter().any(|v| !v.is_empty()) {
            acks = sub.superstep(
                &mut st,
                acks,
                |_m, s, inbox, acct| {
                    for AckMsg { node, acc } in inbox {
                        let n = &mut s.nodes[node as usize];
                        n.received += 1;
                        if let Some(v) = acc {
                            n.acc = Some(match n.acc.take() {
                                Some(a) => app.combine(a, v),
                                None => v,
                            });
                            acct.work(1);
                        }
                    }
                    emit_ready_acks(s, app, acct)
                },
                |_msg: &AckMsg<A::Out>| out_words + ACK_HDR_WORDS,
            );
        }

        // ------------- Phase 4b: direct write-backs ---------------------
        let wb_in: Vec<Vec<(Addr, A::Out)>> = sub.superstep(
            &mut st,
            no_messages(p),
            |_m, s, _in, _acct| {
                let mut out = Vec::with_capacity(s.wb.len());
                for (addr, slot) in s.wb.drain() {
                    out.push((owner_of(addr, p), (addr, slot.expect("wb slot"))));
                }
                out
            },
            |_msg: &(Addr, A::Out)| out_words + WB_HDR_WORDS,
        );
        let _done: Vec<Vec<Nothing>> = sub.superstep(
            &mut st,
            wb_in,
            |_m, s, inbox, acct| {
                super::merge_and_apply(app, inbox, &mut s.shard, acct);
                Vec::new()
            },
            nothing_words,
        );

        super::finish_stage(
            store,
            st.into_iter().map(|s| (s.executed, s.shard)).collect(),
            submitted,
            "td-orch",
        )
    }
}
