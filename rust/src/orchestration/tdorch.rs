//! TD-Orch: the paper's four-phase push-pull orchestration engine (§3).
//!
//! Phase 1 — *contention detection*: every task's context climbs the
//! communication forest toward the machine owning its read chunk, merging
//! into meta-task sets at each transit node (so no machine ever receives
//! more than F bounded-size messages per node per round, even for a chunk
//! requested by all n tasks).
//!
//! Phase 2 — *co-location (distributed push-pull)*: at the root, a chunk
//! whose reference count is ≤ C already holds all requesting contexts (the
//! *push* completed during Phase 1 — no extra hops).  A contended chunk
//! instead *pulls*: its value is broadcast down the meta-task tree, level
//! by level, to every machine where contexts were parked.
//!
//! Phase 3 — *execution*: each machine executes its co-located (context,
//! value) pairs; the per-machine batch is funneled through
//! [`OrchApp::execute_batch`] so applications can dispatch to the
//! AOT-compiled XLA artifact.
//!
//! Phase 4 — *write-backs*: results aimed at the pulled chunk merge (⊗)
//! up the reverse meta-task tree; results aimed at other chunks are
//! pre-combined per machine and sent to their owners, which apply (⊙).

use crate::bsp::{Cluster, MachineId};
use crate::det::{det_map, DetMap};
use crate::forest::Forest;
use crate::metatask::{MetaTask, MetaTaskSet, SlotStore};
use crate::store::{Addr, DistStore};

use super::{OrchApp, Scheduler, StageOutcome, Task};

/// Wire overhead (words) of a pull-down message beyond the chunk value:
/// {addr, slot, parent machine, parent node}.
const PULL_HDR_WORDS: u64 = 4;
/// Wire overhead of an ack climbing the reverse tree: {node, has_value}.
const ACK_HDR_WORDS: u64 = 2;
/// Wire overhead of a direct write-back: {addr}.
const WB_HDR_WORDS: u64 = 1;

/// The TD-Orch scheduler.  `fanout`/`c` default to the paper's
/// theory-guided choices: F = Θ(log P / log log P), C = Θ(B/σ).
#[derive(Clone, Copy, Debug)]
pub struct TdOrch {
    pub fanout: Option<usize>,
    pub c: Option<usize>,
    /// Paper §3 key takeaway (a): a machine whose *local* reference count
    /// for a chunk is ≤ C sends those contexts straight to the owner (one
    /// hop) instead of climbing the forest; only locally-contended groups
    /// (a strong signal of global contention) take the aggregating tree
    /// path.  Disable to measure the ablation.
    pub direct_shortcut: bool,
}

impl Default for TdOrch {
    fn default() -> Self {
        TdOrch { fanout: None, c: None, direct_shortcut: true }
    }
}

impl TdOrch {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_params(fanout: usize, c: usize) -> Self {
        TdOrch { fanout: Some(fanout), c: Some(c), direct_shortcut: true }
    }

    pub fn without_shortcut() -> Self {
        TdOrch { direct_shortcut: false, ..Self::default() }
    }

    fn effective_c<A: OrchApp>(&self, app: &A) -> usize {
        self.c.unwrap_or_else(|| {
            let ratio = app.chunk_words() / app.sigma().max(1);
            (ratio as usize).clamp(2, 64)
        })
    }
}

/// A node of a pull tree (one per expanded slot, plus one per root).
struct PullNode<O> {
    addr: Addr,
    parent: Option<(MachineId, u32)>,
    expected: u32,
    received: u32,
    acc: Option<O>,
    sent: bool,
}

/// Value copy descending the meta-task tree.
struct PullMsg<V> {
    addr: Addr,
    val: V,
    slot: u32,
    parent: (MachineId, u32),
}

/// Merged write-back climbing the reverse tree.
struct AckMsg<O> {
    node: u32,
    acc: Option<O>,
}

impl<A: OrchApp> Scheduler<A> for TdOrch {
    fn name(&self) -> &'static str {
        "td-orch"
    }

    fn run_stage(
        &self,
        cluster: &mut Cluster,
        app: &A,
        tasks: Vec<Vec<Task<A::Ctx>>>,
        store: &mut DistStore<A::Val>,
    ) -> StageOutcome {
        let p = cluster.p;
        let forest = Forest::new(p, self.fanout.unwrap_or_else(|| Forest::default_fanout(p)));
        let c = self.effective_c(app);
        let sigma = app.sigma();
        let chunk_words = app.chunk_words();
        let out_words = app.out_words();

        let mut outcome = StageOutcome {
            executed_per_machine: vec![0; p],
            total_executed: 0,
        };

        // Per-machine parked-context storage (transit machines).
        let mut slots: Vec<SlotStore<Task<A::Ctx>>> = (0..p).map(|_| SlotStore::new()).collect();

        // ---------------- Phase 1: contention detection ----------------
        // holdings[m]: (addr, node_idx) -> meta-task set climbing the
        // tree, currently hosted on machine m.  root_sets[m]: fully
        // arrived sets at the owner (level 0).
        let mut holdings: Vec<DetMap<(Addr, u64), MetaTaskSet<Task<A::Ctx>>>> =
            (0..p).map(|_| det_map()).collect();
        let mut root_sets: Vec<DetMap<Addr, MetaTaskSet<Task<A::Ctx>>>> =
            (0..p).map(|_| det_map()).collect();
        // Direct-shortcut sends, folded into the first exchange round.
        let mut direct_out: Vec<Vec<(MachineId, (Addr, MetaTaskSet<Task<A::Ctx>>))>> =
            (0..p).map(|_| Vec::new()).collect();

        for (m, batch) in tasks.into_iter().enumerate() {
            cluster.work(m, batch.len() as u64); // local grouping sweep
            // Pre-sized map: grouping was rehash-bound before (Perf pass:
            // RawTable::reserve_rehash was ~11% of stage wall time).
            let mut groups: DetMap<Addr, Vec<Task<A::Ctx>>> =
                DetMap::with_capacity_and_hasher(batch.len(), Default::default());
            for t in batch {
                groups.entry(t.read_addr).or_default().push(t);
            }
            let (_, leaf_idx) = forest.leaf(m);
            for (addr, ctxs) in groups {
                let root = store.owner(addr);
                if self.direct_shortcut && ctxs.len() <= c {
                    // Low local contention: push contexts straight to the
                    // owner — "no hops on a communication tree".
                    direct_out[m].push((root, (addr, MetaTaskSet::from_ctxs(ctxs))));
                } else {
                    let mut set = MetaTaskSet::from_ctxs(ctxs);
                    let touched = set.normalize(c, &mut slots[m], m);
                    cluster.work(m, touched);
                    holdings[m].insert((addr, leaf_idx), set);
                }
            }
        }
        cluster.barrier();

        // Helper to merge a set arriving at the owner (level 0).
        let merge_at_root =
            |cluster: &mut Cluster,
             root_sets: &mut Vec<DetMap<Addr, MetaTaskSet<Task<A::Ctx>>>>,
             slots: &mut Vec<SlotStore<Task<A::Ctx>>>,
             m: MachineId,
             addr: Addr,
             set: MetaTaskSet<Task<A::Ctx>>| {
                match root_sets[m].entry(addr) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        let touched = e.get_mut().merge(set, c, &mut slots[m], m);
                        cluster.work(m, touched);
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        let mut set = set;
                        let touched = set.normalize(c, &mut slots[m], m);
                        cluster.work(m, touched);
                        e.insert(set);
                    }
                }
            };

        // Deliver the direct-shortcut contexts (one superstep).
        if direct_out.iter().any(|o| !o.is_empty()) {
            let inboxes = cluster.exchange(direct_out, |(_, set)| set.words(sigma));
            for (m, inbox) in inboxes.into_iter().enumerate() {
                for (addr, set) in inbox {
                    merge_at_root(cluster, &mut root_sets, &mut slots, m, addr, set);
                }
            }
        }

        // Climb the forest: entries at level l move to their parent node
        // at level l-1; equal (addr, parent_idx) sets merge on arrival.
        for level in (1..=forest.height()).rev() {
            let mut outboxes: Vec<Vec<(MachineId, (Addr, u64, MetaTaskSet<Task<A::Ctx>>))>> =
                (0..p).map(|_| Vec::new()).collect();
            for (m, holding) in holdings.iter_mut().enumerate() {
                for ((addr, idx), set) in holding.drain() {
                    let root = store.owner(addr);
                    let (pl, pidx) = forest.parent(level, idx);
                    let dest = forest.machine_of(root, pl, pidx);
                    outboxes[m].push((dest, (addr, pidx, set)));
                }
            }
            let inboxes = cluster.exchange(outboxes, |(_, _, set)| set.words(sigma));
            let at_root = level == 1;
            for (m, inbox) in inboxes.into_iter().enumerate() {
                for (addr, pidx, set) in inbox {
                    if at_root {
                        merge_at_root(cluster, &mut root_sets, &mut slots, m, addr, set);
                        continue;
                    }
                    match holdings[m].entry((addr, pidx)) {
                        std::collections::hash_map::Entry::Occupied(mut e) => {
                            let touched = e.get_mut().merge(set, c, &mut slots[m], m);
                            cluster.work(m, touched);
                        }
                        std::collections::hash_map::Entry::Vacant(e) => {
                            let mut set = set;
                            let touched = set.normalize(c, &mut slots[m], m);
                            cluster.work(m, touched);
                            e.insert(set);
                        }
                    }
                }
            }
        }
        // P == 1 (height 0): tree entries never moved; they are already at
        // their owner.
        if forest.height() == 0 {
            for m in 0..p {
                let holding = std::mem::take(&mut holdings[m]);
                for ((addr, _), set) in holding {
                    merge_at_root(cluster, &mut root_sets, &mut slots, m, addr, set);
                }
            }
        }

        // ------------- Phase 2+3: co-location and execution -------------
        // Pull-tree bookkeeping (one node per expanded slot / root).
        let mut nodes: Vec<Vec<PullNode<A::Out>>> = (0..p).map(|_| Vec::new()).collect();
        // Direct write-back pool: (machine) -> write_addr -> merged out.
        // Option-wrapped values allow in-place ⊗ with one hash lookup.
        let mut wb: Vec<DetMap<Addr, Option<A::Out>>> = (0..p).map(|_| det_map()).collect();
        // Pull messages produced this round, to be exchanged.
        let mut pull_out: Vec<Vec<(MachineId, PullMsg<A::Val>)>> =
            (0..p).map(|_| Vec::new()).collect();

        // Root processing: for every final meta-task set, execute local
        // contexts; spawn pull trees for pointer entries.
        for m in 0..p {
            let holding = std::mem::take(&mut root_sets[m]);
            // (val, tasks, tree_node): batched after collection.
            let mut exec_groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)> = Vec::new();
            for (addr, set) in holding {
                debug_assert_eq!(store.owner(addr), m, "final set not at owner");
                let val = store.read_copy(addr);
                let mut ctxs: Vec<Task<A::Ctx>> = Vec::new();
                let mut ptrs: Vec<(MachineId, u32)> = Vec::new();
                for lvl in set.levels {
                    for mt in lvl {
                        match mt {
                            MetaTask::Ctx(t) => ctxs.push(t),
                            MetaTask::Ptr { holder, slot, .. } => ptrs.push((holder, slot)),
                        }
                    }
                }
                let tree_node = if ptrs.is_empty() {
                    None // pure push case: executes here, applies here
                } else {
                    let id = nodes[m].len() as u32;
                    nodes[m].push(PullNode {
                        addr,
                        parent: None,
                        expected: ptrs.len() as u32,
                        received: 0,
                        acc: None,
                        sent: false,
                    });
                    for (holder, slot) in ptrs {
                        pull_out[m].push((
                            holder,
                            PullMsg { addr, val: val.clone(), slot, parent: (m, id) },
                        ));
                    }
                    Some(id)
                };
                if !ctxs.is_empty() {
                    exec_groups.push((val, ctxs, tree_node));
                }
            }
            execute_groups(cluster, app, m, exec_groups, &mut nodes, &mut wb, &mut outcome);
        }
        cluster.barrier();

        // Pull rounds: broadcast values down the meta-task trees.
        loop {
            let any = pull_out.iter().any(|o| !o.is_empty());
            if !any {
                break;
            }
            let outboxes = std::mem::replace(
                &mut pull_out,
                (0..p).map(|_| Vec::new()).collect(),
            );
            let inboxes =
                cluster.exchange(outboxes, |_msg| chunk_words + PULL_HDR_WORDS);
            for (m, inbox) in inboxes.into_iter().enumerate() {
                let mut exec_groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)> = Vec::new();
                for PullMsg { addr, val, slot, parent } in inbox {
                    // Slot expansion is a single pass that the execution
                    // batch below already pays for per context; charge
                    // only the pointer handling here.
                    let content = slots[m].take(slot);
                    cluster.work(m, 1);
                    let mut ctxs: Vec<Task<A::Ctx>> = Vec::new();
                    let mut ptrs: Vec<(MachineId, u32)> = Vec::new();
                    for mt in content {
                        match mt {
                            MetaTask::Ctx(t) => ctxs.push(t),
                            MetaTask::Ptr { holder, slot, .. } => ptrs.push((holder, slot)),
                        }
                    }
                    let id = nodes[m].len() as u32;
                    nodes[m].push(PullNode {
                        addr,
                        parent: Some(parent),
                        expected: ptrs.len() as u32,
                        received: 0,
                        acc: None,
                        sent: false,
                    });
                    for (holder, pslot) in ptrs {
                        pull_out[m].push((
                            holder,
                            PullMsg { addr, val: val.clone(), slot: pslot, parent: (m, id) },
                        ));
                    }
                    if !ctxs.is_empty() {
                        exec_groups.push((val, ctxs, Some(id)));
                    }
                }
                execute_groups(cluster, app, m, exec_groups, &mut nodes, &mut wb, &mut outcome);
            }
        }

        // ------------- Phase 4a: reverse-tree write-back merge -----------
        loop {
            let mut ack_out: Vec<Vec<(MachineId, AckMsg<A::Out>)>> =
                (0..p).map(|_| Vec::new()).collect();
            let mut sent_any = false;
            for m in 0..p {
                for node in nodes[m].iter_mut() {
                    if !node.sent && node.received == node.expected {
                        node.sent = true;
                        sent_any = true;
                        match node.parent {
                            Some((pm, pid)) => {
                                ack_out[m].push((pm, AckMsg { node: pid, acc: node.acc.take() }));
                            }
                            None => {
                                // Root: apply the fully merged write-back.
                                if let Some(out) = node.acc.take() {
                                    app.apply(store.get_or_default(node.addr), out);
                                    cluster.work(m, 1);
                                }
                            }
                        }
                    }
                }
            }
            if !sent_any {
                break;
            }
            let inboxes = cluster.exchange(ack_out, |_| out_words + ACK_HDR_WORDS);
            for (m, inbox) in inboxes.into_iter().enumerate() {
                for AckMsg { node, acc } in inbox {
                    let n = &mut nodes[m][node as usize];
                    n.received += 1;
                    if let Some(v) = acc {
                        n.acc = Some(match n.acc.take() {
                            Some(a) => app.combine(a, v),
                            None => v,
                        });
                        cluster.work(m, 1);
                    }
                }
            }
        }

        // ------------- Phase 4b: direct write-backs ---------------------
        let mut wb_out: Vec<Vec<(MachineId, (Addr, A::Out))>> =
            (0..p).map(|_| Vec::new()).collect();
        for (m, pool) in wb.iter_mut().enumerate() {
            for (addr, out) in pool.drain() {
                wb_out[m].push((store.owner(addr), (addr, out.expect("wb slot"))));
            }
        }
        let inboxes = cluster.exchange(wb_out, |_| out_words + WB_HDR_WORDS);
        for (m, inbox) in inboxes.into_iter().enumerate() {
            let mut merged: DetMap<Addr, Option<A::Out>> = det_map();
            for (addr, out) in inbox {
                cluster.work(m, 1);
                let slot = merged.entry(addr).or_insert(None);
                *slot = Some(match slot.take() {
                    Some(acc) => app.combine(acc, out),
                    None => out,
                });
            }
            // Drain once + sort (one hash op per address instead of two).
            let mut pairs: Vec<(Addr, A::Out)> = merged
                .drain()
                .map(|(a, o)| (a, o.expect("merged slot")))
                .collect();
            pairs.sort_unstable_by_key(|(a, _)| *a);
            for (addr, out) in pairs {
                app.apply(store.get_or_default(addr), out);
            }
        }

        outcome.total_executed = outcome.executed_per_machine.iter().sum();
        outcome
    }
}

/// Phase-3 helper: batch-execute groups of co-located (value, tasks) on
/// machine `m`, then route each write-back — into the group's pull-tree
/// node (reverse-tree path) when it targets the pulled chunk, else into
/// the direct write-back pool.
#[allow(clippy::too_many_arguments)]
fn execute_groups<A: OrchApp>(
    cluster: &mut Cluster,
    app: &A,
    m: MachineId,
    groups: Vec<(A::Val, Vec<Task<A::Ctx>>, Option<u32>)>,
    nodes: &mut [Vec<PullNode<A::Out>>],
    wb: &mut [DetMap<Addr, Option<A::Out>>],
    outcome: &mut StageOutcome,
) {
    if groups.is_empty() {
        return;
    }
    // One flat batch per machine: this is the XLA dispatch point.
    let items: Vec<(&A::Ctx, &A::Val)> = groups
        .iter()
        .flat_map(|(val, tasks, _)| tasks.iter().map(move |t| (&t.ctx, val)))
        .collect();
    let mut outs: Vec<Option<A::Out>> = Vec::with_capacity(items.len());
    app.execute_batch(&items, &mut outs);
    debug_assert_eq!(outs.len(), items.len());
    let n_tasks = items.len() as u64;
    cluster.work(m, n_tasks * app.task_work());
    cluster.executed(m, n_tasks);
    outcome.executed_per_machine[m] += n_tasks;

    let mut it = outs.into_iter();
    for (_, tasks, tree_node) in groups {
        for t in tasks {
            let Some(out) = it.next().expect("execute_batch arity") else {
                continue;
            };
            let group_addr = tree_node.map(|id| nodes[m][id as usize].addr);
            match tree_node {
                Some(id) if group_addr == Some(t.write_addr) => {
                    let node = &mut nodes[m][id as usize];
                    node.acc = Some(match node.acc.take() {
                        Some(a) => app.combine(a, out),
                        None => out,
                    });
                    cluster.work(m, 1);
                }
                _ => {
                    // Pure push at the owner (write==read) lands here too:
                    // owner(write_addr) == m makes the send free.
                    let slot = wb[m].entry(t.write_addr).or_insert(None);
                    *slot = Some(match slot.take() {
                        Some(acc) => app.combine(acc, out),
                        None => out,
                    });
                    cluster.work(m, 1);
                }
            }
        }
    }
}
