//! Distributed key-value store — the paper's Case Study I (§4).
//!
//! A concurrent distributed hash table expressed as a one-stage
//! orchestration: chunks are hash buckets of (key, value) pairs, tasks are
//! read/update operations, the lambda is the YCSB multiply-and-add, and
//! write-backs resolve concurrent updates deterministically by sequence
//! number (Def. 2 class iv merge-able writes).
//!
//! Phase-3 execution can be offloaded to the AOT-compiled Pallas `fma`
//! kernel through [`crate::runtime::Engine`] (see [`KvApp::with_engine`]):
//! the per-machine co-located batch is packed into (vals, mul, add)
//! arrays, executed by PJRT, and scattered back — the three-layer hot
//! path with Python nowhere in sight.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::orchestration::OrchApp;
use crate::rng::hash64;
use crate::runtime::Engine;
use crate::store::{Addr, DistStore};

/// Target number of records per bucket.
pub const BUCKET_TARGET: u64 = 8;
/// Words per YCSB record: standard YCSB uses 1 KB values (10 x 100 B
/// fields) + key ≈ 130 words.  The simulator tracks only the one f32
/// field the multiply-add touches, but the *wire* cost of moving a
/// bucket is the full record payload.
pub const RECORD_WORDS: u64 = 130;
/// Chunk granularity B in words: a bucket of 8 records.
pub const BUCKET_WORDS: u64 = BUCKET_TARGET * RECORD_WORDS;

/// One hash bucket: a small vector of (key, value) pairs.
pub type Bucket = Vec<(u64, f32)>;

/// The operation kind of one KV task.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum KvKind {
    /// Fetch + multiply-add, discard result (YCSB read).
    Read,
    /// Fetch + multiply-add, write back (YCSB update; also insert).
    Update { mul: f32, add: f32 },
}

/// Task context: the operation closure (σ = 4 words: key, kind+mul, add,
/// seq).
#[derive(Clone, Copy, Debug)]
pub struct KvOp {
    pub key: u64,
    pub kind: KvKind,
    /// Global sequence number: ties between concurrent writers to the
    /// same key resolve to the *largest* seq — a deterministic decision
    /// process per Def. 2(iv).
    pub seq: u64,
}

impl KvOp {
    pub fn read(key: u64, seq: u64) -> Self {
        KvOp { key, kind: KvKind::Read, seq }
    }

    pub fn update(key: u64, seq: u64, mul: f32, add: f32) -> Self {
        KvOp { key, kind: KvKind::Update { mul, add }, seq }
    }

    pub fn is_write(&self) -> bool {
        matches!(self.kind, KvKind::Update { .. })
    }

    /// The bucket (chunk address) this key lives in.
    pub fn bucket(&self, buckets: u64) -> Addr {
        hash64(self.key) % buckets
    }
}

/// Write-back: one winning (key → value) per bucket update, plus losers
/// folded away by ⊗.  Multiple distinct keys in the same bucket are kept.
#[derive(Clone, Debug, Default)]
pub struct KvWriteSet {
    /// (key, value, seq) — at most one entry per key after ⊗.
    pub writes: Vec<(u64, f32, u64)>,
}

/// The KV application: implements the Fig 1 closure triple.
///
/// `Sync` by construction (atomic counter, shared engine reference): the
/// threaded execution substrate calls [`OrchApp::execute_batch`] from P
/// worker threads concurrently.
pub struct KvApp<'e> {
    pub buckets: u64,
    engine: Option<&'e Engine>,
    /// Count of lambda invocations served by the XLA artifact.
    xla_served: AtomicU64,
}

impl<'e> KvApp<'e> {
    pub fn new(buckets: u64) -> Self {
        KvApp { buckets, engine: None, xla_served: AtomicU64::new(0) }
    }

    /// Execute Phase-3 lambdas on the AOT-compiled Pallas kernel.
    pub fn with_engine(buckets: u64, engine: &'e Engine) -> Self {
        KvApp { buckets, engine: Some(engine), xla_served: AtomicU64::new(0) }
    }

    pub fn xla_served(&self) -> u64 {
        self.xla_served.load(Ordering::Relaxed)
    }

    fn lookup(bucket: &Bucket, key: u64) -> f32 {
        bucket
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    fn out_for(op: &KvOp, new_val: f32) -> Option<KvWriteSet> {
        match op.kind {
            KvKind::Read => None,
            KvKind::Update { .. } => Some(KvWriteSet {
                writes: vec![(op.key, new_val, op.seq)],
            }),
        }
    }
}

impl OrchApp for KvApp<'_> {
    type Ctx = KvOp;
    type Val = Bucket;
    type Out = KvWriteSet;

    fn sigma(&self) -> u64 {
        4
    }

    fn chunk_words(&self) -> u64 {
        BUCKET_WORDS
    }

    fn out_words(&self) -> u64 {
        // A write-back carries the updated record.
        RECORD_WORDS + 2
    }

    fn execute(&self, op: &KvOp, bucket: &Bucket) -> Option<KvWriteSet> {
        let v = Self::lookup(bucket, op.key);
        let (mul, add) = match op.kind {
            KvKind::Read => (1.0f32, 0.0f32), // fetch + mul-add, discarded
            KvKind::Update { mul, add } => (mul, add),
        };
        Self::out_for(op, v * mul + add)
    }

    /// ⊗: per key, the largest sequence number wins (deterministic
    /// resolution of concurrent writes).
    fn combine(&self, mut a: KvWriteSet, b: KvWriteSet) -> KvWriteSet {
        for (k, v, seq) in b.writes {
            match a.writes.iter_mut().find(|(k2, _, _)| *k2 == k) {
                Some(slot) => {
                    if seq > slot.2 {
                        *slot = (k, v, seq);
                    }
                }
                None => a.writes.push((k, v, seq)),
            }
        }
        a
    }

    /// ⊙: install winning values in the bucket (insert-or-overwrite).
    fn apply(&self, bucket: &mut Bucket, out: KvWriteSet) {
        for (k, v, _) in out.writes {
            match bucket.iter_mut().find(|(k2, _)| *k2 == k) {
                Some(slot) => slot.1 = v,
                None => bucket.push((k, v)),
            }
        }
    }

    /// Phase-3 batch: pack lambdas into (vals, mul, add) arrays and run
    /// the AOT Pallas `fma` artifact when an engine is attached.
    fn execute_batch(
        &self,
        items: &[(&KvOp, &Bucket)],
        sink: &mut Vec<Option<KvWriteSet>>,
    ) {
        let Some(engine) = self.engine else {
            sink.extend(items.iter().map(|(op, b)| self.execute(op, b)));
            return;
        };
        let mut vals = Vec::with_capacity(items.len());
        let mut muls = Vec::with_capacity(items.len());
        let mut adds = Vec::with_capacity(items.len());
        for (op, bucket) in items {
            vals.push(Self::lookup(bucket, op.key));
            let (m, a) = match op.kind {
                KvKind::Read => (1.0, 0.0),
                KvKind::Update { mul, add } => (mul, add),
            };
            muls.push(m);
            adds.push(a);
        }
        match engine.ycsb_batch(&vals, &muls, &adds) {
            Ok(outs) => {
                self.xla_served.fetch_add(items.len() as u64, Ordering::Relaxed);
                for ((op, _), new_val) in items.iter().zip(outs) {
                    sink.push(Self::out_for(op, new_val));
                }
            }
            Err(e) => {
                // Engine failure is a bug in artifact generation — make it
                // loud in debug, degrade gracefully in release.
                if cfg!(debug_assertions) {
                    panic!("XLA batch failed: {e}");
                }
                sink.extend(items.iter().map(|(op, b)| self.execute(op, b)));
            }
        }
    }
}

/// Pre-load a store with `n_keys` sequential keys (value = key as f32),
/// as the paper's experiments do before timed batches.
pub fn preload(store: &mut DistStore<Bucket>, buckets: u64, n_keys: u64) {
    for key in 0..n_keys {
        let addr = hash64(key) % buckets;
        store.get_or_default(addr).push((key, key as f32));
    }
}

/// Canonical normalization for comparing bucket stores across schedulers
/// and substrates: bucket vectors are insertion-ordered (different
/// schedulers insert new keys in different orders), so sort each bucket
/// by key and compare f32 values bit-exactly.
pub fn normalized_snapshot(store: &DistStore<Bucket>) -> Vec<(Addr, Vec<(u64, u32)>)> {
    store
        .snapshot()
        .into_iter()
        .map(|(a, mut b)| {
            b.sort_by_key(|(k, _)| *k);
            (a, b.into_iter().map(|(k, v)| (k, v.to_bits())).collect())
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestration::{sequential_reference, spread_tasks, Scheduler, Task};
    use crate::orchestration::tdorch::TdOrch;
    use crate::{Cluster, CostModel};

    #[test]
    fn read_produces_no_writeback() {
        let app = KvApp::new(64);
        let bucket: Bucket = vec![(5, 2.0)];
        assert!(app.execute(&KvOp::read(5, 1), &bucket).is_none());
    }

    #[test]
    fn update_multiplies_and_adds() {
        let app = KvApp::new(64);
        let bucket: Bucket = vec![(5, 2.0)];
        let out = app.execute(&KvOp::update(5, 1, 3.0, 1.0), &bucket).unwrap();
        assert_eq!(out.writes, vec![(5, 7.0, 1)]);
    }

    #[test]
    fn missing_key_reads_zero() {
        let app = KvApp::new(64);
        let out = app.execute(&KvOp::update(9, 1, 3.0, 4.0), &vec![]).unwrap();
        assert_eq!(out.writes, vec![(9, 4.0, 1)]); // 0*3+4
    }

    #[test]
    fn combine_picks_highest_seq() {
        let app = KvApp::new(64);
        let a = KvWriteSet { writes: vec![(1, 10.0, 5)] };
        let b = KvWriteSet { writes: vec![(1, 20.0, 9), (2, 1.0, 3)] };
        let m = app.combine(a, b);
        assert!(m.writes.contains(&(1, 20.0, 9)));
        assert!(m.writes.contains(&(2, 1.0, 3)));
        // Commutativity: the other order gives the same set.
        let a = KvWriteSet { writes: vec![(1, 10.0, 5)] };
        let b = KvWriteSet { writes: vec![(1, 20.0, 9), (2, 1.0, 3)] };
        let m2 = app.combine(b, a);
        let norm = |mut w: Vec<(u64, f32, u64)>| {
            w.sort_by_key(|(k, _, _)| *k);
            w
        };
        assert_eq!(norm(m.writes), norm(m2.writes));
    }

    #[test]
    fn apply_inserts_and_overwrites() {
        let app = KvApp::new(64);
        let mut bucket: Bucket = vec![(1, 1.0)];
        app.apply(
            &mut bucket,
            KvWriteSet { writes: vec![(1, 5.0, 2), (7, 9.0, 3)] },
        );
        assert_eq!(bucket, vec![(1, 5.0), (7, 9.0)]);
    }

    #[test]
    fn kv_via_tdorch_matches_reference() {
        let app = KvApp::new(128);
        let p = 8;
        let mut ops = Vec::new();
        for i in 0..2000u64 {
            let key = i % 300;
            let op = if i % 3 == 0 {
                KvOp::read(key, i)
            } else {
                KvOp::update(key, i, 1.5, 0.5)
            };
            ops.push(Task::inplace(op.bucket(128), op));
        }
        let spread = spread_tasks(ops, p);

        let mut expected: DistStore<Bucket> = DistStore::new(p);
        preload(&mut expected, 128, 300);
        sequential_reference(&app, &spread, &mut expected);

        let mut store: DistStore<Bucket> = DistStore::new(p);
        preload(&mut store, 128, 300);
        let mut cluster = Cluster::new(p, CostModel::paper_cluster());
        TdOrch::new().run_stage(&mut cluster, &app, spread, &mut store);

        let norm = |s: &DistStore<Bucket>| {
            let mut all: Vec<(u64, Vec<(u64, u32)>)> = s
                .snapshot()
                .into_iter()
                .map(|(a, mut b)| {
                    b.sort_by_key(|(k, _)| *k);
                    (a, b.into_iter().map(|(k, v)| (k, v.to_bits())).collect())
                })
                .collect();
            all.sort();
            all
        };
        assert_eq!(norm(&store), norm(&expected));
    }
}
