//! # TD-Orch / TDO-GP
//!
//! A from-scratch reproduction of *"TD-Orch: Scalable Load-Balancing for
//! Distributed Systems with Applications to Graph Processing"* (CS.DC
//! 2025): the task-data orchestration abstraction (Fig 1), the TD-Orch
//! push-pull scheduler (§3), the three baseline schedulers it is evaluated
//! against (§2.3), the distributed KV-store case study (§4), and the
//! TDO-GP distributed graph-processing system (§5–6) — all running on an
//! executable BSP cluster model (§2.2) with full per-machine communication
//! and computation accounting.
//!
//! Layer map (see DESIGN.md and rust/README.md):
//! * L3 (this crate): coordinator, schedulers, graph engine, metrics.
//! * L2/L1 (python/, build-time): JAX models + Pallas kernels, AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * [`runtime`]: loads the artifacts via PJRT and executes them from the
//!   Phase-3 hot path — Python is never on the request path.
//!
//! Execution substrates ([`exec`]): every scheduler is written against
//! the [`exec::Substrate`] superstep API and runs unchanged on either the
//! BSP cost-model *simulator* ([`Cluster`]) or the *real* shared-nothing
//! threaded backend ([`exec::ThreadedCluster`]) — one OS worker thread
//! per logical machine, channels, a reusable barrier, and measured
//! per-machine wall-clock.
//!
//! Serving ([`serve`]): an online layer that admits a continuous Zipf
//! query stream ({BFS, SSSP, PR, CC, BC}), batches it deterministically, and
//! dispatches on a long-lived `SpmdEngine` — one ingestion and one
//! worker pool per process, queries separated by
//! `SpmdEngine::reset_for_query`.  Live mutation ([`mutate`]): seeded
//! edge delta batches absorbed in place between dispatches
//! (`SpmdEngine::apply_delta`), each bumping an epoch stamped on every
//! result — still one ingestion per process.  Adaptive placement
//! ([`place`]): a deterministic controller that watches the flight
//! recorder's per-machine work signal and migrates/replicates hot edge
//! blocks between dispatches (`SpmdEngine::apply_placement`) — the
//! serve→observe→migrate→serve loop, bit-identical across backends.

pub mod baselines;
pub mod kvstore;
pub mod bsp;
pub mod det;
pub mod exec;
pub mod forest;
pub mod graph;
pub mod metatask;
pub mod metrics;
pub mod mutate;
pub mod obs;
pub mod orchestration;
pub mod place;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod workload;

pub use bsp::{Cluster, CostModel, MachineId, NumaTopo};
pub use exec::{Substrate, ThreadedCluster};
pub use metrics::{Breakdown, Metrics, Report};
pub use orchestration::{OrchApp, Scheduler, StageOutcome, Task};
pub use store::{Addr, DistStore};
