//! BSP cost model (paper §2.2, Appendix A).
//!
//! The simulator charges each superstep
//! `g * max_m(max(sent_m, recv_m)) + w * max_m(work_m) + ov * max_m(msgs_m) + L`
//! — exactly the h-relation structure the paper analyzes.  Because every
//! term takes the *maximum* over machines, load balance is what the model
//! rewards; that is the whole point of TD-Orch.

/// NUMA topology of a simulated machine (paper §6.5 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NumaTopo {
    /// Four NUMA nodes in a square: some node pairs are 2 hops apart, which
    /// penalizes NUMA-oblivious parallel local computation (the paper's
    /// budget cluster).
    Square4,
    /// Single NUMA node per machine (Table 5 configuration).
    Single,
    /// Four nodes, all-to-all interconnect (Table 6's Xeon E7 server).
    AllToAll4,
}

impl NumaTopo {
    /// Multiplier on local-computation time for a NUMA-*oblivious*
    /// parallel runtime (the paper attributes TDO-GP's two PR losses to
    /// this).  NUMA-aware engines take no penalty.
    pub fn compute_penalty(self) -> f64 {
        match self {
            // Remote-node cache traffic inflates memory-bound scans.
            NumaTopo::Square4 => 1.55,
            NumaTopo::Single => 1.0,
            NumaTopo::AllToAll4 => 1.08,
        }
    }
}

/// Time constants for one simulated cluster.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Seconds per 8-byte word communicated (the BSP `g`).
    pub g: f64,
    /// Barrier/synchronization cost per superstep (the BSP `L`).
    pub l: f64,
    /// Seconds per unit of local work (one task lambda / edge relaxation),
    /// already divided by per-machine parallelism.
    pub work_unit: f64,
    /// Fixed per-message overhead (packing, matching, dispatch) — this is
    /// the "Overhead" series of the paper's Fig 10 breakdown.
    pub per_msg: f64,
    /// NUMA topology of each machine.
    pub numa: NumaTopo,
}

impl CostModel {
    /// Calibration note (DESIGN.md §2): datasets here are ~1000x smaller
    /// than the paper's, so the barrier/latency floor is scaled down with
    /// them — otherwise every per-round work difference (the O(n·diam)
    /// terms that drive Table 2) would drown under L and the *shapes*
    /// would be lost.  work_unit is the effective memory-bound cost per
    /// edge/vertex touch; g matches 10 GbE; per_msg is per packed item;
    /// unbatched RPCs pay `RPC_MSG_FACTOR` per-msg units instead
    /// (`Cluster::set_msg_factor`).
    pub fn paper_cluster() -> Self {
        CostModel {
            g: 8.0e-9,
            l: 2.0e-6,
            work_unit: 5.0e-8,
            per_msg: 1.0e-8,
            numa: NumaTopo::Square4,
        }
    }

    /// Table 5: one NUMA node per machine — no square-topology penalty but
    /// only a quarter of the cores.
    pub fn single_numa() -> Self {
        CostModel {
            work_unit: 5.0e-8 * 4.0,
            numa: NumaTopo::Single,
            ..Self::paper_cluster()
        }
    }

    /// Table 6: single 144-core Xeon E7 with all-to-all NUMA; "network"
    /// is shared memory (g tiny, barriers cheap).
    pub fn big_numa_server() -> Self {
        CostModel {
            g: 2.0e-10,
            l: 5.0e-7,
            work_unit: 1.5e-8,
            per_msg: 2.0e-9,
            numa: NumaTopo::AllToAll4,
        }
    }

    /// Seconds for `units` of work.  NUMA penalties are applied by the
    /// engines per their runtime's NUMA-awareness (paper §6.5: ParlayLib
    /// -based TDO-GP is NUMA-oblivious, Gemini/Graphite are NUMA-aware),
    /// not here.
    #[inline]
    pub fn work_seconds(&self, units: u64) -> f64 {
        units as f64 * self.work_unit
    }
}

impl Default for CostModel {
    fn default() -> Self {
        Self::paper_cluster()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_ordered_sensibly() {
        let paper = CostModel::paper_cluster();
        let single = CostModel::single_numa();
        let big = CostModel::big_numa_server();
        // Single-NUMA machines have fewer cores -> slower per unit.
        assert!(single.work_unit > paper.work_unit);
        // The big server's interconnect is much faster than 10 GbE.
        assert!(big.g < paper.g);
        assert!(big.l < paper.l);
    }

    #[test]
    fn numa_penalty_ranking() {
        assert!(NumaTopo::Square4.compute_penalty() > NumaTopo::AllToAll4.compute_penalty());
        assert_eq!(NumaTopo::Single.compute_penalty(), 1.0);
    }
}
