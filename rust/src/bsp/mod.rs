//! BSP cluster simulator — the paper's §2.2 machine model, executable.
//!
//! `P` logical machines, no shared memory, point-to-point messages, barrier
//! -separated supersteps.  The simulator runs in-process but *accounts*
//! every word sent/received and every unit of local work per machine, then
//! charges the superstep with the BSP h-relation cost (see [`cost`]).
//! Because all reported "runtimes" are derived from these maxima, the
//! win/lose relationships between schedulers depend only on their
//! communication/computation structure — which is what the reproduction
//! must preserve — not on host wall-clock noise.

pub mod cost;

pub use cost::{CostModel, NumaTopo};

use crate::metrics::Metrics;

/// Index of a physical machine in the cluster: `0..P`.
pub type MachineId = usize;

/// Per-message overhead multiplier for *unbatched* remote operations
/// (RPC-style requests that cannot be packed with their neighbors — e.g.
/// per-edge direct pulls): a ~10 µs round-trip against the ~0.1 µs
/// amortized cost of a packed message item.  Engines select it per
/// superstep via [`Cluster::set_msg_factor`].
pub const RPC_MSG_FACTOR: u64 = 300;

/// Per-superstep accumulator, folded into [`Metrics`] at each barrier.
#[derive(Clone, Debug, Default)]
struct StepAccum {
    sent: Vec<u64>,
    recv: Vec<u64>,
    work: Vec<u64>,
    msgs: Vec<u64>,
    /// Cross-machine messages *sent* per machine, unfactored — the ledger
    /// message count the flight recorder reports.  Kept separate from
    /// `msgs`, which is an overhead-*time* quantity (both endpoints pay,
    /// scaled by `msg_factor`) and therefore not backend-comparable.
    sent_msgs: Vec<u64>,
    dirty: bool,
}

impl StepAccum {
    fn new(p: usize) -> Self {
        StepAccum {
            sent: vec![0; p],
            recv: vec![0; p],
            work: vec![0; p],
            msgs: vec![0; p],
            sent_msgs: vec![0; p],
            dirty: false,
        }
    }

    fn reset(&mut self) {
        self.sent.fill(0);
        self.recv.fill(0);
        self.work.fill(0);
        self.msgs.fill(0);
        self.sent_msgs.fill(0);
        self.dirty = false;
    }
}

/// A simulated BSP cluster: the substrate every scheduler in this repo
/// (TD-Orch, the three §2.3 baselines, and all graph engines) runs on.
pub struct Cluster {
    pub p: usize,
    pub cost: CostModel,
    pub metrics: Metrics,
    step: StepAccum,
    /// Per-message overhead units charged to both endpoints of each
    /// accounted message (1 = packed item; [`RPC_MSG_FACTOR`] = RPC).
    msg_factor: u64,
    /// Attached flight recorder, if any.  `None` (the default) skips all
    /// event work — the observer is zero-cost when disabled.
    observer: Option<crate::obs::ObserverHandle>,
}

impl Cluster {
    pub fn new(p: usize, cost: CostModel) -> Self {
        assert!(p >= 1, "cluster needs at least one machine");
        Cluster {
            p,
            cost,
            metrics: Metrics::new(p),
            step: StepAccum::new(p),
            msg_factor: 1,
            observer: None,
        }
    }

    /// Set the per-message overhead multiplier applied to messages
    /// accounted from now on: 1 (default) for packed/batched message
    /// items, [`RPC_MSG_FACTOR`] for unbatchable RPC round-trips.  Only
    /// the overhead *time* term (`per_msg * max_msgs`) sees the factor;
    /// the ledger (words, message counts, work) is unaffected, which is
    /// what keeps the simulator ledger bit-comparable to the measured
    /// threaded backend whatever the factor.
    pub fn set_msg_factor(&mut self, factor: u64) {
        self.msg_factor = factor.max(1);
    }

    /// Attach (or detach) a flight recorder.  While attached, every
    /// *ledger* superstep (the `dirty` ones — empty barriers still record
    /// nothing, on either backend) emits one
    /// [`crate::obs::EventKind::Superstep`] carrying this step's
    /// per-machine work, sent/received words, and unfactored sent-message
    /// counts, with no wall annotation (the simulator has no wall).
    pub fn set_observer(&mut self, obs: Option<crate::obs::ObserverHandle>) {
        self.observer = obs;
    }

    /// Charge `units` of local work to machine `m` in the current superstep.
    #[inline]
    pub fn work(&mut self, m: MachineId, units: u64) {
        self.step.work[m] += units;
        self.step.dirty = true;
    }

    /// Record that machine `m` executed `n` tasks (Theorem 1(ii) metric).
    #[inline]
    pub fn executed(&mut self, m: MachineId, n: u64) {
        self.metrics.executed_by_machine[m] += n;
    }

    /// Account one message of `words` words from `from` to `to`.
    /// Self-sends are free (the dashed edges of the paper's Fig 2).
    #[inline]
    pub fn account_msg(&mut self, from: MachineId, to: MachineId, words: u64) {
        if from == to {
            return;
        }
        self.step.sent[from] += words;
        self.step.recv[to] += words;
        // Both endpoints pay the fixed per-message cost (pack + unpack);
        // this is what makes per-edge messaging to a hot vertex's owner
        // expensive even when the payloads are small.  `msg_factor`
        // scales it for unbatchable RPCs (see `set_msg_factor`).
        self.step.msgs[from] += self.msg_factor;
        self.step.msgs[to] += self.msg_factor;
        self.step.sent_msgs[from] += 1;
        self.metrics.total_words += words;
        self.metrics.total_msgs += 1;
        self.step.dirty = true;
    }

    /// Close the current superstep: charge BSP cost and reset accumulators.
    pub fn barrier(&mut self) {
        if !self.step.dirty {
            return; // empty step — nothing happened, charge nothing
        }
        let max_comm = self
            .step
            .sent
            .iter()
            .zip(&self.step.recv)
            .map(|(s, r)| (*s).max(*r))
            .max()
            .unwrap_or(0);
        let max_work = self.step.work.iter().copied().max().unwrap_or(0);
        let max_msgs = self.step.msgs.iter().copied().max().unwrap_or(0);

        self.metrics.time.communication += self.cost.g * max_comm as f64;
        self.metrics.time.computation += self.cost.work_seconds(max_work);
        self.metrics.time.overhead += self.cost.per_msg * max_msgs as f64 + self.cost.l;
        self.metrics.supersteps += 1;
        self.metrics.makespan_work += max_work;

        for m in 0..self.p {
            self.metrics.sent_by_machine[m] += self.step.sent[m];
            self.metrics.recv_by_machine[m] += self.step.recv[m];
            self.metrics.work_by_machine[m] += self.step.work[m];
        }
        if let Some(obs) = &self.observer {
            // Emitted per ledger step only (the early-return above skips
            // empty barriers), with the step's per-machine ledger slice —
            // the exact quantities the threaded backend's driver fold
            // records, so the streams compare bit for bit.
            obs.lock().unwrap().record_superstep(
                self.metrics.supersteps,
                self.step.work.clone(),
                self.step.sent.clone(),
                self.step.recv.clone(),
                self.step.sent_msgs.clone(),
                None,
            );
        }
        self.step.reset();
    }

    /// All-to-all message exchange closing one superstep.
    ///
    /// `outboxes[m]` holds `(dest, payload)` pairs produced by machine `m`
    /// during this superstep's compute; `words(payload)` gives the wire
    /// size.  Returns `inboxes[m]` = payloads delivered to machine `m`,
    /// in deterministic (sender, emission) order.
    pub fn exchange<T>(
        &mut self,
        outboxes: Vec<Vec<(MachineId, T)>>,
        words: impl Fn(&T) -> u64,
    ) -> Vec<Vec<T>> {
        debug_assert_eq!(outboxes.len(), self.p);
        let mut inboxes: Vec<Vec<T>> = (0..self.p).map(|_| Vec::new()).collect();
        for (from, box_m) in outboxes.into_iter().enumerate() {
            for (to, payload) in box_m {
                debug_assert!(to < self.p, "destination {to} out of range");
                self.account_msg(from, to, words(&payload));
                inboxes[to].push(payload);
            }
        }
        self.barrier();
        inboxes
    }

    /// Reset metrics (e.g. to exclude ingestion from a measured run).
    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::new(self.p);
        self.step.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unit_cost() -> CostModel {
        CostModel {
            g: 1.0,
            l: 0.0,
            work_unit: 1.0,
            per_msg: 0.0,
            numa: NumaTopo::Single,
        }
    }

    #[test]
    fn exchange_delivers_and_accounts() {
        let mut c = Cluster::new(4, unit_cost());
        let mut out: Vec<Vec<(MachineId, u32)>> = vec![vec![]; 4];
        out[0].push((1, 10));
        out[0].push((2, 20));
        out[3].push((1, 30));
        let inboxes = c.exchange(out, |_| 5);
        assert_eq!(inboxes[1], vec![10, 30]);
        assert_eq!(inboxes[2], vec![20]);
        assert!(inboxes[0].is_empty());
        // machine 0 sent 2 msgs * 5 words; max(sent,recv) over machines = 10
        assert_eq!(c.metrics.total_words, 15);
        assert!((c.metrics.time.communication - 10.0).abs() < 1e-12);
        assert_eq!(c.metrics.supersteps, 1);
    }

    #[test]
    fn self_sends_are_free() {
        let mut c = Cluster::new(2, unit_cost());
        let out = vec![vec![(0usize, 1u32)], vec![]];
        let inboxes = c.exchange(out, |_| 100);
        assert_eq!(inboxes[0], vec![1]);
        assert_eq!(c.metrics.total_words, 0);
        // delivery happened but no comm time was charged
        assert_eq!(c.metrics.time.communication, 0.0);
    }

    #[test]
    fn work_charged_by_max_machine() {
        let mut c = Cluster::new(3, unit_cost());
        c.work(0, 5);
        c.work(1, 9);
        c.barrier();
        assert!((c.metrics.time.computation - 9.0).abs() < 1e-12);
        assert_eq!(c.metrics.work_by_machine, vec![5, 9, 0]);
    }

    #[test]
    fn empty_barrier_is_free() {
        let mut c = Cluster::new(2, unit_cost());
        c.barrier();
        c.barrier();
        assert_eq!(c.metrics.supersteps, 0);
        assert_eq!(c.metrics.sim_seconds(), 0.0);
    }

    #[test]
    fn msg_factor_scales_overhead_term_only() {
        // The RPC factor inflates the simulated per-message overhead time
        // without touching the ledger the threaded backend must match.
        let cost = CostModel {
            g: 0.0,
            l: 0.0,
            work_unit: 0.0,
            per_msg: 1.0,
            numa: NumaTopo::Single,
        };
        let mut a = Cluster::new(2, cost);
        a.account_msg(0, 1, 3);
        a.barrier();
        let mut b = Cluster::new(2, cost);
        b.set_msg_factor(RPC_MSG_FACTOR);
        b.account_msg(0, 1, 3);
        b.barrier();
        assert!((a.metrics.time.overhead - 1.0).abs() < 1e-12);
        assert!((b.metrics.time.overhead - RPC_MSG_FACTOR as f64).abs() < 1e-12);
        assert_eq!(a.metrics.total_words, b.metrics.total_words);
        assert_eq!(a.metrics.total_msgs, b.metrics.total_msgs);
        assert_eq!(a.metrics.sent_by_machine, b.metrics.sent_by_machine);
        assert_eq!(a.metrics.recv_by_machine, b.metrics.recv_by_machine);
        // Factor 0 clamps to 1 (a message always costs at least itself);
        // resetting to 1 restores packed-item accounting.
        b.set_msg_factor(0);
        b.account_msg(1, 0, 3);
        b.barrier();
        assert!((b.metrics.time.overhead - (RPC_MSG_FACTOR as f64 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn observer_records_ledger_steps_only_with_unfactored_counts() {
        use crate::obs::{EventKind, FlightRecorder};
        let mut c = Cluster::new(2, unit_cost());
        let rec = FlightRecorder::shared(64);
        c.set_observer(Some(rec.clone()));
        c.barrier(); // empty: no ledger step, no event
        c.set_msg_factor(RPC_MSG_FACTOR); // must not leak into the event
        c.account_msg(0, 1, 3);
        c.work(1, 5);
        c.barrier();
        let r = rec.lock().unwrap();
        assert_eq!(r.len(), 1, "one event per ledger superstep");
        let e = r.events().next().unwrap();
        match &e.kind {
            EventKind::Superstep { step, work, sent_words, recv_words, sent_msgs } => {
                assert_eq!(*step, 1);
                assert_eq!(work, &vec![0, 5]);
                assert_eq!(sent_words, &vec![3, 0]);
                assert_eq!(recv_words, &vec![0, 3]);
                assert_eq!(sent_msgs, &vec![1, 0], "unfactored, from-side only");
            }
            other => panic!("expected Superstep, got {:?}", other),
        }
        assert!(e.wall.is_none(), "the simulator never annotates wall time");
    }

    #[test]
    fn barrier_cost_l_charged_per_nonempty_step() {
        let mut cost = unit_cost();
        cost.l = 7.0;
        let mut c = Cluster::new(2, cost);
        c.work(0, 1);
        c.barrier();
        c.work(1, 1);
        c.barrier();
        assert!((c.metrics.time.overhead - 14.0).abs() < 1e-12);
    }

    #[test]
    fn hotspot_comm_is_max_not_sum() {
        // 3 machines each send 4 words to machine 0: comm = recv at 0 = 12,
        // not total 12+... (max over machines of max(sent,recv)).
        let mut c = Cluster::new(4, unit_cost());
        let mut out: Vec<Vec<(MachineId, u8)>> = vec![vec![]; 4];
        for m in 1..4 {
            out[m].push((0, 0));
        }
        c.exchange(out, |_| 4);
        assert!((c.metrics.time.communication - 12.0).abs() < 1e-12);
    }
}
