//! The real shared-nothing threaded backend, on a **persistent worker
//! pool**.
//!
//! One OS worker thread per logical machine, spawned exactly once when the
//! [`ThreadedCluster`] is constructed and parked between supersteps — not
//! re-spawned per superstep as in the first version of this module.  That
//! matters for multi-round graph algorithms: PageRank, BFS, SSSP, CC and
//! BC run tens of supersteps per query, and a spawn-per-superstep model
//! pays the ~10 µs thread-creation cost on every one of them *and* loses
//! any chance of cache/NUMA affinity between rounds.
//!
//! ## Pool lifecycle
//!
//! * `try_new(p)` spawns the P workers up front.  Each worker owns one end
//!   of a private job channel and blocks on `recv()` until the driver
//!   publishes work.  If thread `k` fails to spawn, the already-spawned
//!   `k-1` workers are still parked on their channels (they have never
//!   touched a barrier), so the constructor hangs up those channels, joins
//!   the threads, and returns the spawn error — a partially-spawned
//!   cluster can never silently compute on fewer machines.  `new(p)`
//!   panics with context instead of returning the error.
//! * Each [`Substrate::superstep`] call is one **epoch**: the driver
//!   builds per-machine task cells on its stack, publishes a single
//!   lifetime-erased job pointer to every worker, and finally waits on the
//!   `(P+1)`-party `epoch_done` barrier.  Workers run the job (the whole
//!   compute → send → barrier → drain sequence below), store their report
//!   into their cell, and meet the driver at `epoch_done`.  The barrier is
//!   what makes the single `unsafe` lifetime erasure sound: no worker can
//!   touch the job closure or the cells after `epoch_done`, and the driver
//!   does not touch them before it.
//! * Dropping the cluster hangs up the job channels; workers observe the
//!   disconnect and exit, and `Drop` joins them.
//!
//! ## One superstep (inside the job)
//!
//! 1. all P workers rendezvous on the reusable P-party `comm_barrier`
//!    (the superstep start line — keeps the per-machine wall-clock
//!    windows comparable);
//! 2. each worker runs the superstep closure on *its own* state — the
//!    scheduler threads each machine's `DistStore` shard, graph shard,
//!    slot store, pull-tree nodes etc. through here, so no two threads
//!    ever touch the same data (shared-nothing by construction, enforced
//!    by `&mut`);
//! 3. each worker groups its outbox into **one batch per destination**
//!    (a recycled `Vec` of payloads, in emission order) and performs
//!    exactly P channel sends over the **persistent mesh** — P channels
//!    created once at pool construction, one receiver per machine, every
//!    worker holding a clone of every sender.  One send per *destination*
//!    per superstep, not one per message: the mesh channels and the batch
//!    buffers amortize across the whole superstep, and across supersteps
//!    via each worker's recycling pool;
//! 4. all workers rendezvous on `comm_barrier` again (the communication
//!    barrier), then receive exactly P batches each — which never blocks,
//!    because every peer completed its sends before the barrier.  Time
//!    spent *waiting* at either barrier is deliberately excluded from the
//!    per-machine busy clocks: `compute_ns` is the superstep closure,
//!    `comm_ns` is group + send + drain, and barrier wait is idle — so a
//!    machine that finishes early does not absorb the slowest machine's
//!    window and load imbalance stays visible in the busy table;
//! 5. the received batches are sorted by sender id (each batch is
//!    internally in emission order already), restoring exactly the
//!    (sender, emission-index) delivery order the simulator uses, so a
//!    threaded run is bit-identical to a simulated one.  Emptied batch
//!    buffers go back into the worker's recycling pool.
//!
//! A panic inside the superstep closure (or in the user `words` function)
//! is caught on the worker, which still completes the full protocol —
//! sends P (empty) batches, passes the communication barrier, drains its
//! P incoming batches (a persistent receiver MUST be drained, or the
//! leftovers would poison the next epoch), reaches `epoch_done` — and
//! only then re-raises; the driver rethrows the payload.  A poisoned
//! superstep neither deadlocks the pool nor hides the panic.
//!
//! Metrics: the [`Metrics`] mirror is filled with the same ledger the
//! simulator keeps (per-machine work units, words sent/received, executed
//! tasks, supersteps), except that the time breakdown holds *measured*
//! seconds — `computation` accumulates the slowest machine's compute
//! window and `communication` the slowest machine's send+drain window.
//! Per-machine cumulative wall-clock is kept separately in
//! [`ThreadedCluster::compute_ns`] / [`ThreadedCluster::comm_ns`].
//! The ledger counters (work, words, messages, supersteps, delivery
//! order) are deterministic — identical across runs and across any
//! oversubscription of workers to cores; only the nanosecond clocks vary
//! with the host.

use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Barrier, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::bsp::MachineId;
use crate::metrics::Metrics;

use super::{MachineAcct, Substrate};

/// What one worker reports back from one superstep.
struct WorkerReport<T> {
    acct: MachineAcct,
    inbox: Vec<T>,
    sent_words: u64,
    recv_words: u64,
    sent_msgs: u64,
    compute_ns: u64,
    comm_ns: u64,
}

/// One batch on the persistent mesh: `(sender id, boxed `Vec<Tout>`)`.
/// The payload type changes per superstep, so the wire type is erased;
/// each epoch's workers downcast with the epoch's `Tout`.
type MeshBatch = (u32, Box<dyn Any + Send>);

/// Per-worker persistent communication state, owned by the worker thread
/// for the pool's whole lifetime and lent to each epoch's job.  This is
/// what makes superstep communication allocation-free in steady state:
/// the mesh channels are built once at pool construction, and the batch
/// buffers + drain staging circulate through [`WorkerLocal::take_buf`] /
/// [`WorkerLocal::put_buf`] instead of being reallocated per superstep.
struct WorkerLocal {
    /// One sender per destination machine (the P×P mesh, built once).
    batch_txs: Vec<mpsc::Sender<MeshBatch>>,
    /// This machine's mesh receiver.
    batch_rx: mpsc::Receiver<MeshBatch>,
    /// Recycled drain staging (exactly P entries per superstep).
    staging: Vec<MeshBatch>,
    /// Recycled outbox batch buffers keyed by `TypeId::of::<Vec<T>>()` —
    /// supersteps alternate payload types (values, contributions, delta
    /// notes…), and each type's buffers circulate independently.
    pool: HashMap<TypeId, Vec<Box<dyn Any + Send>>>,
}

impl WorkerLocal {
    /// Pop a recycled buffer of the requested payload type (or allocate
    /// an empty one on first use).
    fn take_buf<T: Send + 'static>(&mut self) -> Box<Vec<T>> {
        self.pool
            .get_mut(&TypeId::of::<Vec<T>>())
            .and_then(|bufs| bufs.pop())
            .map(|b| b.downcast::<Vec<T>>().expect("pool is keyed by TypeId"))
            .unwrap_or_default()
    }

    /// Return an emptied buffer to the pool (capacity kept).
    fn put_buf<T: Send + 'static>(&mut self, mut buf: Box<Vec<T>>) {
        buf.clear();
        self.pool.entry(TypeId::of::<Vec<T>>()).or_default().push(buf);
    }
}

/// A lifetime-erased job pointer: the address of the driver's stack-local
/// superstep closure.  Soundness contract (see module docs): the driver
/// keeps the closure alive until every worker has passed `epoch_done`,
/// and workers never dereference the pointer after passing it.
#[derive(Clone, Copy)]
struct Job(*const (dyn Fn(usize, &mut WorkerLocal) + Sync));

// SAFETY: the pointee is `Sync` (callable from any thread by shared ref)
// and the epoch protocol bounds its lifetime as described above.
unsafe impl Send for Job {}

fn worker_loop(
    m: MachineId,
    rx: mpsc::Receiver<Job>,
    epoch_done: Arc<Barrier>,
    panics: Arc<Vec<Mutex<Option<Box<dyn Any + Send>>>>>,
    epochs: Arc<Vec<AtomicU64>>,
    mut local: WorkerLocal,
) {
    // A disconnected channel is the shutdown signal (pool dropped, or the
    // constructor tearing down a partially-spawned pool).
    while let Ok(job) = rx.recv() {
        // SAFETY: see `Job` — the driver guarantees the closure outlives
        // this dereference (it blocks on `epoch_done` below).
        let f = unsafe { &*job.0 };
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(m, &mut local))) {
            *panics[m].lock().unwrap() = Some(payload);
        }
        epochs[m].fetch_add(1, Ordering::Relaxed);
        epoch_done.wait();
    }
}

/// Point-in-time counters of a long-lived pool.  The serving layer keeps
/// one `ThreadedCluster` alive across an entire query stream (the pool is
/// spawned once, reused by every query via `reset_for_query`), so
/// per-query accounting is done by snapshotting before/after each
/// dispatch and diffing with [`PoolSnapshot::since`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolSnapshot {
    /// Completed barrier epochs (== supersteps driven through the pool).
    pub epochs: u64,
    /// Total busy wall-clock across all machines, nanoseconds.
    pub busy_ns: u64,
}

impl PoolSnapshot {
    /// Counters accumulated between `earlier` and `self`.  Saturating on
    /// BOTH fields: `epochs` is monotone for the pool's lifetime, but
    /// `busy_ns` derives from the busy clocks, which
    /// [`ThreadedCluster::reset_metrics`] zeroes — a snapshot taken
    /// before a reset would otherwise underflow the diff.  An `earlier`
    /// argument that is actually *ahead* of `self` (snapshots swapped, or
    /// taken across a reset) therefore yields zeros, never a wrapped
    /// garbage delta — pinned by
    /// `snapshot_since_saturates_when_earlier_is_ahead`.
    pub fn since(&self, earlier: PoolSnapshot) -> PoolSnapshot {
        PoolSnapshot {
            epochs: self.epochs.saturating_sub(earlier.epochs),
            busy_ns: self.busy_ns.saturating_sub(earlier.busy_ns),
        }
    }

    /// Fraction of `p` workers' wall-clock spent busy over a window of
    /// `wall_ns` nanoseconds: `busy_ns / (wall_ns * p)`.  1.0 means every
    /// worker computed or communicated for the whole window; the serving
    /// load curves report it per sweep point to show where the pool — as
    /// opposed to the admission queue — saturates.
    ///
    /// Edge cases (pinned by `busy_fraction_bounds`): a zero-width window
    /// (`wall_ns == 0`), zero machines, or a `wall_ns * p` product that
    /// saturates `u64::MAX` all make the denominator degenerate and
    /// return NaN — there is no window to attribute busy time to, and a
    /// saturated denominator would silently *understate* utilization if
    /// it were divided through.
    pub fn busy_fraction(&self, wall_ns: u64, p: usize) -> f64 {
        let denom = match wall_ns.checked_mul(p as u64) {
            Some(0) | None => return f64::NAN,
            Some(d) => d,
        };
        self.busy_ns as f64 / denom as f64
    }
}

/// A real cluster of P persistent worker threads (see module docs).
pub struct ThreadedCluster {
    p: usize,
    /// Same ledger as the simulator's; `time` holds measured seconds.
    pub metrics: Metrics,
    /// Cumulative per-machine wall-clock spent inside superstep closures.
    pub compute_ns: Vec<u64>,
    /// Cumulative per-machine wall-clock spent sending + draining.
    pub comm_ns: Vec<u64>,
    /// Reusable P-party barrier: superstep start line + communication
    /// barrier (workers only; the driver is not a party).
    comm_barrier: Arc<Barrier>,
    /// (P+1)-party epoch barrier: the P workers plus the driver meet here
    /// at the end of every superstep.
    epoch_done: Arc<Barrier>,
    /// One job channel per worker; dropping them shuts the pool down.
    job_txs: Vec<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    /// Per-worker slot for a caught superstep panic payload.
    panics: Arc<Vec<Mutex<Option<Box<dyn Any + Send>>>>>,
    /// Per-worker count of executed epochs (pool-lifecycle regression
    /// tests assert exactly one per superstep).
    worker_epochs: Arc<Vec<AtomicU64>>,
    /// Driver-side count of completed epochs.
    epochs: u64,
    /// Attached flight recorder, if any.  Emission happens on the DRIVER
    /// thread only (in the report fold below), so the lock is never
    /// contended and workers stay observer-free; `None` (the default)
    /// skips all event work.
    observer: Option<crate::obs::ObserverHandle>,
}

impl ThreadedCluster {
    /// Spawn the pool, panicking with context on failure (tests and
    /// callers that must not proceed on a partial cluster can use
    /// [`ThreadedCluster::try_new`] to handle the error instead).
    pub fn new(p: usize) -> Self {
        Self::try_new(p).unwrap_or_else(|e| {
            panic!("ThreadedCluster: could not spawn the {p}-worker pool: {e}")
        })
    }

    /// Spawn the P-worker pool, returning the spawn error (with every
    /// already-spawned worker cleanly joined) if the OS refuses a thread.
    pub fn try_new(p: usize) -> std::io::Result<Self> {
        Self::try_new_with_stack(p, None)
    }

    /// Like [`ThreadedCluster::try_new`], with an explicit worker stack
    /// size.  Mainly a test seam: an impossible stack size (larger than
    /// the address space) makes the first spawn fail deterministically,
    /// exercising the partial-spawn teardown path without exhausting real
    /// process limits.
    pub fn try_new_with_stack(p: usize, stack_bytes: Option<usize>) -> std::io::Result<Self> {
        assert!(p >= 1, "cluster needs at least one machine");
        let comm_barrier = Arc::new(Barrier::new(p));
        let epoch_done = Arc::new(Barrier::new(p + 1));
        let panics: Arc<Vec<Mutex<Option<Box<dyn Any + Send>>>>> =
            Arc::new((0..p).map(|_| Mutex::new(None)).collect());
        let worker_epochs: Arc<Vec<AtomicU64>> =
            Arc::new((0..p).map(|_| AtomicU64::new(0)).collect());
        // The persistent P×P mesh: one channel per destination machine,
        // built once here; worker m owns receiver m plus a clone of every
        // sender.  Per-superstep communication reuses these channels (one
        // batched send per destination) instead of building a fresh mesh
        // each epoch.
        let mut mesh_txs: Vec<mpsc::Sender<MeshBatch>> = Vec::with_capacity(p);
        let mut mesh_rxs: Vec<mpsc::Receiver<MeshBatch>> = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = mpsc::channel::<MeshBatch>();
            mesh_txs.push(tx);
            mesh_rxs.push(rx);
        }
        let mut mesh_rxs = mesh_rxs.into_iter();
        let mut job_txs = Vec::with_capacity(p);
        let mut handles = Vec::with_capacity(p);
        for m in 0..p {
            let (tx, rx) = mpsc::channel::<Job>();
            let mut builder = std::thread::Builder::new().name(format!("tdorch-worker-{m}"));
            if let Some(bytes) = stack_bytes {
                builder = builder.stack_size(bytes);
            }
            let epoch_done_w = Arc::clone(&epoch_done);
            let panics_w = Arc::clone(&panics);
            let epochs_w = Arc::clone(&worker_epochs);
            let local = WorkerLocal {
                batch_txs: mesh_txs.clone(),
                batch_rx: mesh_rxs.next().expect("one mesh receiver per worker"),
                staging: Vec::with_capacity(p),
                pool: HashMap::new(),
            };
            match builder.spawn(move || worker_loop(m, rx, epoch_done_w, panics_w, epochs_w, local))
            {
                Ok(h) => {
                    job_txs.push(tx);
                    handles.push(h);
                }
                Err(e) => {
                    // The m already-spawned workers are parked on their
                    // job channels and have never touched a barrier:
                    // hanging up the channels makes them exit, so the
                    // caller gets an error, never a smaller cluster.
                    drop(tx);
                    drop(job_txs);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(std::io::Error::new(
                        e.kind(),
                        format!("spawned only {m} of {p} worker threads: {e}"),
                    ));
                }
            }
        }
        Ok(ThreadedCluster {
            p,
            metrics: Metrics::new(p),
            compute_ns: vec![0; p],
            comm_ns: vec![0; p],
            comm_barrier,
            epoch_done,
            job_txs,
            handles,
            panics,
            worker_epochs,
            epochs: 0,
            observer: None,
        })
    }

    /// Attach (or detach) a flight recorder.  While attached, every
    /// *ledger* superstep (same dirty condition as the simulator: work or
    /// a cross-machine send) emits one
    /// [`crate::obs::EventKind::Superstep`] whose deterministic core
    /// carries the identical per-machine ledger slice the simulator
    /// records, annotated here with measured per-machine busy
    /// nanoseconds (compute + comm — never compared across backends).
    pub fn set_observer(&mut self, obs: Option<crate::obs::ObserverHandle>) {
        self.observer = obs;
    }

    /// Number of OS threads this cluster has ever spawned — exactly P for
    /// the pool's whole lifetime, however many supersteps run (the
    /// acceptance counter for the persistent-pool contract).
    pub fn pool_threads(&self) -> usize {
        self.handles.len()
    }

    /// Completed barrier epochs (== supersteps driven through the pool,
    /// including ledger-empty ones).
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Per-worker executed-epoch counts; every entry equals
    /// [`ThreadedCluster::epochs`] when no superstep lost or duplicated a
    /// worker (the pool-regression invariant).
    pub fn worker_epochs(&self) -> Vec<u64> {
        self.worker_epochs
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Total busy wall-clock of machine `m` so far, in nanoseconds.
    pub fn busy_ns(&self, m: MachineId) -> u64 {
        self.compute_ns[m] + self.comm_ns[m]
    }

    /// Busy wall-clock of the most-loaded machine, in milliseconds — the
    /// quantity the BSP max-terms model, now measured for real.
    pub fn max_busy_ms(&self) -> f64 {
        (0..self.p).map(|m| self.busy_ns(m)).max().unwrap_or(0) as f64 / 1e6
    }

    /// Per-machine busy milliseconds (compute + comm).
    pub fn busy_ms_by_machine(&self) -> Vec<f64> {
        (0..self.p).map(|m| self.busy_ns(m) as f64 / 1e6).collect()
    }

    /// Current pool counters, for per-query/per-batch accounting on a
    /// long-lived serving cluster (see [`PoolSnapshot`]).
    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            epochs: self.epochs,
            busy_ns: (0..self.p).map(|m| self.busy_ns(m)).sum(),
        }
    }

    /// Reset the ledger (the pool and its epoch counters stay).
    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::new(self.p);
        self.compute_ns.fill(0);
        self.comm_ns.fill(0);
    }
}

impl Drop for ThreadedCluster {
    fn drop(&mut self) {
        // Hang up the job channels; parked workers see the disconnect and
        // exit their loop.
        self.job_txs.clear();
        for h in self.handles.drain(..) {
            // A worker that panicked *outside* a job (impossible today)
            // must not turn Drop into a double panic.
            let _ = h.join();
        }
    }
}

/// Per-machine cell for one epoch: input taken by the worker at job
/// start, report stored at job end.  The `Mutex` exists only to make the
/// shared cell vector `Sync` — each cell is touched by exactly one
/// worker, then by the driver after `epoch_done`, so the lock is never
/// contended.  (Communication endpoints live in each worker's persistent
/// [`WorkerLocal`], not here — the cell carries only epoch-specific
/// state.)
struct Cell<'a, St, Tin, Tout> {
    input: Option<CellIn<'a, St, Tin>>,
    report: Option<WorkerReport<Tout>>,
}

struct CellIn<'a, St, Tin> {
    st: &'a mut St,
    inbox: Vec<Tin>,
}

impl Substrate for ThreadedCluster {
    fn machines(&self) -> usize {
        self.p
    }

    fn set_observer(&mut self, obs: Option<crate::obs::ObserverHandle>) {
        ThreadedCluster::set_observer(self, obs);
    }

    fn ledger_supersteps(&self) -> u64 {
        self.metrics.supersteps
    }

    fn ledger_makespan(&self) -> u64 {
        self.metrics.makespan_work
    }

    fn superstep<St, Tin, Tout, F, W>(
        &mut self,
        state: &mut [St],
        inboxes: Vec<Vec<Tin>>,
        f: F,
        words: W,
    ) -> Vec<Vec<Tout>>
    where
        St: Send,
        Tin: Send,
        Tout: Send + 'static,
        F: Fn(MachineId, &mut St, Vec<Tin>, &mut MachineAcct) -> Vec<(MachineId, Tout)> + Sync,
        W: Fn(&Tout) -> u64 + Sync,
    {
        let p = self.p;
        assert_eq!(state.len(), p, "state must have one entry per machine");
        assert_eq!(inboxes.len(), p, "inboxes must have one entry per machine");

        let cells: Vec<Mutex<Cell<'_, St, Tin, Tout>>> = state
            .iter_mut()
            .zip(inboxes)
            .map(|(st, inbox)| {
                Mutex::new(Cell {
                    input: Some(CellIn { st, inbox }),
                    report: None,
                })
            })
            .collect();

        let f = &f;
        let words = &words;
        let comm_barrier: &Barrier = &self.comm_barrier;
        let cells_ref = &cells;

        // The per-epoch job: machine m's full superstep, communicating
        // over the worker's persistent mesh endpoints (`wl`).  Runs on
        // worker thread m; borrows this stack frame (cells, f, words) —
        // sound because the driver blocks on `epoch_done` below before
        // touching or dropping any of it.
        //
        // The protocol is unconditional: every worker sends exactly one
        // batch to every destination and receives exactly P batches,
        // every superstep, panic or no panic.  That is what keeps the
        // persistent mesh clean across epochs — an unsent batch would
        // block a peer's drain, and an undrained one would be delivered
        // to the NEXT superstep (with the wrong payload type).
        let job = move |m: usize, wl: &mut WorkerLocal| {
            let mut cell = cells_ref[m].lock().unwrap();
            let CellIn { st, inbox } =
                cell.input.take().expect("epoch cell already consumed");
            comm_barrier.wait(); // superstep start line
            let t0 = Instant::now();
            let mut acct = MachineAcct::default();
            let compute = catch_unwind(AssertUnwindSafe(|| f(m, st, inbox, &mut acct)));
            let compute_ns = t0.elapsed().as_nanos() as u64;

            // Group the outbox into one recycled batch per destination,
            // counting the ledger per *payload* (self-sends are free, as
            // in the simulator).  `words` is user code: a panic in it is
            // caught like one in `f`, and the protocol still completes
            // with empty batches.
            let t1 = Instant::now();
            let mut panicked: Option<Box<dyn Any + Send>> = None;
            let mut sent_words = 0u64;
            let mut sent_msgs = 0u64;
            let mut dests: Vec<Box<Vec<Tout>>> = (0..p).map(|_| wl.take_buf::<Tout>()).collect();
            match compute {
                Ok(outbox) => {
                    let grouped = catch_unwind(AssertUnwindSafe(|| {
                        for (to, payload) in outbox {
                            debug_assert!(to < p, "destination {to} out of range");
                            if to != m {
                                sent_words += words(&payload);
                                sent_msgs += 1;
                            }
                            dests[to].push(payload);
                        }
                    }));
                    if let Err(payload) = grouped {
                        panicked = Some(payload);
                    }
                }
                Err(payload) => panicked = Some(payload),
            }
            if panicked.is_some() {
                for d in dests.iter_mut() {
                    d.clear();
                }
                sent_words = 0;
                sent_msgs = 0;
            }
            for (to, buf) in dests.into_iter().enumerate() {
                if wl.batch_txs[to].send((m as u32, buf)).is_err() {
                    // A mesh receiver can only be gone if its worker
                    // thread died — the pool invariant is already broken
                    // and peers may be blocked on their drains forever.
                    eprintln!("fatal: mesh peer {to} of {p} hung up mid-superstep");
                    std::process::abort();
                }
            }
            let send_ns = t1.elapsed().as_nanos() as u64;
            // Communication barrier: once every worker passes this line,
            // all P batches addressed to this machine have been sent, so
            // the blocking drain below never actually waits.  The wait
            // itself is idle time and stays OFF the busy clocks — an
            // early finisher must not absorb the slowest machine's
            // window, or load imbalance would vanish from the per-machine
            // busy table.
            comm_barrier.wait();
            let t2 = Instant::now();
            let mut staging = std::mem::take(&mut wl.staging);
            for _ in 0..p {
                match wl.batch_rx.recv() {
                    Ok(batch) => staging.push(batch),
                    Err(_) => {
                        // All senders gone mid-epoch: every peer (each
                        // holding a sender clone) died.  Unrecoverable.
                        eprintln!("fatal: mesh senders disconnected mid-superstep on {m}");
                        std::process::abort();
                    }
                }
            }
            // One batch per sender, already in emission order internally:
            // sorting by sender id restores the simulator's (sender,
            // emission-index) delivery order.
            staging.sort_unstable_by_key(|&(sender, _)| sender);
            let mut recv_words = 0u64;
            let unpacked = catch_unwind(AssertUnwindSafe(|| {
                let total: usize = staging
                    .iter()
                    .map(|(_, b)| b.downcast_ref::<Vec<Tout>>().map_or(0, |v| v.len()))
                    .sum();
                // The merged inbox leaves the substrate (it is returned to
                // the caller), so it cannot come from the recycling pool:
                // one exact-capacity allocation per machine per superstep.
                let mut inbox: Vec<Tout> = Vec::with_capacity(total);
                for (sender, anybox) in staging.drain(..) {
                    let mut batch = anybox
                        .downcast::<Vec<Tout>>()
                        .expect("mesh batch carries the epoch's payload type");
                    if sender as usize != m {
                        for payload in batch.iter() {
                            recv_words += words(payload);
                        }
                    }
                    inbox.append(&mut batch);
                    wl.put_buf(batch);
                }
                inbox
            }));
            // Even on a panic, `staging.drain`'s drop has emptied the
            // staging vec, so nothing leaks into the next epoch.
            wl.staging = staging;
            let inbox = match unpacked {
                Ok(inbox) => inbox,
                Err(payload) => {
                    panicked.get_or_insert(payload);
                    Vec::new()
                }
            };
            let comm_ns = send_ns + t2.elapsed().as_nanos() as u64;
            cell.report = Some(WorkerReport {
                acct,
                inbox,
                sent_words,
                recv_words,
                sent_msgs,
                compute_ns,
                comm_ns,
            });
            drop(cell);
            if let Some(payload) = panicked {
                // Protocol complete (batches sent, barrier passed, mesh
                // drained): now the panic may surface.  worker_loop's
                // catch stores it for the driver to rethrow.
                std::panic::resume_unwind(payload);
            }
        };

        let job_ref: &(dyn Fn(usize, &mut WorkerLocal) + Sync) = &job;
        // SAFETY: erases the stack lifetime of `job`.  Sound because (a)
        // every worker dereferences the pointer only between `recv()` and
        // its `epoch_done.wait()`, and (b) on every path below the driver
        // either parks on the same `epoch_done` barrier before
        // `job`/`cells` can drop, or aborts the process (failed publish)
        // without unwinding past them.
        let raw = Job(unsafe {
            std::mem::transmute::<
                *const (dyn Fn(usize, &mut WorkerLocal) + Sync + '_),
                *const (dyn Fn(usize, &mut WorkerLocal) + Sync + 'static),
            >(job_ref)
        });
        for (m, tx) in self.job_txs.iter().enumerate() {
            if tx.send(raw).is_err() {
                // A worker's recv loop has exited — the pool invariant is
                // already broken, and the workers before `m` hold the raw
                // job pointer: unwinding here would free the stack-local
                // closure (and the `&mut` state in `cells`) under them
                // while they park forever at the P-party comm barrier.
                // There is no safe continuation; fail fast.
                eprintln!("fatal: worker pool thread {m} of {p} exited before the epoch");
                std::process::abort();
            }
        }
        self.epoch_done.wait(); // the (P+1)-th party: epoch complete
        self.epochs += 1;

        // All workers are parked on their job channels again; the cells
        // are exclusively the driver's from here on.  Drain EVERY panic
        // slot before rethrowing: if two machines panicked in this epoch,
        // leaving the second payload behind would spuriously fail the
        // next (clean) superstep on this pool.
        let mut first_panic = None;
        for (m, slot) in self.panics.iter().enumerate() {
            if let Some(payload) = slot.lock().unwrap().take() {
                eprintln!("worker thread {m} panicked inside a superstep closure");
                first_panic.get_or_insert(payload);
            }
        }
        if let Some(payload) = first_panic {
            std::panic::resume_unwind(payload);
        }

        // Fold the reports into the metrics mirror (driver thread).
        let mut next = Vec::with_capacity(p);
        let mut dirty = false;
        let mut max_work = 0u64;
        let mut max_compute_ns = 0u64;
        let mut max_comm_ns = 0u64;
        // Per-machine slices for the flight recorder, collected only
        // while observing — the unobserved fold does no extra work.
        let observing = self.observer.is_some();
        let mut step_work = Vec::with_capacity(if observing { p } else { 0 });
        let mut step_sent = Vec::with_capacity(if observing { p } else { 0 });
        let mut step_recv = Vec::with_capacity(if observing { p } else { 0 });
        let mut step_msgs = Vec::with_capacity(if observing { p } else { 0 });
        let mut step_busy = Vec::with_capacity(if observing { p } else { 0 });
        for (m, cell) in cells.into_iter().enumerate() {
            let WorkerReport {
                acct,
                inbox,
                sent_words,
                recv_words,
                sent_msgs,
                compute_ns,
                comm_ns,
            } = cell
                .into_inner()
                .unwrap()
                .report
                .expect("worker finished the epoch without a report");
            self.metrics.work_by_machine[m] += acct.work_units;
            self.metrics.executed_by_machine[m] += acct.executed_tasks;
            self.metrics.sent_by_machine[m] += sent_words;
            self.metrics.recv_by_machine[m] += recv_words;
            self.metrics.total_words += sent_words;
            self.metrics.total_msgs += sent_msgs;
            self.compute_ns[m] += compute_ns;
            self.comm_ns[m] += comm_ns;
            max_work = max_work.max(acct.work_units);
            max_compute_ns = max_compute_ns.max(compute_ns);
            max_comm_ns = max_comm_ns.max(comm_ns);
            dirty |= acct.work_units > 0 || sent_msgs > 0;
            if observing {
                step_work.push(acct.work_units);
                step_sent.push(sent_words);
                step_recv.push(recv_words);
                step_msgs.push(sent_msgs);
                step_busy.push(compute_ns + comm_ns);
            }
            next.push(inbox);
        }
        if dirty {
            self.metrics.supersteps += 1;
            self.metrics.makespan_work += max_work;
            self.metrics.time.computation += max_compute_ns as f64 / 1e9;
            self.metrics.time.communication += max_comm_ns as f64 / 1e9;
            if let Some(obs) = &self.observer {
                // Ledger steps only — non-dirty epochs (the pool runs an
                // epoch either way) emit nothing on BOTH backends, which
                // is what keeps the event streams aligned.
                obs.lock().unwrap().record_superstep(
                    self.metrics.supersteps,
                    step_work,
                    step_sent,
                    step_recv,
                    step_msgs,
                    Some(step_busy),
                );
            }
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{no_messages, nothing_words, Nothing};

    #[test]
    fn routes_like_the_simulator() {
        let mut tc = ThreadedCluster::new(4);
        let mut state = vec![0u64; 4];
        let inboxes = tc.superstep(
            &mut state,
            no_messages(4),
            |m, st, _in, acct| {
                *st = m as u64;
                acct.work(1);
                // Everyone sends two payloads to machine 1.
                vec![(1, (m * 10) as u32), (1, (m * 10 + 1) as u32)]
            },
            |_| 3,
        );
        // Delivery order: (sender, emission index).
        assert_eq!(inboxes[1], vec![0, 1, 10, 11, 20, 21, 30, 31]);
        assert!(inboxes[0].is_empty() && inboxes[2].is_empty() && inboxes[3].is_empty());
        assert_eq!(state, vec![0, 1, 2, 3]);
        // Machine 1 received 6 cross-machine payloads * 3 words; its own
        // 2 self-sends are free.
        assert_eq!(tc.metrics.recv_by_machine[1], 18);
        assert_eq!(tc.metrics.total_words, 18);
        assert_eq!(tc.metrics.supersteps, 1);
    }

    #[test]
    fn state_is_private_per_machine() {
        let mut tc = ThreadedCluster::new(8);
        let mut state: Vec<Vec<u64>> = (0..8).map(|_| Vec::new()).collect();
        for round in 0..5u64 {
            let _: Vec<Vec<Nothing>> = tc.superstep(
                &mut state,
                no_messages(8),
                |m, st, _in, _acct| {
                    st.push(m as u64 * 100 + round);
                    Vec::new()
                },
                nothing_words,
            );
        }
        for (m, st) in state.iter().enumerate() {
            let expect: Vec<u64> = (0..5).map(|r| m as u64 * 100 + r).collect();
            assert_eq!(*st, expect);
        }
    }

    #[test]
    fn multi_superstep_pipeline() {
        // Token ring: a token hops machine to machine for P supersteps
        // and must come home incremented P times.
        let p = 5;
        let mut tc = ThreadedCluster::new(p);
        let mut state = vec![(); p];
        let mut inboxes = tc.superstep(
            &mut state,
            no_messages(p),
            |m, _st, _in, _acct| {
                if m == 0 {
                    vec![(1usize, 0u64)]
                } else {
                    Vec::new()
                }
            },
            |_| 1,
        );
        for _ in 0..p - 1 {
            inboxes = tc.superstep(
                &mut state,
                inboxes,
                |m, _st, inbox, _acct| {
                    inbox
                        .into_iter()
                        .map(|tok| ((m + 1) % p, tok + 1))
                        .collect()
                },
                |_| 1,
            );
        }
        assert_eq!(inboxes[0], vec![(p - 1) as u64]);
    }

    #[test]
    fn wall_clock_accumulates() {
        let mut tc = ThreadedCluster::new(2);
        let mut state = vec![(); 2];
        let _: Vec<Vec<Nothing>> = tc.superstep(
            &mut state,
            no_messages(2),
            |_m, _st, _in, acct| {
                // A small spin so the compute window is nonzero.
                let mut x = 0u64;
                for i in 0..10_000u64 {
                    x = x.wrapping_add(i * i);
                }
                std::hint::black_box(x);
                acct.work(1);
                Vec::new()
            },
            nothing_words,
        );
        assert!(tc.busy_ns(0) > 0);
        assert!(tc.busy_ns(1) > 0);
        assert!(tc.max_busy_ms() > 0.0);
        assert_eq!(tc.metrics.supersteps, 1);
        assert!(tc.metrics.time.computation > 0.0);
    }

    #[test]
    fn pool_spawns_exactly_p_threads_across_many_supersteps() {
        let p = 3;
        let mut tc = ThreadedCluster::new(p);
        assert_eq!(tc.pool_threads(), p);
        let mut state = vec![0u64; p];
        for _ in 0..50 {
            let _: Vec<Vec<Nothing>> = tc.superstep(
                &mut state,
                no_messages(p),
                |_m, st, _in, _acct| {
                    *st += 1;
                    Vec::new()
                },
                nothing_words,
            );
        }
        // Still the same P threads: the pool is persistent.
        assert_eq!(tc.pool_threads(), p);
        assert_eq!(tc.epochs(), 50);
        assert_eq!(tc.worker_epochs(), vec![50; p]);
        assert_eq!(state, vec![50; p]);
    }

    #[test]
    fn snapshot_diffs_isolate_per_unit_epochs() {
        // The serving layer's per-query accounting: snapshot before and
        // after a unit of work; the diff holds exactly that unit's epochs.
        let mut tc = ThreadedCluster::new(2);
        let mut state = vec![(); 2];
        let s0 = tc.snapshot();
        assert_eq!(s0.epochs, 0);
        for _ in 0..3 {
            let _: Vec<Vec<Nothing>> = tc.superstep(
                &mut state,
                no_messages(2),
                |_m, _st, _in, acct| {
                    acct.work(1);
                    Vec::new()
                },
                nothing_words,
            );
        }
        let s1 = tc.snapshot();
        assert_eq!(s1.since(s0).epochs, 3);
        let _: Vec<Vec<Nothing>> = tc.superstep(
            &mut state,
            no_messages(2),
            |_m, _st, _in, _acct| Vec::new(),
            nothing_words,
        );
        assert_eq!(tc.snapshot().since(s1).epochs, 1, "empty supersteps are epochs too");
    }

    #[test]
    fn partial_spawn_fails_closed() {
        // A worker stack larger than the virtual address space cannot be
        // mapped, so the spawn fails deterministically and the
        // constructor must return an error (never a smaller pool).
        let err = ThreadedCluster::try_new_with_stack(4, Some(usize::MAX / 2));
        assert!(err.is_err(), "impossible stack size must fail the spawn");
        let msg = err.err().unwrap().to_string();
        assert!(msg.contains("of 4 worker threads"), "context lost: {msg}");
    }

    #[test]
    fn superstep_panic_propagates_without_deadlock() {
        let result = std::panic::catch_unwind(|| {
            let mut tc = ThreadedCluster::new(4);
            let mut state = vec![(); 4];
            let _: Vec<Vec<Nothing>> = tc.superstep(
                &mut state,
                no_messages(4),
                |m, _st, _in, _acct| {
                    if m == 2 {
                        panic!("boom on machine 2");
                    }
                    Vec::new()
                },
                nothing_words,
            );
        });
        let payload = result.expect_err("panic must propagate to the driver");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("boom"), "unexpected payload: {msg}");
    }

    #[test]
    fn ledger_supersteps_counts_exactly_what_the_simulator_counts() {
        // The serving layer's logical clock is a DELTA of
        // `Substrate::ledger_supersteps`, so the two backends must agree
        // on which supersteps count: work or a cross-machine send marks
        // a step dirty; a step with only self-sends is skipped by BOTH
        // (self-sends are free in the simulator and uncounted in
        // `sent_msgs` here).
        let cost = crate::bsp::CostModel::paper_cluster();
        let mut tc = ThreadedCluster::new(2);
        let mut sim = crate::bsp::Cluster::new(2, cost);
        let mut st_t = vec![(); 2];
        let mut st_s = vec![(); 2];
        // self-send only: must NOT count
        let self_send = |m: usize, _st: &mut (), _in: Vec<u32>, _acct: &mut MachineAcct| {
            vec![(m, 7u32)]
        };
        // local work only: must count
        let work_only = |_m: usize, _st: &mut (), _in: Vec<u32>, acct: &mut MachineAcct| {
            acct.work(1);
            Vec::<(usize, u32)>::new()
        };
        // cross-machine send only: must count
        let cross_send = |m: usize, _st: &mut (), _in: Vec<u32>, _acct: &mut MachineAcct| {
            vec![((m + 1) % 2, 9u32)]
        };
        let _ = tc.superstep(&mut st_t, no_messages(2), self_send, |_| 1);
        let _ = tc.superstep(&mut st_t, no_messages(2), work_only, |_| 1);
        let _ = tc.superstep(&mut st_t, no_messages(2), cross_send, |_| 1);
        let _ = sim.superstep(&mut st_s, no_messages(2), self_send, |_| 1);
        let _ = sim.superstep(&mut st_s, no_messages(2), work_only, |_| 1);
        let _ = sim.superstep(&mut st_s, no_messages(2), cross_send, |_| 1);
        assert_eq!(Substrate::ledger_supersteps(&tc), 2);
        assert_eq!(Substrate::ledger_supersteps(&sim), 2);
        assert_eq!(tc.epochs(), 3, "all three epochs ran on the pool");
    }

    #[test]
    fn busy_fraction_bounds() {
        let s = PoolSnapshot { epochs: 4, busy_ns: 500 };
        assert!((s.busy_fraction(1000, 1) - 0.5).abs() < 1e-12);
        assert!((s.busy_fraction(1000, 2) - 0.25).abs() < 1e-12);
        assert!(s.busy_fraction(0, 2).is_nan(), "empty window has no fraction");
        assert!(s.busy_fraction(1000, 0).is_nan(), "zero machines has no fraction");
        // A denominator that would overflow u64 is degenerate, not a
        // silently tiny utilization: NaN, same as the empty window.
        assert!(
            s.busy_fraction(u64::MAX, 2).is_nan(),
            "overflowing wall_ns * p must not understate utilization"
        );
    }

    #[test]
    fn snapshot_since_saturates_when_earlier_is_ahead() {
        // Snapshots taken across a reset_metrics (or simply swapped by
        // the caller) put `earlier` ahead of `self`: the diff saturates
        // to zero on both fields instead of wrapping.
        let behind = PoolSnapshot { epochs: 2, busy_ns: 100 };
        let ahead = PoolSnapshot { epochs: 5, busy_ns: 900 };
        assert_eq!(behind.since(ahead), PoolSnapshot { epochs: 0, busy_ns: 0 });
        // The well-ordered direction still diffs exactly.
        assert_eq!(ahead.since(behind), PoolSnapshot { epochs: 3, busy_ns: 800 });
    }

    #[test]
    fn observer_streams_match_the_simulator_bit_for_bit() {
        use crate::obs::FlightRecorder;
        // The same three-superstep program as the ledger test above, with
        // a recorder on each backend: the deterministic core streams must
        // be identical, and only the threaded one carries wall notes.
        let cost = crate::bsp::CostModel::paper_cluster();
        let mut tc = ThreadedCluster::new(2);
        let mut sim = crate::bsp::Cluster::new(2, cost);
        let rec_t = FlightRecorder::shared(64);
        let rec_s = FlightRecorder::shared(64);
        Substrate::set_observer(&mut tc, Some(rec_t.clone()));
        Substrate::set_observer(&mut sim, Some(rec_s.clone()));
        let self_send = |m: usize, _st: &mut (), _in: Vec<u32>, _acct: &mut MachineAcct| {
            vec![(m, 7u32)]
        };
        let work_only = |_m: usize, _st: &mut (), _in: Vec<u32>, acct: &mut MachineAcct| {
            acct.work(3);
            Vec::<(usize, u32)>::new()
        };
        let cross_send = |m: usize, _st: &mut (), _in: Vec<u32>, _acct: &mut MachineAcct| {
            vec![((m + 1) % 2, 9u32)]
        };
        let mut st_t = vec![(); 2];
        let mut st_s = vec![(); 2];
        let _ = tc.superstep(&mut st_t, no_messages(2), self_send, |_| 2);
        let _ = tc.superstep(&mut st_t, no_messages(2), work_only, |_| 2);
        let _ = tc.superstep(&mut st_t, no_messages(2), cross_send, |_| 2);
        let _ = sim.superstep(&mut st_s, no_messages(2), self_send, |_| 2);
        let _ = sim.superstep(&mut st_s, no_messages(2), work_only, |_| 2);
        let _ = sim.superstep(&mut st_s, no_messages(2), cross_send, |_| 2);
        let (rt, rs) = (rec_t.lock().unwrap(), rec_s.lock().unwrap());
        assert_eq!(rt.len(), 2, "self-send-only epoch records nothing");
        assert_eq!(rt.det_stream(), rs.det_stream());
        assert!(rt.events().all(|e| e.wall.is_some()), "threaded events carry busy ns");
        assert!(rs.events().all(|e| e.wall.is_none()), "sim events never do");
    }

    #[test]
    fn pool_survives_between_differently_typed_supersteps() {
        // The same pool must serve supersteps with different payload
        // types (the SPMD graph engine alternates value and contribution
        // messages within one round).
        let mut tc = ThreadedCluster::new(2);
        let mut state = vec![(); 2];
        let ints = tc.superstep(
            &mut state,
            no_messages(2),
            |m, _st, _in, _acct| vec![((m + 1) % 2, m as u64)],
            |_| 1,
        );
        let strs = tc.superstep(
            &mut state,
            ints,
            |m, _st, inbox, _acct| {
                inbox
                    .into_iter()
                    .map(|x| ((m + 1) % 2, format!("got-{x}")))
                    .collect::<Vec<(usize, String)>>()
            },
            |s: &String| s.len() as u64,
        );
        assert_eq!(strs[0], vec!["got-0".to_string()]);
        assert_eq!(strs[1], vec!["got-1".to_string()]);
        assert_eq!(tc.epochs(), 2);
        assert_eq!(tc.worker_epochs(), vec![2, 2]);
    }
}
