//! The real shared-nothing threaded backend.
//!
//! One OS worker thread per logical machine.  Each superstep:
//!
//! 1. all P workers rendezvous on a reusable [`std::sync::Barrier`]
//!    (the superstep start line — keeps the per-machine wall-clock
//!    windows comparable);
//! 2. each worker runs the superstep closure on *its own* state — the
//!    scheduler threads each machine's `DistStore` shard, slot store,
//!    pull-tree nodes etc. through here, so no two threads ever touch the
//!    same data (shared-nothing by construction, enforced by `&mut`);
//! 3. each worker pushes its outbox payloads into per-destination
//!    channels (the per-pair edges of the paper's Fig 2 machine model)
//!    and drops its senders — mpsc sends never block, so the payloads
//!    are fully buffered before anyone starts reading;
//! 4. all workers rendezvous on the barrier again (the communication
//!    barrier), then drain their receivers — which never block, because
//!    every sender hung up before the barrier.  Time spent *waiting* at
//!    either barrier is deliberately excluded from the per-machine busy
//!    clocks: `compute_ns` is the superstep closure, `comm_ns` is
//!    send + drain, and barrier wait is idle — so a machine that
//!    finishes early does not absorb the slowest machine's window and
//!    load imbalance stays visible in the busy table;
//! 5. the received payloads are sorted by (sender, emission index),
//!    restoring exactly the delivery order the simulator uses, so a
//!    threaded run is bit-identical to a simulated one.
//!
//! Workers are spawned per superstep with [`std::thread::scope`]: scoped
//! spawning is what lets worker closures borrow the scheduler's
//! stack-local state without `unsafe` lifetime erasure.  The ~10 µs spawn
//! cost per worker is amortized over the Θ(n/P) work of a superstep; a
//! persistent pool (which would need boxed closures with erased
//! lifetimes, or crossbeam) is future work once profiles demand it.
//!
//! Metrics: the [`Metrics`] mirror is filled with the same ledger the
//! simulator keeps (per-machine work units, words sent/received, executed
//! tasks, supersteps), except that the time breakdown holds *measured*
//! seconds — `computation` accumulates the slowest machine's compute
//! window and `communication` the slowest machine's send+drain window.
//! Per-machine cumulative wall-clock is kept separately in
//! [`ThreadedCluster::compute_ns`] / [`ThreadedCluster::comm_ns`].

use std::sync::mpsc;
use std::sync::Barrier;
use std::time::Instant;

use crate::bsp::MachineId;
use crate::metrics::Metrics;

use super::{MachineAcct, Substrate};

/// What one worker reports back from one superstep.
struct WorkerReport<T> {
    acct: MachineAcct,
    inbox: Vec<T>,
    sent_words: u64,
    recv_words: u64,
    sent_msgs: u64,
    compute_ns: u64,
    comm_ns: u64,
}

/// Releases the communication barrier if a worker unwinds before
/// reaching it, so a panic in one superstep closure propagates as a
/// panic (via the scope join) instead of deadlocking the other P-1
/// workers.  By drop order, the panicking worker's sender clones
/// (closure captures) drop right after this guard fires, so the released
/// peers' drains still terminate.
struct BarrierOnUnwind<'a> {
    barrier: &'a Barrier,
    armed: bool,
}

impl Drop for BarrierOnUnwind<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.barrier.wait();
        }
    }
}

/// A real cluster of P worker threads (see module docs).
pub struct ThreadedCluster {
    p: usize,
    /// Same ledger as the simulator's; `time` holds measured seconds.
    pub metrics: Metrics,
    /// Cumulative per-machine wall-clock spent inside superstep closures.
    pub compute_ns: Vec<u64>,
    /// Cumulative per-machine wall-clock spent sending + draining.
    pub comm_ns: Vec<u64>,
    /// Reusable superstep start barrier (all P workers rendezvous here).
    barrier: Barrier,
}

impl ThreadedCluster {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1, "cluster needs at least one machine");
        ThreadedCluster {
            p,
            metrics: Metrics::new(p),
            compute_ns: vec![0; p],
            comm_ns: vec![0; p],
            barrier: Barrier::new(p),
        }
    }

    /// Total busy wall-clock of machine `m` so far, in nanoseconds.
    pub fn busy_ns(&self, m: MachineId) -> u64 {
        self.compute_ns[m] + self.comm_ns[m]
    }

    /// Busy wall-clock of the most-loaded machine, in milliseconds — the
    /// quantity the BSP max-terms model, now measured for real.
    pub fn max_busy_ms(&self) -> f64 {
        (0..self.p).map(|m| self.busy_ns(m)).max().unwrap_or(0) as f64 / 1e6
    }

    /// Per-machine busy milliseconds (compute + comm).
    pub fn busy_ms_by_machine(&self) -> Vec<f64> {
        (0..self.p).map(|m| self.busy_ns(m) as f64 / 1e6).collect()
    }

    pub fn reset_metrics(&mut self) {
        self.metrics = Metrics::new(self.p);
        self.compute_ns.fill(0);
        self.comm_ns.fill(0);
    }
}

impl Substrate for ThreadedCluster {
    fn machines(&self) -> usize {
        self.p
    }

    fn superstep<St, Tin, Tout, F, W>(
        &mut self,
        state: &mut [St],
        inboxes: Vec<Vec<Tin>>,
        f: F,
        words: W,
    ) -> Vec<Vec<Tout>>
    where
        St: Send,
        Tin: Send,
        Tout: Send,
        F: Fn(MachineId, &mut St, Vec<Tin>, &mut MachineAcct) -> Vec<(MachineId, Tout)> + Sync,
        W: Fn(&Tout) -> u64 + Sync,
    {
        let p = self.p;
        assert_eq!(state.len(), p, "state must have one entry per machine");
        assert_eq!(inboxes.len(), p, "inboxes must have one entry per machine");

        // One channel per destination machine; every worker holds a clone
        // of every sender, giving P*P logical point-to-point edges.
        let mut txs = Vec::with_capacity(p);
        let mut rxs = Vec::with_capacity(p);
        for _ in 0..p {
            let (tx, rx) = mpsc::channel::<(u32, u32, Tout)>();
            txs.push(tx);
            rxs.push(rx);
        }
        let worker_txs: Vec<Vec<mpsc::Sender<(u32, u32, Tout)>>> =
            (0..p).map(|_| txs.clone()).collect();
        drop(txs); // workers' clones are now the only senders

        let f = &f;
        let words = &words;
        let barrier = &self.barrier;

        let reports: Vec<WorkerReport<Tout>> = std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(p);
            let workers = state
                .iter_mut()
                .zip(inboxes)
                .zip(worker_txs.into_iter().zip(rxs))
                .enumerate();
            for (m, ((st, inbox), (txs, rx))) in workers {
                let spawned = std::thread::Builder::new()
                    .name(format!("tdorch-worker-{m}"))
                    .spawn_scoped(scope, move || {
                    barrier.wait(); // superstep start line
                    let mut comm_guard = BarrierOnUnwind { barrier, armed: true };
                    let t0 = Instant::now();
                    let mut acct = MachineAcct::default();
                    let outbox = f(m, st, inbox, &mut acct);
                    let compute_ns = t0.elapsed().as_nanos() as u64;

                    let t1 = Instant::now();
                    let mut sent_words = 0u64;
                    let mut sent_msgs = 0u64;
                    for (i, (to, payload)) in outbox.into_iter().enumerate() {
                        debug_assert!(to < p, "destination {to} out of range");
                        if to != m {
                            // Self-sends are free, as in the simulator.
                            sent_words += words(&payload);
                            sent_msgs += 1;
                        }
                        txs[to]
                            .send((m as u32, i as u32, payload))
                            .expect("peer receiver dropped mid-superstep");
                    }
                    drop(txs);
                    let send_ns = t1.elapsed().as_nanos() as u64;
                    // Communication barrier: once every worker passes this
                    // line, every sender clone has been dropped, so the
                    // drain below never blocks.  The wait itself is idle
                    // time and stays OFF the busy clocks — an early
                    // finisher must not absorb the slowest machine's
                    // window, or load imbalance would vanish from the
                    // per-machine busy table.
                    comm_guard.armed = false;
                    barrier.wait();
                    let t2 = Instant::now();
                    let mut inbox: Vec<(u32, u32, Tout)> = rx.iter().collect();
                    inbox.sort_unstable_by_key(|&(sender, idx, _)| (sender, idx));
                    let mut recv_words = 0u64;
                    for (sender, _, payload) in &inbox {
                        if *sender as usize != m {
                            recv_words += words(payload);
                        }
                    }
                    let comm_ns = send_ns + t2.elapsed().as_nanos() as u64;
                    WorkerReport {
                        acct,
                        inbox: inbox.into_iter().map(|(_, _, payload)| payload).collect(),
                        sent_words,
                        recv_words,
                        sent_msgs,
                        compute_ns,
                        comm_ns,
                    }
                });
                match spawned {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        // Earlier workers are already parked at the start
                        // barrier and can never be released (std Barrier
                        // has no poisoning), so unwinding here would trade
                        // a clear error for a permanent hang: fail fast.
                        eprintln!("fatal: could not spawn worker thread {m} of {p}: {e}");
                        std::process::abort();
                    }
                }
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("worker thread panicked"))
                .collect()
        });

        // Fold the reports into the metrics mirror (driver thread).
        let mut next = Vec::with_capacity(p);
        let mut dirty = false;
        let mut max_compute_ns = 0u64;
        let mut max_comm_ns = 0u64;
        for (m, report) in reports.into_iter().enumerate() {
            let WorkerReport {
                acct,
                inbox,
                sent_words,
                recv_words,
                sent_msgs,
                compute_ns,
                comm_ns,
            } = report;
            self.metrics.work_by_machine[m] += acct.work_units;
            self.metrics.executed_by_machine[m] += acct.executed_tasks;
            self.metrics.sent_by_machine[m] += sent_words;
            self.metrics.recv_by_machine[m] += recv_words;
            self.metrics.total_words += sent_words;
            self.metrics.total_msgs += sent_msgs;
            self.compute_ns[m] += compute_ns;
            self.comm_ns[m] += comm_ns;
            max_compute_ns = max_compute_ns.max(compute_ns);
            max_comm_ns = max_comm_ns.max(comm_ns);
            dirty |= acct.work_units > 0 || sent_msgs > 0;
            next.push(inbox);
        }
        if dirty {
            self.metrics.supersteps += 1;
            self.metrics.time.computation += max_compute_ns as f64 / 1e9;
            self.metrics.time.communication += max_comm_ns as f64 / 1e9;
        }
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{no_messages, nothing_words, Nothing};

    #[test]
    fn routes_like_the_simulator() {
        let mut tc = ThreadedCluster::new(4);
        let mut state = vec![0u64; 4];
        let inboxes = tc.superstep(
            &mut state,
            no_messages(4),
            |m, st, _in, acct| {
                *st = m as u64;
                acct.work(1);
                // Everyone sends two payloads to machine 1.
                vec![(1, (m * 10) as u32), (1, (m * 10 + 1) as u32)]
            },
            |_| 3,
        );
        // Delivery order: (sender, emission index).
        assert_eq!(inboxes[1], vec![0, 1, 10, 11, 20, 21, 30, 31]);
        assert!(inboxes[0].is_empty() && inboxes[2].is_empty() && inboxes[3].is_empty());
        assert_eq!(state, vec![0, 1, 2, 3]);
        // Machine 1 received 6 cross-machine payloads * 3 words; its own
        // 2 self-sends are free.
        assert_eq!(tc.metrics.recv_by_machine[1], 18);
        assert_eq!(tc.metrics.total_words, 18);
        assert_eq!(tc.metrics.supersteps, 1);
    }

    #[test]
    fn state_is_private_per_machine() {
        let mut tc = ThreadedCluster::new(8);
        let mut state: Vec<Vec<u64>> = (0..8).map(|_| Vec::new()).collect();
        for round in 0..5u64 {
            let _: Vec<Vec<Nothing>> = tc.superstep(
                &mut state,
                no_messages(8),
                |m, st, _in, _acct| {
                    st.push(m as u64 * 100 + round);
                    Vec::new()
                },
                nothing_words,
            );
        }
        for (m, st) in state.iter().enumerate() {
            let expect: Vec<u64> = (0..5).map(|r| m as u64 * 100 + r).collect();
            assert_eq!(*st, expect);
        }
    }

    #[test]
    fn multi_superstep_pipeline() {
        // Token ring: a token hops machine to machine for P supersteps
        // and must come home incremented P times.
        let p = 5;
        let mut tc = ThreadedCluster::new(p);
        let mut state = vec![(); p];
        let mut inboxes = tc.superstep(
            &mut state,
            no_messages(p),
            |m, _st, _in, _acct| {
                if m == 0 {
                    vec![(1usize, 0u64)]
                } else {
                    Vec::new()
                }
            },
            |_| 1,
        );
        for _ in 0..p - 1 {
            inboxes = tc.superstep(
                &mut state,
                inboxes,
                |m, _st, inbox, _acct| {
                    inbox
                        .into_iter()
                        .map(|tok| ((m + 1) % p, tok + 1))
                        .collect()
                },
                |_| 1,
            );
        }
        assert_eq!(inboxes[0], vec![(p - 1) as u64]);
    }

    #[test]
    fn wall_clock_accumulates() {
        let mut tc = ThreadedCluster::new(2);
        let mut state = vec![(); 2];
        let _: Vec<Vec<Nothing>> = tc.superstep(
            &mut state,
            no_messages(2),
            |_m, _st, _in, acct| {
                // A small spin so the compute window is nonzero.
                let mut x = 0u64;
                for i in 0..10_000u64 {
                    x = x.wrapping_add(i * i);
                }
                std::hint::black_box(x);
                acct.work(1);
                Vec::new()
            },
            nothing_words,
        );
        assert!(tc.busy_ns(0) > 0);
        assert!(tc.busy_ns(1) > 0);
        assert!(tc.max_busy_ms() > 0.0);
        assert_eq!(tc.metrics.supersteps, 1);
        assert!(tc.metrics.time.computation > 0.0);
    }
}
