//! `exec` — execution substrates for orchestration stages.
//!
//! The paper's schedulers are distributed algorithms over P shared-nothing
//! machines exchanging messages in barrier-separated supersteps.  This
//! module abstracts that machine model behind the [`Substrate`] trait so
//! one scheduler implementation (TD-Orch's four phases, or any of the
//! §2.3 baselines) runs unchanged on either backend:
//!
//! * [`crate::bsp::Cluster`] — the single-threaded *simulator*: runs every
//!   machine's superstep closure sequentially and charges the BSP
//!   h-relation cost model.  All paper figures/tables come from this
//!   backend; its numbers are deterministic and hardware-independent.
//! * [`ThreadedCluster`] — the *real* backend: a **persistent pool** of
//!   one OS worker thread per logical machine (spawned once per cluster,
//!   parked between supersteps), each owning its shard of the
//!   [`crate::store::DistStore`] — or its graph shard, for
//!   [`crate::graph::spmd::SpmdEngine`] — exchanging payloads over
//!   channels and synchronizing on reusable barriers.  Its metrics are
//!   measured wall-clock and real bytes moved.
//!
//! The unit of execution is one **superstep**: every machine consumes its
//! inbox from the previous superstep, computes on its private state, and
//! emits `(destination, payload)` pairs; the substrate routes the payloads
//! and closes the step with a barrier.  Inboxes are delivered in
//! (sender, emission-index) order on *both* backends, so a scheduler run
//! is bit-for-bit identical on the simulator and on real threads — which
//! is what lets `tests/exec_equivalence.rs` cross-validate the two against
//! [`crate::orchestration::sequential_reference`].

pub mod apps;
pub mod threaded;

pub use threaded::{PoolSnapshot, ThreadedCluster};

use crate::bsp::{Cluster, MachineId};

/// Per-machine, per-superstep accounting handle passed to the superstep
/// closure.  Work/executed counts feed the substrate's [`crate::Metrics`]
/// mirror; on the threaded backend they coexist with measured wall-clock.
#[derive(Clone, Copy, Debug, Default)]
pub struct MachineAcct {
    pub work_units: u64,
    pub executed_tasks: u64,
}

impl MachineAcct {
    /// Charge `units` of local work to this machine in this superstep.
    #[inline]
    pub fn work(&mut self, units: u64) {
        self.work_units += units;
    }

    /// Record that this machine executed `n` tasks (Theorem 1(ii) metric).
    #[inline]
    pub fn executed(&mut self, n: u64) {
        self.executed_tasks += n;
    }
}

/// Uninhabited payload type for supersteps that start a stage (no inbox)
/// or end one (no outbox).
#[derive(Clone, Copy, Debug)]
pub enum Nothing {}

/// Empty inboxes for the first superstep of a stage.
pub fn no_messages(p: usize) -> Vec<Vec<Nothing>> {
    (0..p).map(|_| Vec::new()).collect()
}

/// Wire-size function for [`Nothing`] outboxes (never called — the type is
/// uninhabited — but the substrate API needs one).
pub fn nothing_words(_: &Nothing) -> u64 {
    0
}

/// A shared-nothing execution substrate: P logical machines running
/// barrier-separated supersteps.  See the module docs for the two
/// implementations and the determinism contract.
pub trait Substrate {
    /// Number of logical machines P.
    fn machines(&self) -> usize;

    /// Per-message overhead multiplier for messages accounted in
    /// subsequent supersteps: 1 (default) for packed/batched items,
    /// [`crate::bsp::RPC_MSG_FACTOR`] for unbatchable per-item RPC
    /// round-trips (the per-edge "direct pull" wire shape).  Only
    /// *accounting* backends act on it — the simulator folds it into its
    /// overhead time term; the measured threaded backend ignores it (its
    /// per-message cost is real wall-clock).  The ledger both backends
    /// share (words, message counts, work) never sees the factor, so
    /// cross-backend bit-equality is unaffected.
    fn set_msg_factor(&mut self, _factor: u64) {}

    /// Attach (or detach, with `None`) a flight recorder.  While one is
    /// attached, the substrate records one
    /// [`crate::obs::EventKind::Superstep`] per **ledger** superstep —
    /// same dirty condition, same per-machine ledger quantities, same
    /// call-site semantics on both backends — so the deterministic event
    /// stream is bit-identical between the simulator and the threaded
    /// pool.  The threaded backend additionally annotates each event
    /// with measured per-machine busy nanoseconds (never compared).
    ///
    /// Default is a no-op: a substrate that doesn't observe ignores the
    /// handle, and with no recorder attached both implementations skip
    /// all event work (zero cost when disabled).
    fn set_observer(&mut self, _obs: Option<crate::obs::ObserverHandle>) {}

    /// Ledger supersteps completed so far — supersteps in which at least
    /// one machine charged work or sent a cross-machine message (both
    /// backends skip empty ones under exactly this condition).  The
    /// ledger contract makes the count a pure function of what ran —
    /// never of the backend or the host — which is what lets the serving
    /// layer use *deltas* of this counter as a deterministic logical
    /// clock for per-query service cost ([`crate::serve`]).
    fn ledger_supersteps(&self) -> u64;

    /// Cumulative work makespan: Σ over ledger supersteps of the
    /// max-over-machines work units of that step
    /// ([`crate::Metrics::makespan_work`]).  Like `ledger_supersteps`
    /// this is a pure function of what ran — both backends fold the same
    /// per-step work vectors — so *deltas* of it give the serving layer a
    /// placement-*sensitive* logical cost: step counts barely move when a
    /// hot machine is relieved, but the per-step maxima do.
    fn ledger_makespan(&self) -> u64;

    /// Run one superstep.
    ///
    /// `state[m]` is machine `m`'s private state (on the threaded backend
    /// it is handed to machine `m`'s worker thread — shards of the
    /// `DistStore` travel through here).  `inboxes[m]` are the payloads
    /// delivered to `m` by the previous superstep.  `f(m, state, inbox,
    /// acct)` computes machine `m`'s contribution and returns its outbox
    /// as `(destination, payload)` pairs; `words` gives each payload's
    /// wire size for communication accounting.  Returns next inboxes,
    /// delivered in deterministic (sender, emission-index) order.
    ///
    /// (`Tout: 'static` because the threaded backend ships batches over a
    /// type-erased persistent mesh — payloads are plain data, never
    /// borrows.)
    fn superstep<St, Tin, Tout, F, W>(
        &mut self,
        state: &mut [St],
        inboxes: Vec<Vec<Tin>>,
        f: F,
        words: W,
    ) -> Vec<Vec<Tout>>
    where
        St: Send,
        Tin: Send,
        Tout: Send + 'static,
        F: Fn(MachineId, &mut St, Vec<Tin>, &mut MachineAcct) -> Vec<(MachineId, Tout)> + Sync,
        W: Fn(&Tout) -> u64 + Sync;
}

/// The simulator backend: machines run sequentially on the caller thread;
/// the superstep is charged with the BSP cost model at the closing
/// barrier, exactly like the pre-existing `Cluster::exchange` path.
impl Substrate for Cluster {
    fn machines(&self) -> usize {
        self.p
    }

    fn set_msg_factor(&mut self, factor: u64) {
        Cluster::set_msg_factor(self, factor);
    }

    fn set_observer(&mut self, obs: Option<crate::obs::ObserverHandle>) {
        Cluster::set_observer(self, obs);
    }

    fn ledger_supersteps(&self) -> u64 {
        self.metrics.supersteps
    }

    fn ledger_makespan(&self) -> u64 {
        self.metrics.makespan_work
    }

    fn superstep<St, Tin, Tout, F, W>(
        &mut self,
        state: &mut [St],
        inboxes: Vec<Vec<Tin>>,
        f: F,
        words: W,
    ) -> Vec<Vec<Tout>>
    where
        St: Send,
        Tin: Send,
        Tout: Send + 'static,
        F: Fn(MachineId, &mut St, Vec<Tin>, &mut MachineAcct) -> Vec<(MachineId, Tout)> + Sync,
        W: Fn(&Tout) -> u64 + Sync,
    {
        let p = self.p;
        assert_eq!(state.len(), p, "state must have one entry per machine");
        assert_eq!(inboxes.len(), p, "inboxes must have one entry per machine");
        let mut next: Vec<Vec<Tout>> = (0..p).map(|_| Vec::new()).collect();
        for (m, (st, inbox)) in state.iter_mut().zip(inboxes).enumerate() {
            let mut acct = MachineAcct::default();
            let outbox = f(m, st, inbox, &mut acct);
            if acct.work_units > 0 {
                self.work(m, acct.work_units);
            }
            if acct.executed_tasks > 0 {
                self.executed(m, acct.executed_tasks);
            }
            for (to, payload) in outbox {
                debug_assert!(to < p, "destination {to} out of range");
                self.account_msg(m, to, words(&payload));
                next[to].push(payload);
            }
        }
        self.barrier();
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::CostModel;

    fn unit_cost() -> CostModel {
        CostModel {
            g: 1.0,
            l: 0.0,
            work_unit: 1.0,
            per_msg: 0.0,
            numa: crate::bsp::NumaTopo::Single,
        }
    }

    #[test]
    fn cluster_superstep_routes_and_accounts() {
        let mut c = Cluster::new(3, unit_cost());
        let mut state = vec![0u64; 3];
        // Each machine sends its id+10 to machine (m+1) % 3 and charges
        // 2 units of work.
        let inboxes = c.superstep(
            &mut state,
            no_messages(3),
            |m, st, _in, acct| {
                *st += 1;
                acct.work(2);
                vec![((m + 1) % 3, (m + 10) as u32)]
            },
            |_| 4,
        );
        assert_eq!(inboxes[0], vec![12]);
        assert_eq!(inboxes[1], vec![10]);
        assert_eq!(inboxes[2], vec![11]);
        assert_eq!(state, vec![1, 1, 1]);
        assert_eq!(c.metrics.total_words, 12);
        assert_eq!(c.metrics.work_by_machine, vec![2, 2, 2]);
        assert_eq!(c.metrics.supersteps, 1);
    }

    #[test]
    fn cluster_superstep_delivery_order_is_sender_then_emission() {
        let mut c = Cluster::new(4, unit_cost());
        let mut state = vec![(); 4];
        let inboxes = c.superstep(
            &mut state,
            no_messages(4),
            |m, _st, _in, _acct| vec![(0, (m, 0usize)), (0, (m, 1usize))],
            |_| 1,
        );
        let expect: Vec<(usize, usize)> =
            (0..4).flat_map(|s| [(s, 0), (s, 1)]).collect();
        assert_eq!(inboxes[0], expect);
    }

    #[test]
    fn empty_superstep_charges_nothing() {
        let mut c = Cluster::new(2, unit_cost());
        let mut state = vec![(); 2];
        let _: Vec<Vec<Nothing>> = c.superstep(
            &mut state,
            no_messages(2),
            |_m, _st, _in, _acct| Vec::new(),
            nothing_words,
        );
        assert_eq!(c.metrics.supersteps, 0);
        assert_eq!(c.metrics.sim_seconds(), 0.0);
    }
}
