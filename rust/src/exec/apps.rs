//! Orchestration apps wired end-to-end through the execution substrates.
//!
//! * [`CounterApp`] — the canonical additive toy app (Def. 2 class ii),
//!   shared by tests and benches.
//! * [`SsspApp`] + [`sssp_stages`] — single-source shortest paths as a
//!   sequence of orchestration stages: each frontier round is one stage
//!   whose tasks are the frontier's out-edges, the lambda is the same
//!   `min(dv, du + w)` relaxation the Pallas `relax_batch` artifact
//!   computes, ⊗ is `min` (associative, commutative, idempotent — Def. 2
//!   class i), and ⊙ relaxes the destination's chunk.  The driver derives
//!   the next frontier by diffing candidate distances across the stage,
//!   so the whole algorithm runs unchanged on the simulator or on the
//!   threaded backend — and must produce exactly the distances that
//!   [`crate::graph::algorithms::sssp`] computes on the simulated
//!   TDO-GP engine.

use crate::det::det_set;
use crate::graph::{Graph, Vid};
use crate::orchestration::{spread_tasks, OrchApp, Scheduler, Task};
use crate::store::{Addr, DistStore};

use super::Substrate;

/// Additive counters: chunk = i64, ctx = increment, ⊗ = +, ⊙ = +=.
pub struct CounterApp;

impl OrchApp for CounterApp {
    type Ctx = i64;
    type Val = i64;
    type Out = i64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        8
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, ctx: &i64, _val: &i64) -> Option<i64> {
        Some(*ctx)
    }
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }
    fn apply(&self, val: &mut i64, out: i64) {
        *val += out;
    }
}

/// A tentative distance chunk.  `Default` is "unreached" (+inf), which is
/// what makes the store's absent-chunk semantics correct for SSSP.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dist(pub f64);

impl Default for Dist {
    fn default() -> Self {
        Dist(f64::INFINITY)
    }
}

/// SSSP relaxation as an orchestration app.  A task reads the distance
/// chunk of edge source `u` (`read_addr = u`), carries the edge weight as
/// its context, and writes a candidate distance to the chunk of edge
/// target `v` (`write_addr = v`).
pub struct SsspApp;

impl OrchApp for SsspApp {
    /// Edge weight.
    type Ctx = f32;
    type Val = Dist;
    /// Candidate distance for the target vertex.
    type Out = f64;

    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        2
    }
    fn out_words(&self) -> u64 {
        2
    }

    fn execute(&self, w: &f32, du: &Dist) -> Option<f64> {
        if du.0.is_finite() {
            Some(du.0 + *w as f64)
        } else {
            None // relaxing from an unreached vertex proposes nothing
        }
    }

    fn combine(&self, a: f64, b: f64) -> f64 {
        a.min(b)
    }

    fn apply(&self, dv: &mut Dist, out: f64) {
        if out < dv.0 {
            dv.0 = out;
        }
    }
}

/// Frontier-driven SSSP over orchestration stages (see module docs).
/// Returns per-vertex distances (`f64::INFINITY` = unreachable).
pub fn sssp_stages<S: Substrate>(
    sub: &mut S,
    sched: &dyn Scheduler<SsspApp, S>,
    g: &Graph,
    src: Vid,
) -> Vec<f64> {
    let p = sub.machines();
    let app = SsspApp;
    let mut store: DistStore<Dist> = DistStore::new(p);
    store.insert(src as Addr, Dist(0.0));
    let mut frontier: Vec<Vid> = vec![src];
    // Bellman-Ford settles within n rounds on non-negative weights; the
    // frontier normally empties long before that.
    let mut rounds = 0usize;
    while !frontier.is_empty() && rounds <= g.n {
        rounds += 1;
        let mut tasks: Vec<Task<f32>> = Vec::new();
        let mut candidates: Vec<Vid> = Vec::new();
        let mut seen = det_set();
        for &u in &frontier {
            for &(v, w) in g.neighbors(u) {
                tasks.push(Task::new(u as Addr, v as Addr, w));
                if seen.insert(v) {
                    candidates.push(v);
                }
            }
        }
        if tasks.is_empty() {
            break;
        }
        let before: Vec<f64> = candidates
            .iter()
            .map(|&v| store.read_copy(v as Addr).0)
            .collect();
        sched.run_stage(sub, &app, spread_tasks(tasks, p), &mut store);
        frontier = candidates
            .iter()
            .zip(&before)
            .filter(|&(&v, &b)| store.read_copy(v as Addr).0 < b)
            .map(|(&v, _)| v)
            .collect();
    }
    (0..g.n as Vid)
        .map(|v| store.read_copy(v as Addr).0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bsp::{Cluster, CostModel};
    use crate::orchestration::tdorch::TdOrch;

    /// Textbook Dijkstra on the raw graph.
    fn dijkstra_ref(g: &Graph, src: Vid) -> Vec<f64> {
        let mut dist = vec![f64::INFINITY; g.n];
        dist[src as usize] = 0.0;
        let mut done = vec![false; g.n];
        loop {
            let mut u = None;
            let mut best = f64::INFINITY;
            for v in 0..g.n {
                if !done[v] && dist[v] < best {
                    best = dist[v];
                    u = Some(v as Vid);
                }
            }
            let Some(u) = u else { break };
            done[u as usize] = true;
            for &(v, w) in g.neighbors(u) {
                let cand = dist[u as usize] + w as f64;
                if cand < dist[v as usize] {
                    dist[v as usize] = cand;
                }
            }
        }
        dist
    }

    #[test]
    fn sssp_stages_matches_dijkstra_on_simulator() {
        let g = crate::graph::gen::barabasi_albert(400, 4, 3);
        let expected = dijkstra_ref(&g, 0);
        let mut cluster = Cluster::new(4, CostModel::paper_cluster());
        let got = sssp_stages(&mut cluster, &TdOrch::new(), &g, 0);
        assert_eq!(got.len(), expected.len());
        for (v, (a, b)) in got.iter().zip(&expected).enumerate() {
            assert!(
                (a - b).abs() < 1e-9 || (a.is_infinite() && b.is_infinite()),
                "vertex {v}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn unreached_vertices_stay_infinite() {
        // Two disconnected edges: 0-1 and 2-3.
        let g = Graph::from_arcs(
            4,
            vec![(0, 1, 1.0), (1, 0, 1.0), (2, 3, 1.0), (3, 2, 1.0)],
        );
        let mut cluster = Cluster::new(2, CostModel::paper_cluster());
        let d = sssp_stages(&mut cluster, &TdOrch::new(), &g, 0);
        assert_eq!(d[0], 0.0);
        assert_eq!(d[1], 1.0);
        assert!(d[2].is_infinite() && d[3].is_infinite());
    }
}
