//! Epoch-keyed result memoization for the serving layer.
//!
//! Zipf-skewed streams repeat hot sources constantly; because every
//! query on this engine is bit-deterministic given (kind, source, flags,
//! PR iteration count, graph epoch), a result computed once can be
//! replayed for every later identical query at **zero** engine cost —
//! the ROADMAP's "memoized serving" attack.  The key design points:
//!
//! * **Epoch in the key** ([`CacheKey::epoch`], PR 6's `graph_epoch`):
//!   a stale entry can never match a post-mutation probe, so serving a
//!   pre-mutation result after an epoch bump is *structurally*
//!   impossible, not merely avoided.  [`ResultCache::retain_epoch`]
//!   additionally evicts non-current entries — a mutated graph never
//!   comes back, so stale rows are pure memory waste.
//! * **Canonical sources** ([`canonical_source`]): CC and PR ignore the
//!   query source, so all their queries share one entry per epoch.
//! * **Dispatch-only** consultation: the server probes the cache when a
//!   batch member comes up for dispatch; [`super::Server::run_query`]
//!   itself never touches it, so the single-shot path the reverse-order
//!   cross-checks re-execute can never validate a result against a
//!   cached copy of itself (`tests/serve_cache.rs` pins this).

use crate::det::{det_map, DetMap};
use crate::graph::flags::Flags;
use crate::graph::Vid;
use crate::workload::QueryKind;

/// Full result identity of one served query.  Two queries with equal
/// keys produce bit-identical results, so replaying the stored bits is
/// exact, not approximate.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct CacheKey {
    pub kind: QueryKind,
    /// Canonicalized via [`canonical_source`] (0 for source-independent
    /// kinds), so equivalent queries share an entry.
    pub source: Vid,
    /// The engine's whole policy block: results are a function of it.
    pub flags: Flags,
    /// PR iteration count — part of result identity for PR (harmless
    /// constant in the key for every other kind).
    pub pr_iters: usize,
    /// Graph epoch the result was computed against — the invalidation
    /// hook: a mutation bumps the epoch, and no pre-bump key can match
    /// a post-bump probe.
    pub epoch: u64,
}

/// The source a result actually depends on: CC labels and PageRank
/// scores are global (source-free) computations, so every source maps
/// to one shared entry; the traversal kinds keep their real source.
pub fn canonical_source(kind: QueryKind, source: Vid) -> Vid {
    match kind {
        QueryKind::Cc | QueryKind::Pr => 0,
        QueryKind::Bfs | QueryKind::Sssp | QueryKind::Bc => source,
    }
}

/// Deterministic result store (fixed-seed hashing like every map in
/// this crate, though nothing iterates it — lookups only).
pub struct ResultCache {
    entries: DetMap<CacheKey, Vec<u64>>,
}

impl ResultCache {
    pub fn new() -> Self {
        ResultCache { entries: det_map() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &CacheKey) -> Option<&Vec<u64>> {
        self.entries.get(key)
    }

    pub fn insert(&mut self, key: CacheKey, bits: Vec<u64>) {
        self.entries.insert(key, bits);
    }

    /// Evict everything not at `epoch` — called on every epoch bump, so
    /// an invalidation drops *exactly* the stale entries (hot current
    /// entries survive untouched).
    pub fn retain_epoch(&mut self, epoch: u64) {
        self.entries.retain(|k, _| k.epoch == epoch);
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }
}

impl Default for ResultCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(kind: QueryKind, source: Vid, epoch: u64) -> CacheKey {
        CacheKey {
            kind,
            source: canonical_source(kind, source),
            flags: Flags::tdo_gp(),
            pr_iters: 5,
            epoch,
        }
    }

    #[test]
    fn source_independent_kinds_share_one_entry() {
        assert_eq!(key(QueryKind::Cc, 7, 0), key(QueryKind::Cc, 123, 0));
        assert_eq!(key(QueryKind::Pr, 7, 0), key(QueryKind::Pr, 123, 0));
        assert_ne!(key(QueryKind::Bfs, 7, 0), key(QueryKind::Bfs, 123, 0));
        assert_ne!(key(QueryKind::Bc, 7, 0), key(QueryKind::Bc, 123, 0));
    }

    #[test]
    fn epoch_and_flags_split_entries() {
        assert_ne!(key(QueryKind::Bfs, 7, 0), key(QueryKind::Bfs, 7, 1));
        let mut ablated = key(QueryKind::Bfs, 7, 0);
        ablated.flags = Flags::gemini_like();
        assert_ne!(key(QueryKind::Bfs, 7, 0), ablated);
    }

    #[test]
    fn retain_epoch_drops_exactly_the_stale_entries() {
        let mut c = ResultCache::new();
        c.insert(key(QueryKind::Bfs, 1, 0), vec![1]);
        c.insert(key(QueryKind::Bfs, 2, 0), vec![2]);
        c.insert(key(QueryKind::Bfs, 1, 1), vec![3]);
        assert_eq!(c.len(), 3);
        c.retain_epoch(1);
        assert_eq!(c.len(), 1, "both epoch-0 entries must go, the epoch-1 one stays");
        assert_eq!(c.get(&key(QueryKind::Bfs, 1, 1)), Some(&vec![3]));
        assert_eq!(c.get(&key(QueryKind::Bfs, 1, 0)), None);
    }
}
