//! The serving loop: bounded admission, deterministic batch formation,
//! back-to-back dispatch on the reused engine (see the module docs in
//! [`super`] for the pipeline picture and the determinism contract).

use std::collections::VecDeque;
use std::time::Instant;

use crate::exec::Substrate;
use crate::graph::algorithms::{bc, bfs, cc, pagerank, sssp};
use crate::graph::spmd::SpmdEngine;
use crate::graph::Vid;
use crate::metrics::p50_p95_p99;
use crate::workload::{Query, QueryKind};

use super::QueryShard;

/// PageRank iterations per PR query on the serving path (matches the
/// equivalence suite's round count; `repro table2`'s figure runs keep
/// their own deeper constant).
pub const DEFAULT_PR_ITERS: usize = 5;

/// Batching/admission policy.  All knobs are *logical* (query counts and
/// ticks), so a config fully determines the batch schedule.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Close a batch as soon as this many queries are pending.
    pub batch: usize,
    /// ...or as soon as the oldest pending query has waited this many
    /// ticks (bounds tail latency under a trickle of arrivals).
    pub deadline_ticks: u64,
    /// Bounded admission queue: arrivals beyond this are rejected — an
    /// open-loop server sheds load instead of buffering unboundedly.
    pub queue_cap: usize,
    /// PageRank iterations per PR query.
    pub pr_iters: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { batch: 8, deadline_ticks: 4, queue_cap: 64, pr_iters: DEFAULT_PR_ITERS }
    }
}

/// One served query's outcome.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: u64,
    pub kind: QueryKind,
    pub source: Vid,
    /// Canonical result encoding — BFS hop counts and CC labels
    /// zero/sign-extended to u64, SSSP/PR/BC f64 bit patterns — so every
    /// kind cross-checks with one `bits == bits` comparison (see
    /// [`Server::run_query`]).
    pub bits: Vec<u64>,
    /// Logical ticks between arrival and dispatch (deterministic).
    pub wait_ticks: u64,
    /// Measured service wall-clock, milliseconds (host-dependent).
    pub service_ms: f64,
    /// Sequence number of the batch this query was dispatched in.
    pub batch: u64,
}

/// Outcome of a whole serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub results: Vec<QueryResult>,
    /// Arrivals dropped at admission (queue full).
    pub rejected: u64,
    pub batches: u64,
    /// Logical ticks the run spanned.
    pub ticks: u64,
    /// Wall-clock of the whole admission+dispatch loop, milliseconds.
    pub wall_ms: f64,
}

impl ServeReport {
    pub fn served(&self) -> usize {
        self.results.len()
    }

    /// Sustained throughput over the whole run (NaN for an empty run).
    pub fn queries_per_sec(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return f64::NAN;
        }
        self.results.len() as f64 / (self.wall_ms / 1e3)
    }

    /// (p50, p95, p99) of per-query service wall-clock, ms.
    pub fn service_ms_percentiles(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> = self.results.iter().map(|r| r.service_ms).collect();
        p50_p95_p99(&xs)
    }

    /// (p50, p95, p99) of per-query queue wait, logical ticks.
    pub fn wait_tick_percentiles(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> = self.results.iter().map(|r| r.wait_ticks as f64).collect();
        p50_p95_p99(&xs)
    }
}

/// The online server: admits a stream, forms batches, dispatches each
/// batch back-to-back on one long-lived engine.
pub struct Server<B: Substrate> {
    engine: SpmdEngine<B, QueryShard>,
    cfg: ServeConfig,
}

impl<B: Substrate> Server<B> {
    pub fn new(engine: SpmdEngine<B, QueryShard>, cfg: ServeConfig) -> Self {
        assert!(cfg.batch >= 1, "batch size must be >= 1");
        assert!(cfg.queue_cap >= 1, "queue capacity must be >= 1");
        assert!(cfg.pr_iters >= 1, "PR needs at least one iteration");
        Server { engine, cfg }
    }

    pub fn engine(&self) -> &SpmdEngine<B, QueryShard> {
        &self.engine
    }

    /// Consume the server, returning the engine (to read final substrate
    /// metrics after the stream is done).
    pub fn into_engine(self) -> SpmdEngine<B, QueryShard> {
        self.engine
    }

    /// Execute one query on the reused engine: reset the shard its
    /// algorithm runs on (`QueryShard::reset_kind` — ingestion, relay
    /// trees and the worker pool stay), run the algorithm, encode the
    /// result canonically.  This is also the "single-shot" path the
    /// cross-checks use — a reset engine is bit-equivalent to a fresh
    /// one.
    pub fn run_query(&mut self, q: &Query) -> Vec<u64> {
        let kind = q.kind;
        self.engine
            .reset_for_query(move |m, meta, st: &mut QueryShard| st.reset_kind(kind, m, meta));
        match q.kind {
            QueryKind::Bfs => bfs(&mut self.engine, q.source)
                .into_iter()
                .map(|d| d as u64)
                .collect(),
            QueryKind::Sssp => sssp(&mut self.engine, q.source)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
            QueryKind::Pr => pagerank(&mut self.engine, self.cfg.pr_iters)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
            QueryKind::Cc => cc(&mut self.engine)
                .into_iter()
                .map(|l| l as u64)
                .collect(),
            QueryKind::Bc => bc(&mut self.engine, q.source)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
        }
    }

    /// Drive the full admission → batch → dispatch loop over `stream`
    /// (which must be in nondecreasing arrival order, as
    /// `generate_stream` emits it).
    pub fn run(&mut self, stream: &[Query]) -> ServeReport {
        self.run_with(stream, |_r, _e| {})
    }

    /// Like [`Server::run`], with a per-query observer called right
    /// after each dispatch with the fresh result and the engine — the
    /// hook `repro serve` uses to snapshot pool counters per query.
    pub fn run_with(
        &mut self,
        stream: &[Query],
        mut observe: impl FnMut(&QueryResult, &SpmdEngine<B, QueryShard>),
    ) -> ServeReport {
        debug_assert!(
            stream.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "stream must arrive in nondecreasing tick order"
        );
        let cfg = self.cfg;
        let mut pending: VecDeque<Query> = VecDeque::new();
        let mut results: Vec<QueryResult> = Vec::with_capacity(stream.len());
        let mut rejected = 0u64;
        let mut batches = 0u64;
        let mut next = 0usize; // cursor into `stream`
        let mut tick = 0u64;
        let t0 = Instant::now();
        while next < stream.len() || !pending.is_empty() {
            // ---- admission: this tick's arrivals, bounded queue ----
            while next < stream.len() && stream[next].arrival <= tick {
                if pending.len() < cfg.queue_cap {
                    pending.push_back(stream[next]);
                } else {
                    rejected += 1;
                }
                next += 1;
            }
            // ---- batch formation + dispatch ----
            loop {
                let full = pending.len() >= cfg.batch;
                let overdue = pending
                    .front()
                    .is_some_and(|q| tick - q.arrival >= cfg.deadline_ticks);
                // End of stream: nothing else will ever top the batch up,
                // so drain instead of waiting out the deadline.
                let draining = next >= stream.len() && !pending.is_empty();
                if !(full || overdue || draining) {
                    break;
                }
                let take = pending.len().min(cfg.batch);
                let batch_seq = batches;
                batches += 1;
                for _ in 0..take {
                    let q = pending.pop_front().expect("batch drew from an empty queue");
                    let ts = Instant::now();
                    let bits = self.run_query(&q);
                    let res = QueryResult {
                        id: q.id,
                        kind: q.kind,
                        source: q.source,
                        bits,
                        wait_ticks: tick - q.arrival,
                        service_ms: ts.elapsed().as_secs_f64() * 1e3,
                        batch: batch_seq,
                    };
                    observe(&res, &self.engine);
                    results.push(res);
                }
            }
            tick += 1;
            // Idle gap: nothing is queued and the next arrival is in
            // the future — jump straight to its tick instead of
            // spinning one loop iteration per empty tick (a caller-built
            // stream may place arrivals arbitrarily far apart).  No
            // query is waiting, so no wait computation can observe the
            // skipped ticks.
            if pending.is_empty() {
                if let Some(q) = stream.get(next) {
                    tick = tick.max(q.arrival);
                }
            }
        }
        ServeReport {
            results,
            rejected,
            batches,
            ticks: tick,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        }
    }
}
