//! The serving loop: bounded **pipelined** admission, deterministic
//! batch formation, per-query dispatch on the reused engine under a
//! logical service clock (see the module docs in [`super`] for the
//! pipeline picture and the determinism contract).

use std::collections::VecDeque;
use std::time::Instant;

use crate::exec::Substrate;
use crate::graph::algorithms::{bc, bfs, cc, pagerank, sssp};
use crate::graph::spmd::SpmdEngine;
use crate::graph::Vid;
use crate::metrics::p50_p95_p99;
use crate::mutate::MutationFeed;
use crate::obs::{CloseReason, EventKind, FlightRecorder, ObserverHandle};
use crate::place::{PlaceOp, PlacementController, PlacementPolicy};
use crate::workload::{ArrivalSource, Query, QueryKind};

use super::cache::{canonical_source, CacheKey, ResultCache};
use super::fused::{fusable, run_fused_wave};
use super::QueryShard;

/// PageRank iterations per PR query on the serving path (matches the
/// equivalence suite's round count; `repro table2`'s figure runs keep
/// their own deeper constant).
pub const DEFAULT_PR_ITERS: usize = 5;

/// Batching/admission policy.  All knobs are *logical* (query counts and
/// ticks), so a config fully determines the batch schedule.
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Close a batch as soon as this many queries are pending.
    pub batch: usize,
    /// ...or as soon as the oldest pending query has waited this many
    /// ticks (bounds the time a partial batch sits waiting to close;
    /// once the server is busy serving, further wait accrues at the
    /// logical service rate).
    pub deadline_ticks: u64,
    /// Bounded admission queue: arrivals beyond this are rejected — an
    /// open-loop server sheds load instead of buffering unboundedly.
    pub queue_cap: usize,
    /// PageRank iterations per PR query.
    pub pr_iters: usize,
    /// Logical service rate: how many *ledger* supersteps
    /// ([`Substrate::ledger_supersteps`]) the server retires per logical
    /// tick.  A query that consumed S ledger supersteps occupies the
    /// server for `max(1, ceil(S / supersteps_per_tick))` ticks, which is
    /// how service time enters the same clock that drives admission —
    /// deterministically, because ledger supersteps are a pure function
    /// of (graph, flags, P), never of the backend or the host.
    pub supersteps_per_tick: u64,
    /// Optional **work-sensitive** service pricing: when set, an engine
    /// pass that accumulated a work-makespan delta of `K` work units
    /// ([`Substrate::ledger_makespan`]) costs
    /// `max(ceil(steps / supersteps_per_tick), ceil(K / work_per_tick))`
    /// logical ticks instead of the step-count term alone.  Superstep
    /// *counts* barely move when one machine is overloaded — the
    /// straggler stretches every superstep instead, which only the
    /// per-step work maxima see — so this is the knob that makes the
    /// logical clock feel imbalance, and what adaptive placement
    /// ([`ServePolicy::placement`]) improves.  `None` (the default)
    /// reproduces the pure step-count clock bit for bit.
    pub work_per_tick: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            batch: 8,
            deadline_ticks: 4,
            queue_cap: 64,
            pr_iters: DEFAULT_PR_ITERS,
            supersteps_per_tick: 8,
            work_per_tick: None,
        }
    }
}

/// What the server *does* with admitted queries, as one typed value:
/// batch fusion, result memoization, and hotspot-adaptive placement.
/// Replaces the old loose `(fuse, cache)` boolean pair and the flags
/// that used to ride on [`ServeConfig`] — policy (what to run) and
/// config (the logical clock and admission shape) are now separate
/// types.  Build with the `with_*` combinators and install via
/// [`Server::set_serving_policy`] (between runs on one long-lived
/// server) or [`Server::with_serving_policy`] (at construction); the
/// default policy reproduces the plain per-query dispatch loop
/// bit for bit.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServePolicy {
    /// Fuse a closed batch's same-kind exact queries (BFS/SSSP/CC) into
    /// one multi-source engine wave ([`super::run_fused_wave`]).  Off
    /// (the default) dispatches every query singly — the exact pre-fusion
    /// loop, schedule-bit-identical.
    pub fuse: bool,
    /// Memoize results in a [`ResultCache`] keyed by `(kind, canonical
    /// source, flags, pr_iters, graph_epoch)` and serve repeats at zero
    /// service ticks.  Off by default.
    pub cache: bool,
    /// Hotspot-adaptive placement: run a [`PlacementController`] over
    /// the attached flight recorder's per-machine work signal and apply
    /// its block migrations/splits at epoch boundaries — between
    /// dispatches, never inside one.  `None` (the default) never moves
    /// a block.  An external controller passed via [`RunOpts::placement`]
    /// takes precedence for that run.
    pub placement: Option<PlacementPolicy>,
}

impl ServePolicy {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_fuse(mut self, fuse: bool) -> Self {
        self.fuse = fuse;
        self
    }

    pub fn with_cache(mut self, cache: bool) -> Self {
        self.cache = cache;
        self
    }

    pub fn with_placement(mut self, policy: PlacementPolicy) -> Self {
        self.placement = Some(policy);
        self
    }
}

/// One served query's outcome.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: u64,
    pub kind: QueryKind,
    pub source: Vid,
    /// Canonical result encoding — BFS hop counts and CC labels
    /// zero/sign-extended to u64, SSSP/PR/BC f64 bit patterns — so every
    /// kind cross-checks with one `bits == bits` comparison (see
    /// [`Server::run_query`]).
    pub bits: Vec<u64>,
    /// Logical ticks between arrival and dispatch (deterministic).
    pub wait_ticks: u64,
    /// Logical ticks of service this query occupied the server for —
    /// `max(1, ceil(ledger supersteps / supersteps_per_tick))`,
    /// deterministic and identical across backends.
    pub service_ticks: u64,
    /// Measured service wall-clock, milliseconds (host-dependent).
    pub service_ms: f64,
    /// Sequence number of the batch this query was dispatched in.
    pub batch: u64,
    /// Graph epoch the query executed against (0 = the freshly-ingested
    /// graph; mutations apply only *between* dispatches, so one epoch
    /// fully identifies the snapshot this result was computed on).
    pub graph_epoch: u64,
    /// Served from the result cache (zero service ticks, no engine
    /// pass).  Always false with [`ServePolicy::cache`] off.
    pub cached: bool,
}

impl QueryResult {
    /// Logical end-to-end latency: queue wait + service, ticks.
    pub fn sojourn_ticks(&self) -> u64 {
        self.wait_ticks + self.service_ticks
    }
}

/// One absorbed mutation batch in a serving run's timeline.
#[derive(Clone, Debug)]
pub struct MutationRecord {
    pub batch_id: u64,
    /// Logical tick the batch was scheduled for.
    pub arrival: u64,
    /// Tick at which it actually applied (>= arrival: the epoch barrier
    /// makes a due batch wait out the dispatch in progress).
    pub applied_tick: u64,
    /// Engine epoch after absorption (batch k brings the epoch to k+1).
    pub epoch_after: u64,
    /// Directed edge ops applied.
    pub ops: usize,
    /// Logical ticks the application occupied the server for.
    pub service_ticks: u64,
}

/// One applied placement round in a serving run's timeline: the
/// controller saw enough skew in its recorder window, and the engine
/// absorbed the resulting delta in place
/// ([`SpmdEngine::apply_placement`]) at an epoch boundary — between
/// dispatches, under the same barrier mutation batches use.
#[derive(Clone, Debug)]
pub struct PlacementRecord {
    /// Controller round number (1-based; bounded by
    /// [`PlacementPolicy::max_rounds`]).
    pub round: u64,
    /// Logical tick the delta applied at.
    pub applied_tick: u64,
    /// Whole-block migrations in the delta.
    pub moves: usize,
    /// Hot-block splits (each replicates the block's source vertex onto
    /// the destination machine) in the delta.
    pub splits: usize,
    /// The exact ops, for offline replay
    /// ([`crate::place::apply_to_distgraph`]).
    pub ops: Vec<PlaceOp>,
    /// Engine epoch after absorption (each op bumps it once).
    pub epoch_after: u64,
    /// Logical ticks the application occupied the server for.
    pub service_ticks: u64,
}

/// One engine pass of a batch dispatch: a fused multi-source wave
/// (`lanes >= 2`) or a single-query dispatch (`lanes == 1`).  Cache
/// hits never appear here — they cost no engine pass.
#[derive(Clone, Debug)]
pub struct WaveRecord {
    /// Batch sequence number the wave served members of.
    pub batch: u64,
    pub kind: QueryKind,
    /// Member count (1 = unfused single dispatch).
    pub lanes: usize,
    /// Member query ids, in dispatch order.
    pub query_ids: Vec<u64>,
    /// Logical ticks the pass occupied the server — charged ONCE for
    /// the whole wave and stamped on every member, so a fused batch's
    /// total service is the max-shaped wave cost, not a member sum.
    pub service_ticks: u64,
}

/// Outcome of a whole serving run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub results: Vec<QueryResult>,
    /// Arrivals dropped at admission (queue full).
    pub rejected: u64,
    /// Rejections split by query kind, indexed by [`QueryKind::index`].
    /// Invariant: the entries sum to `rejected` (asserted consistent
    /// with the recorder's `Reject` events in `tests/obs_trace.rs`).
    pub rejected_by_kind: [u64; 5],
    /// Deepest the bounded admission queue ever got (measured right
    /// after each admission round — the deterministic backlog peak).
    pub max_queue_depth: usize,
    pub batches: u64,
    /// Logical ticks the run spanned.
    pub ticks: u64,
    /// Wall-clock of the whole admission+dispatch loop, milliseconds.
    pub wall_ms: f64,
    /// Engine epoch when the run finished — equals the number of
    /// mutation batches absorbed; constant 0 for a mutation-free run.
    pub graph_epoch: u64,
    /// Timeline of absorbed mutation batches (empty without a feed).
    pub mutations: Vec<MutationRecord>,
    /// Timeline of applied placement rounds (empty unless a placement
    /// controller was active — [`ServePolicy::placement`] or
    /// [`RunOpts::placement`]).
    pub placements: Vec<PlacementRecord>,
    /// Queries served from the result cache (0 with the cache off).
    pub cache_hits: u64,
    /// Queries served by engine execution.  Invariant:
    /// `served() == cache_hits + cache_misses` — with the cache off,
    /// every served query counts as a miss.
    pub cache_misses: u64,
    /// One record per engine pass (fused or single), in dispatch order.
    pub waves: Vec<WaveRecord>,
}

impl ServeReport {
    pub fn served(&self) -> usize {
        self.results.len()
    }

    /// Arrivals of `kind` shed at admission.
    pub fn rejected_of(&self, kind: QueryKind) -> u64 {
        self.rejected_by_kind[kind.index()]
    }

    /// Total arrivals the run *offered*: served + rejected.  The old
    /// `queries_per_sec` reported served-over-wall and called it "the"
    /// throughput, silently dropping every rejected query from every
    /// rate metric; offered, goodput and rejection rate are now separate
    /// quantities.
    pub fn offered(&self) -> u64 {
        self.results.len() as u64 + self.rejected
    }

    /// Fraction of offered queries shed at admission (NaN for an empty
    /// run — there is no rate to report).
    pub fn rejection_rate(&self) -> f64 {
        let offered = self.offered();
        if offered == 0 {
            return f64::NAN;
        }
        self.rejected as f64 / offered as f64
    }

    /// *Served* throughput over the measured run, queries/sec (NaN for
    /// an empty run).
    pub fn goodput_qps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return f64::NAN;
        }
        self.results.len() as f64 / (self.wall_ms / 1e3)
    }

    /// *Offered* throughput over the measured run, queries/sec —
    /// rejected queries included (NaN for an empty run).
    pub fn offered_qps(&self) -> f64 {
        if self.wall_ms <= 0.0 {
            return f64::NAN;
        }
        self.offered() as f64 / (self.wall_ms / 1e3)
    }

    /// Served queries per logical tick — the deterministic goodput the
    /// load curves plot (identical across backends and hosts).
    pub fn goodput_per_tick(&self) -> f64 {
        if self.ticks == 0 {
            return f64::NAN;
        }
        self.results.len() as f64 / self.ticks as f64
    }

    /// Offered queries per logical tick over the run's actual span.
    pub fn offered_per_tick(&self) -> f64 {
        if self.ticks == 0 {
            return f64::NAN;
        }
        self.offered() as f64 / self.ticks as f64
    }

    /// (p50, p95, p99) of per-query service wall-clock, ms.
    pub fn service_ms_percentiles(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> = self.results.iter().map(|r| r.service_ms).collect();
        p50_p95_p99(&xs)
    }

    /// (p50, p95, p99) of per-query queue wait, logical ticks.
    pub fn wait_tick_percentiles(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> = self.results.iter().map(|r| r.wait_ticks as f64).collect();
        p50_p95_p99(&xs)
    }

    /// (p50, p95, p99) of per-query logical service cost, ticks.
    pub fn service_tick_percentiles(&self) -> (f64, f64, f64) {
        let xs: Vec<f64> = self.results.iter().map(|r| r.service_ticks as f64).collect();
        p50_p95_p99(&xs)
    }
}

/// The admission state of one serving run: the bounded queue plus the
/// shed/backlog counters `ServeReport` carries.  One struct so the two
/// admission call sites (loop head + mid-wave pipelined admission) stay
/// a single code path.
struct Admission {
    pending: VecDeque<Query>,
    rejected: u64,
    rejected_by_kind: [u64; 5],
    max_queue_depth: usize,
}

impl Admission {
    fn new() -> Self {
        Admission {
            pending: VecDeque::new(),
            rejected: 0,
            rejected_by_kind: [0; 5],
            max_queue_depth: 0,
        }
    }

    /// Admit every arrival `source` has scheduled at or before `tick`
    /// into the bounded queue; shed (and notify) the overflow.  With a
    /// recorder attached, each admission records its post-push queue
    /// depth and each shed arrival records a `Reject`.
    fn admit(
        &mut self,
        source: &mut dyn ArrivalSource,
        tick: u64,
        queue_cap: usize,
        rec: Option<&ObserverHandle>,
    ) {
        for q in source.poll(tick) {
            if self.pending.len() < queue_cap {
                self.pending.push_back(q);
                if let Some(rec) = rec {
                    rec.lock().unwrap().record(EventKind::Admit {
                        tick,
                        query: q.id,
                        kind: q.kind,
                        queue_depth: self.pending.len(),
                    });
                }
            } else {
                self.rejected += 1;
                self.rejected_by_kind[q.kind.index()] += 1;
                if let Some(rec) = rec {
                    rec.lock().unwrap().record(EventKind::Reject {
                        tick,
                        query: q.id,
                        kind: q.kind,
                    });
                }
                source.on_reject(q.id, tick);
            }
        }
        self.max_queue_depth = self.max_queue_depth.max(self.pending.len());
    }
}

/// Everything one [`Server::serve`] call can carry beyond the arrival
/// source, as one typed bundle — the single entry point's option block,
/// replacing the old quartet of specialized run methods.  Build with
/// the combinators:
///
/// ```ignore
/// server.serve(&mut src, RunOpts::default());                    // plain run
/// server.serve(&mut src, RunOpts::new().observe(|r, e| { .. })); // hook
/// server.serve(&mut src, RunOpts::new().feed(&mut feed));        // mutating
/// server.serve(&mut src, RunOpts::new().placement(&mut ctl));    // adaptive
/// ```
///
/// Every option defaults to absent, and an all-default bundle
/// reproduces the plain mutation-free run bit for bit.
pub struct RunOpts<'a, B: Substrate> {
    observe: Option<Box<dyn FnMut(&QueryResult, &SpmdEngine<B, QueryShard>) + 'a>>,
    feed: Option<&'a mut MutationFeed>,
    placement: Option<&'a mut PlacementController>,
}

impl<'a, B: Substrate> RunOpts<'a, B> {
    pub fn new() -> Self {
        RunOpts {
            observe: None,
            feed: None,
            placement: None,
        }
    }

    /// Per-query hook, called right after each result lands with the
    /// fresh result and the serving engine — e.g. to snapshot pool
    /// counters per query (`repro serve`) or drive closed-loop clients.
    pub fn observe(
        mut self,
        f: impl FnMut(&QueryResult, &SpmdEngine<B, QueryShard>) + 'a,
    ) -> Self {
        self.observe = Some(Box::new(f));
        self
    }

    /// Live mutation feed: its delta batches interleave with queries on
    /// the logical service clock, under the epoch barrier.
    pub fn feed(mut self, feed: &'a mut MutationFeed) -> Self {
        self.feed = Some(feed);
        self
    }

    /// External placement controller for this run.  Takes precedence
    /// over the policy-owned controller ([`ServePolicy::placement`]),
    /// and the caller keeps it afterwards — decision log, applied
    /// deltas and the recorder cursor included — which is what the
    /// equivalence suites diff across backends.
    pub fn placement(mut self, ctl: &'a mut PlacementController) -> Self {
        self.placement = Some(ctl);
        self
    }
}

impl<B: Substrate> Default for RunOpts<'_, B> {
    fn default() -> Self {
        Self::new()
    }
}

/// The online server: admits a stream, forms batches, dispatches each
/// batch back-to-back on one long-lived engine.
pub struct Server<B: Substrate> {
    engine: SpmdEngine<B, QueryShard>,
    cfg: ServeConfig,
    policy: ServePolicy,
    cache: ResultCache,
    /// Attached flight recorder, if any — shared with the engine's
    /// substrate (see [`Server::set_recorder`]).  `None` skips all
    /// event work; the serving schedule is identical either way.
    recorder: Option<ObserverHandle>,
    /// The policy-owned placement controller (`None` unless
    /// [`ServePolicy::placement`] is set).  Lives on the server so its
    /// round budget and recorder cursor span successive
    /// [`Server::serve`] calls; a [`RunOpts::placement`] controller
    /// shadows it for a run.
    placement_ctl: Option<PlacementController>,
}

impl<B: Substrate> Server<B> {
    pub fn new(engine: SpmdEngine<B, QueryShard>, cfg: ServeConfig) -> Self {
        assert!(cfg.batch >= 1, "batch size must be >= 1");
        assert!(cfg.queue_cap >= 1, "queue capacity must be >= 1");
        assert!(cfg.pr_iters >= 1, "PR needs at least one iteration");
        assert!(cfg.supersteps_per_tick >= 1, "the service clock needs a positive rate");
        assert!(cfg.work_per_tick != Some(0), "work_per_tick must be >= 1 when set");
        Server {
            engine,
            cfg,
            policy: ServePolicy::default(),
            cache: ResultCache::new(),
            recorder: None,
            placement_ctl: None,
        }
    }

    /// Attach (or detach, with `None`) a flight recorder to BOTH layers
    /// at once: the serving loop records admission / rejection /
    /// batch-close / cache / wave / completion / mutation events, and the
    /// engine's substrate records one event per ledger superstep — into
    /// the same ring, interleaved in causal order.  The recorder never
    /// influences the schedule: a recorded run's report is identical to
    /// an unrecorded one (pinned by `tests/obs_trace.rs`).
    pub fn set_recorder(&mut self, rec: Option<ObserverHandle>) {
        self.engine.set_observer(rec.clone());
        self.recorder = rec;
    }

    /// Record one serving-layer event, if a recorder is attached.
    fn record_event(&self, kind: EventKind) {
        if let Some(rec) = &self.recorder {
            rec.lock().unwrap().record(kind);
        }
    }

    pub fn engine(&self) -> &SpmdEngine<B, QueryShard> {
        &self.engine
    }

    /// Consume the server, returning the engine (to read final substrate
    /// metrics after the stream is done).
    pub fn into_engine(self) -> SpmdEngine<B, QueryShard> {
        self.engine
    }

    /// Current result-cache population (test/diagnostic surface).
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Install a new serving policy between runs on one long-lived
    /// server.  Clears the result cache — so an ON run after an OFF run
    /// starts cold and A/B comparisons on the same server are fair —
    /// and (re)builds the policy-owned placement controller from
    /// [`ServePolicy::placement`].
    pub fn set_serving_policy(&mut self, policy: ServePolicy) {
        self.policy = policy;
        self.cache.clear();
        self.placement_ctl = policy.placement.map(PlacementController::new);
    }

    /// Builder form of [`Server::set_serving_policy`].
    pub fn with_serving_policy(mut self, policy: ServePolicy) -> Self {
        self.set_serving_policy(policy);
        self
    }

    pub fn serving_policy(&self) -> ServePolicy {
        self.policy
    }

    /// Result identity of a query on THIS server at `epoch`: the key
    /// canonicalizes the source and folds in the engine's whole flag
    /// block plus the PR iteration budget.
    fn cache_key(&self, kind: QueryKind, source: Vid, epoch: u64) -> CacheKey {
        CacheKey {
            kind,
            source: canonical_source(kind, source),
            flags: self.engine.flags,
            pr_iters: self.cfg.pr_iters,
            epoch,
        }
    }

    /// One fused multi-source wave on the serving engine (the dispatch
    /// loop's fused path, exposed for the bit-equality test wall).
    pub fn run_fused(&mut self, kind: QueryKind, sources: &[Vid]) -> Vec<Vec<u64>> {
        run_fused_wave(&mut self.engine, kind, sources)
    }

    /// Execute one query on the reused engine: reset the shard its
    /// algorithm runs on (`QueryShard::reset_kind` — ingestion, relay
    /// trees and the worker pool stay), run the algorithm, encode the
    /// result canonically.  This is also the "single-shot" path the
    /// cross-checks use — a reset engine is bit-equivalent to a fresh
    /// one.  It NEVER consults the result cache (memoization lives at
    /// dispatch, inside [`Server::serve`]), so a reference re-execution
    /// through this path can never be satisfied by a cached copy of the
    /// very result it is meant to verify.
    pub fn run_query(&mut self, q: &Query) -> Vec<u64> {
        let kind = q.kind;
        self.engine
            .reset_for_query(move |m, meta, st: &mut QueryShard| st.reset_kind(kind, m, meta));
        match q.kind {
            QueryKind::Bfs => bfs(&mut self.engine, q.source)
                .into_iter()
                .map(|d| d as u64)
                .collect(),
            QueryKind::Sssp => sssp(&mut self.engine, q.source)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
            QueryKind::Pr => pagerank(&mut self.engine, self.cfg.pr_iters)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
            QueryKind::Cc => cc(&mut self.engine)
                .into_iter()
                .map(|l| l as u64)
                .collect(),
            QueryKind::Bc => bc(&mut self.engine, q.source)
                .into_iter()
                .map(f64::to_bits)
                .collect(),
        }
    }

    /// Deterministic service price of an engine pass, in logical ticks:
    /// the ledger-superstep term, raised to the work-makespan term when
    /// [`ServeConfig::work_per_tick`] is set, and never below 1.  Both
    /// inputs are pure functions of (graph, flags, P) — identical across
    /// backends — so the priced clock stays bit-reproducible.
    fn price_ticks(&self, steps: u64, makespan: u64) -> u64 {
        let base = steps.div_ceil(self.cfg.supersteps_per_tick);
        let loaded = self.cfg.work_per_tick.map_or(0, |w| makespan.div_ceil(w));
        base.max(loaded).max(1)
    }

    /// Absorb every mutation batch due at the current tick, advancing the
    /// logical clock by each batch's deterministic service cost — the
    /// same ledger pricing queries pay.
    fn apply_due_mutations(
        &mut self,
        feed: &mut MutationFeed,
        tick: &mut u64,
        records: &mut Vec<MutationRecord>,
    ) {
        while let Some(batch) = feed.pop_due(*tick) {
            let s0 = self.engine.sub().ledger_supersteps();
            let k0 = self.engine.sub().ledger_makespan();
            let applied = self.engine.apply_delta(&batch);
            let steps = self.engine.sub().ledger_supersteps().saturating_sub(s0);
            let work = self.engine.sub().ledger_makespan().saturating_sub(k0);
            let service_ticks = self.price_ticks(steps, work);
            let applied_tick = *tick;
            *tick += service_ticks;
            let epoch_after = self.engine.graph_epoch();
            records.push(MutationRecord {
                batch_id: batch.id,
                arrival: batch.arrival,
                applied_tick,
                epoch_after,
                ops: applied,
                service_ticks,
            });
            self.record_event(EventKind::MutationApply {
                tick: applied_tick,
                batch: batch.id,
                ops: applied,
                epoch_after,
                service_ticks,
            });
        }
    }

    /// One controller pass at an epoch boundary: feed the recorder's
    /// fresh superstep events to `ctl`, and if it decides on a delta,
    /// absorb it in place ([`SpmdEngine::apply_placement`]) and advance
    /// the logical clock by the application's deterministic service
    /// cost — placement pays for its own data movement on the same
    /// clock queries and mutations do.
    fn apply_due_placement(
        &mut self,
        ctl: &mut PlacementController,
        tick: &mut u64,
        records: &mut Vec<PlacementRecord>,
    ) {
        let Some(rec) = self.recorder.clone() else {
            // No signal, no decisions — serve() attaches a recorder
            // whenever a controller is active, so this is a dead arm in
            // practice, kept as a guard for direct callers.
            return;
        };
        ctl.observe_recorder(&rec.lock().unwrap());
        let catalog = self.engine.block_catalog();
        let meta = self.engine.meta();
        let Some(delta) = ctl.decide(&catalog, &meta.out_deg) else {
            return;
        };
        let moves = delta
            .ops
            .iter()
            .filter(|o| matches!(o, PlaceOp::Move { .. }))
            .count();
        let splits = delta.ops.len() - moves;
        let s0 = self.engine.sub().ledger_supersteps();
        let k0 = self.engine.sub().ledger_makespan();
        self.engine.apply_placement(&delta);
        let steps = self.engine.sub().ledger_supersteps().saturating_sub(s0);
        let work = self.engine.sub().ledger_makespan().saturating_sub(k0);
        let service_ticks = self.price_ticks(steps, work);
        let applied_tick = *tick;
        *tick += service_ticks;
        let epoch_after = self.engine.graph_epoch();
        records.push(PlacementRecord {
            round: delta.round,
            applied_tick,
            moves,
            splits,
            ops: delta.ops.clone(),
            epoch_after,
            service_ticks,
        });
        self.record_event(EventKind::PlacementApply {
            tick: applied_tick,
            round: delta.round,
            moves,
            splits,
            epoch_after,
            service_ticks,
        });
    }

    /// Drive the full **pipelined** admission → batch → dispatch loop
    /// over any [`ArrivalSource`] (an [`crate::workload::OpenLoopSource`]
    /// over a pre-generated stream, or closed-loop clients) — **the**
    /// serving entry point.  Everything else a run can carry rides in
    /// [`RunOpts`]: a per-query observer, a live [`MutationFeed`], an
    /// external [`PlacementController`].  An all-default bundle is the
    /// plain mutation-free run.
    ///
    /// With a feed, delta batches interleave with queries **on the same
    /// logical service clock**, under an epoch barrier — a due batch
    /// applies only *between* dispatches (never inside one), so every
    /// query executes against exactly one consistent snapshot,
    /// identified by the `graph_epoch` stamped on its result.  Queries
    /// that queue behind a delta absorb its service time as wait,
    /// exactly as they would behind another query.  With a placement
    /// controller active (via [`ServePolicy::placement`] or
    /// [`RunOpts::placement`]), the controller runs at the same epoch
    /// boundaries: it reads the flight recorder's fresh per-machine
    /// work totals and, when the window shows enough skew, migrates or
    /// splits hot edge blocks in place — each applied delta bumps the
    /// epoch and pays its own deterministic service cost on the clock.
    ///
    /// Service occupies logical time: after each query the clock jumps
    /// forward by that query's deterministic service cost
    /// ([`ServeConfig::supersteps_per_tick`], optionally raised by the
    /// work-makespan term of [`ServeConfig::work_per_tick`]) and
    /// admission runs *again* before the next query of the same batch —
    /// so arrivals landing while a batch executes are queued (or shed at
    /// the cap) exactly where they land, not at the end of the batch.  A
    /// batch's *composition* is still fixed at close: mid-batch arrivals
    /// are eligible for the next batch only.  Because service costs are
    /// ledger deltas (pure functions of (graph, flags, P)), the whole
    /// admission/wait/rejection/mutation/placement schedule is
    /// bit-reproducible across runs and across backends.
    ///
    /// When the query stream ends before the feed, the remaining batches
    /// are drained at their scheduled ticks, so the final epoch — and
    /// the graph the engine holds afterwards — is a function of the feed
    /// alone, never of where the stream happened to stop.
    pub fn serve(&mut self, source: &mut dyn ArrivalSource, opts: RunOpts<'_, B>) -> ServeReport {
        let RunOpts {
            observe,
            feed,
            placement,
        } = opts;
        let mut observe = observe
            .unwrap_or_else(|| Box::new(|_: &QueryResult, _: &SpmdEngine<B, QueryShard>| {}));
        let mut empty_feed = MutationFeed::empty();
        let feed = feed.unwrap_or(&mut empty_feed);
        // The run's controller: the caller's wins; otherwise the
        // policy-owned one, taken out for the run (and restored at the
        // end) so `self` stays free for the dispatch methods.
        let mut internal = if placement.is_none() {
            self.placement_ctl.take()
        } else {
            None
        };
        let mut ctl = placement.or(internal.as_mut());
        // Placement decisions are driven by the recorder's superstep
        // signal; attach one if the caller hasn't.  Recording never
        // perturbs the schedule (`tests/obs_trace.rs`).
        if ctl.is_some() && self.recorder.is_none() {
            self.set_recorder(Some(FlightRecorder::shared(FlightRecorder::DEFAULT_CAPACITY)));
        }
        let cfg = self.cfg;
        let policy = self.policy;
        let mut adm = Admission::new();
        let mut results: Vec<QueryResult> = Vec::new();
        let mut mutations: Vec<MutationRecord> = Vec::new();
        let mut placements: Vec<PlacementRecord> = Vec::new();
        let mut waves: Vec<WaveRecord> = Vec::new();
        let mut batches = 0u64;
        let mut cache_hits = 0u64;
        let mut cache_misses = 0u64;
        let mut tick = 0u64;
        let t0 = Instant::now();
        loop {
            // ---- deltas due at the current logical time apply first
            //      (then any placement round they or the last waves
            //      triggered), so admission sees the post-epoch clock ----
            self.apply_due_mutations(feed, &mut tick, &mut mutations);
            if let Some(c) = ctl.as_deref_mut() {
                self.apply_due_placement(c, &mut tick, &mut placements);
            }
            adm.admit(source, tick, cfg.queue_cap, self.recorder.as_ref());
            let full = adm.pending.len() >= cfg.batch;
            let overdue = adm
                .pending
                .front()
                .is_some_and(|q| tick - q.arrival >= cfg.deadline_ticks);
            // Source exhausted: nothing will ever top the batch up, so
            // drain instead of waiting out the deadline.
            let draining = source.done() && !adm.pending.is_empty();
            if full || overdue || draining {
                // ---- close a batch (composition fixed now) and serve
                //      it wave by wave on the logical clock.  With both
                //      knobs off every wave is a single query, and this
                //      loop is the per-query dispatch loop verbatim ----
                let take = adm.pending.len().min(cfg.batch);
                let batch_seq = batches;
                batches += 1;
                self.record_event(EventKind::BatchClose {
                    tick,
                    batch: batch_seq,
                    size: take,
                    reason: if full {
                        CloseReason::Full
                    } else if overdue {
                        CloseReason::Overdue
                    } else {
                        CloseReason::Drain
                    },
                });
                let mut members: VecDeque<Query> = adm.pending.drain(..take).collect();
                while !members.is_empty() {
                    // Epoch barrier: deltas that fell due during the
                    // previous wave's service window apply here,
                    // BETWEEN dispatches — never inside one.  Placement
                    // rounds use the same barrier: the controller sees
                    // the recorder as of the last wave and may migrate
                    // blocks before the next one dispatches.
                    self.apply_due_mutations(feed, &mut tick, &mut mutations);
                    if let Some(c) = ctl.as_deref_mut() {
                        self.apply_due_placement(c, &mut tick, &mut placements);
                    }
                    let epoch = self.engine.graph_epoch();
                    if policy.cache {
                        // Mutations never un-apply, so entries from any
                        // earlier epoch can never hit again — evict.
                        self.cache.retain_epoch(epoch);
                        // Serve every remaining member with a memoized
                        // result NOW, at zero service ticks: replaying
                        // stored bits costs no engine pass and the
                        // logical clock does not move.
                        let mut missed: VecDeque<Query> = VecDeque::new();
                        while let Some(q) = members.pop_front() {
                            let key = self.cache_key(q.kind, q.source, epoch);
                            let Some(bits) = self.cache.get(&key) else {
                                missed.push_back(q);
                                continue;
                            };
                            cache_hits += 1;
                            self.record_event(EventKind::CacheHit {
                                tick,
                                query: q.id,
                                batch: batch_seq,
                                epoch,
                            });
                            let res = QueryResult {
                                id: q.id,
                                kind: q.kind,
                                source: q.source,
                                bits: bits.clone(),
                                wait_ticks: tick - q.arrival,
                                service_ticks: 0,
                                service_ms: 0.0,
                                batch: batch_seq,
                                graph_epoch: epoch,
                                cached: true,
                            };
                            source.on_complete(q.id, tick);
                            self.record_event(EventKind::QueryComplete {
                                tick,
                                query: q.id,
                                wait_ticks: res.wait_ticks,
                                service_ticks: 0,
                                cached: true,
                            });
                            observe(&res, &self.engine);
                            results.push(res);
                        }
                        members = missed;
                        if members.is_empty() {
                            break;
                        }
                    }
                    // ---- form one engine wave: the head member alone,
                    //      or (fusion on, exact kind) every same-kind
                    //      member of the batch as lanes ----
                    let kind = members.front().expect("checked nonempty").kind;
                    let wave: Vec<Query> = if policy.fuse && fusable(kind) {
                        let mut wave = Vec::new();
                        let mut rest = VecDeque::new();
                        for q in members.drain(..) {
                            if q.kind == kind {
                                wave.push(q);
                            } else {
                                rest.push_back(q);
                            }
                        }
                        members = rest;
                        wave
                    } else {
                        vec![members.pop_front().expect("checked nonempty")]
                    };
                    let dispatch_tick = tick;
                    // Every wave member is a cache miss by construction
                    // (the hit loop above already filtered): record each
                    // at the dispatch tick, BEFORE the engine pass, so
                    // misses precede their wave's superstep events.
                    if let Some(rec) = &self.recorder {
                        let mut r = rec.lock().unwrap();
                        for q in &wave {
                            r.record(EventKind::CacheMiss {
                                tick: dispatch_tick,
                                query: q.id,
                                batch: batch_seq,
                                epoch,
                            });
                        }
                    }
                    let s0 = self.engine.sub().ledger_supersteps();
                    let k0 = self.engine.sub().ledger_makespan();
                    let ts = Instant::now();
                    let bits_per: Vec<Vec<u64>> = if wave.len() >= 2 {
                        let sources: Vec<Vid> = wave.iter().map(|q| q.source).collect();
                        run_fused_wave(&mut self.engine, kind, &sources)
                    } else {
                        vec![self.run_query(&wave[0])]
                    };
                    let service_ms = ts.elapsed().as_secs_f64() * 1e3;
                    let steps = self.engine.sub().ledger_supersteps().saturating_sub(s0);
                    let work = self.engine.sub().ledger_makespan().saturating_sub(k0);
                    // The whole wave is priced ONCE — this is the
                    // amortization: lanes share every superstep, so a
                    // fused batch costs its max-shaped wave, not the sum
                    // of B solo runs.
                    let wave_ticks = self.price_ticks(steps, work);
                    tick += wave_ticks;
                    waves.push(WaveRecord {
                        batch: batch_seq,
                        kind,
                        lanes: wave.len(),
                        query_ids: wave.iter().map(|q| q.id).collect(),
                        service_ticks: wave_ticks,
                    });
                    // Recorded AFTER the pass so the recorder can stamp
                    // the event with the per-machine busy deltas its
                    // supersteps accumulated (threaded runs only).
                    self.record_event(EventKind::WaveDispatch {
                        tick: dispatch_tick,
                        batch: batch_seq,
                        kind,
                        lanes: wave.len(),
                        query_ids: wave.iter().map(|q| q.id).collect(),
                        service_ticks: wave_ticks,
                        epoch,
                    });
                    for (q, bits) in wave.into_iter().zip(bits_per) {
                        cache_misses += 1;
                        if policy.cache {
                            let key = self.cache_key(q.kind, q.source, epoch);
                            self.cache.insert(key, bits.clone());
                        }
                        let res = QueryResult {
                            id: q.id,
                            kind: q.kind,
                            source: q.source,
                            bits,
                            wait_ticks: dispatch_tick - q.arrival,
                            service_ticks: wave_ticks,
                            service_ms,
                            batch: batch_seq,
                            graph_epoch: epoch,
                            cached: false,
                        };
                        source.on_complete(q.id, tick);
                        self.record_event(EventKind::QueryComplete {
                            tick,
                            query: res.id,
                            wait_ticks: res.wait_ticks,
                            service_ticks: wave_ticks,
                            cached: false,
                        });
                        observe(&res, &self.engine);
                        results.push(res);
                    }
                    // ---- pipelined admission: arrivals that landed
                    //      during this wave's service window ----
                    adm.admit(source, tick, cfg.queue_cap, self.recorder.as_ref());
                }
                // Re-evaluate immediately: the queue may already hold a
                // full (or overdue) next batch at the post-service tick.
                continue;
            }
            if adm.pending.is_empty() {
                if source.done() {
                    break;
                }
                let next = match (source.next_arrival(), feed.next_arrival()) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (Some(a), None) => Some(a),
                    (None, Some(b)) => Some(b),
                    (None, None) => None,
                };
                match next {
                    // Idle gap: jump to the next scheduled arrival — of
                    // a query OR a delta batch, whichever is earlier, so
                    // deltas apply at their due tick and later queries
                    // never absorb their service time as phantom wait.
                    // No query is waiting, so no wait computation can
                    // observe the skipped ticks; `max(tick + 1)`
                    // guarantees progress even against a source that
                    // mis-schedules into the past.
                    Some(t) => tick = t.max(tick + 1),
                    None => {
                        // A live source with nothing scheduled and
                        // nothing in flight cannot make progress — a
                        // source-contract violation, not a server state.
                        if cfg!(debug_assertions) {
                            panic!("ArrivalSource not done but nothing scheduled or queued");
                        }
                        break;
                    }
                }
            } else {
                tick += 1;
            }
        }
        // ---- post-stream drain: remaining scheduled deltas apply at
        //      their due ticks (the clock may jump forward to reach
        //      them), so the final epoch is feed-determined ----
        while let Some(arrival) = feed.next_arrival() {
            tick = tick.max(arrival);
            self.apply_due_mutations(feed, &mut tick, &mut mutations);
        }
        // One last controller pass, so supersteps observed during the
        // final waves are considered before the run's state freezes —
        // the engine a follow-up `serve` call inherits is a function of
        // everything this run observed, not of where the stream stopped.
        if let Some(c) = ctl.as_deref_mut() {
            self.apply_due_placement(c, &mut tick, &mut placements);
        }
        if internal.is_some() {
            self.placement_ctl = internal;
        }
        ServeReport {
            results,
            rejected: adm.rejected,
            rejected_by_kind: adm.rejected_by_kind,
            max_queue_depth: adm.max_queue_depth,
            batches,
            ticks: tick,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            graph_epoch: self.engine.graph_epoch(),
            mutations,
            placements,
            cache_hits,
            cache_misses,
            waves,
        }
    }
}
