//! `serve` — the online multi-query serving layer over the persistent
//! TD-Orch runtime.
//!
//! Everything below this module runs *one* query end-to-end; everything
//! in this module is about running a **stream** of queries on engines
//! that live for the whole process:
//!
//! ```text
//!   workload::OpenLoopSource        workload::ClosedLoop
//!   (fixed-rate Zipf stream)        (N clients · think time · ≤1
//!        │                           outstanding query each)
//!        └───────────┬──────────────┘
//!                    ▼  ArrivalSource::poll(tick)
//!        admission (bounded queue; overflow is rejected → on_reject)
//!                    │         ▲
//!                    ▼         │ re-polled BETWEEN queries of an
//!   serve::Server ── batch former (close on size B or tick deadline D;
//!        │           composition fixed at close)
//!        │ per-query dispatch:  tick += max(1, ⌈Δledger-supersteps /
//!        │                     supersteps_per_tick⌉)  → on_complete
//!        ▼
//!   SpmdEngine<B, QueryShard> ── reset_for_query between queries
//!        │                        (shards re-init; ingestion, relay
//!        ▼                         trees, worker pool all KEPT)
//!   exec::Substrate (Cluster | ThreadedCluster)
//! ```
//!
//! The central invariant is **one ingestion per process**: the graph is
//! placed once ([`crate::graph::spmd::ingest_once`]), every engine —
//! serving and cross-check reference — is built from clones of that
//! placement ([`crate::graph::spmd::SpmdEngine::from_ingested`]), and
//! [`QueryShard::reset`] restores query state in place.
//! `graph::ingest::ingestions()` counts placement passes so `repro
//! serve`, `repro graph` and the tests can *assert* the invariant rather
//! than trust it.
//!
//! ## Determinism contract for pipelined runs
//!
//! Service occupies **logical time**: each query advances the clock by
//! its ledger-superstep delta scaled by
//! [`ServeConfig::supersteps_per_tick`], and admission runs between the
//! queries of an executing batch — so queueing, shedding and think-time
//! dynamics all play out on one deterministic clock.  For a fixed
//! (arrival source, [`ServeConfig`], graph, P): admission decisions,
//! rejections, batch composition, per-query queue waits, service ticks
//! and every query's result bits are identical across runs and across
//! substrates — ledger supersteps are a pure function of (graph, flags,
//! P), never of the backend or the host, and each query starts from a
//! reset engine whose result is bit-identical to a fresh engine's
//! (`tests/serve_equivalence.rs`, `tests/serve_load.rs`).  Only the
//! measured service milliseconds and wall-clock throughput vary with the
//! host — which is why the `repro loadcurve` sweeps plot *logical*
//! goodput and latency and treat wall-clock as annotation.
//!
//! ## One entry point, one option bundle, one policy
//!
//! The server exposes exactly ONE run method:
//! [`Server::serve`]`(&mut dyn ArrivalSource, RunOpts)`.  Everything a
//! run can carry beyond the arrival source — a per-query observer, a
//! live [`crate::mutate::MutationFeed`], an external
//! [`crate::place::PlacementController`] — rides in the [`RunOpts`]
//! bundle, and an all-default bundle is the plain mutation-free run.
//! What the server *does* with admitted queries (fusion, memoization,
//! adaptive placement) is a [`ServePolicy`] value installed with
//! [`Server::set_serving_policy`] / [`Server::with_serving_policy`],
//! kept separate from the [`ServeConfig`] clock/admission knobs.
//!
//! ## Fused waves and the result cache
//!
//! With [`ServePolicy::fuse`] on, a closed batch's same-kind **exact**
//! queries (BFS/SSSP/CC — order-insensitive merges) dispatch as ONE
//! multi-source `edge_map_lanes` wave ([`run_fused_wave`]): query `l`
//! becomes lane `l`, the wave is priced once on the ledger clock, and
//! each member's bits equal its solo single-shot run.  With
//! [`ServePolicy::cache`] on, results memoize in a [`ResultCache`]
//! keyed by `(kind, canonical source, flags, pr_iters, graph_epoch)`;
//! the cache is consulted at **dispatch only** — never inside
//! [`Server::run_query`], which stays the pure single-shot path every
//! cross-check re-executes — and hits are served at zero service ticks.
//! The epoch in the key makes stale hits structurally impossible under
//! a mutating feed; an epoch bump also evicts the stale entries.  Both
//! knobs default **off**, and the off-off dispatch loop is the exact
//! per-query loop of PR 5 — schedules bit-identical.  Hit/miss counts
//! and per-wave records surface in [`ServeReport`].
//!
//! ## Live mutation
//!
//! [`Server::serve`] with a [`RunOpts::feed`] interleaves a
//! [`crate::mutate::MutationFeed`] of edge delta batches with the query
//! stream on the same logical clock: a due batch is absorbed in place by
//! `SpmdEngine::apply_delta` (no re-ingestion — the one-ingestion
//! witness extends to mutating runs) **between** query dispatches, never
//! inside one, bumping the engine's `graph_epoch`.  Every
//! [`QueryResult`] carries the epoch it executed against and every
//! absorbed batch leaves a [`MutationRecord`] in the [`ServeReport`],
//! which is what lets `repro mutate` cross-check each result against a
//! reference engine built at exactly that snapshot.  The determinism
//! contract above extends verbatim: for a fixed (source, feed, config,
//! graph, P) the full interleaving — epochs, waits, rejections, bits —
//! is identical across runs and across substrates.
//!
//! ## Adaptive placement
//!
//! With [`ServePolicy::placement`] set (or an external controller via
//! [`RunOpts::placement`]), a [`crate::place::PlacementController`]
//! watches the attached flight recorder's per-machine work totals and,
//! at the same epoch boundaries mutations use, migrates/splits hot edge
//! blocks in place ([`crate::graph::spmd::SpmdEngine::apply_placement`]
//! — no re-ingestion, the one-ingestion witness holds).  Each applied
//! round bumps the epoch, leaves a [`PlacementRecord`] in the report,
//! and pays its own service cost on the logical clock.  Pair it with
//! [`ServeConfig::work_per_tick`] so the clock actually *feels* the
//! imbalance placement repairs; see [`crate::place`] for the decision
//! rules and the determinism contract.
//!
//! ## Observability
//!
//! [`Server::set_recorder`] attaches a [`crate::obs::FlightRecorder`] to
//! both layers at once: the serving loop records admission / rejection /
//! batch-close / cache / wave / completion / mutation events, the
//! engine's substrate records one event per ledger superstep (per-machine
//! work/words/messages), all into one ring in causal order.  The
//! deterministic event cores obey the same contract as the schedule —
//! bit-identical across runs and substrates (`repro trace` gates on it)
//! — and recording never perturbs the run: a recorded report equals an
//! unrecorded one field for field (`tests/obs_trace.rs`).

pub mod cache;
mod fused;
mod server;

pub use cache::{canonical_source, CacheKey, ResultCache};
pub use fused::{fusable, run_fused_wave};
pub use server::{
    MutationRecord, PlacementRecord, QueryResult, RunOpts, ServeConfig, ServePolicy, ServeReport,
    Server, WaveRecord, DEFAULT_PR_ITERS,
};

use crate::bsp::MachineId;
use crate::graph::algorithms::{
    BcShard, BfsShard, CcShard, FusedShard, PrShard, ShardAccess, SsspShard,
};
use crate::graph::spmd::GraphMeta;
use crate::workload::QueryKind;

/// Machine-local state for the whole {BFS, SSSP, PR, CC, BC} query mix:
/// all five algorithm shards side by side (each O(n/P)), so ONE
/// long-lived engine serves every query kind.  The `ShardAccess` impls
/// project out the slice the running algorithm needs; [`QueryShard::reset`]
/// is the `reset_for_query` hook that restores the freshly-initialized
/// state in place between queries (allocations reused).
pub struct QueryShard {
    pub bfs: BfsShard,
    pub sssp: SsspShard,
    pub cc: CcShard,
    pub pr: PrShard,
    pub bc: BcShard,
    /// Per-lane state for fused multi-source waves ([`run_fused_wave`]);
    /// unconfigured (zero lanes) outside a fused dispatch.
    pub fused: FusedShard,
}

impl QueryShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        QueryShard {
            bfs: BfsShard::new(m, meta),
            sssp: SsspShard::new(m, meta),
            cc: CcShard::new(m, meta),
            pr: PrShard::new(m, meta),
            bc: BcShard::new(m, meta),
            fused: FusedShard::new(m, meta),
        }
    }

    /// Restore every algorithm slice to its freshly-constructed state
    /// (the safe catch-all hook; `repro graph` and the figure paths use
    /// it between differently-kinded queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        self.bfs.reset(m, meta);
        self.sssp.reset(m, meta);
        self.cc.reset(m, meta);
        self.pr.reset(m, meta);
        self.bc.reset(m, meta);
        self.fused.reset(m, meta);
    }

    /// Restore only the shard `kind` is about to run on.  Sufficient —
    /// and bit-identical to a full [`QueryShard::reset`] — on the
    /// serving path, because every query resets its own shard before
    /// running and no algorithm ever reads a sibling's slice; it skips
    /// four of the five O(n/P) fills per query.
    pub fn reset_kind(&mut self, kind: QueryKind, m: MachineId, meta: &GraphMeta) {
        match kind {
            QueryKind::Bfs => self.bfs.reset(m, meta),
            QueryKind::Sssp => self.sssp.reset(m, meta),
            QueryKind::Pr => self.pr.reset(m, meta),
            QueryKind::Cc => self.cc.reset(m, meta),
            QueryKind::Bc => self.bc.reset(m, meta),
        }
    }
}

impl ShardAccess<BfsShard> for QueryShard {
    fn shard(&self) -> &BfsShard {
        &self.bfs
    }

    fn shard_mut(&mut self) -> &mut BfsShard {
        &mut self.bfs
    }
}

impl ShardAccess<SsspShard> for QueryShard {
    fn shard(&self) -> &SsspShard {
        &self.sssp
    }

    fn shard_mut(&mut self) -> &mut SsspShard {
        &mut self.sssp
    }
}

impl ShardAccess<CcShard> for QueryShard {
    fn shard(&self) -> &CcShard {
        &self.cc
    }

    fn shard_mut(&mut self) -> &mut CcShard {
        &mut self.cc
    }
}

impl ShardAccess<PrShard> for QueryShard {
    fn shard(&self) -> &PrShard {
        &self.pr
    }

    fn shard_mut(&mut self) -> &mut PrShard {
        &mut self.pr
    }
}

impl ShardAccess<BcShard> for QueryShard {
    fn shard(&self) -> &BcShard {
        &self.bc
    }

    fn shard_mut(&mut self) -> &mut BcShard {
        &mut self.bc
    }
}

impl ShardAccess<FusedShard> for QueryShard {
    fn shard(&self) -> &FusedShard {
        &self.fused
    }

    fn shard_mut(&mut self) -> &mut FusedShard {
        &mut self.fused
    }
}
