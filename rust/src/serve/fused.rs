//! Fused batch dispatch: run a closed batch's same-kind exact queries
//! as ONE multi-source engine wave instead of B back-to-back passes —
//! the paper's batch-amortization idea applied to serving (ROADMAP's
//! "multi-source fusion").  Query `l` of the wave becomes lane `l` of
//! [`crate::graph::spmd::SpmdEngine::edge_map_lanes`]; the wave is
//! priced once on the ledger-superstep clock, so a fused batch's
//! `service_ticks` is the max-shaped cost of its slowest member rather
//! than the sum of all members.

use crate::exec::Substrate;
use crate::graph::algorithms::{bfs_fused, cc_fused, sssp_fused};
use crate::graph::spmd::SpmdEngine;
use crate::graph::Vid;
use crate::workload::QueryKind;

use super::QueryShard;

/// Kinds eligible for multi-source fusion: the exact-merge traversals
/// (first-writer / `min`), whose fused bits provably equal their solo
/// bits at every P on both backends.  PR and BC fold f64 sums, where
/// lane sharing could regroup rounding — they dispatch singly (and
/// still memoize, since their solo runs are bit-deterministic).
pub fn fusable(kind: QueryKind) -> bool {
    matches!(kind, QueryKind::Bfs | QueryKind::Sssp | QueryKind::Cc)
}

/// One fused wave on the serving engine: reset once, run every source
/// as a lane, return canonically-encoded bits per member in input order
/// — the exact encodings [`super::Server::run_query`] produces for the
/// same kind, so fused results drop into the same cross-check and cache
/// paths bit-for-bit.
pub fn run_fused_wave<B: Substrate>(
    engine: &mut SpmdEngine<B, QueryShard>,
    kind: QueryKind,
    sources: &[Vid],
) -> Vec<Vec<u64>> {
    assert!(fusable(kind), "{kind:?} queries cannot join a fused wave");
    engine.reset_for_query(|m, meta, st: &mut QueryShard| st.fused.reset(m, meta));
    match kind {
        QueryKind::Bfs => bfs_fused(engine, sources)
            .into_iter()
            .map(|lane| lane.into_iter().map(|d| d as u64).collect())
            .collect(),
        QueryKind::Sssp => sssp_fused(engine, sources)
            .into_iter()
            .map(|lane| lane.into_iter().map(f64::to_bits).collect())
            .collect(),
        QueryKind::Cc => cc_fused(engine, sources.len())
            .into_iter()
            .map(|lane| lane.into_iter().map(|l| l as u64).collect())
            .collect(),
        QueryKind::Pr | QueryKind::Bc => unreachable!("gated by fusable() above"),
    }
}
