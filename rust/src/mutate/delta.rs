//! In-place delta application over ingested edge blocks.
//!
//! The frozen-placement contract: partition and block placement are
//! decided once, at epoch-0 ingestion, and **never revisited** by a
//! delta.  Inserted arcs accrete at the source's *owner* machine
//! (appended to its first resident block, or a fresh block if the owner
//! holds none), deleted arcs are removed from whichever machine holds
//! them, and emptied blocks stay in place so block indices — which
//! `block_of` references — remain stable.  That keeps a mutated engine's
//! state a pure function of (epoch-0 ingest, op sequence), independent
//! of when queries interleave, which is what lets `repro mutate`
//! rebuild a bit-identical reference by replaying the same ops onto a
//! fresh clone of the epoch-0 `DistGraph`.
//!
//! What a delta *does* maintain incrementally: `out_deg`, the arc count
//! `m`, and the source/destination tree leaf sets (sorted machine lists,
//! updated by binary-search splice).  [`recompute_leaves`] is the
//! from-scratch ground truth the incremental path is tested against.

use crate::bsp::MachineId;
use crate::graph::ingest::{DistGraph, EdgeBlock};
use crate::graph::layout::BlockIndex;
use crate::graph::Vid;

use super::stream::{EdgeOp, MutationBatch};

/// A note shipped to the delta superstep's driver: machine `machine`'s
/// holdings for `vertex` changed — `present` is whether it still holds
/// source blocks (is_src) / in-edges (!is_src) of the vertex afterwards,
/// and `deg_delta` the out-degree change it caused.  Per-(vertex,
/// machine) notes arrive in application order, so last-note-wins.
#[derive(Clone, Copy, Debug)]
pub struct DeltaNote {
    pub vertex: Vid,
    pub machine: u32,
    pub is_src: bool,
    pub present: bool,
    pub deg_delta: i32,
}

/// Insert arc u→v into ONE machine's holdings: appended to u's first
/// resident block, or a new block when the machine holds none (the
/// owner-accretion path — deltas never spawn blocks on transit
/// machines).
pub fn insert_arc(blocks: &mut Vec<EdgeBlock>, block_of: &mut BlockIndex, u: Vid, v: Vid, w: f32) {
    if let Some(first) = block_of.first(u) {
        blocks[first as usize].targets.push((v, w));
    } else {
        let idx = blocks.len() as u32;
        blocks.push(EdgeBlock { src: u, targets: vec![(v, w)] });
        block_of.insert(u, idx);
    }
}

/// Delete arc u→v from ONE machine's holdings: first match across u's
/// blocks in index order, removed by shift (`Vec::remove`) so the
/// surviving target order — and therefore every later f64 fold over the
/// block — is a deterministic function of the op sequence.  Returns
/// whether the arc was found here.  Emptied blocks are kept: block
/// indices must stay stable.
pub fn delete_arc(blocks: &mut [EdgeBlock], block_of: &BlockIndex, u: Vid, v: Vid) -> bool {
    for &bi in block_of.get(u) {
        let targets = &mut blocks[bi as usize].targets;
        if let Some(pos) = targets.iter().position(|(t, _)| *t == v) {
            targets.remove(pos);
            return true;
        }
    }
    false
}

/// Does this machine still hold any out-edge of `u`?  (Source-tree leaf
/// membership after a delete.)
pub fn holds_src(blocks: &[EdgeBlock], block_of: &BlockIndex, u: Vid) -> bool {
    block_of.get(u).iter().any(|&bi| !blocks[bi as usize].targets.is_empty())
}

/// Does this machine still hold any in-edge of `v`?  (Destination-tree
/// leaf membership after a delete; a full scan of the machine's blocks,
/// mirroring how ingestion discovers dst leaves.)
pub fn holds_dst(blocks: &[EdgeBlock], v: Vid) -> bool {
    blocks.iter().any(|b| b.targets.iter().any(|(t, _)| *t == v))
}

/// Splice machine `m` in or out of a sorted leaf list according to
/// `present`.  Idempotent: re-asserting an existing membership is a
/// no-op, which is what makes per-(vertex, machine) last-note-wins
/// folding correct.
pub fn set_membership(leaves: &mut Vec<MachineId>, m: MachineId, present: bool) {
    debug_assert!(leaves.windows(2).all(|w| w[0] < w[1]), "leaf lists are sorted+deduped");
    match leaves.binary_search(&m) {
        Ok(pos) => {
            if !present {
                leaves.remove(pos);
            }
        }
        Err(pos) => {
            if present {
                leaves.insert(pos, m);
            }
        }
    }
}

/// Ground-truth leaf sets from a full scan of every machine's blocks —
/// exactly how ingestion derives them, O(m).  The incremental membership
/// maintenance in [`DistGraph::apply_batch`] / `SpmdEngine::apply_delta`
/// is tested against this.
pub fn recompute_leaves(dg: &DistGraph) -> (Vec<Vec<MachineId>>, Vec<Vec<MachineId>>) {
    let mut src: Vec<Vec<MachineId>> = vec![Vec::new(); dg.n];
    let mut dst: Vec<Vec<MachineId>> = vec![Vec::new(); dg.n];
    for (mach, machine_blocks) in dg.blocks.iter().enumerate() {
        for block in machine_blocks {
            if block.targets.is_empty() {
                continue;
            }
            src[block.src as usize].push(mach);
            for (v, _) in &block.targets {
                dst[*v as usize].push(mach);
            }
        }
    }
    for leaves in src.iter_mut().chain(dst.iter_mut()) {
        leaves.sort_unstable();
        leaves.dedup();
    }
    (src, dst)
}

impl DistGraph {
    /// Replay one mutation batch directly onto this `DistGraph` — the
    /// single-address-space reference for `SpmdEngine::apply_delta`,
    /// following the identical frozen-placement rules (inserts at
    /// `part.owner(u)`, first-match delete, emptied blocks kept).
    /// Returns the number of directed ops applied.
    pub fn apply_batch(&mut self, batch: &MutationBatch) -> usize {
        let mut applied = 0;
        for op in &batch.ops {
            match *op {
                EdgeOp::Insert { u, v, w } => {
                    let owner = self.part.owner(u);
                    insert_arc(&mut self.blocks[owner], &mut self.block_of[owner], u, v, w);
                    set_membership(&mut self.src_leaves[u as usize], owner, true);
                    set_membership(&mut self.dst_leaves[v as usize], owner, true);
                    self.out_deg[u as usize] += 1;
                    self.m += 1;
                    applied += 1;
                }
                EdgeOp::Delete { u, v } => {
                    // The arc is globally unique, so at most one machine
                    // holds it; scan in ascending machine order.
                    let found = (0..self.p).find(|&mach| {
                        delete_arc(&mut self.blocks[mach], &self.block_of[mach], u, v)
                    });
                    if let Some(mach) = found {
                        let src_present =
                            holds_src(&self.blocks[mach], &self.block_of[mach], u);
                        let dst_present = holds_dst(&self.blocks[mach], v);
                        set_membership(&mut self.src_leaves[u as usize], mach, src_present);
                        set_membership(&mut self.dst_leaves[v as usize], mach, dst_present);
                        self.out_deg[u as usize] -= 1;
                        self.m -= 1;
                        applied += 1;
                    }
                }
            }
        }
        applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::graph::ingest::ingest;
    use crate::graph::Graph;
    use crate::mutate::stream::{generate_mutations, MutationConfig};
    use crate::workload::hot_source_order;
    use crate::{Cluster, CostModel};

    fn ingested(n: usize, p: usize, seed: u64) -> (Graph, DistGraph) {
        let g = gen::barabasi_albert(n, 5, seed);
        let mut c = Cluster::new(p, CostModel::paper_cluster());
        let dg = ingest(&mut c, &g, 8);
        (g, dg)
    }

    fn mcfg(batches: usize) -> MutationConfig {
        MutationConfig {
            batches,
            ops_per_batch: 12,
            insert_pct: 55,
            zipf_s: 1.2,
            start_tick: 0,
            every_ticks: 1,
        }
    }

    #[test]
    fn apply_batch_keeps_leaves_in_sync_with_ground_truth() {
        let (g, mut dg) = ingested(800, 4, 3);
        let hot = hot_source_order(&dg.out_deg);
        let stream = generate_mutations(mcfg(5), &g, &hot, 17);
        for b in &stream {
            let applied = dg.apply_batch(b);
            assert_eq!(applied, b.ops.len(), "stream ops are valid by construction");
            let (src, dst) = recompute_leaves(&dg);
            assert_eq!(dg.src_leaves, src, "incremental src leaves drifted");
            assert_eq!(dg.dst_leaves, dst, "incremental dst leaves drifted");
        }
    }

    #[test]
    fn apply_batch_tracks_degrees_and_arc_count() {
        let (g, mut dg) = ingested(600, 4, 9);
        let hot = hot_source_order(&dg.out_deg);
        let stream = generate_mutations(mcfg(4), &g, &hot, 23);
        for b in &stream {
            dg.apply_batch(b);
        }
        let placed: usize = dg
            .blocks
            .iter()
            .flat_map(|bs| bs.iter().map(|b| b.targets.len()))
            .sum();
        assert_eq!(placed, dg.m, "m must equal resident arcs");
        let mut deg = vec![0u32; dg.n];
        for bs in &dg.blocks {
            for b in bs {
                deg[b.src as usize] += b.targets.len() as u32;
            }
        }
        assert_eq!(deg, dg.out_deg, "out_deg must equal resident block sizes");
    }

    #[test]
    fn blocks_never_move_or_vanish() {
        // Frozen placement: deltas may append targets, create owner
        // blocks, or empty blocks out — but an existing block's index
        // and src never change.
        let (g, mut dg) = ingested(600, 4, 5);
        let before: Vec<Vec<Vid>> =
            dg.blocks.iter().map(|bs| bs.iter().map(|b| b.src).collect()).collect();
        let hot = hot_source_order(&dg.out_deg);
        for b in &generate_mutations(mcfg(6), &g, &hot, 31) {
            dg.apply_batch(b);
        }
        for (mach, srcs) in before.iter().enumerate() {
            assert!(dg.blocks[mach].len() >= srcs.len(), "blocks vanished on {mach}");
            for (i, &src) in srcs.iter().enumerate() {
                assert_eq!(dg.blocks[mach][i].src, src, "block {i}@{mach} moved");
            }
        }
    }

    #[test]
    fn set_membership_splices_sorted_lists() {
        let mut leaves: Vec<MachineId> = vec![1, 4, 7];
        set_membership(&mut leaves, 4, true); // idempotent re-assert
        assert_eq!(leaves, vec![1, 4, 7]);
        set_membership(&mut leaves, 3, true);
        assert_eq!(leaves, vec![1, 3, 4, 7]);
        set_membership(&mut leaves, 7, false);
        assert_eq!(leaves, vec![1, 3, 4]);
        set_membership(&mut leaves, 9, false); // absent removal is a no-op
        assert_eq!(leaves, vec![1, 3, 4]);
    }

    #[test]
    fn insert_then_delete_roundtrips_on_one_machine() {
        let (_, mut dg) = ingested(300, 2, 1);
        let u: Vid = 0;
        let owner = dg.part.owner(u);
        let deg0 = dg.out_deg[u as usize];
        // A self-consistent directed pair to a far vertex.
        let v: Vid = 250;
        let batch = MutationBatch {
            id: 0,
            arrival: 0,
            ops: vec![
                EdgeOp::Insert { u, v, w: 2.5 },
                EdgeOp::Insert { u: v, v: u, w: 2.5 },
            ],
        };
        assert_eq!(dg.apply_batch(&batch), 2);
        assert_eq!(dg.out_deg[u as usize], deg0 + 1);
        assert!(dg.src_leaves[u as usize].contains(&owner));
        assert!(dg.dst_leaves[v as usize].contains(&owner));
        let undo = MutationBatch {
            id: 1,
            arrival: 0,
            ops: vec![EdgeOp::Delete { u, v }, EdgeOp::Delete { u: v, v: u }],
        };
        assert_eq!(dg.apply_batch(&undo), 2);
        assert_eq!(dg.out_deg[u as usize], deg0);
        let (src, dst) = recompute_leaves(&dg);
        assert_eq!(dg.src_leaves, src);
        assert_eq!(dg.dst_leaves, dst);
    }
}
