//! Live graph mutation under serving traffic.
//!
//! PRs 3–5 built a serving stack that ingests once and serves a frozen
//! graph; this subsystem makes the resident engine absorb **edge delta
//! batches in place** while queries keep flowing — the "data moves too"
//! regime the paper's task-data orchestration targets, with the
//! epoch/timestamp discipline of differential dataflow's incremental
//! model providing the consistency story.
//!
//! The delta path, end to end:
//!
//! ```text
//!   generate_mutations(cfg, g, hot, seed)          P-independent stream
//!        │  Vec<MutationBatch>  (Zipf-by-hotness edge ops, valid in order)
//!        ▼
//!   MutationFeed ── pop_due(tick) ──► Server::serve (RunOpts::feed)
//!        │   (logical service clock; epoch barrier: batches apply only
//!        │    BETWEEN query dispatches, never inside one)
//!        ▼
//!   SpmdEngine::apply_delta(batch)                 ONE pool superstep
//!        │   workers patch blocks/block_of in place (delta.rs helpers)
//!        │   and ship DeltaNotes to the driver, which splices leaf
//!        │   sets, degrees, and rebuilds ONLY the dirty relay trees
//!        ▼
//!   graph_epoch += 1     stamped on the engine, every QueryResult,
//!                        every MutationRecord, and the ServeReport
//! ```
//!
//! **The counter-witness extends to deltas.**  `ingest::ingestions()`
//! counts full ingestion passes; `apply_delta` never calls one, so a
//! mutating serving run still finishes with exactly 1 ingestion on the
//! served engine — `repro mutate` enforces it, making "absorbed in
//! place" an enforceable property rather than a code-review claim.
//!
//! **Snapshot consistency.**  Every query executes against exactly one
//! epoch: batch composition is fixed at close and mutations apply only
//! between dispatches, so `QueryResult::graph_epoch` fully identifies
//! the graph a result was computed on.  `repro mutate` exploits that to
//! cross-check every result bit-for-bit against reference engines built
//! at that epoch (replayed placement for all five kinds; a true fresh
//! ingest of the mutated graph for the placement-independent exact
//! kinds BFS/SSSP/CC).
//!
//! **Observability.**  With a flight recorder attached
//! (`Server::set_recorder`), every absorbed batch also records a
//! deterministic [`crate::obs::EventKind::MutationApply`] event — the
//! applied tick, op count, service ticks, and the epoch it bumped the
//! engine to — interleaved in causal order with the queries' admission /
//! wave / superstep events, so epoch bumps are visible in the same
//! per-run trace the `repro trace` gate compares across backends.

pub mod delta;
pub mod stream;

pub use delta::{
    delete_arc, holds_dst, holds_src, insert_arc, recompute_leaves, set_membership, DeltaNote,
};
pub use stream::{
    generate_mutations, EdgeOp, MutationBatch, MutationConfig, MutationFeed, MutationStream,
};
