//! Deterministic live-mutation streams for the serving layer.
//!
//! Production graphs change while they are served; differential
//! dataflow's incremental model (and the dynamic-graph sections of the
//! massive-graphs survey, arXiv 2404.06037) frame the workload as a
//! stream of timestamped edge *delta batches* interleaved with queries.
//! This module generates that stream the same way [`crate::workload::queries`]
//! generates query streams: a pure function of (graph, hotness order,
//! config, seed) that **never sees the machine count or the backend** —
//! the same seed drives byte-identical mutation batches into a P=1
//! engine and a P=64 engine, on the simulator or the threaded pool
//! (`tests/mutate_equivalence.rs`), which is what keeps mutating runs
//! cross-checkable against any reference deployment.
//!
//! Mutations address vertices by Zipf-distributed *hotness rank*
//! (hubs churn most, the adversarial case for placement), and the
//! generator maintains a shadow adjacency so every emitted operation is
//! valid **at its application point in the stream**: inserts only create
//! absent edges, deletes only remove present ones, and each undirected
//! edge op is emitted as its two directed arcs back-to-back — the graph
//! stays symmetric, exactly like [`crate::graph::gen`] builds it.

use crate::det::{det_set, DetSet};
use crate::graph::{Graph, Vid};
use crate::rng::Rng;
use crate::workload::Zipf;

/// One directed-arc mutation.  Undirected edge operations appear in the
/// stream as two consecutive `EdgeOp`s (u→v then v→u, same weight).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeOp {
    Insert { u: Vid, v: Vid, w: f32 },
    Delete { u: Vid, v: Vid },
}

/// One epoch's worth of mutations: applied atomically between query
/// dispatches, bumping the engine's `graph_epoch` by exactly one.
#[derive(Clone, Debug, PartialEq)]
pub struct MutationBatch {
    pub id: u64,
    /// Logical service-clock tick at which the batch becomes due.
    pub arrival: u64,
    pub ops: Vec<EdgeOp>,
}

/// The full mutation stream, in nondecreasing arrival order.
pub type MutationStream = Vec<MutationBatch>;

/// Stream parameters.  Like [`crate::workload::StreamConfig`], everything
/// is logical (op counts and ticks), so a config fully determines the
/// delta schedule.
#[derive(Clone, Copy, Debug)]
pub struct MutationConfig {
    /// Number of delta batches (== number of epoch bumps).
    pub batches: usize,
    /// Undirected edge operations per batch (each emits 2 directed ops).
    pub ops_per_batch: usize,
    /// Percentage (0..=100) of operations that are inserts; the rest are
    /// deletes.
    pub insert_pct: u32,
    /// Zipf exponent over vertex hotness ranks for the endpoints.
    pub zipf_s: f64,
    /// Tick of the first batch.
    pub start_tick: u64,
    /// Ticks between consecutive batches.
    pub every_ticks: u64,
}

/// Attempts per operation before the slot is skipped (e.g. a delete drawn
/// for an isolated vertex); bounded so generation always terminates.
const MAX_ATTEMPTS: usize = 64;

#[inline]
fn arc_key(u: Vid, v: Vid) -> u64 {
    ((u as u64) << 32) | v as u64
}

/// Generate the deterministic mutation stream: batch `i` arrives at tick
/// `start_tick + i * every_ticks`; endpoints are drawn Zipf(`zipf_s`)
/// over `hot` ranks (rank 0 = hottest).  A shadow adjacency keeps every
/// op valid at its application point, so a consumer that applies the
/// stream in order never sees a duplicate insert or a miss on delete.
/// Pure function of (g, hot, cfg, seed) — P and backend never enter.
pub fn generate_mutations(
    cfg: MutationConfig,
    g: &Graph,
    hot: &[Vid],
    seed: u64,
) -> MutationStream {
    assert!(cfg.every_ticks >= 1, "batches need a period of at least one tick");
    assert!(cfg.insert_pct <= 100, "insert_pct is a percentage");
    assert!(!hot.is_empty(), "empty vertex universe");
    let zipf = Zipf::new(hot.len(), cfg.zipf_s);
    let mut rng = Rng::new(seed);

    // Shadow state: adjacency lists + directed-arc membership, evolved
    // alongside the stream so validity is judged against the graph AS
    // MUTATED SO FAR, not the original.
    let mut adj: Vec<Vec<Vid>> = (0..g.n as Vid)
        .map(|u| g.neighbors(u).iter().map(|(v, _)| *v).collect())
        .collect();
    let mut present: DetSet<u64> = det_set();
    for u in 0..g.n as Vid {
        for (v, _) in g.neighbors(u) {
            present.insert(arc_key(u, *v));
        }
    }

    let mut stream = Vec::with_capacity(cfg.batches);
    for b in 0..cfg.batches {
        let mut ops = Vec::with_capacity(cfg.ops_per_batch * 2);
        for _ in 0..cfg.ops_per_batch {
            for _attempt in 0..MAX_ATTEMPTS {
                let u = hot[zipf.sample(&mut rng)];
                let insert = rng.next_below(100) < cfg.insert_pct as u64;
                if insert {
                    let v = hot[zipf.sample(&mut rng)];
                    if v == u || present.contains(&arc_key(u, v)) {
                        continue;
                    }
                    // Same weight distribution as graph::gen, symmetric.
                    let w = 1.0 + rng.next_f32() * 9.0;
                    ops.push(EdgeOp::Insert { u, v, w });
                    ops.push(EdgeOp::Insert { u: v, v: u, w });
                    present.insert(arc_key(u, v));
                    present.insert(arc_key(v, u));
                    adj[u as usize].push(v);
                    adj[v as usize].push(u);
                } else {
                    if adj[u as usize].is_empty() {
                        continue;
                    }
                    let idx = rng.next_usize(adj[u as usize].len());
                    let v = adj[u as usize][idx];
                    ops.push(EdgeOp::Delete { u, v });
                    ops.push(EdgeOp::Delete { u: v, v: u });
                    present.remove(&arc_key(u, v));
                    present.remove(&arc_key(v, u));
                    adj[u as usize].swap_remove(idx);
                    let back = adj[v as usize]
                        .iter()
                        .position(|x| *x == u)
                        .expect("shadow adjacency must be symmetric");
                    adj[v as usize].swap_remove(back);
                }
                break;
            }
        }
        stream.push(MutationBatch {
            id: b as u64,
            arrival: cfg.start_tick + b as u64 * cfg.every_ticks,
            ops,
        });
    }
    stream
}

/// How the serving loop consumes a mutation stream: polled on the
/// logical service clock between query dispatches, mirroring
/// [`crate::workload::ArrivalSource`] for arrivals.  Batches come out in
/// schedule order, exactly once each.
pub struct MutationFeed {
    stream: MutationStream,
    next: usize,
}

impl MutationFeed {
    pub fn new(stream: MutationStream) -> Self {
        assert!(
            stream.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "mutation batches must arrive in nondecreasing tick order"
        );
        MutationFeed { stream, next: 0 }
    }

    /// A feed with no batches — what a mutation-free serving run uses.
    pub fn empty() -> Self {
        MutationFeed { stream: Vec::new(), next: 0 }
    }

    /// Earliest tick at which an unconsumed batch is scheduled.
    pub fn next_arrival(&self) -> Option<u64> {
        self.stream.get(self.next).map(|b| b.arrival)
    }

    /// Hand out the next batch iff it is due at `tick` (call in a loop —
    /// several batches can fall due inside one service window).
    pub fn pop_due(&mut self, tick: u64) -> Option<MutationBatch> {
        let b = self.stream.get(self.next)?;
        if b.arrival > tick {
            return None;
        }
        self.next += 1;
        Some(b.clone())
    }

    /// Hand out the next batch regardless of schedule — the post-stream
    /// drain path, so the final epoch never depends on where the query
    /// stream happened to end.
    pub fn pop_next(&mut self) -> Option<MutationBatch> {
        let b = self.stream.get(self.next)?;
        self.next += 1;
        Some(b.clone())
    }

    pub fn remaining(&self) -> usize {
        self.stream.len() - self.next
    }

    pub fn done(&self) -> bool {
        self.next >= self.stream.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::workload::hot_source_order;

    fn cfg(batches: usize, ops: usize) -> MutationConfig {
        MutationConfig {
            batches,
            ops_per_batch: ops,
            insert_pct: 60,
            zipf_s: 1.2,
            start_tick: 2,
            every_ticks: 6,
        }
    }

    fn setup() -> (Graph, Vec<Vid>) {
        let g = gen::barabasi_albert(400, 5, 3);
        let hot: Vec<Vid> = {
            let mut deg = vec![0u32; g.n];
            for (u, d) in deg.iter_mut().enumerate() {
                *d = g.out_degree(u as Vid) as u32;
            }
            hot_source_order(&deg)
        };
        (g, hot)
    }

    #[test]
    fn same_seed_same_stream_distinct_seeds_diverge() {
        let (g, hot) = setup();
        let a = generate_mutations(cfg(4, 8), &g, &hot, 42);
        let b = generate_mutations(cfg(4, 8), &g, &hot, 42);
        assert_eq!(a, b);
        let c = generate_mutations(cfg(4, 8), &g, &hot, 43);
        assert_ne!(a, c, "distinct seeds must diverge");
    }

    #[test]
    fn arrivals_follow_the_schedule() {
        let (g, hot) = setup();
        let s = generate_mutations(cfg(4, 4), &g, &hot, 7);
        let arrivals: Vec<u64> = s.iter().map(|b| b.arrival).collect();
        assert_eq!(arrivals, vec![2, 8, 14, 20]);
        assert_eq!(s[2].id, 2);
    }

    #[test]
    fn every_op_is_valid_at_its_application_point() {
        // Replay the stream against an independently-maintained arc set:
        // every directed insert must hit an absent arc, every delete a
        // present one, and ops must come in symmetric directed pairs.
        let (g, hot) = setup();
        let s = generate_mutations(cfg(6, 16), &g, &hot, 11);
        let mut present: DetSet<u64> = det_set();
        for u in 0..g.n as Vid {
            for (v, _) in g.neighbors(u) {
                present.insert(arc_key(u, *v));
            }
        }
        let mut total_ops = 0usize;
        for b in &s {
            assert_eq!(b.ops.len() % 2, 0, "directed ops come in pairs");
            for pair in b.ops.chunks(2) {
                match (pair[0], pair[1]) {
                    (EdgeOp::Insert { u, v, w }, EdgeOp::Insert { u: v2, v: u2, w: w2 }) => {
                        assert_eq!((u, v), (u2, v2), "pair must be the reverse arc");
                        assert_eq!(w.to_bits(), w2.to_bits(), "symmetric weight");
                        assert_ne!(u, v, "no self loops");
                        assert!(present.insert(arc_key(u, v)), "insert of a present arc");
                        assert!(present.insert(arc_key(v, u)), "insert of a present arc");
                        assert!((1.0..10.0).contains(&w));
                    }
                    (EdgeOp::Delete { u, v }, EdgeOp::Delete { u: v2, v: u2 }) => {
                        assert_eq!((u, v), (u2, v2), "pair must be the reverse arc");
                        assert!(present.remove(&arc_key(u, v)), "delete of an absent arc");
                        assert!(present.remove(&arc_key(v, u)), "delete of an absent arc");
                    }
                    other => panic!("mixed directed pair: {other:?}"),
                }
            }
            total_ops += b.ops.len();
        }
        assert!(total_ops > 0, "stream must mutate something");
    }

    #[test]
    fn mix_covers_inserts_and_deletes() {
        let (g, hot) = setup();
        let s = generate_mutations(cfg(8, 32), &g, &hot, 5);
        let ins = s
            .iter()
            .flat_map(|b| &b.ops)
            .filter(|o| matches!(o, EdgeOp::Insert { .. }))
            .count();
        let del = s
            .iter()
            .flat_map(|b| &b.ops)
            .filter(|o| matches!(o, EdgeOp::Delete { .. }))
            .count();
        assert!(ins > 0 && del > 0, "60/40 mix must draw both ({ins} ins / {del} del)");
    }

    #[test]
    fn feed_emits_each_batch_once_in_order() {
        let (g, hot) = setup();
        let s = generate_mutations(cfg(3, 4), &g, &hot, 9);
        let mut feed = MutationFeed::new(s.clone());
        assert_eq!(feed.next_arrival(), Some(2));
        assert_eq!(feed.remaining(), 3);
        assert!(feed.pop_due(1).is_none(), "not due yet");
        let b0 = feed.pop_due(2).expect("batch 0 due at tick 2");
        assert_eq!(b0.id, 0);
        assert!(feed.pop_due(7).is_none(), "batch 1 arrives at 8");
        let b1 = feed.pop_due(30).expect("due");
        assert_eq!(b1.id, 1);
        let b2 = feed.pop_next().expect("drain ignores the schedule");
        assert_eq!(b2.id, 2);
        assert!(feed.done());
        assert_eq!(feed.next_arrival(), None);
        assert!(MutationFeed::empty().done());
    }
}
