//! `repro` — the TD-Orch / TDO-GP reproduction CLI (L3 leader entrypoint).
//!
//! Each subcommand regenerates one table or figure from the paper's
//! evaluation on the simulated BSP cluster (see DESIGN.md §4):
//!
//! ```text
//! repro fig5    [--per-machine N] [--seed S]   YCSB weak scaling (§4)
//! repro table2  [--seed S]                     graph end-to-end (§6.2)
//! repro fig8    [--seed S]                     strong scaling (§6.3)
//! repro fig9    [--edges N] [--seed S]         weak scaling (§6.3)
//! repro fig10   [--seed S]                     breakdown (§6.4)
//! repro table3  [--seed S]                     TD-Orch ablation (§6.4)
//! repro table4  [--seed S]                     technique ablation (§6.4)
//! repro table5  [--seed S]                     single-NUMA PR (§6.5)
//! repro table6  [--seed S]                     big NUMA server (§6.5)
//! repro all     [--seed S]                     everything above
//! repro smoke                                  tiny end-to-end sanity run
//! ```
//!
//! (CLI is hand-rolled: the offline build has no clap — see Cargo.toml.)

use tdorch::repro;

struct Args {
    cmd: String,
    seed: u64,
    per_machine: usize,
    edges: usize,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        seed: 42,
        per_machine: 20_000,
        edges: 50_000,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--seed" => {
                i += 1;
                args.seed = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                    eprintln!("--seed needs a u64");
                    std::process::exit(2);
                });
            }
            "--per-machine" => {
                i += 1;
                args.per_machine = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                    eprintln!("--per-machine needs a usize");
                    std::process::exit(2);
                });
            }
            "--edges" => {
                i += 1;
                args.edges = argv.get(i).and_then(|s| s.parse().ok()).unwrap_or_else(|| {
                    eprintln!("--edges needs a usize");
                    std::process::exit(2);
                });
            }
            flag if flag.starts_with("--") => {
                eprintln!("unknown flag {flag}");
                std::process::exit(2);
            }
            cmd => {
                if args.cmd.is_empty() {
                    args.cmd = cmd.to_string();
                } else {
                    eprintln!("multiple commands given: {} and {cmd}", args.cmd);
                    std::process::exit(2);
                }
            }
        }
        i += 1;
    }
    args
}

fn smoke() {
    // A miniature of everything: one orchestration stage on the KV store
    // (XLA-backed if artifacts are present) plus one graph algorithm.
    use tdorch::graph::algorithms::bfs;
    use tdorch::graph::engine::Engine as GraphEngineImpl;
    use tdorch::graph::engine::GraphEngine as _;
    use tdorch::graph::gen;
    use tdorch::kvstore::{preload, Bucket, KvApp};
    use tdorch::orchestration::tdorch::TdOrch;
    use tdorch::orchestration::{spread_tasks, Scheduler, Task};
    use tdorch::workload::{YcsbKind, YcsbWorkload};
    use tdorch::{Cluster, CostModel, DistStore};

    println!("== smoke: KV store over TD-Orch ==");
    let buckets = 1 << 12;
    let engine = tdorch::runtime::Engine::load_default().ok();
    let app = match &engine {
        Some(e) => {
            println!("artifacts loaded: {:?}", e.artifact_names());
            KvApp::with_engine(buckets, e)
        }
        None => {
            println!("artifacts not found — native lambda path");
            KvApp::new(buckets)
        }
    };
    let workload = YcsbWorkload::new(YcsbKind::A, 100_000, 1.5, buckets);
    let mut rng = tdorch::rng::Rng::new(7);
    let tasks: Vec<Task<tdorch::kvstore::KvOp>> = workload.generate(&mut rng, 20_000, 0);
    let p = 8;
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(p);
    preload(&mut store, buckets, 10_000);
    let outcome = TdOrch::new().run_stage(&mut cluster, &app, spread_tasks(tasks, p), &mut store);
    println!(
        "executed {} tasks (xla-served: {}), sim {:.4}s, exec imbalance {:.2}",
        outcome.total_executed,
        app.xla_served(),
        cluster.metrics.sim_seconds(),
        tdorch::metrics::Metrics::imbalance(&outcome.executed_per_machine),
    );

    println!("\n== smoke: TDO-GP BFS ==");
    let g = gen::barabasi_albert(2_000, 6, 7);
    let mut ge = GraphEngineImpl::tdo_gp(&g, 8, CostModel::paper_cluster());
    ge.reset_metrics();
    let dist = bfs(&mut ge, 0);
    let reached = dist.iter().filter(|d| **d >= 0).count();
    println!(
        "BFS reached {reached}/{} vertices in sim {:.4}s over {} supersteps",
        g.n,
        ge.metrics().sim_seconds(),
        ge.metrics().supersteps,
    );
    println!("\nsmoke OK");
}

fn main() {
    let args = parse_args();
    match args.cmd.as_str() {
        "fig5" => {
            repro::kv::fig5(args.per_machine, args.seed);
        }
        "table2" => {
            repro::graphs::table2(args.seed);
        }
        "fig8" => {
            repro::graphs::fig8(args.seed);
        }
        "fig9" => {
            repro::graphs::fig9(args.edges, args.seed);
        }
        "fig10" => {
            repro::graphs::fig10(args.seed);
        }
        "table3" => {
            repro::graphs::table3(args.seed);
        }
        "table4" => {
            repro::graphs::table4(args.seed);
        }
        "table5" => {
            repro::graphs::table5(args.seed);
        }
        "table6" => {
            repro::graphs::table6(args.seed);
        }
        "all" => {
            repro::kv::fig5(args.per_machine, args.seed);
            repro::graphs::table2(args.seed);
            repro::graphs::fig8(args.seed);
            repro::graphs::fig9(args.edges, args.seed);
            repro::graphs::fig10(args.seed);
            repro::graphs::table3(args.seed);
            repro::graphs::table4(args.seed);
            repro::graphs::table5(args.seed);
            repro::graphs::table6(args.seed);
        }
        "smoke" => smoke(),
        "" => {
            eprintln!("usage: repro <fig5|table2|fig8|fig9|fig10|table3|table4|table5|table6|all|smoke> [--seed S] [--per-machine N] [--edges N]");
            std::process::exit(2);
        }
        other => {
            eprintln!("unknown command {other:?}");
            std::process::exit(2);
        }
    }
}
