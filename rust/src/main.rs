//! `repro` — the TD-Orch / TDO-GP reproduction CLI (L3 leader entrypoint).
//!
//! Each subcommand regenerates one table or figure from the paper's
//! evaluation on the simulated BSP cluster, or drives the real threaded
//! substrate (see DESIGN.md §4 and rust/README.md):
//!
//! ```text
//! repro fig5    [--per-machine N] [--seed S]   YCSB weak scaling (§4)
//! repro table2  [--seed S]                     graph end-to-end (§6.2)
//! repro fig8    [--seed S]                     strong scaling (§6.3)
//! repro fig9    [--edges N] [--seed S]         weak scaling (§6.3)
//! repro fig10   [--seed S]                     breakdown (§6.4)
//! repro table3  [--seed S]                     TD-Orch ablation (§6.4)
//! repro table4  [--seed S]                     technique ablation (§6.4)
//! repro table5  [--seed S]                     single-NUMA PR (§6.5)
//! repro table6  [--seed S]                     big NUMA server (§6.5)
//! repro graphs  [--quick] [--edges N] [--seed S]
//!                                              every graph figure/table;
//!                                              --quick = CI smoke that
//!                                              ASSERTS the orderings
//! repro exec    [--threads P | --machines P] [--per-machine N]
//!               [--gamma G] [--seed S]         REAL threaded substrate
//! repro graph   [--backend sim|threaded] [--threads P | --machines P]
//!               [--seed S]                     TDO-GP edge_map on the pool
//! repro serve   [--backend sim|threaded] [--threads P] [--queries N]
//!               [--zipf S] [--batch B] [--fuse] [--cache] [--adapt]
//!               [--seed S]                     online Zipf query stream;
//!                                              --fuse = multi-source
//!                                              batch waves, --cache =
//!                                              epoch-keyed memoization,
//!                                              --adapt = hotspot-adaptive
//!                                              placement
//! repro loadcurve [--quick] [--backend sim|threaded] [--threads P]
//!               [--seed S] [--out PATH]        latency vs offered load:
//!                                              open-loop rate + closed-
//!                                              loop client sweeps, JSON
//!                                              report; --quick = CI gate
//! repro mutate  [--quick] [--backend sim|threaded] [--threads P]
//!               [--fuse] [--cache] [--seed S]  live edge mutations under
//!                                              serving traffic, every
//!                                              result cross-checked at
//!                                              its epoch; CI gate
//! repro trace   [--quick] [--backend sim|threaded] [--threads P]
//!               [--seed S] [--out DIR]         deterministic flight
//!                                              recorder: replays the
//!                                              mutating serve workload
//!                                              on sim AND the requested
//!                                              backend at P and P=1,
//!                                              exit 1 unless the event
//!                                              streams are bit-identical;
//!                                              writes Chrome trace JSON +
//!                                              work/words heatmap
//! repro placement [--quick] [--backend sim|threaded] [--threads P]
//!               [--seed S] [--out PATH]        hotspot-adaptive placement
//!                                              A/B: the same Zipf-hot
//!                                              query stream + drifting
//!                                              mutation feed served with
//!                                              static and adaptive
//!                                              placement, every result
//!                                              cross-checked at its
//!                                              placement epoch, adaptive
//!                                              must win on goodput AND
//!                                              imbalance; CI gate
//! repro bench-snapshot [--out DIR] [--check] [--baseline DIR]
//!                                              regenerate the committed
//!                                              perf snapshots; --check
//!                                              diffs them against the
//!                                              repo-root baselines
//! repro profile [--reps N] [--out PATH]        per-stage wallclock A/Bs
//!                                              (scheduler stage, DetMap
//!                                              vs slab, sparse vs dense
//!                                              frontier, per-message vs
//!                                              batched sends); --out
//!                                              writes the JSON blob
//! repro all     [--seed S]                     every figure/table above
//! repro smoke                                  tiny end-to-end sanity run
//! ```
//!
//! `repro exec` runs TD-Orch and the direct-push/direct-pull baselines on
//! real OS worker threads (one per logical machine — the shared-nothing
//! model ties the two counts together, so `--threads` and `--machines`
//! are synonyms), validates every run against the sequential oracle, and
//! prints measured per-machine wall-clock.
//!
//! `repro graph` runs PageRank and SSSP through the SPMD `DistEdgeMap`
//! engine on the persistent threaded worker pool, asserts the results
//! are bit-identical to the BSP-simulator backend of the *same* engine,
//! and prints the measured per-machine busy table (exit 1 on
//! divergence).  `--backend sim` skips the threaded leg.
//!
//! `repro serve` admits an open-loop {BFS,SSSP,PR,CC,BC} query stream with
//! Zipf-skewed sources, batches it, and serves it on ONE long-lived
//! engine (graph ingested exactly once — verified by counter), cross
//! -checking every result bit-for-bit against a single-shot sim
//! reference and reporting wait/service percentiles plus queries/sec
//! (exit 1 on any divergence or a second ingestion).
//!
//! `repro mutate` interleaves seeded edge insert/delete batches with the
//! serving stream on the same logical clock: deltas are absorbed in
//! place by `SpmdEngine::apply_delta` (the served engine still ingests
//! exactly once), each bumping the engine's graph epoch, and every
//! post-mutation result is cross-checked bit-for-bit against reference
//! engines built at exactly that epoch (exit 1 on divergence, a second
//! ingestion, or an epoch-accounting violation).
//!
//! (CLI is hand-rolled: the offline build has no clap — see Cargo.toml.)

use tdorch::repro;

struct Args {
    cmd: String,
    seed: u64,
    per_machine: usize,
    edges: usize,
    gamma: f64,
    threads: Option<usize>,
    machines: Option<usize>,
    backend: String,
    queries: usize,
    zipf: f64,
    batch: usize,
    quick: bool,
    fuse: bool,
    cache: bool,
    adapt: bool,
    /// `--out` target; `None` = the subcommand's own default
    /// (loadcurve: `target/loadcurve/loadcurve.json`; bench-snapshot:
    /// `target/bench-snapshot`).
    out: Option<String>,
    check: bool,
    baseline: String,
    reps: usize,
}

/// Parse the value following flag `name` at `argv[*i]`, advancing `i`.
/// Exits with a usage error when the value is missing or malformed.
fn parse_flag<T: std::str::FromStr>(argv: &[String], i: &mut usize, name: &str) -> T {
    *i += 1;
    match argv.get(*i).and_then(|s| s.parse::<T>().ok()) {
        Some(v) => v,
        None => {
            eprintln!("{name} needs a {} value", std::any::type_name::<T>());
            std::process::exit(2);
        }
    }
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        seed: 42,
        per_machine: 20_000,
        edges: 50_000,
        gamma: 1.0,
        threads: None,
        machines: None,
        backend: "threaded".to_string(),
        queries: 64,
        zipf: 1.5,
        batch: 8,
        quick: false,
        fuse: false,
        cache: false,
        adapt: false,
        out: None,
        check: false,
        baseline: "..".to_string(),
        reps: 20,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        match argv[i].as_str() {
            "--seed" => args.seed = parse_flag(&argv, &mut i, "--seed"),
            "--per-machine" => args.per_machine = parse_flag(&argv, &mut i, "--per-machine"),
            "--edges" => args.edges = parse_flag(&argv, &mut i, "--edges"),
            "--gamma" => args.gamma = parse_flag(&argv, &mut i, "--gamma"),
            "--threads" => args.threads = Some(parse_flag(&argv, &mut i, "--threads")),
            "--machines" => args.machines = Some(parse_flag(&argv, &mut i, "--machines")),
            "--backend" => args.backend = parse_flag(&argv, &mut i, "--backend"),
            "--queries" => args.queries = parse_flag(&argv, &mut i, "--queries"),
            "--zipf" => args.zipf = parse_flag(&argv, &mut i, "--zipf"),
            "--batch" => args.batch = parse_flag(&argv, &mut i, "--batch"),
            "--quick" => args.quick = true,
            "--fuse" => args.fuse = true,
            "--cache" => args.cache = true,
            "--adapt" => args.adapt = true,
            "--out" => args.out = Some(parse_flag(&argv, &mut i, "--out")),
            "--check" => args.check = true,
            "--baseline" => args.baseline = parse_flag(&argv, &mut i, "--baseline"),
            "--reps" => args.reps = parse_flag(&argv, &mut i, "--reps"),
            flag if flag.starts_with("--") => {
                eprintln!("unknown flag {flag}");
                std::process::exit(2);
            }
            cmd => {
                if args.cmd.is_empty() {
                    args.cmd = cmd.to_string();
                } else {
                    eprintln!("multiple commands given: {} and {cmd}", args.cmd);
                    std::process::exit(2);
                }
            }
        }
        i += 1;
    }
    args
}

fn smoke() {
    // A miniature of everything: one orchestration stage on the KV store
    // (XLA-backed if artifacts are present) plus one graph algorithm.
    use tdorch::graph::algorithms::{bfs, BfsShard};
    use tdorch::graph::gen;
    use tdorch::graph::spmd::SpmdEngine;
    use tdorch::kvstore::{preload, Bucket, KvApp};
    use tdorch::orchestration::tdorch::TdOrch;
    use tdorch::orchestration::{spread_tasks, Scheduler, Task};
    use tdorch::workload::{YcsbKind, YcsbWorkload};
    use tdorch::{Cluster, CostModel, DistStore};

    println!("== smoke: KV store over TD-Orch ==");
    let buckets = 1 << 12;
    let engine = tdorch::runtime::Engine::load_default().ok();
    let app = match &engine {
        Some(e) => {
            println!("artifacts loaded: {:?}", e.artifact_names());
            KvApp::with_engine(buckets, e)
        }
        None => {
            println!("artifacts not found — native lambda path");
            KvApp::new(buckets)
        }
    };
    let workload = YcsbWorkload::new(YcsbKind::A, 100_000, 1.5, buckets);
    let mut rng = tdorch::rng::Rng::new(7);
    let tasks: Vec<Task<tdorch::kvstore::KvOp>> = workload.generate(&mut rng, 20_000, 0);
    let p = 8;
    let mut cluster = Cluster::new(p, CostModel::paper_cluster());
    let mut store: DistStore<Bucket> = DistStore::new(p);
    preload(&mut store, buckets, 10_000);
    let outcome = TdOrch::new().run_stage(&mut cluster, &app, spread_tasks(tasks, p), &mut store);
    println!(
        "executed {} tasks (xla-served: {}), sim {:.4}s, exec imbalance {:.2}",
        outcome.total_executed,
        app.xla_served(),
        cluster.metrics.sim_seconds(),
        tdorch::metrics::Metrics::imbalance(&outcome.executed_per_machine),
    );

    println!("\n== smoke: TDO-GP BFS ==");
    let g = gen::barabasi_albert(2_000, 6, 7);
    let ge_cost = CostModel::paper_cluster();
    let mut ge = SpmdEngine::tdo_gp(Cluster::new(8, ge_cost), &g, ge_cost, BfsShard::new);
    ge.sub_mut().reset_metrics();
    let dist = bfs(&mut ge, 0);
    let reached = dist.iter().filter(|d| **d >= 0).count();
    println!(
        "BFS reached {reached}/{} vertices in sim {:.4}s over {} supersteps",
        g.n,
        ge.sub().metrics.sim_seconds(),
        ge.sub().metrics.supersteps,
    );
    println!("\nsmoke OK");
}

/// Resolve the worker/machine count shared by the threaded subcommands
/// (`--threads` and `--machines` are synonyms — one worker per machine).
fn resolve_p(args: &Args) -> usize {
    let p = match (args.threads, args.machines) {
        (Some(t), Some(m)) if t != m => {
            eprintln!(
                "--threads {t} and --machines {m} disagree: the shared-nothing \
                 substrate runs exactly one worker thread per logical machine"
            );
            std::process::exit(2);
        }
        (t, m) => t.or(m).unwrap_or(8),
    };
    if p < 1 {
        eprintln!("--threads/--machines must be >= 1");
        std::process::exit(2);
    }
    p
}

fn main() {
    let args = parse_args();
    match args.cmd.as_str() {
        "fig5" => {
            repro::kv::fig5(args.per_machine, args.seed);
        }
        "table2" => {
            repro::graphs::table2(args.seed);
        }
        "fig8" => {
            repro::graphs::fig8(args.seed);
        }
        "fig9" => {
            repro::graphs::fig9(args.edges, args.seed);
        }
        "fig10" => {
            repro::graphs::fig10(args.seed);
        }
        "table3" => {
            repro::graphs::table3(args.seed);
        }
        "table4" => {
            repro::graphs::table4(args.seed);
        }
        "table5" => {
            repro::graphs::table5(args.seed);
        }
        "table6" => {
            repro::graphs::table6(args.seed);
        }
        "graphs" => {
            if !repro::graphs::run_graphs(args.edges, args.seed, args.quick) {
                std::process::exit(1);
            }
        }
        "exec" => {
            let p = resolve_p(&args);
            if args.per_machine < 1 {
                eprintln!("--per-machine must be >= 1");
                std::process::exit(2);
            }
            let summary = repro::exec::run_exec(p, args.per_machine, args.gamma, args.seed);
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "graph" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            if !repro::graphs::run_graph_backend(p, args.seed, &args.backend) {
                std::process::exit(1);
            }
        }
        "serve" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            if args.queries < 1 || args.batch < 1 {
                eprintln!("--queries and --batch must be >= 1");
                std::process::exit(2);
            }
            let summary = repro::serve::run_serve(
                p,
                args.queries,
                args.zipf,
                args.batch,
                args.seed,
                &args.backend,
                args.fuse,
                args.cache,
                args.adapt,
            );
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "loadcurve" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            let out = args
                .out
                .clone()
                .unwrap_or_else(|| "target/loadcurve/loadcurve.json".to_string());
            let summary =
                repro::loadcurve::run_loadcurve(p, args.seed, &args.backend, args.quick, &out);
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "mutate" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            let summary = repro::mutate::run_mutate(
                p,
                args.seed,
                &args.backend,
                args.quick,
                args.fuse,
                args.cache,
            );
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "trace" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            let out = args.out.clone().unwrap_or_else(|| "target/trace".to_string());
            let summary = repro::trace::run_trace(p, args.seed, &args.backend, args.quick, &out);
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "placement" => {
            let p = resolve_p(&args);
            match args.backend.as_str() {
                "sim" | "threaded" => {}
                other => {
                    eprintln!("--backend must be sim or threaded (got {other:?})");
                    std::process::exit(2);
                }
            }
            let out = args
                .out
                .clone()
                .unwrap_or_else(|| "target/placement/placement.json".to_string());
            let summary =
                repro::placement::run_placement(p, args.seed, &args.backend, args.quick, &out);
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "bench-snapshot" => {
            let out = args
                .out
                .clone()
                .unwrap_or_else(|| "target/bench-snapshot".to_string());
            let baseline = if args.check {
                Some(args.baseline.as_str())
            } else {
                None
            };
            let summary = repro::bench_snapshot::run_bench_snapshot(&out, baseline);
            if !summary.all_valid {
                std::process::exit(1);
            }
        }
        "profile" => {
            if args.reps < 1 {
                eprintln!("--reps must be >= 1");
                std::process::exit(2);
            }
            let report = repro::profile::run_profile(args.reps);
            if let Some(path) = &args.out {
                match std::fs::write(path, report.json()) {
                    Ok(()) => println!("wrote {path}"),
                    Err(e) => {
                        eprintln!("FAILED to write {path}: {e}");
                        std::process::exit(1);
                    }
                }
            }
        }
        "all" => {
            repro::kv::fig5(args.per_machine, args.seed);
            repro::graphs::table2(args.seed);
            repro::graphs::fig8(args.seed);
            repro::graphs::fig9(args.edges, args.seed);
            repro::graphs::fig10(args.seed);
            repro::graphs::table3(args.seed);
            repro::graphs::table4(args.seed);
            repro::graphs::table5(args.seed);
            repro::graphs::table6(args.seed);
        }
        "smoke" => smoke(),
        "" => {
            eprintln!(
                "usage: repro <fig5|table2|fig8|fig9|fig10|table3|table4|table5|table6|graphs|exec|graph|serve|loadcurve|mutate|trace|placement|bench-snapshot|profile|all|smoke> \
                 [--seed S] [--per-machine N] [--edges N] [--gamma G] [--threads P] [--machines P] \
                 [--backend sim|threaded] [--queries N] [--zipf S] [--batch B] [--fuse] [--cache] \
                 [--adapt] [--quick] [--out PATH] [--check] [--baseline DIR] [--reps N]"
            );
            std::process::exit(2);
        }
        other => {
            eprintln!("unknown command {other:?}");
            std::process::exit(2);
        }
    }
}
