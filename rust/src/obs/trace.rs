//! The flight recorder: a bounded ring buffer of structured [`Event`]s
//! covering both substrates and the whole serving pipeline.
//!
//! ## Determinism split
//!
//! Every event is two parts:
//!
//! * the **deterministic core** — [`Event::kind`], an [`EventKind`] of
//!   logical-clock stamps and ledger quantities only (ticks, ledger
//!   superstep indices, per-machine work/words/message counts, query
//!   ids, epochs).  By the repo's determinism contract these are pure
//!   functions of (graph, flags, config, P) — never of the backend or
//!   the host — so the rendered core stream ([`FlightRecorder::det_stream`])
//!   is **bit-identical** between the simulator and the threaded pool,
//!   which `repro trace` enforces as an exit-1 gate.
//! * an optional **wall-clock annotation** — [`Event::wall`], per-machine
//!   busy nanoseconds.  Only the threaded backend produces it, and it is
//!   *never* part of any comparison: it is carried alongside for the
//!   Chrome-trace export, exactly like the `service_ms` field on a
//!   `QueryResult`.
//!
//! ## Clock stamps
//!
//! Serving events carry the **logical service tick** they happened at.
//! [`EventKind::Superstep`] events come from below the serving layer (the
//! substrate's barrier) and carry the **ledger superstep index** instead —
//! the very counter whose deltas *define* the service clock
//! (`ServeConfig::supersteps_per_tick`), so the two stamp domains are two
//! gears of the same deterministic clockwork.
//!
//! ## Ring buffer
//!
//! The recorder is bounded: when full, the **oldest** event is dropped
//! (the newest tail of a run is what a post-mortem needs) and
//! [`FlightRecorder::dropped`] counts the loss explicitly — truncation is
//! visible, never silent.  Sequence numbers keep counting across drops,
//! so surviving events still say where they sat in the full stream.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::workload::QueryKind;

/// How a shared recorder travels: the driver thread of either substrate,
/// the server, and the exporter all hold clones of one handle.  The lock
/// is uncontended by construction — both backends emit from the driver
/// thread only (the simulator at `barrier()`, the pool in the driver's
/// report fold), never from workers.
pub type ObserverHandle = Arc<Mutex<FlightRecorder>>;

/// Default ring capacity — roomy enough that the CI trace workloads
/// record loss-free, small enough to bound memory on long serving runs.
pub const DEFAULT_CAPACITY: usize = 65_536;

/// Why the server closed a batch ([`EventKind::BatchClose`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CloseReason {
    /// `batch` pending queries accumulated.
    Full,
    /// The oldest pending query aged past `deadline_ticks`.
    Overdue,
    /// The source is exhausted; the partial batch drains.
    Drain,
}

/// Wall-clock annotation (threaded backend only — see the module docs;
/// never part of the deterministic core, never compared).
#[derive(Clone, Debug)]
pub struct WallNote {
    /// Per-machine busy nanoseconds: for a [`EventKind::Superstep`], that
    /// step's compute+comm window per machine; for a
    /// [`EventKind::WaveDispatch`], the per-machine busy *delta* since the
    /// previous dispatch (mutation-absorption supersteps included).
    pub busy_ns: Vec<u64>,
}

/// The deterministic core of one recorded event.  `Debug` is the stable
/// rendering [`FlightRecorder::det_stream`] compares across backends —
/// every field is an integer or an integer vector, so the rendering has
/// no float-formatting hazards.
#[derive(Clone, Debug)]
pub enum EventKind {
    /// One ledger-counted superstep closed on the substrate.  `step` is
    /// the ledger index *after* the step (1-based); the per-machine
    /// vectors are that step's ledger contributions — work units, words
    /// sent/received (self-sends excluded, as in the ledger), and
    /// cross-machine messages sent (unfactored counts, never the
    /// simulator's RPC-factored overhead units).
    Superstep {
        step: u64,
        work: Vec<u64>,
        sent_words: Vec<u64>,
        recv_words: Vec<u64>,
        sent_msgs: Vec<u64>,
    },
    /// A query entered the bounded admission queue; `queue_depth` is the
    /// depth **after** the push (the span's queue-depth-at-admission).
    Admit { tick: u64, query: u64, kind: QueryKind, queue_depth: usize },
    /// A query was shed at the admission cap.
    Reject { tick: u64, query: u64, kind: QueryKind },
    /// A batch's composition was fixed (size-or-deadline policy).
    BatchClose { tick: u64, batch: u64, size: usize, reason: CloseReason },
    /// A member was served from the epoch-keyed result cache at zero
    /// service ticks.
    CacheHit { tick: u64, query: u64, batch: u64, epoch: u64 },
    /// A member missed the cache (or ran with the cache off) and is about
    /// to pay an engine pass at `tick`.
    CacheMiss { tick: u64, query: u64, batch: u64, epoch: u64 },
    /// One engine pass served `lanes` member(s) of `batch` — a fused
    /// multi-source wave when `lanes >= 2`.  `tick` is the dispatch tick;
    /// `service_ticks` the wave's ledger-priced cost.
    WaveDispatch {
        tick: u64,
        batch: u64,
        kind: QueryKind,
        lanes: usize,
        query_ids: Vec<u64>,
        service_ticks: u64,
        epoch: u64,
    },
    /// A query finished (cache hit or wave member) at `tick`.
    QueryComplete { tick: u64, query: u64, wait_ticks: u64, service_ticks: u64, cached: bool },
    /// A mutation batch was absorbed in place, bumping the graph epoch to
    /// `epoch_after` — the epoch-bump event of the stream.
    MutationApply { tick: u64, batch: u64, ops: usize, epoch_after: u64, service_ticks: u64 },
    /// A placement delta (controller round `round`: `moves` whole-block
    /// migrations + `splits` hot-block replications) was applied in
    /// place between dispatches, bumping the graph epoch to
    /// `epoch_after` — one bump per op, so `epoch_after` advances by
    /// `moves + splits` over the previous epoch.
    PlacementApply {
        tick: u64,
        round: u64,
        moves: usize,
        splits: usize,
        epoch_after: u64,
        service_ticks: u64,
    },
}

/// One recorded event: a monotone sequence number (counted across drops),
/// the deterministic core, and the optional wall annotation.
#[derive(Clone, Debug)]
pub struct Event {
    pub seq: u64,
    pub kind: EventKind,
    pub wall: Option<WallNote>,
}

/// A per-query lifecycle derived from the event stream: admitted →
/// batch-closed → wave-dispatched (or cache-hit) → completed.  Stages an
/// overflowed ring no longer holds are `None` — a partial span is honest
/// about what survived.
#[derive(Clone, Debug)]
pub struct Span {
    pub query: u64,
    pub kind: QueryKind,
    pub admitted_tick: Option<u64>,
    /// Queue depth right after this query's admission.
    pub queue_depth_at_admission: Option<usize>,
    /// Batch the query was dispatched in.
    pub batch: Option<u64>,
    pub batch_closed_tick: Option<u64>,
    /// Tick of the wave dispatch (for a cache hit: the hit tick).
    pub dispatched_tick: Option<u64>,
    pub completed_tick: Option<u64>,
    pub wait_ticks: Option<u64>,
    pub service_ticks: Option<u64>,
    pub cached: bool,
    /// Per-machine busy-ns delta of the wave that served this query
    /// (threaded runs only; empty on the simulator and for cache hits).
    pub wave_busy_ns: Vec<u64>,
}

impl Span {
    fn blank(query: u64, kind: QueryKind) -> Self {
        Span {
            query,
            kind,
            admitted_tick: None,
            queue_depth_at_admission: None,
            batch: None,
            batch_closed_tick: None,
            dispatched_tick: None,
            completed_tick: None,
            wait_ticks: None,
            service_ticks: None,
            cached: false,
            wave_busy_ns: Vec::new(),
        }
    }
}

/// The bounded ring-buffer recorder (see the module docs).
pub struct FlightRecorder {
    cap: usize,
    events: VecDeque<Event>,
    /// Next sequence number == total events ever recorded.
    next_seq: u64,
    /// Events evicted by the ring bound (oldest-first).
    dropped: u64,
    /// Per-machine busy ns accumulated from `Superstep` wall annotations
    /// since the last `WaveDispatch` — drained onto that event as its
    /// per-wave busy delta.  Stays empty on the simulator.
    wave_busy: Vec<u64>,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl FlightRecorder {
    pub fn new() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap >= 1, "the recorder needs room for at least one event");
        FlightRecorder {
            cap,
            events: VecDeque::with_capacity(cap.min(1024)),
            next_seq: 0,
            dropped: 0,
            wave_busy: Vec::new(),
        }
    }

    /// A fresh recorder behind the shared handle both the substrate hook
    /// ([`crate::exec::Substrate::set_observer`]) and the server
    /// (`Server::set_recorder`) take.
    pub fn shared(cap: usize) -> ObserverHandle {
        Arc::new(Mutex::new(Self::with_capacity(cap)))
    }

    fn push(&mut self, kind: EventKind, wall: Option<WallNote>) {
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(Event { seq: self.next_seq, kind, wall });
        self.next_seq += 1;
    }

    /// Record a serving-layer event.  A `WaveDispatch` drains the busy
    /// accumulator onto its wall annotation (per-wave busy delta).
    pub fn record(&mut self, kind: EventKind) {
        let wall = match kind {
            EventKind::WaveDispatch { .. } if !self.wave_busy.is_empty() => {
                Some(WallNote { busy_ns: std::mem::take(&mut self.wave_busy) })
            }
            _ => None,
        };
        self.push(kind, wall);
    }

    /// Record one closed ledger superstep — the substrate-side emission
    /// point (`Cluster::barrier`, the pool driver's report fold).
    /// `busy_ns` is the threaded backend's per-machine wall window for
    /// the step; the simulator passes `None`.
    pub fn record_superstep(
        &mut self,
        step: u64,
        work: Vec<u64>,
        sent_words: Vec<u64>,
        recv_words: Vec<u64>,
        sent_msgs: Vec<u64>,
        busy_ns: Option<Vec<u64>>,
    ) {
        if let Some(b) = &busy_ns {
            if self.wave_busy.len() != b.len() {
                self.wave_busy = vec![0; b.len()];
            }
            for (acc, x) in self.wave_busy.iter_mut().zip(b) {
                *acc += *x;
            }
        }
        self.push(
            EventKind::Superstep { step, work, sent_words, recv_words, sent_msgs },
            busy_ns.map(|b| WallNote { busy_ns: b }),
        );
    }

    /// Events currently held (oldest surviving first).
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Total events ever recorded, evicted ones included.
    pub fn recorded(&self) -> u64 {
        self.next_seq
    }

    /// Events evicted by the ring bound — the explicit loss counter.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// The deterministic core stream, one stable line per surviving
    /// event (wall annotations and sequence numbers excluded).  This is
    /// the quantity `repro trace` and `tests/obs_trace.rs` compare
    /// bit-for-bit between backends.
    pub fn det_stream(&self) -> Vec<String> {
        self.events.iter().map(|e| format!("{:?}", e.kind)).collect()
    }

    /// Fold the surviving events into per-query [`Span`]s, in order of
    /// first appearance.
    pub fn query_spans(&self) -> Vec<Span> {
        let mut spans: Vec<Span> = Vec::new();
        let mut by_id: BTreeMap<u64, usize> = BTreeMap::new();
        let mut close_ticks: BTreeMap<u64, u64> = BTreeMap::new();
        let mut slot = |spans: &mut Vec<Span>,
                        by_id: &mut BTreeMap<u64, usize>,
                        query: u64,
                        kind: QueryKind|
         -> usize {
            *by_id.entry(query).or_insert_with(|| {
                spans.push(Span::blank(query, kind));
                spans.len() - 1
            })
        };
        for e in &self.events {
            match &e.kind {
                EventKind::Admit { tick, query, kind, queue_depth } => {
                    let i = slot(&mut spans, &mut by_id, *query, *kind);
                    spans[i].admitted_tick = Some(*tick);
                    spans[i].queue_depth_at_admission = Some(*queue_depth);
                }
                EventKind::BatchClose { tick, batch, .. } => {
                    close_ticks.insert(*batch, *tick);
                }
                EventKind::CacheHit { tick, query, batch, .. } => {
                    // Kind is unknown from the hit alone; the Admit (or
                    // Complete) event for the same id supplies it — a
                    // blank slot here defaults and is overwritten never,
                    // so seed with Bfs only when the id was never seen.
                    let i = slot(&mut spans, &mut by_id, *query, QueryKind::Bfs);
                    spans[i].batch = Some(*batch);
                    spans[i].dispatched_tick = Some(*tick);
                    spans[i].cached = true;
                }
                EventKind::WaveDispatch { tick, batch, kind, query_ids, service_ticks, .. } => {
                    let busy = e.wall.as_ref().map(|w| w.busy_ns.clone()).unwrap_or_default();
                    for id in query_ids {
                        let i = slot(&mut spans, &mut by_id, *id, *kind);
                        spans[i].batch = Some(*batch);
                        spans[i].dispatched_tick = Some(*tick);
                        spans[i].service_ticks = Some(*service_ticks);
                        spans[i].wave_busy_ns = busy.clone();
                    }
                }
                EventKind::QueryComplete { tick, query, wait_ticks, service_ticks, cached } => {
                    let i = slot(&mut spans, &mut by_id, *query, QueryKind::Bfs);
                    spans[i].completed_tick = Some(*tick);
                    spans[i].wait_ticks = Some(*wait_ticks);
                    spans[i].service_ticks = Some(*service_ticks);
                    spans[i].cached = *cached;
                }
                EventKind::Superstep { .. }
                | EventKind::Reject { .. }
                | EventKind::MutationApply { .. }
                | EventKind::PlacementApply { .. } => {}
            }
        }
        for s in &mut spans {
            s.batch_closed_tick = s.batch.and_then(|b| close_ticks.get(&b).copied());
        }
        spans
    }

    /// Discard every event and counter (capacity stays).
    pub fn clear(&mut self) {
        self.events.clear();
        self.next_seq = 0;
        self.dropped = 0;
        self.wave_busy.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn admit(tick: u64, query: u64) -> EventKind {
        EventKind::Admit { tick, query, kind: QueryKind::Bfs, queue_depth: 1 }
    }

    #[test]
    fn ring_keeps_the_newest_and_counts_drops() {
        let mut rec = FlightRecorder::with_capacity(4);
        for i in 0..10 {
            rec.record(admit(i, i));
        }
        assert_eq!(rec.len(), 4);
        assert_eq!(rec.dropped(), 6);
        assert_eq!(rec.recorded(), 10);
        let seqs: Vec<u64> = rec.events().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9], "newest events survive, seq counted across drops");
    }

    #[test]
    fn det_stream_excludes_wall_annotations() {
        let mut a = FlightRecorder::new();
        let mut b = FlightRecorder::new();
        // Same deterministic core, one with a wall note (threaded), one
        // without (sim): the rendered streams must still match.
        a.record_superstep(1, vec![3, 0], vec![4, 0], vec![0, 4], vec![1, 0], None);
        b.record_superstep(1, vec![3, 0], vec![4, 0], vec![0, 4], vec![1, 0], Some(vec![9, 7]));
        assert_eq!(a.det_stream(), b.det_stream());
        assert!(a.events().next().unwrap().wall.is_none());
        assert_eq!(b.events().next().unwrap().wall.as_ref().unwrap().busy_ns, vec![9, 7]);
    }

    #[test]
    fn wave_dispatch_drains_busy_deltas_since_last_dispatch() {
        let mut rec = FlightRecorder::new();
        rec.record_superstep(1, vec![1, 1], vec![0, 0], vec![0, 0], vec![0, 0], Some(vec![5, 2]));
        rec.record_superstep(2, vec![1, 1], vec![0, 0], vec![0, 0], vec![0, 0], Some(vec![1, 3]));
        rec.record(EventKind::WaveDispatch {
            tick: 0,
            batch: 0,
            kind: QueryKind::Bfs,
            lanes: 1,
            query_ids: vec![0],
            service_ticks: 1,
            epoch: 0,
        });
        let wave = rec.events().last().unwrap();
        assert_eq!(wave.wall.as_ref().unwrap().busy_ns, vec![6, 5]);
        // The accumulator was drained: a second dispatch with no steps
        // in between carries no annotation.
        rec.record(EventKind::WaveDispatch {
            tick: 1,
            batch: 0,
            kind: QueryKind::Bfs,
            lanes: 1,
            query_ids: vec![1],
            service_ticks: 1,
            epoch: 0,
        });
        assert!(rec.events().last().unwrap().wall.is_none());
    }

    #[test]
    fn spans_assemble_the_lifecycle() {
        let mut rec = FlightRecorder::new();
        rec.record(EventKind::Admit { tick: 2, query: 7, kind: QueryKind::Sssp, queue_depth: 3 });
        rec.record(EventKind::BatchClose {
            tick: 4,
            batch: 1,
            size: 1,
            reason: CloseReason::Overdue,
        });
        rec.record(EventKind::WaveDispatch {
            tick: 5,
            batch: 1,
            kind: QueryKind::Sssp,
            lanes: 1,
            query_ids: vec![7],
            service_ticks: 2,
            epoch: 0,
        });
        rec.record(EventKind::QueryComplete {
            tick: 7,
            query: 7,
            wait_ticks: 3,
            service_ticks: 2,
            cached: false,
        });
        let spans = rec.query_spans();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        assert_eq!(s.query, 7);
        assert_eq!(s.kind, QueryKind::Sssp);
        assert_eq!(s.admitted_tick, Some(2));
        assert_eq!(s.queue_depth_at_admission, Some(3));
        assert_eq!(s.batch, Some(1));
        assert_eq!(s.batch_closed_tick, Some(4));
        assert_eq!(s.dispatched_tick, Some(5));
        assert_eq!(s.completed_tick, Some(7));
        assert_eq!((s.wait_ticks, s.service_ticks), (Some(3), Some(2)));
        assert!(!s.cached);
    }

    #[test]
    fn clear_resets_everything_but_capacity() {
        let mut rec = FlightRecorder::with_capacity(2);
        rec.record(admit(0, 0));
        rec.record(admit(1, 1));
        rec.record(admit(2, 2));
        assert_eq!(rec.dropped(), 1);
        rec.clear();
        assert!(rec.is_empty());
        assert_eq!(rec.dropped(), 0);
        assert_eq!(rec.recorded(), 0);
        assert_eq!(rec.capacity(), 2);
    }
}
