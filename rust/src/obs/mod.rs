//! `obs/` — the deterministic flight recorder.
//!
//! Observability here is another *enforceable* correctness surface, not
//! best-effort logging: every recorded event splits into a deterministic
//! core (logical clocks + ledger quantities, bit-identical between the
//! simulator and the threaded pool at every P) and an optional
//! wall-clock annotation (threaded only, never compared).  See
//! [`trace`] for the event model and ring-buffer recorder, [`export`]
//! for Chrome-trace JSON / heatmap rendering and the divergence probe
//! the `repro trace` CI gate is built on.

pub mod export;
pub mod trace;

pub use export::{chrome_trace_json, first_divergence, heatmap_table};
pub use trace::{CloseReason, Event, EventKind, FlightRecorder, ObserverHandle, Span, WallNote};
