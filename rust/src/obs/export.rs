//! Exports for the flight recorder: Chrome trace-event JSON (load the
//! file in `chrome://tracing` or <https://ui.perfetto.dev>), a
//! per-(superstep, machine) work/words heatmap table, and the
//! divergence probe `repro trace` gates on.
//!
//! JSON is hand-rolled like every other report in this crate — the
//! trace-event format is flat arrays of small objects, well within
//! `format!` territory.

use std::fmt::Write as _;

use crate::metrics::Metrics;
use crate::obs::trace::{EventKind, FlightRecorder};

/// Synthesized timeline unit for simulator runs, where a superstep has
/// no wall width: each ledger step gets at least this many "µs" of lane
/// width so the track stays readable.
const MIN_STEP_US: u64 = 1;

fn push_args_u64s(out: &mut String, pairs: &[(&str, u64)]) {
    out.push('{');
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "\"{}\":{}", k, v);
    }
    out.push('}');
}

/// Render the recorder as Chrome trace-event JSON:
///
/// * **pid 0** — one track (tid) per machine; every ledger superstep is
///   a complete (`"ph":"X"`) slice.  On threaded runs the slice width is
///   the machine's measured busy time for the step (ns → µs); simulator
///   runs synthesize width from ledger work units so the deterministic
///   trace still has visual shape.  Slice `args` carry the deterministic
///   per-machine ledger quantities.
/// * **pid 1** — the query-span track: one slice per query from
///   admission tick to completion tick (logical-clock units), with kind,
///   batch, queue depth at admission, and cache status in `args`.
///
/// Machine slices advance on a common cursor (steps are globally ordered
/// barriers), so skew within a step shows up as ragged slice widths
/// under one aligned start — exactly the hotspot picture the ROADMAP's
/// adaptive-placement work needs.
pub fn chrome_trace_json(rec: &FlightRecorder) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut emit = |line: String, out: &mut String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        *first = false;
        out.push_str(&line);
    };

    let mut machines = 0usize;
    let mut cursor: u64 = 0;
    for e in rec.events() {
        if let EventKind::Superstep { step, work, sent_words, recv_words, sent_msgs } = &e.kind {
            machines = machines.max(work.len());
            let busy = e.wall.as_ref().map(|w| &w.busy_ns);
            let mut widest = MIN_STEP_US;
            for m in 0..work.len() {
                let dur = match busy {
                    Some(b) => (b.get(m).copied().unwrap_or(0) / 1_000).max(MIN_STEP_US),
                    None => work[m].max(MIN_STEP_US),
                };
                widest = widest.max(dur);
                let mut line = format!(
                    "{{\"name\":\"step {}\",\"ph\":\"X\",\"pid\":0,\"tid\":{},\"ts\":{},\"dur\":{},\"args\":",
                    step, m, cursor, dur
                );
                push_args_u64s(
                    &mut line,
                    &[
                        ("work", work[m]),
                        ("sent_words", sent_words[m]),
                        ("recv_words", recv_words[m]),
                        ("sent_msgs", sent_msgs[m]),
                    ],
                );
                line.push('}');
                emit(line, &mut out, &mut first);
            }
            cursor += widest;
        }
    }

    for s in rec.query_spans() {
        let (Some(adm), Some(done)) = (s.admitted_tick, s.completed_tick) else {
            continue; // overflowed ring: a partial span has no slice.
        };
        let mut line = format!(
            "{{\"name\":\"{} q{}\",\"ph\":\"X\",\"pid\":1,\"tid\":0,\"ts\":{},\"dur\":{},\"args\":",
            s.kind.label(),
            s.query,
            adm,
            done.saturating_sub(adm).max(1)
        );
        push_args_u64s(
            &mut line,
            &[
                ("query", s.query),
                ("batch", s.batch.unwrap_or(0)),
                ("queue_depth_at_admission", s.queue_depth_at_admission.unwrap_or(0) as u64),
                ("wait_ticks", s.wait_ticks.unwrap_or(0)),
                ("service_ticks", s.service_ticks.unwrap_or(0)),
                ("cached", u64::from(s.cached)),
            ],
        );
        line.push('}');
        emit(line, &mut out, &mut first);
    }

    emit(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":0,\"args\":{\"name\":\"machines\"}}"
            .to_string(),
        &mut out,
        &mut first,
    );
    emit(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"args\":{\"name\":\"queries\"}}"
            .to_string(),
        &mut out,
        &mut first,
    );
    for m in 0..machines {
        emit(
            format!(
                "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":0,\"tid\":{},\"args\":{{\"name\":\"machine {}\"}}}}",
                m, m
            ),
            &mut out,
            &mut first,
        );
    }
    emit(
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\"args\":{\"name\":\"query spans\"}}"
            .to_string(),
        &mut out,
        &mut first,
    );

    out.push_str("\n]}\n");
    out
}

/// Render the per-(superstep, machine) heatmap: one row per recorded
/// ledger superstep, one `work/sent_words` cell per machine, and the
/// step's work-imbalance factor (max/mean — [`Metrics::step_imbalance`])
/// in the last column.  This is the table `repro trace` writes next to
/// the Chrome JSON and previews on stdout.
pub fn heatmap_table(rec: &FlightRecorder) -> String {
    let machines = rec
        .events()
        .filter_map(|e| match &e.kind {
            EventKind::Superstep { work, .. } => Some(work.len()),
            _ => None,
        })
        .max()
        .unwrap_or(0);
    let mut out = String::new();
    let _ = write!(out, "{:>6}", "step");
    for m in 0..machines {
        let _ = write!(out, "  {:>14}", format!("m{} work/words", m));
    }
    let _ = writeln!(out, "  {:>9}", "imbalance");
    for e in rec.events() {
        if let EventKind::Superstep { step, work, sent_words, .. } = &e.kind {
            let _ = write!(out, "{:>6}", step);
            for m in 0..machines {
                let cell = format!(
                    "{}/{}",
                    work.get(m).copied().unwrap_or(0),
                    sent_words.get(m).copied().unwrap_or(0)
                );
                let _ = write!(out, "  {:>14}", cell);
            }
            let _ = writeln!(out, "  {:>9.3}", Metrics::step_imbalance(work));
        }
    }
    out
}

/// First index where the two deterministic streams disagree, with both
/// sides' lines (`"<end>"` for an exhausted stream).  `None` means the
/// streams are bit-identical — the property `repro trace` gates on.
pub fn first_divergence(a: &[String], b: &[String]) -> Option<(usize, String, String)> {
    let n = a.len().max(b.len());
    for i in 0..n {
        let la = a.get(i).map(String::as_str).unwrap_or("<end>");
        let lb = b.get(i).map(String::as_str).unwrap_or("<end>");
        if la != lb {
            return Some((i, la.to_string(), lb.to_string()));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{CloseReason, EventKind, FlightRecorder};
    use crate::workload::QueryKind;

    fn sample_recorder() -> FlightRecorder {
        let mut rec = FlightRecorder::new();
        rec.record(EventKind::Admit { tick: 0, query: 0, kind: QueryKind::Bfs, queue_depth: 1 });
        rec.record(EventKind::BatchClose { tick: 1, batch: 0, size: 1, reason: CloseReason::Drain });
        rec.record_superstep(1, vec![5, 2], vec![8, 0], vec![0, 8], vec![2, 0], None);
        rec.record(EventKind::WaveDispatch {
            tick: 1,
            batch: 0,
            kind: QueryKind::Bfs,
            lanes: 1,
            query_ids: vec![0],
            service_ticks: 1,
            epoch: 0,
        });
        rec.record(EventKind::QueryComplete {
            tick: 2,
            query: 0,
            wait_ticks: 1,
            service_ticks: 1,
            cached: false,
        });
        rec
    }

    #[test]
    fn chrome_trace_has_machine_and_span_tracks() {
        let json = chrome_trace_json(&sample_recorder());
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        assert!(json.contains("\"name\":\"step 1\""));
        assert!(json.contains("\"pid\":0,\"tid\":1"), "one track per machine");
        assert!(json.contains("\"name\":\"BFS q0\""), "query-span slice present");
        assert!(json.contains("\"name\":\"machine 0\""));
        assert!(json.contains("\"name\":\"query spans\""));
        assert!(json.contains("\"work\":5"));
    }

    #[test]
    fn sim_slices_synthesize_width_from_work_units() {
        let json = chrome_trace_json(&sample_recorder());
        // machine 0 did 5 work units → dur 5; machine 1 did 2 → dur 2.
        assert!(json.contains("\"tid\":0,\"ts\":0,\"dur\":5"));
        assert!(json.contains("\"tid\":1,\"ts\":0,\"dur\":2"));
    }

    #[test]
    fn threaded_slices_use_busy_ns() {
        let mut rec = FlightRecorder::new();
        rec.record_superstep(1, vec![5, 2], vec![0, 0], vec![0, 0], vec![0, 0], Some(vec![9_000, 4_000]));
        let json = chrome_trace_json(&rec);
        assert!(json.contains("\"tid\":0,\"ts\":0,\"dur\":9"));
        assert!(json.contains("\"tid\":1,\"ts\":0,\"dur\":4"));
    }

    #[test]
    fn heatmap_rows_carry_work_words_and_imbalance() {
        let table = heatmap_table(&sample_recorder());
        let mut lines = table.lines();
        let header = lines.next().unwrap();
        assert!(header.contains("m0 work/words"));
        assert!(header.contains("imbalance"));
        let row = lines.next().unwrap();
        assert!(row.contains("5/8"));
        assert!(row.contains("2/0"));
        // max 5 over mean 3.5 = 1.429 (work imbalance for the step).
        assert!(row.contains("1.429"));
    }

    #[test]
    fn first_divergence_reports_index_and_both_sides() {
        let a: Vec<String> = ["x", "y", "z"].iter().map(|s| s.to_string()).collect();
        let same = a.clone();
        assert!(first_divergence(&a, &same).is_none());
        let b: Vec<String> = ["x", "q", "z"].iter().map(|s| s.to_string()).collect();
        assert_eq!(first_divergence(&a, &b), Some((1, "y".to_string(), "q".to_string())));
        let short: Vec<String> = ["x"].iter().map(|s| s.to_string()).collect();
        assert_eq!(
            first_divergence(&a, &short),
            Some((1, "y".to_string(), "<end>".to_string()))
        );
    }
}
