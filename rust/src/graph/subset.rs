//! `DistVertexSubset` — the distributed frontier (paper §5, D.2).
//!
//! One `VertexSubset` per machine, each independently switching between a
//! sparse representation (vertex list — the paper upgrades Ligra's array
//! to a phase-concurrent hash table; here a sorted vec with the same
//! asymptotics in a sequential simulator) and a dense representation
//! (bitmap — the paper's concurrent-bitmap improvement, T2).

use super::{VertexPart, Vid};

/// Per-machine representation.
#[derive(Clone, Debug)]
enum Rep {
    Sparse(Vec<Vid>),
    Dense { bits: Vec<u64>, base: Vid, count: usize },
}

/// A subset of vertices distributed across machines.
#[derive(Clone, Debug)]
pub struct DistVertexSubset {
    reps: Vec<Rep>,
    len: usize,
}

/// Switch a machine's rep to dense above this activation fraction.
const DENSE_FRAC: f64 = 0.125;

impl DistVertexSubset {
    pub fn empty(part: &VertexPart) -> Self {
        DistVertexSubset {
            reps: (0..part.p()).map(|_| Rep::Sparse(Vec::new())).collect(),
            len: 0,
        }
    }

    pub fn single(part: &VertexPart, v: Vid) -> Self {
        let mut s = Self::empty(part);
        s.insert(part, v);
        s
    }

    pub fn all(part: &VertexPart) -> Self {
        let mut s = Self::empty(part);
        for m in 0..part.p() {
            let range = part.range(m);
            let base = range.start;
            let n_local = (range.end - range.start) as usize;
            let mut bits = vec![u64::MAX; n_local.div_ceil(64)];
            // Clear tail bits.
            if n_local % 64 != 0 {
                if let Some(last) = bits.last_mut() {
                    *last = (1u64 << (n_local % 64)) - 1;
                }
            }
            if n_local == 0 {
                bits.clear();
            }
            s.reps[m] = Rep::Dense { bits, base, count: n_local };
            s.len += n_local;
        }
        s
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert `v` (idempotent).  Machine-local rep upgrades to dense when
    /// it crosses `DENSE_FRAC` of its range.
    pub fn insert(&mut self, part: &VertexPart, v: Vid) {
        let m = part.owner(v);
        let range = part.range(m);
        let n_local = (range.end - range.start) as usize;
        match &mut self.reps[m] {
            Rep::Sparse(list) => {
                if list.contains(&v) {
                    return;
                }
                list.push(v);
                self.len += 1;
                if n_local > 0 && (list.len() as f64) > DENSE_FRAC * n_local as f64 {
                    // Upgrade to bitmap.
                    let mut bits = vec![0u64; n_local.div_ceil(64)];
                    let mut count = 0;
                    for &u in list.iter() {
                        let off = (u - range.start) as usize;
                        if bits[off / 64] & (1 << (off % 64)) == 0 {
                            bits[off / 64] |= 1 << (off % 64);
                            count += 1;
                        }
                    }
                    self.reps[m] = Rep::Dense { bits, base: range.start, count };
                }
            }
            Rep::Dense { bits, base, count } => {
                let off = (v - *base) as usize;
                if bits[off / 64] & (1 << (off % 64)) == 0 {
                    bits[off / 64] |= 1 << (off % 64);
                    *count += 1;
                    self.len += 1;
                }
            }
        }
    }

    pub fn contains(&self, part: &VertexPart, v: Vid) -> bool {
        let m = part.owner(v);
        match &self.reps[m] {
            Rep::Sparse(list) => list.contains(&v),
            Rep::Dense { bits, base, .. } => {
                let off = (v - *base) as usize;
                bits[off / 64] & (1 << (off % 64)) != 0
            }
        }
    }

    /// Number of active vertices on machine `m`.
    pub fn len_on(&self, m: usize) -> usize {
        match &self.reps[m] {
            Rep::Sparse(list) => list.len(),
            Rep::Dense { count, .. } => *count,
        }
    }

    /// Iterate active vertices on machine `m` in ascending order.
    pub fn iter_on(&self, m: usize) -> Vec<Vid> {
        match &self.reps[m] {
            Rep::Sparse(list) => {
                let mut v = list.clone();
                v.sort_unstable();
                v
            }
            Rep::Dense { bits, base, .. } => {
                let mut out = Vec::new();
                for (w, word) in bits.iter().enumerate() {
                    let mut bitsw = *word;
                    while bitsw != 0 {
                        let b = bitsw.trailing_zeros();
                        out.push(base + (w * 64) as Vid + b as Vid);
                        bitsw &= bitsw - 1;
                    }
                }
                out
            }
        }
    }

    /// All active vertices across machines (ascending within machine).
    pub fn iter_all(&self, part: &VertexPart) -> Vec<Vid> {
        (0..part.p()).flat_map(|m| self.iter_on(m)).collect()
    }

    /// True if machine m's rep is dense (for accounting/debug).
    pub fn is_dense_on(&self, m: usize) -> bool {
        matches!(self.reps[m], Rep::Dense { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, VertexPart};

    fn part(n: usize, p: usize) -> VertexPart {
        let g = Graph::from_arcs(n, vec![]);
        VertexPart::degree_balanced(&g, p)
    }

    #[test]
    fn insert_idempotent() {
        let part = part(100, 4);
        let mut s = DistVertexSubset::empty(&part);
        s.insert(&part, 5);
        s.insert(&part, 5);
        assert_eq!(s.len(), 1);
        assert!(s.contains(&part, 5));
        assert!(!s.contains(&part, 6));
    }

    #[test]
    fn all_has_every_vertex() {
        let part = part(130, 4);
        let s = DistVertexSubset::all(&part);
        assert_eq!(s.len(), 130);
        for v in 0..130u32 {
            assert!(s.contains(&part, v), "missing {v}");
        }
        assert_eq!(s.iter_all(&part).len(), 130);
    }

    #[test]
    fn upgrade_to_dense_preserves_members() {
        let part = part(256, 2);
        let mut s = DistVertexSubset::empty(&part);
        let members: Vec<Vid> = (0..100).map(|i| i * 2).collect();
        for &v in &members {
            s.insert(&part, v);
        }
        assert_eq!(s.len(), 100);
        for &v in &members {
            assert!(s.contains(&part, v));
        }
        let mut all = s.iter_all(&part);
        all.sort_unstable();
        assert_eq!(all, members);
    }

    #[test]
    fn single_and_empty() {
        let part = part(10, 3);
        assert!(DistVertexSubset::empty(&part).is_empty());
        let s = DistVertexSubset::single(&part, 7);
        assert_eq!(s.len(), 1);
        assert_eq!(s.iter_all(&part), vec![7]);
    }

    #[test]
    fn per_machine_counts_sum() {
        let part = part(1000, 8);
        let mut s = DistVertexSubset::empty(&part);
        for v in (0..1000).step_by(3) {
            s.insert(&part, v);
        }
        let total: usize = (0..8).map(|m| s.len_on(m)).sum();
        assert_eq!(total, s.len());
    }
}
