//! TDO-GP — distributed graph processing on TD-Orch (paper §5).
//!
//! Submodules:
//! * [`gen`] — synthetic dataset generators standing in for the paper's
//!   datasets (see DESIGN.md §2 substitution ledger).
//! * [`ingest`] — ingestion-time orchestration: degree-balanced vertex
//!   pinning, edge-block placement (transit machines for hot vertices),
//!   source/destination communication trees.
//! * [`flags`] — the policy matrix: one [`flags::Flags`] block selects
//!   TDO-GP vs each baseline family and carries the T1/T2/T3 ablation
//!   knobs.
//! * [`layout`] — flat machine-local storage for the engine's hot paths:
//!   dirty-listed f64 slabs (plus the fused lane variant), the CSR-style
//!   block index, and the sparse/dense frontier with its deterministic
//!   occupancy switch.
//! * [`spmd`] — THE engine: the `DistEdgeMap` round (paper §5.1, Fig 6)
//!   in SPMD form over [`crate::exec::Substrate`] — machine-private
//!   shards, real value-carrying messages, sparse-dense dual-mode
//!   execution, flag-selected policies.  On [`crate::bsp::Cluster`] it
//!   produces the simulated-cost ledger behind every paper figure; on
//!   [`crate::exec::ThreadedCluster`] it produces measured wall-clock —
//!   bit-identically.
//! * [`algorithms`] — BFS, SSSP, BC, CC, PR, each one shard type + one
//!   runner against the unified engine.
//! * [`baselines`] — gemini-like, linear-algebra-like, ligra-dist
//!   constructors (flags + placement presets of the same engine).

pub mod algorithms;
pub mod baselines;
pub mod flags;
pub mod gen;
pub mod ingest;
pub mod layout;
pub mod spmd;

use crate::bsp::MachineId;

/// Vertex id.
pub type Vid = u32;

/// An input graph in CSR form.  All generators emit *symmetric* graphs
/// (each undirected edge stored as two directed arcs), matching how the
/// paper's systems ingest their datasets.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n: usize,
    /// CSR row offsets, length n+1.
    pub offsets: Vec<u64>,
    /// CSR adjacency (target, weight).
    pub edges: Vec<(Vid, f32)>,
}

impl Graph {
    /// Build from an arc list (deduplicated, self-loops dropped).
    pub fn from_arcs(n: usize, mut arcs: Vec<(Vid, Vid, f32)>) -> Self {
        arcs.retain(|(u, v, _)| u != v && (*u as usize) < n && (*v as usize) < n);
        arcs.sort_unstable_by_key(|(u, v, _)| ((*u as u64) << 32) | *v as u64);
        arcs.dedup_by_key(|(u, v, _)| (*u, *v));
        let mut offsets = vec![0u64; n + 1];
        for (u, _, _) in &arcs {
            offsets[*u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let edges = arcs.into_iter().map(|(_, v, w)| (v, w)).collect();
        Graph { n, offsets, edges }
    }

    pub fn m(&self) -> usize {
        self.edges.len()
    }

    #[inline]
    pub fn out_degree(&self, u: Vid) -> u64 {
        self.offsets[u as usize + 1] - self.offsets[u as usize]
    }

    #[inline]
    pub fn neighbors(&self, u: Vid) -> &[(Vid, f32)] {
        &self.edges[self.offsets[u as usize] as usize..self.offsets[u as usize + 1] as usize]
    }

    /// Max out-degree (skew indicator).
    pub fn max_degree(&self) -> u64 {
        (0..self.n as Vid).map(|u| self.out_degree(u)).max().unwrap_or(0)
    }
}

/// Degree-balanced contiguous vertex partition (paper D.3: "the total
/// number of outgoing edges assigned to each machine is approximately
/// equal").
#[derive(Clone, Debug)]
pub struct VertexPart {
    /// boundaries[i]..boundaries[i+1] = vertices of machine i.
    pub boundaries: Vec<Vid>,
}

impl VertexPart {
    /// Split `g`'s vertices into `p` contiguous ranges of ~equal total
    /// out-degree (each vertex also counts 1 so isolated vertices spread).
    pub fn degree_balanced(g: &Graph, p: usize) -> Self {
        let total: u64 = g.m() as u64 + g.n as u64;
        let per = total.div_ceil(p as u64).max(1);
        let mut boundaries = Vec::with_capacity(p + 1);
        boundaries.push(0);
        let mut acc = 0u64;
        for u in 0..g.n as Vid {
            acc += g.out_degree(u) + 1;
            if acc >= per && boundaries.len() < p {
                boundaries.push(u + 1);
                acc = 0;
            }
        }
        while boundaries.len() < p {
            boundaries.push(g.n as Vid);
        }
        boundaries.push(g.n as Vid);
        VertexPart { boundaries }
    }

    pub fn p(&self) -> usize {
        self.boundaries.len() - 1
    }

    #[inline]
    pub fn owner(&self, v: Vid) -> MachineId {
        // Contiguous ranges: binary search the boundary array.
        match self.boundaries.binary_search(&v) {
            Ok(i) => i.min(self.p() - 1),
            Err(i) => i - 1,
        }
    }

    pub fn range(&self, m: MachineId) -> std::ops::Range<Vid> {
        self.boundaries[m]..self.boundaries[m + 1]
    }

    pub fn count_on(&self, m: MachineId) -> usize {
        (self.boundaries[m + 1] - self.boundaries[m]) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph(n: usize) -> Graph {
        let mut arcs = Vec::new();
        for u in 0..n as Vid - 1 {
            arcs.push((u, u + 1, 1.0));
            arcs.push((u + 1, u, 1.0));
        }
        Graph::from_arcs(n, arcs)
    }

    #[test]
    fn csr_roundtrip() {
        let g = Graph::from_arcs(4, vec![(0, 1, 1.0), (1, 2, 2.0), (0, 3, 3.0), (0, 1, 9.0)]);
        assert_eq!(g.m(), 3); // duplicate (0,1) dropped
        assert_eq!(g.out_degree(0), 2);
        assert_eq!(g.neighbors(1), &[(2, 2.0)]);
        assert_eq!(g.out_degree(3), 0);
    }

    #[test]
    fn self_loops_dropped() {
        let g = Graph::from_arcs(3, vec![(0, 0, 1.0), (0, 1, 1.0)]);
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn partition_covers_all_vertices() {
        let g = path_graph(100);
        for p in [1, 3, 8, 16] {
            let part = VertexPart::degree_balanced(&g, p);
            assert_eq!(part.p(), p);
            let total: usize = (0..p).map(|m| part.count_on(m)).sum();
            assert_eq!(total, 100);
            for v in 0..100u32 {
                let m = part.owner(v);
                assert!(part.range(m).contains(&v), "v={v} m={m}");
            }
        }
    }

    #[test]
    fn partition_balances_degree() {
        // A graph with one huge-degree vertex still yields ranges whose
        // edge totals differ by at most ~the hub degree.
        let mut arcs = Vec::new();
        for v in 1..1000u32 {
            arcs.push((0, v, 1.0));
            arcs.push((v, 0, 1.0));
        }
        let g = Graph::from_arcs(1000, arcs);
        let part = VertexPart::degree_balanced(&g, 4);
        // Machine 0 gets the hub and little else.
        assert!(part.count_on(0) < 400);
    }

    #[test]
    fn owner_boundaries_exact() {
        let g = path_graph(10);
        let part = VertexPart::degree_balanced(&g, 2);
        let b = part.boundaries[1];
        if b > 0 && (b as usize) < 10 {
            assert_eq!(part.owner(b - 1), 0);
            assert_eq!(part.owner(b), 1);
        }
    }
}
