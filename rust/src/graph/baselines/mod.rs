//! Prior-system baseline engines (paper §6.1), expressed as policy
//! configurations of the ONE unified engine core
//! ([`crate::graph::spmd::SpmdEngine`]) so comparisons isolate the
//! scheduling/layout differences the paper attributes its wins to:
//!
//! * [`gemini_like`] — the graph-algorithm family (Gemini): edges pinned
//!   to their source's owner (mirror-style direct exchange, hubs
//!   concentrate), per-round Θ(n/P) vertex-array work (the O(n·diam)
//!   term), no transit trees.
//! * [`la_like`] — the linear-algebra family (Graphite/LA3): full SpMV
//!   scan every round regardless of frontier sparsity.
//! * [`ligra_dist`] — Table 3's prototype: Ligra semantics + direct pull,
//!   per-edge RPC contribution messages, no TD-Orch ingestion or trees.
//!
//! Each helper is generic over the execution substrate, like the engine
//! itself: hand it a [`crate::bsp::Cluster`] for the simulated-cost
//! figure paths or a [`crate::exec::ThreadedCluster`] to run the same
//! baseline on the real worker pool.

use crate::bsp::MachineId;
use crate::exec::Substrate;
use crate::graph::flags::Flags;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Graph;
use crate::CostModel;

pub fn gemini_like<B: Substrate, AS: Send>(
    sub: B,
    g: &Graph,
    cost: CostModel,
    init: impl Fn(MachineId, &GraphMeta) -> AS,
) -> SpmdEngine<B, AS> {
    SpmdEngine::baseline(sub, g, cost, Flags::gemini_like(), "gemini-like", init)
}

pub fn la_like<B: Substrate, AS: Send>(
    sub: B,
    g: &Graph,
    cost: CostModel,
    init: impl Fn(MachineId, &GraphMeta) -> AS,
) -> SpmdEngine<B, AS> {
    SpmdEngine::baseline(sub, g, cost, Flags::la_like(), "la-like", init)
}

pub fn ligra_dist<B: Substrate, AS: Send>(
    sub: B,
    g: &Graph,
    cost: CostModel,
    init: impl Fn(MachineId, &GraphMeta) -> AS,
) -> SpmdEngine<B, AS> {
    SpmdEngine::baseline(sub, g, cost, Flags::ligra_dist(), "ligra-dist", init)
}
