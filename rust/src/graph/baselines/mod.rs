//! Prior-system baseline engines (paper §6.1), reimplemented as policy
//! configurations of the shared engine core so comparisons isolate the
//! scheduling/layout differences the paper attributes its wins to:
//!
//! * [`gemini_like`] — the graph-algorithm family (Gemini): edges pinned
//!   to their source's owner (mirror-style direct exchange, hubs
//!   concentrate), per-round Θ(n/P) vertex-array work (the O(n·diam)
//!   term), no transit trees.
//! * [`la_like`] — the linear-algebra family (Graphite/LA3): full SpMV
//!   scan every round regardless of frontier sparsity.
//! * [`ligra_dist`] — Table 3's prototype: Ligra semantics + direct pull,
//!   per-edge contribution messages, no TD-Orch ingestion or trees.

use crate::graph::engine::{Engine, Flags};
use crate::graph::Graph;
use crate::CostModel;

pub fn gemini_like(g: &Graph, p: usize, cost: CostModel) -> Engine {
    Engine::baseline(g, p, cost, Flags::gemini_like(), "gemini-like")
}

pub fn la_like(g: &Graph, p: usize, cost: CostModel) -> Engine {
    Engine::baseline(g, p, cost, Flags::la_like(), "la-like")
}

pub fn ligra_dist(g: &Graph, p: usize, cost: CostModel) -> Engine {
    Engine::baseline(g, p, cost, Flags::ligra_dist(), "ligra-dist")
}
