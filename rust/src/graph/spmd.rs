//! SPMD `DistEdgeMap`: THE TDO-GP engine, on the [`Substrate`] trait.
//!
//! One engine core implements the read→execute→merge→write-back round
//! (paper §5.1, Fig 6); a [`Flags`] block selects between TDO-GP
//! (source/destination trees, per-machine pre-merge, destination-aware
//! broadcast, sparse-dense switching) and the baseline families'
//! policies (direct exchange, per-edge RPC messages, full scans,
//! per-round vertex-array overheads).  Every paper figure and the
//! threaded runtime/serving paths run THIS engine — the figure paths on
//! [`crate::bsp::Cluster`] (simulated-cost ledger), the runtime on
//! [`crate::exec::ThreadedCluster`] (measured wall-clock) — so §6's
//! comparisons are structural: one engine, one substrate API, one
//! metrics ledger.  (Its accounting-only cost-model predecessor, which
//! duplicated every algorithm, is retired.)  The round is SPMD
//! throughout:
//!
//! * every machine owns a **shard** — its edge blocks, its slice of the
//!   algorithm's vertex state, its slice of the frontier — handed to the
//!   substrate's per-machine workers through `&mut` exactly like the
//!   `DistStore::take_maps`/`put_maps` pattern of the orchestration
//!   stages (shared-nothing by construction);
//! * source values, contributions and tree partials travel as **real
//!   messages** with the wire sizes the cost model charges
//!   ([`VAL_WORDS`], [`CONTRIB_WORDS`]);
//! * the driver thread orchestrates *between* supersteps only: it picks
//!   sparse/dense mode from per-shard frontier stats, sizes the
//!   level-synchronous tree phases, and gathers results — never touching
//!   shard state while a superstep runs.
//!
//! Because one generic implementation serves both backends, running on
//! [`crate::bsp::Cluster`] yields the familiar simulated ledger while
//! running on [`crate::exec::ThreadedCluster`]'s persistent worker pool
//! yields measured wall-clock — with **bit-identical results**, which is
//! the determinism contract `tests/graph_exec_equivalence.rs` pins down:
//!
//! 1. For a fixed (graph, flags, P): simulator and threaded runs produce
//!    identical bits, because payloads are delivered in (sender,
//!    emission-index) order on both backends and every fold in this file
//!    iterates in sorted-key or delivery order — never in hash-map order.
//! 2. For exact merge operators (`min`, first-writer: BFS/SSSP/CC), the
//!    results are additionally bit-identical to a single-machine
//!    reference at **every** P, since `min` over the same candidate set
//!    is order-insensitive.
//! 3. For rounding merge operators (`+` in PageRank and in BC's σ/δ
//!    folds), the fold grouping is part of the bits: PageRank at P=1
//!    matches a reference that folds in-edge contributions in ascending
//!    source order; P>1 (and BC, whose Brandes reference accumulates in
//!    BFS-queue order) regroups the same f64 sums by shard/tree, so it
//!    agrees with the reference only to rounding (still bit-identical
//!    across backends and across repeated runs — contract 1 is
//!    unconditional).
//!
//! The engine is built to be **long-lived**: [`ingest_once`] +
//! [`SpmdEngine::from_ingested`] separate the one-time placement pass
//! from engine construction, and [`SpmdEngine::reset_for_query`]
//! re-initializes the algorithm shards in place (keeping blocks, trees
//! and the worker pool) so the serving layer ([`crate::serve`]) can run
//! an online query stream with exactly one ingestion per process.
//!
//! Tree aggregation uses [`relay_tree_levels`], whose machine-unique
//! -position invariant matters because partials here are real values: a
//! machine holding two positions in one level would double-send its
//! merged partial.

use std::sync::Arc;

use crate::bsp::{Cluster, MachineId, RPC_MSG_FACTOR};
use crate::exec::{no_messages, nothing_words, MachineAcct, Nothing, Substrate};
use crate::mutate::{self, DeltaNote, EdgeOp, MutationBatch};
use crate::CostModel;

use super::flags::{Flags, CONTRIB_WORDS, DENSE_DIV, VAL_WORDS};
use super::ingest::{ingest, ingest_at_owner, relay_tree_levels, DistGraph, EdgeBlock};
use super::layout::{BlockIndex, Frontier, LaneSlab, Slab};
use super::{Graph, VertexPart, Vid};

/// Run the ingestion pass once for a P-machine deployment (on a scratch
/// simulator cluster — the paper times queries, not loading) with the
/// default tree fanout.  The serving layer calls this ONE time per
/// process and builds every engine it needs — the serving engine and the
/// sim cross-check reference — from clones of the result via
/// [`SpmdEngine::from_ingested`], which is how `repro serve` keeps
/// `ingest::ingestions() == 1` however many queries run.
pub fn ingest_once(g: &Graph, p: usize, cost: CostModel, placement: Placement) -> DistGraph {
    let c = crate::forest::Forest::default_fanout(p).max(4);
    let mut scratch = Cluster::new(p, cost);
    match placement {
        Placement::Spread => ingest(&mut scratch, g, c),
        Placement::AtOwner => ingest_at_owner(&mut scratch, g, c),
    }
}

/// Read-only graph metadata replicated to every machine (a real system
/// ships this catalog with the shards at ingestion; sharing it through an
/// `Arc` models replication without P deep copies).  `Clone` exists for
/// the delta path: [`SpmdEngine::apply_delta`] updates the catalog via
/// `Arc::make_mut` — copy-on-write, so an engine whose meta nobody else
/// holds (the steady serving state) patches it in place.
#[derive(Clone)]
pub struct GraphMeta {
    pub n: usize,
    pub m: usize,
    pub p: usize,
    /// Tree fanout C.
    pub c: usize,
    pub part: VertexPart,
    /// Machines holding out-edge blocks of u (source-tree leaves).
    pub src_leaves: Vec<Vec<MachineId>>,
    /// Machines holding in-edges of v (destination-tree leaves).
    pub dst_leaves: Vec<Vec<MachineId>>,
    pub out_deg: Vec<u32>,
    /// Per-vertex source-broadcast relay tree ([`relay_tree_levels`] over
    /// `src_leaves[u]`, rooted at the owner).  Precomputed at engine
    /// construction: the trees are pure functions of the ingestion-time
    /// placement, and recomputing them inside the per-round supersteps
    /// would pollute the measured per-machine busy clocks on the
    /// threaded backend.
    pub src_tree: Vec<Vec<Vec<(MachineId, MachineId)>>>,
    /// Per-vertex destination-merge relay tree (over `dst_leaves[v]`).
    pub dst_tree: Vec<Vec<Vec<(MachineId, MachineId)>>>,
}

/// One machine's private shard: graph blocks + algorithm state + frontier
/// slice + the round-scratch buffers.  This is the `St` that travels
/// through [`Substrate::superstep`] — workers own it for the duration of
/// a superstep, the driver between supersteps.
pub struct MachineState<AS> {
    blocks: Vec<EdgeBlock>,
    /// CSR-style source→block index ([`BlockIndex`]): two array reads
    /// per lookup instead of a hash.
    block_of: BlockIndex,
    /// Algorithm state for the owned vertex range (e.g. a distance
    /// slice); see the shard constructors in [`super::algorithms`].
    pub algo: AS,
    /// Active owned vertices over `[range.start, range.end)` — sparse
    /// vec or dense bitset, switched deterministically at
    /// [`Frontier::seal`]; both iterate ascending.
    frontier: Frontier,
    /// Phase-1 scratch: delivered (or self-seeded) source values
    /// (flat dirty-listed slab; see [`super::layout`]).
    relay: Slab,
    /// Phase-2 scratch: pre-merged contributions per destination.
    agg: Slab,
    /// Phase-2 scratch: raw per-edge contributions (premerge off).
    raw: Vec<(Vid, f64)>,
    /// Phase-3/4 scratch: partial aggregates currently held here.
    pending: Slab,
    /// Destination-tree depth this machine's contributions need.
    depth_needed: usize,
    /// Fused-wave frontier: active (vertex, lane) pairs, ascending.
    /// `frontier` always holds its vertex projection so the mode
    /// heuristic and tree sizing read one field in both round shapes.
    lane_frontier: Vec<(Vid, u32)>,
    /// Lane-keyed mirrors of the round scratch above, used by
    /// [`SpmdEngine::edge_map_lanes`] (fused multi-source waves);
    /// geometry set per wave by the frontier seeding calls.
    relay_l: LaneSlab,
    agg_l: LaneSlab,
    raw_l: Vec<(Vid, u32, f64)>,
    pending_l: LaneSlab,
}

/// Block placement policy (the two ingestion passes of §5.1 / §6.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// TD-Orch ingestion: hot vertices' blocks spread over transit
    /// machines ([`ingest`]).
    Spread,
    /// Baseline ingestion: all blocks at the source's owner
    /// ([`ingest_at_owner`]).
    AtOwner,
}

/// The SPMD TDO-GP engine, generic over the execution substrate.
pub struct SpmdEngine<B: Substrate, AS: Send> {
    sub: B,
    pub flags: Flags,
    meta: Arc<GraphMeta>,
    machines: Vec<MachineState<AS>>,
    label: String,
    eff_work_pct: u64,
    resets: u64,
    /// Number of mutation batches absorbed ([`SpmdEngine::apply_delta`]).
    /// Epoch 0 is the freshly-ingested graph; every batch — even an empty
    /// one — advances the epoch by exactly one, so an epoch value fully
    /// identifies a graph snapshot given the mutation stream.
    graph_epoch: u64,
    /// Total directed edge ops absorbed across all epochs.
    mutations_applied: u64,
}

impl<B: Substrate, AS: Send> SpmdEngine<B, AS> {
    /// Build shards on `sub`'s machines.  Ingestion runs on a scratch
    /// simulator cluster (the paper times queries, not loading).
    pub fn new(
        sub: B,
        g: &Graph,
        cost: CostModel,
        flags: Flags,
        placement: Placement,
        label: &str,
        init: impl Fn(MachineId, &GraphMeta) -> AS,
    ) -> Self {
        let dg = ingest_once(g, sub.machines(), cost, placement);
        Self::from_ingested(sub, dg, cost, flags, label, init)
    }

    /// Build an engine from an **already-ingested** graph.  The serving
    /// path ingests once ([`ingest_once`]) and constructs its engines —
    /// one per substrate — from clones of the same `DistGraph`, so the
    /// expensive placement pass never repeats per engine or per query.
    pub fn from_ingested(
        sub: B,
        dg: DistGraph,
        cost: CostModel,
        flags: Flags,
        label: &str,
        init: impl Fn(MachineId, &GraphMeta) -> AS,
    ) -> Self {
        let p = sub.machines();
        assert_eq!(
            p, dg.p,
            "ingested for {} machines but the substrate has {p}",
            dg.p
        );
        let eff_work_pct = flags.effective_pct(cost);
        let src_tree: Vec<_> = (0..dg.n)
            .map(|u| {
                relay_tree_levels(
                    u as u64,
                    &dg.src_leaves[u],
                    dg.part.owner(u as Vid),
                    dg.c,
                    p,
                )
            })
            .collect();
        let dst_tree: Vec<_> = (0..dg.n)
            .map(|v| {
                relay_tree_levels(
                    v as u64 ^ 0xD5,
                    &dg.dst_leaves[v],
                    dg.part.owner(v as Vid),
                    dg.c,
                    p,
                )
            })
            .collect();
        let meta = Arc::new(GraphMeta {
            n: dg.n,
            m: dg.m,
            p,
            c: dg.c,
            part: dg.part,
            src_leaves: dg.src_leaves,
            dst_leaves: dg.dst_leaves,
            out_deg: dg.out_deg,
            src_tree,
            dst_tree,
        });
        let machines = dg
            .blocks
            .into_iter()
            .zip(dg.block_of)
            .enumerate()
            .map(|(m, (blocks, block_of))| {
                // The value slabs are keyed by global vertex id (relay /
                // agg / pending hold non-owned vertices at block and
                // relay machines); only the frontier is owned-range.
                let mut st = MachineState {
                    blocks,
                    block_of,
                    algo: init(m, &meta),
                    frontier: Frontier::new(meta.part.range(m).start, meta.part.count_on(m)),
                    relay: Slab::new(),
                    agg: Slab::new(),
                    raw: Vec::new(),
                    pending: Slab::new(),
                    depth_needed: 0,
                    lane_frontier: Vec::new(),
                    relay_l: LaneSlab::new(),
                    agg_l: LaneSlab::new(),
                    raw_l: Vec::new(),
                    pending_l: LaneSlab::new(),
                };
                st.relay.ensure(meta.n);
                st.agg.ensure(meta.n);
                st.pending.ensure(meta.n);
                st
            })
            .collect();
        SpmdEngine {
            sub,
            flags,
            meta,
            machines,
            label: label.to_string(),
            eff_work_pct,
            resets: 0,
            graph_epoch: 0,
            mutations_applied: 0,
        }
    }

    /// TDO-GP defaults: full technique flags, spread placement.
    pub fn tdo_gp(
        sub: B,
        g: &Graph,
        cost: CostModel,
        init: impl Fn(MachineId, &GraphMeta) -> AS,
    ) -> Self {
        Self::new(sub, g, cost, Flags::tdo_gp(), Placement::Spread, "tdo-gp", init)
    }

    /// Baseline presets: family flags + owner placement (no transit
    /// machines, so hub vertices concentrate on their owners).
    pub fn baseline(
        sub: B,
        g: &Graph,
        cost: CostModel,
        flags: Flags,
        label: &str,
        init: impl Fn(MachineId, &GraphMeta) -> AS,
    ) -> Self {
        Self::new(sub, g, cost, flags, Placement::AtOwner, label, init)
    }

    pub fn label(&self) -> &str {
        &self.label
    }

    pub fn meta(&self) -> Arc<GraphMeta> {
        Arc::clone(&self.meta)
    }

    pub fn sub(&self) -> &B {
        &self.sub
    }

    pub fn sub_mut(&mut self) -> &mut B {
        &mut self.sub
    }

    /// Attach (or detach) a flight recorder on the underlying substrate:
    /// while attached, every ledger superstep this engine drives — query
    /// passes and absorbed mutation batches alike — emits one
    /// [`crate::obs::EventKind::Superstep`] with the per-machine ledger
    /// slice (see [`crate::exec::Substrate::set_observer`]).
    pub fn set_observer(&mut self, obs: Option<crate::obs::ObserverHandle>) {
        self.sub.set_observer(obs);
    }

    /// Consume the engine, returning the substrate (to read final
    /// metrics/wall-clock after the shards are no longer needed).
    pub fn into_sub(self) -> B {
        self.sub
    }

    pub fn algo(&self, m: MachineId) -> &AS {
        &self.machines[m].algo
    }

    pub fn algo_mut(&mut self, m: MachineId) -> &mut AS {
        &mut self.machines[m].algo
    }

    /// Driver-side sweep over shards (between supersteps only).
    pub fn for_each_algo(&mut self, mut f: impl FnMut(MachineId, &mut AS)) {
        for (m, st) in self.machines.iter_mut().enumerate() {
            f(m, &mut st.algo);
        }
    }

    /// Gather a global vector by concatenating each machine's owned-range
    /// slice (ranges are contiguous and ascending, so concatenation *is*
    /// vertex order).
    pub fn gather<T>(&self, f: impl Fn(MachineId, &AS) -> Vec<T>) -> Vec<T> {
        let mut out = Vec::with_capacity(self.meta.n);
        for (m, st) in self.machines.iter().enumerate() {
            let mut part = f(m, &st.algo);
            debug_assert_eq!(part.len(), self.meta.part.count_on(m), "gather slice mismatch");
            out.append(&mut part);
        }
        out
    }

    pub fn frontier_len(&self) -> usize {
        self.machines.iter().map(|s| s.frontier.len()).sum()
    }

    pub fn clear_frontier(&mut self) {
        for st in self.machines.iter_mut() {
            st.frontier.clear();
            st.lane_frontier.clear();
        }
    }

    pub fn set_frontier_single(&mut self, v: Vid) {
        self.clear_frontier();
        let owner = self.meta.part.owner(v);
        self.machines[owner].frontier.insert(v);
    }

    pub fn set_frontier_all(&mut self) {
        for st in self.machines.iter_mut() {
            st.frontier.fill_all();
        }
    }

    /// Number of machines whose frontier currently sits in the dense
    /// bitset representation (pure observability — the regression tests
    /// use it to pin that the sparse↔dense switch actually engages).
    pub fn frontier_dense_machines(&self) -> usize {
        self.machines.iter().filter(|s| s.frontier.is_dense()).count()
    }

    /// Per-machine snapshot of the current frontier (driver-side,
    /// between supersteps) — BC's forward pass records these to replay
    /// the levels backward.
    pub fn frontier_parts(&self) -> Vec<Vec<Vid>> {
        self.machines.iter().map(|s| s.frontier.to_vec()).collect()
    }

    /// Restore a frontier previously captured with
    /// [`SpmdEngine::frontier_parts`] (each part must hold vertices the
    /// corresponding machine owns, ascending, as captured).
    pub fn set_frontier_parts(&mut self, parts: &[Vec<Vid>]) {
        assert_eq!(parts.len(), self.machines.len(), "frontier parts != machines");
        for (st, part) in self.machines.iter_mut().zip(parts) {
            st.frontier.clear();
            for &v in part {
                st.frontier.push(v);
            }
            st.frontier.seal();
        }
    }

    /// Total active (vertex, lane) pairs in the fused frontier.
    pub fn lane_frontier_len(&self) -> usize {
        self.machines.iter().map(|s| s.lane_frontier.len()).sum()
    }

    /// Rebuild the single-frontier vertex projection from
    /// `lane_frontier` (which is kept ascending by (vertex, lane), so
    /// pushing on vertex change yields a sorted, deduped projection).
    fn project_lane_union(st: &mut MachineState<AS>) {
        st.frontier.clear();
        let mut last: Option<Vid> = None;
        for &(v, _lane) in &st.lane_frontier {
            if last != Some(v) {
                st.frontier.push(v);
                last = Some(v);
            }
        }
        st.frontier.seal();
    }

    /// Seed a fused multi-source wave: activate each (vertex, lane) pair
    /// at the vertex's owner.  Lane ids are dense indices into the batch
    /// being fused (lane `l` is query `l`'s traversal).
    pub fn set_frontier_lanes(&mut self, seeds: &[(Vid, u32)]) {
        let meta = Arc::clone(&self.meta);
        let lanes = seeds.iter().map(|&(_, l)| l + 1).max().unwrap_or(0);
        for st in self.machines.iter_mut() {
            st.frontier.clear();
            st.lane_frontier.clear();
            st.relay_l.configure(meta.n, lanes);
            st.agg_l.configure(meta.n, lanes);
            st.pending_l.configure(meta.n, lanes);
        }
        for &(v, lane) in seeds {
            let owner = meta.part.owner(v);
            self.machines[owner].lane_frontier.push((v, lane));
        }
        for st in self.machines.iter_mut() {
            st.lane_frontier.sort_unstable();
            st.lane_frontier.dedup();
            Self::project_lane_union(st);
        }
    }

    /// Activate every owned vertex in every lane (the CC-style start,
    /// fused: all lanes run the same everywhere-active sweep).
    pub fn set_frontier_all_lanes(&mut self, lanes: u32) {
        let meta = Arc::clone(&self.meta);
        for (m, st) in self.machines.iter_mut().enumerate() {
            st.frontier.fill_all();
            st.lane_frontier.clear();
            st.relay_l.configure(meta.n, lanes);
            st.agg_l.configure(meta.n, lanes);
            st.pending_l.configure(meta.n, lanes);
            for v in meta.part.range(m) {
                for lane in 0..lanes {
                    st.lane_frontier.push((v, lane));
                }
            }
        }
    }

    /// Re-initialize the engine for the next query, KEEPING ingestion
    /// (block placement), the precomputed relay trees, and the substrate
    /// — on the threaded backend, the parked worker pool.  `reinit` runs
    /// *inside* one superstep, so each worker resets its own shard in
    /// parallel (and cache-warm); the frontier and round scratch are
    /// cleared alongside.  After a reset the engine is observationally a
    /// freshly constructed one — `tests/serve_equivalence.rs` pins that
    /// the next query's result is bit-identical to a brand-new engine's
    /// — which is what lets the serving layer run query after query
    /// without ever re-ingesting the graph.  No work units are charged
    /// and no messages move, so the accounting ledger is untouched (the
    /// reset does consume one pool epoch on the threaded backend).
    pub fn reset_for_query(&mut self, reinit: impl Fn(MachineId, &GraphMeta, &mut AS) + Sync) {
        let meta = Arc::clone(&self.meta);
        let p = meta.p;
        let reinit = &reinit;
        let meta_ref = &meta;
        let _: Vec<Vec<Nothing>> = self.sub.superstep(
            &mut self.machines,
            no_messages(p),
            move |m, st: &mut MachineState<AS>, _in: Vec<Nothing>, _acct: &mut MachineAcct| {
                st.frontier.clear();
                st.relay.clear();
                st.agg.clear();
                st.raw.clear();
                st.pending.clear();
                st.depth_needed = 0;
                st.lane_frontier.clear();
                st.relay_l.clear();
                st.agg_l.clear();
                st.raw_l.clear();
                st.pending_l.clear();
                reinit(m, meta_ref, &mut st.algo);
                Vec::new()
            },
            nothing_words,
        );
        self.resets += 1;
    }

    /// Number of [`SpmdEngine::reset_for_query`] calls so far (the
    /// serving layer's per-engine query counter).
    pub fn resets(&self) -> u64 {
        self.resets
    }

    /// Current graph epoch: 0 = freshly ingested, +1 per absorbed
    /// mutation batch.  Stamped on every `QueryResult` by the server —
    /// it fully identifies the snapshot a result was computed on.
    pub fn graph_epoch(&self) -> u64 {
        self.graph_epoch
    }

    /// Total directed edge ops absorbed in place so far.
    pub fn mutations_applied(&self) -> u64 {
        self.mutations_applied
    }

    /// Absorb one mutation batch **in place**, inside a single superstep
    /// on the substrate — no re-ingestion ([`crate::mutate`] module docs
    /// have the full contract; `ingest::ingestions()` is the witness).
    ///
    /// The driver routes each directed op to the machines that can hold
    /// the arc under the frozen placement: inserts to the source's owner
    /// (where deltas accrete), deletes to the source's current leaf set
    /// ∪ the owner — the union covers an arc inserted at the owner
    /// earlier in the SAME batch, before this catalog update.  Workers
    /// patch their blocks with the [`mutate::delta`] helpers (first-match
    /// shift delete, emptied blocks kept — the identical rules
    /// `DistGraph::apply_batch` replays) and ship per-(vertex, machine)
    /// [`DeltaNote`]s to machine 0; the driver folds them last-note-wins
    /// into the shared catalog via `Arc::make_mut`, then rebuilds relay
    /// trees for exactly the dirty vertices.  Every inbox is
    /// driver-built, so work charges and results are bit-identical
    /// across backends; a non-empty batch costs exactly one ledger
    /// superstep.  Returns the number of directed ops applied.
    pub fn apply_delta(&mut self, batch: &MutationBatch) -> usize {
        let p = self.meta.p;
        let mut inboxes: Vec<Vec<EdgeOp>> = (0..p).map(|_| Vec::new()).collect();
        for op in &batch.ops {
            match *op {
                EdgeOp::Insert { u, .. } => {
                    inboxes[self.meta.part.owner(u)].push(*op);
                }
                EdgeOp::Delete { u, .. } => {
                    let owner = self.meta.part.owner(u);
                    let mut sent_owner = false;
                    for &leaf in &self.meta.src_leaves[u as usize] {
                        inboxes[leaf].push(*op);
                        sent_owner |= leaf == owner;
                    }
                    if !sent_owner {
                        inboxes[owner].push(*op);
                    }
                }
            }
        }

        let notes_by_dest: Vec<Vec<DeltaNote>> = self.sub.superstep(
            &mut self.machines,
            inboxes,
            move |m, st: &mut MachineState<AS>, inbox: Vec<EdgeOp>, acct: &mut MachineAcct| {
                let ops = inbox.len() as u64;
                let MachineState { blocks, block_of, .. } = st;
                let mut out: Vec<(MachineId, DeltaNote)> = Vec::new();
                for op in inbox {
                    match op {
                        EdgeOp::Insert { u, v, w } => {
                            mutate::insert_arc(blocks, block_of, u, v, w);
                            out.push((0, DeltaNote {
                                vertex: u,
                                machine: m as u32,
                                is_src: true,
                                present: true,
                                deg_delta: 1,
                            }));
                            out.push((0, DeltaNote {
                                vertex: v,
                                machine: m as u32,
                                is_src: false,
                                present: true,
                                deg_delta: 0,
                            }));
                        }
                        EdgeOp::Delete { u, v } => {
                            // The arc is globally unique: at most one of
                            // the probed machines finds it.
                            if mutate::delete_arc(blocks, block_of, u, v) {
                                out.push((0, DeltaNote {
                                    vertex: u,
                                    machine: m as u32,
                                    is_src: true,
                                    present: mutate::holds_src(blocks, block_of, u),
                                    deg_delta: -1,
                                }));
                                out.push((0, DeltaNote {
                                    vertex: v,
                                    machine: m as u32,
                                    is_src: false,
                                    present: mutate::holds_dst(blocks, v),
                                    deg_delta: 0,
                                }));
                            }
                        }
                    }
                }
                acct.work(ops);
                out
            },
            |_: &DeltaNote| 2,
        );

        // Fold the notes into the shared catalog.  Delivery is (sender,
        // emission-index) ordered on both backends, so per-(vertex,
        // machine) notes arrive in that machine's application order and
        // last-note-wins is correct; `set_membership` is idempotent.
        let notes = &notes_by_dest[0];
        let applied = notes.len() / 2;
        let meta = Arc::make_mut(&mut self.meta);
        let mut dirty_src: Vec<Vid> = Vec::new();
        let mut dirty_dst: Vec<Vid> = Vec::new();
        let mut m_delta: i64 = 0;
        for note in notes {
            let vid = note.vertex as usize;
            if note.is_src {
                mutate::set_membership(&mut meta.src_leaves[vid], note.machine as usize, note.present);
                meta.out_deg[vid] = (meta.out_deg[vid] as i64 + note.deg_delta as i64) as u32;
                m_delta += note.deg_delta as i64;
                dirty_src.push(note.vertex);
            } else {
                mutate::set_membership(&mut meta.dst_leaves[vid], note.machine as usize, note.present);
                dirty_dst.push(note.vertex);
            }
        }
        meta.m = (meta.m as i64 + m_delta) as usize;
        dirty_src.sort_unstable();
        dirty_src.dedup();
        dirty_dst.sort_unstable();
        dirty_dst.dedup();
        // Relay trees are pure functions of (key, leaves, root, c, p):
        // rebuild exactly the dirty ones, with the construction-time keys.
        for &u in &dirty_src {
            meta.src_tree[u as usize] = relay_tree_levels(
                u as u64,
                &meta.src_leaves[u as usize],
                meta.part.owner(u),
                meta.c,
                p,
            );
        }
        for &v in &dirty_dst {
            meta.dst_tree[v as usize] = relay_tree_levels(
                v as u64 ^ 0xD5,
                &meta.dst_leaves[v as usize],
                meta.part.owner(v),
                meta.c,
                p,
            );
        }
        self.graph_epoch += 1;
        self.mutations_applied += applied as u64;
        applied
    }

    /// Driver-side snapshot of resident blocks: per machine, per block
    /// slot, `(src, targets_len)` — hollowed slots report 0.  The
    /// placement controller's decision input (deterministic: block order
    /// is part of the engine's bit-level state).
    pub fn block_catalog(&self) -> Vec<Vec<(Vid, u32)>> {
        self.machines
            .iter()
            .map(|st| st.blocks.iter().map(|b| (b.src, b.targets.len() as u32)).collect())
            .collect()
    }

    /// Apply one placement delta **in place**, inside a single superstep
    /// on the substrate — the placement counterpart of
    /// [`SpmdEngine::apply_delta`], same frozen-ownership discipline, no
    /// re-ingestion (`ingest::ingestions()` stays the witness).
    ///
    /// The driver snapshots every shipped payload from the pre-delta
    /// blocks and builds per-machine patch inboxes
    /// ([`crate::place::PlacementDelta`] semantics: a `Move` hollows the
    /// source slot and installs the block at the destination's tail; a
    /// `Split` keeps the head half and installs the tail — hot-vertex
    /// replication).  Workers apply their patches in inbox order and
    /// ship per-(vertex, machine) [`DeltaNote`]s to machine 0; the
    /// driver folds them into the shared catalog via `Arc::make_mut`
    /// and rebuilds relay trees for exactly the dirty vertices, with
    /// the construction-time keys.  `out_deg` and `m` never change —
    /// placement moves arcs, it does not create or destroy them — and
    /// `graph_epoch` advances by one per op, so every placement is a
    /// distinct, cacheable snapshot.  Returns the number of ops applied
    /// (a non-empty delta costs exactly one ledger superstep).
    pub fn apply_placement(&mut self, delta: &crate::place::PlacementDelta) -> usize {
        if delta.ops.is_empty() {
            return 0;
        }
        let p = self.meta.p;
        let inboxes = crate::place::build_patches(p, delta, |m, b| {
            let blk = &self.machines[m].blocks[b as usize];
            (blk.src, blk.targets.clone())
        });

        let notes_by_dest: Vec<Vec<DeltaNote>> = self.sub.superstep(
            &mut self.machines,
            inboxes,
            move |m,
                  st: &mut MachineState<AS>,
                  inbox: Vec<crate::place::Patch>,
                  acct: &mut MachineAcct| {
                let MachineState { blocks, block_of, .. } = st;
                let (notes, work) = crate::place::apply_patches(blocks, block_of, inbox);
                acct.work(work);
                notes
                    .into_iter()
                    .map(|(vertex, is_src, present)| {
                        (0, DeltaNote {
                            vertex,
                            machine: m as u32,
                            is_src,
                            present,
                            deg_delta: 0,
                        })
                    })
                    .collect()
            },
            |_: &DeltaNote| 2,
        );

        // Fold the membership notes exactly like the mutation path:
        // (sender, emission-index) delivery order, last-note-wins,
        // idempotent splices — but no degree or arc-count changes.
        let notes = &notes_by_dest[0];
        let meta = Arc::make_mut(&mut self.meta);
        let mut dirty_src: Vec<Vid> = Vec::new();
        let mut dirty_dst: Vec<Vid> = Vec::new();
        for note in notes {
            let vid = note.vertex as usize;
            if note.is_src {
                mutate::set_membership(&mut meta.src_leaves[vid], note.machine as usize, note.present);
                dirty_src.push(note.vertex);
            } else {
                mutate::set_membership(&mut meta.dst_leaves[vid], note.machine as usize, note.present);
                dirty_dst.push(note.vertex);
            }
        }
        dirty_src.sort_unstable();
        dirty_src.dedup();
        dirty_dst.sort_unstable();
        dirty_dst.dedup();
        for &u in &dirty_src {
            meta.src_tree[u as usize] = relay_tree_levels(
                u as u64,
                &meta.src_leaves[u as usize],
                meta.part.owner(u),
                meta.c,
                p,
            );
        }
        for &v in &dirty_dst {
            meta.dst_tree[v as usize] = relay_tree_levels(
                v as u64 ^ 0xD5,
                &meta.dst_leaves[v as usize],
                meta.part.owner(v),
                meta.c,
                p,
            );
        }
        self.graph_epoch += delta.ops.len() as u64;
        delta.ops.len()
    }

    #[inline]
    fn scaled(&self, units: u64) -> u64 {
        units * self.eff_work_pct / 100
    }

    /// Charge `units` of algorithm-level local work on every machine
    /// (init sweeps etc.) — one superstep with no messages.
    pub fn charge_local(&mut self, units_per_machine: u64) {
        self.local_step(units_per_machine, |_m, _algo| {});
    }

    /// One message-free superstep of per-machine local work: run `f` on
    /// each shard's algorithm state *inside* the substrate — parallel on
    /// the threaded backend, so the measured busy clocks contain the work
    /// the ledger charges — and charge `units_per_machine` scaled units
    /// (PR's per-round base reset is the canonical use).
    pub fn local_step(
        &mut self,
        units_per_machine: u64,
        f: impl Fn(MachineId, &mut AS) + Sync,
    ) {
        let u = self.scaled(units_per_machine);
        let p = self.meta.p;
        let f = &f;
        let _: Vec<Vec<Nothing>> = self.sub.superstep(
            &mut self.machines,
            no_messages(p),
            move |m, st: &mut MachineState<AS>, _in: Vec<Nothing>, acct: &mut MachineAcct| {
                f(m, &mut st.algo);
                acct.work(u);
                Vec::new()
            },
            nothing_words,
        );
    }

    /// DISTEDGEMAP (Fig 6) as supersteps — see the module docs for the
    /// phase structure.  `src_value(m, algo, u)` produces the value an
    /// active owned vertex broadcasts (None = contributes nothing this
    /// round); `edge_fn(value, u, v, w)` runs at the block machine on the
    /// *delivered* value; `merge` ⊗-combines contributions per
    /// destination; `write_back(algo, v, merged)` runs at v's owner and
    /// returns whether v joins the next frontier.  Returns the new global
    /// frontier size.
    pub fn edge_map(
        &mut self,
        src_value: &(dyn Fn(MachineId, &AS, Vid) -> Option<f64> + Sync),
        edge_fn: &(dyn Fn(f64, Vid, Vid, f32) -> Option<f64> + Sync),
        merge: &(dyn Fn(f64, f64) -> f64 + Sync),
        write_back: &(dyn Fn(&mut AS, Vid, f64) -> bool + Sync),
    ) -> usize {
        let p = self.meta.p;
        let flags = self.flags;
        let eff = self.eff_work_pct;
        let meta = Arc::clone(&self.meta);

        // ---- driver: mode decision from per-shard frontier stats
        // (Ligra's sparse-dense heuristic, computed between supersteps
        // where the driver legitimately owns the shards) ----
        let active_total: usize = self.machines.iter().map(|s| s.frontier.len()).sum();
        if active_total == 0 {
            return 0;
        }
        let sum_deg: u64 = self
            .machines
            .iter()
            .flat_map(|s| s.frontier.iter())
            .map(|u| meta.out_deg[u as usize] as u64)
            .sum();
        let dense = !flags.sparse_mode
            || (sum_deg + active_total as u64) > meta.m as u64 / DENSE_DIV;
        let tree_bcast = !dense && flags.use_trees;
        let scan = dense || flags.full_scan;

        // Depth of the level-synchronous source broadcast (tree mode).
        let d_src = if tree_bcast {
            self.machines
                .iter()
                .flat_map(|s| s.frontier.iter())
                .map(|u| meta.src_tree[u as usize].len())
                .max()
                .unwrap_or(0)
        } else {
            0
        };

        // ---- Phase 1a: owners emit source values (and clear scratch) --
        let meta1 = Arc::clone(&meta);
        let mut val_msgs: Vec<Vec<(Vid, f64)>> = self.sub.superstep(
            &mut self.machines,
            no_messages(p),
            move |m, st: &mut MachineState<AS>, _in: Vec<Nothing>, _acct: &mut MachineAcct| {
                st.relay.clear();
                st.agg.clear();
                st.raw.clear();
                st.pending.clear();
                st.depth_needed = 0;
                let mut out: Vec<(MachineId, (Vid, f64))> = Vec::new();
                for u in st.frontier.iter() {
                    let Some(val) = src_value(m, &st.algo, u) else { continue };
                    if dense {
                        if flags.dest_aware {
                            for &leaf in &meta1.src_leaves[u as usize] {
                                out.push((leaf, (u, val)));
                            }
                        } else {
                            for t in 0..p {
                                out.push((t, (u, val)));
                            }
                        }
                    } else if flags.use_trees {
                        // Root seeds its own relay; top-down depth 0 is
                        // the reversed *last* bottom-up level.
                        st.relay.insert(u, val);
                        let levels = &meta1.src_tree[u as usize];
                        if let Some(level) = levels.last() {
                            for &(child, parent) in level {
                                if parent == m {
                                    out.push((child, (u, val)));
                                }
                            }
                        }
                    } else {
                        // Direct fan-out from the owner (mirror-style).
                        for &leaf in &meta1.src_leaves[u as usize] {
                            out.push((leaf, (u, val)));
                        }
                    }
                }
                out
            },
            |_: &(Vid, f64)| VAL_WORDS,
        );

        // ---- Phase 1b: remaining top-down tree levels ----
        if tree_bcast {
            for d in 1..d_src {
                let meta_d = Arc::clone(&meta);
                val_msgs = self.sub.superstep(
                    &mut self.machines,
                    val_msgs,
                    move |m,
                          st: &mut MachineState<AS>,
                          inbox: Vec<(Vid, f64)>,
                          _acct: &mut MachineAcct| {
                        for (u, val) in inbox {
                            st.relay.insert_first(u, val);
                        }
                        st.relay.normalize();
                        let mut out = Vec::new();
                        for &u in st.relay.dirty() {
                            let val = st.relay.get(u).unwrap();
                            let levels = &meta_d.src_tree[u as usize];
                            let k = levels.len();
                            if k <= d {
                                continue; // this vertex's tree is shallower
                            }
                            for &(child, parent) in &levels[k - 1 - d] {
                                if parent == m {
                                    out.push((child, (u, val)));
                                }
                            }
                        }
                        out
                    },
                    |_: &(Vid, f64)| VAL_WORDS,
                );
            }
        }

        // ---- Phase 2: execute f at block machines; emit level-0
        // contributions (pre-merged per destination, or raw per edge;
        // raw per-edge contributions cannot be packed with their
        // neighbors, so they are charged as RPC round-trips — the
        // "direct pull" wire shape the paper's prototype baseline pays)
        if !flags.premerge {
            self.sub.set_msg_factor(RPC_MSG_FACTOR);
        }
        let meta2 = Arc::clone(&meta);
        let mut contrib_msgs: Vec<Vec<(Vid, f64)>> = self.sub.superstep(
            &mut self.machines,
            val_msgs,
            move |m,
                  st: &mut MachineState<AS>,
                  inbox: Vec<(Vid, f64)>,
                  acct: &mut MachineAcct| {
                for (u, val) in inbox {
                    st.relay.insert_first(u, val);
                }
                let MachineState { blocks, block_of, relay, agg, raw, pending, depth_needed, .. } =
                    st;
                let emit = |v: Vid, cv: f64, agg: &mut Slab, raw: &mut Vec<(Vid, f64)>| {
                    if flags.premerge {
                        agg.merge_with(v, cv, merge);
                    } else {
                        raw.push((v, cv));
                    }
                };
                let mut work = 0u64;
                if scan {
                    for block in blocks.iter() {
                        work += block.targets.len() as u64;
                        let Some(val) = relay.get(block.src) else { continue };
                        for &(v, w) in &block.targets {
                            if let Some(cv) = edge_fn(val, block.src, v, w) {
                                work += 1;
                                emit(v, cv, agg, raw);
                            }
                        }
                    }
                } else {
                    relay.normalize();
                    for &u in relay.dirty() {
                        let val = relay.get(u).unwrap();
                        for &idx in block_of.get(u) {
                            let block = &blocks[idx as usize];
                            for &(v, w) in &block.targets {
                                work += 1;
                                if let Some(cv) = edge_fn(val, u, v, w) {
                                    emit(v, cv, agg, raw);
                                }
                            }
                        }
                    }
                }
                let mut units = work * eff / 100;
                if flags.round_overhead_n {
                    units += meta2.part.count_on(m) as u64;
                }
                acct.work(units);

                // Emit this machine's contributions toward the owners.
                let mut out: Vec<(MachineId, (Vid, f64))> = Vec::new();
                if flags.premerge {
                    agg.normalize();
                    if flags.use_trees {
                        let mut max_d = 0usize;
                        for &v in agg.dirty() {
                            let val = agg.get(v).unwrap();
                            let levels = &meta2.dst_tree[v as usize];
                            max_d = max_d.max(levels.len());
                            let edge = levels
                                .first()
                                .and_then(|lvl| lvl.iter().find(|&&(c, _)| c == m));
                            match edge {
                                Some(&(_, parent)) => out.push((parent, (v, val))),
                                // No level-0 edge ⟺ this machine is the
                                // root: hold the partial locally.
                                None => {
                                    pending.insert(v, val);
                                }
                            }
                        }
                        *depth_needed = max_d;
                    } else {
                        for &v in agg.dirty() {
                            out.push((meta2.part.owner(v), (v, agg.get(v).unwrap())));
                        }
                    }
                } else {
                    for &(v, cv) in raw.iter() {
                        out.push((meta2.part.owner(v), (v, cv)));
                    }
                }
                out
            },
            |_: &(Vid, f64)| CONTRIB_WORDS,
        );
        if !flags.premerge {
            self.sub.set_msg_factor(1);
        }

        // ---- Phase 3: remaining destination-tree merge levels ----
        let d_dst = if flags.premerge && flags.use_trees {
            self.machines.iter().map(|s| s.depth_needed).max().unwrap_or(0)
        } else {
            0
        };
        for d in 1..d_dst {
            let meta_d = Arc::clone(&meta);
            contrib_msgs = self.sub.superstep(
                &mut self.machines,
                contrib_msgs,
                move |m,
                      st: &mut MachineState<AS>,
                      inbox: Vec<(Vid, f64)>,
                      _acct: &mut MachineAcct| {
                    // ⊗-merge arriving partials in (sender, emission)
                    // order — deterministic on both backends.
                    for (v, val) in inbox {
                        st.pending.merge_with(v, val, merge);
                    }
                    // Indexed walk: `take` flips presence without touching
                    // the dirty-list, so indices stay stable mid-loop.
                    st.pending.normalize();
                    let mut out = Vec::new();
                    for i in 0..st.pending.dirty_len() {
                        let v = st.pending.key_at(i);
                        let levels = &meta_d.dst_tree[v as usize];
                        if levels.len() <= d {
                            continue; // merged out already / root holds it
                        }
                        let Some(&(_, parent)) =
                            levels[d].iter().find(|&&(c, _)| c == m)
                        else {
                            continue; // root (or not yet at this level)
                        };
                        let val = st.pending.take(v).unwrap();
                        out.push((parent, (v, val)));
                    }
                    out
                },
                |_: &(Vid, f64)| CONTRIB_WORDS,
            );
        }

        // ---- Phase 4: write-backs at destination owners ----
        let meta4 = Arc::clone(&meta);
        let _: Vec<Vec<Nothing>> = self.sub.superstep(
            &mut self.machines,
            contrib_msgs,
            move |m,
                  st: &mut MachineState<AS>,
                  inbox: Vec<(Vid, f64)>,
                  acct: &mut MachineAcct| {
                for (v, val) in inbox {
                    st.pending.merge_with(v, val, merge);
                }
                st.pending.normalize();
                st.frontier.clear();
                let mut wb = 0u64;
                for i in 0..st.pending.dirty_len() {
                    let v = st.pending.key_at(i);
                    let val = st.pending.take(v).unwrap();
                    debug_assert_eq!(
                        meta4.part.owner(v),
                        m,
                        "contribution for {v} landed on non-owner {m}"
                    );
                    wb += 1;
                    if write_back(&mut st.algo, v, val) {
                        st.frontier.push(v);
                    }
                }
                st.frontier.seal();
                acct.work(wb * eff / 100);
                Vec::new()
            },
            nothing_words,
        );

        self.machines.iter().map(|s| s.frontier.len()).sum()
    }

    /// DISTEDGEMAP over a **fused multi-source wave**: the same four
    /// phases as [`SpmdEngine::edge_map`], with a lane id riding in
    /// every message — `(vertex, lane, value)` — and per-(vertex, lane)
    /// round scratch, so one engine pass advances a whole batch of
    /// same-kind traversals at once (paper-style batch amortization:
    /// the ROADMAP's "multi-source fusion").
    ///
    /// Determinism: lanes evolve independently — a lane only receives
    /// contributions generated from its own active pairs, and every
    /// fold iterates sorted `(vertex, lane)` keys or delivery order —
    /// so for the exact merge operators (min / first-writer) each
    /// lane's bits equal the single-source [`SpmdEngine::edge_map`] run
    /// at every P and on both backends.  Mode selection (dense/sparse)
    /// is computed over the *union* of active pairs, which can differ
    /// from any member's solo run; like the single path, the mode only
    /// moves routing and cost, never the per-lane candidate sets.
    ///
    /// Cost: one fused round prices the block scan once for all lanes
    /// (the work saving), charges per-(edge, lane) application, and
    /// ships lane-tagged payloads one word wider than the single-run
    /// wire shapes ([`VAL_WORDS`]/[`CONTRIB_WORDS`] + 1).
    pub fn edge_map_lanes(
        &mut self,
        src_value: &(dyn Fn(MachineId, &AS, Vid, u32) -> Option<f64> + Sync),
        edge_fn: &(dyn Fn(f64, Vid, Vid, f32) -> Option<f64> + Sync),
        merge: &(dyn Fn(f64, f64) -> f64 + Sync),
        write_back: &(dyn Fn(&mut AS, Vid, u32, f64) -> bool + Sync),
    ) -> usize {
        let p = self.meta.p;
        let flags = self.flags;
        let eff = self.eff_work_pct;
        let meta = Arc::clone(&self.meta);

        // ---- driver: mode decision over active (vertex, lane) pairs —
        // per-lane traffic scales with pairs, so pairs are the honest
        // analog of the single-run frontier stats ----
        let active_total: usize = self.machines.iter().map(|s| s.lane_frontier.len()).sum();
        if active_total == 0 {
            return 0;
        }
        let sum_deg: u64 = self
            .machines
            .iter()
            .flat_map(|s| s.lane_frontier.iter())
            .map(|&(u, _lane)| meta.out_deg[u as usize] as u64)
            .sum();
        let dense = !flags.sparse_mode
            || (sum_deg + active_total as u64) > meta.m as u64 / DENSE_DIV;
        let tree_bcast = !dense && flags.use_trees;
        let scan = dense || flags.full_scan;

        // Tree depth is per-vertex: size the broadcast over the union.
        let d_src = if tree_bcast {
            self.machines
                .iter()
                .flat_map(|s| s.frontier.iter())
                .map(|u| meta.src_tree[u as usize].len())
                .max()
                .unwrap_or(0)
        } else {
            0
        };

        // ---- Phase 1a: owners emit lane-tagged source values ----
        let meta1 = Arc::clone(&meta);
        let mut val_msgs: Vec<Vec<(Vid, u32, f64)>> = self.sub.superstep(
            &mut self.machines,
            no_messages(p),
            move |m, st: &mut MachineState<AS>, _in: Vec<Nothing>, _acct: &mut MachineAcct| {
                st.relay_l.clear();
                st.agg_l.clear();
                st.raw_l.clear();
                st.pending_l.clear();
                st.depth_needed = 0;
                let mut out: Vec<(MachineId, (Vid, u32, f64))> = Vec::new();
                for &(u, lane) in &st.lane_frontier {
                    let Some(val) = src_value(m, &st.algo, u, lane) else { continue };
                    if dense {
                        if flags.dest_aware {
                            for &leaf in &meta1.src_leaves[u as usize] {
                                out.push((leaf, (u, lane, val)));
                            }
                        } else {
                            for t in 0..p {
                                out.push((t, (u, lane, val)));
                            }
                        }
                    } else if flags.use_trees {
                        st.relay_l.insert((u, lane), val);
                        let levels = &meta1.src_tree[u as usize];
                        if let Some(level) = levels.last() {
                            for &(child, parent) in level {
                                if parent == m {
                                    out.push((child, (u, lane, val)));
                                }
                            }
                        }
                    } else {
                        for &leaf in &meta1.src_leaves[u as usize] {
                            out.push((leaf, (u, lane, val)));
                        }
                    }
                }
                out
            },
            |_: &(Vid, u32, f64)| VAL_WORDS + 1,
        );

        // ---- Phase 1b: remaining top-down tree levels ----
        if tree_bcast {
            for d in 1..d_src {
                let meta_d = Arc::clone(&meta);
                val_msgs = self.sub.superstep(
                    &mut self.machines,
                    val_msgs,
                    move |m,
                          st: &mut MachineState<AS>,
                          inbox: Vec<(Vid, u32, f64)>,
                          _acct: &mut MachineAcct| {
                        for (u, lane, val) in inbox {
                            st.relay_l.insert_first((u, lane), val);
                        }
                        st.relay_l.normalize();
                        let mut out = Vec::new();
                        for &(u, lane) in st.relay_l.dirty() {
                            let val = st.relay_l.get((u, lane)).unwrap();
                            let levels = &meta_d.src_tree[u as usize];
                            let k = levels.len();
                            if k <= d {
                                continue; // this vertex's tree is shallower
                            }
                            for &(child, parent) in &levels[k - 1 - d] {
                                if parent == m {
                                    out.push((child, (u, lane, val)));
                                }
                            }
                        }
                        out
                    },
                    |_: &(Vid, u32, f64)| VAL_WORDS + 1,
                );
            }
        }

        // ---- Phase 2: execute f at block machines, all lanes in one
        // block walk (a scan pays the walk once, however many lanes) ----
        if !flags.premerge {
            self.sub.set_msg_factor(RPC_MSG_FACTOR);
        }
        let meta2 = Arc::clone(&meta);
        let mut contrib_msgs: Vec<Vec<(Vid, u32, f64)>> = self.sub.superstep(
            &mut self.machines,
            val_msgs,
            move |m,
                  st: &mut MachineState<AS>,
                  inbox: Vec<(Vid, u32, f64)>,
                  acct: &mut MachineAcct| {
                for (u, lane, val) in inbox {
                    st.relay_l.insert_first((u, lane), val);
                }
                let MachineState {
                    blocks, block_of, relay_l, agg_l, raw_l, pending_l, depth_needed, ..
                } = st;
                // The normalized dirty-list is sorted (vertex, lane), so
                // each source's lanes are one contiguous run
                // ([`LaneSlab::pairs_for`]) — no per-superstep regrouping
                // map; one block walk still serves every lane.
                relay_l.normalize();
                let emit = |v: Vid,
                            lane: u32,
                            cv: f64,
                            agg_l: &mut LaneSlab,
                            raw_l: &mut Vec<(Vid, u32, f64)>| {
                    if flags.premerge {
                        agg_l.merge_with((v, lane), cv, merge);
                    } else {
                        raw_l.push((v, lane, cv));
                    }
                };
                let mut work = 0u64;
                if scan {
                    for block in blocks.iter() {
                        work += block.targets.len() as u64;
                        let lanes = relay_l.pairs_for(block.src);
                        if lanes.is_empty() {
                            continue;
                        }
                        for &(v, w) in &block.targets {
                            for &(u, lane) in lanes {
                                let val = relay_l.get((u, lane)).unwrap();
                                if let Some(cv) = edge_fn(val, block.src, v, w) {
                                    work += 1;
                                    emit(v, lane, cv, agg_l, raw_l);
                                }
                            }
                        }
                    }
                } else {
                    // Walk the dirty-list in per-source runs.
                    let keys = relay_l.dirty();
                    let mut i = 0;
                    while i < keys.len() {
                        let u = keys[i].0;
                        let mut j = i;
                        while j < keys.len() && keys[j].0 == u {
                            j += 1;
                        }
                        for &idx in block_of.get(u) {
                            let block = &blocks[idx as usize];
                            for &(v, w) in &block.targets {
                                for &(uu, lane) in &keys[i..j] {
                                    let val = relay_l.get((uu, lane)).unwrap();
                                    work += 1;
                                    if let Some(cv) = edge_fn(val, u, v, w) {
                                        emit(v, lane, cv, agg_l, raw_l);
                                    }
                                }
                            }
                        }
                        i = j;
                    }
                }
                let mut units = work * eff / 100;
                if flags.round_overhead_n {
                    units += meta2.part.count_on(m) as u64;
                }
                acct.work(units);

                // Emit this machine's contributions toward the owners.
                let mut out: Vec<(MachineId, (Vid, u32, f64))> = Vec::new();
                if flags.premerge {
                    agg_l.normalize();
                    if flags.use_trees {
                        let mut max_d = 0usize;
                        for &(v, lane) in agg_l.dirty() {
                            let val = agg_l.get((v, lane)).unwrap();
                            let levels = &meta2.dst_tree[v as usize];
                            max_d = max_d.max(levels.len());
                            let edge = levels
                                .first()
                                .and_then(|lvl| lvl.iter().find(|&&(c, _)| c == m));
                            match edge {
                                Some(&(_, parent)) => out.push((parent, (v, lane, val))),
                                // No level-0 edge ⟺ this machine is the
                                // root: hold the partial locally.
                                None => {
                                    pending_l.insert((v, lane), val);
                                }
                            }
                        }
                        *depth_needed = max_d;
                    } else {
                        for &(v, lane) in agg_l.dirty() {
                            out.push((
                                meta2.part.owner(v),
                                (v, lane, agg_l.get((v, lane)).unwrap()),
                            ));
                        }
                    }
                } else {
                    for &(v, lane, cv) in raw_l.iter() {
                        out.push((meta2.part.owner(v), (v, lane, cv)));
                    }
                }
                out
            },
            |_: &(Vid, u32, f64)| CONTRIB_WORDS + 1,
        );
        if !flags.premerge {
            self.sub.set_msg_factor(1);
        }

        // ---- Phase 3: remaining destination-tree merge levels ----
        let d_dst = if flags.premerge && flags.use_trees {
            self.machines.iter().map(|s| s.depth_needed).max().unwrap_or(0)
        } else {
            0
        };
        for d in 1..d_dst {
            let meta_d = Arc::clone(&meta);
            contrib_msgs = self.sub.superstep(
                &mut self.machines,
                contrib_msgs,
                move |m,
                      st: &mut MachineState<AS>,
                      inbox: Vec<(Vid, u32, f64)>,
                      _acct: &mut MachineAcct| {
                    for (v, lane, val) in inbox {
                        st.pending_l.merge_with((v, lane), val, merge);
                    }
                    // Indexed walk (`take` leaves dirty indices stable).
                    st.pending_l.normalize();
                    let mut out = Vec::new();
                    for i in 0..st.pending_l.dirty_len() {
                        let (v, lane) = st.pending_l.key_at(i);
                        let levels = &meta_d.dst_tree[v as usize];
                        if levels.len() <= d {
                            continue; // merged out already / root holds it
                        }
                        let Some(&(_, parent)) =
                            levels[d].iter().find(|&&(c, _)| c == m)
                        else {
                            continue; // root (or not yet at this level)
                        };
                        let val = st.pending_l.take((v, lane)).unwrap();
                        out.push((parent, (v, lane, val)));
                    }
                    out
                },
                |_: &(Vid, u32, f64)| CONTRIB_WORDS + 1,
            );
        }

        // ---- Phase 4: per-lane write-backs at destination owners ----
        let meta4 = Arc::clone(&meta);
        let _: Vec<Vec<Nothing>> = self.sub.superstep(
            &mut self.machines,
            contrib_msgs,
            move |m,
                  st: &mut MachineState<AS>,
                  inbox: Vec<(Vid, u32, f64)>,
                  acct: &mut MachineAcct| {
                for (v, lane, val) in inbox {
                    st.pending_l.merge_with((v, lane), val, merge);
                }
                st.pending_l.normalize();
                st.lane_frontier.clear();
                let mut wb = 0u64;
                for i in 0..st.pending_l.dirty_len() {
                    let (v, lane) = st.pending_l.key_at(i);
                    let val = st.pending_l.take((v, lane)).unwrap();
                    debug_assert_eq!(
                        meta4.part.owner(v),
                        m,
                        "contribution for {v} lane {lane} landed on non-owner {m}"
                    );
                    wb += 1;
                    if write_back(&mut st.algo, v, lane, val) {
                        st.lane_frontier.push((v, lane));
                    }
                }
                Self::project_lane_union(st);
                acct.work(wb * eff / 100);
                Vec::new()
            },
            nothing_words,
        );

        self.machines.iter().map(|s| s.lane_frontier.len()).sum()
    }
}

// End-to-end algorithm coverage (all flags × placements × P on both
// backends, against shared reference oracles) lives in
// tests/graph_exec_equivalence.rs; the unit tests here pin the two
// engine-local invariants that suite does not isolate.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn spmd_merge_applied_once_per_destination() {
        // Two frontier vertices pointing at one destination: write_back
        // must see a single merged value.
        let g = Graph::from_arcs(
            3,
            vec![(0, 2, 1.0), (1, 2, 1.0), (2, 0, 1.0), (2, 1, 1.0)],
        );
        let sub = Cluster::new(2, CostModel::paper_cluster());
        let mut engine = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| {
            Vec::<(Vid, f64)>::new()
        });
        engine.clear_frontier();
        engine.set_frontier_single(0);
        let owner1 = engine.meta().part.owner(1);
        engine.machines[owner1].frontier.insert(1);
        engine.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|seen: &mut Vec<(Vid, f64)>, v, val| {
                seen.push((v, val));
                false
            },
        );
        let mut all: Vec<(Vid, f64)> = Vec::new();
        engine.for_each_algo(|_m, seen| all.append(seen));
        assert_eq!(all, vec![(2, 2.0)]);
    }

    #[test]
    fn fused_lanes_evolve_independently() {
        // Two lanes seeded at different sources feeding one destination:
        // each lane's write-back must see ONLY its own contribution —
        // lane isolation is what makes fused bits equal single-run bits.
        let g = Graph::from_arcs(
            3,
            vec![(0, 2, 1.0), (1, 2, 1.0), (2, 0, 1.0), (2, 1, 1.0)],
        );
        let sub = Cluster::new(2, CostModel::paper_cluster());
        let mut engine = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| {
            Vec::<(Vid, u32, f64)>::new()
        });
        engine.set_frontier_lanes(&[(0, 0), (1, 1)]);
        assert_eq!(engine.lane_frontier_len(), 2);
        engine.edge_map_lanes(
            &|_m, _st, _u, lane| Some(if lane == 0 { 1.0 } else { 5.0 }),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|seen: &mut Vec<(Vid, u32, f64)>, v, lane, val| {
                seen.push((v, lane, val));
                false
            },
        );
        let mut all: Vec<(Vid, u32, f64)> = Vec::new();
        engine.for_each_algo(|_m, seen| all.append(seen));
        all.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        assert_eq!(all, vec![(2, 0, 1.0), (2, 1, 5.0)]);
    }

    #[test]
    fn lane_frontier_seed_projection_and_reset() {
        let g = gen::erdos_renyi(40, 160, 3);
        let sub = Cluster::new(4, CostModel::paper_cluster());
        let mut e = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| ());
        // Duplicate pair + two lanes on one vertex: pairs dedup, the
        // vertex projection dedups further.
        e.set_frontier_lanes(&[(3, 1), (3, 0), (7, 2), (3, 1)]);
        assert_eq!(e.lane_frontier_len(), 3, "pairs must dedup");
        assert_eq!(e.frontier_len(), 2, "projection must dedup vertices");
        e.reset_for_query(|_m, _meta, _st| {});
        assert_eq!(e.lane_frontier_len(), 0, "reset must clear lane frontier");
        assert_eq!(e.frontier_len(), 0);
    }

    #[test]
    fn reset_for_query_clears_frontier_and_reinits_state() {
        let g = gen::erdos_renyi(200, 800, 3);
        let sub = Cluster::new(2, CostModel::paper_cluster());
        let mut e = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| 0u64);
        e.set_frontier_all();
        e.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|st: &mut u64, _v, _val| {
                *st += 1;
                true
            },
        );
        assert!(e.frontier_len() > 0, "write-backs should re-activate vertices");
        e.reset_for_query(|_m, _meta, st| *st = 0);
        assert_eq!(e.frontier_len(), 0, "reset must clear the frontier");
        assert_eq!(e.resets(), 1);
        let mut total = 0u64;
        e.for_each_algo(|_m, st| total += *st);
        assert_eq!(total, 0, "reinit hook must run on every shard");
    }

    #[test]
    fn edge_map_respects_frontier() {
        // Only edges out of the frontier may fire (ported from the
        // retired cost-model engine's regression suite).
        let g = gen::grid2d(8, 3);
        let sub = Cluster::new(4, CostModel::paper_cluster());
        let mut engine =
            SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| ());
        engine.set_frontier_single(0);
        let fired = std::sync::Mutex::new(Vec::new());
        engine.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, u, v, _w| {
                fired.lock().unwrap().push((u, v));
                Some(sv)
            },
            &|a, _b| a,
            &|_st, _v, _val| false,
        );
        let mut fired = fired.into_inner().unwrap();
        let mut expected: Vec<(Vid, Vid)> =
            g.neighbors(0).iter().map(|(v, _)| (0, *v)).collect();
        fired.sort_unstable();
        expected.sort_unstable();
        assert_eq!(fired, expected);
    }

    #[test]
    fn dense_mode_supersteps_bounded() {
        // Dense path: broadcast + exec + tree merges + write-back — a
        // bounded number of supersteps regardless of frontier size.
        let g = gen::erdos_renyi(500, 3000, 5);
        let sub = Cluster::new(4, CostModel::paper_cluster());
        let mut engine =
            SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| ());
        engine.set_frontier_all();
        engine.sub_mut().reset_metrics();
        engine.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|_st, _v, _val| false,
        );
        let steps = engine.sub().metrics.supersteps;
        assert!((1..=8).contains(&steps), "dense round took {steps} supersteps");
    }

    #[test]
    fn frontier_parts_roundtrip() {
        let g = gen::erdos_renyi(120, 500, 2);
        let sub = Cluster::new(4, CostModel::paper_cluster());
        let mut e = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| ());
        e.set_frontier_all();
        let parts = e.frontier_parts();
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), e.frontier_len());
        e.clear_frontier();
        assert_eq!(e.frontier_len(), 0);
        e.set_frontier_parts(&parts);
        assert_eq!(e.frontier_len(), 120);
        assert_eq!(e.frontier_parts(), parts);
    }

    #[test]
    fn spmd_work_accounting_populates_ledger() {
        let g = gen::erdos_renyi(400, 2400, 5);
        let sub = Cluster::new(4, CostModel::paper_cluster());
        let mut engine = SpmdEngine::tdo_gp(sub, &g, CostModel::paper_cluster(), |_m, _meta| ());
        engine.sub_mut().reset_metrics();
        engine.set_frontier_all();
        engine.edge_map(
            &|_m, _st, _u| Some(1.0),
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a + b,
            &|_st, _v, _val| false,
        );
        let m = &engine.sub().metrics;
        assert!(m.supersteps > 0, "no supersteps charged");
        assert!(m.work_by_machine.iter().sum::<u64>() > 0, "no work charged");
        assert!(m.total_words > 0, "no communication charged");
    }
}
