//! The engine policy matrix (paper §5.1/§6.1, Table 4).
//!
//! One [`Flags`] block is what distinguishes TDO-GP from every baseline
//! family on the unified SPMD engine ([`crate::graph::spmd::SpmdEngine`]):
//! trees vs direct exchange, pre-merge vs per-edge messages, sparse-dense
//! switching vs full scans, per-round dense-array overheads, and each
//! system's local-engine efficiency.  The T1–T3 ablation knobs of §5.2
//! are the same bits toggled individually.  Because every family is a
//! flag configuration of ONE engine sharing one substrate and one
//! metrics ledger, §6's comparisons are *structural* — they isolate the
//! scheduling/layout policies the paper attributes its wins to.

use crate::CostModel;

/// Policy flags distinguishing TDO-GP from the baseline families, plus
/// the T1–T3 ablation knobs (paper §5.2, Table 4).
///
/// `Eq`/`Hash` exist because the serving layer's result cache keys on
/// the full flag block: two engines with equal flags (and equal graph
/// epoch) produce bit-identical results, so flag equality is result
/// identity ([`crate::serve::cache`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Flags {
    /// Source/destination communication trees (TD-Orch layout).  Off =
    /// direct fan-out/fan-in (mirror-style).
    pub use_trees: bool,
    /// Pre-merge contributions per (machine, destination) before sending
    /// (part of T1).  Off = one message per edge contribution, charged
    /// as an unbatchable RPC ([`crate::bsp::RPC_MSG_FACTOR`]).
    pub premerge: bool,
    /// Dense-mode broadcast only to machines holding the vertex's edges
    /// (part of T1).  Off = broadcast to all P machines.
    pub dest_aware: bool,
    /// Allow the sparse (vertex-centric) mode.  Off = every round is a
    /// dense scan (the linear-algebra family).
    pub sparse_mode: bool,
    /// Charge a full local-edge scan every round regardless of frontier
    /// (the SpMV cost model of Graphite/LA3).
    pub full_scan: bool,
    /// Charge Θ(n/P) per-machine work every round (dense vertex arrays —
    /// the O(n·diam) term of gemini-like systems; also T2-off).
    pub round_overhead_n: bool,
    /// Local-work multiplier x100 (100 = 1.0).  Captures each system's
    /// local-engine efficiency, calibrated from the paper's single
    /// -machine Table 6 (TDO-GP 1.0x; Gemini ~1.6x; LA ~1.4x; GBBS-like
    /// ~1.0x), and the T2/T3 ablation costs (T2-off 2x, T3-off 1.6x).
    pub work_mult_pct: u64,
    /// Whether the local runtime is NUMA-oblivious (ParlayLib-based
    /// TDO-GP and GBBS/Ligra: yes; Gemini/Graphite: no — paper §6.5).
    /// Oblivious engines pay the cluster topology's compute penalty.
    pub numa_oblivious: bool,
}

impl Flags {
    pub fn tdo_gp() -> Self {
        Flags {
            use_trees: true,
            premerge: true,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: false,
            work_mult_pct: 100,
            numa_oblivious: true,
        }
    }

    pub fn gemini_like() -> Self {
        Flags {
            use_trees: false,
            premerge: true,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: true,
            work_mult_pct: 200,
            numa_oblivious: false,
        }
    }

    pub fn la_like() -> Self {
        Flags {
            use_trees: false,
            premerge: true,
            dest_aware: true,
            sparse_mode: false,
            full_scan: true,
            round_overhead_n: true,
            work_mult_pct: 150,
            numa_oblivious: false,
        }
    }

    pub fn ligra_dist() -> Self {
        Flags {
            use_trees: false,
            premerge: false,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: false,
            // Ligra/GBBS local engines trail TDO-GP's lightweight local
            // EDGEMAP (paper Table 3 P=1: 5.36 vs 4.54; Table 6).
            work_mult_pct: 120,
            numa_oblivious: true,
        }
    }

    /// Apply the T1/T2/T3 ablation toggles to a TDO-GP engine.
    /// T1-off removes the tree-based dedup/aggregation and the
    /// destination-aware broadcast (contributions still pre-merge per
    /// machine, as any MPI code would, but fan in directly).
    pub fn with_techniques(t1: bool, t2: bool, t3: bool) -> Self {
        let mut f = Self::tdo_gp();
        if !t1 {
            f.use_trees = false;
            f.dest_aware = false;
        }
        if !t2 {
            f.work_mult_pct = f.work_mult_pct * 200 / 100;
            f.round_overhead_n = true;
        }
        if !t3 {
            f.work_mult_pct = f.work_mult_pct * 160 / 100;
        }
        f
    }

    /// The three labeled technique-ablation profiles of Table 4, stated
    /// ONCE: the figure paths, the `repro graphs --quick` CI smoke, the
    /// transition tests and the benches all draw the same bit-toggles
    /// from here, so a recalibration or typo cannot make the enforcers
    /// silently assert different ablations.
    pub fn ablations() -> [(&'static str, Flags); 3] {
        [
            ("-T1", Self::with_techniques(false, true, true)),
            ("-T2", Self::with_techniques(true, false, true)),
            ("-T3", Self::with_techniques(true, true, false)),
        ]
    }

    /// Effective local-work multiplier x100 for this flags/cost pair:
    /// engine base x NUMA penalty (NUMA-oblivious runtimes pay the
    /// topology's compute penalty; NUMA-aware ones don't — §6.5).
    pub fn effective_pct(&self, cost: CostModel) -> u64 {
        let numa_pct = if self.numa_oblivious {
            (cost.numa.compute_penalty() * 100.0).round() as u64
        } else {
            100
        };
        self.work_mult_pct * numa_pct / 100
    }
}

/// Fraction divisor for the sparse→dense switch: dense when
/// Σdeg(U) + |U| > m / DENSE_DIV (Ligra's heuristic, paper §5.1).
pub(crate) const DENSE_DIV: u64 = 20;

/// Words on the wire for a (vertex, value) pair.
pub(crate) const VAL_WORDS: u64 = 2;
/// Words for a contribution message {v, value, tag}.
pub(crate) const CONTRIB_WORDS: u64 = 3;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablations_strictly_raise_cost_knobs() {
        let full = Flags::tdo_gp();
        let no_t1 = Flags::with_techniques(false, true, true);
        assert!(!no_t1.use_trees && !no_t1.dest_aware);
        assert_eq!(no_t1.work_mult_pct, full.work_mult_pct);
        let no_t2 = Flags::with_techniques(true, false, true);
        assert!(no_t2.round_overhead_n);
        assert_eq!(no_t2.work_mult_pct, 200);
        let no_t3 = Flags::with_techniques(true, true, false);
        assert_eq!(no_t3.work_mult_pct, 160);
    }

    #[test]
    fn effective_pct_applies_numa_penalty_to_oblivious_engines_only() {
        let cost = CostModel::paper_cluster(); // Square4: 1.55x penalty
        assert_eq!(Flags::tdo_gp().effective_pct(cost), 155);
        // Gemini is NUMA-aware: base multiplier only.
        assert_eq!(Flags::gemini_like().effective_pct(cost), 200);
        let single = CostModel::single_numa();
        assert_eq!(Flags::tdo_gp().effective_pct(single), 100);
    }
}
