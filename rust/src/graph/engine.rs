//! The `DistEdgeMap` execution engine (paper §5.1, Fig 6).
//!
//! One engine core implements the read→execute→merge→write-back round;
//! a [`Flags`] block selects between TDO-GP (source/destination trees,
//! per-machine pre-merge, destination-aware broadcast, sparse-dense
//! switching) and the baseline families' policies (direct exchange,
//! per-edge messages, full scans, per-round vertex-array overheads).
//! This makes §6's comparisons *structural*: every engine shares the
//! same substrate, metrics, and algorithm code.
//!
//! Simulation note: lambdas read vertex values through the algorithm's
//! own state arrays, while the engine charges the messages a real
//! deployment would need to deliver those values (down source trees /
//! broadcast) and to return write-backs (up destination trees / direct).
//! BSP phase separation (all `f` reads happen before any `write_back`
//! mutation) keeps the simulated semantics equal to the distributed ones.
//!
//! This module is the *cost-model* engine behind every paper figure.  Its
//! SPMD sibling, [`crate::graph::spmd::SpmdEngine`], implements the same
//! round with machine-private shards and real value-carrying messages
//! over the [`crate::exec::Substrate`] trait, so it runs unchanged on the
//! simulator **and** on [`crate::exec::ThreadedCluster`]'s worker pool;
//! `tests/graph_exec_equivalence.rs` pins the two engines and the two
//! substrates together.

use crate::bsp::Cluster;
use crate::det::{det_map, DetMap};
use crate::metrics::Metrics;

use super::ingest::{ingest, ingest_at_owner, tree_levels, DistGraph};
use super::subset::DistVertexSubset;
use super::{Graph, VertexPart, Vid};

/// Policy flags distinguishing TDO-GP from the baseline families, plus
/// the T1–T3 ablation knobs (paper §5.2, Table 4).
#[derive(Clone, Copy, Debug)]
pub struct Flags {
    /// Source/destination communication trees (TD-Orch layout).  Off =
    /// direct fan-out/fan-in (mirror-style).
    pub use_trees: bool,
    /// Pre-merge contributions per (machine, destination) before sending
    /// (part of T1).  Off = one message per edge contribution.
    pub premerge: bool,
    /// Dense-mode broadcast only to machines holding the vertex's edges
    /// (part of T1).  Off = broadcast to all P machines.
    pub dest_aware: bool,
    /// Allow the sparse (vertex-centric) mode.  Off = every round is a
    /// dense scan (the linear-algebra family).
    pub sparse_mode: bool,
    /// Charge a full local-edge scan every round regardless of frontier
    /// (the SpMV cost model of Graphite/LA3).
    pub full_scan: bool,
    /// Charge Θ(n/P) per-machine work every round (dense vertex arrays —
    /// the O(n·diam) term of gemini-like systems; also T2-off).
    pub round_overhead_n: bool,
    /// Local-work multiplier x100 (100 = 1.0).  Captures each system's
    /// local-engine efficiency, calibrated from the paper's single
    /// -machine Table 6 (TDO-GP 1.0x; Gemini ~1.6x; LA ~1.4x; GBBS-like
    /// ~1.0x), and the T2/T3 ablation costs (T2-off 2x, T3-off 1.6x).
    pub work_mult_pct: u64,
    /// Whether the local runtime is NUMA-oblivious (ParlayLib-based
    /// TDO-GP and GBBS/Ligra: yes; Gemini/Graphite: no — paper §6.5).
    /// Oblivious engines pay the cluster topology's compute penalty.
    pub numa_oblivious: bool,
}

impl Flags {
    pub fn tdo_gp() -> Self {
        Flags {
            use_trees: true,
            premerge: true,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: false,
            work_mult_pct: 100,
            numa_oblivious: true,
        }
    }

    pub fn gemini_like() -> Self {
        Flags {
            use_trees: false,
            premerge: true,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: true,
            work_mult_pct: 200,
            numa_oblivious: false,
        }
    }

    pub fn la_like() -> Self {
        Flags {
            use_trees: false,
            premerge: true,
            dest_aware: true,
            sparse_mode: false,
            full_scan: true,
            round_overhead_n: true,
            work_mult_pct: 150,
            numa_oblivious: false,
        }
    }

    pub fn ligra_dist() -> Self {
        Flags {
            use_trees: false,
            premerge: false,
            dest_aware: true,
            sparse_mode: true,
            full_scan: false,
            round_overhead_n: false,
            // Ligra/GBBS local engines trail TDO-GP's lightweight local
            // EDGEMAP (paper Table 3 P=1: 5.36 vs 4.54; Table 6).
            work_mult_pct: 120,
            numa_oblivious: true,
        }
    }

    /// Apply the T1/T2/T3 ablation toggles to a TDO-GP engine.
    /// T1-off removes the tree-based dedup/aggregation and the
    /// destination-aware broadcast (contributions still pre-merge per
    /// machine, as any MPI code would, but fan in directly).
    pub fn with_techniques(t1: bool, t2: bool, t3: bool) -> Self {
        let mut f = Self::tdo_gp();
        if !t1 {
            f.use_trees = false;
            f.dest_aware = false;
        }
        if !t2 {
            f.work_mult_pct = f.work_mult_pct * 200 / 100;
            f.round_overhead_n = true;
        }
        if !t3 {
            f.work_mult_pct = f.work_mult_pct * 160 / 100;
        }
        f
    }
}

/// Fraction divisor for the sparse→dense switch: dense when
/// Σdeg(U) + |U| > m / DENSE_DIV (Ligra's heuristic, paper §5.1).
/// Shared with the SPMD engine ([`crate::graph::spmd`]) so both make the
/// same mode decision on the same frontier.
pub(crate) const DENSE_DIV: u64 = 20;

/// Words on the wire for a (vertex, value) pair.
pub(crate) const VAL_WORDS: u64 = 2;
/// Words for a contribution message {v, value, tag}.
pub(crate) const CONTRIB_WORDS: u64 = 3;

/// The abstract engine interface the five graph algorithms run against.
pub trait GraphEngine {
    fn label(&self) -> &str;
    fn part(&self) -> &VertexPart;
    fn n(&self) -> usize;
    fn m(&self) -> usize;
    fn out_degree(&self, u: Vid) -> u64;
    fn cluster_mut(&mut self) -> &mut Cluster;
    fn metrics(&self) -> &Metrics;

    /// Charge `units` of work on every machine (algorithm-level local
    /// sweeps such as PR's base-rank init).
    fn charge_local(&mut self, units_per_machine: u64);

    /// DISTEDGEMAP (Fig 6): apply `f` to every edge (u, v) with u in the
    /// frontier, ⊗-merge returned values per destination with `merge`,
    /// apply `write_back` at each destination's owner, and return the
    /// subset of destinations whose write_back returned true.
    fn edge_map<S>(
        &mut self,
        state: &mut S,
        frontier: &DistVertexSubset,
        f: &mut dyn FnMut(&S, Vid, Vid, f32) -> Option<f64>,
        merge: &dyn Fn(f64, f64) -> f64,
        write_back: &mut dyn FnMut(&mut S, Vid, f64) -> bool,
    ) -> DistVertexSubset;
}

/// The unified engine (TDO-GP or a baseline, depending on flags +
/// placement).
pub struct Engine {
    pub dg: DistGraph,
    pub cluster: Cluster,
    pub flags: Flags,
    label: String,
    /// Effective local-work multiplier x100: engine base x NUMA penalty.
    eff_work_pct: u64,
}

impl Engine {
    /// TDO-GP with default techniques.
    pub fn tdo_gp(g: &Graph, p: usize, cost: crate::CostModel) -> Self {
        Self::tdo_gp_with(g, p, cost, Flags::tdo_gp(), "tdo-gp")
    }

    /// TDO-GP with explicit flags (ablations).
    pub fn tdo_gp_with(
        g: &Graph,
        p: usize,
        cost: crate::CostModel,
        flags: Flags,
        label: &str,
    ) -> Self {
        let mut cluster = Cluster::new(p, cost);
        let c = crate::forest::Forest::default_fanout(p).max(4);
        let dg = ingest(&mut cluster, g, c);
        let eff_work_pct = Self::effective_pct(&flags, cost);
        Engine { dg, cluster, flags, label: label.to_string(), eff_work_pct }
    }

    /// Baseline constructor: owner placement + family flags.
    pub fn baseline(
        g: &Graph,
        p: usize,
        cost: crate::CostModel,
        flags: Flags,
        label: &str,
    ) -> Self {
        let mut cluster = Cluster::new(p, cost);
        let c = crate::forest::Forest::default_fanout(p).max(4);
        let dg = ingest_at_owner(&mut cluster, g, c);
        let eff_work_pct = Self::effective_pct(&flags, cost);
        Engine { dg, cluster, flags, label: label.to_string(), eff_work_pct }
    }

    /// Effective local-work multiplier x100 for a flags/cost pair — also
    /// used by the SPMD engine so both charge identical work units.
    pub(crate) fn effective_pct(flags: &Flags, cost: crate::CostModel) -> u64 {
        let numa_pct = if flags.numa_oblivious {
            (cost.numa.compute_penalty() * 100.0).round() as u64
        } else {
            100
        };
        flags.work_mult_pct * numa_pct / 100
    }

    /// Exclude ingestion from measured metrics (the paper times queries,
    /// not loading).
    pub fn reset_metrics(&mut self) {
        self.cluster.reset_metrics();
    }

    #[inline]
    fn scaled(&self, units: u64) -> u64 {
        units * self.eff_work_pct / 100
    }
}

impl GraphEngine for Engine {
    fn label(&self) -> &str {
        &self.label
    }

    fn part(&self) -> &VertexPart {
        &self.dg.part
    }

    fn n(&self) -> usize {
        self.dg.n
    }

    fn m(&self) -> usize {
        self.dg.m
    }

    fn out_degree(&self, u: Vid) -> u64 {
        self.dg.out_deg[u as usize] as u64
    }

    fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    fn metrics(&self) -> &Metrics {
        &self.cluster.metrics
    }

    fn charge_local(&mut self, units_per_machine: u64) {
        let u = self.scaled(units_per_machine);
        for m in 0..self.cluster.p {
            self.cluster.work(m, u);
        }
        self.cluster.barrier();
    }

    fn edge_map<S>(
        &mut self,
        state: &mut S,
        frontier: &DistVertexSubset,
        f: &mut dyn FnMut(&S, Vid, Vid, f32) -> Option<f64>,
        merge: &dyn Fn(f64, f64) -> f64,
        write_back: &mut dyn FnMut(&mut S, Vid, f64) -> bool,
    ) -> DistVertexSubset {
        let p = self.cluster.p;
        let part = self.dg.part.clone();
        let next = DistVertexSubset::empty(&part);
        if frontier.is_empty() {
            return next;
        }
        let active = frontier.iter_all(&part);
        let sum_deg: u64 = active.iter().map(|u| self.dg.out_deg[*u as usize] as u64).sum();
        let dense = !self.flags.sparse_mode
            || (sum_deg + active.len() as u64) > self.dg.m as u64 / DENSE_DIV;

        // ---- Phase 1: deliver source values to edge-block machines ----
        if dense {
            // One broadcast superstep.
            for &u in &active {
                let owner = part.owner(u);
                if self.flags.dest_aware {
                    for &leaf in &self.dg.src_leaves[u as usize] {
                        self.cluster.account_msg(owner, leaf, VAL_WORDS);
                    }
                } else {
                    for t in 0..p {
                        self.cluster.account_msg(owner, t, VAL_WORDS);
                    }
                }
            }
            self.cluster.barrier();
        } else if self.flags.use_trees {
            // Top-down source-tree broadcast, level-synchronous.
            let mut depth_msgs: Vec<Vec<(usize, usize)>> = Vec::new();
            for &u in &active {
                let leaves = &self.dg.src_leaves[u as usize];
                let owner = part.owner(u);
                let levels = tree_levels(u as u64, leaves, owner, self.dg.c, p);
                // `levels` is bottom-up; broadcast replays it top-down
                // with direction reversed.
                for (d, level) in levels.iter().rev().enumerate() {
                    if depth_msgs.len() <= d {
                        depth_msgs.push(Vec::new());
                    }
                    for (child, parent) in level {
                        depth_msgs[d].push((*parent, *child));
                    }
                }
            }
            for level in depth_msgs {
                for (from, to) in level {
                    self.cluster.account_msg(from, to, VAL_WORDS);
                }
                self.cluster.barrier();
            }
        } else {
            // Direct fan-out from each owner (mirror-style).
            for &u in &active {
                let owner = part.owner(u);
                for &leaf in &self.dg.src_leaves[u as usize] {
                    self.cluster.account_msg(owner, leaf, VAL_WORDS);
                }
            }
            self.cluster.barrier();
        }

        // ---- Phase 2: execute f at block machines, gather contributions
        let mut work = vec![0u64; p];
        let mut contribs: Vec<DetMap<Vid, f64>> = (0..p).map(|_| det_map()).collect();
        let mut raw: Vec<Vec<(Vid, f64)>> = (0..p).map(|_| Vec::new()).collect();

        let emit = |mach: usize,
                        v: Vid,
                        cv: f64,
                        contribs: &mut Vec<DetMap<Vid, f64>>,
                        raw: &mut Vec<Vec<(Vid, f64)>>| {
            if self.flags.premerge {
                // In-place ⊗ with a single hash lookup (hot loop).
                contribs[mach]
                    .entry(v)
                    .and_modify(|acc| *acc = merge(*acc, cv))
                    .or_insert(cv);
            } else {
                raw[mach].push((v, cv));
            }
        };

        if dense || self.flags.full_scan {
            for mach in 0..p {
                for block in &self.dg.blocks[mach] {
                    work[mach] += block.targets.len() as u64;
                    if !frontier.contains(&part, block.src) {
                        continue;
                    }
                    for (v, w) in &block.targets {
                        if let Some(cv) = f(state, block.src, *v, *w) {
                            work[mach] += 1;
                            emit(mach, *v, cv, &mut contribs, &mut raw);
                        }
                    }
                }
            }
        } else {
            for &u in &active {
                for &mach in &self.dg.src_leaves[u as usize] {
                    let Some(idxs) = self.dg.block_of[mach].get(&u) else { continue };
                    for &idx in idxs {
                        let block = &self.dg.blocks[mach][idx as usize];
                        for (v, w) in &block.targets {
                            work[mach] += 1;
                            if let Some(cv) = f(state, u, *v, *w) {
                                emit(mach, *v, cv, &mut contribs, &mut raw);
                            }
                        }
                    }
                }
            }
        }
        for m in 0..p {
            let mut units = self.scaled(work[m]);
            if self.flags.round_overhead_n {
                units += self.dg.part.count_on(m) as u64;
            }
            self.cluster.work(m, units);
        }
        self.cluster.barrier();

        // ---- Phase 3: aggregate contributions to destination owners ----
        // per destination: (merged value, contributing machines).
        let mut per_v: DetMap<Vid, (f64, Vec<usize>)> = det_map();
        if self.flags.premerge {
            for (mach, cmap) in contribs.iter_mut().enumerate() {
                for (v, val) in cmap.drain() {
                    per_v
                        .entry(v)
                        .and_modify(|(acc, members)| {
                            *acc = merge(*acc, val);
                            members.push(mach);
                        })
                        .or_insert_with(|| (val, vec![mach]));
                }
            }
            if self.flags.use_trees {
                // Destination-tree merge, level-synchronous.
                let mut depth_msgs: Vec<Vec<(usize, usize)>> = Vec::new();
                for (v, (_, members)) in per_v.iter_mut() {
                    members.sort_unstable();
                    let owner = part.owner(*v);
                    let levels = tree_levels(*v as u64 ^ 0xD5, members, owner, self.dg.c, p);
                    for (d, level) in levels.iter().enumerate() {
                        if depth_msgs.len() <= d {
                            depth_msgs.push(Vec::new());
                        }
                        depth_msgs[d].extend(level.iter().copied());
                    }
                }
                for level in depth_msgs {
                    for (from, to) in level {
                        self.cluster.account_msg(from, to, CONTRIB_WORDS);
                    }
                    self.cluster.barrier();
                }
            } else {
                for (v, (_, members)) in per_v.iter() {
                    let owner = part.owner(*v);
                    for &mach in members {
                        self.cluster.account_msg(mach, owner, CONTRIB_WORDS);
                    }
                }
                self.cluster.barrier();
            }
        } else {
            // Per-edge messages straight to the destination owner — the
            // "direct pull" prototype: each cross-machine edge costs a
            // request plus a reply (no aggregation anywhere).
            for (mach, list) in raw.iter_mut().enumerate() {
                for (v, val) in list.drain(..) {
                    let owner = part.owner(v);
                    self.cluster.account_rpc(mach, owner, CONTRIB_WORDS);
                    per_v
                        .entry(v)
                        .and_modify(|(acc, _)| *acc = merge(*acc, val))
                        .or_insert_with(|| (val, vec![mach]));
                }
            }
            self.cluster.barrier();
        }

        // ---- Phase 4: write-backs at destination owners ----
        let mut next = next;
        let mut keys: Vec<Vid> = per_v.keys().copied().collect();
        keys.sort_unstable();
        let mut wb_work = vec![0u64; p];
        for v in keys {
            let (acc, _) = per_v.remove(&v).unwrap();
            let owner = part.owner(v);
            wb_work[owner] += 1;
            if write_back(state, v, acc) {
                next.insert(&part, v);
            }
        }
        for m in 0..p {
            self.cluster.work(m, self.scaled(wb_work[m]));
        }
        self.cluster.barrier();
        next
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::CostModel;

    /// Plain BFS reference on the raw graph.
    fn bfs_ref(g: &Graph, src: Vid) -> Vec<i64> {
        let mut dist = vec![-1i64; g.n];
        dist[src as usize] = 0;
        let mut q = std::collections::VecDeque::from([src]);
        while let Some(u) = q.pop_front() {
            for (v, _) in g.neighbors(u) {
                if dist[*v as usize] < 0 {
                    dist[*v as usize] = dist[u as usize] + 1;
                    q.push_back(*v);
                }
            }
        }
        dist
    }

    /// Minimal BFS written against the engine, exercising edge_map.
    fn bfs_engine<E: GraphEngine>(engine: &mut E, src: Vid) -> Vec<i64> {
        let part = engine.part().clone();
        let mut dist = vec![-1i64; engine.n()];
        dist[src as usize] = 0;
        let mut frontier = DistVertexSubset::single(&part, src);
        let mut round = 0i64;
        while !frontier.is_empty() {
            round += 1;
            let r = round;
            frontier = engine.edge_map(
                &mut dist,
                &frontier,
                &mut |_, _, _, _| Some(r as f64),
                &|a, b| a.min(b),
                &mut |dist, v, val| {
                    if dist[v as usize] < 0 {
                        dist[v as usize] = val as i64;
                        true
                    } else {
                        false
                    }
                },
            );
        }
        dist
    }

    #[test]
    fn edge_map_bfs_matches_reference_all_engines() {
        let g = gen::barabasi_albert(1500, 5, 11);
        let expected = bfs_ref(&g, 0);
        let cost = CostModel::paper_cluster();
        for (label, mut engine) in [
            ("tdo", Engine::tdo_gp(&g, 8, cost)),
            ("gem", Engine::baseline(&g, 8, cost, Flags::gemini_like(), "gemini-like")),
            ("la", Engine::baseline(&g, 8, cost, Flags::la_like(), "la-like")),
            ("lig", Engine::baseline(&g, 8, cost, Flags::ligra_dist(), "ligra-dist")),
        ] {
            let got = bfs_engine(&mut engine, 0);
            assert_eq!(got, expected, "{label}");
        }
    }

    #[test]
    fn edge_map_respects_frontier() {
        // Only edges out of the frontier may fire.
        let g = gen::grid2d(8, 3);
        let mut engine = Engine::tdo_gp(&g, 4, CostModel::paper_cluster());
        let part = engine.part().clone();
        let frontier = DistVertexSubset::single(&part, 0);
        let mut state = ();
        let mut fired = Vec::new();
        engine.edge_map(
            &mut state,
            &frontier,
            &mut |_, u, v, _| {
                fired.push((u, v));
                Some(1.0)
            },
            &|a, _| a,
            &mut |_, _, _| false,
        );
        let mut expected: Vec<(Vid, Vid)> =
            g.neighbors(0).iter().map(|(v, _)| (0, *v)).collect();
        fired.sort_unstable();
        expected.sort_unstable();
        assert_eq!(fired, expected);
    }

    #[test]
    fn merge_applied_once_per_destination() {
        // Two frontier vertices pointing at one destination: write_back
        // must see a single merged value.
        let g = Graph::from_arcs(
            3,
            vec![(0, 2, 1.0), (1, 2, 1.0), (2, 0, 1.0), (2, 1, 1.0)],
        );
        let mut engine = Engine::tdo_gp(&g, 2, CostModel::paper_cluster());
        let part = engine.part().clone();
        let mut frontier = DistVertexSubset::empty(&part);
        frontier.insert(&part, 0);
        frontier.insert(&part, 1);
        let mut seen: Vec<(Vid, f64)> = Vec::new();
        engine.edge_map(
            &mut seen,
            &frontier,
            &mut |_, _, _, _| Some(1.0),
            &|a, b| a + b,
            &mut |seen, v, val| {
                seen.push((v, val));
                false
            },
        );
        assert_eq!(seen, vec![(2, 2.0)]);
    }

    #[test]
    fn dense_mode_engages_on_large_frontier() {
        let g = gen::erdos_renyi(500, 3000, 5);
        let mut engine = Engine::tdo_gp(&g, 4, CostModel::paper_cluster());
        let part = engine.part().clone();
        let all = DistVertexSubset::all(&part);
        let before = engine.metrics().supersteps;
        let mut state = ();
        engine.edge_map(
            &mut state,
            &all,
            &mut |_, _, _, _| Some(1.0),
            &|a, b| a + b,
            &mut |_, _, _| false,
        );
        // Dense path: 1 broadcast + 1 exec + tree/merge + wb supersteps —
        // bounded regardless of frontier size.
        let steps = engine.metrics().supersteps - before;
        assert!(steps <= 8, "dense round took {steps} supersteps");
    }

    #[test]
    fn tdo_balances_hub_work_vs_owner_placement() {
        // A hub whose degree exceeds m/P cannot be balanced by vertex
        // partitioning alone: TDO-GP's transit-machine blocks must beat
        // owner placement on a full-frontier round.
        let mut arcs = Vec::new();
        for v in 1..3000u32 {
            arcs.push((0, v, 1.0));
            arcs.push((v, 0, 1.0));
            let w = if v == 2999 { 1 } else { v + 1 };
            arcs.push((v, w, 1.0));
            arcs.push((w, v, 1.0));
        }
        let g = Graph::from_arcs(3000, arcs);
        let cost = CostModel::paper_cluster();
        let run = |mut engine: Engine| {
            let part = engine.part().clone();
            let all = DistVertexSubset::all(&part);
            engine.reset_metrics();
            let mut state = ();
            engine.edge_map(
                &mut state,
                &all,
                &mut |_, _, _, _| Some(1.0),
                &|a, b| a + b,
                &mut |_, _, _| false,
            );
            engine.metrics().work_imbalance()
        };
        let tdo = run(Engine::tdo_gp(&g, 8, cost));
        let gem = run(Engine::baseline(&g, 8, cost, Flags::gemini_like(), "gemini-like"));
        assert!(
            tdo < gem,
            "tdo imbalance {tdo:.2} should beat owner placement {gem:.2}"
        );
    }

    #[test]
    fn ablation_flags_cost_more() {
        let g = gen::barabasi_albert(2000, 6, 17);
        let cost = CostModel::paper_cluster();
        let run = |flags: Flags| {
            let mut engine = Engine::tdo_gp_with(&g, 8, cost, flags, "x");
            let part = engine.part().clone();
            engine.reset_metrics();
            let mut dist = vec![-1i64; engine.n()];
            dist[0] = 0;
            let mut frontier = DistVertexSubset::single(&part, 0);
            let mut round = 0i64;
            while !frontier.is_empty() && round < 50 {
                round += 1;
                let r = round;
                frontier = engine.edge_map(
                    &mut dist,
                    &frontier,
                    &mut |_, _, _, _| Some(r as f64),
                    &|a, b| a.min(b),
                    &mut |dist, v, val| {
                        if dist[v as usize] < 0 {
                            dist[v as usize] = val as i64;
                            true
                        } else {
                            false
                        }
                    },
                );
            }
            engine.metrics().sim_seconds()
        };
        let full = run(Flags::tdo_gp());
        let no_t1 = run(Flags::with_techniques(false, true, true));
        let no_t2 = run(Flags::with_techniques(true, false, true));
        let no_t3 = run(Flags::with_techniques(true, true, false));
        assert!(no_t1 > full, "no_t1 {no_t1} !> {full}");
        assert!(no_t2 > full, "no_t2 {no_t2} !> {full}");
        assert!(no_t3 > full, "no_t3 {no_t3} !> {full}");
    }
}
