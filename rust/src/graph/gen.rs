//! Synthetic graph generators — stand-ins for the paper's datasets
//! (DESIGN.md §2): Erdős–Rényi (unskewed, Fig 9), Barabási–Albert
//! (power-law, the social-network family, γ≈2.2 per §6.3), RMAT
//! (web-like skew), 2-D grid (road-network family: high diameter,
//! bounded degree), and a community-ring hybrid (web-graph family:
//! skewed *and* high-diameter, like uk-2005 / Hyperlink).
//!
//! All generators emit symmetric weighted graphs.

use super::{Graph, Vid};
use crate::rng::Rng;

fn symmetrize(arcs: &mut Vec<(Vid, Vid, f32)>) {
    let fwd: Vec<(Vid, Vid, f32)> = arcs.clone();
    for (u, v, w) in fwd {
        arcs.push((v, u, w));
    }
}

fn rand_weight(rng: &mut Rng) -> f32 {
    1.0 + rng.next_f32() * 9.0
}

/// Erdős–Rényi G(n, m): `m_target` undirected edges chosen uniformly.
pub fn erdos_renyi(n: usize, m_target: usize, seed: u64) -> Graph {
    let mut rng = Rng::new(seed);
    let mut arcs = Vec::with_capacity(m_target * 2);
    for _ in 0..m_target {
        let u = rng.next_below(n as u64) as Vid;
        let v = rng.next_below(n as u64) as Vid;
        if u != v {
            arcs.push((u, v, rand_weight(&mut rng)));
        }
    }
    symmetrize(&mut arcs);
    Graph::from_arcs(n, arcs)
}

/// Barabási–Albert preferential attachment with `k` edges per new vertex:
/// power-law degree distribution (exponent ≈ 3 classically; attachment by
/// sampling endpoints of existing edges reproduces the heavy tail the
/// paper's social graphs exhibit).
pub fn barabasi_albert(n: usize, k: usize, seed: u64) -> Graph {
    assert!(n > k && k >= 1);
    let mut rng = Rng::new(seed);
    let mut arcs: Vec<(Vid, Vid, f32)> = Vec::with_capacity(n * k * 2);
    // Endpoint pool: sampling uniformly from it = preferential attachment.
    let mut pool: Vec<Vid> = Vec::with_capacity(n * k * 2);
    // Seed clique over the first k+1 vertices.
    for u in 0..=(k as Vid) {
        for v in 0..u {
            arcs.push((u, v, rand_weight(&mut rng)));
            pool.push(u);
            pool.push(v);
        }
    }
    for u in (k as Vid + 1)..(n as Vid) {
        for _ in 0..k {
            let v = pool[rng.next_usize(pool.len())];
            if v != u {
                arcs.push((u, v, rand_weight(&mut rng)));
                pool.push(u);
                pool.push(v);
            }
        }
    }
    symmetrize(&mut arcs);
    Graph::from_arcs(n, arcs)
}

/// RMAT (Kronecker-style) generator with the classic (0.57, 0.19, 0.19,
/// 0.05) partition probabilities — web-graph skew.
pub fn rmat(scale: u32, m_target: usize, seed: u64) -> Graph {
    let n = 1usize << scale;
    let mut rng = Rng::new(seed);
    let mut arcs = Vec::with_capacity(m_target * 2);
    for _ in 0..m_target {
        let (mut u, mut v) = (0u64, 0u64);
        for _ in 0..scale {
            let r = rng.next_f64();
            let (bu, bv) = if r < 0.57 {
                (0, 0)
            } else if r < 0.76 {
                (0, 1)
            } else if r < 0.95 {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | bu;
            v = (v << 1) | bv;
        }
        if u != v {
            arcs.push((u as Vid, v as Vid, rand_weight(&mut rng)));
        }
    }
    symmetrize(&mut arcs);
    Graph::from_arcs(n, arcs)
}

/// 2-D grid (4-neighbor torus-free): the road-network stand-in — diameter
/// Θ(√n), max degree 4.
pub fn grid2d(side: usize, seed: u64) -> Graph {
    let n = side * side;
    let mut rng = Rng::new(seed);
    let id = |r: usize, c: usize| (r * side + c) as Vid;
    let mut arcs = Vec::with_capacity(n * 4);
    for r in 0..side {
        for c in 0..side {
            if c + 1 < side {
                arcs.push((id(r, c), id(r, c + 1), rand_weight(&mut rng)));
            }
            if r + 1 < side {
                arcs.push((id(r, c), id(r + 1, c), rand_weight(&mut rng)));
            }
        }
    }
    symmetrize(&mut arcs);
    Graph::from_arcs(n, arcs)
}

/// Ring of `communities` BA communities bridged by single edges: skewed
/// degree distribution *and* diameter Θ(communities) — the web-graph
/// (uk-2005 / Hyperlink) stand-in.
pub fn community_ring(n: usize, communities: usize, k: usize, seed: u64) -> Graph {
    assert!(communities >= 1);
    let per = n / communities;
    assert!(per > k + 1);
    let mut rng = Rng::new(seed);
    let mut arcs: Vec<(Vid, Vid, f32)> = Vec::new();
    for c in 0..communities {
        let base = (c * per) as Vid;
        let local = barabasi_albert(per, k, seed ^ (c as u64 + 1));
        for u in 0..local.n as Vid {
            for (v, w) in local.neighbors(u) {
                arcs.push((base + u, base + v, *w));
            }
        }
        // Bridge to the next community.
        let next_base = (((c + 1) % communities) * per) as Vid;
        let a = base + rng.next_below(per as u64) as Vid;
        let b = next_base + rng.next_below(per as u64) as Vid;
        arcs.push((a, b, rand_weight(&mut rng)));
        arcs.push((b, a, rand_weight(&mut rng)));
    }
    Graph::from_arcs(communities * per, arcs)
}

/// Named dataset stand-ins for Table 2 (scaled ~1000x down; see DESIGN.md).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    RedditLike,
    UkLike,
    TwitterLike,
    FriendsterLike,
    HyperlinkLike,
    RoadLike,
}

impl Dataset {
    pub const ALL: [Dataset; 6] = [
        Dataset::RedditLike,
        Dataset::UkLike,
        Dataset::TwitterLike,
        Dataset::FriendsterLike,
        Dataset::HyperlinkLike,
        Dataset::RoadLike,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Dataset::RedditLike => "reddit-like (BA)",
            Dataset::UkLike => "uk-like (community ring)",
            Dataset::TwitterLike => "twitter-like (BA hub-heavy)",
            Dataset::FriendsterLike => "friendster-like (ER+BA)",
            Dataset::HyperlinkLike => "hyperlink-like (RMAT)",
            Dataset::RoadLike => "road-like (grid)",
        }
    }

    /// Machines used in Table 2 for this dataset (paper: proportional to
    /// dataset size).
    pub fn machines(self) -> usize {
        match self {
            Dataset::RedditLike => 4,
            Dataset::UkLike | Dataset::TwitterLike | Dataset::FriendsterLike => 8,
            Dataset::HyperlinkLike | Dataset::RoadLike => 16,
        }
    }

    pub fn build(self, seed: u64) -> Graph {
        match self {
            // Dense social graph, m/n ~ 24 (reddit: 49).
            Dataset::RedditLike => barabasi_albert(16_000, 12, seed),
            // Skew + diameter ~ community count (uk-2005: diam 276).
            Dataset::UkLike => community_ring(64_000, 128, 4, seed),
            // Hub-heavy social graph (twitter).
            Dataset::TwitterLike => barabasi_albert(50_000, 10, seed),
            // Larger, less skewed social graph (friendster).
            Dataset::FriendsterLike => erdos_renyi(80_000, 500_000, seed),
            // Web crawl skew (hyperlink12).
            Dataset::HyperlinkLike => rmat(16, 600_000, seed),
            // Road network: n ~= m, diam Θ(√n) (road-usa: diam 6139).
            Dataset::RoadLike => grid2d(384, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn er_size_and_symmetry() {
        let g = erdos_renyi(1000, 5000, 1);
        assert_eq!(g.n, 1000);
        assert!(g.m() > 8000 && g.m() <= 10_000, "m={}", g.m());
        // Symmetric: every arc has its reverse.
        for u in 0..g.n as Vid {
            for (v, _) in g.neighbors(u) {
                assert!(g.neighbors(*v).iter().any(|(x, _)| *x == u));
            }
        }
    }

    #[test]
    fn ba_is_skewed() {
        let g = barabasi_albert(5000, 5, 2);
        let avg = g.m() as f64 / g.n as f64;
        let max = g.max_degree() as f64;
        assert!(
            max > 12.0 * avg,
            "BA should have hubs: max {max} avg {avg:.1}"
        );
    }

    #[test]
    fn grid_has_bounded_degree() {
        let g = grid2d(30, 3);
        assert_eq!(g.n, 900);
        assert!(g.max_degree() <= 4);
        assert_eq!(g.m(), 2 * (2 * 30 * 29));
    }

    #[test]
    fn rmat_size() {
        let g = rmat(10, 4000, 4);
        assert_eq!(g.n, 1024);
        assert!(g.m() > 4000);
    }

    #[test]
    fn community_ring_connected_and_skewed() {
        let g = community_ring(2000, 10, 3, 5);
        assert!(g.max_degree() > 15);
        // BFS from 0 reaches everything with positive degree.
        let mut seen = vec![false; g.n];
        let mut queue = std::collections::VecDeque::from([0 as Vid]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for (v, _) in g.neighbors(u) {
                if !seen[*v as usize] {
                    seen[*v as usize] = true;
                    count += 1;
                    queue.push_back(*v);
                }
            }
        }
        let with_deg = (0..g.n as Vid).filter(|u| g.out_degree(*u) > 0).count();
        assert!(count >= with_deg, "{count} < {with_deg}");
    }

    #[test]
    fn datasets_build() {
        // Smoke-test two Table 2 stand-ins.
        let r = Dataset::RedditLike.build(7);
        assert!(r.n >= 16_000 && r.m() > 300_000);
        let road = Dataset::RoadLike.build(7);
        assert_eq!(road.n, 384 * 384);
    }

    #[test]
    fn generators_deterministic() {
        let a = barabasi_albert(500, 4, 9);
        let b = barabasi_albert(500, 4, 9);
        assert_eq!(a.offsets, b.offsets);
        assert_eq!(
            a.edges.iter().map(|(v, _)| *v).collect::<Vec<_>>(),
            b.edges.iter().map(|(v, _)| *v).collect::<Vec<_>>()
        );
    }
}
