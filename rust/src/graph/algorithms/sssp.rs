//! Single-source shortest paths (frontier-driven Bellman-Ford) via
//! DISTEDGEMAP.  The relaxation lambda `min(dv, du + w)` is the same
//! computation AOT-compiled as the `relax_batch` Pallas artifact; the
//! simulator charges it as one work unit per edge either way.

use crate::graph::engine::GraphEngine;
use crate::graph::subset::DistVertexSubset;
use crate::graph::Vid;

/// Returns the shortest distance from `src` per vertex (f64::INFINITY =
/// unreachable).  Weights must be non-negative.
pub fn sssp<E: GraphEngine>(engine: &mut E, src: Vid) -> Vec<f64> {
    let part = engine.part().clone();
    let mut dist = vec![f64::INFINITY; engine.n()];
    dist[src as usize] = 0.0;
    let mut frontier = DistVertexSubset::single(&part, src);
    // Bellman-Ford terminates after at most n rounds on any graph with
    // non-negative weights; the frontier usually empties much earlier.
    let max_rounds = engine.n() as u64 + 1;
    let mut rounds = 0;
    while !frontier.is_empty() && rounds < max_rounds {
        rounds += 1;
        frontier = engine.edge_map(
            &mut dist,
            &frontier,
            // f: candidate distance through the frontier vertex.
            &mut |dist: &Vec<f64>, u, _v, w| Some(dist[u as usize] + w as f64),
            // ⊗: keep the shortest candidate.
            &|a, b| a.min(b),
            // ⊙: relax; stay active only on improvement.
            &mut |dist, v, val| {
                if val < dist[v as usize] {
                    dist[v as usize] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    dist
}
