//! Single-source shortest paths (frontier-driven Bellman-Ford) via
//! DISTEDGEMAP.  The relaxation lambda `min(dv, du + w)` is the same
//! computation AOT-compiled as the `relax_batch` Pallas artifact; the
//! simulator charges it as one work unit per edge either way.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::{FusedShard, ShardAccess};

/// Machine-local SSSP state: tentative distances for the owned range.
pub struct SsspShard {
    pub base: Vid,
    pub dist: Vec<f64>,
}

impl SsspShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = SsspShard { base: 0, dist: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query` (in-place,
    /// allocation reused across queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.dist.clear();
        self.dist.resize((r.end - r.start) as usize, f64::INFINITY);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Returns the shortest distance from `src` per vertex (f64::INFINITY =
/// unreachable).  Weights must be non-negative.  The frontier vertex's
/// tentative distance is broadcast as a real message (down the source
/// tree in sparse mode) and the relaxation `min(dv, du + w)` runs at the
/// block machines.  `min` is exact in f64, so the result is
/// bit-identical to any correct sequential solver, at every machine
/// count, on both substrates.
pub fn sssp<B: Substrate, AS: Send + ShardAccess<SsspShard>>(
    engine: &mut SpmdEngine<B, AS>,
    src: Vid,
) -> Vec<f64> {
    let owner = engine.meta().part.owner(src);
    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(src);
        st.dist[i] = 0.0;
    }
    engine.set_frontier_single(src);
    // Bellman-Ford settles within n rounds on non-negative weights; the
    // frontier normally empties long before that.
    let max_rounds = engine.meta().n as u64 + 1;
    let mut rounds = 0u64;
    while engine.frontier_len() > 0 && rounds < max_rounds {
        rounds += 1;
        engine.edge_map(
            // The owner ships the frontier vertex's tentative distance.
            &|_m, st: &AS, u| {
                let s = st.shard();
                Some(s.dist[s.idx(u)])
            },
            // Candidate distance through the frontier vertex, computed at
            // the block machine from the delivered value.
            &|sv, _u, _v, w| Some(sv + w as f64),
            // ⊗: keep the shortest candidate.
            &|a, b| a.min(b),
            // ⊙: relax; stay active only on improvement.
            &|st: &mut AS, v, val| {
                let s = st.shard_mut();
                let i = s.idx(v);
                if val < s.dist[i] {
                    s.dist[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().dist.clone())
}

/// Fused multi-source SSSP: each source relaxes in its own lane of one
/// [`SpmdEngine::edge_map_lanes`] wave.  Returns one distance vector per
/// source, in input order, each bit-identical to [`sssp`] run alone —
/// `min` over a lane's own candidate set is exact in f64 and
/// order-insensitive, and a lane's candidates depend only on its own
/// frontier values, which evolve exactly as in the solo run.
pub fn sssp_fused<B: Substrate, AS: Send + ShardAccess<FusedShard>>(
    engine: &mut SpmdEngine<B, AS>,
    sources: &[Vid],
) -> Vec<Vec<f64>> {
    let lanes = sources.len();
    let meta = engine.meta();
    engine.for_each_algo(|m, st| {
        st.shard_mut().reset_lanes_with(m, &meta, lanes, |_lane, _v| f64::INFINITY)
    });
    let mut seeds = Vec::with_capacity(lanes);
    for (l, &src) in sources.iter().enumerate() {
        let lane = l as u32;
        let owner = meta.part.owner(src);
        engine.algo_mut(owner).shard_mut().set(lane, src, 0.0);
        seeds.push((src, lane));
    }
    engine.set_frontier_lanes(&seeds);
    // Same settling bound as the solo runner; every lane is settled by
    // then, so the shared wave never runs longer than the slowest member.
    let max_rounds = meta.n as u64 + 1;
    let mut rounds = 0u64;
    while engine.lane_frontier_len() > 0 && rounds < max_rounds {
        rounds += 1;
        engine.edge_map_lanes(
            &|_m, st: &AS, u, lane| {
                let s = st.shard();
                Some(s.val[s.idx(lane, u)])
            },
            &|sv, _u, _v, w| Some(sv + w as f64),
            &|a, b| a.min(b),
            &|st: &mut AS, v, lane, val| {
                let s = st.shard_mut();
                let i = s.idx(lane, v);
                if val < s.val[i] {
                    s.val[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    (0..lanes as u32)
        .map(|lane| engine.gather(|_m, st| st.shard().lane(lane).to_vec()))
        .collect()
}
