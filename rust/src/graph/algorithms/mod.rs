//! The five paper algorithms (§5, Appendix C) written against
//! [`GraphEngine::edge_map`] — each a page of user-level code, mirroring
//! the paper's "BC in fewer than 70 lines" interface-conciseness claim.

mod bc;
mod bfs;
mod cc;
mod pagerank;
mod sssp;

pub use bc::bc;
pub use bfs::bfs;
pub use cc::cc;
pub use pagerank::pagerank;
pub use sssp::sssp;

/// Which algorithm — used by the benchmark harness tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Bfs,
    Sssp,
    Bc,
    Cc,
    Pr,
}

impl Algorithm {
    pub const ALL: [Algorithm; 5] = [
        Algorithm::Bfs,
        Algorithm::Sssp,
        Algorithm::Bc,
        Algorithm::Cc,
        Algorithm::Pr,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Bfs => "BFS",
            Algorithm::Sssp => "SSSP",
            Algorithm::Bc => "BC",
            Algorithm::Cc => "CC",
            Algorithm::Pr => "PR",
        }
    }
}
