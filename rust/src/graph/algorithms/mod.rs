//! The five paper algorithms (§5, Appendix C), each ONE shard type plus
//! ONE runner written against the unified SPMD engine's `edge_map`
//! ([`crate::graph::spmd::SpmdEngine`]) — a page of user-level code per
//! algorithm, mirroring the paper's "BC in fewer than 70 lines"
//! interface-conciseness claim.  Vertex state is sharded per machine and
//! source values/contributions travel as real messages, so every
//! implementation runs bit-identically on the BSP simulator (the figure
//! paths) and on the threaded worker pool (the runtime/serving paths) —
//! `tests/graph_exec_equivalence.rs` pins that contract.

mod bc;
mod bfs;
mod cc;
mod fused;
mod pagerank;
mod sssp;

pub use bc::{bc, BcShard};
pub use bfs::{bfs, bfs_fused, BfsShard};
pub use cc::{cc, cc_fused, CcShard};
pub use fused::FusedShard;
pub use pagerank::{pagerank, PrShard, DAMPING};
pub use sssp::{sssp, sssp_fused, SsspShard};

/// Projection from an engine's machine-local algorithm state to one
/// algorithm's shard.  The runners are generic over this, so they serve
/// two callers with one implementation: a single-algorithm engine
/// (`SpmdEngine<B, BfsShard>` — the identity impl below), and the
/// serving layer's [`crate::serve::QueryShard`], which holds all five
/// shards so ONE long-lived engine (one ingestion, one worker pool) can
/// run the whole {BFS, SSSP, PR, CC, BC} query mix, switching algorithms
/// via `SpmdEngine::reset_for_query` instead of engine reconstruction.
pub trait ShardAccess<S> {
    fn shard(&self) -> &S;
    fn shard_mut(&mut self) -> &mut S;
}

impl<S> ShardAccess<S> for S {
    #[inline]
    fn shard(&self) -> &S {
        self
    }

    #[inline]
    fn shard_mut(&mut self) -> &mut S {
        self
    }
}

/// Which algorithm — used by the benchmark harness tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Bfs,
    Sssp,
    Bc,
    Cc,
    Pr,
}

impl Algorithm {
    pub const ALL: [Algorithm; 5] = [
        Algorithm::Bfs,
        Algorithm::Sssp,
        Algorithm::Bc,
        Algorithm::Cc,
        Algorithm::Pr,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Bfs => "BFS",
            Algorithm::Sssp => "SSSP",
            Algorithm::Bc => "BC",
            Algorithm::Cc => "CC",
            Algorithm::Pr => "PR",
        }
    }
}
