//! The five paper algorithms (§5, Appendix C) written against
//! [`GraphEngine::edge_map`] — each a page of user-level code, mirroring
//! the paper's "BC in fewer than 70 lines" interface-conciseness claim.
//!
//! BFS, SSSP, CC and PR additionally ship `*_spmd` variants written
//! against the substrate-generic [`crate::graph::spmd::SpmdEngine`]:
//! same rounds, but vertex state is sharded per machine and source
//! values/contributions travel as real messages, so one implementation
//! runs bit-identically on the BSP simulator and on the threaded worker
//! pool (`tests/graph_exec_equivalence.rs`).
//!
//! [`GraphEngine::edge_map`]: crate::graph::engine::GraphEngine::edge_map

mod bc;
mod bfs;
mod cc;
mod pagerank;
mod sssp;

pub use bc::bc;
pub use bfs::{bfs, bfs_spmd, BfsShard};
pub use cc::{cc, cc_spmd, CcShard};
pub use pagerank::{pagerank, pagerank_spmd, PrShard, DAMPING};
pub use sssp::{sssp, sssp_spmd, SsspShard};

/// Projection from an engine's machine-local algorithm state to one
/// algorithm's shard.  The `*_spmd` runners are generic over this, so
/// they serve two callers with one implementation: a single-algorithm
/// engine (`SpmdEngine<B, BfsShard>` — the identity impl below), and the
/// serving layer's [`crate::serve::QueryShard`], which holds all four
/// shards so ONE long-lived engine (one ingestion, one worker pool) can
/// run the whole {BFS, SSSP, PR, CC} query mix, switching algorithms via
/// `SpmdEngine::reset_for_query` instead of engine reconstruction.
pub trait ShardAccess<S> {
    fn shard(&self) -> &S;
    fn shard_mut(&mut self) -> &mut S;
}

impl<S> ShardAccess<S> for S {
    #[inline]
    fn shard(&self) -> &S {
        self
    }

    #[inline]
    fn shard_mut(&mut self) -> &mut S {
        self
    }
}

/// Which algorithm — used by the benchmark harness tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    Bfs,
    Sssp,
    Bc,
    Cc,
    Pr,
}

impl Algorithm {
    pub const ALL: [Algorithm; 5] = [
        Algorithm::Bfs,
        Algorithm::Sssp,
        Algorithm::Bc,
        Algorithm::Cc,
        Algorithm::Pr,
    ];

    pub fn label(self) -> &'static str {
        match self {
            Algorithm::Bfs => "BFS",
            Algorithm::Sssp => "SSSP",
            Algorithm::Bc => "BC",
            Algorithm::Cc => "CC",
            Algorithm::Pr => "PR",
        }
    }
}
