//! Connected components by min-label propagation via DISTEDGEMAP.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::{FusedShard, ShardAccess};

/// Machine-local CC state: component labels for the owned range.
pub struct CcShard {
    pub base: Vid,
    pub label: Vec<f64>,
}

impl CcShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = CcShard { base: 0, label: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query` (in-place,
    /// allocation reused across queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.label.clear();
        self.label.extend((r.start..r.end).map(|v| v as f64));
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Returns, per vertex, the minimum vertex id of its component.  Labels
/// travel as real messages and min-fold at the owners.  Vertex ids are
/// exact in f64, so the fixpoint is bit-identical on every substrate and
/// machine count.
pub fn cc<B: Substrate, AS: Send + ShardAccess<CcShard>>(
    engine: &mut SpmdEngine<B, AS>,
) -> Vec<u32> {
    let meta = engine.meta();
    engine.charge_local((meta.n / meta.p.max(1)) as u64); // init sweep
    engine.set_frontier_all();
    while engine.frontier_len() > 0 {
        engine.edge_map(
            // f: offer our label to the neighbor.
            &|_m, st: &AS, u| {
                let s = st.shard();
                Some(s.label[s.idx(u)])
            },
            &|sv, _u, _v, _w| Some(sv),
            // ⊗: smallest label wins.
            &|a, b| a.min(b),
            // ⊙: adopt improvements, stay active while changing.
            &|st: &mut AS, v, val| {
                let s = st.shard_mut();
                let i = s.idx(v);
                if val < s.label[i] {
                    s.label[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().label.iter().map(|l| *l as u32).collect())
}

/// Fused CC: `lanes` copies of min-label propagation in one wave.  CC is
/// source-independent, so every lane runs the identical everywhere-active
/// sweep and returns the identical labels — fusing it exists so a batch
/// of CC queries still costs one engine pass without special-casing the
/// dispatch (the serving cache makes the duplicate lanes moot in
/// practice).  The init sweep is charged once per lane.
pub fn cc_fused<B: Substrate, AS: Send + ShardAccess<FusedShard>>(
    engine: &mut SpmdEngine<B, AS>,
    lanes: usize,
) -> Vec<Vec<u32>> {
    let meta = engine.meta();
    engine.for_each_algo(|m, st| {
        st.shard_mut().reset_lanes_with(m, &meta, lanes, |_lane, v| v as f64)
    });
    engine.charge_local(((meta.n / meta.p.max(1)) * lanes) as u64); // init sweep
    engine.set_frontier_all_lanes(lanes as u32);
    while engine.lane_frontier_len() > 0 {
        engine.edge_map_lanes(
            &|_m, st: &AS, u, lane| {
                let s = st.shard();
                Some(s.val[s.idx(lane, u)])
            },
            &|sv, _u, _v, _w| Some(sv),
            &|a, b| a.min(b),
            &|st: &mut AS, v, lane, val| {
                let s = st.shard_mut();
                let i = s.idx(lane, v);
                if val < s.val[i] {
                    s.val[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    (0..lanes as u32)
        .map(|lane| {
            engine.gather(|_m, st| st.shard().lane(lane).iter().map(|&x| x as u32).collect())
        })
        .collect()
}
