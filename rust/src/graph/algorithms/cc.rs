//! Connected components by min-label propagation via DISTEDGEMAP, in
//! cost-model and SPMD form.

use crate::exec::Substrate;
use crate::graph::engine::GraphEngine;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::subset::DistVertexSubset;
use crate::graph::Vid;
use crate::MachineId;

use super::ShardAccess;

/// Returns, per vertex, the minimum vertex id of its component.
pub fn cc<E: GraphEngine>(engine: &mut E) -> Vec<u32> {
    let part = engine.part().clone();
    let n = engine.n();
    let mut label: Vec<f64> = (0..n).map(|v| v as f64).collect();
    engine.charge_local((n / engine.part().p().max(1)) as u64); // init sweep
    let mut frontier = DistVertexSubset::all(&part);
    while !frontier.is_empty() {
        frontier = engine.edge_map(
            &mut label,
            &frontier,
            // f: offer our label to the neighbor.
            &mut |label: &Vec<f64>, u, _v, _w| Some(label[u as usize]),
            // ⊗: smallest label wins.
            &|a, b| a.min(b),
            // ⊙: adopt improvements, stay active while changing.
            &mut |label, v, val| {
                if val < label[v as usize] {
                    label[v as usize] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    label.into_iter().map(|l| l as u32).collect()
}

/// Machine-local CC state: component labels for the owned range.
pub struct CcShard {
    pub base: Vid,
    pub label: Vec<f64>,
}

impl CcShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = CcShard { base: 0, label: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query` (in-place,
    /// allocation reused across queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.label.clear();
        self.label.extend((r.start..r.end).map(|v| v as f64));
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// CC in SPMD form: labels travel as real messages and min-fold at the
/// owners.  Vertex ids are exact in f64, so the fixpoint is bit-identical
/// to [`cc`] on every substrate and machine count.
pub fn cc_spmd<B: Substrate, AS: Send + ShardAccess<CcShard>>(
    engine: &mut SpmdEngine<B, AS>,
) -> Vec<u32> {
    let meta = engine.meta();
    engine.charge_local((meta.n / meta.p.max(1)) as u64); // init sweep
    engine.set_frontier_all();
    while engine.frontier_len() > 0 {
        engine.edge_map(
            // f: offer our label to the neighbor.
            &|_m, st: &AS, u| {
                let s = st.shard();
                Some(s.label[s.idx(u)])
            },
            &|sv, _u, _v, _w| Some(sv),
            // ⊗: smallest label wins.
            &|a, b| a.min(b),
            // ⊙: adopt improvements, stay active while changing.
            &|st: &mut AS, v, val| {
                let s = st.shard_mut();
                let i = s.idx(v);
                if val < s.label[i] {
                    s.label[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().label.iter().map(|l| *l as u32).collect())
}
