//! Connected components by min-label propagation via DISTEDGEMAP.

use crate::graph::engine::GraphEngine;
use crate::graph::subset::DistVertexSubset;

/// Returns, per vertex, the minimum vertex id of its component.
pub fn cc<E: GraphEngine>(engine: &mut E) -> Vec<u32> {
    let part = engine.part().clone();
    let n = engine.n();
    let mut label: Vec<f64> = (0..n).map(|v| v as f64).collect();
    engine.charge_local((n / engine.part().p().max(1)) as u64); // init sweep
    let mut frontier = DistVertexSubset::all(&part);
    while !frontier.is_empty() {
        frontier = engine.edge_map(
            &mut label,
            &frontier,
            // f: offer our label to the neighbor.
            &mut |label: &Vec<f64>, u, _v, _w| Some(label[u as usize]),
            // ⊗: smallest label wins.
            &|a, b| a.min(b),
            // ⊙: adopt improvements, stay active while changing.
            &mut |label, v, val| {
                if val < label[v as usize] {
                    label[v as usize] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    label.into_iter().map(|l| l as u32).collect()
}
