//! Shared per-lane vertex state for **fused multi-source waves**.
//!
//! A fused wave runs a whole batch of same-kind exact queries (BFS /
//! SSSP / CC — the merge operators that are order-insensitive and exact
//! in f64) as ONE sequence of [`crate::graph::spmd::SpmdEngine::edge_map_lanes`]
//! rounds: query `l` of the batch becomes *lane* `l`, messages carry a
//! lane id, and this shard holds one value row per lane over the
//! machine's owned vertex range.  Because lanes evolve independently
//! (the engine routes a lane's contributions only from its own active
//! pairs) and the merges are exact, each lane's final row is
//! bit-identical to the corresponding single-source run — the contract
//! `tests/serve_fusion.rs` pins at every P on both backends.

use crate::graph::spmd::GraphMeta;
use crate::graph::Vid;
use crate::MachineId;

/// Machine-local fused state: `lanes` rows of per-vertex f64 values over
/// the owned range, lane-major (`val[lane * width + (v - base)]`).  The
/// f64 cell is the same representation the single-source shards use
/// (BFS distances are exact small integers, SSSP distances are the
/// engine's native message payload, CC labels are exact vertex ids), so
/// fused write-backs are bit-compatible with the single runners.
pub struct FusedShard {
    pub base: Vid,
    /// Owned-range width (cells per lane).
    pub width: usize,
    /// Configured lane count (0 = unconfigured; runners size it).
    pub lanes: usize,
    pub val: Vec<f64>,
}

impl FusedShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = FusedShard { base: 0, width: 0, lanes: 0, val: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query`: back to the
    /// unconfigured state (allocation kept for reuse across waves).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.width = (r.end - r.start) as usize;
        self.lanes = 0;
        self.val.clear();
    }

    /// Size the shard for a wave of `lanes` queries and fill every cell
    /// from `init(lane, vertex)` (e.g. `-1.0` for BFS, `INFINITY` for
    /// SSSP, `v as f64` for CC).  Runners call this driver-side before
    /// seeding the lane frontier.
    pub fn reset_lanes_with(
        &mut self,
        m: MachineId,
        meta: &GraphMeta,
        lanes: usize,
        init: impl Fn(u32, Vid) -> f64,
    ) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.width = (r.end - r.start) as usize;
        self.lanes = lanes;
        self.val.clear();
        self.val.reserve(lanes * self.width);
        for lane in 0..lanes as u32 {
            for v in r.clone() {
                self.val.push(init(lane, v));
            }
        }
    }

    #[inline]
    pub fn idx(&self, lane: u32, v: Vid) -> usize {
        lane as usize * self.width + (v - self.base) as usize
    }

    #[inline]
    pub fn set(&mut self, lane: u32, v: Vid, val: f64) {
        let i = self.idx(lane, v);
        self.val[i] = val;
    }

    /// One lane's owned-range row (gathered per lane into the global
    /// result vector, exactly like a single shard's slice).
    pub fn lane(&self, lane: u32) -> &[f64] {
        let s = lane as usize * self.width;
        &self.val[s..s + self.width]
    }
}
