//! Betweenness centrality from a single root (Brandes forward/backward,
//! paper Algorithm 3 / Appendix C) via DISTEDGEMAP — two phases on the
//! unified SPMD engine.
//!
//! Forward: level-synchronous BFS accumulating shortest-path counts
//! (σ travels as a real message; ⊕-merge sums path counts; first level
//! wins at the owner).  The per-level frontiers are snapshotted
//! ([`SpmdEngine::frontier_parts`]) so the backward pass can replay them
//! deepest-first.
//!
//! Backward: each child v at level r+1 broadcasts its dependency share
//! `(1 + δ(v)) / σ(v)`; shares ⊕-merge per destination, and the **owner**
//! applies the parent filter — the frontier is exactly the level-(r+1)
//! vertices, so "u is a parent" reduces to `level(u) == r`, a check on
//! owner-local state.  Filtering at the owner instead of per edge keeps
//! the edge lambda free of destination-side state (which a block machine
//! does not have in shared-nothing form) and admits the same share set a
//! per-edge `level(u) == level(v) - 1` filter would: on a symmetric
//! graph, every frontier child adjacent to a level-r vertex is one hop
//! below it.  Same-or-deeper neighbors receive a merged value too, but
//! their owner discards it, exactly as the per-edge filter would have
//! produced no contribution for them.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::ShardAccess;

/// Machine-local BC state for the owned range: path counts σ, BFS
/// levels (-1 = unreached), dependency accumulators δ.
pub struct BcShard {
    pub base: Vid,
    pub sigma: Vec<f64>,
    pub level: Vec<i64>,
    pub delta: Vec<f64>,
}

impl BcShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = BcShard { base: 0, sigma: Vec::new(), level: Vec::new(), delta: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query` (in-place,
    /// allocations reused across queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        let n_local = (r.end - r.start) as usize;
        self.base = r.start;
        self.sigma.clear();
        self.sigma.resize(n_local, 0.0);
        self.level.clear();
        self.level.resize(n_local, -1);
        self.delta.clear();
        self.delta.resize(n_local, 0.0);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Single-root BC scores (unnormalized, root's own score = 0), as used in
/// the paper's performance tests.
pub fn bc<B: Substrate, AS: Send + ShardAccess<BcShard>>(
    engine: &mut SpmdEngine<B, AS>,
    root: Vid,
) -> Vec<f64> {
    let owner = engine.meta().part.owner(root);
    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(root);
        st.sigma[i] = 1.0;
        st.level[i] = 0;
    }
    engine.set_frontier_single(root);

    // ---- Forward pass: BFS levels + path counts ----
    let mut frontiers = vec![engine.frontier_parts()];
    let mut round = 0i64;
    while engine.frontier_len() > 0 {
        round += 1;
        let r = round;
        engine.edge_map(
            // f_forward: propagate path counts (Algorithm 3 line 4).
            &|_m, st: &AS, u| {
                let s = st.shard();
                Some(s.sigma[s.idx(u)])
            },
            &|sv, _u, _v, _w| Some(sv),
            // ⊗: path counts add.
            &|a, b| a + b,
            // wb_forward: first level wins; accumulate sigma.
            &move |st: &mut AS, v, agg| {
                let s = st.shard_mut();
                let i = s.idx(v);
                if s.level[i] < 0 {
                    s.level[i] = r;
                    s.sigma[i] = agg;
                    true
                } else {
                    false
                }
            },
        );
        frontiers.push(engine.frontier_parts());
    }

    // ---- Backward pass: dependency accumulation, deepest level first.
    // Symmetric edges mean edge_map from the level-(r+1) frontier reaches
    // its level-r parents; the owner-side level check selects them (see
    // module docs).
    for r in (0..frontiers.len().saturating_sub(1)).rev() {
        let deeper = &frontiers[r + 1];
        if deeper.iter().all(|part| part.is_empty()) {
            continue;
        }
        engine.set_frontier_parts(deeper);
        let parent_level = r as i64;
        engine.edge_map(
            // f_backward: child v at level r+1 offers its dependency
            // share to its neighbors.
            &|_m, st: &AS, v| {
                let s = st.shard();
                let i = s.idx(v);
                Some((1.0 + s.delta[i]) / s.sigma[i])
            },
            &|sv, _u, _v, _w| Some(sv),
            // ⊗: shares add.
            &|a, b| a + b,
            // wb_backward: parents (level == r) take δ(u) = σ(u)·Σshares;
            // everyone else discards the aggregate.
            &move |st: &mut AS, u, agg| {
                let s = st.shard_mut();
                let i = s.idx(u);
                if s.level[i] == parent_level {
                    s.delta[i] = s.sigma[i] * agg;
                }
                false
            },
        );
    }

    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(root);
        st.delta[i] = 0.0;
    }
    engine.gather(|_m, st| st.shard().delta.clone())
}
