//! Betweenness centrality from a single root (Brandes forward/backward,
//! paper Algorithm 3 / Appendix C) via DISTEDGEMAP.

use crate::graph::engine::GraphEngine;
use crate::graph::subset::DistVertexSubset;
use crate::graph::Vid;

struct BcState {
    /// Number of shortest paths from the root.
    sigma: Vec<f64>,
    /// BFS level (-1 = unreached).
    level: Vec<i64>,
    /// Dependency accumulator.
    delta: Vec<f64>,
    round: i64,
}

/// Single-root BC scores (unnormalized, root's own score = 0), as used in
/// the paper's performance tests.
pub fn bc<E: GraphEngine>(engine: &mut E, root: Vid) -> Vec<f64> {
    let part = engine.part().clone();
    let n = engine.n();
    let mut st = BcState {
        sigma: vec![0.0; n],
        level: vec![-1; n],
        delta: vec![0.0; n],
        round: 0,
    };
    st.sigma[root as usize] = 1.0;
    st.level[root as usize] = 0;

    // ---- Forward pass: BFS levels + path counts ----
    let mut frontier = DistVertexSubset::single(&part, root);
    let mut frontiers = vec![frontier.clone()];
    while !frontier.is_empty() {
        st.round += 1;
        frontier = engine.edge_map(
            &mut st,
            &frontier,
            // f_forward: propagate path counts (Algorithm 3 line 4).
            &mut |st: &BcState, u, _v, _w| Some(st.sigma[u as usize]),
            // ⊗: path counts add.
            &|a, b| a + b,
            // wb_forward: first level wins; accumulate sigma.
            &mut |st, v, agg| {
                if st.level[v as usize] < 0 {
                    st.level[v as usize] = st.round;
                    st.sigma[v as usize] = agg;
                    true
                } else {
                    false
                }
            },
        );
        frontiers.push(frontier.clone());
    }

    // ---- Backward pass: dependency accumulation ----
    // Process levels deepest-first; symmetric edges mean edge_map from
    // the level-(r+1) frontier reaches its level-r parents.
    for r in (0..frontiers.len().saturating_sub(1)).rev() {
        let deeper = frontiers[r + 1].clone();
        if deeper.is_empty() {
            continue;
        }
        engine.edge_map(
            &mut st,
            &deeper,
            // f_backward: child v at level r+1 offers its dependency
            // share to parents one level up.
            &mut |st: &BcState, v, u, _w| {
                if st.level[u as usize] == st.level[v as usize] - 1 {
                    Some((1.0 + st.delta[v as usize]) / st.sigma[v as usize])
                } else {
                    None
                }
            },
            // ⊗: shares add.
            &|a, b| a + b,
            // wb_backward: delta[u] = sigma[u] * Σ shares.
            &mut |st, u, agg| {
                st.delta[u as usize] = st.sigma[u as usize] * agg;
                false
            },
        );
    }

    st.delta[root as usize] = 0.0;
    st.delta
}
