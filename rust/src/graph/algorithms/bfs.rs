//! Breadth-first search via DISTEDGEMAP (paper Algorithm 2) on the
//! unified SPMD engine.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::{FusedShard, ShardAccess};

/// Machine-local BFS state: hop distances for the owned vertex range.
pub struct BfsShard {
    pub base: Vid,
    pub dist: Vec<i64>,
}

impl BfsShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = BfsShard { base: 0, dist: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query`: restore the
    /// freshly-constructed state in place (allocation reused across
    /// queries on the serving path).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.dist.clear();
        self.dist.resize((r.end - r.start) as usize, -1);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Returns the hop distance from `src` per vertex (-1 = unreachable).
/// The per-round hop count travels as a real message through the
/// substrate, so the same code runs (bit-identically) on the simulator
/// and the threaded pool.  Generic over [`ShardAccess`] so both a
/// dedicated BFS engine and the serving layer's multi-algorithm engine
/// can call it.
pub fn bfs<B: Substrate, AS: Send + ShardAccess<BfsShard>>(
    engine: &mut SpmdEngine<B, AS>,
    src: Vid,
) -> Vec<i64> {
    let owner = engine.meta().part.owner(src);
    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(src);
        st.dist[i] = 0;
    }
    engine.set_frontier_single(src);
    let mut round = 0i64;
    while engine.frontier_len() > 0 {
        round += 1;
        let r = round as f64;
        engine.edge_map(
            // The source is on the current frontier, so the candidate
            // distance is simply this round number (Algorithm 2 line 4).
            &move |_m, _st: &AS, _u| Some(r),
            &|sv, _u, _v, _w| Some(sv),
            // merge: all contributions equal this round; keep one.
            &|a, _b| a,
            // write_back: first writer wins (Algorithm 2 lines 6-9).
            &|st: &mut AS, v, val| {
                let st = st.shard_mut();
                let i = st.idx(v);
                if st.dist[i] < 0 {
                    st.dist[i] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().dist.clone())
}

/// Fused multi-source BFS: one [`SpmdEngine::edge_map_lanes`] wave runs
/// every source as its own lane.  Returns one distance vector per source,
/// in input order — each bit-identical to [`bfs`] run alone on the same
/// engine (first-writer merge is order-insensitive, and the shared round
/// counter assigns lane `l`'s level-k vertices the same round number its
/// solo run would).  The runner sizes/fills the fused shard itself; the
/// caller only needs a reset engine.
pub fn bfs_fused<B: Substrate, AS: Send + ShardAccess<FusedShard>>(
    engine: &mut SpmdEngine<B, AS>,
    sources: &[Vid],
) -> Vec<Vec<i64>> {
    let lanes = sources.len();
    let meta = engine.meta();
    engine.for_each_algo(|m, st| {
        st.shard_mut().reset_lanes_with(m, &meta, lanes, |_lane, _v| -1.0)
    });
    let mut seeds = Vec::with_capacity(lanes);
    for (l, &src) in sources.iter().enumerate() {
        let lane = l as u32;
        let owner = meta.part.owner(src);
        engine.algo_mut(owner).shard_mut().set(lane, src, 0.0);
        seeds.push((src, lane));
    }
    engine.set_frontier_lanes(&seeds);
    let mut round = 0i64;
    while engine.lane_frontier_len() > 0 {
        round += 1;
        let r = round as f64;
        engine.edge_map_lanes(
            &move |_m, _st: &AS, _u, _lane| Some(r),
            &|sv, _u, _v, _w| Some(sv),
            &|a, _b| a,
            &|st: &mut AS, v, lane, val| {
                let s = st.shard_mut();
                let i = s.idx(lane, v);
                if s.val[i] < 0.0 {
                    s.val[i] = val;
                    true
                } else {
                    false
                }
            },
        );
    }
    (0..lanes as u32)
        .map(|lane| {
            engine.gather(|_m, st| st.shard().lane(lane).iter().map(|&d| d as i64).collect())
        })
        .collect()
}
