//! Breadth-first search via DISTEDGEMAP (paper Algorithm 2) on the
//! unified SPMD engine.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::ShardAccess;

/// Machine-local BFS state: hop distances for the owned vertex range.
pub struct BfsShard {
    pub base: Vid,
    pub dist: Vec<i64>,
}

impl BfsShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = BfsShard { base: 0, dist: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query`: restore the
    /// freshly-constructed state in place (allocation reused across
    /// queries on the serving path).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.dist.clear();
        self.dist.resize((r.end - r.start) as usize, -1);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Returns the hop distance from `src` per vertex (-1 = unreachable).
/// The per-round hop count travels as a real message through the
/// substrate, so the same code runs (bit-identically) on the simulator
/// and the threaded pool.  Generic over [`ShardAccess`] so both a
/// dedicated BFS engine and the serving layer's multi-algorithm engine
/// can call it.
pub fn bfs<B: Substrate, AS: Send + ShardAccess<BfsShard>>(
    engine: &mut SpmdEngine<B, AS>,
    src: Vid,
) -> Vec<i64> {
    let owner = engine.meta().part.owner(src);
    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(src);
        st.dist[i] = 0;
    }
    engine.set_frontier_single(src);
    let mut round = 0i64;
    while engine.frontier_len() > 0 {
        round += 1;
        let r = round as f64;
        engine.edge_map(
            // The source is on the current frontier, so the candidate
            // distance is simply this round number (Algorithm 2 line 4).
            &move |_m, _st: &AS, _u| Some(r),
            &|sv, _u, _v, _w| Some(sv),
            // merge: all contributions equal this round; keep one.
            &|a, _b| a,
            // write_back: first writer wins (Algorithm 2 lines 6-9).
            &|st: &mut AS, v, val| {
                let st = st.shard_mut();
                let i = st.idx(v);
                if st.dist[i] < 0 {
                    st.dist[i] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().dist.clone())
}
