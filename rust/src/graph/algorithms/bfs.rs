//! Breadth-first search via DISTEDGEMAP (paper Algorithm 2).

use crate::graph::engine::GraphEngine;
use crate::graph::subset::DistVertexSubset;
use crate::graph::Vid;

/// Returns the hop distance from `src` per vertex (-1 = unreachable).
pub fn bfs<E: GraphEngine>(engine: &mut E, src: Vid) -> Vec<i64> {
    let part = engine.part().clone();
    let mut dist = vec![-1i64; engine.n()];
    dist[src as usize] = 0;
    let mut frontier = DistVertexSubset::single(&part, src);
    let mut round = 0i64;
    while !frontier.is_empty() {
        round += 1;
        let r = round;
        frontier = engine.edge_map(
            &mut dist,
            &frontier,
            // f: the source is on the current frontier, so the new
            // distance is simply this round number (Algorithm 2 line 4).
            &mut |_, _, _, _| Some(r as f64),
            // merge: all contributions equal this round; keep one.
            &|a, _| a,
            // write_back: first writer wins (Algorithm 2 lines 6-9).
            &mut |dist, v, val| {
                if dist[v as usize] < 0 {
                    dist[v as usize] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
    }
    dist
}
