//! Breadth-first search via DISTEDGEMAP (paper Algorithm 2), in both
//! forms: against the cost-model [`GraphEngine`] and in SPMD form against
//! the substrate-generic [`SpmdEngine`].

use crate::exec::Substrate;
use crate::graph::engine::GraphEngine;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::subset::DistVertexSubset;
use crate::graph::Vid;
use crate::MachineId;

use super::ShardAccess;

/// Returns the hop distance from `src` per vertex (-1 = unreachable).
pub fn bfs<E: GraphEngine>(engine: &mut E, src: Vid) -> Vec<i64> {
    let part = engine.part().clone();
    let mut dist = vec![-1i64; engine.n()];
    dist[src as usize] = 0;
    let mut frontier = DistVertexSubset::single(&part, src);
    let mut round = 0i64;
    while !frontier.is_empty() {
        round += 1;
        let r = round;
        frontier = engine.edge_map(
            &mut dist,
            &frontier,
            // f: the source is on the current frontier, so the new
            // distance is simply this round number (Algorithm 2 line 4).
            &mut |_, _, _, _| Some(r as f64),
            // merge: all contributions equal this round; keep one.
            &|a, _| a,
            // write_back: first writer wins (Algorithm 2 lines 6-9).
            &mut |dist, v, val| {
                if dist[v as usize] < 0 {
                    dist[v as usize] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
    }
    dist
}

/// Machine-local BFS state: hop distances for the owned vertex range.
pub struct BfsShard {
    pub base: Vid,
    pub dist: Vec<i64>,
}

impl BfsShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = BfsShard { base: 0, dist: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query`: restore the
    /// freshly-constructed state in place (allocation reused across
    /// queries on the serving path).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        self.base = r.start;
        self.dist.clear();
        self.dist.resize((r.end - r.start) as usize, -1);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// BFS in SPMD form: identical rounds to [`bfs`], but the per-round hop
/// count travels as a real message through the substrate, so the same
/// code runs (bit-identically) on the simulator and the threaded pool.
/// Generic over [`ShardAccess`] so both a dedicated BFS engine and the
/// serving layer's multi-algorithm engine can call it.
pub fn bfs_spmd<B: Substrate, AS: Send + ShardAccess<BfsShard>>(
    engine: &mut SpmdEngine<B, AS>,
    src: Vid,
) -> Vec<i64> {
    let owner = engine.meta().part.owner(src);
    {
        let st = engine.algo_mut(owner).shard_mut();
        let i = st.idx(src);
        st.dist[i] = 0;
    }
    engine.set_frontier_single(src);
    let mut round = 0i64;
    while engine.frontier_len() > 0 {
        round += 1;
        let r = round as f64;
        engine.edge_map(
            // The source is on the current frontier, so the candidate
            // distance is simply this round number (Algorithm 2 line 4).
            &move |_m, _st: &AS, _u| Some(r),
            &|sv, _u, _v, _w| Some(sv),
            // merge: all contributions equal this round; keep one.
            &|a, _b| a,
            // write_back: first writer wins (Algorithm 2 lines 6-9).
            &|st: &mut AS, v, val| {
                let st = st.shard_mut();
                let i = st.idx(v);
                if st.dist[i] < 0 {
                    st.dist[i] = val as i64;
                    true
                } else {
                    false
                }
            },
        );
    }
    engine.gather(|_m, st| st.shard().dist.clone())
}
