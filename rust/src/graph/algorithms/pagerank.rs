//! PageRank iteration via DISTEDGEMAP (always dense — every vertex is
//! active every round, which is exactly where the destination-tree
//! aggregation and destination-aware broadcast pay off).
//!
//! The per-machine dense aggregation is the computation AOT-compiled as
//! the `spmv_panel` Pallas artifact (alpha·A·x + beta); the simulator
//! charges it as one work unit per edge.

use crate::exec::Substrate;
use crate::graph::spmd::{GraphMeta, SpmdEngine};
use crate::graph::Vid;
use crate::MachineId;

use super::ShardAccess;

pub const DAMPING: f64 = 0.85;

/// Machine-local PR state: rank and next-rank for the owned range.
pub struct PrShard {
    pub base: Vid,
    pub rank: Vec<f64>,
    pub next: Vec<f64>,
}

impl PrShard {
    pub fn new(m: MachineId, meta: &GraphMeta) -> Self {
        let mut s = PrShard { base: 0, rank: Vec::new(), next: Vec::new() };
        s.reset(m, meta);
        s
    }

    /// Re-init hook for `SpmdEngine::reset_for_query` (in-place,
    /// allocations reused across queries).
    pub fn reset(&mut self, m: MachineId, meta: &GraphMeta) {
        let r = meta.part.range(m);
        let n_local = (r.end - r.start) as usize;
        let n = meta.n as f64;
        self.base = r.start;
        self.rank.clear();
        self.rank.resize(n_local, 1.0 / n);
        self.next.clear();
        self.next.resize(n_local, (1.0 - DAMPING) / n);
    }

    #[inline]
    fn idx(&self, v: Vid) -> usize {
        (v - self.base) as usize
    }
}

/// Run `iters` PageRank iterations; returns the final rank vector.  Each
/// owner broadcasts `rank[u]/deg(u)` as a real message (destination
/// -aware in dense mode); contributions ⊕-fold per destination in
/// (sender, emission-index) order.  Because f64 addition rounds, the
/// fold *grouping* — per block machine, then per destination tree — is
/// part of the result's bit pattern: runs are bit-identical across
/// substrates and across repeats at fixed (P, flags), equal to an
/// ascending-source sequential fold at P=1, and equal to it only up to
/// rounding for P>1 (see `graph/spmd.rs` docs).
pub fn pagerank<B: Substrate, AS: Send + ShardAccess<PrShard>>(
    engine: &mut SpmdEngine<B, AS>,
    iters: usize,
) -> Vec<f64> {
    let meta = engine.meta();
    let n = meta.n;
    let base = (1.0 - DAMPING) / n as f64;
    let per_machine = (n / meta.p.max(1)) as u64;
    engine.charge_local(per_machine); // rank init sweep
    for _ in 0..iters {
        // Per-round base reset: O(n/P) on each worker, inside the
        // substrate, so the threaded busy clocks contain the work the
        // ledger charges for it.
        engine.local_step(per_machine, |_m, st: &mut AS| st.shard_mut().next.fill(base));
        engine.set_frontier_all();
        let meta_c = std::sync::Arc::clone(&meta);
        engine.edge_map(
            // f: share of the source's rank (dangling-free contribution).
            &move |_m, st: &AS, u| {
                let d = meta_c.out_deg[u as usize];
                if d == 0 {
                    None
                } else {
                    let s = st.shard();
                    Some(s.rank[s.idx(u)] / d as f64)
                }
            },
            &|sv, _u, _v, _w| Some(sv),
            // ⊗: contributions add.
            &|a, b| a + b,
            // ⊙: damped update; frontier membership irrelevant (dense).
            &|st: &mut AS, v, agg| {
                let s = st.shard_mut();
                let i = s.idx(v);
                s.next[i] = base + DAMPING * agg;
                false
            },
        );
        engine.for_each_algo(|_m, st| {
            let s = st.shard_mut();
            std::mem::swap(&mut s.rank, &mut s.next);
        });
    }
    engine.gather(|_m, st| st.shard().rank.clone())
}
