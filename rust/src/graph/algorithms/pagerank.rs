//! PageRank iteration via DISTEDGEMAP (always dense — every vertex is
//! active every round, which is exactly where the destination-tree
//! aggregation and destination-aware broadcast pay off).
//!
//! The per-machine dense aggregation is the computation AOT-compiled as
//! the `spmv_panel` Pallas artifact (alpha·A·x + beta); the simulator
//! charges it as one work unit per edge.

use crate::graph::engine::GraphEngine;
use crate::graph::subset::DistVertexSubset;

pub const DAMPING: f64 = 0.85;

struct PrState {
    rank: Vec<f64>,
    next: Vec<f64>,
    out_deg: Vec<u64>,
}

/// Run `iters` PageRank iterations; returns the final rank vector.
pub fn pagerank<E: GraphEngine>(engine: &mut E, iters: usize) -> Vec<f64> {
    let part = engine.part().clone();
    let n = engine.n();
    let base = (1.0 - DAMPING) / n as f64;
    let per_machine = (n / part.p().max(1)) as u64;
    let mut st = PrState {
        rank: vec![1.0 / n as f64; n],
        next: vec![base; n],
        out_deg: (0..n as u32).map(|u| engine.out_degree(u)).collect(),
    };
    engine.charge_local(per_machine); // rank init sweep
    let all = DistVertexSubset::all(&part);
    for _ in 0..iters {
        st.next.fill(base);
        engine.charge_local(per_machine); // per-round base reset
        engine.edge_map(
            &mut st,
            &all,
            // f: share of the source's rank (dangling-free contribution).
            &mut |st: &PrState, u, _v, _w| {
                let d = st.out_deg[u as usize];
                if d == 0 {
                    None
                } else {
                    Some(st.rank[u as usize] / d as f64)
                }
            },
            // ⊗: contributions add.
            &|a, b| a + b,
            // ⊙: damped update; frontier membership irrelevant (dense).
            &mut |st, v, agg| {
                st.next[v as usize] = base + DAMPING * agg;
                false
            },
        );
        std::mem::swap(&mut st.rank, &mut st.next);
    }
    st.rank
}
