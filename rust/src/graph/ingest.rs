//! Ingestion-time orchestration (paper §5.1).
//!
//! One TD-Orch-style preprocessing pass when the graph is loaded resolves
//! all future skew: vertices are pinned by a degree-balanced schema, edges
//! are organized into per-source *edge blocks*, and blocks of hot (high
//! -degree) vertices are spread over transit machines instead of piling
//! onto the vertex owner.  The machines holding a vertex's blocks are the
//! leaves of its *source tree* (value broadcast) and the machines holding
//! its in-edges are the leaves of its *destination tree* (write-back
//! aggregation) — the persisted meta-task trees of §5.1.

use std::cell::Cell;

use crate::bsp::{Cluster, MachineId};
use crate::rng::{hash2, hash64};

use super::layout::BlockIndex;
use super::{Graph, VertexPart, Vid};

thread_local! {
    /// Per-thread count of full ingestion passes (see [`ingestions`]).
    static INGESTIONS: Cell<u64> = const { Cell::new(0) };
}

/// Number of full ingestion passes ([`ingest`] / [`ingest_at_owner`])
/// executed on the **calling thread** so far.  This is the serving
/// layer's regression counter: `repro serve` and `repro graph` assert
/// the graph was ingested exactly once however many queries ran, and
/// `SpmdEngine::reset_for_query` is what lets them keep that promise.
/// Thread-local on purpose — engines ingest on the thread constructing
/// them, so parallel test runs cannot disturb each other's counts.
pub fn ingestions() -> u64 {
    INGESTIONS.with(|c| c.get())
}

fn note_ingestion() {
    INGESTIONS.with(|c| c.set(c.get() + 1));
}

/// One edge block: a contiguous chunk of a vertex's out-edges parked on
/// one machine.
#[derive(Clone, Debug)]
pub struct EdgeBlock {
    pub src: Vid,
    pub targets: Vec<(Vid, f32)>,
}

/// Ingestion statistics (reported by the harness).
#[derive(Clone, Copy, Debug, Default)]
pub struct IngestStats {
    pub hot_vertices: u64,
    pub blocks: u64,
    pub moved_edges: u64,
}

/// The distributed graph after ingestion.
#[derive(Clone, Debug)]
pub struct DistGraph {
    pub n: usize,
    pub m: usize,
    pub p: usize,
    pub part: VertexPart,
    /// Per-machine edge blocks.
    pub blocks: Vec<Vec<EdgeBlock>>,
    /// Per-machine CSR index: source vertex -> indices into `blocks[m]`.
    pub block_of: Vec<BlockIndex>,
    /// Source-tree leaves: machines holding out-edge blocks of u.
    pub src_leaves: Vec<Vec<MachineId>>,
    /// Destination-tree leaves: machines holding in-edges of v.
    pub dst_leaves: Vec<Vec<MachineId>>,
    pub out_deg: Vec<u32>,
    /// Tree fanout C for source/destination trees.
    pub c: usize,
    pub stats: IngestStats,
}

/// Aggregation/broadcast relay tree over `members` rooted at `root`:
/// returns bottom-up levels of (child_machine, parent_machine) message
/// edges, C-ary, transit machines mapped by hash — the meta-task tree of
/// §3.3 persisted for graph use.  Empty when members == [root].
///
/// Duplicate transit parents are removed before each next grouping
/// round, so every machine appears **at most once per level**: a machine
/// holding a value/partial for the keyed vertex at depth `d` has exactly
/// one `(machine, parent)` edge in `levels[d]` — or none, iff it is the
/// root holding the final value.  This is load-bearing now that the tree
/// carries real partial aggregates (the unified SPMD engine): a machine
/// hashed into two positions of one level would otherwise send — and
/// double-count — its merged partial twice.  (The retired accounting
/// -only cost engine tolerated such duplicates; its non-deduped
/// `tree_levels` variant died with it.)
pub fn relay_tree_levels(
    key: u64,
    members: &[MachineId],
    root: MachineId,
    fanout: usize,
    p: usize,
) -> Vec<Vec<(MachineId, MachineId)>> {
    let fanout = fanout.max(2);
    let mut levels = Vec::new();
    let mut cur: Vec<MachineId> = members.to_vec();
    let mut depth = 0u64;
    while cur.len() > fanout {
        let mut next = Vec::with_capacity(cur.len().div_ceil(fanout));
        let mut edges = Vec::with_capacity(cur.len());
        for (gidx, group) in cur.chunks(fanout).enumerate() {
            let parent = (hash2(key, (depth << 32) | gidx as u64) % p as u64) as usize;
            for &child in group {
                edges.push((child, parent));
            }
            if !next.contains(&parent) {
                next.push(parent);
            }
        }
        levels.push(edges);
        cur = next;
        depth += 1;
    }
    let last: Vec<(MachineId, MachineId)> =
        cur.into_iter().filter(|m| *m != root).map(|m| (m, root)).collect();
    if !last.is_empty() {
        levels.push(last);
    }
    levels
}

/// Ingest `g` onto `p` machines.  `c` is the tree fanout / hot threshold
/// parameter (the paper's C).  Communication and work of the
/// preprocessing pass are charged to `cluster`.
pub fn ingest(cluster: &mut Cluster, g: &Graph, c: usize) -> DistGraph {
    note_ingestion();
    let p = cluster.p;
    let part = VertexPart::degree_balanced(g, p);
    let n = g.n;
    let m = g.m();
    let mut stats = IngestStats::default();

    // Hot vertices: degree above both C and a per-machine fair share
    // sliver get their blocks spread over transit machines.
    let hot_threshold = (c as u64).max((m as u64 / (8 * p as u64)).max(8));
    let block_cap = hot_threshold as usize;

    let mut blocks: Vec<Vec<EdgeBlock>> = (0..p).map(|_| Vec::new()).collect();
    // Per-machine (src, block idx) entries; the outer vertex loop below
    // runs ascending, so each machine's list is sorted by source — ready
    // for the CSR finalize without another sort.
    let mut index_entries: Vec<Vec<(Vid, u32)>> = (0..p).map(|_| Vec::new()).collect();
    let mut src_leaves: Vec<Vec<MachineId>> = vec![Vec::new(); n];
    let mut dst_leaves: Vec<Vec<MachineId>> = vec![Vec::new(); n];
    let mut out_deg = vec![0u32; n];
    // Greedy balance of spread blocks.
    let mut load: Vec<u64> = vec![0; p];

    let place_block = |u: Vid,
                           targets: Vec<(Vid, f32)>,
                           machine: MachineId,
                           blocks: &mut Vec<Vec<EdgeBlock>>,
                           index_entries: &mut Vec<Vec<(Vid, u32)>>,
                           load: &mut Vec<u64>| {
        load[machine] += targets.len() as u64;
        let idx = blocks[machine].len() as u32;
        blocks[machine].push(EdgeBlock { src: u, targets });
        index_entries[machine].push((u, idx));
    };

    for u in 0..n as Vid {
        let deg = g.out_degree(u);
        out_deg[u as usize] = deg as u32;
        if deg == 0 {
            continue;
        }
        let owner = part.owner(u);
        let neigh = g.neighbors(u);
        if deg <= hot_threshold {
            // Stage-1 push: the whole block co-locates with its source.
            place_block(u, neigh.to_vec(), owner, &mut blocks, &mut index_entries, &mut load);
            src_leaves[u as usize].push(owner);
        } else {
            // Hot source: blocks park on transit machines (TD-Orch would
            // have left them on the contention-detection forest; we place
            // them greedily-balanced with a deterministic hashed start,
            // which is what the randomized trees achieve).
            stats.hot_vertices += 1;
            let mut leaves = Vec::new();
            for (i, chunk) in neigh.chunks(block_cap).enumerate() {
                let machine = if i == 0 {
                    owner // first block stays home for locality
                } else {
                    // Least-loaded among a hashed probe pair (power of two
                    // choices keeps it deterministic AND balanced).
                    let a = (hash2(u as u64, i as u64) % p as u64) as usize;
                    let b = (hash2(u as u64, (i as u64) << 20) % p as u64) as usize;
                    if load[a] <= load[b] {
                        a
                    } else {
                        b
                    }
                };
                stats.moved_edges += if machine == owner { 0 } else { chunk.len() as u64 };
                place_block(u, chunk.to_vec(), machine, &mut blocks, &mut index_entries, &mut load);
                leaves.push(machine);
            }
            leaves.sort_unstable();
            leaves.dedup();
            src_leaves[u as usize] = leaves;
        }
    }
    stats.blocks = blocks.iter().map(|b| b.len() as u64).sum();

    // Destination-tree leaves: machines holding at least one in-edge of v.
    for (mach, machine_blocks) in blocks.iter().enumerate() {
        for block in machine_blocks {
            for (v, _) in &block.targets {
                dst_leaves[*v as usize].push(mach);
            }
        }
    }
    for leaves in dst_leaves.iter_mut() {
        leaves.sort_unstable();
        leaves.dedup();
    }

    // Charge the preprocessing cost: every edge starts on a random
    // machine (paper §5.1 stage 1) and moves to its final block host;
    // stage 2's destination-tree discovery sends one probe per edge.
    let mut probe_out: Vec<Vec<(MachineId, u32)>> = (0..p).map(|_| Vec::new()).collect();
    for (mach, machine_blocks) in blocks.iter().enumerate() {
        cluster.work(mach, load[mach]);
        for block in machine_blocks {
            let src_machine = (hash64(block.src as u64) % p as u64) as usize;
            if src_machine != mach {
                probe_out[src_machine].push((mach, block.targets.len() as u32));
            }
        }
    }
    let _ = cluster.exchange(probe_out, |sz| *sz as u64 * 3);
    let mut probe2: Vec<Vec<(MachineId, u32)>> = (0..p).map(|_| Vec::new()).collect();
    for (v, leaves) in dst_leaves.iter().enumerate() {
        let owner = part.owner(v as Vid);
        for &l in leaves {
            if l != owner {
                probe2[l].push((owner, 1));
            }
        }
    }
    let _ = cluster.exchange(probe2, |_| 1);

    let block_of = index_entries
        .into_iter()
        .map(|e| BlockIndex::from_entries(n, &e))
        .collect();
    DistGraph {
        n,
        m,
        p,
        part,
        blocks,
        block_of,
        src_leaves,
        dst_leaves,
        out_deg,
        c,
        stats,
    }
}

/// Baseline placement (gemini/ligra/LA families): every out-edge block
/// lives on its source's owner — no transit machines, so hub vertices
/// concentrate work on one machine.
pub fn ingest_at_owner(cluster: &mut Cluster, g: &Graph, c: usize) -> DistGraph {
    note_ingestion();
    let p = cluster.p;
    let part = VertexPart::degree_balanced(g, p);
    let n = g.n;
    let mut blocks: Vec<Vec<EdgeBlock>> = (0..p).map(|_| Vec::new()).collect();
    let mut index_entries: Vec<Vec<(Vid, u32)>> = (0..p).map(|_| Vec::new()).collect();
    let mut src_leaves: Vec<Vec<MachineId>> = vec![Vec::new(); n];
    let mut dst_leaves: Vec<Vec<MachineId>> = vec![Vec::new(); n];
    let mut out_deg = vec![0u32; n];
    for u in 0..n as Vid {
        let deg = g.out_degree(u);
        out_deg[u as usize] = deg as u32;
        if deg == 0 {
            continue;
        }
        let owner = part.owner(u);
        let idx = blocks[owner].len() as u32;
        blocks[owner].push(EdgeBlock { src: u, targets: g.neighbors(u).to_vec() });
        index_entries[owner].push((u, idx));
        src_leaves[u as usize].push(owner);
        cluster.work(owner, deg);
        for (v, _) in g.neighbors(u) {
            dst_leaves[*v as usize].push(owner);
        }
    }
    for leaves in dst_leaves.iter_mut() {
        leaves.sort_unstable();
        leaves.dedup();
    }
    cluster.barrier();
    let block_of = index_entries
        .into_iter()
        .map(|e| BlockIndex::from_entries(n, &e))
        .collect();
    DistGraph {
        n,
        m: g.m(),
        p,
        part,
        blocks,
        block_of,
        src_leaves,
        dst_leaves,
        out_deg,
        c,
        stats: IngestStats::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::CostModel;

    fn cluster(p: usize) -> Cluster {
        Cluster::new(p, CostModel::paper_cluster())
    }

    #[test]
    fn all_edges_placed_exactly_once() {
        let g = gen::barabasi_albert(2000, 6, 1);
        let mut c = cluster(8);
        let dg = ingest(&mut c, &g, 8);
        let placed: usize = dg
            .blocks
            .iter()
            .flat_map(|bs| bs.iter().map(|b| b.targets.len()))
            .sum();
        assert_eq!(placed, g.m());
    }

    /// Star graph: vertex 0 adjacent to everything, plus a ring so every
    /// machine holds background edges — a hub whose degree exceeds any
    /// machine's fair share m/P.
    fn star_graph(n: usize) -> crate::graph::Graph {
        let mut arcs = Vec::new();
        for v in 1..n as Vid {
            arcs.push((0, v, 1.0));
            arcs.push((v, 0, 1.0));
            let w = if v as usize == n - 1 { 1 } else { v + 1 };
            arcs.push((v, w, 1.0));
            arcs.push((w, v, 1.0));
        }
        crate::graph::Graph::from_arcs(n, arcs)
    }

    #[test]
    fn hot_vertices_spread_over_machines() {
        let g = star_graph(4000);
        let mut c = cluster(8);
        let dg = ingest(&mut c, &g, 8);
        assert!(dg.stats.hot_vertices > 0);
        // The hub's blocks span multiple machines.
        assert!(
            dg.src_leaves[0].len() > 1,
            "hub deg {} on {:?}",
            g.out_degree(0),
            dg.src_leaves[0]
        );
    }

    #[test]
    fn edge_load_balanced_on_skewed_graph() {
        let g = gen::barabasi_albert(4000, 8, 3);
        let mut c = cluster(8);
        let dg = ingest(&mut c, &g, 8);
        let loads: Vec<u64> = dg
            .blocks
            .iter()
            .map(|bs| bs.iter().map(|b| b.targets.len() as u64).sum())
            .collect();
        let max = *loads.iter().max().unwrap() as f64;
        let mean = g.m() as f64 / 8.0;
        assert!(max / mean < 1.6, "edge imbalance {:.2} ({loads:?})", max / mean);
    }

    #[test]
    fn owner_placement_concentrates_hubs() {
        let g = gen::barabasi_albert(4000, 8, 3);
        let mut c = cluster(8);
        let dg = ingest_at_owner(&mut c, &g, 8);
        let hub = (0..g.n as Vid).max_by_key(|u| g.out_degree(*u)).unwrap();
        assert_eq!(dg.src_leaves[hub as usize].len(), 1);
        let placed: usize = dg
            .blocks
            .iter()
            .flat_map(|bs| bs.iter().map(|b| b.targets.len()))
            .sum();
        assert_eq!(placed, g.m());
    }

    #[test]
    fn dst_leaves_cover_in_edges() {
        let g = gen::grid2d(12, 4);
        let mut c = cluster(4);
        let dg = ingest(&mut c, &g, 4);
        // Every edge's target lists the block's machine as a dst leaf.
        for (mach, bs) in dg.blocks.iter().enumerate() {
            for b in bs {
                for (v, _) in &b.targets {
                    assert!(dg.dst_leaves[*v as usize].contains(&mach));
                }
            }
        }
    }

    #[test]
    fn relay_tree_structure() {
        // 9 members, fanout 3, root 0: one transit level then the root.
        let members: Vec<usize> = (1..10).collect();
        let levels = relay_tree_levels(42, &members, 0, 3, 16);
        assert!(levels.len() >= 2);
        // Bottom level has one message per member.
        assert_eq!(levels[0].len(), 9);
        // All paths terminate at the root.
        let last = levels.last().unwrap();
        assert!(last.iter().all(|(_, to)| *to == 0));
    }

    #[test]
    fn relay_tree_trivial_cases() {
        assert!(relay_tree_levels(1, &[5], 5, 4, 8).is_empty());
        let lv = relay_tree_levels(1, &[3], 5, 4, 8);
        assert_eq!(lv, vec![vec![(3, 5)]]);
    }

    #[test]
    fn relay_tree_bounded_depth() {
        let members: Vec<usize> = (0..16).collect();
        let levels = relay_tree_levels(9, &members, 0, 2, 16);
        // depth ≤ ceil(log2 16) + 1
        assert!(levels.len() <= 5, "depth {}", levels.len());
    }

    #[test]
    fn relay_tree_levels_unique_child_per_level() {
        // The relay invariant (regression for the retired non-deduped
        // `tree_levels`, which could hash two groups of one level to the
        // same transit parent and then treat that machine as two children
        // of the next — a double-send of a real merged partial): no
        // machine appears as child twice in one level.
        for key in [1u64, 7, 42, 0xD5, 991] {
            for p in [4usize, 8, 16] {
                let members: Vec<usize> = (0..p).collect();
                for root in [0usize, p - 1] {
                    let levels = relay_tree_levels(key, &members, root, 2, p);
                    for (d, level) in levels.iter().enumerate() {
                        let mut children: Vec<usize> =
                            level.iter().map(|(c, _)| *c).collect();
                        let n = children.len();
                        children.sort_unstable();
                        children.dedup();
                        assert_eq!(children.len(), n, "dup child at level {d} (key={key})");
                    }
                }
            }
        }
    }

    #[test]
    fn relay_tree_walk_conserves_partials() {
        // Simulate the SPMD merge walk: every member starts with value 1;
        // at level d, each holder with a (m, parent) edge ships its
        // partial to the parent.  The root must end with exactly
        // |members| — nothing lost, nothing double-counted.
        for key in [3u64, 19, 0x5EED] {
            let p = 16;
            let members: Vec<usize> = (0..12).collect();
            let root = 5usize;
            let levels = relay_tree_levels(key, &members, root, 3, p);
            let mut holding = vec![0u64; p];
            for &m in &members {
                holding[m] += 1;
            }
            for level in &levels {
                let mut incoming = vec![0u64; p];
                for &(child, parent) in level {
                    incoming[parent] += holding[child];
                    holding[child] = 0;
                }
                for m in 0..p {
                    holding[m] += incoming[m];
                }
            }
            assert_eq!(holding[root], members.len() as u64, "key={key}");
            let stray: u64 = (0..p).filter(|m| *m != root).map(|m| holding[m]).sum();
            assert_eq!(stray, 0, "partials stranded off-root (key={key})");
        }
    }
}
