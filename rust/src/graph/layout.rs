//! Flat machine-local storage for the SPMD engine's hot paths.
//!
//! The engine's inner loops used to live in `DetMap` scratch
//! (relay/agg/pending and their lane variants), a `DetMap<Vid, Vec<u32>>`
//! block index, and a plain `Vec<Vid>` frontier — every superstep paid
//! hashing on each message fold plus a `keys().copied().collect()` +
//! sort re-materialization per phase.  This module replaces all three
//! with flat, index-addressed structures:
//!
//! * [`Slab`] / [`LaneSlab`] — dense `Vec<f64>` value slabs with a
//!   `present` bitmap and an explicit **dirty-list** of touched keys.
//!   Inserts/merges are O(1) array stores; per-phase iteration is one
//!   `normalize()` (retain-present + sort + dedup of the dirty-list —
//!   the same ascending-unique order the old collect-and-sort produced,
//!   over a list proportional to the *touched* set, not the map) and a
//!   linear walk.
//! * [`BlockIndex`] — the per-machine source→edge-block index in CSR
//!   form (offsets + data) instead of a hash map of Vecs.
//! * [`Frontier`] — the per-machine active-vertex set over the owned
//!   range, sparse `Vec<Vid>` at low occupancy and a dense bitset at
//!   high occupancy, switched by a **deterministic** threshold at
//!   [`Frontier::seal`].  Both representations iterate in ascending
//!   vertex order and report the same length, so the switch is
//!   observationally invisible to the engine — which is what keeps the
//!   threaded==sim bit-equality contract (the license for this surgery)
//!   intact.
//!
//! Determinism note: nothing here iterates in hash order.  Every
//! iteration surface (`Slab::dirty` after `normalize`, `BlockIndex::get`,
//! `Frontier::iter`) is ascending and a pure function of the inserted
//! key set, exactly matching the sorted-key iteration the DetMap code
//! performed — so the swap changes constants, not bits.

use super::Vid;

/// Dense f64 scratch keyed by vertex id with an explicit dirty-list.
///
/// Semantics mirror the `DetMap<Vid, f64>` it replaces:
/// * [`Slab::insert`]        == `map.insert(k, v)` (overwrite)
/// * [`Slab::insert_first`]  == `map.entry(k).or_insert(v)` (first write wins)
/// * [`Slab::merge_with`]    == `map.entry(k).and_modify(f).or_insert(v)`
/// * [`Slab::take`]          == `map.remove(&k)`
/// * [`Slab::normalize`] + [`Slab::dirty`] == sorted `map.keys()`
///
/// `take` leaves a stale entry on the dirty-list (cleaned by the next
/// `normalize`/`clear`), and re-inserting a taken key pushes it again —
/// `normalize` dedups, so the iteration set is always exactly the live
/// key set in ascending order.
#[derive(Clone, Debug, Default)]
pub struct Slab {
    vals: Vec<f64>,
    present: Vec<bool>,
    dirty: Vec<Vid>,
}

impl Slab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Size the slab for keys in `0..n`.  Idempotent; call once at
    /// machine construction.
    pub fn ensure(&mut self, n: usize) {
        if self.vals.len() < n {
            self.vals.resize(n, 0.0);
            self.present.resize(n, false);
        }
    }

    /// Remove every entry (O(touched), not O(n)).
    pub fn clear(&mut self) {
        for &v in &self.dirty {
            self.present[v as usize] = false;
        }
        self.dirty.clear();
    }

    #[inline]
    pub fn get(&self, v: Vid) -> Option<f64> {
        if self.present[v as usize] {
            Some(self.vals[v as usize])
        } else {
            None
        }
    }

    /// Overwriting insert.
    #[inline]
    pub fn insert(&mut self, v: Vid, val: f64) {
        let i = v as usize;
        if !self.present[i] {
            self.present[i] = true;
            self.dirty.push(v);
        }
        self.vals[i] = val;
    }

    /// First write wins (`entry().or_insert()`).
    #[inline]
    pub fn insert_first(&mut self, v: Vid, val: f64) {
        let i = v as usize;
        if !self.present[i] {
            self.present[i] = true;
            self.dirty.push(v);
            self.vals[i] = val;
        }
    }

    /// `entry().and_modify(|a| *a = f(*a, val)).or_insert(val)`.
    #[inline]
    pub fn merge_with(&mut self, v: Vid, val: f64, f: impl Fn(f64, f64) -> f64) {
        let i = v as usize;
        if self.present[i] {
            self.vals[i] = f(self.vals[i], val);
        } else {
            self.present[i] = true;
            self.dirty.push(v);
            self.vals[i] = val;
        }
    }

    /// `map.remove(&v)` — the dirty-list keeps a stale entry until the
    /// next `normalize`/`clear`.
    #[inline]
    pub fn take(&mut self, v: Vid) -> Option<f64> {
        let i = v as usize;
        if self.present[i] {
            self.present[i] = false;
            Some(self.vals[i])
        } else {
            None
        }
    }

    /// Sort + dedup the dirty-list and drop stale (taken) entries, so
    /// [`Slab::dirty`] is exactly the live key set, ascending — the same
    /// order the old `keys().collect()` + `sort_unstable()` produced.
    pub fn normalize(&mut self) {
        let present = &self.present;
        self.dirty.retain(|&v| present[v as usize]);
        self.dirty.sort_unstable();
        self.dirty.dedup();
    }

    /// The touched key list.  Ascending and duplicate-free only after
    /// [`Slab::normalize`].
    #[inline]
    pub fn dirty(&self) -> &[Vid] {
        &self.dirty
    }

    #[inline]
    pub fn dirty_len(&self) -> usize {
        self.dirty.len()
    }

    /// Indexed access into the dirty-list, for loops that `take` from
    /// the slab while walking it (taking flips `present` but never
    /// touches the dirty-list, so indices stay stable).
    #[inline]
    pub fn key_at(&self, i: usize) -> Vid {
        self.dirty[i]
    }
}

/// [`Slab`] keyed by `(vertex, lane)` — the fused multi-source scratch.
/// Values live at flat index `v * lanes + lane`; the dirty-list holds
/// `(Vid, u32)` pairs whose sorted order equals the old `DetMap`
/// sorted-key order (tuple order: vertex-major, lane-minor).
#[derive(Clone, Debug, Default)]
pub struct LaneSlab {
    vals: Vec<f64>,
    present: Vec<bool>,
    dirty: Vec<(Vid, u32)>,
    lanes: u32,
}

impl LaneSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Prepare for a fused pass over keys in `0..n` × `0..lanes`.
    /// Clears any previous contents; storage is retained when the
    /// geometry shrinks, grown when it doesn't fit.
    pub fn configure(&mut self, n: usize, lanes: u32) {
        self.clear();
        self.lanes = lanes;
        let need = n * lanes as usize;
        if self.vals.len() < need {
            self.vals.resize(need, 0.0);
            self.present.resize(need, false);
        }
    }

    pub fn clear(&mut self) {
        for &(v, l) in &self.dirty {
            let i = self.idx(v, l);
            self.present[i] = false;
        }
        self.dirty.clear();
    }

    #[inline]
    fn idx(&self, v: Vid, lane: u32) -> usize {
        v as usize * self.lanes as usize + lane as usize
    }

    #[inline]
    pub fn get(&self, key: (Vid, u32)) -> Option<f64> {
        let i = self.idx(key.0, key.1);
        if self.present[i] {
            Some(self.vals[i])
        } else {
            None
        }
    }

    #[inline]
    pub fn insert(&mut self, key: (Vid, u32), val: f64) {
        let i = self.idx(key.0, key.1);
        if !self.present[i] {
            self.present[i] = true;
            self.dirty.push(key);
        }
        self.vals[i] = val;
    }

    #[inline]
    pub fn insert_first(&mut self, key: (Vid, u32), val: f64) {
        let i = self.idx(key.0, key.1);
        if !self.present[i] {
            self.present[i] = true;
            self.dirty.push(key);
            self.vals[i] = val;
        }
    }

    #[inline]
    pub fn merge_with(&mut self, key: (Vid, u32), val: f64, f: impl Fn(f64, f64) -> f64) {
        let i = self.idx(key.0, key.1);
        if self.present[i] {
            self.vals[i] = f(self.vals[i], val);
        } else {
            self.present[i] = true;
            self.dirty.push(key);
            self.vals[i] = val;
        }
    }

    #[inline]
    pub fn take(&mut self, key: (Vid, u32)) -> Option<f64> {
        let i = self.idx(key.0, key.1);
        if self.present[i] {
            self.present[i] = false;
            Some(self.vals[i])
        } else {
            None
        }
    }

    pub fn normalize(&mut self) {
        let present = &self.present;
        let lanes = self.lanes as usize;
        self.dirty
            .retain(|&(v, l)| present[v as usize * lanes + l as usize]);
        self.dirty.sort_unstable();
        self.dirty.dedup();
    }

    #[inline]
    pub fn dirty(&self) -> &[(Vid, u32)] {
        &self.dirty
    }

    #[inline]
    pub fn dirty_len(&self) -> usize {
        self.dirty.len()
    }

    #[inline]
    pub fn key_at(&self, i: usize) -> (Vid, u32) {
        self.dirty[i]
    }

    /// The contiguous run of live `(v, lane)` keys for vertex `v`, lanes
    /// ascending.  Requires a prior [`LaneSlab::normalize`] (the run is
    /// found by binary search on the sorted dirty-list).  This replaces
    /// the per-superstep `by_src: DetMap<Vid, Vec<_>>` regrouping the
    /// fused scan path used to build.
    pub fn pairs_for(&self, v: Vid) -> &[(Vid, u32)] {
        let lo = self.dirty.partition_point(|&(u, _)| u < v);
        let hi = self.dirty.partition_point(|&(u, _)| u <= v);
        &self.dirty[lo..hi]
    }
}

/// CSR-style per-machine source→edge-block index: `data[offsets[u] ..
/// offsets[u+1]]` are the indices into the machine's block vector whose
/// `src == u`, ascending.  Replaces `DetMap<Vid, Vec<u32>>` — lookup is
/// two array reads instead of a hash, and iteration order is inherent.
#[derive(Clone, Debug)]
pub struct BlockIndex {
    offsets: Vec<u32>,
    data: Vec<u32>,
}

impl BlockIndex {
    pub fn empty(n: usize) -> Self {
        BlockIndex {
            offsets: vec![0; n + 1],
            data: Vec::new(),
        }
    }

    /// Build from `(src, block_idx)` entries sorted ascending by src
    /// (ingestion emits them that way: its outer loop walks vertices in
    /// order, appending each machine's entries ascending).
    pub fn from_entries(n: usize, entries: &[(Vid, u32)]) -> Self {
        debug_assert!(
            entries.windows(2).all(|w| w[0].0 <= w[1].0),
            "BlockIndex entries must be sorted by source"
        );
        let mut offsets = vec![0u32; n + 1];
        for &(u, _) in entries {
            offsets[u as usize + 1] += 1;
        }
        for i in 0..n {
            offsets[i + 1] += offsets[i];
        }
        let data = entries.iter().map(|&(_, idx)| idx).collect();
        BlockIndex { offsets, data }
    }

    /// Block indices for source `u` (empty slice when the machine holds
    /// none of `u`'s blocks).
    #[inline]
    pub fn get(&self, u: Vid) -> &[u32] {
        let lo = self.offsets[u as usize] as usize;
        let hi = self.offsets[u as usize + 1] as usize;
        &self.data[lo..hi]
    }

    /// First resident block of `u` — the accretion target for live edge
    /// inserts.
    #[inline]
    pub fn first(&self, u: Vid) -> Option<u32> {
        self.get(u).first().copied()
    }

    /// Register a new block index for `u`.  O(n) — used only by the live
    /// -mutation path when a machine gains its first block for a source
    /// (batches are small; the read paths stay O(1)).
    pub fn insert(&mut self, u: Vid, idx: u32) {
        let at = self.offsets[u as usize + 1] as usize;
        self.data.insert(at, idx);
        for off in self.offsets[u as usize + 1..].iter_mut() {
            *off += 1;
        }
    }

    /// Unregister block index `idx` from `u`'s run; returns whether it
    /// was present.  O(n), the mirror of [`BlockIndex::insert`] — used
    /// only by the placement path when a block migrates off a machine
    /// (the block vector slot stays, hollowed, so all *other* indices
    /// remain valid).
    pub fn remove(&mut self, u: Vid, idx: u32) -> bool {
        let lo = self.offsets[u as usize] as usize;
        let hi = self.offsets[u as usize + 1] as usize;
        let Some(pos) = self.data[lo..hi].iter().position(|&b| b == idx) else {
            return false;
        };
        self.data.remove(lo + pos);
        for off in self.offsets[u as usize + 1..].iter_mut() {
            *off -= 1;
        }
        true
    }
}

/// Occupancy divisor for the sparse↔dense frontier switch: the dense
/// bitset representation engages when at least `span / DENSE_OCCUPANCY_DIV`
/// of a machine's owned range is active.  A pure function of (active
/// count, span) — identical on every backend at every P, so the switch
/// can never perturb results.
pub const DENSE_OCCUPANCY_DIV: usize = 16;

/// Spans below this stay sparse: a bitset over a handful of words saves
/// nothing and the sparse path is simpler to reason about at tiny P.
pub const DENSE_MIN_SPAN: usize = 64;

/// The per-machine active-vertex set over the owned range
/// `[base, base + span)`.
///
/// Accumulation (`push`/`insert`) goes into a recycled sparse vec;
/// [`Frontier::seal`] converts to a dense bitset when occupancy crosses
/// `span / DENSE_OCCUPANCY_DIV` (and the span is worth it) —
/// deterministically, per round.  `fill_all` is the all-active fast path
/// (O(span/64) instead of materializing the whole range).  Both
/// representations iterate ascending and agree on `len`, so engine
/// behavior — and therefore the cross-backend bit contract — cannot
/// depend on which one is active.
#[derive(Clone, Debug)]
pub struct Frontier {
    base: Vid,
    span: usize,
    sparse: Vec<Vid>,
    bits: Vec<u64>,
    count: usize,
    dense: bool,
}

impl Frontier {
    pub fn new(base: Vid, span: usize) -> Self {
        Frontier {
            base,
            span,
            sparse: Vec::new(),
            bits: Vec::new(),
            count: 0,
            dense: false,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.count
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    #[inline]
    pub fn is_dense(&self) -> bool {
        self.dense
    }

    /// Empty the set and return to sparse accumulation (capacity kept).
    pub fn clear(&mut self) {
        self.sparse.clear();
        if self.dense {
            self.bits.iter_mut().for_each(|w| *w = 0);
        }
        self.dense = false;
        self.count = 0;
    }

    /// Append an owned vertex in ascending order (the engine's write-back
    /// loop walks vertices ascending, so this is the hot path).
    #[inline]
    pub fn push(&mut self, v: Vid) {
        debug_assert!(
            v >= self.base && ((v - self.base) as usize) < self.span,
            "frontier push outside owned range"
        );
        if self.dense {
            let bit = (v - self.base) as usize;
            let w = &mut self.bits[bit / 64];
            let mask = 1u64 << (bit % 64);
            if *w & mask == 0 {
                *w |= mask;
                self.count += 1;
            }
        } else {
            debug_assert!(
                self.sparse.last().is_none_or(|&last| last < v),
                "sparse frontier pushes must be ascending"
            );
            self.sparse.push(v);
            self.count += 1;
        }
    }

    /// Insert an owned vertex in any order (seed paths, tests).
    pub fn insert(&mut self, v: Vid) {
        if self.dense {
            self.push(v);
            return;
        }
        match self.sparse.binary_search(&v) {
            Ok(_) => {}
            Err(pos) => {
                self.sparse.insert(pos, v);
                self.count += 1;
            }
        }
    }

    /// Mark the whole owned range active via the dense representation.
    pub fn fill_all(&mut self) {
        self.clear();
        self.ensure_bits();
        let full_words = self.span / 64;
        for w in &mut self.bits[..full_words] {
            *w = u64::MAX;
        }
        let rem = self.span % 64;
        if rem > 0 {
            self.bits[full_words] = (1u64 << rem) - 1;
        }
        self.dense = true;
        self.count = self.span;
    }

    fn ensure_bits(&mut self) {
        let words = self.span.div_ceil(64);
        if self.bits.len() < words {
            self.bits.resize(words, 0);
        }
    }

    /// Finish a round of accumulation: switch to the dense bitset iff
    /// occupancy ≥ span / [`DENSE_OCCUPANCY_DIV`] and the span clears
    /// [`DENSE_MIN_SPAN`].  Pure function of (count, span) — same
    /// decision on every backend.
    pub fn seal(&mut self) {
        if self.dense || self.span < DENSE_MIN_SPAN {
            return;
        }
        if self.count * DENSE_OCCUPANCY_DIV >= self.span {
            self.force_dense();
        }
    }

    /// Densify regardless of the occupancy threshold.  A bench/test seam
    /// — engine code only densifies through [`Frontier::seal`], which is
    /// what keeps the switch deterministic.
    pub fn force_dense(&mut self) {
        if self.dense {
            return;
        }
        self.ensure_bits();
        for &v in &self.sparse {
            let bit = (v - self.base) as usize;
            self.bits[bit / 64] |= 1u64 << (bit % 64);
        }
        self.sparse.clear();
        self.dense = true;
    }

    /// Ascending iteration over active vertices — identical order in
    /// both representations.
    pub fn iter(&self) -> FrontierIter<'_> {
        if self.dense {
            FrontierIter::Dense {
                bits: &self.bits,
                base: self.base,
                word: 0,
                cur: self.bits.first().copied().unwrap_or(0),
            }
        } else {
            FrontierIter::Sparse(self.sparse.iter())
        }
    }

    pub fn to_vec(&self) -> Vec<Vid> {
        self.iter().collect()
    }
}

pub enum FrontierIter<'a> {
    Sparse(std::slice::Iter<'a, Vid>),
    Dense {
        bits: &'a [u64],
        base: Vid,
        word: usize,
        cur: u64,
    },
}

impl Iterator for FrontierIter<'_> {
    type Item = Vid;

    #[inline]
    fn next(&mut self) -> Option<Vid> {
        match self {
            FrontierIter::Sparse(it) => it.next().copied(),
            FrontierIter::Dense {
                bits,
                base,
                word,
                cur,
            } => {
                while *cur == 0 {
                    *word += 1;
                    if *word >= bits.len() {
                        return None;
                    }
                    *cur = bits[*word];
                }
                let bit = cur.trailing_zeros() as usize;
                *cur &= *cur - 1;
                Some(*base + (*word * 64 + bit) as Vid)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_matches_map_semantics() {
        let mut s = Slab::new();
        s.ensure(16);
        s.insert_first(3, 1.0);
        s.insert_first(3, 9.0); // first write wins
        assert_eq!(s.get(3), Some(1.0));
        s.merge_with(3, 5.0, f64::min);
        assert_eq!(s.get(3), Some(1.0));
        s.merge_with(7, 2.0, f64::min); // or_insert arm
        assert_eq!(s.get(7), Some(2.0));
        s.insert(7, 4.0); // overwrite
        assert_eq!(s.get(7), Some(4.0));
        assert_eq!(s.take(3), Some(1.0));
        assert_eq!(s.take(3), None);
        assert_eq!(s.get(3), None);
    }

    #[test]
    fn slab_normalize_yields_ascending_live_keys() {
        let mut s = Slab::new();
        s.ensure(32);
        for v in [9u32, 2, 30, 2, 17] {
            s.merge_with(v, 1.0, |a, b| a + b);
        }
        s.take(17);
        s.insert(17, 3.0); // re-inserted after take → duplicate dirty entry
        s.take(30); // stale entry
        s.normalize();
        assert_eq!(s.dirty(), &[2, 9, 17]);
        // take during an indexed walk leaves indices stable
        for i in 0..s.dirty_len() {
            let v = s.key_at(i);
            assert!(s.take(v).is_some());
        }
        s.normalize();
        assert!(s.dirty().is_empty());
    }

    #[test]
    fn slab_clear_is_o_touched_and_idempotent() {
        let mut s = Slab::new();
        s.ensure(8);
        s.insert(1, 1.0);
        s.insert(5, 2.0);
        s.clear();
        assert_eq!(s.get(1), None);
        assert_eq!(s.get(5), None);
        s.clear();
        s.insert(5, 7.0);
        assert_eq!(s.get(5), Some(7.0));
        s.normalize();
        assert_eq!(s.dirty(), &[5]);
    }

    #[test]
    fn lane_slab_orders_vertex_major_lane_minor() {
        let mut s = LaneSlab::new();
        s.configure(8, 3);
        s.insert_first((5, 2), 1.0);
        s.insert_first((1, 1), 2.0);
        s.insert_first((5, 0), 3.0);
        s.insert_first((1, 1), 9.0); // first write wins
        s.normalize();
        assert_eq!(s.dirty(), &[(1, 1), (5, 0), (5, 2)]);
        assert_eq!(s.pairs_for(5), &[(5, 0), (5, 2)]);
        assert_eq!(s.pairs_for(1), &[(1, 1)]);
        assert!(s.pairs_for(3).is_empty());
        assert_eq!(s.get((1, 1)), Some(2.0));
        // reconfigure resets contents, keeps storage
        s.configure(8, 3);
        assert_eq!(s.get((1, 1)), None);
        assert_eq!(s.dirty_len(), 0);
    }

    #[test]
    fn block_index_matches_map_of_vecs() {
        // entries as ingestion emits them: ascending src, idx order kept
        let entries = vec![(0u32, 0u32), (0, 1), (3, 2), (7, 3)];
        let ix = BlockIndex::from_entries(8, &entries);
        assert_eq!(ix.get(0), &[0, 1]);
        assert_eq!(ix.get(3), &[2]);
        assert_eq!(ix.get(7), &[3]);
        assert!(ix.get(5).is_empty());
        assert_eq!(ix.first(0), Some(0));
        assert_eq!(ix.first(5), None);
        let empty = BlockIndex::empty(4);
        assert!(empty.get(2).is_empty());
    }

    #[test]
    fn block_index_insert_registers_new_source() {
        let mut ix = BlockIndex::from_entries(6, &[(1, 0), (4, 1)]);
        ix.insert(2, 7);
        assert_eq!(ix.get(1), &[0]);
        assert_eq!(ix.get(2), &[7]);
        assert_eq!(ix.get(4), &[1]);
        ix.insert(2, 9); // second block for the same source appends
        assert_eq!(ix.get(2), &[7, 9]);
    }

    #[test]
    fn frontier_sparse_and_dense_iterate_identically() {
        let base = 100u32;
        let span = 256usize;
        let mut f = Frontier::new(base, span);
        let picks: Vec<Vid> = (0..span as Vid).step_by(3).map(|i| base + i).collect();
        for &v in &picks {
            f.push(v);
        }
        assert!(!f.is_dense());
        let sparse_order = f.to_vec();
        f.seal(); // 86/256 ≥ 256/16 → densify
        assert!(f.is_dense());
        assert_eq!(f.len(), picks.len());
        assert_eq!(f.to_vec(), sparse_order);
        assert_eq!(sparse_order, picks);
    }

    #[test]
    fn frontier_switch_threshold_is_exact() {
        let span = 160usize;
        let threshold = span / DENSE_OCCUPANCY_DIV; // 10
        let mut f = Frontier::new(0, span);
        for v in 0..threshold as Vid - 1 {
            f.push(v);
        }
        f.seal();
        assert!(!f.is_dense(), "below threshold must stay sparse");
        f.push(threshold as Vid - 1);
        f.seal();
        assert!(f.is_dense(), "at threshold must densify");
        // tiny spans never densify
        let mut tiny = Frontier::new(0, DENSE_MIN_SPAN - 1);
        for v in 0..(DENSE_MIN_SPAN - 1) as Vid {
            tiny.push(v);
        }
        tiny.seal();
        assert!(!tiny.is_dense());
    }

    #[test]
    fn frontier_fill_all_masks_the_last_word() {
        let mut f = Frontier::new(64, 100);
        f.fill_all();
        assert!(f.is_dense());
        assert_eq!(f.len(), 100);
        let all = f.to_vec();
        assert_eq!(all.len(), 100);
        assert_eq!(all[0], 64);
        assert_eq!(*all.last().unwrap(), 64 + 99);
        // clear returns to sparse accumulation with no leftover bits
        f.clear();
        assert_eq!(f.len(), 0);
        f.push(70);
        f.seal();
        assert_eq!(f.to_vec(), vec![70]);
    }

    #[test]
    fn frontier_insert_is_order_insensitive_and_dedups() {
        let mut f = Frontier::new(0, 128);
        f.insert(9);
        f.insert(4);
        f.insert(9);
        assert_eq!(f.to_vec(), vec![4, 9]);
        assert_eq!(f.len(), 2);
        f.fill_all();
        f.insert(4); // dense-mode insert is idempotent too
        assert_eq!(f.len(), 128);
    }
}
