//! PJRT artifact engine — the Rust↔XLA bridge.
//!
//! Loads the HLO-text artifacts emitted once by `python/compile/aot.py`
//! (`make artifacts`), compiles them on the PJRT CPU client, and exposes
//! typed batch-execution entry points used from Phase 3 of the
//! orchestrator and from the graph engines.  Python is never on this
//! path: after `make artifacts` the binary is self-contained.
//!
//! Interchange is HLO *text*, not serialized protos — jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! ## Feature gating
//!
//! The real engine needs the `xla` crate, which the offline build image
//! cannot fetch (no registry).  It therefore compiles only with
//! `--features xla` **and** `--cfg xla_vendored` after vendoring the
//! dependency (see Cargo.toml).  The default build ships a stub
//! [`Engine`] with the same API whose `load` always fails, so every
//! consumer (KV store, smoke test, benches) degrades to the native
//! lambda path exactly as if artifacts were missing.  Building with the
//! feature but without the vendored crate hits the directed
//! `compile_error!` below instead of a bare E0433 "undeclared crate
//! `xla`".  Manifest parsing is feature-independent and stays tested.

// `--all-features` / `--features xla` without the vendored crate used to
// die with E0433 at the first `xla::` path.  Gate the real engine on the
// `xla_vendored` cfg as well, so the only error in that configuration is
// this recipe.  (`xla_vendored` is declared to check-cfg via
// [lints.rust] in Cargo.toml.)
#[cfg(all(feature = "xla", not(xla_vendored)))]
compile_error!(
    "tdorch was built with `--features xla` but the xla-rs crate is not vendored: \
     vendor it (e.g. into rust/vendor/xla-rs), add `xla = { path = \"vendor/xla-rs\" }` \
     under [dependencies] in rust/Cargo.toml, then rebuild with \
     RUSTFLAGS=\"--cfg xla_vendored\" --features xla (see Cargo.toml)"
);

use std::fmt;
use std::path::{Path, PathBuf};

/// Error type for the artifact runtime (the crate is dependency-free, so
/// no `anyhow` here).
#[derive(Clone, Debug)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        RuntimeError(msg.into())
    }
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Shape of one artifact input/output (row-major dims; empty = scalar).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactShape(pub Vec<usize>);

impl ArtifactShape {
    pub fn elements(&self) -> usize {
        self.0.iter().product()
    }

    fn parse(s: &str) -> Result<Self> {
        if s == "scalar" {
            return Ok(ArtifactShape(vec![]));
        }
        let dims = s
            .split('x')
            .map(|d| {
                d.parse::<usize>()
                    .map_err(|e| RuntimeError::new(format!("bad dim {d}: {e}")))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ArtifactShape(dims))
    }
}

/// One manifest entry: artifact name, file, input shapes, output shape.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<ArtifactShape>,
    pub output: ArtifactShape,
}

/// Parse `manifest.tsv` (emitted alongside the HLO text by aot.py).
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>> {
    let mut entries = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 4 {
            return Err(RuntimeError::new(format!(
                "manifest line {} malformed: {line:?}",
                lineno + 1
            )));
        }
        entries.push(ManifestEntry {
            name: cols[0].to_string(),
            file: cols[1].to_string(),
            inputs: cols[2]
                .split(',')
                .map(ArtifactShape::parse)
                .collect::<Result<Vec<_>>>()?,
            output: ArtifactShape::parse(cols[3])?,
        });
    }
    Ok(entries)
}

/// The conventional artifact directory (`$TDORCH_ARTIFACTS` or
/// `./artifacts`).
fn default_dir() -> String {
    std::env::var("TDORCH_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}

// ---------------------------------------------------------------------
// Stub engine (default build): same API, `load` always fails.
// ---------------------------------------------------------------------

/// Artifact engine stub — the crate was built without the `xla` feature.
#[cfg(not(all(feature = "xla", xla_vendored)))]
pub struct Engine {
    dir: PathBuf,
}

#[cfg(not(all(feature = "xla", xla_vendored)))]
impl Engine {
    fn unavailable(what: &str) -> RuntimeError {
        RuntimeError::new(format!(
            "{what}: tdorch was built without the real PJRT engine — artifact \
             execution is unavailable; vendor the xla crate and rebuild with \
             `--features xla` and RUSTFLAGS=\"--cfg xla_vendored\" (see Cargo.toml)"
        ))
    }

    /// Always fails in the stub build (see module docs).
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let _ = dir.as_ref();
        Err(Self::unavailable("Engine::load"))
    }

    /// Load from the conventional location (`$TDORCH_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Engine> {
        Self::load(default_dir())
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Execute artifact `name` on f32 inputs (unavailable in the stub).
    pub fn run_f32(&self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        Err(Self::unavailable(name))
    }

    /// Batched YCSB lambda: out[i] = vals[i] * mul[i] + add[i].
    pub fn ycsb_batch(&self, _vals: &[f32], _mul: &[f32], _add: &[f32]) -> Result<Vec<f32>> {
        Err(Self::unavailable("ycsb_batch"))
    }

    /// Batched SSSP relaxation: out[i] = min(dv[i], du[i] + w[i]).
    pub fn relax_batch(&self, _dv: &[f32], _du: &[f32], _w: &[f32]) -> Result<Vec<f32>> {
        Err(Self::unavailable("relax_batch"))
    }

    /// Dense panel step: alpha * (A @ X) + beta.
    pub fn spmv_panel(&self, _a: &[f32], _x: &[f32], _alpha: f32, _beta: f32) -> Result<Vec<f32>> {
        Err(Self::unavailable("spmv_panel"))
    }

    /// Manifest shapes for artifact `name` (unavailable in the stub).
    pub fn shapes(&self, name: &str) -> Result<(Vec<ArtifactShape>, ArtifactShape)> {
        Err(Self::unavailable(name))
    }
}

// ---------------------------------------------------------------------
// Real engine (`--features xla`, requires a vendored xla crate).
// ---------------------------------------------------------------------

/// A compiled artifact plus its manifest metadata.
#[cfg(all(feature = "xla", xla_vendored))]
struct LoadedArtifact {
    exe: xla::PjRtLoadedExecutable,
    entry: ManifestEntry,
}

/// The PJRT engine: one CPU client, one compiled executable per artifact.
#[cfg(all(feature = "xla", xla_vendored))]
pub struct Engine {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    artifacts: std::collections::HashMap<String, LoadedArtifact>,
    dir: PathBuf,
    /// Serializes every PJRT call (see the `Sync` note below).
    exec_lock: std::sync::Mutex<()>,
}

// The threaded substrate shares one `&Engine` across its P workers, so
// Engine must be Send + Sync even though the xla-rs wrappers are raw
// C++-handle types with no such guarantee of their own.  Soundness
// argument: after `load` returns, `client`/`artifacts` are never mutated,
// and every call that enters PJRT (`run_f32`, hence all batch entry
// points) first takes `exec_lock`, so the underlying C++ objects are
// accessed by at most one thread at a time.  Literals built per call are
// thread-local.  If xla-rs ever documents thread-safe execution, the
// lock can be dropped.
#[cfg(all(feature = "xla", xla_vendored))]
unsafe impl Send for Engine {}
#[cfg(all(feature = "xla", xla_vendored))]
unsafe impl Sync for Engine {}

#[cfg(all(feature = "xla", xla_vendored))]
impl Engine {
    /// Load and compile every artifact listed in `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            RuntimeError::new(format!(
                "reading {manifest_path:?} — run `make artifacts` first: {e}"
            ))
        })?;
        let entries = parse_manifest(&text)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| RuntimeError::new(format!("PJRT cpu client: {e:?}")))?;
        let mut artifacts = std::collections::HashMap::new();
        for entry in entries {
            let path = dir.join(&entry.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| RuntimeError::new(format!("parsing {path:?}: {e:?}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| RuntimeError::new(format!("compiling {}: {e:?}", entry.name)))?;
            artifacts.insert(entry.name.clone(), LoadedArtifact { exe, entry });
        }
        Ok(Engine { client, artifacts, dir, exec_lock: std::sync::Mutex::new(()) })
    }

    /// Load from the conventional location (`$TDORCH_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Engine> {
        Self::load(default_dir())
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.artifacts.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn artifact(&self, name: &str) -> Result<&LoadedArtifact> {
        self.artifacts.get(name).ok_or_else(|| {
            RuntimeError::new(format!(
                "artifact {name} not loaded (have {:?})",
                self.artifact_names()
            ))
        })
    }

    /// Execute artifact `name` on f32 inputs (shapes per the manifest) and
    /// return the flattened f32 output.  PJRT entry is serialized (see
    /// the `Sync` note on [`Engine`]).
    pub fn run_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let _pjrt = self.exec_lock.lock().expect("pjrt lock poisoned");
        let art = self.artifact(name)?;
        if inputs.len() != art.entry.inputs.len() {
            return Err(RuntimeError::new(format!(
                "{name}: expected {} inputs, got {}",
                art.entry.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&art.entry.inputs) {
            if data.len() != shape.elements() {
                return Err(RuntimeError::new(format!(
                    "{name}: input length {} != manifest shape {:?}",
                    data.len(),
                    shape.0
                )));
            }
            let lit = if shape.0.is_empty() {
                xla::Literal::scalar(data[0])
            } else if shape.0.len() == 1 {
                xla::Literal::vec1(data)
            } else {
                let dims: Vec<i64> = shape.0.iter().map(|d| *d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| RuntimeError::new(format!("reshape {name}: {e:?}")))?
            };
            literals.push(lit);
        }
        let result = art
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| RuntimeError::new(format!("execute {name}: {e:?}")))?[0][0]
            .to_literal_sync()
            .map_err(|e| RuntimeError::new(format!("sync {name}: {e:?}")))?;
        // aot.py lowers with return_tuple=True.
        let out = result
            .to_tuple1()
            .map_err(|e| RuntimeError::new(format!("untuple {name}: {e:?}")))?;
        out.to_vec::<f32>()
            .map_err(|e| RuntimeError::new(format!("to_vec {name}: {e:?}")))
    }

    /// Batched YCSB lambda: out[i] = vals[i] * mul[i] + add[i].
    /// Arbitrary lengths; padded to the artifact batch internally.
    pub fn ycsb_batch(&self, vals: &[f32], mul: &[f32], add: &[f32]) -> Result<Vec<f32>> {
        self.elementwise3("ycsb_batch", vals, mul, add)
    }

    /// Batched SSSP relaxation: out[i] = min(dv[i], du[i] + w[i]).
    pub fn relax_batch(&self, dv: &[f32], du: &[f32], w: &[f32]) -> Result<Vec<f32>> {
        self.elementwise3("relax_batch", dv, du, w)
    }

    fn elementwise3(&self, name: &str, a: &[f32], b: &[f32], c: &[f32]) -> Result<Vec<f32>> {
        if a.len() != b.len() || a.len() != c.len() {
            return Err(RuntimeError::new(format!("{name}: input length mismatch")));
        }
        let art = self.artifact(name)?;
        let batch = art.entry.inputs[0].elements();
        let mut out = Vec::with_capacity(a.len());
        let mut pa = vec![0f32; batch];
        let mut pb = vec![0f32; batch];
        let mut pc = vec![0f32; batch];
        for start in (0..a.len()).step_by(batch) {
            let end = (start + batch).min(a.len());
            let n = end - start;
            pa[..n].copy_from_slice(&a[start..end]);
            pb[..n].copy_from_slice(&b[start..end]);
            pc[..n].copy_from_slice(&c[start..end]);
            pa[n..].fill(0.0);
            pb[n..].fill(0.0);
            pc[n..].fill(0.0);
            let res = self.run_f32(name, &[&pa, &pb, &pc])?;
            out.extend_from_slice(&res[..n]);
        }
        Ok(out)
    }

    /// Dense panel step: alpha * (A @ X) + beta over the manifest tile
    /// shapes ((m,k) @ (k,panel)).
    pub fn spmv_panel(&self, a: &[f32], x: &[f32], alpha: f32, beta: f32) -> Result<Vec<f32>> {
        self.run_f32("spmv_panel", &[a, x, &[alpha], &[beta]])
    }

    /// Manifest shapes for artifact `name` (inputs, output).
    pub fn shapes(&self, name: &str) -> Result<(Vec<ArtifactShape>, ArtifactShape)> {
        let art = self.artifact(name)?;
        Ok((art.entry.inputs.clone(), art.entry.output.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let text = "ycsb_batch\tycsb_batch.hlo.txt\t4096,4096,4096\t4096\n\
                    spmv_panel\tspmv_panel.hlo.txt\t512x512,512x128,scalar,scalar\t512x128\n";
        let entries = parse_manifest(text).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].inputs.len(), 3);
        assert_eq!(entries[0].inputs[0], ArtifactShape(vec![4096]));
        assert_eq!(entries[1].inputs[2], ArtifactShape(vec![]));
        assert_eq!(entries[1].inputs[0].elements(), 512 * 512);
        assert_eq!(entries[1].output, ArtifactShape(vec![512, 128]));
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(parse_manifest("only\ttwo\tcols\n").is_err());
        assert!(parse_manifest("a\tb\t4xx\t4\n").is_err());
    }

    #[test]
    fn shape_parse() {
        assert_eq!(ArtifactShape::parse("scalar").unwrap().0, Vec::<usize>::new());
        assert_eq!(ArtifactShape::parse("8x128").unwrap().0, vec![8, 128]);
        assert_eq!(ArtifactShape::parse("scalar").unwrap().elements(), 1);
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_engine_fails_loudly() {
        let err = Engine::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
        assert!(Engine::load_default().is_err());
    }
}
