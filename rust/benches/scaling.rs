//! Bench: Fig 8 (strong scaling) + Fig 9 (weak scaling) at reduced scale.
//! `cargo bench --bench scaling`.

mod bench_util;

use bench_util::Bench;
use tdorch::graph::algorithms::Algorithm;
use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::repro::graphs::run_alg;
use tdorch::serve::QueryShard;
use tdorch::{Cluster, CostModel};

fn main() {
    let b = Bench::new("scaling");
    let cost = CostModel::paper_cluster();

    // Fig 8: strong scaling, BC on a fixed social graph.
    let g = gen::barabasi_albert(20_000, 10, 5);
    let mut series = Vec::new();
    for p in [1usize, 4, 16] {
        let mut sim = 0.0;
        b.run(&format!("fig8-strong-BC-P{p}"), 3, || {
            let mut e = SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new);
            sim = run_alg(&mut e, Algorithm::Bc).0;
            sim.to_bits()
        });
        println!("    sim-s: {sim:.4}");
        series.push(sim);
    }
    assert!(
        series[2] < series[0] / 2.0,
        "strong scaling regressed: {series:?}"
    );
    println!("shape check OK: P=16 is {:.1}x faster than P=1", series[0] / series[2]);

    // Fig 9: weak scaling, PR with fixed edges/machine.
    let mut weak = Vec::new();
    for p in [1usize, 4, 16] {
        let g = gen::barabasi_albert(3_000 * p, 8, 6);
        let mut sim = 0.0;
        b.run(&format!("fig9-weak-PR-P{p}"), 3, || {
            let mut e = SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, QueryShard::new);
            sim = run_alg(&mut e, Algorithm::Pr).0;
            sim.to_bits()
        });
        println!("    sim-s: {sim:.4}");
        weak.push(sim);
    }
    assert!(
        weak[2] < 3.0 * weak[0],
        "weak scaling regressed: {weak:?}"
    );
    println!("shape check OK: weak-scaling P=16/P=1 ratio = {:.2}", weak[2] / weak[0]);
}
