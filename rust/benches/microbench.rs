//! Bench: hot-path microbenchmarks used by the §Perf pass — meta-task
//! merging, forest mapping, Zipf sampling, cluster exchange, a full
//! TD-Orch stage (host wall time), the flat-layout A/Bs (DetMap scratch
//! vs slab, sparse vs dense frontier, per-message vs batched mpsc), and
//! the PJRT `fma` artifact throughput.  `cargo bench --bench microbench`.

mod bench_util;

use std::sync::mpsc;

use bench_util::Bench;
use tdorch::det::{det_map, DetMap};
use tdorch::forest::Forest;
use tdorch::graph::layout::{Frontier, Slab};
use tdorch::metatask::{MetaTaskSet, SlotStore};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::workload::Zipf;
use tdorch::{Cluster, CostModel, DistStore};

struct CounterApp;
impl tdorch::OrchApp for CounterApp {
    type Ctx = i64;
    type Val = i64;
    type Out = i64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        16
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, c: &i64, _v: &i64) -> Option<i64> {
        Some(*c)
    }
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }
    fn apply(&self, v: &mut i64, o: i64) {
        *v += o;
    }
}

fn main() {
    let b = Bench::new("microbench");

    // Meta-task set merging (Phase 1 inner loop).
    b.run("metatask-merge-100k-singletons", 5, || {
        let mut slots = SlotStore::new();
        let mut acc: MetaTaskSet<u64> = MetaTaskSet::new();
        for i in 0..100_000u64 {
            acc.merge(MetaTaskSet::from_ctxs([i]), 8, &mut slots, 0);
        }
        acc.total_count()
    });

    // Forest VM->PM mapping (every Phase-1 route goes through this).
    let forest = Forest::new(16, 3);
    b.run("forest-machine_of-1M", 5, || {
        let mut acc = 0usize;
        for i in 0..1_000_000u64 {
            acc ^= forest.machine_of((i % 16) as usize, 1, i % 64);
        }
        acc
    });

    // Zipf sampling (workload generation).
    let zipf = Zipf::new(1_000_000, 1.5);
    b.run("zipf-sample-1M", 5, || {
        let mut rng = Rng::new(3);
        let mut acc = 0usize;
        for _ in 0..1_000_000 {
            acc ^= zipf.sample(&mut rng);
        }
        acc
    });

    // Cluster exchange throughput (substrate overhead).
    b.run("cluster-exchange-16x10k", 5, || {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let out: Vec<Vec<(usize, u64)>> = (0..16)
            .map(|m| (0..10_000).map(|i| ((m + i) % 16, i as u64)).collect())
            .collect();
        let inboxes = c.exchange(out, |_| 4);
        inboxes.len()
    });

    // Full TD-Orch stage: HOST wall time per task (the L3 hot path that
    // the §Perf pass optimizes).
    let tasks: Vec<Task<i64>> = (0..200_000)
        .map(|i| {
            let addr = if i % 4 == 0 {
                (i % 16) as u64
            } else {
                (i as u64).wrapping_mul(0x9E3779B9) % 1_000_000
            };
            Task::inplace(addr, 1)
        })
        .collect();
    b.run("tdorch-stage-200k-tasks-P16", 5, || {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let mut s: DistStore<i64> = DistStore::new(16);
        let o = TdOrch::new().run_stage(&mut c, &CounterApp, spread_tasks(tasks.clone(), 16), &mut s);
        o.total_executed
    });

    // --- Flat-layout A/Bs (shard memory-layout PR) ---

    // (a) DetMap scratch vs flat slab: the edge_map message fold — merge
    // 300k (vertex, value) contributions keyed by 100k vertices, then
    // walk the touched set in ascending order, exactly the shape of the
    // old (hash + keys().collect() + sort) and new (array store +
    // normalize + dirty walk) Phase-2 inner loops.
    let n = 100_000usize;
    let contribs: Vec<(u32, f64)> = (0..300_000u64)
        .map(|i| ((i.wrapping_mul(0x9E37_79B9) % n as u64) as u32, i as f64))
        .collect();
    b.run("scratch-detmap-merge-walk-300k", 5, || {
        let mut m: DetMap<u32, f64> = det_map();
        for &(v, x) in &contribs {
            m.entry(v).and_modify(|a| *a = a.min(x)).or_insert(x);
        }
        let mut keys: Vec<u32> = m.keys().copied().collect();
        keys.sort_unstable();
        let mut acc = 0.0;
        for k in keys {
            acc += m[&k];
        }
        acc
    });
    let mut slab = Slab::new();
    slab.ensure(n);
    b.run("scratch-flat-slab-merge-walk-300k", 5, || {
        slab.clear();
        for &(v, x) in &contribs {
            slab.merge_with(v, x, f64::min);
        }
        slab.normalize();
        let mut acc = 0.0;
        for &v in slab.dirty() {
            acc += slab.get(v).unwrap();
        }
        acc
    });

    // (b) Sparse vec vs dense bitset frontier iteration over a 1M-vertex
    // owned range, at the two occupancies that bracket the engine's
    // seal threshold (1/16): dense should win high, sparse should win
    // low — the numbers justify the deterministic switch.
    let span = 1_000_000usize;
    for (tag, stride) in [("hi-occ-1of2", 2usize), ("lo-occ-1of64", 64)] {
        let mut sparse_f = Frontier::new(0, span);
        let mut dense_f = Frontier::new(0, span);
        for v in (0..span as u32).step_by(stride) {
            sparse_f.push(v);
            dense_f.push(v);
        }
        dense_f.force_dense();
        b.run(&format!("frontier-sparse-iter-{tag}"), 5, || {
            let mut acc = 0u64;
            for v in sparse_f.iter() {
                acc = acc.wrapping_add(v as u64);
            }
            acc
        });
        b.run(&format!("frontier-dense-iter-{tag}"), 5, || {
            let mut acc = 0u64;
            for v in dense_f.iter() {
                acc = acc.wrapping_add(v as u64);
            }
            acc
        });
    }

    // (c) Per-message vs batched channel discipline: 100k u64 payloads
    // through one mpsc channel — one send per payload (the threaded
    // substrate's old wire shape) vs one send carrying the whole batch
    // (the new persistent-mesh shape; the clone stands in for the
    // grouping pass that fills a recycled batch buffer).
    let msgs: Vec<u64> = (0..100_000u64).collect();
    b.run("mpsc-per-message-100k", 5, || {
        let (tx, rx) = mpsc::channel::<u64>();
        for &x in &msgs {
            tx.send(x).unwrap();
        }
        drop(tx);
        let mut acc = 0u64;
        while let Ok(x) = rx.recv() {
            acc = acc.wrapping_add(x);
        }
        acc
    });
    b.run("mpsc-batched-100k", 5, || {
        let (tx, rx) = mpsc::channel::<Vec<u64>>();
        tx.send(msgs.clone()).unwrap();
        drop(tx);
        let mut acc = 0u64;
        while let Ok(batch) = rx.recv() {
            for x in batch {
                acc = acc.wrapping_add(x);
            }
        }
        acc
    });

    // PJRT artifact execution (the L1/L2 hot path) — skipped without
    // artifacts.
    match tdorch::runtime::Engine::load_default() {
        Ok(engine) => {
            let vals = vec![1.5f32; 4096];
            let muls = vec![2.0f32; 4096];
            let adds = vec![0.5f32; 4096];
            b.run("pjrt-ycsb_batch-4096", 20, || {
                engine.ycsb_batch(&vals, &muls, &adds).unwrap().len()
            });
            let a = vec![0.5f32; 512 * 512];
            let x = vec![1.0f32; 512 * 128];
            b.run("pjrt-spmv_panel-512x512x128", 10, || {
                engine.spmv_panel(&a, &x, 0.85, 0.15).unwrap().len()
            });
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }
    println!("microbench done");
}
