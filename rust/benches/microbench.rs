//! Bench: hot-path microbenchmarks used by the §Perf pass — meta-task
//! merging, forest mapping, Zipf sampling, cluster exchange, a full
//! TD-Orch stage (host wall time), and the PJRT `fma` artifact
//! throughput.  `cargo bench --bench microbench`.

mod bench_util;

use bench_util::Bench;
use tdorch::forest::Forest;
use tdorch::metatask::{MetaTaskSet, SlotStore};
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, Scheduler, Task};
use tdorch::rng::Rng;
use tdorch::workload::Zipf;
use tdorch::{Cluster, CostModel, DistStore};

struct CounterApp;
impl tdorch::OrchApp for CounterApp {
    type Ctx = i64;
    type Val = i64;
    type Out = i64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        16
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, c: &i64, _v: &i64) -> Option<i64> {
        Some(*c)
    }
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }
    fn apply(&self, v: &mut i64, o: i64) {
        *v += o;
    }
}

fn main() {
    let b = Bench::new("microbench");

    // Meta-task set merging (Phase 1 inner loop).
    b.run("metatask-merge-100k-singletons", 5, || {
        let mut slots = SlotStore::new();
        let mut acc: MetaTaskSet<u64> = MetaTaskSet::new();
        for i in 0..100_000u64 {
            acc.merge(MetaTaskSet::from_ctxs([i]), 8, &mut slots, 0);
        }
        acc.total_count()
    });

    // Forest VM->PM mapping (every Phase-1 route goes through this).
    let forest = Forest::new(16, 3);
    b.run("forest-machine_of-1M", 5, || {
        let mut acc = 0usize;
        for i in 0..1_000_000u64 {
            acc ^= forest.machine_of((i % 16) as usize, 1, i % 64);
        }
        acc
    });

    // Zipf sampling (workload generation).
    let zipf = Zipf::new(1_000_000, 1.5);
    b.run("zipf-sample-1M", 5, || {
        let mut rng = Rng::new(3);
        let mut acc = 0usize;
        for _ in 0..1_000_000 {
            acc ^= zipf.sample(&mut rng);
        }
        acc
    });

    // Cluster exchange throughput (substrate overhead).
    b.run("cluster-exchange-16x10k", 5, || {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let out: Vec<Vec<(usize, u64)>> = (0..16)
            .map(|m| (0..10_000).map(|i| ((m + i) % 16, i as u64)).collect())
            .collect();
        let inboxes = c.exchange(out, |_| 4);
        inboxes.len()
    });

    // Full TD-Orch stage: HOST wall time per task (the L3 hot path that
    // the §Perf pass optimizes).
    let tasks: Vec<Task<i64>> = (0..200_000)
        .map(|i| {
            let addr = if i % 4 == 0 {
                (i % 16) as u64
            } else {
                (i as u64).wrapping_mul(0x9E3779B9) % 1_000_000
            };
            Task::inplace(addr, 1)
        })
        .collect();
    b.run("tdorch-stage-200k-tasks-P16", 5, || {
        let mut c = Cluster::new(16, CostModel::paper_cluster());
        let mut s: DistStore<i64> = DistStore::new(16);
        let o = TdOrch::new().run_stage(&mut c, &CounterApp, spread_tasks(tasks.clone(), 16), &mut s);
        o.total_executed
    });

    // PJRT artifact execution (the L1/L2 hot path) — skipped without
    // artifacts.
    match tdorch::runtime::Engine::load_default() {
        Ok(engine) => {
            let vals = vec![1.5f32; 4096];
            let muls = vec![2.0f32; 4096];
            let adds = vec![0.5f32; 4096];
            b.run("pjrt-ycsb_batch-4096", 20, || {
                engine.ycsb_batch(&vals, &muls, &adds).unwrap().len()
            });
            let a = vec![0.5f32; 512 * 512];
            let x = vec![1.0f32; 512 * 128];
            b.run("pjrt-spmv_panel-512x512x128", 10, || {
                engine.spmv_panel(&a, &x, 0.85, 0.15).unwrap().len()
            });
        }
        Err(e) => println!("pjrt benches skipped: {e}"),
    }
    println!("microbench done");
}
