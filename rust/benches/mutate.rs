//! Bench: absorbing edge deltas in place (`SpmdEngine::apply_delta`) vs
//! what a mutation-oblivious system would pay — rebuilding the engine
//! from a fresh ingestion of the mutated edge set.  Measured on both
//! substrates at P=8 over a 30k-vertex BA graph; the in-place path is
//! the whole point of the `mutate` subsystem, so the gap is the
//! headline.  Each timed rebuild iteration re-ingests by design (it IS
//! the re-ingestion cost); the delta iterations never do, which the
//! ingestion counter asserts at the end.  Both backends must land on
//! identical catalogs (degrees, arc count, leaf sets) after the same
//! batch sequence.  `cargo bench --bench mutate`.

mod bench_util;

use bench_util::Bench;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::ingestions;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::graph::Vid;
use tdorch::mutate::{generate_mutations, MutationConfig, MutationStream};
use tdorch::serve::QueryShard;
use tdorch::workload::hot_source_order;
use tdorch::{Cluster, CostModel};

const ITERS: usize = 3;
const BATCHES: usize = 16;

fn main() {
    let b = Bench::new("mutate");
    let g = gen::barabasi_albert(30_000, 8, 7);
    let cost = CostModel::paper_cluster();
    let p = 8;
    println!("BA graph n={} m={}, P={p}, {BATCHES} batches", g.n, g.m());

    let hot_deg: Vec<u32> = (0..g.n as Vid).map(|u| g.out_degree(u) as u32).collect();
    let hot = hot_source_order(&hot_deg);
    let batches: MutationStream = generate_mutations(
        MutationConfig {
            batches: BATCHES,
            ops_per_batch: 16,
            insert_pct: 60,
            zipf_s: 1.2,
            start_tick: 0,
            every_ticks: 1,
        },
        &g,
        &hot,
        11,
    );

    // ONE ingestion feeds every delta iteration on both backends.
    let dg = ingest_once(&g, p, cost, Placement::Spread);
    let ing0 = ingestions();

    b.run(&format!("apply-{BATCHES}-batches-sim-P{p}"), ITERS, || {
        let mut e = SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "mutate-bench-sim",
            QueryShard::new,
        );
        for batch in &batches {
            e.apply_delta(batch);
        }
        assert_eq!(e.graph_epoch(), BATCHES as u64);
        e.meta().m
    });
    b.run(&format!("apply-{BATCHES}-batches-thr-P{p}"), ITERS, || {
        let mut e = SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "mutate-bench-thr",
            QueryShard::new,
        );
        for batch in &batches {
            e.apply_delta(batch);
        }
        e.meta().m
    });
    let delta_ing = ingestions() - ing0;
    assert_eq!(delta_ing, 0, "the delta path must never re-ingest");

    // The mutation-oblivious alternative: one full placement pass (what
    // absorbing the same deltas by rebuild would cost, per rebuild).
    b.run(&format!("rebuild-ingest-P{p}"), ITERS, || {
        ingest_once(&g, p, cost, Placement::Spread).m
    });

    // Cross-backend agreement on the final catalog.
    let mut sim = SpmdEngine::from_ingested(
        Cluster::new(p, cost),
        dg.clone(),
        cost,
        Flags::tdo_gp(),
        "mutate-final-sim",
        QueryShard::new,
    );
    let mut thr = SpmdEngine::from_ingested(
        ThreadedCluster::new(p),
        dg,
        cost,
        Flags::tdo_gp(),
        "mutate-final-thr",
        QueryShard::new,
    );
    for batch in &batches {
        sim.apply_delta(batch);
        thr.apply_delta(batch);
    }
    let (a, z) = (sim.meta(), thr.meta());
    assert_eq!(a.m, z.m, "arc counts diverged across backends");
    assert_eq!(a.out_deg, z.out_deg, "degrees diverged across backends");
    assert_eq!(a.src_leaves, z.src_leaves, "src leaves diverged across backends");
    assert_eq!(a.dst_leaves, z.dst_leaves, "dst leaves diverged across backends");
    println!(
        "final catalogs identical across backends: m={} epoch={}",
        a.m,
        sim.graph_epoch()
    );
}
