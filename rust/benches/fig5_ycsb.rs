//! Bench: Fig 5 (YCSB weak scaling) at reduced scale — regression
//! tracking for the KV case study.  `cargo bench --bench fig5_ycsb`.

mod bench_util;

use bench_util::Bench;
use tdorch::repro::kv::{run_cell, SCHEDULER_NAMES};
use tdorch::workload::YcsbKind;

fn main() {
    let b = Bench::new("fig5_ycsb");
    let per_machine = 5_000;

    for (kind, gamma) in [
        (YcsbKind::A, 1.5),
        (YcsbKind::A, 2.5),
        (YcsbKind::C, 2.0),
        (YcsbKind::Load, 2.0),
    ] {
        for p in [4usize, 16] {
            let label = format!("{}-g{gamma}-P{p}", kind.label());
            let mut last = [0.0; 4];
            b.run(&label, 3, || {
                last = run_cell(kind, gamma, p, per_machine, 7);
                last
            });
            let mut line = String::from("    sim-s: ");
            for (name, t) in SCHEDULER_NAMES.iter().zip(last) {
                line.push_str(&format!("{name}={t:.4} "));
            }
            println!("{line}");
        }
    }

    // Fig 5 headline shape at bench scale: td-orch beats push and sorting
    // at every skew level.
    let cell = run_cell(YcsbKind::A, 2.0, 16, per_machine, 7);
    assert!(cell[0] < cell[1] && cell[0] < cell[3], "fig5 shape regressed: {cell:?}");
    println!("shape check OK: td-orch {:.4} < push {:.4}, sort {:.4}", cell[0], cell[1], cell[3]);
}
