//! Bench: ablations — Table 3 (TD-Orch on/off), Table 4 (T1/T2/T3),
//! plus the design-choice ablations DESIGN.md calls out: the Phase-1
//! direct shortcut, and (F, C) parameter sensitivity.
//! `cargo bench --bench ablations`.

mod bench_util;

use bench_util::Bench;
use tdorch::graph::algorithms::Algorithm;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::orchestration::tdorch::TdOrch;
use tdorch::orchestration::{spread_tasks, Scheduler, Task};
use tdorch::repro::graphs::run_alg;
use tdorch::serve::QueryShard;
use tdorch::{Cluster, CostModel, DistStore};

struct CounterApp;
impl tdorch::OrchApp for CounterApp {
    type Ctx = i64;
    type Val = i64;
    type Out = i64;
    fn sigma(&self) -> u64 {
        2
    }
    fn chunk_words(&self) -> u64 {
        64
    }
    fn out_words(&self) -> u64 {
        1
    }
    fn execute(&self, c: &i64, _v: &i64) -> Option<i64> {
        Some(*c)
    }
    fn combine(&self, a: i64, b: i64) -> i64 {
        a + b
    }
    fn apply(&self, v: &mut i64, o: i64) {
        *v += o;
    }
}

fn zipfish_tasks(n: usize) -> Vec<Task<i64>> {
    (0..n)
        .map(|i| {
            let addr = if i % 5 < 2 {
                (i % 8) as u64
            } else {
                100 + (i as u64).wrapping_mul(0x9E3779B9) % 500_000
            };
            Task::inplace(addr, 1)
        })
        .collect()
}

fn kv_sim(sched: &TdOrch, p: usize, tasks: &[Task<i64>]) -> f64 {
    let mut c = Cluster::new(p, CostModel::paper_cluster());
    let mut s: DistStore<i64> = DistStore::new(p);
    sched.run_stage(&mut c, &CounterApp, spread_tasks(tasks.to_vec(), p), &mut s);
    c.metrics.sim_seconds()
}

fn main() {
    let b = Bench::new("ablations");
    let cost = CostModel::paper_cluster();

    // Table 3: TD-Orch vs no-TD-Orch (ligra-dist) BC.
    let g = gen::barabasi_albert(10_000, 8, 9);
    let mut pair = (0.0, 0.0);
    b.run("table3-BC-P8", 3, || {
        let mut lig = SpmdEngine::baseline(
            Cluster::new(8, cost),
            &g,
            cost,
            Flags::ligra_dist(),
            "ligra-dist",
            QueryShard::new,
        );
        let mut tdo = SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, QueryShard::new);
        pair = (
            run_alg(&mut lig, Algorithm::Bc).0,
            run_alg(&mut tdo, Algorithm::Bc).0,
        );
        pair.0.to_bits() ^ pair.1.to_bits()
    });
    println!("    sim-s: ligra-dist={:.4} tdo-gp={:.4} ({:.1}x)", pair.0, pair.1, pair.0 / pair.1);
    assert!(pair.0 > 2.0 * pair.1, "table3 shape regressed");

    // Table 4: technique ablations, SSSP P=8.
    for (label, flags) in Flags::ablations() {
        let mut ratio = 0.0;
        b.run(&format!("table4-SSSP-P8{label}"), 3, || {
            let mut full = SpmdEngine::tdo_gp(Cluster::new(8, cost), &g, cost, QueryShard::new);
            let mut abl = SpmdEngine::new(
                Cluster::new(8, cost),
                &g,
                cost,
                flags,
                tdorch::graph::spmd::Placement::Spread,
                label,
                QueryShard::new,
            );
            let t_full = run_alg(&mut full, Algorithm::Sssp).0;
            let t_abl = run_alg(&mut abl, Algorithm::Sssp).0;
            ratio = t_abl / t_full;
            ratio.to_bits()
        });
        println!("    slowdown: {ratio:.2}x");
        assert!(ratio > 1.0, "{label} should slow TDO-GP down");
    }

    // DESIGN ablation: the Phase-1 direct shortcut for uncontended tasks.
    let tasks = zipfish_tasks(160_000);
    let mut with = 0.0;
    let mut without = 0.0;
    b.run("orch-direct-shortcut-on", 3, || {
        with = kv_sim(&TdOrch::new(), 16, &tasks);
        with.to_bits()
    });
    b.run("orch-direct-shortcut-off", 3, || {
        without = kv_sim(&TdOrch::without_shortcut(), 16, &tasks);
        without.to_bits()
    });
    println!("    sim-s: with={with:.4} without={without:.4} ({:.2}x win)", without / with);
    assert!(with < without, "direct shortcut should help mixed workloads");

    // DESIGN ablation: (F, C) sensitivity around the theory-guided defaults.
    for (f, c) in [(2usize, 2usize), (2, 32), (8, 2), (8, 32)] {
        let mut sim = 0.0;
        b.run(&format!("orch-params-F{f}-C{c}"), 3, || {
            sim = kv_sim(&TdOrch::with_params(f, c), 16, &tasks);
            sim.to_bits()
        });
        println!("    sim-s: {sim:.4}");
    }
    println!("ablations done");
}
