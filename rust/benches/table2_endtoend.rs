//! Bench: Table 2 (end-to-end graph runtimes) at reduced scale, on the
//! unified SPMD engine (the same code path `repro table2` drives).
//! `cargo bench --bench table2_endtoend`.

mod bench_util;

use bench_util::Bench;
use tdorch::graph::algorithms::Algorithm;
use tdorch::graph::gen;
use tdorch::repro::graphs::{engines_for, run_alg};
use tdorch::CostModel;

fn main() {
    let b = Bench::new("table2_endtoend");
    let cost = CostModel::paper_cluster();

    // Small stand-ins for the two structural extremes of Table 2.
    let social = gen::barabasi_albert(8_000, 8, 3);
    let road = gen::grid2d(96, 3);

    for (gname, g, p) in [("social", &social, 8), ("road", &road, 16)] {
        for alg in [Algorithm::Bfs, Algorithm::Bc, Algorithm::Pr] {
            let mut results = Vec::new();
            b.run(&format!("{gname}-{}", alg.label()), 3, || {
                results.clear();
                // engines_for yields [tdo-gp, gemini-like, la-like,
                // ligra-dist] — every engine built in the timed region
                // is also run, so no dead construction work is timed.
                let mut engines = engines_for(g, p, cost);
                results.push(("tdo", run_alg(&mut engines[0], alg).0));
                results.push(("gem", run_alg(&mut engines[1], alg).0));
                results.push(("la", run_alg(&mut engines[2], alg).0));
                results.push(("lig", run_alg(&mut engines[3], alg).0));
                results.len()
            });
            let line: Vec<String> = results
                .iter()
                .map(|(n, s)| format!("{n}={s:.4}"))
                .collect();
            println!("    sim-s: {}", line.join(" "));
        }
    }

    // Shape checks at bench scale.
    let mut engines = engines_for(&road, 16, cost);
    let t_tdo = run_alg(&mut engines[0], Algorithm::Bfs).0;
    let t_la = run_alg(&mut engines[2], Algorithm::Bfs).0;
    assert!(
        t_la > 2.0 * t_tdo,
        "road BFS shape regressed: la {t_la:.4} vs tdo {t_tdo:.4}"
    );
    println!("shape check OK: road BFS la/tdo = {:.1}x", t_la / t_tdo);
}
