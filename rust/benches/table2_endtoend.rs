//! Bench: Table 2 (end-to-end graph runtimes) at reduced scale.
//! `cargo bench --bench table2_endtoend`.

mod bench_util;

use bench_util::Bench;
use tdorch::graph::algorithms::Algorithm;
use tdorch::graph::engine::{Engine, Flags};
use tdorch::graph::gen;
use tdorch::repro::graphs::run_alg;
use tdorch::CostModel;

fn main() {
    let b = Bench::new("table2_endtoend");
    let cost = CostModel::paper_cluster();

    // Small stand-ins for the two structural extremes of Table 2.
    let social = gen::barabasi_albert(8_000, 8, 3);
    let road = gen::grid2d(96, 3);

    for (gname, g, p) in [("social", &social, 8), ("road", &road, 16)] {
        for alg in [Algorithm::Bfs, Algorithm::Bc, Algorithm::Pr] {
            let mut results = Vec::new();
            b.run(&format!("{gname}-{}", alg.label()), 3, || {
                results.clear();
                let mut tdo = Engine::tdo_gp(g, p, cost);
                let mut gem = Engine::baseline(g, p, cost, Flags::gemini_like(), "gemini-like");
                let mut la = Engine::baseline(g, p, cost, Flags::la_like(), "la-like");
                results.push(("tdo", run_alg(&mut tdo, alg).0));
                results.push(("gem", run_alg(&mut gem, alg).0));
                results.push(("la", run_alg(&mut la, alg).0));
                results.len()
            });
            let line: Vec<String> = results
                .iter()
                .map(|(n, s)| format!("{n}={s:.4}"))
                .collect();
            println!("    sim-s: {}", line.join(" "));
        }
    }

    // Shape checks at bench scale.
    let mut tdo = Engine::tdo_gp(&road, 16, cost);
    let mut la = Engine::baseline(&road, 16, cost, Flags::la_like(), "la-like");
    let t_tdo = run_alg(&mut tdo, Algorithm::Bfs).0;
    let t_la = run_alg(&mut la, Algorithm::Bfs).0;
    assert!(
        t_la > 2.0 * t_tdo,
        "road BFS shape regressed: la {t_la:.4} vs tdo {t_tdo:.4}"
    );
    println!("shape check OK: road BFS la/tdo = {:.1}x", t_la / t_tdo);
}
