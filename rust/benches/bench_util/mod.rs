//! Minimal benchmarking harness (criterion is unavailable offline; see
//! Cargo.toml).  Runs each closure several times, reports median wall
//! time; benches also print the simulated BSP time where relevant, since
//! that is the paper-facing metric.

use std::time::Instant;

pub struct Bench {
    name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Self {
        println!("\n=== bench: {name} ===");
        Bench { name }
    }

    /// Time `f` (returning an arbitrary value to defeat DCE) over `iters`
    /// runs; print median / min wall ms.
    pub fn run<T>(&self, label: &str, iters: usize, mut f: impl FnMut() -> T) {
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            let out = f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
            std::hint::black_box(out);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let min = times[0];
        println!(
            "{:<44} median {:>9.3} ms   min {:>9.3} ms   ({} iters)",
            format!("{}/{}", self.name, label),
            median,
            min,
            iters
        );
    }
}
