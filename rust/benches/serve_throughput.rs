//! Bench: online serving throughput — queries/sec of the mixed
//! {BFS, SSSP, PR, CC, BC} Zipf stream on a long-lived engine, sim vs
//! threaded backend.  Engine construction (ingestion, relay-tree
//! precompute, worker-pool spawn) happens OUTSIDE the timed region; the
//! timed closure is exactly what a serving process pays per stream:
//! admission + batching + per-query shard reset + query execution.
//! `cargo bench --bench serve_throughput`.

mod bench_util;

use bench_util::Bench;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::ingestions;
use tdorch::graph::spmd::{ingest_once, Placement, SpmdEngine};
use tdorch::serve::{QueryShard, RunOpts, ServeConfig, ServeReport, Server};
use tdorch::workload::{generate_stream, hot_source_order, OpenLoopSource, QueryMix, StreamConfig};
use tdorch::{Cluster, CostModel};

const QUERIES: usize = 48;
const ITERS: usize = 3;

fn report_line(label: &str, rep: &ServeReport) {
    let (s50, _, s99) = rep.service_ms_percentiles();
    let (w50, _, w99) = rep.wait_tick_percentiles();
    println!(
        "    {label}: goodput {:.1} queries/sec over {} served of {} offered \
         (rejection rate {:.3}, {} batches); \
         service p50 {s50:.2} / p99 {s99:.2} ms; wait p50 {w50:.0} / p99 {w99:.0} ticks",
        rep.goodput_qps(),
        rep.served(),
        rep.offered(),
        rep.rejection_rate(),
        rep.batches,
    );
}

fn main() {
    let b = Bench::new("serve_throughput");
    let g = gen::barabasi_albert(10_000, 6, 7);
    let cost = CostModel::paper_cluster();
    let ing0 = ingestions();
    println!("BA graph n={} m={}, {QUERIES}-query balanced mix, zipf 1.5", g.n, g.m());

    for p in [4usize, 8] {
        let dg = ingest_once(&g, p, cost, Placement::Spread);
        let hot = hot_source_order(&dg.out_deg);
        let stream = generate_stream(
            StreamConfig {
                queries: QUERIES,
                per_tick: 2,
                every_ticks: 1,
                zipf_s: 1.5,
                mix: QueryMix::balanced(),
            },
            &hot,
            42,
        );
        let cfg = ServeConfig::default();

        let mut sim = Server::new(
            SpmdEngine::from_ingested(
                Cluster::new(p, cost),
                dg.clone(),
                cost,
                Flags::tdo_gp(),
                "serve-sim",
                QueryShard::new,
            ),
            cfg,
        );
        let mut last_sim: Option<ServeReport> = None;
        b.run(&format!("serve-sim-P{p}"), ITERS, || {
            let rep = sim.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
            let n = rep.served();
            last_sim = Some(rep);
            n
        });
        report_line("sim", last_sim.as_ref().expect("at least one iteration ran"));

        let mut thr = Server::new(
            SpmdEngine::from_ingested(
                ThreadedCluster::new(p),
                dg,
                cost,
                Flags::tdo_gp(),
                "serve-threaded",
                QueryShard::new,
            ),
            cfg,
        );
        let mut last_thr: Option<ServeReport> = None;
        b.run(&format!("serve-threaded-P{p}"), ITERS, || {
            let rep = thr.serve(&mut OpenLoopSource::new(&stream), RunOpts::default());
            let n = rep.served();
            last_thr = Some(rep);
            n
        });
        let rep = last_thr.as_ref().expect("at least one iteration ran");
        report_line("threaded", rep);
        // Cross-backend spot check on the last iteration's bits (the full
        // per-query contract lives in tests/serve_equivalence.rs).
        let sim_rep = last_sim.as_ref().unwrap();
        for (s, t) in sim_rep.results.iter().zip(&rep.results) {
            assert_eq!(s.id, t.id, "batch schedules diverged across backends");
            assert_eq!(s.bits, t.bits, "query {} bits diverged across backends", s.id);
        }
        println!(
            "    pool: {} threads, {} epochs, {} resets over {} streams",
            thr.engine().sub().pool_threads(),
            thr.engine().sub().epochs(),
            thr.engine().resets(),
            ITERS,
        );
    }

    let ingested = ingestions() - ing0;
    assert_eq!(ingested, 2, "serving must ingest exactly once per machine count");
    println!("\ningestions: {ingested} (one per machine count, shared by both backends)");
}
