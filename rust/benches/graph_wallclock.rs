//! Bench: real wall-clock of the SPMD `DistEdgeMap` engine — PageRank
//! and SSSP on the persistent threaded worker pool vs the same engine on
//! the single-threaded BSP simulator.  Engine construction (ingestion,
//! tree precomputation, pool spawn) happens OUTSIDE the timed closures —
//! the paper times queries, not loading.  Every threaded run is
//! validated bit-for-bit against the simulator result before its time is
//! reported, and the pool-thread counter is printed to demonstrate the
//! persistent-pool contract (at most P threads per run, however many
//! supersteps the algorithms take).
//! `cargo bench --bench graph_wallclock`.

mod bench_util;

use bench_util::Bench;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::algorithms::{pagerank_spmd, sssp_spmd, PrShard, SsspShard};
use tdorch::graph::gen;
use tdorch::graph::spmd::SpmdEngine;
use tdorch::repro::graphs::bits_equal;
use tdorch::{Cluster, CostModel};

const PR_ITERS: usize = 10;
const ITERS: usize = 3;

fn main() {
    let b = Bench::new("graph_wallclock");
    let g = gen::barabasi_albert(30_000, 8, 7);
    let cost = CostModel::paper_cluster();
    println!("BA graph n={} m={}", g.n, g.m());

    for p in [4usize, 8] {
        // Reference bits from the simulator backend of the same engine.
        let pr_sim = {
            let mut e = SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, PrShard::new);
            pagerank_spmd(&mut e, PR_ITERS)
        };
        let ss_sim = {
            let mut e = SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, SsspShard::new);
            sssp_spmd(&mut e, 0)
        };

        // ---- PageRank ----
        let mut sim_engines: Vec<SpmdEngine<Cluster, PrShard>> = (0..ITERS)
            .map(|_| SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, PrShard::new))
            .collect();
        b.run(&format!("pagerank-sim-P{p}"), ITERS, || {
            let mut e = sim_engines.pop().expect("one prepared engine per iter");
            pagerank_spmd(&mut e, PR_ITERS).len()
        });

        let mut thr_engines: Vec<SpmdEngine<ThreadedCluster, PrShard>> = (0..ITERS)
            .map(|_| SpmdEngine::tdo_gp(ThreadedCluster::new(p), &g, cost, PrShard::new))
            .collect();
        let mut last_busy = 0.0f64;
        let mut last_threads = 0usize;
        let mut last_epochs = 0u64;
        let mut finished: Vec<(Vec<f64>, SpmdEngine<ThreadedCluster, PrShard>)> = Vec::new();
        b.run(&format!("pagerank-threaded-P{p}"), ITERS, || {
            let mut e = thr_engines.pop().expect("one prepared engine per iter");
            let rank = pagerank_spmd(&mut e, PR_ITERS);
            let n = rank.len();
            finished.push((rank, e));
            n
        });
        for (rank, e) in &finished {
            assert!(bits_equal(rank, &pr_sim), "threaded PR diverged from simulator");
            last_busy = e.sub().max_busy_ms();
            last_threads = e.sub().pool_threads();
            last_epochs = e.sub().epochs();
        }
        println!(
            "    PR: max-loaded machine busy {last_busy:.2} ms; pool spawned \
             {last_threads} threads for {last_epochs} superstep epochs"
        );

        // ---- SSSP ----
        let mut sim_engines: Vec<SpmdEngine<Cluster, SsspShard>> = (0..ITERS)
            .map(|_| SpmdEngine::tdo_gp(Cluster::new(p, cost), &g, cost, SsspShard::new))
            .collect();
        b.run(&format!("sssp-sim-P{p}"), ITERS, || {
            let mut e = sim_engines.pop().expect("one prepared engine per iter");
            sssp_spmd(&mut e, 0).len()
        });

        let mut thr_engines: Vec<SpmdEngine<ThreadedCluster, SsspShard>> = (0..ITERS)
            .map(|_| SpmdEngine::tdo_gp(ThreadedCluster::new(p), &g, cost, SsspShard::new))
            .collect();
        let mut finished: Vec<(Vec<f64>, SpmdEngine<ThreadedCluster, SsspShard>)> = Vec::new();
        b.run(&format!("sssp-threaded-P{p}"), ITERS, || {
            let mut e = thr_engines.pop().expect("one prepared engine per iter");
            let d = sssp_spmd(&mut e, 0);
            let n = d.len();
            finished.push((d, e));
            n
        });
        for (d, e) in &finished {
            assert!(bits_equal(d, &ss_sim), "threaded SSSP diverged from simulator");
            last_busy = e.sub().max_busy_ms();
            last_threads = e.sub().pool_threads();
            last_epochs = e.sub().epochs();
        }
        println!(
            "    SSSP: max-loaded machine busy {last_busy:.2} ms; pool spawned \
             {last_threads} threads for {last_epochs} superstep epochs"
        );
    }
}
