//! Bench: real wall-clock of the SPMD `DistEdgeMap` engine — PageRank
//! and SSSP on the persistent threaded worker pool vs the same engine on
//! the single-threaded BSP simulator.  The serving contract applies:
//! the graph is ingested ONCE per machine count (`ingest_once`), both
//! engines are built from clones of that placement, and every timed
//! iteration reuses its engine via `reset_for_query` — so the timed
//! region is query work (plus the O(n/P) shard reset a serving system
//! pays per query), never ingestion, tree precomputation, or pool
//! spawning.  Every threaded run is validated bit-for-bit against the
//! simulator result before its time is reported, and the pool/ingestion
//! counters are printed (and asserted) to demonstrate the contract.
//! `cargo bench --bench graph_wallclock`.

mod bench_util;

use bench_util::Bench;
use tdorch::exec::ThreadedCluster;
use tdorch::graph::algorithms::{pagerank, sssp};
use tdorch::graph::flags::Flags;
use tdorch::graph::gen;
use tdorch::graph::ingest::ingestions;
use tdorch::graph::spmd::{ingest_once, GraphMeta, Placement, SpmdEngine};
use tdorch::repro::graphs::bits_equal;
use tdorch::serve::QueryShard;
use tdorch::workload::QueryKind;
use tdorch::{Cluster, CostModel, MachineId};

const PR_ITERS: usize = 10;
const ITERS: usize = 3;

// Per-kind resets, exactly what `serve::Server::run_query` pays per
// query (a full 4-shard reset would inflate the measured reset cost;
// tests pin the per-kind variant bit-identical).
fn reset_pr(m: MachineId, meta: &GraphMeta, st: &mut QueryShard) {
    st.reset_kind(QueryKind::Pr, m, meta);
}

fn reset_ss(m: MachineId, meta: &GraphMeta, st: &mut QueryShard) {
    st.reset_kind(QueryKind::Sssp, m, meta);
}

fn main() {
    let b = Bench::new("graph_wallclock");
    let g = gen::barabasi_albert(30_000, 8, 7);
    let cost = CostModel::paper_cluster();
    let ing0 = ingestions();
    println!("BA graph n={} m={}", g.n, g.m());

    for p in [4usize, 8] {
        // ONE ingestion, TWO long-lived engines (sim reference +
        // threaded), reused by every timed iteration below.
        let dg = ingest_once(&g, p, cost, Placement::Spread);
        let mut sim = SpmdEngine::from_ingested(
            Cluster::new(p, cost),
            dg.clone(),
            cost,
            Flags::tdo_gp(),
            "bench-sim",
            QueryShard::new,
        );
        let mut thr = SpmdEngine::from_ingested(
            ThreadedCluster::new(p),
            dg,
            cost,
            Flags::tdo_gp(),
            "bench-threaded",
            QueryShard::new,
        );

        // Reference bits from the simulator backend of the same engine.
        sim.reset_for_query(reset_pr);
        let pr_sim = pagerank(&mut sim, PR_ITERS);
        sim.reset_for_query(reset_ss);
        let ss_sim = sssp(&mut sim, 0);

        // ---- PageRank ----
        b.run(&format!("pagerank-sim-P{p}"), ITERS, || {
            sim.reset_for_query(reset_pr);
            pagerank(&mut sim, PR_ITERS).len()
        });

        let mut pr_runs: Vec<Vec<f64>> = Vec::new();
        b.run(&format!("pagerank-threaded-P{p}"), ITERS, || {
            thr.reset_for_query(reset_pr);
            let rank = pagerank(&mut thr, PR_ITERS);
            let n = rank.len();
            pr_runs.push(rank);
            n
        });
        for rank in &pr_runs {
            assert!(bits_equal(rank, &pr_sim), "threaded PR diverged from simulator");
        }
        println!(
            "    PR: max-loaded machine busy {:.2} ms; pool spawned {} threads for \
             {} superstep epochs so far",
            thr.sub().max_busy_ms(),
            thr.sub().pool_threads(),
            thr.sub().epochs(),
        );

        // ---- SSSP ----
        b.run(&format!("sssp-sim-P{p}"), ITERS, || {
            sim.reset_for_query(reset_ss);
            sssp(&mut sim, 0).len()
        });

        thr.sub_mut().reset_metrics();
        let mut ss_runs: Vec<Vec<f64>> = Vec::new();
        b.run(&format!("sssp-threaded-P{p}"), ITERS, || {
            thr.reset_for_query(reset_ss);
            let d = sssp(&mut thr, 0);
            let n = d.len();
            ss_runs.push(d);
            n
        });
        for d in &ss_runs {
            assert!(bits_equal(d, &ss_sim), "threaded SSSP diverged from simulator");
        }
        println!(
            "    SSSP: max-loaded machine busy {:.2} ms; pool spawned {} threads for \
             {} superstep epochs total; {} engine resets served",
            thr.sub().max_busy_ms(),
            thr.sub().pool_threads(),
            thr.sub().epochs(),
            thr.resets(),
        );
    }

    let ingested = ingestions() - ing0;
    assert_eq!(ingested, 2, "bench must ingest exactly once per machine count");
    println!("\ningestions: {ingested} (one per machine count, shared by both backends)");
}
